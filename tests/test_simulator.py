"""Property-based tests of the pipeline simulator invariants (hypothesis)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.data.pipeline import (criteo_pipeline, make_pipeline,
                                 stage_throughput)
from repro.data.simulator import (Allocation, MachineSpec, PipelineSim,
                                  OOM_RESTART_TICKS)


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 1000), n_stages=st.integers(3, 6))
def test_throughput_is_bottleneck(seed, n_stages):
    spec = make_pipeline(n_stages, seed=seed)
    sim = PipelineSim(spec, MachineSpec())
    rng = np.random.RandomState(seed)
    alloc = Allocation(rng.randint(1, 20, size=n_stages))
    rates = sim.stage_rates(alloc)
    assert sim.throughput(alloc) == pytest.approx(min(rates))


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 1000), stage=st.integers(0, 4),
       w=st.integers(1, 60))
def test_stage_rate_monotone_in_workers(seed, stage, w):
    spec = make_pipeline(5, seed=seed)
    st_ = spec.stages[stage]
    assert stage_throughput(st_, w + 1) >= stage_throughput(st_, w)
    # and concave-ish: marginal gain shrinks
    g1 = stage_throughput(st_, w + 1) - stage_throughput(st_, w)
    g2 = stage_throughput(st_, w + 2) - stage_throughput(st_, w + 1)
    assert g2 <= g1 + 1e-9


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 500))
def test_oracle_dominates_random(seed):
    spec = make_pipeline(5, seed=seed)
    machine = MachineSpec(n_cpus=64)
    sim = PipelineSim(spec, machine)
    _, best = sim.best_allocation()
    rng = np.random.RandomState(seed)
    for _ in range(5):
        w = rng.randint(1, 16, size=5)
        if w.sum() > machine.n_cpus:
            continue
        assert sim.throughput(Allocation(w)) <= best + 1e-9


def test_oom_restart_window():
    spec = criteo_pipeline()
    sim = PipelineSim(spec, MachineSpec(mem_mb=4096))
    # allocation whose prefetch blows the memory cap
    alloc = Allocation(np.ones(5, dtype=int), prefetch_mb=1e6)
    m = sim.apply(alloc)
    assert m["oom"] and m["throughput"] == 0.0
    ok = Allocation(np.ones(5, dtype=int), prefetch_mb=64)
    for _ in range(OOM_RESTART_TICKS):
        m = sim.apply(ok)
        assert m["throughput"] == 0.0   # still restarting
    m = sim.apply(ok)
    assert m["throughput"] > 0          # recovered


def test_oversubscription_slows_down():
    spec = criteo_pipeline()
    sim = PipelineSim(spec, MachineSpec(n_cpus=16))
    small = Allocation(np.full(5, 3))    # 15 <= 16
    big = Allocation(np.full(5, 32))     # 160 > 16 -> scaled down
    t_small = sim.apply(small)["throughput"]
    t_big = sim.apply(big)["throughput"]
    assert t_big < sim.throughput(big)   # penalty applied


def test_resize_changes_capacity():
    spec = criteo_pipeline()
    sim = PipelineSim(spec, MachineSpec(n_cpus=128))
    a128, t128 = sim.best_allocation()
    sim.resize(32)
    a32, t32 = sim.best_allocation()
    assert t32 < t128
    assert a32.workers.sum() <= 32
