"""repro.api surface: Session semantics, the tune() one-liner, typed
results, and allocation validation.

The legacy benchmarks.common loops finished their one-PR deprecation
cycle and are gone; the protocol-semantics pins that used to ride on
the shims now exercise the direct Session path (and one test guards
that the shims stay deleted)."""
import numpy as np
import pytest

from repro.api import (AllocationError, DeadWindow, RELAUNCH_TICKS,
                       ResizeEvent, RunResult, Session, SimBackend,
                       Telemetry, tune, make_backend, resize_events,
                       validate_allocation, validate_fleet_allocation)
from repro.core.optimizer import make_optimizer
from repro.data.fleet import (ClusterSpec, FleetAllocation, FleetEvent,
                              TrainerSpec, demo_cluster)
from repro.data.pipeline import criteo_pipeline
from repro.data.simulator import Allocation, MachineSpec, resize_schedule

SPEC = criteo_pipeline()
MACHINE = MachineSpec(n_cpus=64, mem_mb=65536.0)


# ------------------------------------------------------------ session -----
def test_session_resize_event_changes_capacity_mid_run():
    opt = make_optimizer("heuristic", SPEC, MACHINE)
    res = Session(SimBackend(SPEC, MACHINE, seed=0), opt).run(
        10, events=[ResizeEvent(5, 32)])
    assert res.used_cpus[4] > res.used_cpus[5]          # re-proposed at 32
    assert res.ticks == 10


def test_session_dead_window_zeroes_ticks():
    opt = make_optimizer("oracle", SPEC, MACHINE)
    res = Session(SimBackend(SPEC, MACHINE, seed=0), opt).run(
        8, events=[DeadWindow(2, 3)])
    assert res.throughput[0] > 0.0 and res.throughput[5] > 0.0
    assert res.throughput[2:5] == [0.0, 0.0, 0.0]
    assert res.used_cpus[2:5] == [0, 0, 0]


def test_session_relaunch_dead_charged_on_proposal_change():
    opt = make_optimizer("heuristic", SPEC, MACHINE)
    res = Session(SimBackend(SPEC, MACHINE, seed=0), opt).run(
        10, events=[ResizeEvent(4, 32)], relaunch_dead=3)
    assert res.throughput[3] > 0.0
    assert res.throughput[4:7] == [0.0, 0.0, 0.0]       # re-proposal paid
    assert res.throughput[7] > 0.0


def test_session_collect_sees_telemetry_every_tick():
    seen = []
    opt = make_optimizer("oracle", SPEC, MACHINE)
    Session(SimBackend(SPEC, MACHINE, seed=0), opt).run(
        4, collect=lambda t, tel: seen.append((t, tel)))
    assert [t for t, _ in seen] == [0, 1, 2, 3]
    assert all(isinstance(tel, Telemetry) for _, tel in seen)


def test_run_result_is_mapping_compatible():
    opt = make_optimizer("oracle", SPEC, MACHINE)
    res = Session(SimBackend(SPEC, MACHINE, seed=0), opt).run(3)
    assert isinstance(res, RunResult)
    assert res["throughput"] == res.throughput
    assert res["oom_count"] == res.oom_count == 0
    assert res.get("missing") is None and "mem_mb" in res
    assert set(res.to_dict()) >= {"throughput", "used_cpus", "mem_mb",
                                  "oom_count"}


# --------------------------------------------------------------- tune -----
def test_tune_one_liner_sim():
    res = tune(SPEC, MACHINE, optimizer="oracle", backend="sim", ticks=5)
    assert res.ticks == 5 and min(res.throughput) > 0
    assert res.extras["optimizer"].name == "oracle"


def test_tune_one_liner_fleet():
    cluster = demo_cluster(40)
    res = tune(cluster, optimizer="fleet_even", backend="sim", ticks=8,
               relaunch_dead=2)
    assert res.ticks == 8
    assert res.extras["optimizer"].name == "fleet_even"


def test_make_backend_rejects_unknown_names():
    with pytest.raises(KeyError, match="unknown single backend"):
        make_backend("warp_drive", SPEC, MACHINE)
    with pytest.raises(KeyError, match="unknown fleet backend"):
        make_backend("warp_drive", demo_cluster(10))
    with pytest.raises(TypeError, match="needs a MachineSpec"):
        make_backend("sim", SPEC)           # machine=None: named error
    with pytest.raises(TypeError, match="silently ignored"):
        make_backend("sim", demo_cluster(10), MACHINE)


def test_wrapped_executor_counts_oom_entries_without_killing():
    from repro.api import ExecutorBackend
    from repro.data.executor import ThreadedPipeline
    from repro.data.live_fleet import (live_linear_pipeline,
                                       synthetic_stage_fns)
    spec = live_linear_pipeline()
    pipe = ThreadedPipeline(spec, fns=synthetic_stage_fns(spec),
                            queue_depth=4,
                            machine=MachineSpec(n_cpus=4, mem_mb=2500.0))
    backend = ExecutorBackend.wrap(pipe, window_s=0.02)
    try:
        ok = Allocation(np.ones(5, dtype=int), prefetch_mb=16.0)
        over = Allocation(np.full(5, 3, dtype=int), prefetch_mb=16.0)
        assert backend.apply(ok).oom is False
        # two consecutive over-budget ticks = ONE observed OOM entry;
        # wrap mode reports, it never kills the user's process
        assert backend.apply(over).oom is True
        assert backend.apply(over).oom is True
        assert backend.oom_count == 1
        assert backend.apply(ok).oom is False
        assert backend.apply(over).oom is True      # re-entry counts again
        assert backend.oom_count == 2
    finally:
        acct = backend.shutdown()
    assert acct["all_joined"] is True


# --------------------------------------------------------- validation -----
def test_validate_allocation_rejects_bad_shapes():
    with pytest.raises(AllocationError, match="negative worker count"):
        validate_allocation(SPEC, Allocation(np.array([1, -2, 1, 1, 1])))
    with pytest.raises(AllocationError, match="5 stages"):
        validate_allocation(SPEC, Allocation(np.ones(3, dtype=int)))
    with pytest.raises(AllocationError, match="prefetch_mb"):
        validate_allocation(
            SPEC, Allocation(np.ones(5, dtype=int), prefetch_mb=-1.0))
    with pytest.raises(AllocationError, match="integers"):
        validate_allocation(SPEC, Allocation(np.ones(5)))
    with pytest.raises(AllocationError, match="1-D"):
        validate_allocation(SPEC, Allocation(np.ones((5, 1), dtype=int)))
    # a valid allocation passes silently
    validate_allocation(SPEC, Allocation(np.ones(5, dtype=int), 64.0))


def test_validate_fleet_allocation():
    cluster = demo_cluster(40)
    ok = {t.name: Allocation(np.ones(t.pipeline.n_stages, dtype=int), 8.0)
          for t in cluster.trainers}
    validate_fleet_allocation(cluster, FleetAllocation(dict(ok), {"big": 1}))
    with pytest.raises(AllocationError, match="unknown trainer"):
        validate_fleet_allocation(cluster, FleetAllocation(
            {"nope": Allocation(np.ones(5, dtype=int))}))
    with pytest.raises(AllocationError, match="trainer 'big'"):
        bad = dict(ok)
        bad["big"] = Allocation(np.array([1, 1, -1, 1, 1]))
        validate_fleet_allocation(cluster, FleetAllocation(bad))
    with pytest.raises(AllocationError, match="negative pool grant"):
        validate_fleet_allocation(
            cluster, FleetAllocation(dict(ok), {"big": -2}))


def test_sim_backend_rejects_bad_allocation_before_apply():
    backend = SimBackend(SPEC, MACHINE, seed=0)
    with pytest.raises(AllocationError):
        backend.apply(Allocation(np.ones(7, dtype=int)))
    assert backend.snapshot()["time"] == 0      # nothing was applied


# ------------------------------------------- legacy protocol, direct ------
def test_readapt_policy_reproduces_legacy_static_protocol():
    """The direct Session path (ReadaptPolicy + ResizeEvent/DeadWindow)
    must reproduce the pre-API run_static loop exactly, including the
    quirk that a readapt policy pays the relaunch window at EVERY
    scheduled resize tick (even a same-cap re-cap). This pin used to
    ride on the deprecation shim; the shim is gone, the protocol
    contract is not."""
    from benchmarks.common import ReadaptPolicy
    from repro.core import baselines as B
    resizes = [(0, 64), (20, 32)]
    alloc = B.heuristic_even(SPEC, MACHINE)
    events = resize_events(resizes) + [DeadWindow(t, RELAUNCH_TICKS)
                                       for t, _ in resizes]
    opt = ReadaptPolicy(alloc, lambda s, m, seed: B.heuristic_even(s, m),
                        seed=0, resize_ticks=[t for t, _ in resizes])
    res = Session(SimBackend(SPEC, MACHINE, seed=0), opt).run(
        50, events=events)
    # hand-rolled legacy loop (the pre-PR4 implementation, verbatim)
    from repro.data.simulator import PipelineSim
    sim = PipelineSim(SPEC, MACHINE, seed=0)
    tput, mem, used = [], [], []
    dead, cur, rmap = 0, alloc, dict(resizes)
    for t in range(50):
        if t in rmap:
            sim.resize(rmap[t])
            cur = B.heuristic_even(SPEC, sim.machine)
            dead = RELAUNCH_TICKS
        if dead > 0:
            dead -= 1
            m = {"throughput": 0.0, "mem_mb": 0.0, "used_cpus": 0}
            sim.time += 1
        else:
            m = sim.apply(cur)
        tput.append(m["throughput"])
        used.append(min(m["used_cpus"], sim.machine.n_cpus))
        mem.append(m["mem_mb"])
    assert list(res["throughput"]) == tput
    assert list(res["used_cpus"]) == used
    assert list(res["mem_mb"]) == mem


def test_telemetry_items_and_values():
    tel = Telemetry(1.0, 2.0, 3, False, False, {"pool": 4})
    assert dict(tel.items())["pool"] == 4
    assert 1.0 in tel.values()
    assert {k: v for k, v in tel.items()} == tel.to_dict()


def test_deprecation_shims_are_gone():
    """The one-PR deprecation window is over: benchmarks.common must not
    grow the legacy loops back (ROADMAP: 'can be dropped next PR')."""
    from benchmarks import common
    for name in ("run_static", "run_optimizer", "run_fleet_optimizer",
                 "run_intune", "run_intune_protocol"):
        assert not hasattr(common, name), \
            f"benchmarks.common.{name} should stay deleted"
        assert name not in common.__all__


# ------------------------------------------------ constants / events ------
def test_relaunch_ticks_single_source_of_truth():
    from benchmarks import common
    from repro.api import constants
    assert common.RELAUNCH_TICKS is constants.RELAUNCH_TICKS
    assert RELAUNCH_TICKS == 20


def test_resize_events_lifts_legacy_schedule():
    evs = resize_events(resize_schedule(100))
    assert evs[0] == ResizeEvent(0, 32)
    assert [e.tick for e in evs] == [0, 20, 40, 60, 80]


def test_fleet_backend_inject_event_merges_pending_tail():
    from repro.data.fleet import FleetSim
    cluster = demo_cluster(100)           # late joins at tick 33
    sim = FleetSim(cluster, seed=0)
    sim.inject_event(FleetEvent(tick=5, kind="leave", trainer="mid"))
    state = sim.machine
    assert "mid" in state.active          # tick 0: not yet
    sim.time = 5
    assert "mid" not in sim.machine.active
    sim.time = 33
    assert "late" in sim.machine.active   # original schedule intact
