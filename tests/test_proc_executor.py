"""ProcessPipeline + calibration: the process plane's own suite.

Three layers:
  - mechanics (tier-1, sleep-free): the ThreadedPipeline contract on
    real worker processes — round trip, join alignment, clean teardown
    accounting — plus the stale-rate-meter regression (a starved stage
    must report a falling rate, not its last healthy EWMA) and the
    closed-form Amdahl fit.
  - physics (slow): measured RSS moves with the worker ballast; the
    measured-RSS OOM judge kills, pays the dead window, and relaunches.
  - sim <-> proc transfer (slow): measured throughput RANKS candidate
    allocations the way PipelineSim predicts (the process-plane sibling
    of tests/test_sim_vs_executor.py — rank-based, never absolute), and
    calibration recovers a designed serial_frac within 20%.
"""
import os
import time

import numpy as np
import pytest

from repro.data.calibrate import calibrate_stagegraph, fit_amdahl
from repro.data.executor import _RateMeter, ThreadedPipeline
from repro.data.pipeline import StageGraph, StageSpec
from repro.data.proc_executor import ProcessPipeline, spin_stage_fns
from repro.data.simulator import (Allocation, MachineSpec,
                                  OOM_RESTART_TICKS, PipelineSim)


def _stage(name, cost, serial=0.0, inputs=(), mem=4.0):
    return StageSpec(name, "udf", cost=cost, serial_frac=serial,
                     mem_per_worker_mb=mem, inputs=inputs)


# ------------------------------------------------- rate-meter regression --
def test_rate_meter_decays_on_read():
    """ISSUE 5 satellite: the EWMA only updated inside mark(), so a
    dead stage reported its last healthy rate forever. The rate must
    now decay on read, falling toward 0 for a stalled stage."""
    m = _RateMeter(alpha=0.5)
    for _ in range(8):
        m.mark()
        time.sleep(0.01)
    healthy = m.rate
    assert healthy > 20.0           # ~100/s marks, EWMA mostly converged
    time.sleep(0.3)
    stale = m.rate
    assert stale <= 1.0 / 0.3 + 0.5          # capped by 1/overdue
    assert stale < healthy / 2               # fell, did not stick
    time.sleep(0.2)
    assert m.rate < stale                    # keeps falling toward 0


def test_rate_meter_mark_many_matches_counter_feed():
    m = _RateMeter(alpha=1.0)       # alpha 1: rate == last window rate
    m.mark_many(5, now=100.0)       # first mark: seeds the clock
    m.mark_many(10, now=102.0)      # 10 events over 2s
    # read immediately (inject no staleness): EWMA is 5/s
    assert m.count == 15
    assert m._ewma == pytest.approx(5.0)


def test_stalled_stage_stats_fall_toward_zero():
    """End-to-end: a ThreadedPipeline whose stream ends keeps serving
    stats(); the reported stage rates must decay, not freeze."""
    produced = [0]

    def src():
        if produced[0] >= 15:
            return None             # EOS: the stage starves from here
        produced[0] += 1
        return produced[0]

    spec = StageGraph("lin2", (_stage("src", 0.001),
                               _stage("sink", 0.001, inputs=("src",))),
                      batch_mb=1.0)
    pipe = ThreadedPipeline(spec, fns={"src": src, "sink": lambda x: x},
                            queue_depth=4, item_mb=1.0)
    try:
        got = 0
        while True:
            try:
                pipe.get_batch(timeout=5.0)
                got += 1
            except StopIteration:
                break
        assert got >= 10
        time.sleep(0.4)
        rates = pipe.stats()["stage_rate"]
        assert all(r <= 1.0 / 0.4 + 1.0 for r in rates), rates
    finally:
        pipe.stop()


# ----------------------------------------------------- amdahl fit (math) --
def test_fit_amdahl_recovers_exact_curve():
    cost, s = 0.02, 0.3
    rates = [1.0 / (cost * (s + (1 - s) / a)) for a in (1, 2, 3, 4)]
    c_hat, s_hat = fit_amdahl((1, 2, 3, 4), rates)
    assert c_hat == pytest.approx(cost)
    assert s_hat == pytest.approx(s)


def test_fit_amdahl_edge_cases():
    # single point: underdetermined -> cost = 1/rate, serial 0
    c_hat, s_hat = fit_amdahl([2], [10.0])
    assert c_hat == pytest.approx(0.1) and s_hat == 0.0
    # perfectly linear scaling -> serial 0
    c_hat, s_hat = fit_amdahl((1, 2, 4), [10.0, 20.0, 40.0])
    assert c_hat == pytest.approx(0.1) and s_hat == pytest.approx(0.0)
    # fully serial: flat curve -> serial 1
    c_hat, s_hat = fit_amdahl((1, 2, 4), [10.0, 10.0, 10.0])
    assert s_hat == pytest.approx(1.0)
    with pytest.raises(ValueError):
        fit_amdahl([], [])


# ------------------------------------------------------- proc mechanics ---
def test_process_pipeline_round_trip_and_clean_teardown():
    spec = StageGraph("p2", (_stage("src", 0.002),
                             _stage("work", 0.002, inputs=("src",))),
                      batch_mb=1.0)
    pipe = ProcessPipeline(spec, machine=MachineSpec(n_cpus=2,
                                                     mem_mb=4096.0),
                           item_mb=1.0)
    try:
        pipe.set_allocation([1, 2], prefetch_mb=8.0)
        assert pipe.worker_counts() == [1, 2]
        for _ in range(12):
            assert pipe.get_batch(timeout=20.0) is not None
        c = pipe.counters()
        assert c["consumed"] == 12 and c["delivered"] >= 12
        stats = pipe.stats()
        assert stats["workers"] == [1, 2]
        assert stats["rss_mb"] > 0.0         # measured, not declared
        assert stats["mem_frac"] == stats["rss_mb"] / 4096.0
    finally:
        acct = pipe.shutdown(drain=True)
    assert acct["joined"] is True
    assert acct["dropped"] == 0
    assert acct["delivered"] == acct["consumed"] + acct["drained"]


def test_process_pipeline_join_graph_aligned():
    spec = StageGraph("j4", (
        _stage("a", 0.002), _stage("b", 0.002),
        _stage("j", 0.001, inputs=("a", "b")),
        _stage("s", 0.001, inputs=("j",)),
    ), batch_mb=1.0)
    pipe = ProcessPipeline(spec, machine=MachineSpec(n_cpus=2,
                                                     mem_mb=4096.0),
                           item_mb=1.0)
    try:
        pipe.set_allocation([1, 1, 1, 1], prefetch_mb=8.0)
        for _ in range(8):
            item = pipe.get_batch(timeout=20.0)
            # join pairs one item per input: (a_item, b_item) forwarded
            assert isinstance(item, tuple) and len(item) == 2
    finally:
        acct = pipe.shutdown(drain=True)
    assert acct["joined"] is True and acct["dropped"] == 0


def test_process_pipeline_prefetch_gate_rebounds_live():
    spec = StageGraph("p1", (_stage("src", 0.001),), batch_mb=1.0)
    pipe = ProcessPipeline(spec, machine=MachineSpec(n_cpus=1,
                                                     mem_mb=4096.0),
                           item_mb=1.0)
    try:
        pipe.set_allocation([1], prefetch_mb=4.0)
        assert pipe.prefetch_depth == 4
        pipe.set_allocation([1], prefetch_mb=32.0)
        assert pipe.prefetch_depth == 32
    finally:
        pipe.shutdown(drain=False)


# ------------------------------------------------------- memory physics ---
@pytest.mark.slow
def test_rss_grows_with_worker_ballast():
    spec = StageGraph("mem1", (
        StageSpec("src", "source", cost=0.005, serial_frac=0.0,
                  mem_per_worker_mb=48.0),), batch_mb=1.0)
    pipe = ProcessPipeline(spec, fns=spin_stage_fns(spec),
                           machine=MachineSpec(n_cpus=4, mem_mb=8192.0),
                           item_mb=1.0)
    try:
        pipe.set_allocation([1], prefetch_mb=8.0)
        time.sleep(1.2)                      # calibration + ballast touch
        rss1 = pipe.rss_mb()
        assert rss1 > 30.0                   # one worker's ballast resident
        pipe.set_allocation([3], prefetch_mb=8.0)
        time.sleep(1.5)
        rss3 = pipe.rss_mb()
        # two more workers = two more 48MB ballasts (Pss-shared pages make
        # the exact delta fuzzy; 60MB of the designed 96MB must show up)
        assert rss3 > rss1 + 60.0, (rss1, rss3)
    finally:
        pipe.shutdown(drain=False)


@pytest.mark.slow
def test_proc_backend_measured_oom_kill_and_relaunch():
    """The measured-RSS OOM judge: a machine whose mem_mb is below the
    pipeline's real resident footprint OOMs, pays OOM_RESTART_TICKS dead
    ticks, relaunches, and (still over) crash-loops — the Fig. 5B
    behavior on real processes."""
    from repro.api import make_backend
    spec = StageGraph("oom2", (
        StageSpec("src", "source", cost=0.004, serial_frac=0.0,
                  mem_per_worker_mb=48.0),
        StageSpec("work", "udf", cost=0.008, serial_frac=0.0,
                  mem_per_worker_mb=48.0, inputs=("src",)),
    ), batch_mb=1.0)
    # two workers x 48MB touched ballast = ~96MB of measured private
    # pages against a 64MB machine: the kernel-observed verdict
    be = make_backend("proc", spec, MachineSpec(n_cpus=4, mem_mb=64.0),
                      window_s=0.05, ballast=True)
    alloc = Allocation(np.ones(2, dtype=int), prefetch_mb=16.0)
    try:
        time.sleep(1.0)                      # calibration + ballast touch
        tel = be.apply(alloc)
        assert tel.oom is True and tel.restarting is True
        assert tel.mem_mb > 64.0             # the measured verdict
        assert be.oom_count == 1
        for _ in range(OOM_RESTART_TICKS):
            tel = be.apply(alloc)
            assert tel.restarting is True and tel.throughput == 0.0
            assert tel.oom is False
        # dead window expired: the relaunch happened on its last tick
        assert be.stats() is not None
        time.sleep(1.0)                      # fresh workers re-ballast
        tel = be.apply(alloc)                # still over: crash loop
        assert tel.oom is True and be.oom_count == 2
    finally:
        acct = be.shutdown()
    assert acct["all_joined"] is True
    assert acct["oom_count"] == 2


# ------------------------------------------------- sim <-> proc transfer --
@pytest.mark.slow
def test_sim_vs_proc_differential_ranking():
    """Measured throughput must rank candidate allocations the way
    PipelineSim predicts (rank-based: absolute rates read low under IPC
    overhead and host virtualization, rankings transfer).

    Design notes for a small/throttled host: the winning candidate's
    CPU demand stays near the host's real capacity (misplacing a worker
    on the cheap stage vs placing it on the bottleneck), and the two
    candidates are measured INTERLEAVED so second-scale host-speed
    drift hits both symmetrically."""
    from repro.api import make_backend
    if (os.cpu_count() or 1) < 2:
        # within-pipeline placement needs at least two runnable workers:
        # on one core the cheap stage blocks on a full queue either way,
        # so both candidates measure the same and rank is undefined
        pytest.skip("sim->proc placement ranking needs >= 2 CPUs")
    spec = StageGraph("d2", (_stage("src", 0.005),
                             _stage("work", 0.06, inputs=("src",))),
                      batch_mb=1.0)
    candidates = [[2, 1], [1, 2]]    # waste on src vs fix the bottleneck
    sim = PipelineSim(spec, MachineSpec(n_cpus=64, mem_mb=65536.0))
    predicted = [sim.throughput(Allocation(np.asarray(w, dtype=int)))
                 for w in candidates]
    assert predicted[1] / predicted[0] >= 1.9    # designed separation
    be = make_backend("proc", spec, MachineSpec(n_cpus=8, mem_mb=8192.0),
                      window_s=0.4, ballast=False)
    sums = [0.0, 0.0]
    try:
        time.sleep(1.0)                      # worker spin calibration
        for _ in range(3):
            for i, w in enumerate(candidates):
                alloc = Allocation(np.asarray(w, dtype=int),
                                   prefetch_mb=16.0)
                be.apply(alloc)              # settle: resize + warm pools
                time.sleep(0.5)
                sums[i] += float(np.mean(
                    [be.apply(alloc).throughput for _ in range(2)]))
    finally:
        be.shutdown()
    measured = [s / 3.0 for s in sums]
    ranked = (np.argsort(predicted).tolist()
              == np.argsort(measured).tolist()
              and measured[1] > measured[0] * 1.1)   # real, not noise
    if not ranked and measured[0] < 0.85 * predicted[0]:
        # a burstable host that has exhausted its CPU budget cannot even
        # realize the BASELINE candidate (~1 core of demand), so no wall
        # measurement can separate candidates: the rank claim is
        # untestable here rather than false. Only skip when the ranking
        # actually failed AND the baseline shows the capacity cap — a
        # healthy host must still prove the transfer.
        pytest.skip(f"host too throttled for rank transfer: baseline "
                    f"measured {measured[0]:.1f} of {predicted[0]:.1f} "
                    f"predicted b/s")
    assert ranked, f"sim ranks {predicted} but proc measures {measured}"


@pytest.mark.slow
def test_calibration_recovers_designed_serial_frac():
    """The acceptance bar: sweep workers, fit Amdahl, recover a designed
    serial_frac within 20% (and the designed cost to ~30%); the
    calibrated StageGraph must be directly consumable by the sim."""
    spec = StageGraph("cal2", (
        StageSpec("par", "source", cost=0.06, serial_frac=0.0,
                  mem_per_worker_mb=4.0),
        StageSpec("ser", "udf", cost=0.12, serial_frac=0.5,
                  mem_per_worker_mb=4.0, inputs=("par",)),
    ), batch_mb=1.0)
    cal, report = calibrate_stagegraph(spec, workers=(1, 2, 3),
                                       window_s=2.0)
    ser = report["ser"]
    assert abs(ser["serial_frac"] - 0.5) <= 0.1, report
    assert abs(ser["cost"] - 0.12) <= 0.03, report
    par = report["par"]
    assert par["serial_frac"] <= 0.15, report
    assert abs(par["cost"] - 0.06) <= 0.015, report
    # the calibrated graph feeds the analytic plane directly: the
    # measured sim <-> live closure
    sim = PipelineSim(cal, MachineSpec(n_cpus=8, mem_mb=8192.0))
    tput = sim.throughput(Allocation(np.array([2, 4], dtype=int)))
    assert tput == pytest.approx(
        1.0 / (ser["cost"] * (ser["serial_frac"]
                              + (1 - ser["serial_frac"]) / 4)), rel=0.35)
