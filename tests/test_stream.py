"""data/stream.py + the streaming plane (ISSUE 7): arrival-process
determinism and exact integrals, the simulator's backlog/staleness/shed
accounting against hand-computed recursions, backlog-driven OOM, the
process plane's rate-limited source pacing, the controller's
staleness-AGING reward and its re-adaptation triggers, and (slow) the
fig_stream sim acceptance run."""
import math
import multiprocessing as mp
import sys
import time
from pathlib import Path

import numpy as np
import pytest

from repro.api.telemetry import Telemetry
from repro.core.controller import InTune
from repro.data.pipeline import StageGraph, StageSpec
from repro.data.proc_executor import StreamSourceWork
from repro.data.simulator import Allocation, MachineSpec, PipelineSim
from repro.data.stream import ArrivalProcess, flash_crowd_arrivals


# ------------------------------------------------------ arrival process --

def test_arrivals_deterministic_under_seed():
    kw = dict(users=4096.0, events_per_user_s=1.0, burst_every_s=30.0,
              burst_len_s=5.0, burst_gain=3.0, horizon_s=600.0)
    a, b = ArrivalProcess(seed=7, **kw), ArrivalProcess(seed=7, **kw)
    assert a._bursts == b._bursts
    ts = np.linspace(0.0, 600.0, 601)
    assert [a.events_per_sec(t) for t in ts] \
        == [b.events_per_sec(t) for t in ts]
    c = ArrivalProcess(seed=8, **kw)
    assert a._bursts != c._bursts


def test_constant_rate_integral_is_exact():
    arr = ArrivalProcess(users=4096.0, events_per_user_s=1.0,
                         events_per_batch=4096.0)
    # 1 batch/s, no shape: the integral is just the elapsed time
    assert arr.batches_per_sec(123.0) == pytest.approx(1.0)
    assert arr.batches_between(10.0, 17.5) == pytest.approx(7.5)
    assert arr.batches_before(42.0) == pytest.approx(42.0)
    assert arr.batches_between(5.0, 5.0) == 0.0
    assert arr.batches_between(9.0, 3.0) == 0.0


def test_flash_crowd_integral_splits_at_boundaries():
    arr = flash_crowd_arrivals(2.0, spike_at_s=10.0, spike_len_s=4.0,
                               spike_gain=10.0)
    # window straddles the spike start: 2 s at 2 b/s + 3 s at 20 b/s
    assert arr.batches_between(8.0, 13.0) == pytest.approx(2 * 2 + 3 * 20)
    # wholly inside / wholly outside
    assert arr.batches_between(10.0, 14.0) == pytest.approx(4 * 20)
    assert arr.batches_between(14.0, 20.0) == pytest.approx(6 * 2)


def test_diurnal_integral_matches_quadrature():
    arr = ArrivalProcess(users=4096.0, events_per_user_s=2.0,
                         diurnal_amp=0.4, diurnal_period_s=97.0,
                         diurnal_phase_s=13.0,
                         flash_crowds=((20.0, 11.0, 5.0),))
    ts = np.linspace(3.0, 71.0, 200001)
    rates = np.array([arr.events_per_sec(t) for t in ts])
    numeric = float(np.trapezoid(rates, ts))
    assert arr.events_between(3.0, 71.0) == pytest.approx(numeric, rel=1e-6)


def test_diurnal_amp_bounds():
    with pytest.raises(ValueError):
        ArrivalProcess(diurnal_amp=1.0)


# ----------------------------------------------------- sim stream plane --

def _stream_spec(arrival, cost=0.5, **graph_kw):
    stages = (
        StageSpec("src", "stream", cost=cost, arrival=arrival),
        StageSpec("sink", "batch", cost=cost, inputs=("src",)),
    )
    return StageGraph("t_stream", stages, batch_mb=1.0,
                      target_rate=arrival.batches_per_sec(0.0), **graph_kw)


def test_sim_backlog_and_staleness_recursion():
    """The reported freshness metrics must satisfy the definitional
    recursion bl_k = max(0, bl_{k-1} + arrivals_k - tput_k * tick_s) and
    stale = backlog / drain rate, with arrivals the exact integral."""
    arr = ArrivalProcess(users=5 * 4096.0, events_per_user_s=1.0)  # 5 b/s
    spec = _stream_spec(arr, cost=0.5)  # 1 worker/stage => 2 b/s capacity
    sim = PipelineSim(spec, MachineSpec(n_cpus=8, mem_mb=8192.0),
                      obs_noise=0.0)
    alloc = Allocation(np.array([1, 1], dtype=int), prefetch_mb=2.0)
    bl = 0.0
    for k in range(10):
        out = sim.apply(alloc)
        arrivals = arr.batches_between(float(k), float(k + 1))
        assert out["arrival_rate"] == pytest.approx(arrivals)
        bl = max(0.0, bl + arrivals - out["throughput"] * 1.0)
        assert out["backlog_items"] == pytest.approx(bl)
        assert out["batch_staleness_s"] == pytest.approx(
            bl / out["throughput"])
        assert out["p99_queue_delay_s"] >= 0.0
    # undersized by 3 b/s: ten ticks of unbounded growth
    assert bl == pytest.approx(30.0, abs=1.0)


def test_sim_stream_caps_service_at_arrivals():
    """An overprovisioned allocation cannot process events that have not
    happened: throughput tracks the arrival rate and backlog stays 0."""
    arr = ArrivalProcess(users=4096.0, events_per_user_s=1.0)  # 1 b/s
    spec = _stream_spec(arr, cost=0.01)  # 100 b/s per worker
    sim = PipelineSim(spec, MachineSpec(n_cpus=8, mem_mb=8192.0),
                      obs_noise=0.0)
    alloc = Allocation(np.array([2, 2], dtype=int), prefetch_mb=2.0)
    for _ in range(5):
        out = sim.apply(alloc)
        assert out["throughput"] == pytest.approx(1.0, rel=0.01)
        assert out["backlog_items"] == pytest.approx(0.0, abs=1e-6)
        assert out["batch_staleness_s"] == pytest.approx(0.0, abs=1e-6)


def test_sim_ooms_on_backlog_growth():
    """Backlogged batches hold buffer memory: an undersized allocation
    must OOM from backlog growth alone, and the backlog keeps accruing
    through the restart dead window (the world does not pause)."""
    arr = ArrivalProcess(users=5 * 4096.0, events_per_user_s=1.0,
                         buffer_mb_per_batch=50.0)
    spec = _stream_spec(arr, cost=0.5)
    sim = PipelineSim(spec, MachineSpec(n_cpus=8, mem_mb=1000.0),
                      obs_noise=0.0)
    alloc = Allocation(np.array([1, 1], dtype=int), prefetch_mb=2.0)
    outs = [sim.apply(alloc) for _ in range(20)]
    assert sim.oom_count >= 1
    first = next(i for i, o in enumerate(outs) if o["oom"])
    # dead window: no draining, backlog strictly grows
    assert outs[first + 1]["restarting"]
    assert outs[first + 1]["backlog_items"] > outs[first]["backlog_items"]


def test_sim_retention_cap_sheds():
    arr = ArrivalProcess(users=5 * 4096.0, events_per_user_s=1.0,
                         buffer_cap_batches=4.0)
    spec = _stream_spec(arr, cost=0.5)
    sim = PipelineSim(spec, MachineSpec(n_cpus=8, mem_mb=8192.0),
                      obs_noise=0.0)
    alloc = Allocation(np.array([1, 1], dtype=int), prefetch_mb=2.0)
    out = None
    for _ in range(10):
        out = sim.apply(alloc)
        assert out["backlog_items"] <= 4.0 + 1e-9
    assert out["shed_batches"] > 0.0


def test_non_stream_spec_reports_no_freshness():
    from repro.data.pipeline import criteo_pipeline
    sim = PipelineSim(criteo_pipeline(), MachineSpec(), obs_noise=0.0)
    out = sim.apply(Allocation(np.array([1, 1, 1, 1, 1], dtype=int),
                               prefetch_mb=2.0))
    assert "backlog_items" not in out and "batch_staleness_s" not in out


# ---------------------------------------------------- proc source pacing --

def test_stream_source_work_paces_emissions():
    """In-process (no forks): a StreamSourceWork must emit at the arrival
    curve's pace, not the CPU's — the token bucket is the same integral
    the simulator scores."""
    arr = ArrivalProcess(users=40 * 4096.0, events_per_user_s=1.0)  # 40 b/s
    work = StreamSourceWork(cost=1e-4, arrival=arr)
    emitted = mp.Value("L", 0)
    t0 = mp.Value("d", time.monotonic())
    work.attach_stream(emitted, t0)
    deadline = time.monotonic() + 0.5
    while time.monotonic() < deadline:
        work()
    elapsed = time.monotonic() - t0.value
    available = arr.batches_before(elapsed)
    # never ahead of the world; CPU is ~100x faster than the curve, so
    # it should also not fall meaningfully behind it
    assert emitted.value <= available + 1e-9
    assert emitted.value >= 0.5 * available


def test_stream_source_work_unthrottled_without_attach():
    arr = ArrivalProcess(users=4096.0, events_per_user_s=1.0)  # 1 b/s
    work = StreamSourceWork(cost=1e-4, arrival=arr)
    # no attach_stream: degrades to a plain source, no pacing, no skips
    for _ in range(50):
        assert work() is not None


# ------------------------------------------------- controller freshness --

def _tuner(**kw):
    arr = flash_crowd_arrivals(2.0, spike_at_s=1e9, spike_len_s=1.0)
    spec = _stream_spec(arr, cost=0.1)
    machine = MachineSpec(n_cpus=8, mem_mb=4096.0)
    tuner = InTune(spec, machine, seed=0, head="factored",
                   init_alloc=Allocation(np.array([1, 1], dtype=int),
                                         prefetch_mb=2.0), **kw)
    return spec, machine, tuner


def _tel(tput=5.0, stale=None):
    return Telemetry(throughput=tput, mem_mb=64.0, used_cpus=2,
                     batch_staleness_s=stale,
                     backlog_items=None if stale is None else stale * tput)


def test_reward_charges_staleness_growth_not_level():
    """Absolute staleness is non-stationary across a spike (minute 5
    scores worse than minute 1 under the SAME allocation); the reward
    must charge the per-window GROWTH instead."""
    spec, machine, tuner = _tuner(stale_scale=1.0)
    tuner.propose(spec, machine, None)
    tuner.observe(_tel(stale=3.0))          # aging 3 from a fresh start
    r_growing = tuner.history[-1]["reward"]
    tuner.propose(spec, machine, None)
    tuner.observe(_tel(stale=3.0))          # same level, aging 0
    r_holding = tuner.history[-1]["reward"]
    tuner.propose(spec, machine, None)
    tuner.observe(_tel(stale=1.0))          # draining: aging clamped to 0
    r_draining = tuner.history[-1]["reward"]
    assert r_growing == pytest.approx(r_holding / 4.0)   # 1/(1+3/1)
    assert r_holding == pytest.approx(r_draining)
    # and a fresh pipe scores the same as a draining one at equal tput
    tuner.propose(spec, machine, None)
    tuner.observe(_tel(stale=0.0))
    assert tuner.history[-1]["reward"] == pytest.approx(r_holding)


def test_readapt_reopens_on_unimproving_staleness():
    spec, machine, tuner = _tuner(finetune_ticks=2, readapt_stale_s=1.0,
                                  readapt_drift=0.0)
    for stale in (0.0, 0.0):                # tuning window, then serving
        tuner.propose(spec, machine, None)
        tuner.observe(_tel(stale=stale))
    assert tuner.ticks_since_reset == 2     # serving; baseline stale 0.0
    tuner.propose(spec, machine, None)
    tuner.observe(_tel(stale=5.0))          # over the line, not improving
    assert tuner.ticks_since_reset == 0     # exploration reopened
    assert tuner.best == (-1.0, None)


def test_readapt_leaves_a_draining_incumbent_alone():
    """The progress guard: staleness over the line but IMPROVING since
    serving began means the incumbent is draining a spike's backlog at
    full rate — reopening would trade it for an exploration storm."""
    spec, machine, tuner = _tuner(finetune_ticks=2, readapt_stale_s=1.0,
                                  readapt_drift=0.0)
    tuner.propose(spec, machine, None)
    tuner.observe(_tel(stale=0.0))
    tuner.propose(spec, machine, None)
    tuner.observe(_tel(stale=9.0))          # serving starts: baseline 9
    for stale in (8.0, 7.0, 6.0):           # still > 1.0 but draining
        tsr = tuner.ticks_since_reset
        tuner.propose(spec, machine, None)
        tuner.observe(_tel(stale=stale))
        assert tuner.ticks_since_reset == tsr + 1   # no reopen
    tuner.propose(spec, machine, None)
    tuner.observe(_tel(stale=9.5))          # progress lost: reopen
    assert tuner.ticks_since_reset == 0


def test_readapt_drift_is_downward_only():
    """Throughput rising while fresh is a served demand surge (nothing
    to fix); only the downward drift of a trough reopens exploration."""
    spec, machine, tuner = _tuner(finetune_ticks=2, readapt_stale_s=0.0,
                                  readapt_drift=0.5)
    for _ in range(4):                      # serving, EWMA ref ~= 10
        tuner.propose(spec, machine, None)
        tuner.observe(_tel(tput=10.0, stale=0.0))
    assert tuner.ticks_since_reset == 4
    tuner.propose(spec, machine, None)
    tuner.observe(_tel(tput=30.0, stale=0.0))   # upward surge: hold
    assert tuner.ticks_since_reset == 5
    # EWMA moved toward 30; re-anchor it near 10 before the trough
    for _ in range(6):
        tuner.propose(spec, machine, None)
        tuner.observe(_tel(tput=10.0, stale=0.0))
    tuner.propose(spec, machine, None)
    tuner.observe(_tel(tput=2.0, stale=0.0))    # trough: reopen
    assert tuner.ticks_since_reset == 0


# ------------------------------------------------------- slow acceptance --

@pytest.mark.slow
def test_fig_stream_sim_acceptance():
    """ISSUE 7 acceptance (sim plane): under a 10x flash crowd the tuned
    arm re-adapts within half of the best frozen arm's sustained
    starvation window with zero OOMs, while both frozen arms fail."""
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    from benchmarks import fig_stream
    res = fig_stream.run_sim(seed=0)
    assert all(res["pass"].values()), res["pass"]
