"""repro.lint: per-rule bad/good fixtures, the pragma allowlist
round-trip, the JSON report schema, and the tier-1 gate that the repo
itself lints clean.

Every rule is tested in BOTH directions — a known-bad snippet that must
fire and a known-good snippet that must not — so a rule can neither
silently stop firing nor start flagging sanctioned idioms.
"""
from __future__ import annotations

import importlib.util
import json
import os
import queue as queue_mod
import subprocess
import sys
import textwrap
import threading
import time

import pytest

from repro.lint import ALL_RULES, lint_paths, lint_source
from repro.lint.findings import BAD_PRAGMA, PARSE_ERROR, UNUSED_PRAGMA

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SIM = "pkg/data/simulator.py"          # a sim-plane-scoped path
CORE = "pkg/core/controller.py"        # also sim-plane (core/*)
CONC = "pkg/data/executor.py"          # a concurrency-scoped path
PLAIN = "pkg/tools/misc.py"            # out of every special scope


def rules_of(path, src, **kw):
    """Set of unsuppressed rule ids lint_source reports."""
    return {f.rule for f in lint_source(path, textwrap.dedent(src), **kw)
            if not f.suppressed}


def lint_scoped(tmp_path, **files):
    """Write {name: src} files under tmp/pkg/data/ (the xfn finding
    scope) and lint the tree — ProjectRules only run via lint_paths."""
    pkg = tmp_path / "pkg" / "data"
    pkg.mkdir(parents=True, exist_ok=True)
    for name, src in files.items():
        (pkg / name).write_text(textwrap.dedent(src))
    report = lint_paths([str(tmp_path)])
    return {f.rule for f in report.unsuppressed}


# ---------------------------------------------------------------------------
# sim-plane purity
# ---------------------------------------------------------------------------

class TestSimPurity:
    def test_wall_clock_fires_in_sim_plane(self):
        src = "import time\nt = time.time()\n"
        assert "sim-wall-clock" in rules_of(SIM, src)
        assert "sim-wall-clock" in rules_of(CORE, src)

    def test_wall_clock_ignored_outside_sim_plane(self):
        src = "import time\nt = time.monotonic()\n"
        assert "sim-wall-clock" not in rules_of(PLAIN, src)

    def test_wall_clock_from_import(self):
        src = "from time import perf_counter\n"
        assert "sim-wall-clock" in rules_of(SIM, src)

    def test_tick_arithmetic_is_clean(self):
        src = "def step(tick, dt):\n    return tick * dt\n"
        assert rules_of(SIM, src) == set()

    def test_sleep_fires(self):
        src = "import time\ntime.sleep(0.1)\n"
        assert "sim-sleep" in rules_of(SIM, src)

    def test_sleep_allowed_in_executor_plane(self):
        src = "import time\ntime.sleep(0.1)\n"
        assert "sim-sleep" not in rules_of(PLAIN, src)

    def test_thread_import_fires(self):
        assert "sim-thread-import" in rules_of(SIM, "import threading\n")
        assert "sim-thread-import" in rules_of(
            CORE, "from multiprocessing import Queue\n")

    def test_thread_import_fine_elsewhere(self):
        assert "sim-thread-import" not in rules_of(
            PLAIN, "import threading\n")

    def test_unseeded_numpy_fires(self):
        src = "import numpy as np\nx = np.random.rand(3)\n"
        assert "sim-unseeded-rng" in rules_of(SIM, src)

    def test_seedless_ctor_fires(self):
        src = "import numpy as np\nrng = np.random.default_rng()\n"
        assert "sim-unseeded-rng" in rules_of(SIM, src)

    def test_seeded_ctor_is_clean(self):
        src = ("import numpy as np\n"
               "rng = np.random.RandomState(7)\n"
               "g = np.random.default_rng(11)\n")
        assert "sim-unseeded-rng" not in rules_of(SIM, src)

    def test_stdlib_global_rng_fires(self):
        assert "sim-unseeded-rng" in rules_of(
            SIM, "import random\nx = random.random()\n")

    def test_seeded_stdlib_rng_is_clean(self):
        assert "sim-unseeded-rng" not in rules_of(
            SIM, "import random\nr = random.Random(3)\n")


# ---------------------------------------------------------------------------
# forbidden APIs
# ---------------------------------------------------------------------------

class TestForbiddenApis:
    def test_cancel_join_thread_fires_everywhere(self):
        src = "def teardown(q):\n    q.cancel_join_thread()\n"
        assert "no-cancel-join-thread" in rules_of(PLAIN, src)

    def test_plain_close_is_clean(self):
        src = "def teardown(q):\n    q.close()\n    q.join_thread()\n"
        assert "no-cancel-join-thread" not in rules_of(PLAIN, src)

    def test_bare_mp_queue_fires(self):
        src = "import multiprocessing as mp\nq = mp.Queue()\n"
        assert "mp-queue-protocol" in rules_of(PLAIN, src)

    def test_mp_queue_in_class_without_shutdown_fires(self):
        src = """\
        import multiprocessing as mp
        class Pool:
            def __init__(self):
                self.q = mp.Queue()
        """
        assert "mp-queue-protocol" in rules_of(PLAIN, src)

    def test_mp_queue_inside_shutdown_protocol_is_clean(self):
        src = """\
        import multiprocessing as mp
        class Pipeline:
            def __init__(self, ctx):
                self.q = mp.Queue()
                self.out = ctx.SimpleQueue()
            def shutdown(self, drain=True):
                pass
        """
        assert "mp-queue-protocol" not in rules_of(PLAIN, src)

    def test_threading_queue_not_confused_with_mp(self):
        src = "import queue\nq = queue.Queue()\n"
        assert "mp-queue-protocol" not in rules_of(PLAIN, src)


# ---------------------------------------------------------------------------
# spec hygiene
# ---------------------------------------------------------------------------

class TestSpecHygiene:
    def test_unfrozen_spec_fires(self):
        src = """\
        from dataclasses import dataclass
        @dataclass
        class StageSpec:
            rate: float = 1.0
        """
        assert "spec-frozen" in rules_of(PLAIN, src)

    def test_frozen_spec_is_clean(self):
        src = """\
        from dataclasses import dataclass
        @dataclass(frozen=True)
        class StageSpec:
            rate: float = 1.0
        """
        assert "spec-frozen" not in rules_of(PLAIN, src)

    def test_non_spec_dataclass_may_be_mutable(self):
        src = """\
        from dataclasses import dataclass
        @dataclass
        class Allocation:
            workers: int = 0
        """
        assert "spec-frozen" not in rules_of(PLAIN, src)

    def test_mutable_function_default_fires(self):
        src = "def f(xs=[]):\n    return xs\n"
        assert "mutable-default" in rules_of(PLAIN, src)

    def test_mutable_kwonly_default_fires(self):
        src = "def f(*, cfg={}):\n    return cfg\n"
        assert "mutable-default" in rules_of(PLAIN, src)

    def test_none_default_is_clean(self):
        src = "def f(xs=None):\n    return xs or []\n"
        assert "mutable-default" not in rules_of(PLAIN, src)

    def test_dataclass_field_default_mutable_fires(self):
        src = """\
        from dataclasses import dataclass, field
        @dataclass
        class Box:
            items: list = field(default=[])
        """
        assert "mutable-default" in rules_of(PLAIN, src)

    def test_default_factory_is_clean(self):
        src = """\
        from dataclasses import dataclass, field
        @dataclass
        class Box:
            items: list = field(default_factory=list)
        """
        assert "mutable-default" not in rules_of(PLAIN, src)


# ---------------------------------------------------------------------------
# golden stability
# ---------------------------------------------------------------------------

class TestGoldenStability:
    def test_post_baseline_field_with_live_default_fires(self):
        src = """\
        from dataclasses import dataclass
        @dataclass
        class Telemetry:
            throughput: float = 0.0
            feed_stall_s: float = 0.0
        """
        assert "golden-field-default" in rules_of(PLAIN, src)

    def test_post_baseline_field_without_default_fires(self):
        src = """\
        from dataclasses import dataclass
        @dataclass
        class RunResult:
            throughput: float
            brand_new: float
        """
        assert "golden-field-default" in rules_of(PLAIN, src)

    def test_none_default_is_clean(self):
        src = """\
        from dataclasses import dataclass
        from typing import Optional
        @dataclass
        class Telemetry:
            throughput: float = 0.0
            feed_stall_s: Optional[float] = None
        """
        assert "golden-field-default" not in rules_of(PLAIN, src)

    def test_baseline_fields_keep_live_defaults(self):
        src = """\
        from dataclasses import dataclass, field
        @dataclass
        class RunResult:
            throughput: float = 0.0
            oom_count: int = 0
            extras: dict = field(default_factory=dict)
        """
        assert "golden-field-default" not in rules_of(PLAIN, src)

    def test_other_classes_unconstrained(self):
        src = """\
        from dataclasses import dataclass
        @dataclass
        class Snapshot:
            anything: float = 1.0
        """
        assert "golden-field-default" not in rules_of(PLAIN, src)


# ---------------------------------------------------------------------------
# concurrency analysis
# ---------------------------------------------------------------------------

class TestConcurrency:
    def test_lock_order_cycle_fires(self):
        src = """\
        def a(self):
            with self.lock_a:
                with self.lock_b:
                    pass
        def b(self):
            with self.lock_b:
                with self.lock_a:
                    pass
        """
        assert "lock-order-cycle" in rules_of(CONC, src)

    def test_consistent_order_is_clean(self):
        src = """\
        def a(self):
            with self.lock_a:
                with self.lock_b:
                    pass
        def b(self):
            with self.lock_a:
                with self.lock_b:
                    pass
        """
        assert "lock-order-cycle" not in rules_of(CONC, src)

    def test_three_way_cycle_fires(self):
        src = """\
        def f(self):
            with self.a_lock:
                with self.b_lock:
                    pass
        def g(self):
            with self.b_lock:
                with self.c_lock:
                    pass
        def h(self):
            with self.c_lock:
                with self.a_lock:
                    pass
        """
        assert "lock-order-cycle" in rules_of(CONC, src)

    def test_acquire_release_builds_edges(self):
        src = """\
        def f(self):
            self.a_lock.acquire()
            self.b_lock.acquire()
            self.b_lock.release()
            self.a_lock.release()
        def g(self):
            with self.b_lock:
                with self.a_lock:
                    pass
        """
        assert "lock-order-cycle" in rules_of(CONC, src)

    def test_blocking_get_under_lock_fires(self):
        src = """\
        def f(self, q):
            with self._lock:
                item = q.get()
        """
        assert "blocking-while-locked" in rules_of(CONC, src)

    def test_get_with_timeout_is_clean(self):
        src = """\
        def f(self, q):
            with self._lock:
                item = q.get(timeout=0.05)
        """
        assert "blocking-while-locked" not in rules_of(CONC, src)

    def test_dict_get_not_confused_with_queue_get(self):
        src = """\
        def f(self, d):
            with self._lock:
                return d.get("key", None)
        """
        assert "blocking-while-locked" not in rules_of(CONC, src)

    def test_join_under_lock_fires(self):
        src = """\
        def f(self, proc):
            with self.state_lock:
                proc.join()
        """
        assert "blocking-while-locked" in rules_of(CONC, src)

    def test_str_join_is_clean(self):
        src = """\
        def f(self, names):
            with self.state_lock:
                return ",".join(names)
        """
        assert "blocking-while-locked" not in rules_of(CONC, src)

    def test_blocking_outside_lock_is_clean(self):
        src = """\
        def f(self, q):
            item = q.get()
            with self._lock:
                self.items.append(item)
        """
        assert "blocking-while-locked" not in rules_of(CONC, src)

    def test_release_clears_held_state(self):
        src = """\
        def f(self, q):
            self._lock.acquire()
            self._lock.release()
            item = q.get()
        """
        assert "blocking-while-locked" not in rules_of(CONC, src)

    def test_inner_def_does_not_inherit_held_locks(self):
        # a closure defined under a lock runs later, on its own stack
        src = """\
        def f(self, q):
            with self._lock:
                def worker():
                    return q.get()
                self.fn = worker
        """
        assert "blocking-while-locked" not in rules_of(CONC, src)

    def test_concurrency_rules_scoped_to_executor_modules(self):
        src = """\
        def f(self, q):
            with self._lock:
                item = q.get()
        """
        assert "blocking-while-locked" not in rules_of(PLAIN, src)


# ---------------------------------------------------------------------------
# pragmas
# ---------------------------------------------------------------------------

class TestPragmas:
    BAD = ("import time\n"
           "t = time.time()  # lint: allow[sim-wall-clock] -- test reason\n")

    def test_same_line_pragma_suppresses(self):
        findings = lint_source(SIM, self.BAD)
        wall = [f for f in findings if f.rule == "sim-wall-clock"]
        assert wall and all(f.suppressed for f in wall)

    def test_own_line_pragma_covers_next_code_line(self):
        src = ("import time\n"
               "# lint: allow[sim-wall-clock] -- test reason\n"
               "t = time.time()\n")
        findings = lint_source(SIM, src)
        wall = [f for f in findings if f.rule == "sim-wall-clock"]
        assert wall and all(f.suppressed for f in wall)

    def test_no_pragmas_flag_restores_finding(self):
        # the delete-any-pragma direction: without the allowlist the
        # violation is live again
        assert "sim-wall-clock" in rules_of(SIM, self.BAD,
                                            respect_pragmas=False)

    def test_reasonless_pragma_is_a_finding(self):
        src = ("import time\n"
               "t = time.time()  # lint: allow[sim-wall-clock]\n")
        assert BAD_PRAGMA in rules_of(SIM, src)

    def test_unknown_rule_pragma_is_a_finding(self):
        src = "x = 1  # lint: allow[no-such-rule] -- whatever\n"
        assert BAD_PRAGMA in rules_of(PLAIN, src)

    def test_unused_pragma_is_a_finding(self):
        src = "x = 1  # lint: allow[sim-wall-clock] -- covers nothing\n"
        assert UNUSED_PRAGMA in rules_of(PLAIN, src)

    def test_pragma_in_string_literal_ignored(self):
        src = 's = "# lint: allow[sim-wall-clock] -- not a comment"\n'
        findings = lint_source(PLAIN, src)
        assert findings == []

    def test_pragma_suppresses_only_named_rule(self):
        src = ("import time\n"
               "t = time.time()  # lint: allow[sim-sleep] -- wrong rule\n")
        ids = rules_of(SIM, src)
        assert "sim-wall-clock" in ids          # still live
        assert UNUSED_PRAGMA in ids             # and the pragma is stale

    def test_meta_rules_cannot_be_suppressed(self):
        # a pragma naming bad-pragma is itself malformed (unknown rule)
        src = "x = 1  # lint: allow[bad-pragma] -- nope\n"
        assert BAD_PRAGMA in rules_of(PLAIN, src)


# ---------------------------------------------------------------------------
# engine + CLI
# ---------------------------------------------------------------------------

class TestEngine:
    def test_syntax_error_reports_parse_error(self):
        assert PARSE_ERROR in rules_of(PLAIN, "def broken(:\n")

    def test_lint_paths_walks_directories(self, tmp_path):
        pkg = tmp_path / "data"
        pkg.mkdir()
        (pkg / "simulator.py").write_text("import time\nt = time.time()\n")
        (pkg / "other.py").write_text("x = 1\n")
        report = lint_paths([str(tmp_path)])
        assert report.files_checked == 2
        assert {f.rule for f in report.unsuppressed} == {"sim-wall-clock"}

    def test_report_json_schema(self, tmp_path):
        (tmp_path / "ok.py").write_text("x = 1\n")
        d = lint_paths([str(tmp_path)]).to_dict()
        assert set(d) == {"files_checked", "ok", "counts", "findings"}
        assert set(d["counts"]) == {"total", "suppressed", "unsuppressed"}
        bad = tmp_path / "data"
        bad.mkdir()
        (bad / "fleet.py").write_text("import time\nt = time.time()\n")
        d = lint_paths([str(tmp_path)]).to_dict()
        assert d["ok"] is False
        (f,) = [x for x in d["findings"] if x["rule"] == "sim-wall-clock"]
        assert set(f) == {"path", "line", "col", "rule", "message",
                          "suppressed", "snippet", "finding_id"}
        assert f["snippet"] == "t = time.time()"
        assert len(f["finding_id"]) == 12

    def test_rule_registry_well_formed(self):
        ids = [r.id for r in ALL_RULES]
        assert len(ids) == len(set(ids))
        assert all(r.id and r.doc for r in ALL_RULES)


def _run_cli(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.lint", *args],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=120)


class TestCli:
    def test_list_rules(self):
        proc = _run_cli("--list-rules")
        assert proc.returncode == 0
        for rule in ALL_RULES:
            assert rule.id in proc.stdout

    def test_nonzero_exit_on_finding(self, tmp_path):
        bad = tmp_path / "core"
        bad.mkdir()
        (bad / "model.py").write_text("import time\nt = time.time()\n")
        proc = _run_cli(str(tmp_path))
        assert proc.returncode == 1
        assert "sim-wall-clock" in proc.stdout

    def test_json_output_parses(self, tmp_path):
        (tmp_path / "ok.py").write_text("x = 1\n")
        proc = _run_cli("--json", str(tmp_path))
        assert proc.returncode == 0
        assert json.loads(proc.stdout)["ok"] is True


# ---------------------------------------------------------------------------
# the tier-1 gate: the repo itself lints clean, and every pragma in it
# is load-bearing
# ---------------------------------------------------------------------------

GATE_DIRS = ("src", "benchmarks", "examples", "tests")


class TestRepoGate:
    def test_repo_lints_clean(self):
        proc = _run_cli("--json", *GATE_DIRS)
        assert proc.returncode == 0, proc.stdout + proc.stderr
        report = json.loads(proc.stdout)
        assert report["ok"] is True
        assert report["counts"]["unsuppressed"] == 0

    def test_every_pragma_is_load_bearing(self):
        # normal run: zero unused-pragma findings (each pragma suppresses
        # something). --no-pragmas: each suppression surfaces as a live
        # finding. Together: deleting any single pragma flips exit to 1.
        clean = json.loads(_run_cli("--json", *GATE_DIRS).stdout)
        assert not any(f["rule"] == "unused-pragma"
                       for f in clean["findings"])
        suppressed = [f for f in clean["findings"] if f["suppressed"]]
        assert suppressed, "expected the repo's sanctioned exceptions"
        raw = json.loads(
            _run_cli("--json", "--no-pragmas", *GATE_DIRS).stdout)
        live = {(f["path"], f["line"], f["rule"])
                for f in raw["findings"] if not f["suppressed"]}
        for f in suppressed:
            assert (f["path"], f["line"], f["rule"]) in live

    def test_reintroduced_violation_fails(self, tmp_path):
        # put time.time() back into data/simulator.py: the gate must trip
        src_path = os.path.join(REPO, "src", "repro", "data",
                                "simulator.py")
        with open(src_path, encoding="utf-8") as fh:
            text = fh.read()
        sandbox = tmp_path / "data"
        sandbox.mkdir()
        tainted = text + "\nimport time\n_T0 = time.time()\n"
        (sandbox / "simulator.py").write_text(tainted)
        report = lint_paths([str(tmp_path)])
        assert not report.ok
        assert any(f.rule == "sim-wall-clock" for f in report.unsuppressed)


# ---------------------------------------------------------------------------
# interprocedural (xfn) rules: the cross-function lock graph
# ---------------------------------------------------------------------------

# One fixture, used in BOTH directions: lint_paths must flag the cross-
# function inversion statically, and running path_one()/path_two() live
# under REPRO_SANITIZE must record the same cycle (see
# TestSeededInversion below).
INVERSION_SRC = """\
import threading


class Pool:
    def __init__(self):
        self.a_lock = threading.Lock()
        self.b_lock = threading.Lock()

    def _inner_ab(self):
        with self.b_lock:
            pass

    def path_one(self):
        with self.a_lock:
            self._inner_ab()

    def _inner_ba(self):
        with self.a_lock:
            pass

    def path_two(self):
        with self.b_lock:
            self._inner_ba()
"""


class TestXfnStatic:
    def test_cross_function_inversion_fires(self, tmp_path):
        ids = lint_scoped(tmp_path, **{"executor.py": INVERSION_SRC})
        assert "xfn-lock-order-cycle" in ids

    def test_consistent_cross_function_order_is_clean(self, tmp_path):
        src = """\
        import threading

        class Pool:
            def __init__(self):
                self.a_lock = threading.Lock()
                self.b_lock = threading.Lock()

            def _inner(self):
                with self.b_lock:
                    pass

            def path_one(self):
                with self.a_lock:
                    self._inner()

            def path_two(self):
                with self.a_lock:
                    self._inner()
        """
        ids = lint_scoped(tmp_path, **{"executor.py": src})
        assert "xfn-lock-order-cycle" not in ids

    def test_intra_cycle_not_double_reported(self, tmp_path):
        # a single-function inversion pair is the intra rule's territory:
        # the xfn rule must stay quiet (no cross-frame edge, one module)
        src = """\
        def a(self):
            with self.lock_a:
                with self.lock_b:
                    pass
        def b(self):
            with self.lock_b:
                with self.lock_a:
                    pass
        """
        ids = lint_scoped(tmp_path, **{"executor.py": src})
        assert "lock-order-cycle" in ids
        assert "xfn-lock-order-cycle" not in ids

    def test_blocking_via_callee_fires(self, tmp_path):
        src = """\
        import queue
        import threading

        class Pool:
            def __init__(self):
                self.lock = threading.Lock()
                self.q = queue.Queue()

            def _drain(self):
                return self.q.get()

            def snapshot(self):
                with self.lock:
                    return self._drain()
        """
        ids = lint_scoped(tmp_path, **{"executor.py": src})
        assert "xfn-blocking-while-locked" in ids

    def test_blocking_via_callee_with_timeout_is_clean(self, tmp_path):
        src = """\
        import queue
        import threading

        class Pool:
            def __init__(self):
                self.lock = threading.Lock()
                self.q = queue.Queue()

            def _drain(self):
                return self.q.get(timeout=0.05)

            def snapshot(self):
                with self.lock:
                    return self._drain()
        """
        ids = lint_scoped(tmp_path, **{"executor.py": src})
        assert "xfn-blocking-while-locked" not in ids

    def test_thread_leak_fires(self, tmp_path):
        src = """\
        import threading

        class Runner:
            def start(self):
                self._t = threading.Thread(target=self._work, daemon=True)
                self._t.start()

            def _work(self):
                pass
        """
        ids = lint_scoped(tmp_path, **{"live_fleet.py": src})
        assert "resource-lifecycle" in ids

    def test_joined_thread_is_clean(self, tmp_path):
        src = """\
        import threading

        class Runner:
            def start(self):
                self._t = threading.Thread(target=self._work, daemon=True)
                self._t.start()

            def _work(self):
                pass

            def close(self):
                self._t.join(timeout=5)
        """
        ids = lint_scoped(tmp_path, **{"live_fleet.py": src})
        assert "resource-lifecycle" not in ids

    def test_reap_via_helper_method_is_clean(self, tmp_path):
        # the reap may be reachable through a call chain, not direct
        src = """\
        import threading

        class Runner:
            def start(self):
                self._t = threading.Thread(target=self._work, daemon=True)
                self._t.start()

            def _work(self):
                pass

            def _teardown(self):
                self._t.join(timeout=5)

            def close(self):
                self._teardown()
        """
        ids = lint_scoped(tmp_path, **{"live_fleet.py": src})
        assert "resource-lifecycle" not in ids

    def test_xfn_rules_scoped_to_executor_modules(self, tmp_path):
        pkg = tmp_path / "pkg" / "tools"
        pkg.mkdir(parents=True)
        (pkg / "misc.py").write_text(INVERSION_SRC)
        report = lint_paths([str(tmp_path)])
        assert not any(f.rule.startswith("xfn-") for f in report.findings)

    def test_unresolved_call_is_recorded_not_guessed(self, tmp_path):
        # the same inversion routed through a function-valued attribute:
        # the call graph cannot resolve self._fn(), so the static rule
        # must stay SILENT (no guessing) — this is the documented
        # soundness hole the runtime sanitizer exists to cover
        src = """\
        import threading

        class Pool:
            def __init__(self):
                self.a_lock = threading.Lock()
                self.b_lock = threading.Lock()
                self._fn = self._inner_ba

            def _inner_ab(self):
                with self.b_lock:
                    pass

            def path_one(self):
                with self.a_lock:
                    self._inner_ab()

            def _inner_ba(self):
                with self.a_lock:
                    pass

            def path_two(self):
                with self.b_lock:
                    self._fn()
        """
        ids = lint_scoped(tmp_path, **{"executor.py": src})
        assert "xfn-lock-order-cycle" not in ids
        # ...and the resolver records the miss instead of dropping it
        from repro.lint.callgraph import CallGraph
        from repro.lint.engine import _parse
        mod = _parse("pkg/data/executor.py", textwrap.dedent(src))
        cg = CallGraph([mod])
        for fk in list(cg.funcs):
            if fk.qual.endswith("path_two"):
                import ast as ast_mod
                fn = cg.funcs[fk].node
                calls = [n for n in ast_mod.walk(fn)
                         if isinstance(n, ast_mod.Call)]
                assert cg.resolve_call(fk, calls[0]) is None
        assert any(t == "self._fn" for _, t, _ in cg.unresolved)


# ---------------------------------------------------------------------------
# stable finding ids
# ---------------------------------------------------------------------------

class TestFindingIds:
    BAD = "import time\nt = time.time()\n"

    def _id_of(self, tmp_path, text):
        d = tmp_path / "data"
        d.mkdir(exist_ok=True)
        (d / "simulator.py").write_text(text)
        report = lint_paths([str(tmp_path)]).to_dict()
        (f,) = [x for x in report["findings"]
                if x["rule"] == "sim-wall-clock"]
        return f["finding_id"], f["line"]

    def test_id_survives_line_shift(self, tmp_path):
        # ids hash rule + path + snippet, NOT the line: the same file
        # re-linted after lines shift keeps its ids (CI artifacts diff
        # cleanly), while the line itself moves
        fid_a, line_a = self._id_of(tmp_path, self.BAD)
        shifted = "# a comment\n\nimport time\nt = time.time()\n"
        fid_b, line_b = self._id_of(tmp_path, shifted)
        assert line_a != line_b          # the location moved...
        assert fid_a == fid_b            # ...the id did not

    def test_distinct_findings_get_distinct_ids(self, tmp_path):
        two = "import time\nt = time.time()\nu = time.perf_counter()\n"
        d = tmp_path / "data"
        d.mkdir()
        (d / "simulator.py").write_text(two)
        report = lint_paths([str(tmp_path)]).to_dict()
        ids = [f["finding_id"] for f in report["findings"]]
        assert len(ids) == len(set(ids))

    def test_identical_snippets_get_occurrence_suffix(self, tmp_path):
        dup = "import time\nt = time.time()\n\nt = time.time()\n"
        d = tmp_path / "data"
        d.mkdir()
        (d / "simulator.py").write_text(dup)
        report = lint_paths([str(tmp_path)]).to_dict()
        ids = sorted(f["finding_id"] for f in report["findings"]
                     if f["rule"] == "sim-wall-clock")
        assert len(ids) == 2 and ids[1] == f"{ids[0]}-2"


# ---------------------------------------------------------------------------
# the runtime sanitizer (tsan-lite)
# ---------------------------------------------------------------------------

class _Sanitized:
    """install() for the test's scope — but never tear down a sanitizer
    the session-level plugin (REPRO_SANITIZE=1) already owns."""

    def __enter__(self):
        from repro.lint import runtime
        self.runtime = runtime
        self.owned = not runtime.installed()
        if self.owned:
            runtime.install()
        return runtime

    def __exit__(self, *exc):
        if self.owned:
            self.runtime.uninstall()


def _edges_in(report, path):
    prefix = os.path.abspath(path) + ":"
    return [(e["held"], e["acquired"]) for e in report["edges"]
            if e["held"].startswith(prefix)
            and e["acquired"].startswith(prefix)]


class TestSanitizer:
    def test_observed_inversion_becomes_cycle(self):
        with _Sanitized() as rt:
            a = threading.Lock()
            b = threading.Lock()
            with a:
                with b:
                    pass
            with b:
                with a:
                    pass
            report = rt.snapshot()
        here = _edges_in(report, __file__)
        assert len(here) >= 2
        sites = {s for e in here for s in e}
        assert any(set(cyc) <= sites for cyc in report["cycles"])

    def test_rlock_reentry_adds_no_self_edge(self):
        with _Sanitized() as rt:
            r = threading.RLock()
            with r:
                with r:
                    pass
            report = rt.snapshot()
        assert not any(h == a for h, a in _edges_in(report, __file__))

    def test_unbounded_get_under_lock_recorded(self):
        with _Sanitized() as rt:
            lock = threading.Lock()
            q = queue_mod.Queue()
            q.put("x")
            with lock:
                q.get()                        # unbounded: recorded
            q.put("y")
            with lock:
                q.get(timeout=1)               # bounded: not recorded
            report = rt.snapshot()
        prefix = os.path.abspath(__file__) + ":"
        mine = [b for b in report["blocking"]
                if b["lock"].startswith(prefix)]
        assert len(mine) == 1 and mine[0]["op"] == "queue.get"

    def test_held_duration_histogram_recorded(self):
        with _Sanitized() as rt:
            lock = threading.Lock()
            with lock:
                time.sleep(0.002)
            report = rt.snapshot()
        prefix = os.path.abspath(__file__) + ":"
        stats = [s for site, s in report["locks"].items()
                 if site.startswith(prefix) and s["held_ms_max"] >= 1.0]
        assert stats and sum(stats[0]["held_ms_buckets"].values()) == 1

    def test_uninstall_restores_real_factories(self):
        from repro.lint import runtime as rt
        if rt.installed():
            pytest.skip("session-level sanitizer owns the hooks")
        rt.install()
        rt.uninstall()
        assert type(threading.Lock()) is type(rt._REAL_LOCK())
        assert queue_mod.Queue.get is rt._REAL_GET


class TestSeededInversion:
    """Acceptance: ONE seeded cross-function inversion, caught BOTH ways
    — statically by xfn-lock-order-cycle and live by the sanitizer."""

    def test_static_rule_catches_it(self, tmp_path):
        ids = lint_scoped(tmp_path, **{"executor.py": INVERSION_SRC})
        assert "xfn-lock-order-cycle" in ids

    def test_sanitizer_catches_it_live(self, tmp_path):
        fix = tmp_path / "seeded_inversion.py"
        fix.write_text(INVERSION_SRC)
        with _Sanitized() as rt:
            # import AFTER install so __init__'s locks are tracked
            spec = importlib.util.spec_from_file_location(
                "seeded_inversion", fix)
            mod = importlib.util.module_from_spec(spec)
            spec.loader.exec_module(mod)
            pool = mod.Pool()
            pool.path_one()
            pool.path_two()
            report = rt.snapshot()
        here = _edges_in(report, str(fix))
        assert len(here) == 2, report["edges"]
        sites = {s for e in here for s in e}
        assert any(set(cyc) <= sites for cyc in report["cycles"])


class TestReconcile:
    """Static-vs-runtime diff: observed edges the static pass explains
    are matched; edges it cannot see become dynamic-only findings."""

    SRC = textwrap.dedent("""\
        import threading


        class Pool:
            def __init__(self):
                self.a_lock = threading.Lock()
                self.b_lock = threading.Lock()

            def visible(self):
                with self.a_lock:
                    with self.b_lock:
                        pass
        """)
    A_SITE, B_SITE = 6, 7                     # creation linenos in SRC

    def _mods(self, tmp_path):
        from repro.lint.engine import _parse
        pkg = tmp_path / "pkg" / "data"
        pkg.mkdir(parents=True)
        path = pkg / "executor.py"
        path.write_text(self.SRC)
        return str(path), [_parse(str(path), self.SRC)]

    def test_matched_and_dynamic_only_edges(self, tmp_path):
        from repro.lint.runtime import reconcile
        path, mods = self._mods(tmp_path)
        report = {"edges": [
            {"held": f"{path}:{self.A_SITE}",
             "acquired": f"{path}:{self.B_SITE}", "count": 3},   # static sees
            {"held": f"{path}:{self.B_SITE}",
             "acquired": f"{path}:{self.A_SITE}", "count": 1},   # it does NOT
        ]}
        out = reconcile(report, mods)
        assert out["matched"] == 1
        (dyn,) = out["dynamic_only"]
        assert dyn["held"] == "executor.Pool.b_lock"
        assert dyn["acquired"] == "executor.Pool.a_lock"

    def test_unmappable_sites_counted_not_flagged(self, tmp_path):
        from repro.lint.runtime import reconcile
        path, mods = self._mods(tmp_path)
        report = {"edges": [
            {"held": "/nowhere/else.py:3",
             "acquired": f"{path}:{self.A_SITE}", "count": 1},
        ]}
        out = reconcile(report, mods)
        assert out["dynamic_only"] == [] and out["unattributed"] == 1

    def test_cli_runtime_report_exit_codes(self, tmp_path):
        pkg = tmp_path / "pkg" / "data"
        pkg.mkdir(parents=True)
        path = pkg / "executor.py"
        path.write_text(self.SRC)
        clean = tmp_path / "clean.json"
        clean.write_text(json.dumps({"edges": [
            {"held": f"{path}:{self.A_SITE}",
             "acquired": f"{path}:{self.B_SITE}", "count": 1}]}))
        proc = _run_cli("--runtime-report", str(clean), str(tmp_path))
        assert proc.returncode == 0, proc.stdout + proc.stderr
        dirty = tmp_path / "dirty.json"
        dirty.write_text(json.dumps({"edges": [
            {"held": f"{path}:{self.B_SITE}",
             "acquired": f"{path}:{self.A_SITE}", "count": 1}]}))
        proc = _run_cli("--runtime-report", str(dirty), str(tmp_path))
        assert proc.returncode == 1
        assert "runtime-edge-unmodeled" in proc.stdout


class TestSanitizerOverhead:
    """The sanitizer must stay cheap enough to run the real executor
    suites under: < 2x wall time on an end-to-end ThreadedPipeline run
    (plus a small epsilon so a near-zero baseline can't flake)."""

    @staticmethod
    def _run_pipeline(n_items=20):
        from repro.data.executor import ThreadedPipeline
        from repro.data.pipeline import StageGraph, StageSpec
        spec = StageGraph("ovh", (
            StageSpec("src", "udf", cost=0.002, serial_frac=0.0,
                      inputs=()),
            StageSpec("sink", "udf", cost=0.002, serial_frac=0.0,
                      inputs=("src",)),
        ), batch_mb=1.0)
        count = [0]
        gate = threading.Lock()

        def source():
            with gate:
                if count[0] >= n_items:
                    return None
                count[0] += 1
            time.sleep(0.002)
            return count[0]

        def sink(item):
            time.sleep(0.002)
            return item

        t0 = time.perf_counter()
        pipe = ThreadedPipeline(spec, fns={"src": source, "sink": sink},
                                queue_depth=8, item_mb=1.0)
        try:
            pipe.set_allocation([1, 1], prefetch_mb=8.0)
            while True:
                try:
                    pipe.get_batch(timeout=30)
                except StopIteration:
                    break
        finally:
            pipe.stop()
        return time.perf_counter() - t0

    def test_overhead_under_2x(self):
        base = self._run_pipeline()
        with _Sanitized():
            sanitized = self._run_pipeline()
        assert sanitized < 2.0 * base + 0.5, \
            f"sanitized {sanitized:.3f}s vs base {base:.3f}s"
