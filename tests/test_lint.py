"""repro.lint: per-rule bad/good fixtures, the pragma allowlist
round-trip, the JSON report schema, and the tier-1 gate that the repo
itself lints clean.

Every rule is tested in BOTH directions — a known-bad snippet that must
fire and a known-good snippet that must not — so a rule can neither
silently stop firing nor start flagging sanctioned idioms.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.lint import ALL_RULES, lint_paths, lint_source
from repro.lint.findings import BAD_PRAGMA, PARSE_ERROR, UNUSED_PRAGMA

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SIM = "pkg/data/simulator.py"          # a sim-plane-scoped path
CORE = "pkg/core/controller.py"        # also sim-plane (core/*)
CONC = "pkg/data/executor.py"          # a concurrency-scoped path
PLAIN = "pkg/tools/misc.py"            # out of every special scope


def rules_of(path, src, **kw):
    """Set of unsuppressed rule ids lint_source reports."""
    return {f.rule for f in lint_source(path, textwrap.dedent(src), **kw)
            if not f.suppressed}


# ---------------------------------------------------------------------------
# sim-plane purity
# ---------------------------------------------------------------------------

class TestSimPurity:
    def test_wall_clock_fires_in_sim_plane(self):
        src = "import time\nt = time.time()\n"
        assert "sim-wall-clock" in rules_of(SIM, src)
        assert "sim-wall-clock" in rules_of(CORE, src)

    def test_wall_clock_ignored_outside_sim_plane(self):
        src = "import time\nt = time.monotonic()\n"
        assert "sim-wall-clock" not in rules_of(PLAIN, src)

    def test_wall_clock_from_import(self):
        src = "from time import perf_counter\n"
        assert "sim-wall-clock" in rules_of(SIM, src)

    def test_tick_arithmetic_is_clean(self):
        src = "def step(tick, dt):\n    return tick * dt\n"
        assert rules_of(SIM, src) == set()

    def test_sleep_fires(self):
        src = "import time\ntime.sleep(0.1)\n"
        assert "sim-sleep" in rules_of(SIM, src)

    def test_sleep_allowed_in_executor_plane(self):
        src = "import time\ntime.sleep(0.1)\n"
        assert "sim-sleep" not in rules_of(PLAIN, src)

    def test_thread_import_fires(self):
        assert "sim-thread-import" in rules_of(SIM, "import threading\n")
        assert "sim-thread-import" in rules_of(
            CORE, "from multiprocessing import Queue\n")

    def test_thread_import_fine_elsewhere(self):
        assert "sim-thread-import" not in rules_of(
            PLAIN, "import threading\n")

    def test_unseeded_numpy_fires(self):
        src = "import numpy as np\nx = np.random.rand(3)\n"
        assert "sim-unseeded-rng" in rules_of(SIM, src)

    def test_seedless_ctor_fires(self):
        src = "import numpy as np\nrng = np.random.default_rng()\n"
        assert "sim-unseeded-rng" in rules_of(SIM, src)

    def test_seeded_ctor_is_clean(self):
        src = ("import numpy as np\n"
               "rng = np.random.RandomState(7)\n"
               "g = np.random.default_rng(11)\n")
        assert "sim-unseeded-rng" not in rules_of(SIM, src)

    def test_stdlib_global_rng_fires(self):
        assert "sim-unseeded-rng" in rules_of(
            SIM, "import random\nx = random.random()\n")

    def test_seeded_stdlib_rng_is_clean(self):
        assert "sim-unseeded-rng" not in rules_of(
            SIM, "import random\nr = random.Random(3)\n")


# ---------------------------------------------------------------------------
# forbidden APIs
# ---------------------------------------------------------------------------

class TestForbiddenApis:
    def test_cancel_join_thread_fires_everywhere(self):
        src = "def teardown(q):\n    q.cancel_join_thread()\n"
        assert "no-cancel-join-thread" in rules_of(PLAIN, src)

    def test_plain_close_is_clean(self):
        src = "def teardown(q):\n    q.close()\n    q.join_thread()\n"
        assert "no-cancel-join-thread" not in rules_of(PLAIN, src)

    def test_bare_mp_queue_fires(self):
        src = "import multiprocessing as mp\nq = mp.Queue()\n"
        assert "mp-queue-protocol" in rules_of(PLAIN, src)

    def test_mp_queue_in_class_without_shutdown_fires(self):
        src = """\
        import multiprocessing as mp
        class Pool:
            def __init__(self):
                self.q = mp.Queue()
        """
        assert "mp-queue-protocol" in rules_of(PLAIN, src)

    def test_mp_queue_inside_shutdown_protocol_is_clean(self):
        src = """\
        import multiprocessing as mp
        class Pipeline:
            def __init__(self, ctx):
                self.q = mp.Queue()
                self.out = ctx.SimpleQueue()
            def shutdown(self, drain=True):
                pass
        """
        assert "mp-queue-protocol" not in rules_of(PLAIN, src)

    def test_threading_queue_not_confused_with_mp(self):
        src = "import queue\nq = queue.Queue()\n"
        assert "mp-queue-protocol" not in rules_of(PLAIN, src)


# ---------------------------------------------------------------------------
# spec hygiene
# ---------------------------------------------------------------------------

class TestSpecHygiene:
    def test_unfrozen_spec_fires(self):
        src = """\
        from dataclasses import dataclass
        @dataclass
        class StageSpec:
            rate: float = 1.0
        """
        assert "spec-frozen" in rules_of(PLAIN, src)

    def test_frozen_spec_is_clean(self):
        src = """\
        from dataclasses import dataclass
        @dataclass(frozen=True)
        class StageSpec:
            rate: float = 1.0
        """
        assert "spec-frozen" not in rules_of(PLAIN, src)

    def test_non_spec_dataclass_may_be_mutable(self):
        src = """\
        from dataclasses import dataclass
        @dataclass
        class Allocation:
            workers: int = 0
        """
        assert "spec-frozen" not in rules_of(PLAIN, src)

    def test_mutable_function_default_fires(self):
        src = "def f(xs=[]):\n    return xs\n"
        assert "mutable-default" in rules_of(PLAIN, src)

    def test_mutable_kwonly_default_fires(self):
        src = "def f(*, cfg={}):\n    return cfg\n"
        assert "mutable-default" in rules_of(PLAIN, src)

    def test_none_default_is_clean(self):
        src = "def f(xs=None):\n    return xs or []\n"
        assert "mutable-default" not in rules_of(PLAIN, src)

    def test_dataclass_field_default_mutable_fires(self):
        src = """\
        from dataclasses import dataclass, field
        @dataclass
        class Box:
            items: list = field(default=[])
        """
        assert "mutable-default" in rules_of(PLAIN, src)

    def test_default_factory_is_clean(self):
        src = """\
        from dataclasses import dataclass, field
        @dataclass
        class Box:
            items: list = field(default_factory=list)
        """
        assert "mutable-default" not in rules_of(PLAIN, src)


# ---------------------------------------------------------------------------
# golden stability
# ---------------------------------------------------------------------------

class TestGoldenStability:
    def test_post_baseline_field_with_live_default_fires(self):
        src = """\
        from dataclasses import dataclass
        @dataclass
        class Telemetry:
            throughput: float = 0.0
            feed_stall_s: float = 0.0
        """
        assert "golden-field-default" in rules_of(PLAIN, src)

    def test_post_baseline_field_without_default_fires(self):
        src = """\
        from dataclasses import dataclass
        @dataclass
        class RunResult:
            throughput: float
            brand_new: float
        """
        assert "golden-field-default" in rules_of(PLAIN, src)

    def test_none_default_is_clean(self):
        src = """\
        from dataclasses import dataclass
        from typing import Optional
        @dataclass
        class Telemetry:
            throughput: float = 0.0
            feed_stall_s: Optional[float] = None
        """
        assert "golden-field-default" not in rules_of(PLAIN, src)

    def test_baseline_fields_keep_live_defaults(self):
        src = """\
        from dataclasses import dataclass, field
        @dataclass
        class RunResult:
            throughput: float = 0.0
            oom_count: int = 0
            extras: dict = field(default_factory=dict)
        """
        assert "golden-field-default" not in rules_of(PLAIN, src)

    def test_other_classes_unconstrained(self):
        src = """\
        from dataclasses import dataclass
        @dataclass
        class Snapshot:
            anything: float = 1.0
        """
        assert "golden-field-default" not in rules_of(PLAIN, src)


# ---------------------------------------------------------------------------
# concurrency analysis
# ---------------------------------------------------------------------------

class TestConcurrency:
    def test_lock_order_cycle_fires(self):
        src = """\
        def a(self):
            with self.lock_a:
                with self.lock_b:
                    pass
        def b(self):
            with self.lock_b:
                with self.lock_a:
                    pass
        """
        assert "lock-order-cycle" in rules_of(CONC, src)

    def test_consistent_order_is_clean(self):
        src = """\
        def a(self):
            with self.lock_a:
                with self.lock_b:
                    pass
        def b(self):
            with self.lock_a:
                with self.lock_b:
                    pass
        """
        assert "lock-order-cycle" not in rules_of(CONC, src)

    def test_three_way_cycle_fires(self):
        src = """\
        def f(self):
            with self.a_lock:
                with self.b_lock:
                    pass
        def g(self):
            with self.b_lock:
                with self.c_lock:
                    pass
        def h(self):
            with self.c_lock:
                with self.a_lock:
                    pass
        """
        assert "lock-order-cycle" in rules_of(CONC, src)

    def test_acquire_release_builds_edges(self):
        src = """\
        def f(self):
            self.a_lock.acquire()
            self.b_lock.acquire()
            self.b_lock.release()
            self.a_lock.release()
        def g(self):
            with self.b_lock:
                with self.a_lock:
                    pass
        """
        assert "lock-order-cycle" in rules_of(CONC, src)

    def test_blocking_get_under_lock_fires(self):
        src = """\
        def f(self, q):
            with self._lock:
                item = q.get()
        """
        assert "blocking-while-locked" in rules_of(CONC, src)

    def test_get_with_timeout_is_clean(self):
        src = """\
        def f(self, q):
            with self._lock:
                item = q.get(timeout=0.05)
        """
        assert "blocking-while-locked" not in rules_of(CONC, src)

    def test_dict_get_not_confused_with_queue_get(self):
        src = """\
        def f(self, d):
            with self._lock:
                return d.get("key", None)
        """
        assert "blocking-while-locked" not in rules_of(CONC, src)

    def test_join_under_lock_fires(self):
        src = """\
        def f(self, proc):
            with self.state_lock:
                proc.join()
        """
        assert "blocking-while-locked" in rules_of(CONC, src)

    def test_str_join_is_clean(self):
        src = """\
        def f(self, names):
            with self.state_lock:
                return ",".join(names)
        """
        assert "blocking-while-locked" not in rules_of(CONC, src)

    def test_blocking_outside_lock_is_clean(self):
        src = """\
        def f(self, q):
            item = q.get()
            with self._lock:
                self.items.append(item)
        """
        assert "blocking-while-locked" not in rules_of(CONC, src)

    def test_release_clears_held_state(self):
        src = """\
        def f(self, q):
            self._lock.acquire()
            self._lock.release()
            item = q.get()
        """
        assert "blocking-while-locked" not in rules_of(CONC, src)

    def test_inner_def_does_not_inherit_held_locks(self):
        # a closure defined under a lock runs later, on its own stack
        src = """\
        def f(self, q):
            with self._lock:
                def worker():
                    return q.get()
                self.fn = worker
        """
        assert "blocking-while-locked" not in rules_of(CONC, src)

    def test_concurrency_rules_scoped_to_executor_modules(self):
        src = """\
        def f(self, q):
            with self._lock:
                item = q.get()
        """
        assert "blocking-while-locked" not in rules_of(PLAIN, src)


# ---------------------------------------------------------------------------
# pragmas
# ---------------------------------------------------------------------------

class TestPragmas:
    BAD = ("import time\n"
           "t = time.time()  # lint: allow[sim-wall-clock] -- test reason\n")

    def test_same_line_pragma_suppresses(self):
        findings = lint_source(SIM, self.BAD)
        wall = [f for f in findings if f.rule == "sim-wall-clock"]
        assert wall and all(f.suppressed for f in wall)

    def test_own_line_pragma_covers_next_code_line(self):
        src = ("import time\n"
               "# lint: allow[sim-wall-clock] -- test reason\n"
               "t = time.time()\n")
        findings = lint_source(SIM, src)
        wall = [f for f in findings if f.rule == "sim-wall-clock"]
        assert wall and all(f.suppressed for f in wall)

    def test_no_pragmas_flag_restores_finding(self):
        # the delete-any-pragma direction: without the allowlist the
        # violation is live again
        assert "sim-wall-clock" in rules_of(SIM, self.BAD,
                                            respect_pragmas=False)

    def test_reasonless_pragma_is_a_finding(self):
        src = ("import time\n"
               "t = time.time()  # lint: allow[sim-wall-clock]\n")
        assert BAD_PRAGMA in rules_of(SIM, src)

    def test_unknown_rule_pragma_is_a_finding(self):
        src = "x = 1  # lint: allow[no-such-rule] -- whatever\n"
        assert BAD_PRAGMA in rules_of(PLAIN, src)

    def test_unused_pragma_is_a_finding(self):
        src = "x = 1  # lint: allow[sim-wall-clock] -- covers nothing\n"
        assert UNUSED_PRAGMA in rules_of(PLAIN, src)

    def test_pragma_in_string_literal_ignored(self):
        src = 's = "# lint: allow[sim-wall-clock] -- not a comment"\n'
        findings = lint_source(PLAIN, src)
        assert findings == []

    def test_pragma_suppresses_only_named_rule(self):
        src = ("import time\n"
               "t = time.time()  # lint: allow[sim-sleep] -- wrong rule\n")
        ids = rules_of(SIM, src)
        assert "sim-wall-clock" in ids          # still live
        assert UNUSED_PRAGMA in ids             # and the pragma is stale

    def test_meta_rules_cannot_be_suppressed(self):
        # a pragma naming bad-pragma is itself malformed (unknown rule)
        src = "x = 1  # lint: allow[bad-pragma] -- nope\n"
        assert BAD_PRAGMA in rules_of(PLAIN, src)


# ---------------------------------------------------------------------------
# engine + CLI
# ---------------------------------------------------------------------------

class TestEngine:
    def test_syntax_error_reports_parse_error(self):
        assert PARSE_ERROR in rules_of(PLAIN, "def broken(:\n")

    def test_lint_paths_walks_directories(self, tmp_path):
        pkg = tmp_path / "data"
        pkg.mkdir()
        (pkg / "simulator.py").write_text("import time\nt = time.time()\n")
        (pkg / "other.py").write_text("x = 1\n")
        report = lint_paths([str(tmp_path)])
        assert report.files_checked == 2
        assert {f.rule for f in report.unsuppressed} == {"sim-wall-clock"}

    def test_report_json_schema(self, tmp_path):
        (tmp_path / "ok.py").write_text("x = 1\n")
        d = lint_paths([str(tmp_path)]).to_dict()
        assert set(d) == {"files_checked", "ok", "counts", "findings"}
        assert set(d["counts"]) == {"total", "suppressed", "unsuppressed"}
        bad = tmp_path / "data"
        bad.mkdir()
        (bad / "fleet.py").write_text("import time\nt = time.time()\n")
        d = lint_paths([str(tmp_path)]).to_dict()
        assert d["ok"] is False
        (f,) = [x for x in d["findings"] if x["rule"] == "sim-wall-clock"]
        assert set(f) == {"path", "line", "col", "rule", "message",
                          "suppressed"}

    def test_rule_registry_well_formed(self):
        ids = [r.id for r in ALL_RULES]
        assert len(ids) == len(set(ids))
        assert all(r.id and r.doc for r in ALL_RULES)


def _run_cli(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.lint", *args],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=120)


class TestCli:
    def test_list_rules(self):
        proc = _run_cli("--list-rules")
        assert proc.returncode == 0
        for rule in ALL_RULES:
            assert rule.id in proc.stdout

    def test_nonzero_exit_on_finding(self, tmp_path):
        bad = tmp_path / "core"
        bad.mkdir()
        (bad / "model.py").write_text("import time\nt = time.time()\n")
        proc = _run_cli(str(tmp_path))
        assert proc.returncode == 1
        assert "sim-wall-clock" in proc.stdout

    def test_json_output_parses(self, tmp_path):
        (tmp_path / "ok.py").write_text("x = 1\n")
        proc = _run_cli("--json", str(tmp_path))
        assert proc.returncode == 0
        assert json.loads(proc.stdout)["ok"] is True


# ---------------------------------------------------------------------------
# the tier-1 gate: the repo itself lints clean, and every pragma in it
# is load-bearing
# ---------------------------------------------------------------------------

class TestRepoGate:
    def test_repo_lints_clean(self):
        proc = _run_cli("--json", "src")
        assert proc.returncode == 0, proc.stdout + proc.stderr
        report = json.loads(proc.stdout)
        assert report["ok"] is True
        assert report["counts"]["unsuppressed"] == 0

    def test_every_pragma_is_load_bearing(self):
        # normal run: zero unused-pragma findings (each pragma suppresses
        # something). --no-pragmas: each suppression surfaces as a live
        # finding. Together: deleting any single pragma flips exit to 1.
        clean = json.loads(_run_cli("--json", "src").stdout)
        assert not any(f["rule"] == "unused-pragma"
                       for f in clean["findings"])
        suppressed = [f for f in clean["findings"] if f["suppressed"]]
        assert suppressed, "expected the repo's sanctioned exceptions"
        raw = json.loads(_run_cli("--json", "--no-pragmas", "src").stdout)
        live = {(f["path"], f["line"], f["rule"])
                for f in raw["findings"] if not f["suppressed"]}
        for f in suppressed:
            assert (f["path"], f["line"], f["rule"]) in live

    def test_reintroduced_violation_fails(self, tmp_path):
        # put time.time() back into data/simulator.py: the gate must trip
        src_path = os.path.join(REPO, "src", "repro", "data",
                                "simulator.py")
        with open(src_path, encoding="utf-8") as fh:
            text = fh.read()
        sandbox = tmp_path / "data"
        sandbox.mkdir()
        tainted = text + "\nimport time\n_T0 = time.time()\n"
        (sandbox / "simulator.py").write_text(tainted)
        report = lint_paths([str(tmp_path)])
        assert not report.ok
        assert any(f.rule == "sim-wall-clock" for f in report.unsuppressed)
