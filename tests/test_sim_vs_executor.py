"""Differential test: the analytic PipelineSim must rank allocations the
same way the real ThreadedPipeline measures them.

Every benchmark number comes from the simulator (DESIGN.md §3), so this
is the test that ties the model to the engine: on tiny graphs whose
stage costs are real `time.sleep`s, the measured throughput ordering of
candidate allocations must match the simulator's predicted ordering.

Timing-robustness contract (ISSUE 3): every assertion on measured
throughput is RANK-based — no absolute batches/s thresholds anywhere —
and candidates are chosen with >= 1.9x predicted separation. Per-stage
work quanta are >= 10ms so CI scheduler jitter (~1ms) stays an order of
magnitude below the signal. The fleet-plane extension of this suite
lives in tests/test_live_fleet.py."""
import threading
import time

import numpy as np
import pytest

from repro.data.executor import ThreadedPipeline
from repro.data.pipeline import StageGraph, StageSpec
from repro.data.simulator import Allocation, MachineSpec, PipelineSim


def _stage(name, cost, inputs=()):
    # serial_frac=0: rate = workers / cost, exact in both model and engine
    return StageSpec(name, "udf", cost=cost, serial_frac=0.0, inputs=inputs)


def _sleeper(cost):
    def fn(*items):
        time.sleep(cost)
        return items[0] if items else None
    return fn


def _source(cost, n_items):
    lock = threading.Lock()
    count = [0]

    def fn():
        with lock:
            if count[0] >= n_items:
                return None
            count[0] += 1
            i = count[0]
        time.sleep(cost)
        return i
    return fn


def measured_throughput(spec, fns, workers, n_items=30):
    pipe = ThreadedPipeline(spec, fns=fns, queue_depth=16, item_mb=1.0)
    try:
        pipe.set_allocation(workers, prefetch_mb=8.0)
        pipe.get_batch(timeout=30)          # first batch: pipeline fill
        t0 = time.monotonic()
        got = 0
        while True:
            try:
                pipe.get_batch(timeout=30)
                got += 1
            except StopIteration:
                break
        dt = time.monotonic() - t0
    finally:
        pipe.stop()
    assert got >= n_items // 2, "engine lost most of the stream"
    return got / dt


def rank_check(spec, make_fns, allocations, n_items=30):
    sim = PipelineSim(spec, MachineSpec(n_cpus=64, mem_mb=65536))
    predicted = [sim.throughput(Allocation(np.asarray(w)))
                 for w in allocations]
    gaps = sorted(predicted)
    for lo, hi in zip(gaps, gaps[1:]):
        assert hi / lo >= 1.9, "test design: separation too small"
    measured = [measured_throughput(spec, make_fns(n_items), w, n_items)
                for w in allocations]
    assert np.argsort(predicted).tolist() == np.argsort(measured).tolist(), \
        f"sim ranks {predicted} but engine measures {measured}"


def test_linear_chain_ranking():
    spec = StageGraph("lin3", (
        _stage("src", 0.020),
        _stage("work", 0.040, inputs=("src",)),
        _stage("sink", 0.010, inputs=("work",)),
    ), batch_mb=1.0)

    def make_fns(n_items):
        return {"src": _source(0.020, n_items),
                "work": _sleeper(0.040),
                "sink": _sleeper(0.010)}

    # predicted: 25 (bottleneck work), 50 (work unblocked, src binds),
    # 100 (everything doubled) — each step 2x apart
    rank_check(spec, make_fns, [[1, 1, 1], [1, 4, 1], [2, 8, 2]])


def test_join_graph_ranking():
    spec = StageGraph("join4", (
        _stage("a", 0.015),
        _stage("b", 0.030),
        _stage("j", 0.0075, inputs=("a", "b")),
        _stage("s", 0.010, inputs=("j",)),
    ), batch_mb=1.0)

    def make_fns(n_items):
        return {"a": _source(0.015, n_items),
                "b": _source(0.030, n_items),
                "j": lambda x, y: (x, y),    # pairing is free
                "s": _sleeper(0.010)}

    # predicted: 33.3 (join starved by b) vs 66.7 (b tripled, a binds)
    rank_check(spec, make_fns, [[1, 1, 1, 1], [1, 3, 1, 1]])


def test_sim_predictions_match_engine_semantics_exactly():
    """The two predicted numbers rank_check relies on, by hand: the sim's
    DAG bottleneck must equal workers/cost min over the sustaining path."""
    spec = StageGraph("join4", (
        _stage("a", 0.015), _stage("b", 0.030),
        _stage("j", 0.0075, inputs=("a", "b")),
        _stage("s", 0.010, inputs=("j",)),
    ), batch_mb=1.0)
    sim = PipelineSim(spec, MachineSpec(n_cpus=64, mem_mb=65536))
    assert sim.throughput(Allocation(np.array([1, 1, 1, 1]))) \
        == pytest.approx(1 / 0.030)
    assert sim.throughput(Allocation(np.array([1, 3, 1, 1]))) \
        == pytest.approx(1 / 0.015)
