"""MoE tests: dispatch invariants (hypothesis), local == shard_map-on-1,
capacity drop semantics, expert-parallel psum correctness on a host mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.base import TransformerConfig
from repro.models import moe as moe_lib


def tiny_cfg(e=8, k=2, shared=1):
    return TransformerConfig(
        name="t", n_layers=1, d_model=16, n_heads=2, n_kv_heads=2,
        head_dim=8, d_ff=0, vocab_size=64, n_experts=e, n_shared_experts=shared,
        top_k=k, d_expert=12, param_dtype="float32", capacity_factor=2.0)


def layer_params(cfg, e_pad, seed=0):
    p, _ = moe_lib.init_moe_params(
        jax.random.PRNGKey(seed), 1, cfg.d_model, e_pad, cfg.d_expert,
        cfg.n_shared_experts, jnp.float32)
    return jax.tree_util.tree_map(lambda x: x[0], p)   # drop layer dim


@settings(max_examples=25, deadline=None)
@given(t=st.integers(4, 64), e=st.sampled_from([4, 8, 16]),
       k=st.sampled_from([1, 2, 4]))
def test_dispatch_respects_capacity_and_grouping(t, e, k):
    rng = np.random.RandomState(t * 7 + e)
    x = jnp.asarray(rng.randn(t, 8), jnp.float32)
    flat_e = jnp.asarray(rng.randint(0, e, t * k), jnp.int32)
    flat_w = jnp.asarray(rng.rand(t * k), jnp.float32)
    cap = moe_lib.capacity_for(t, k, e, 1.25)
    xbuf, wbuf, tok = moe_lib._dispatch_local(x, flat_e, flat_w, 0, e, cap)
    assert xbuf.shape == (e, cap, 8)
    counts = np.bincount(np.asarray(flat_e), minlength=e)
    w = np.asarray(wbuf)
    for ei in range(e):
        n_valid = int((w[ei] > 0).sum())
        expected = min(counts[ei], cap)
        # valid slots = min(count, capacity) modulo zero-weight entries
        assert n_valid <= expected
        nonzero_inputs = int((np.asarray(flat_w)[np.asarray(flat_e) == ei]
                              > 0).sum())
        assert n_valid <= nonzero_inputs or nonzero_inputs >= expected


def test_local_moe_combines_weighted_expert_outputs():
    cfg = tiny_cfg(e=4, k=2, shared=0)
    p = layer_params(cfg, 4)
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(16, cfg.d_model), jnp.float32)
    y, aux = moe_lib._moe_local(x, p, cfg=cfg, e_start=0, e_loc=4,
                                tp_axis=None, dp_axes=())
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) > 0
    # manual reference: route, run each expert densely, combine
    probs, topw, topi = moe_lib._route(x, p["router"], 4, 2, True)
    ref = np.zeros_like(np.asarray(x))
    xn = np.asarray(x)
    for t in range(16):
        for j in range(2):
            e = int(topi[t, j])
            w = float(topw[t, j])
            h = (jax.nn.silu(xn[t] @ np.asarray(p["wg"][e]))
                 * (xn[t] @ np.asarray(p["wi"][e])))
            ref[t] += w * (h @ np.asarray(p["wo"][e]))
    np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-4, atol=2e-4)


def test_moe_ffn_shardmap_matches_local_on_host_mesh():
    cfg = tiny_cfg(e=8, k=2, shared=1)
    e_pad = 8
    p = layer_params(cfg, e_pad)
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(4, 8, cfg.d_model), jnp.float32)
    y_local, aux_local = moe_lib.moe_ffn(x, p, cfg, None, e_pad)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    y_sm, aux_sm = jax.jit(
        lambda x, p: moe_lib.moe_ffn(x, p, cfg, mesh, e_pad))(x, p)
    np.testing.assert_allclose(np.asarray(y_local), np.asarray(y_sm),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(aux_local), float(aux_sm), rtol=1e-5)


def test_padded_experts_never_selected():
    cfg = tiny_cfg(e=6, k=2, shared=0)     # pad to 8
    p = layer_params(cfg, 8)
    rng = np.random.RandomState(2)
    x = jnp.asarray(rng.randn(32, cfg.d_model), jnp.float32)
    probs, topw, topi = moe_lib._route(x, p["router"], 6, 2, True)
    assert int(jnp.max(topi)) < 6
