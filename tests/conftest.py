"""Shared fixtures + the tsan-lite sanitizer plugin.

NOTE: no XLA_FLAGS here — smoke tests and benches must see the real
1-CPU device count (only launch/dryrun.py forces 512).

REPRO_SANITIZE=1 installs `repro.lint.runtime`'s tracked lock factories
and queue wrappers for the whole pytest session (CI's push-only
`sanitize` job runs the executor suites this way). At session end the
observed report is dumped to $REPRO_SANITIZE_OUT (default
sanitize-report.json) for the `--runtime-report` reconciliation gate,
and the session FAILS on its own if the run observed a lock-order cycle
or any blocking-under-lock event longer than $REPRO_SANITIZE_BLOCK_MS
(default 200 ms).
"""
import json
import os

import jax
import numpy as np
import pytest

_SANITIZE = os.environ.get("REPRO_SANITIZE") == "1"


def pytest_configure(config):
    if _SANITIZE:
        from repro.lint import runtime
        runtime.install()


def pytest_sessionfinish(session, exitstatus):
    if not _SANITIZE:
        return
    from repro.lint import runtime
    if not runtime.installed():
        return
    report = runtime.snapshot()
    runtime.uninstall()
    out = os.environ.get("REPRO_SANITIZE_OUT", "sanitize-report.json")
    with open(out, "w", encoding="utf-8") as fh:
        json.dump(report, fh, indent=1, sort_keys=True)
    # Gate only on locks CREATED in product code: the lint suite seeds
    # deliberate inversions in tmp fixtures, and those must fail their
    # own assertions, not the whole session. The dumped report keeps
    # everything — reconciliation re-filters by analyzed module anyway.
    src = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       os.pardir, "src")
    src = os.path.abspath(src) + os.sep

    def in_src(site):
        return site.startswith(src)

    threshold_ms = float(os.environ.get("REPRO_SANITIZE_BLOCK_MS", "200"))
    slow = [b for b in report["blocking"]
            if b["ms"] > threshold_ms and in_src(b["lock"])]
    problems = []
    for cyc in report["cycles"]:
        if all(in_src(site) for site in cyc):
            problems.append("observed lock-order cycle: "
                            + " -> ".join(cyc))
    for b in slow:
        problems.append(
            f"blocked {b['ms']:.1f}ms in {b['op']} at {b['site']} while "
            f"holding {b['lock']} (threshold {threshold_ms:.0f}ms)")
    tr = session.config.pluginmanager.get_plugin("terminalreporter")
    if problems:
        session.exitstatus = 1
        lines = ["REPRO_SANITIZE: FAIL"] + problems
    else:
        lines = [f"REPRO_SANITIZE: clean ({len(report['edges'])} lock-order "
                 f"edge(s), {len(report['blocking'])} blocking event(s) "
                 f"under threshold; report: {out})"]
    for line in lines:
        if tr is not None:
            tr.write_line(line)
        else:
            print(line)


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)


@pytest.fixture(scope="session")
def jrng():
    return jax.random.PRNGKey(0)
