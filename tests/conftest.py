"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real 1-CPU device count (only launch/dryrun.py forces 512)."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)


@pytest.fixture(scope="session")
def jrng():
    return jax.random.PRNGKey(0)
