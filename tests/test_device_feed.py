"""data/device_feed.py: prefetch depth/ordering/draining, shard math,
stall metering, and the proc->device bridge composition (fake pipe —
no processes forked here; the live path is tests/test_featurize.py)."""
import queue
import time

import numpy as np
import pytest

from repro.data.device_feed import (MeteredFeed, ShardError, device_prefetch,
                                    make_train_feed, shard_slice)


def _wait_until(pred, deadline_s=2.0):
    t0 = time.monotonic()
    while not pred():
        if time.monotonic() - t0 > deadline_s:
            return False
        time.sleep(0.005)
    return True


# ------------------------------------------------------ device_prefetch --

def test_prefetch_preserves_order_and_count():
    out = list(device_prefetch(iter(range(10)), depth=2))
    assert [int(np.asarray(x)) for x in out] == list(range(10))


def test_prefetch_keeps_depth_in_flight():
    """After the consumer pulls item k, the background producer must
    advance the source to exactly depth items ahead — no more (the
    semaphore bounds in-flight), and eventually no fewer (production is
    asynchronous, so we poll with a deadline rather than assert
    synchronously)."""
    pulled = []

    def src():
        for i in range(8):
            pulled.append(i)
            yield i

    it = device_prefetch(src(), depth=3)
    next(it)
    # one yielded + 3 in the buffer, eventually; never past 4
    assert _wait_until(lambda: len(pulled) == 4)
    time.sleep(0.05)
    assert len(pulled) == 4
    next(it)
    assert _wait_until(lambda: len(pulled) == 5)
    time.sleep(0.05)
    assert len(pulled) == 5
    it.close()


def test_prefetch_hides_jittery_producer():
    """THE regression for the ISSUE 7 prefetch bugfix: with a producer
    whose mean rate beats consumption but whose latency is spiky, a
    depth-2 buffer must absorb the spikes — near-zero stall at the
    metered boundary. The old generator version pulled synchronously
    inside the consumer's `next()`, so every producer hiccup landed in
    `stall_s` verbatim regardless of depth."""
    def jittery():
        for i in range(16):
            if i and i % 4 == 0:
                time.sleep(0.06)   # spike; mean cost/item = 0.015s
            yield i

    feed = MeteredFeed(device_prefetch(jittery(), depth=2))
    out = []
    for x in feed:
        out.append(int(np.asarray(x)))
        time.sleep(0.03)           # consumer slower than the MEAN producer
    assert out == list(range(16))
    # 3 spikes x 0.06s would be ~0.18s stall through the broken
    # prefetcher; the real one hides them behind the buffer
    assert feed.counters()["stall_s"] < 0.06


def test_prefetch_close_joins_producer():
    """close() must stop a producer that still has items upstream, even
    one blocked waiting for a permit."""
    def endless():
        i = 0
        while True:
            yield i
            i += 1

    it = device_prefetch(endless(), depth=2)
    assert int(np.asarray(next(it))) == 0
    it.close()
    assert not it._thread.is_alive()
    it.close()   # idempotent


def test_prefetch_propagates_producer_error():
    def boom():
        yield 1
        raise RuntimeError("upstream died")

    it = device_prefetch(boom(), depth=2)
    assert int(np.asarray(next(it))) == 1
    with pytest.raises(RuntimeError, match="upstream died"):
        for _ in it:
            pass


def test_prefetch_drains_short_and_empty_iterators():
    # source shorter than depth: everything still comes out, in order
    out = list(device_prefetch(iter([7, 8]), depth=5))
    assert [int(np.asarray(x)) for x in out] == [7, 8]
    assert list(device_prefetch(iter([]), depth=2)) == []


def test_prefetch_stopiteration_draining():
    """StopIteration mid-refill must not drop buffered items."""
    it = device_prefetch(iter(range(4)), depth=2)
    assert int(np.asarray(next(it))) == 0     # buffer holds 1, 2
    assert [int(np.asarray(x)) for x in it] == [1, 2, 3]
    with pytest.raises(StopIteration):
        next(it)


def test_prefetch_tree_batches():
    batches = [{"a": np.full((2,), i), "b": np.full((3,), -i)}
               for i in range(4)]
    out = list(device_prefetch(iter(batches), depth=2))
    assert len(out) == 4
    for i, b in enumerate(out):
        np.testing.assert_array_equal(np.asarray(b["a"]), np.full((2,), i))
        np.testing.assert_array_equal(np.asarray(b["b"]), np.full((3,), -i))


# ---------------------------------------------------------- shard_slice --

def test_shard_slice_even_split():
    batch = {"x": np.arange(8), "y": np.arange(16).reshape(8, 2)}
    s0 = shard_slice(batch, 0, 4)
    s3 = shard_slice(batch, 3, 4)
    np.testing.assert_array_equal(s0["x"], [0, 1])
    np.testing.assert_array_equal(s3["x"], [6, 7])
    assert s3["y"].shape == (2, 2)


def test_shard_slice_indivisible_raises():
    """n not divisible by n_hosts used to silently drop the remainder
    rows; it is now a named error (global batch size corruption is not
    a thing to paper over)."""
    batch = {"x": np.arange(10)}
    with pytest.raises(ShardError, match="not divisible"):
        shard_slice(batch, 0, 3)


def test_shard_slice_fewer_rows_than_hosts_raises():
    """n < n_hosts used to hand every host an empty slice."""
    batch = {"x": np.arange(2)}
    with pytest.raises(ShardError, match="empty slice"):
        shard_slice(batch, 0, 4)


def test_shard_slice_bad_host_id_raises():
    batch = {"x": np.arange(8)}
    with pytest.raises(ShardError, match="out of range"):
        shard_slice(batch, 4, 4)


def test_shard_slice_single_host_identity():
    batch = {"x": np.arange(5)}
    np.testing.assert_array_equal(shard_slice(batch, 0, 1)["x"], batch["x"])


# ----------------------------------------------------------- MeteredFeed --

def test_metered_feed_counts_and_passes_through():
    feed = MeteredFeed(iter([10, 20, 30]))
    assert next(feed) == 10
    assert [x for x in feed] == [20, 30]
    c = feed.counters()
    assert c["batches"] == 3.0
    assert c["stall_s"] >= 0.0
    assert c["time"] <= time.monotonic()


def test_metered_feed_times_blocking_next():
    def slow():
        yield 1
        time.sleep(0.05)
        yield 2

    feed = MeteredFeed(slow())
    next(feed)
    c0 = feed.counters()
    next(feed)
    c1 = feed.counters()
    assert c1["stall_s"] - c0["stall_s"] >= 0.04
    assert c1["batches"] - c0["batches"] == 1.0


def test_metered_feed_stall_charged_even_on_stopiteration():
    feed = MeteredFeed(iter([]))
    with pytest.raises(StopIteration):
        next(feed)
    assert feed.counters()["batches"] == 0.0


# ------------------------------------------------------- make_train_feed --

class _FakePipe:
    """ProcessPipeline-shaped: get_batch returns numbered dict batches."""

    def __init__(self):
        self.i = 0
        self.timeouts = []

    def get_batch(self, timeout=10.0):
        self.timeouts.append(timeout)
        self.i += 1
        return {"x": np.full((4,), self.i - 1)}


def test_make_train_feed_composes_bridge():
    pipe = _FakePipe()
    feed = make_train_feed(pipe, depth=2, timeout=33.0)
    assert isinstance(feed, MeteredFeed)
    b0 = next(feed)
    np.testing.assert_array_equal(np.asarray(b0["x"]), np.zeros(4))
    # depth batches in flight beyond the one consumed (async producer)
    assert _wait_until(lambda: pipe.i == 3)
    # get_batch is pulled on a short poll so feed.close() can interrupt
    assert all(t <= 33.0 for t in pipe.timeouts)
    b1 = next(feed)
    np.testing.assert_array_equal(np.asarray(b1["x"]), np.ones(4))
    assert feed.counters()["batches"] == 2.0
    feed.close()


def test_make_train_feed_timeout_raises_empty():
    class _StarvedPipe:
        def get_batch(self, timeout=10.0):
            time.sleep(timeout)
            raise queue.Empty

    feed = make_train_feed(_StarvedPipe(), depth=2, timeout=0.5)
    with pytest.raises(queue.Empty):
        next(feed)


def test_make_train_feed_close_stops_producer():
    pipe = _FakePipe()
    feed = make_train_feed(pipe, depth=2)
    next(feed)
    feed.close()
    pulled = pipe.i
    time.sleep(0.1)
    assert pipe.i == pulled   # producer really stopped


def test_make_train_feed_pipe_eos_is_clean_stop():
    """pipe.get_batch raising StopIteration (EOS sentinel) inside the
    producer generator must surface as normal iterator exhaustion, not
    PEP 479's RuntimeError."""
    class _EosPipe:
        def __init__(self):
            self.i = 0

        def get_batch(self, timeout=10.0):
            if self.i >= 3:
                raise StopIteration
            self.i += 1
            return {"x": np.full((2,), self.i)}

    feed = make_train_feed(_EosPipe(), depth=2)
    assert len(list(feed)) == 3


# -------------------------------------------------- FeedBackend (stubbed) --
# The measure/apply split and the two device-idle modes, on hand-driven
# counters (no processes). The live path runs in examples/ and
# benchmarks/fig_train_feed.py.

from repro.api import FeedBackend, Session, Telemetry          # noqa: E402
from repro.data.pipeline import StageGraph, StageSpec          # noqa: E402
from repro.data.simulator import Allocation, MachineSpec       # noqa: E402


def _spec2():
    return StageGraph("fb2", (
        StageSpec("src", "source", cost=0.01, serial_frac=0.0,
                  mem_per_worker_mb=4.0),
        StageSpec("udf", "udf", cost=0.01, serial_frac=0.0,
                  mem_per_worker_mb=4.0, inputs=("src",)),
    ), batch_mb=1.0)


class _StubPipe:
    def __init__(self, machine):
        self.spec = _spec2()
        self.machine = machine
        self.c = {"delivered": 0.0, "consumed": 0.0, "time": 0.0}
        self.rss = 64.0
        self.allocs = []
        self.shutdowns = 0

    def counters(self):
        return dict(self.c)

    def rss_mb(self):
        return self.rss

    def stats(self):
        return {"throughput": 99.0, "stage_latency": [0.01, 0.01],
                "workers": np.array([2, 1]), "mem_frac": 0.1}

    def set_allocation(self, workers, prefetch_mb):
        self.allocs.append((list(workers), prefetch_mb))

    def apply_cpu_cap(self):
        pass

    def shutdown(self, drain=False, timeout=10.0):
        self.shutdowns += 1
        return {"delivered": 9, "consumed": 9, "drained": 0,
                "joined": True, "dropped": 0}


class _StubFeed:
    def __init__(self):
        self.c = {"batches": 0.0, "stall_s": 0.0, "time": 0.0}

    def counters(self):
        return dict(self.c)


def _feed_backend(device_step_s=None, mem_mb=4096.0):
    pipe = _StubPipe(MachineSpec(n_cpus=4, mem_mb=mem_mb))
    feed = _StubFeed()
    return FeedBackend(pipe, feed, device_step_s=device_step_s), pipe, feed


def test_feed_backend_measure_differences_windows():
    bk, pipe, feed = _feed_backend(device_step_s=0.5)
    # window 1: 10s wall, 8 batches on device, 16 items consumed, 1s stall
    feed.c = {"batches": 8.0, "stall_s": 1.0, "time": 10.0}
    pipe.c = {"delivered": 16.0, "consumed": 16.0, "time": 10.0}
    tel = bk.measure()
    assert tel.throughput == pytest.approx(1.6)
    assert tel.step_time_s == pytest.approx(10.0 / 8)
    assert tel.feed_stall_s == pytest.approx(1.0)
    # idle = 1 - batches * device_step / wall = 1 - 8*0.5/10
    assert tel.device_idle_frac == pytest.approx(0.6)
    assert tel.used_cpus == 3                 # sum of stats()["workers"]
    assert "stage_latency" in tel.extras      # InTune's live-mode trigger
    assert "throughput" not in tel.extras
    # window 2 is differenced, not cumulative: 2 batches over 1s
    feed.c = {"batches": 10.0, "stall_s": 1.0, "time": 11.0}
    pipe.c = {"delivered": 20.0, "consumed": 20.0, "time": 11.0}
    tel2 = bk.measure()
    assert tel2.throughput == pytest.approx(4.0)
    assert tel2.device_idle_frac == pytest.approx(0.0)   # clamped at 0
    assert tel2.step_time_s == pytest.approx(0.5)


def test_feed_backend_stall_mode_without_device_step():
    bk, pipe, feed = _feed_backend(device_step_s=None)
    feed.c = {"batches": 4.0, "stall_s": 2.5, "time": 10.0}
    pipe.c = {"delivered": 4.0, "consumed": 4.0, "time": 10.0}
    tel = bk.measure()
    assert tel.device_idle_frac == pytest.approx(0.25)   # stall / wall


def test_feed_backend_zero_batch_window():
    bk, pipe, feed = _feed_backend(device_step_s=0.5)
    feed.c = {"batches": 0.0, "stall_s": 3.0, "time": 3.0}
    tel = bk.measure()
    assert tel.step_time_s is None
    assert tel.device_idle_frac == pytest.approx(1.0)


def test_feed_backend_apply_validates_and_caches():
    bk, pipe, feed = _feed_backend()
    alloc = Allocation(np.array([2, 1], dtype=int), prefetch_mb=4.0)
    tel = bk.apply(alloc)
    assert pipe.allocs == [([2, 1], 4.0)]
    assert tel.extras.get("pending")          # cached pre-measure Telemetry
    feed.c = {"batches": 2.0, "stall_s": 0.0, "time": 1.0}
    measured = bk.measure()
    assert bk.apply(alloc) is measured        # apply returns LAST measure
    with pytest.raises(Exception):
        bk.apply(Allocation(np.array([2], dtype=int), prefetch_mb=4.0))
    # apply(None) falls through to measure (self-driving contract)
    feed.c = {"batches": 3.0, "stall_s": 0.0, "time": 2.0}
    assert bk.apply(None).throughput >= 0.0


def test_feed_backend_oom_entry_counting():
    bk, pipe, feed = _feed_backend(mem_mb=100.0)
    ticks = []
    for rss in (50.0, 150.0, 150.0, 50.0, 150.0):
        pipe.rss = rss
        feed.c["time"] += 1.0
        pipe.c["consumed"] += 1.0
        ticks.append(bk.measure().oom)
    # report-only: oomed flags every over-budget window, but the COUNT
    # increments only on entry into the over-budget state
    assert ticks == [False, True, True, False, True]
    assert bk.oom_count == 2


def test_feed_backend_shutdown_summary():
    bk, pipe, _ = _feed_backend()
    summary = bk.shutdown()
    assert pipe.shutdowns == 1
    assert summary["all_joined"] and summary["dropped_batches"] == 0
    assert bk.shutdown() is summary           # idempotent, no second teardown
    assert pipe.shutdowns == 1
    with pytest.raises(RuntimeError):
        bk.measure()


def test_telemetry_feed_fields_hidden_when_unset():
    """Backends construct Telemetry positionally; dict-shaped consumers
    (golden JSONs, RunResult series) must see NO new keys unless the
    feed fields are actually populated."""
    plain = Telemetry(throughput=5.0, mem_mb=10.0, used_cpus=2)
    assert "device_idle_frac" not in plain.keys()
    assert "device_idle_frac" not in plain.to_dict()
    fed = Telemetry(throughput=5.0, mem_mb=10.0, used_cpus=2,
                    device_idle_frac=0.3, step_time_s=0.1, feed_stall_s=0.0)
    assert fed.to_dict()["device_idle_frac"] == pytest.approx(0.3)
    assert "step_time_s" in fed.keys()


def test_intune_feed_reward_uses_device_idle():
    """At a feed boundary the reward must be device business, not pipe
    throughput — pipe throughput REWARDS stealing the trainer's cores
    (the regression the first fig_train_feed run measured)."""
    from repro.core.controller import InTune
    spec, machine = _spec2(), MachineSpec(n_cpus=8, mem_mb=4096.0)
    tuner = InTune(spec, machine, seed=0, head="factored",
                   init_alloc=Allocation(np.array([1, 1], dtype=int),
                                         prefetch_mb=2.0))
    np.testing.assert_array_equal(tuner.env.alloc.workers, [1, 1])
    tuner.propose(spec, machine, None)
    live = {"stage_latency": [0.01, 0.02], "workers": [1, 1],
            "free_cpus": 6.0, "mem_frac": 0.2, "prefetch_mb": 2.0}
    tel = Telemetry(throughput=1e6, mem_mb=64.0, used_cpus=2,
                    extras=dict(live), device_idle_frac=0.25,
                    step_time_s=0.1, feed_stall_s=0.0)
    tuner.observe(tel)
    # (1 - idle) * (1 - mem_frac), NOT throughput-scaled (1e6 would
    # explode the throughput-based reward)
    assert tuner.history[-1]["reward"] == pytest.approx(0.75 * 0.8)
    # without feed fields the legacy throughput reward still applies
    tuner.propose(spec, machine, None)
    tel2 = Telemetry(throughput=5.0, mem_mb=64.0, used_cpus=2,
                     extras=dict(live))
    tuner.observe(tel2)
    expected = 5.0 / tuner.env.reward_scale * 0.8
    assert tuner.history[-1]["reward"] == pytest.approx(expected)


def test_session_step_measure_observe_propose_apply_order():
    calls = []

    class _Opt:
        def observe(self, tel):
            calls.append(("observe", tel.throughput))

        def propose(self, spec, machine, stats=None):
            calls.append(("propose", machine.n_cpus,
                          stats and stats.get("throughput")))
            return Allocation(np.array([1, 1], dtype=int), prefetch_mb=2.0)

    bk, pipe, feed = _feed_backend()
    feed.c = {"batches": 2.0, "stall_s": 0.0, "time": 1.0}
    pipe.c = {"delivered": 3.0, "consumed": 3.0, "time": 1.0}
    tel = Session(bk, _Opt()).step()
    assert tel.throughput == pytest.approx(3.0)
    assert calls == [("observe", pytest.approx(3.0)),
                     ("propose", 4, 99.0)]
    assert pipe.allocs == [([1, 1], 2.0)]


