"""Equivalence tests for the §Perf optimized paths vs their baselines
(1-device mesh: same math, different collectives)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.shardlib import ShardCtx, make_rules
from repro.configs.base import DLRMConfig, GNNConfig, RecsysConfig
from repro.data.synthetic import bert4rec_batch
from repro.models import dlrm as dlrm_lib
from repro.models import gnn as gnn_lib
from repro.models import recsys as recsys_lib
from repro.models.embedding import multifeature_bag, tp_multifeature_bag
from repro.train.optim import make_optimizer


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_tp_multifeature_bag_matches(mesh):
    rng = np.random.RandomState(0)
    tables = jnp.asarray(rng.randn(5, 64, 8), jnp.float32)
    ids = jnp.asarray(rng.randint(0, 64, (12, 5, 3)), jnp.int32)
    for combiner in ("sum", "mean"):
        ref = multifeature_bag(tables, ids, combiner=combiner)
        out = jax.jit(lambda t: tp_multifeature_bag(
            t, ids, mesh, combiner=combiner))(tables)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        g_ref = jax.grad(lambda t: jnp.sum(
            jnp.sin(multifeature_bag(t, ids, combiner=combiner))))(tables)
        g_out = jax.jit(jax.grad(lambda t: jnp.sum(jnp.sin(
            tp_multifeature_bag(t, ids, mesh, combiner=combiner)))))(tables)
        np.testing.assert_allclose(np.asarray(g_out), np.asarray(g_ref),
                                   rtol=1e-5, atol=1e-5)


def test_bert4rec_tp_loss_matches(mesh):
    cfg0 = RecsysConfig(name="bert4rec", interaction="bidir-seq",
                        embed_dim=16, n_blocks=2, n_heads=2, seq_len=12,
                        n_items=256, vocab_sizes=(256,), n_mask=3,
                        n_negatives=7)
    cfg1 = cfg0.replace(tp_lookup=True)
    ctx = ShardCtx(mesh, make_rules())
    p, _ = recsys_lib.init_bert4rec(jax.random.PRNGKey(0), cfg0)
    b = {k: jnp.asarray(v) for k, v in bert4rec_batch(
        np.random.RandomState(0), 8, 12, 256, 3, 7).items()}
    l0, _ = recsys_lib.bert4rec_loss(p, cfg0, b)
    l1, _ = jax.jit(
        lambda p, b: recsys_lib.bert4rec_loss(p, cfg1, b, ctx=ctx))(p, b)
    assert abs(float(l0 - l1)) < 1e-5
    g0 = jax.grad(lambda p: recsys_lib.bert4rec_loss(p, cfg0, b)[0])(p)
    g1 = jax.jit(jax.grad(
        lambda p: recsys_lib.bert4rec_loss(p, cfg1, b, ctx=ctx)[0]))(p)
    for a, c in zip(jax.tree_util.tree_leaves(g0),
                    jax.tree_util.tree_leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=1e-4, atol=1e-5)


def test_gnn_partitioned_matches(mesh):
    cfg = GNNConfig(name="sage", n_layers=2, d_hidden=16, n_classes=5)
    rng = np.random.RandomState(0)
    n, e = 50, 200
    params, _ = gnn_lib.init_params(jax.random.PRNGKey(0), cfg, d_feat=12)
    x = jnp.asarray(rng.randn(n, 12), jnp.float32)
    src = rng.randint(0, n, e)
    dst = rng.randint(0, n, e)
    labels = rng.randint(0, 5, n)
    base = {"x": x, "edge_src": jnp.asarray(src),
            "edge_dst": jnp.asarray(dst), "labels": jnp.asarray(labels)}
    l0, _ = gnn_lib.full_graph_loss(params, cfg, base)
    es = np.full((1, 256), -1, np.int32)
    ed = np.full((1, 256), -1, np.int32)
    es[0, :e], ed[0, :e] = src, dst
    pb = {"x": x, "edge_src": jnp.asarray(es), "edge_dst": jnp.asarray(ed),
          "labels": jnp.asarray(labels)}
    l1, _ = jax.jit(lambda p, b: gnn_lib.full_graph_partitioned_loss(
        p, cfg, b, mesh))(params, pb)
    assert abs(float(l0 - l1)) < 1e-5


def test_dlrm_score_candidates_matches_forward(mesh):
    cfg = DLRMConfig(name="dlrm-r", n_sparse=6, n_dense=4, embed_dim=8,
                     vocab_sizes=(64,) * 6, bottom_mlp=(16, 8),
                     top_mlp=(32, 1))
    rng = np.random.RandomState(0)
    params, _ = dlrm_lib.init_params(jax.random.PRNGKey(0), cfg)
    user = {"sparse_ids": jnp.asarray(rng.randint(0, 64, (1, 6, 1)),
                                      jnp.int32),
            "dense": jnp.asarray(rng.randn(1, 4), jnp.float32)}
    cand = jnp.arange(50, dtype=jnp.int32)
    fast = dlrm_lib.score_candidates(params, cfg, user, cand, chunks=5)
    # reference: forward() with the candidate substituted into feature 0
    sp = jnp.broadcast_to(user["sparse_ids"], (50, 6, 1))
    sp = sp.at[:, 0, :].set(cand[:, None] % 64)
    dense = jnp.broadcast_to(user["dense"], (50, 4))
    ref = dlrm_lib.forward(params, cfg, {"sparse_ids": sp, "dense": dense})
    np.testing.assert_allclose(np.asarray(fast), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_rowwise_adagrad():
    opt = make_optimizer("rowwise_adagrad", lr=0.1, warmup=1,
                         total_steps=100)
    target = jnp.asarray(np.random.RandomState(0).randn(8, 16), jnp.float32)
    params = {"w": jnp.zeros((8, 16))}
    state = opt.init(params)
    assert state["acc"]["w"].shape == (8, 16)   # small leaf: elementwise
    loss = lambda p: jnp.mean((p["w"] - target) ** 2)
    l0 = float(loss(params))
    for step in range(80):
        grads = jax.grad(loss)(params)
        params, state, _ = opt.update(grads, state, params, step)
    assert float(loss(params)) < l0 * 0.2
    # big leaf -> row-wise accumulator shape
    big = {"t": jnp.zeros((4, 1 << 23, 1))}
    st = opt.init(big)
    assert st["acc"]["t"].shape == (4, 1 << 23)
