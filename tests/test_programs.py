"""launch/programs unit tests: input_specs shapes, collective parsing."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.launch import programs
from repro.launch.dryrun import parse_collectives, _shape_bytes


def test_all_archs_registered():
    archs = list_archs()
    assert len(archs) == 11
    for a in archs:
        spec = get_arch(a)
        assert len(spec.shapes) == 4


def test_lm_input_specs():
    arch = get_arch("gemma2-2b")
    specs = programs.input_specs(arch, "train_4k")
    assert specs["tokens"].shape == (256, 4096)
    specs = programs.input_specs(arch, "decode_32k")
    assert specs["cache"]["k"].shape == (26, 128, 32768, 4, 256)
    assert specs["tokens"].shape == (128,)
    specs = programs.input_specs(arch, "long_500k")
    assert specs["cache"]["k"].shape == (26, 1, 524288, 4, 256)


def test_gnn_input_specs_pad_edges():
    arch = get_arch("graphsage-reddit")
    specs = programs.input_specs(arch, "ogb_products")
    e = specs["edge_src"].shape[0]
    assert e % 512 == 0 and e >= 61859140
    specs = programs.input_specs(arch, "minibatch_lg")
    assert specs["neigh2"].shape == (1024, 15, 10, 602)


def test_recsys_input_specs():
    arch = get_arch("bert4rec")
    specs = programs.input_specs(arch, "train_batch")
    assert specs["item_seq"].shape == (65536, 200)
    assert specs["neg_ids"].shape == (65536, 20, 127)
    specs = programs.input_specs(arch, "retrieval_cand")
    assert specs["cand_ids"].shape == (1_000_000,)


def test_skipped_cells_documented():
    skipped = {(a, s[0]) for a in list_archs()
               for s in get_arch(a).skipped_shapes}
    assert ("qwen2.5-32b", "long_500k") in skipped
    assert ("gemma2-2b", "long_500k") not in skipped  # hybrid: runs


def test_shape_bytes():
    assert _shape_bytes("bf16[4,8]") == 64
    assert _shape_bytes("f32[10]") == 40
    assert _shape_bytes("pred[3,3]") == 9


def test_parse_collectives():
    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(bf16[1,128]{1,0} %x), dimensions={0}
  %ar = f32[64]{0} all-reduce(f32[64]{0} %y), to_apply=%sum
  %t = (f32[4]{0}, f32[4]{0}) all-to-all(f32[4]{0} %a, f32[4]{0} %b)
  %rs = f32[2,4]{1,0} reduce-scatter(f32[8,4]{1,0} %z), dimensions={0}
  %nota = f32[2] add(f32[2] %p, f32[2] %q)
"""
    out = parse_collectives(hlo)
    assert out["all-gather"]["count"] == 1
    assert out["all-gather"]["bytes"] == 8 * 128 * 2
    assert out["all-reduce"]["bytes"] == 256
    assert out["all-to-all"]["bytes"] == 32
    assert out["reduce-scatter"]["bytes"] == 32
    assert out["total_bytes"] == 8 * 128 * 2 + 256 + 32 + 32
