"""shardlib property tests + elastic mesh planning."""
import jax
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.common import shardlib
from repro.train.elastic import ElasticCoordinator, viable_mesh_shape


class FakeMesh:
    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


@settings(max_examples=60, deadline=None)
@given(dims=st.lists(st.integers(1, 4096), min_size=1, max_size=4),
       names=st.lists(st.sampled_from(
           ["batch", "vocab", "mlp", "fsdp", "heads", None]),
           min_size=1, max_size=4))
def test_sanitized_pspec_always_divisible(dims, names):
    n = min(len(dims), len(names))
    dims, names = dims[:n], names[:n]
    rules = shardlib.make_rules()
    for mesh in (MESH, MESH3):
        spec = shardlib.sanitized_pspec(dims, names, rules, mesh)
        used = []
        for dim, axis in zip(dims, tuple(spec) + (None,) * 10):
            if axis is None:
                continue
            axes = (axis,) if isinstance(axis, str) else axis
            prod = 1
            for a in axes:
                prod *= mesh.shape[a]
                used.append(a)
            assert dim % prod == 0       # never uneven
        assert len(used) == len(set(used))  # each mesh axis at most once


def test_pod_axis_filtered_on_single_pod():
    rules = shardlib.make_rules()
    spec = shardlib.sanitized_pspec((256, 128), ("batch", None), rules, MESH)
    assert spec == P("data", None)
    spec3 = shardlib.sanitized_pspec((256, 128), ("batch", None), rules,
                                     MESH3)
    assert spec3 == P(("pod", "data"), None)


def test_overrides_apply():
    rules = shardlib.make_rules({"heads": None, "head_dim": "model"})
    spec = shardlib.sanitized_pspec((512, 9, 64), ("fsdp", "heads",
                                                   "head_dim"), rules, MESH)
    assert spec == P("data", None, "model")


@settings(max_examples=40, deadline=None)
@given(n=st.integers(1, 2048))
def test_viable_mesh_shape_fits(n):
    dp, tp = viable_mesh_shape(n, model_parallel=16)
    assert dp * tp <= n or (dp == 1 and tp <= 16)
    assert dp & (dp - 1) == 0            # power of two
    assert tp <= 16


def test_elastic_coordinator_plans():
    coord = ElasticCoordinator(n_devices=256, model_parallel=16)
    assert coord.current == (16, 16)
    plan = coord.recovery_plan(200)      # lost 56 devices
    assert plan["mesh_shape"][0] * plan["mesh_shape"][1] <= 200
    plan = coord.recovery_plan(8)        # catastrophic loss
    assert plan["mesh_shape"][1] <= 8
