"""Per-kernel shape/dtype sweeps, assert_allclose against ref.py oracles
(assignment requirement: every Pallas kernel validated in interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("v,d,b,bag", [
    (64, 8, 4, 1), (512, 32, 16, 4), (1024, 128, 32, 8), (128, 10, 8, 3),
])
def test_embedding_bag_sweep(v, d, b, bag, dtype):
    rng = np.random.RandomState(v + d)
    table = jnp.asarray(rng.randn(v, d), dtype)
    ids = jnp.asarray(rng.randint(0, v, (b, bag)), jnp.int32)
    for combiner in ("sum", "mean"):
        out = ops.embedding_bag(table, ids, combiner=combiner,
                                interpret=True)
        exp = ref.embedding_bag_ref(table, ids, combiner=combiner)
        tol = 1e-6 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(exp, np.float32),
            rtol=tol, atol=tol)


@settings(max_examples=20, deadline=None)
@given(v=st.integers(8, 300), d=st.sampled_from([4, 16, 33]),
       b=st.integers(1, 24), bag=st.integers(1, 6))
def test_embedding_bag_property(v, d, b, bag):
    rng = np.random.RandomState(v * 31 + d)
    table = jnp.asarray(rng.randn(v, d), jnp.float32)
    ids = jnp.asarray(rng.randint(0, v, (b, bag)), jnp.int32)
    out = ops.embedding_bag(table, ids, interpret=True)
    exp = ref.embedding_bag_ref(table, ids)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,f,d,tile", [
    (64, 27, 16, 32), (128, 27, 128, 128), (32, 8, 8, 8), (48, 13, 32, 16),
])
def test_dot_interact_sweep(b, f, d, tile, dtype):
    rng = np.random.RandomState(b + f)
    feats = jnp.asarray(rng.randn(b, f, d), dtype)
    out = ops.dot_interact(feats, tile_b=tile, interpret=True)
    exp = ref.dot_interact_ref(feats)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32),
        rtol=tol, atol=tol)
    assert out.shape == (b, f * (f - 1) // 2)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,f,d,h,tile", [
    (64, 15, 64, 128, 32), (128, 10, 602, 128, 64), (32, 25, 32, 16, 32),
])
def test_sage_aggregate_sweep(b, f, d, h, tile, dtype):
    rng = np.random.RandomState(b)
    neigh = jnp.asarray(rng.randn(b, f, d), dtype)
    w = jnp.asarray(rng.randn(d, h) * d ** -0.5, dtype)
    out = ops.sage_aggregate(neigh, w, tile_b=tile, interpret=True)
    exp = ref.sage_aggregate_ref(neigh, w)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32),
        rtol=tol, atol=tol)


def test_kernels_match_model_code():
    """The kernels' oracles ARE the model-code ops they accelerate."""
    from repro.models.dlrm import dot_interaction
    rng = np.random.RandomState(0)
    feats = jnp.asarray(rng.randn(32, 27, 16), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(dot_interaction(feats)),
        np.asarray(ops.dot_interact(feats, tile_b=32, interpret=True)),
        rtol=1e-5, atol=1e-5)
    from repro.models.embedding import embedding_bag as model_bag
    table = jnp.asarray(rng.randn(128, 16), jnp.float32)
    ids = jnp.asarray(rng.randint(0, 128, (8, 4)), jnp.int32)
    np.testing.assert_allclose(
        np.asarray(model_bag(table, ids)),
        np.asarray(ops.embedding_bag(table, ids, interpret=True)),
        rtol=1e-5, atol=1e-5)
