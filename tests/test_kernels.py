"""Per-kernel shape/dtype sweeps, assert_allclose against ref.py oracles
(assignment requirement: every Pallas kernel validated in interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("v,d,b,bag", [
    (64, 8, 4, 1), (512, 32, 16, 4), (1024, 128, 32, 8), (128, 10, 8, 3),
])
def test_embedding_bag_sweep(v, d, b, bag, dtype):
    rng = np.random.RandomState(v + d)
    table = jnp.asarray(rng.randn(v, d), dtype)
    ids = jnp.asarray(rng.randint(0, v, (b, bag)), jnp.int32)
    for combiner in ("sum", "mean"):
        out = ops.embedding_bag(table, ids, combiner=combiner,
                                interpret=True)
        exp = ref.embedding_bag_ref(table, ids, combiner=combiner)
        tol = 1e-6 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(exp, np.float32),
            rtol=tol, atol=tol)


@settings(max_examples=20, deadline=None)
@given(v=st.integers(8, 300), d=st.sampled_from([4, 16, 33]),
       b=st.integers(1, 24), bag=st.integers(1, 6))
def test_embedding_bag_property(v, d, b, bag):
    rng = np.random.RandomState(v * 31 + d)
    table = jnp.asarray(rng.randn(v, d), jnp.float32)
    ids = jnp.asarray(rng.randint(0, v, (b, bag)), jnp.int32)
    out = ops.embedding_bag(table, ids, interpret=True)
    exp = ref.embedding_bag_ref(table, ids)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,f,d,tile", [
    (64, 27, 16, 32), (128, 27, 128, 128), (32, 8, 8, 8), (48, 13, 32, 16),
])
def test_dot_interact_sweep(b, f, d, tile, dtype):
    rng = np.random.RandomState(b + f)
    feats = jnp.asarray(rng.randn(b, f, d), dtype)
    out = ops.dot_interact(feats, tile_b=tile, interpret=True)
    exp = ref.dot_interact_ref(feats)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32),
        rtol=tol, atol=tol)
    assert out.shape == (b, f * (f - 1) // 2)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,f,d,h,tile", [
    (64, 15, 64, 128, 32), (128, 10, 602, 128, 64), (32, 25, 32, 16, 32),
])
def test_sage_aggregate_sweep(b, f, d, h, tile, dtype):
    rng = np.random.RandomState(b)
    neigh = jnp.asarray(rng.randn(b, f, d), dtype)
    w = jnp.asarray(rng.randn(d, h) * d ** -0.5, dtype)
    out = ops.sage_aggregate(neigh, w, tile_b=tile, interpret=True)
    exp = ref.sage_aggregate_ref(neigh, w)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32),
        rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("v,d,b,bag", [
    (64, 8, 4, 1), (512, 32, 16, 4), (1024, 128, 32, 8), (128, 10, 8, 3),
])
def test_embedding_bag_fused_parity(v, d, b, bag, dtype):
    """The fused perf variant is BIT-IDENTICAL to the baseline (same
    j-ascending f32 accumulation), and allclose to the ref oracle."""
    rng = np.random.RandomState(v + d)
    table = jnp.asarray(rng.randn(v, d), dtype)
    ids = jnp.asarray(rng.randint(0, v, (b, bag)), jnp.int32)
    for combiner in ("sum", "mean"):
        base = ops.embedding_bag(table, ids, combiner=combiner,
                                 interpret=True)
        fused = ops.embedding_bag_fused(table, ids, combiner=combiner,
                                        interpret=True)
        assert bool(jnp.all(base == fused)), (v, d, b, bag, combiner)
        exp = ref.embedding_bag_ref(table, ids, combiner=combiner)
        tol = 1e-6 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(
            np.asarray(fused, np.float32), np.asarray(exp, np.float32),
            rtol=tol, atol=tol)


def test_embedding_bag_fused_fallbacks():
    """Over the VMEM table budget or the bag unroll bound, the fused
    entry point must fall back to the row-DMA baseline (same numbers)."""
    from repro.kernels import embedding_bag as eb
    rng = np.random.RandomState(0)
    # bag over the unroll bound (small table)
    table = jnp.asarray(rng.randn(64, 8), jnp.float32)
    big_bag = jnp.asarray(rng.randint(0, 64, (4, eb._FUSED_MAX_BAG + 1)),
                          jnp.int32)
    out = ops.embedding_bag_fused(table, big_bag, interpret=True)
    assert bool(jnp.all(out == ops.embedding_bag(table, big_bag,
                                                 interpret=True)))
    # table over the VMEM budget (small bag)
    v = eb._FUSED_MAX_TABLE_BYTES // (2 * 4) + 8
    big_table = jnp.asarray(rng.randn(v, 2), jnp.float32)
    ids = jnp.asarray(rng.randint(0, v, (4, 2)), jnp.int32)
    out = ops.embedding_bag_fused(big_table, ids, interpret=True)
    assert bool(jnp.all(out == ops.embedding_bag(big_table, ids,
                                                 interpret=True)))


def _pallas_capable() -> bool:
    """Can this host execute a Pallas kernel at all (interpret counts)?"""
    try:
        table = jnp.zeros((4, 4), jnp.float32)
        ids = jnp.zeros((1, 1), jnp.int32)
        ops.embedding_bag(table, ids, interpret=True).block_until_ready()
        return True
    except Exception:       # pragma: no cover - exotic hosts only
        return False


def test_embedding_bag_fused_speedup():
    """The measured win: the fused variant's whole-bag grid steps must
    beat the per-row baseline. The gap is structural (bag x fewer grid
    steps, resident table vs one row DMA per step), so the bar is
    conservative."""
    if not _pallas_capable():   # pragma: no cover - exotic hosts only
        pytest.skip("no Pallas-capable backend on this host")
    import time
    rng = np.random.RandomState(0)
    table = jnp.asarray(rng.randn(1024, 128), jnp.float32)
    ids = jnp.asarray(rng.randint(0, 1024, (64, 4)), jnp.int32)

    def wall(fn, iters=3):
        fn().block_until_ready()
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn()
        out.block_until_ready()
        return (time.perf_counter() - t0) / iters

    tb = wall(lambda: ops.embedding_bag(table, ids, interpret=True))
    tf = wall(lambda: ops.embedding_bag_fused(table, ids, interpret=True))
    # measured ~250-1000x in interpret mode; 3x leaves room for host noise
    assert tb / tf > 3.0, f"fused not faster: base {tb:.4f}s fused {tf:.4f}s"


def test_kernels_match_model_code():
    """The kernels' oracles ARE the model-code ops they accelerate."""
    from repro.models.dlrm import dot_interaction
    rng = np.random.RandomState(0)
    feats = jnp.asarray(rng.randn(32, 27, 16), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(dot_interaction(feats)),
        np.asarray(ops.dot_interact(feats, tile_b=32, interpret=True)),
        rtol=1e-5, atol=1e-5)
    from repro.models.embedding import embedding_bag as model_bag
    table = jnp.asarray(rng.randn(128, 16), jnp.float32)
    ids = jnp.asarray(rng.randint(0, 128, (8, 4)), jnp.int32)
    np.testing.assert_allclose(
        np.asarray(model_bag(table, ids)),
        np.asarray(ops.embedding_bag(table, ids, interpret=True)),
        rtol=1e-5, atol=1e-5)
