"""LiveFleet: the fleet plane on real ThreadedPipeline executors.

Three layers of evidence that the simulated fleet plane transfers to
live execution (the paper's §5 sim-to-real claim, made a standing test):

  - dialect tests: LiveFleet speaks FleetSim's driver contract exactly —
    grant validation, churn-driven rig lifecycle, budget-enforced
    OOM/restart semantics;
  - the fleet differential (tier-1): on a 2-trainer cluster, LiveFleet's
    MEASURED per-trainer throughput ranks candidate FleetAllocations the
    same way FleetSim predicts. Rank-based with >= 1.8x designed
    separation, no absolute-rate assertions, so CI CPU contention cannot
    reorder it (the fleet extension of tests/test_sim_vs_executor.py);
  - (slow) a churn soak — FleetCoordinator over a join/leave/resize/pool
    schedule for a few hundred ticks with zero drops, zero OOMs, and
    every thread joined — and the fig7 --live acceptance run.
"""
import threading
import time

import numpy as np
import pytest

from repro.core.fleet_coordinator import FleetCoordinator
from repro.data.fleet import (ClusterSpec, FleetAllocation, FleetEvent,
                              FleetSim, TrainerSpec, churn_schedule)
from repro.data.live_fleet import (LiveFleet, live_demo_cluster,
                                   live_join_pipeline, live_linear_pipeline,
                                   synthetic_stage_fns)
from repro.data.simulator import (Allocation, MachineSpec, OOM_RESTART_TICKS,
                                  graph_memory_mb)


def diff_cluster() -> ClusterSpec:
    """2-trainer differential cluster: the UDF-skewed chain + the join
    DAG, no model cap and roomy memory so throughput alone is measured."""
    return ClusterSpec("live_diff2", (
        TrainerSpec("lin", live_linear_pipeline(),
                    MachineSpec(n_cpus=10, mem_mb=8192.0)),
        TrainerSpec("dag", live_join_pipeline(),
                    MachineSpec(n_cpus=16, mem_mb=8192.0)),
    ), shared_pool=0)


def falloc(lin_workers, dag_workers, prefetch_mb: float = 16.0):
    return FleetAllocation({
        "lin": Allocation(np.asarray(lin_workers, dtype=int), prefetch_mb),
        "dag": Allocation(np.asarray(dag_workers, dtype=int), prefetch_mb)})


def _wait_threads_settle(base, timeout=3.0):
    """Poll until every thread not in `base` has exited (teardown joins
    are bounded, but give the OS scheduler a moment)."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        extra = [t for t in threading.enumerate() if t not in base]
        if not extra:
            return []
        time.sleep(0.02)
    return [t for t in threading.enumerate() if t not in base]


# --------------------------------------------------------------- dialect ---
def test_live_fleet_enforces_the_grant_and_alloc_contracts():
    cluster = ClusterSpec("contract", (
        TrainerSpec("a", live_linear_pipeline(),
                    MachineSpec(n_cpus=8, mem_mb=8192.0)),
        TrainerSpec("b", live_linear_pipeline(),
                    MachineSpec(n_cpus=8, mem_mb=8192.0)),
    ), shared_pool=4)
    ones = {n: Allocation(np.ones(5, dtype=int), 16.0) for n in ("a", "b")}
    with LiveFleet(cluster, window_s=0.02) as lf:
        with pytest.raises(ValueError, match="exceed shared pool"):
            lf.apply(FleetAllocation(dict(ones), {"a": 3, "b": 2}))
        with pytest.raises(ValueError, match="unknown trainers"):
            lf.apply(FleetAllocation(dict(ones), {"nope": 1}))
        with pytest.raises(KeyError, match="active trainer"):
            lf.apply(FleetAllocation({"a": ones["a"]}))
        m = lf.apply(FleetAllocation(dict(ones), {"a": 2, "b": 2}))
        assert m["n_active"] == 2
        assert m["per_trainer"]["a"]["eff_cpus"] == 8 + 2
        # aggregates are the sum of the per-trainer breakdown
        assert m["throughput"] == pytest.approx(
            sum(p["throughput"] for p in m["per_trainer"].values()))


def test_live_fleet_churn_drives_rig_lifecycle_cleanly():
    """join spins a pipeline up, leave tears one down with zero dropped
    batches, resize re-caps before the next window."""
    cluster = ClusterSpec("churny", (
        TrainerSpec("a", live_linear_pipeline(),
                    MachineSpec(n_cpus=8, mem_mb=8192.0)),
        TrainerSpec("b", live_linear_pipeline(),
                    MachineSpec(n_cpus=8, mem_mb=8192.0),
                    start_active=False),
    ), shared_pool=0, events=(
        FleetEvent(2, "join", "b"),
        FleetEvent(4, "resize", "a", n_cpus=4),
        FleetEvent(6, "leave", "b"),
    ))
    base = set(threading.enumerate())
    lf = LiveFleet(cluster, window_s=0.03)
    assert set(lf.rigs) == {"a"}
    seen = []
    for _ in range(8):
        st = lf.machine
        seen.append((st.tick, st.active, dict(st.base_cpus)))
        fa = FleetAllocation(
            {n: Allocation(np.ones(5, dtype=int), 16.0) for n in st.active})
        m = lf.apply(fa)
        if st.tick == 2:
            assert set(lf.rigs) == {"a", "b"}          # join spun b up
        if st.tick == 4:
            assert m["per_trainer"]["a"]["eff_cpus"] == 4
        if st.tick == 6:
            assert set(lf.rigs) == {"a"}               # leave tore b down
    assert seen[2] == (2, ("a", "b"), {"a": 8, "b": 8})
    assert seen[4][2] == {"a": 4, "b": 8}
    assert seen[6] == (6, ("a",), {"a": 4})
    acct = lf.close()
    assert acct["dropped_batches"] == 0
    assert acct["oom_count"] == 0
    assert acct["all_joined"]
    assert _wait_threads_settle(base) == []


def test_live_fleet_oom_semantics_match_the_sim():
    """An over-budget allocation is an OOM judged by the sim's own
    graph_memory_mb, pays OOM_RESTART_TICKS of dead window, then a fresh
    pipeline relaunches — the coordinator's quarantine contract."""
    pipe = live_linear_pipeline()
    cluster = ClusterSpec("oomy", (
        TrainerSpec("a", pipe, MachineSpec(n_cpus=8, mem_mb=2500.0)),
    ), shared_pool=0)
    fat = FleetAllocation(
        {"a": Allocation(np.full(5, 2, dtype=int), 1024.0)})
    assert graph_memory_mb(pipe, fat.allocs["a"].workers, 1024.0) > 2500.0
    ok = FleetAllocation({"a": Allocation(np.ones(5, dtype=int), 16.0)})
    with LiveFleet(cluster, window_s=0.01) as lf:
        m = lf.apply(fat)
        assert m["oom"] and m["restarting"]
        assert lf.oom_count == 1
        assert "a" not in lf.rigs                   # process was killed
        for i in range(OOM_RESTART_TICKS):
            m = lf.apply(ok)                        # safe alloc proposed
            assert m["restarting"] and not m["oom"]
            assert m["throughput"] == 0.0
        assert "a" in lf.rigs                       # relaunched
        m = lf.apply(ok)
        assert not m["restarting"]


def test_synthetic_stage_fns_shapes():
    spec = live_join_pipeline()
    fns = synthetic_stage_fns(spec)
    assert set(fns) == {s.name for s in spec.stages}
    assert fns["dense_src"]() == 1                  # source: no args
    assert fns["join"]("x", "y") == ("x", "y")      # join: one per input
    assert fns["feature_udf"]("z") == "z"           # unary: forwards


# ---------------------------------------------------------- differential ---
def test_fleet_differential_live_ranks_match_sim():
    """THE fleet differential: LiveFleet's measured per-trainer
    throughput must rank candidate FleetAllocations the way FleetSim
    predicts. Candidates are designed with >= 1.8x predicted separation
    per trainer so thread-timing noise cannot reorder them."""
    cluster = diff_cluster()
    candidates = [
        falloc([1, 1, 1, 1, 1], [1, 1, 1, 1, 1]),   # everything starved
        falloc([1, 1, 3, 1, 1], [1, 2, 1, 2, 1]),   # udf partly fed
        falloc([1, 1, 6, 1, 1], [2, 4, 1, 4, 2]),   # oracle-shaped
    ]
    predicted = {"lin": [], "dag": []}
    for fa in candidates:
        per = FleetSim(cluster, seed=0).apply(fa)["per_trainer"]
        for n in predicted:
            predicted[n].append(per[n]["throughput"])
    for n, preds in predicted.items():
        gaps = sorted(preds)
        for lo, hi in zip(gaps, gaps[1:]):
            assert hi / lo >= 1.8, f"test design: {n} separation too small"

    measured = {"lin": [], "dag": []}
    with LiveFleet(cluster, window_s=0.25) as lf:
        for fa in candidates:
            for _ in range(2):                      # settle the new alloc
                lf.apply(fa)
            per = lf.apply(fa)["per_trainer"]
            for n in measured:
                measured[n].append(per[n]["throughput"])
    for n in predicted:
        assert np.argsort(predicted[n]).tolist() \
            == np.argsort(measured[n]).tolist(), \
            (f"{n}: sim ranks {predicted[n]} but live measures "
             f"{measured[n]}")


# ------------------------------------------------------------ slow suite ---
@pytest.mark.slow
def test_churn_soak_no_drops_no_ooms_no_leaks():
    """Drive the FleetCoordinator over a dense join/leave/resize/pool
    schedule on LiveFleet for a few hundred ticks: zero dropped batches,
    zero OOMs, and every executor thread joined on teardown."""
    ticks = 300
    mk = live_linear_pipeline
    cluster = ClusterSpec("soak3", (
        TrainerSpec("a", mk(), MachineSpec(n_cpus=8, mem_mb=4096.0)),
        TrainerSpec("b", live_join_pipeline(),
                    MachineSpec(n_cpus=8, mem_mb=4096.0),
                    model_latency=0.01),
        TrainerSpec("c", mk(udf_cost=0.004),
                    MachineSpec(n_cpus=6, mem_mb=3000.0),
                    model_latency=0.02),
    ), shared_pool=6, events=churn_schedule(ticks, [
        (0.10, "leave", "b", 0),
        (0.20, "join", "b", 0),
        (0.30, "resize", "a", 5),
        (0.40, "pool", "", 2),
        (0.50, "resize", "a", 8),
        (0.60, "leave", "c", 0),
        (0.70, "pool", "", 6),
        (0.80, "join", "c", 0),
        (0.90, "resize", "c", 4),
    ]))
    base = set(threading.enumerate())
    lf = LiveFleet(cluster, window_s=0.02)
    coord = FleetCoordinator(cluster, seed=0, finetune_ticks=60)
    for _ in range(ticks):
        st = lf.machine
        fa = coord.propose(cluster, st)
        coord.observe(lf.apply(fa))
    acct = lf.close()
    assert acct["oom_count"] == 0, acct
    assert acct["dropped_batches"] == 0, acct
    assert acct["crash_lost"] == 0, acct
    assert acct["all_joined"], acct
    leaked = _wait_threads_settle(base)
    assert leaked == [], f"leaked threads: {leaked}"


@pytest.mark.slow
def test_fig7_fleet_live_acceptance():
    """ISSUE 3 acceptance: fig7_fleet --live completes on the 3-trainer
    live cluster with churn; the coordinator beats fleet_even on
    MEASURED aggregate throughput with zero OOMs, zero dropped batches,
    and every thread joined."""
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    from benchmarks import fig7_fleet
    summary = fig7_fleet.run_live(ticks=160, seed=0, quiet=True)
    coord = summary["fleet_intune"]
    assert summary["_speedups"]["intune_vs_even"] > 1.0, summary
    assert coord["oom_count"] == 0, summary
    assert coord["dropped_batches"] == 0, summary
    assert coord["all_joined"] and summary["fleet_even"]["all_joined"]
