"""Checkpoint: roundtrip fidelity, atomicity, large-array sharding,
latest-step resolution, InTune-state extras."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt


def _tree():
    rng = np.random.RandomState(0)
    return {
        "params": {"w": rng.randn(16, 8).astype(np.float32),
                   "layers": ({"a": rng.randn(3)}, {"a": rng.randn(3)})},
        "opt": {"m": rng.randn(16, 8).astype(np.float32)},
        "step": np.asarray(7),
    }


def _assert_tree_equal(a, b):
    import jax
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_roundtrip(tmp_path):
    tree = _tree()
    ckpt.save(str(tmp_path), 7, tree, extras={"note": "hi"})
    restored, manifest = ckpt.restore(str(tmp_path))
    _assert_tree_equal(tree, restored)
    assert manifest["step"] == 7
    assert manifest["extras"]["note"] == "hi"


def test_latest_step_skips_incomplete(tmp_path):
    ckpt.save(str(tmp_path), 1, _tree())
    ckpt.save(str(tmp_path), 5, _tree())
    # a crashed write: tmp dir without manifest
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert ckpt.latest_step(str(tmp_path)) == 5
    restored, m = ckpt.restore(str(tmp_path))
    assert m["step"] == 5


def test_large_array_sharded(tmp_path):
    big = {"emb": np.arange(4000, dtype=np.float32).reshape(200, 20)}
    ckpt.save(str(tmp_path), 0, big, max_shard_bytes=4096)
    d = tmp_path / "step_00000000"
    shards = [f for f in os.listdir(d) if f.startswith("shard_")]
    assert len(shards) > 1                      # actually split
    restored, _ = ckpt.restore(str(tmp_path), 0)
    np.testing.assert_array_equal(restored["emb"], big["emb"])


def test_intune_state_rides_in_extras(tmp_path):
    from repro.core.controller import InTune
    from repro.data.pipeline import criteo_pipeline
    from repro.data.simulator import MachineSpec
    tuner = InTune(criteo_pipeline(), MachineSpec(), seed=0,
                   finetune_ticks=10)
    tuner.run(12)
    state = tuner.state_dict()
    ckpt.save(str(tmp_path), 3, {"agent_qnet": state["agent"]["qnet"]},
              extras={"workers": state["workers"],
                      "prefetch_mb": state["prefetch_mb"],
                      "agent_steps": state["agent"]["steps"]})
    restored, manifest = ckpt.restore(str(tmp_path))
    tuner2 = InTune(criteo_pipeline(), MachineSpec(), seed=1,
                    finetune_ticks=10)
    tuner2.load_state_dict({
        "agent": {"qnet": restored["agent_qnet"],
                  "steps": manifest["extras"]["agent_steps"]},
        "workers": manifest["extras"]["workers"],
        "prefetch_mb": manifest["extras"]["prefetch_mb"]})
    assert tuner2.allocation.workers.tolist() == state["workers"]
