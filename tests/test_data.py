"""Data substrate: executor threads, synthetic streams, GNN sampler,
device feed, embedding helpers."""
import queue
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.data.executor import ThreadedPipeline
from repro.data.pipeline import criteo_pipeline
from repro.data.sampler import CSRGraph, NeighborSampler
from repro.data.synthetic import CriteoStream, TokenStream, bert4rec_batch
from repro.data.device_feed import device_prefetch, shard_slice
from repro.models import embedding as emb


def test_threaded_pipeline_end_to_end():
    spec = criteo_pipeline()
    stream = CriteoStream(n_sparse=4, n_dense=3, vocab=1024, seed=0)
    count = {"n": 0}

    def source():
        if count["n"] >= 12:
            return None
        count["n"] += 1
        return stream.raw_block(8)

    rng = np.random.RandomState(0)
    pipe = ThreadedPipeline(
        spec, source,
        [lambda b: CriteoStream.shuffle_udf(b, rng),
         stream.feature_udf,
         CriteoStream.batch_udf,
         lambda b: b],          # prefetch = pass-through into final queue
        queue_depth=4, item_mb=1.0)
    got = []
    try:
        for _ in range(12):
            got.append(pipe.get_batch(timeout=20))
    finally:
        pipe.stop()
    assert len(got) == 12
    for b in got:
        assert b["sparse_ids"].shape == (8, 4, 1)
        assert b["sparse_ids"].max() < 1024
        assert np.isfinite(b["dense"]).all()
    stats = pipe.stats()
    assert len(stats["workers"]) == spec.n_stages


def test_executor_resize():
    spec = criteo_pipeline()
    pipe = ThreadedPipeline(spec, lambda: None,
                            [lambda b: b] * 4, item_mb=1.0)
    pipe.set_allocation([3, 2, 4, 1, 2], prefetch_mb=512)
    time.sleep(0.05)
    assert pipe.worker_counts() == [3, 2, 4, 1, 2]
    pipe.set_allocation([1, 1, 1, 1, 1], prefetch_mb=128)
    time.sleep(0.2)
    assert pipe.worker_counts() == [1, 1, 1, 1, 1]
    pipe.stop()


@settings(max_examples=15, deadline=None)
@given(n_nodes=st.integers(5, 200), n_edges=st.integers(10, 800),
       fanout=st.integers(1, 8))
def test_sampler_neighbors_are_adjacent(n_nodes, n_edges, fanout):
    rng = np.random.RandomState(n_nodes)
    src = rng.randint(0, n_nodes, n_edges)
    dst = rng.randint(0, n_nodes, n_edges)
    g = CSRGraph(n_nodes, src, dst)
    adj = {}
    for s, d in zip(src, dst):
        adj.setdefault(d, set()).add(s)
    nodes = rng.randint(0, n_nodes, 20)
    out = g.sample_neighbors(nodes, fanout, rng)
    assert out.shape == (20, fanout)
    for node, nbrs in zip(nodes, out):
        allowed = adj.get(node, {node}) | {node}
        assert set(nbrs.tolist()) <= allowed


def test_neighbor_sampler_blocks():
    g = CSRGraph.random(100, 500, seed=1)
    x = np.random.RandomState(0).randn(100, 7).astype(np.float32)
    y = np.random.RandomState(1).randint(0, 3, 100)
    s = NeighborSampler(g, x, y, fanout=(4, 3))
    b = s.sample(16)
    assert b["x0"].shape == (16, 7)
    assert b["neigh1"].shape == (16, 4, 7)
    assert b["neigh2"].shape == (16, 4, 3, 7)
    assert b["labels"].shape == (16,)


def test_criteo_stream_udfs():
    stream = CriteoStream(n_sparse=5, n_dense=4, vocab=512, multi_hot=2)
    block = stream.raw_block(32)
    out = stream.feature_udf(block)
    assert out["sparse_ids"].shape == (32, 5, 2)
    assert out["sparse_ids"].min() >= 0 and out["sparse_ids"].max() < 512
    assert abs(out["dense"].mean()) < 0.2     # normalized


def test_device_prefetch_order():
    batches = [{"x": np.full((2,), i)} for i in range(7)]
    out = list(device_prefetch(iter(batches), depth=3))
    assert len(out) == 7
    for i, b in enumerate(out):
        assert int(b["x"][0]) == i


def test_shard_slice():
    batch = {"x": np.arange(12).reshape(12, 1)}
    s1 = shard_slice(batch, 1, 4)
    np.testing.assert_array_equal(s1["x"][:, 0], [3, 4, 5])


@settings(max_examples=25, deadline=None)
@given(rows=st.integers(2, 1 << 20), n=st.integers(1, 64))
def test_hash_ids_in_range(rows, n):
    rng = np.random.RandomState(n)
    raw = jnp.asarray(rng.randint(0, 1 << 31, n), jnp.int32)
    h = emb.hash_ids(raw, rows)
    assert int(h.min()) >= 0 and int(h.max()) < rows


def test_ragged_embedding_bag():
    rng = np.random.RandomState(0)
    table = jnp.asarray(rng.randn(64, 8), jnp.float32)
    ids = jnp.asarray([1, 2, 3, 10, 11, 40], jnp.int32)
    seg = jnp.asarray([0, 0, 0, 1, 1, 2], jnp.int32)
    out = emb.ragged_embedding_bag(table, ids, seg, 4)
    exp0 = np.asarray(table)[[1, 2, 3]].sum(0)
    np.testing.assert_allclose(np.asarray(out[0]), exp0, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out[3]), np.zeros(8), atol=0)
    mean = emb.ragged_embedding_bag(table, ids, seg, 4, combiner="mean")
    np.testing.assert_allclose(np.asarray(mean[0]), exp0 / 3, rtol=1e-6)


def test_tp_embedding_matches_take_on_host_mesh():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rng = np.random.RandomState(0)
    table = jnp.asarray(rng.randn(32, 4), jnp.float32)
    ids = jnp.asarray(rng.randint(0, 32, (6, 3)), jnp.int32)
    out = emb.tp_embedding_lookup(table, ids, mesh)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.take(table, ids, axis=0)),
                               rtol=1e-6)
