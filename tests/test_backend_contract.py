"""Backend-protocol conformance: one parametrized suite, five backends.

The `repro.api.Backend` contract is what makes the Session driver (and
everything above it) substrate-agnostic, so the contract itself is
tested, not assumed: every backend — analytic sim, threaded executor,
process executor, fleet sim, live fleet — must present the same
`apply -> Telemetry` surface, accept ResizeEvents, tear down
idempotently, and (fleet backends) accept injected ChurnEvents. Seeded
(analytic) backends must additionally replay byte-identically from the
same seed.

The live backends run REAL threads (and, for "proc", real worker
processes) here: pipelines are tiny (ms-scale stage costs, ~0.04s
measurement windows) so the whole suite stays tier-1 fast.
"""
import numpy as np
import pytest

from repro.api import (AllocationError, Backend, ChurnEvent, ResizeEvent,
                       Session, Telemetry, UnsupportedEventError,
                       make_backend)
from repro.data.fleet import ClusterSpec, TrainerSpec
from repro.data.live_fleet import live_linear_pipeline
from repro.data.simulator import Allocation, MachineSpec

BACKENDS = ["sim", "executor", "proc", "fleet_sim", "fleet_live",
            "fleet_proc", "fleet_market"]
FLEET = {"fleet_sim", "fleet_live", "fleet_proc", "fleet_market"}
SEEDED = {"sim", "fleet_sim", "fleet_market"}  # analytic: same seed, same bytes
LIVE = {"executor", "proc", "fleet_live", "fleet_proc"}  # threads / processes
LIVE_KW = {"window_s": 0.04}
# model_latency throttles the single-machine rigs' background
# consumption: conformance asserts contracts, not rates, and an
# unthrottled proc rig would burn real cores for the whole fixture
# lifetime (burstable CI hosts deplete their CPU budget)
SINGLE_KW = {**LIVE_KW, "model_latency": 0.1}


def _spec():
    return live_linear_pipeline()         # 5 stages, ms-scale costs


def _machine():
    return MachineSpec(n_cpus=8, mem_mb=4096.0)


def _cluster():
    spec = _spec()
    trainers = (
        TrainerSpec("a", spec, MachineSpec(n_cpus=4, mem_mb=4096.0),
                    model_latency=0.01),
        TrainerSpec("b", spec, MachineSpec(n_cpus=4, mem_mb=4096.0),
                    model_latency=0.01),
    )
    return ClusterSpec("contract_fleet", trainers, shared_pool=4)


def _market():
    """The _cluster shape as a MarketSpec: every fleet backend must run
    a jobs-partitioned spec unchanged (jobs only matter to the optimizer
    layer) — MarketSpec conformance IS ClusterSpec conformance."""
    from repro.data.fleet import JobSpec, MarketSpec
    base = _cluster()
    return MarketSpec("contract_market", base.trainers, shared_pool=4,
                      jobs=(JobSpec("j0", ("a",), weight=2.0, floor=1),
                            JobSpec("j1", ("b",))))


def _make(name: str, seed: int = 0) -> Backend:
    if name == "sim":
        return make_backend("sim", _spec(), _machine(), seed=seed)
    if name == "executor":
        return make_backend("executor", _spec(), _machine(), seed=seed,
                            **SINGLE_KW)
    if name == "proc":
        # ballast off: conformance exercises the contract, not the
        # memory physics (tests/test_proc_executor.py covers those)
        return make_backend("proc", _spec(), _machine(), seed=seed,
                            ballast=False, **SINGLE_KW)
    if name == "fleet_sim":
        return make_backend("sim", _cluster(), seed=seed)
    if name == "fleet_market":
        return make_backend("sim", _market(), seed=seed)
    if name == "fleet_proc":
        return make_backend("proc", _cluster(), seed=seed, ballast=False,
                            **LIVE_KW)
    return make_backend("live", _cluster(), seed=seed, **LIVE_KW)


def _alloc(name: str, backend: Backend):
    """A valid allocation for the backend's current state."""
    if name in FLEET:
        from repro.data.fleet import FleetAllocation
        state = backend.machine
        return FleetAllocation(
            {n: Allocation(np.ones(5, dtype=int), prefetch_mb=16.0)
             for n in state.active},
            {n: 0 for n in state.active})
    return Allocation(np.ones(5, dtype=int), prefetch_mb=16.0)


def _bad_alloc(name: str, backend: Backend):
    if name in FLEET:
        from repro.data.fleet import FleetAllocation
        state = backend.machine
        allocs = {n: Allocation(np.ones(5, dtype=int), prefetch_mb=16.0)
                  for n in state.active}
        first = state.active[0]
        allocs[first] = Allocation(np.array([1, -1, 1, 1, 1]))
        return FleetAllocation(allocs, {n: 0 for n in state.active})
    return Allocation(np.array([1, -1, 1, 1, 1]))


@pytest.fixture(params=BACKENDS)
def case(request):
    backend = _make(request.param)
    yield request.param, backend
    backend.shutdown()


# ----------------------------------------------------------- telemetry ----
def test_apply_returns_full_telemetry(case):
    name, backend = case
    tel = backend.apply(_alloc(name, backend))
    assert isinstance(tel, Telemetry)
    assert isinstance(tel.throughput, float) and tel.throughput >= 0.0
    assert isinstance(tel.mem_mb, float) and tel.mem_mb > 0.0
    # fleet aggregates clamp each trainer's 5 workers to its 4 owned CPUs
    assert isinstance(tel.used_cpus, int) and tel.used_cpus == (
        8 if name in FLEET else 5)
    assert tel.oom is False and tel.restarting is False
    # mapping compatibility is part of the contract (legacy observers)
    assert tel["throughput"] == tel.throughput
    assert "mem_mb" in tel and tel.get("nope", 42) == 42
    assert set(dict(tel)) >= {"throughput", "mem_mb", "used_cpus",
                              "oom", "restarting"}
    if name in FLEET:
        assert set(tel["per_trainer"]) == set(backend.machine.active)


def test_skip_tick_advances_clock_and_zeroes(case):
    name, backend = case
    t0 = backend.snapshot()["time"]
    tel = backend.skip_tick()
    assert tel.throughput == 0.0 and tel.restarting is True
    assert backend.snapshot()["time"] == t0 + 1


# ------------------------------------------------------------- resize -----
def test_inject_resize_changes_capacity(case):
    name, backend = case
    backend.apply(_alloc(name, backend))
    before = backend.capacity
    if name in FLEET:
        # fleet dialect: ResizeEvent re-caps the shared pool
        backend.inject(ResizeEvent(tick=1, n_cpus=1))
        assert backend.capacity == before - 3        # pool 4 -> 1
    else:
        backend.inject(ResizeEvent(tick=1, n_cpus=3))
        assert backend.capacity == 3
    # the backend still runs after the re-cap
    tel = backend.apply(_alloc(name, backend))
    assert isinstance(tel, Telemetry)


# -------------------------------------------------------------- churn -----
def test_churn_injection(case):
    name, backend = case
    if name not in FLEET:
        with pytest.raises(UnsupportedEventError):
            backend.inject(ChurnEvent(tick=0, kind="leave", trainer="a"))
        return
    assert set(backend.machine.active) == {"a", "b"}
    backend.inject(ChurnEvent(tick=0, kind="leave", trainer="b"))
    assert set(backend.machine.active) == {"a"}
    tel = backend.apply(_alloc(name, backend))
    assert set(tel["per_trainer"]) == {"a"}
    backend.inject(ChurnEvent(tick=1, kind="join", trainer="b"))
    assert set(backend.machine.active) == {"a", "b"}
    tel = backend.apply(_alloc(name, backend))
    assert set(tel["per_trainer"]) == {"a", "b"}
    # unknown trainer / kind are rejected at injection time
    with pytest.raises(ValueError):
        backend.inject(ChurnEvent(tick=2, kind="leave", trainer="nope"))
    with pytest.raises(ValueError):
        backend.inject(ChurnEvent(tick=2, kind="explode", trainer="a"))


# ----------------------------------------------------------- shutdown -----
def test_shutdown_idempotent(case):
    name, backend = case
    backend.apply(_alloc(name, backend))
    first = backend.shutdown()
    second = backend.shutdown()
    assert first is second          # cached accounting, not a re-teardown
    if name in LIVE:
        assert first["all_joined"] is True
        assert first["oom_count"] == 0
    # applying to a torn-down backend is a NAMED error on every substrate
    with pytest.raises(RuntimeError, match="shut down"):
        backend.apply(_alloc(name, backend))


# ----------------------------------------------------------- snapshot -----
def test_snapshot_has_clock_and_ooms(case):
    name, backend = case
    snap = backend.snapshot()
    assert snap["time"] == 0 and snap["oom_count"] == 0
    backend.apply(_alloc(name, backend))
    assert backend.snapshot()["time"] == 1


@pytest.mark.parametrize("name", sorted(SEEDED))
def test_snapshot_deterministic_for_seeded_backends(name):
    def trace(seed):
        backend = _make(name, seed=seed)
        tels = []
        for _ in range(5):
            tel = backend.apply(_alloc(name, backend))
            tels.append((tel.throughput, tel.mem_mb, tel.used_cpus))
        return tels, backend.snapshot()
    tels_a, snap_a = trace(3)
    tels_b, snap_b = trace(3)
    assert tels_a == tels_b and snap_a == snap_b


# --------------------------------------------------------- validation -----
def test_invalid_allocation_rejected_at_the_boundary(case):
    name, backend = case
    with pytest.raises(AllocationError):
        backend.apply(_bad_alloc(name, backend))


# ---------------------------------------------------- session smoke -------
def test_session_drives_every_backend(case):
    """The same Session loop runs all four backends end to end."""
    name, backend = case

    class Hold:
        name = "hold"

        def propose(self, spec, machine, stats=None):
            return _alloc(name, backend)

        def observe(self, metrics):
            self.last = metrics

    opt = Hold()
    res = Session(backend, opt).run(3)
    assert res.ticks == 3 and len(res.used_cpus) == 3
    assert isinstance(opt.last, Telemetry)
    assert res.oom_count == 0
