"""Fleet plane: ClusterSpec validation, FleetSim churn semantics, the
pool-grant contract, fleet baselines, the FleetCoordinator's admission
control and OOM quarantine, and (slow) the fig7_fleet acceptance run."""
import numpy as np
import pytest

from repro.core import baselines as B
from repro.core.env import FleetEnv, even_allocation
from repro.core.fleet_coordinator import FleetCoordinator, clamp_to_memory
from repro.core.optimizer import FleetStaticOptimizer, make_fleet_optimizer
from repro.data.fleet import (ClusterSpec, FleetAllocation, FleetEvent,
                              FleetSim, TrainerSpec, churn_schedule,
                              demo_cluster)
from repro.data.pipeline import criteo_pipeline, multisource_dlrm_pipeline
from repro.data.simulator import Allocation, MachineSpec, PipelineSim


def tiny_cluster(pool=8, events=()):
    return ClusterSpec("tiny", (
        TrainerSpec("a", criteo_pipeline(),
                    MachineSpec(n_cpus=16, mem_mb=16384.0)),
        TrainerSpec("b", multisource_dlrm_pipeline(),
                    MachineSpec(n_cpus=12, mem_mb=16384.0),
                    model_latency=0.5),    # saturates at 2 b/s: pool bait
    ), shared_pool=pool, events=tuple(events))


# ------------------------------------------------------------ validation ---
def test_cluster_spec_validation():
    t = TrainerSpec("a", criteo_pipeline(), MachineSpec())
    with pytest.raises(ValueError, match="duplicate"):
        ClusterSpec("bad", (t, t))
    with pytest.raises(ValueError, match="unknown trainer"):
        ClusterSpec("bad", (t,), events=(FleetEvent(5, "leave", "nope"),))
    with pytest.raises(ValueError, match="unknown event kind"):
        ClusterSpec("bad", (t,), events=(FleetEvent(5, "explode", "a"),))
    with pytest.raises(ValueError, match="shared_pool"):
        ClusterSpec("bad", (t,), shared_pool=-1)


def test_churn_schedule_places_events_at_fractions():
    evs = churn_schedule(1000, [(0.25, "join", "x", 0),
                                (0.5, "resize", "y", 32)])
    assert [(e.tick, e.kind) for e in evs] == [(250, "join"), (500, "resize")]


# ----------------------------------------------------------- fleet sim -----
def test_fleet_events_drive_active_set_and_caps():
    cluster = ClusterSpec("churny", (
        TrainerSpec("a", criteo_pipeline(),
                    MachineSpec(n_cpus=16, mem_mb=16384.0)),
        TrainerSpec("b", criteo_pipeline(),
                    MachineSpec(n_cpus=8, mem_mb=16384.0),
                    start_active=False),
    ), shared_pool=4, events=(
        FleetEvent(2, "join", "b"),
        FleetEvent(4, "resize", "a", n_cpus=10),
        FleetEvent(6, "leave", "a"),
        FleetEvent(8, "pool", n_cpus=2),
    ))
    sim = FleetSim(cluster, seed=0)
    seen = []
    for _ in range(10):
        st = sim.machine
        seen.append((st.tick, st.active, dict(st.base_cpus), st.pool))
        falloc = FleetAllocation(
            {n: Allocation(np.ones(5, dtype=int), prefetch_mb=64.0)
             for n in st.active})
        sim.apply(falloc)
    assert seen[0] == (0, ("a",), {"a": 16}, 4)
    assert seen[2] == (2, ("a", "b"), {"a": 16, "b": 8}, 4)
    assert seen[4] == (4, ("a", "b"), {"a": 10, "b": 8}, 4)
    assert seen[6] == (6, ("b",), {"b": 8}, 4)
    assert seen[8] == (8, ("b",), {"b": 8}, 2)
    # n_cpus view: owned + pool
    assert FleetSim(cluster, seed=0).machine.n_cpus == 16 + 4


def test_fleet_sim_grant_and_alloc_contracts():
    cluster = tiny_cluster(pool=8)
    sim = FleetSim(cluster, seed=0)
    ok = {n: Allocation(np.ones(
        cluster.trainer(n).pipeline.n_stages, dtype=int), 64.0)
        for n in ("a", "b")}
    with pytest.raises(ValueError, match="exceed shared pool"):
        sim.apply(FleetAllocation(dict(ok), {"a": 5, "b": 4}))
    with pytest.raises(KeyError, match="active trainer"):
        sim.apply(FleetAllocation({"a": ok["a"]}))
    m = sim.apply(FleetAllocation(dict(ok), {"a": 5, "b": 3}))
    assert m["n_active"] == 2
    # aggregates are the sum of the per-trainer breakdown
    per = m["per_trainer"]
    assert m["throughput"] == pytest.approx(
        sum(p["throughput"] for p in per.values()))
    assert m["mem_mb"] == pytest.approx(
        sum(p["mem_mb"] for p in per.values()))
    # grants raise the effective cap the per-trainer sim sees
    assert per["a"]["eff_cpus"] == 16 + 5


def test_fleet_allocation_flattens_grants_into_change_detection():
    a = Allocation(np.ones(5, dtype=int), 64.0)
    f1 = FleetAllocation({"x": a.copy()}, {"x": 3})
    f2 = FleetAllocation({"x": a.copy()}, {"x": 4})
    assert not np.array_equal(f1.workers, f2.workers)
    assert f1.prefetch_mb == f2.prefetch_mb == 64.0


# ------------------------------------------------------------ baselines ----
def test_fleet_baselines_respect_pool_and_shapes():
    cluster = tiny_cluster(pool=8)
    state = FleetSim(cluster, seed=0).machine
    for name, fn in B.FLEET_BASELINES.items():
        fa = fn(cluster, state, 0)
        assert set(fa.allocs) == {"a", "b"}, name
        assert sum(fa.grants.values()) <= state.pool, name
        for n, alloc in fa.allocs.items():
            spec = cluster.trainer(n).pipeline
            assert alloc.workers.shape == (spec.n_stages,), (name, n)


def test_fleet_oracle_beats_even_and_local():
    cluster = tiny_cluster(pool=8)
    state = FleetSim(cluster, seed=0).machine

    def tput(fa):
        return FleetSim(cluster, seed=0).apply(fa)["throughput"]

    t_oracle = tput(B.fleet_oracle(cluster, state))
    assert t_oracle >= tput(B.fleet_even(cluster, state)) - 1e-9
    assert t_oracle >= tput(B.fleet_local_oracle(cluster, state)) - 1e-9


def test_fleet_static_optimizer_reproposes_on_churn_only():
    cluster = tiny_cluster(pool=8, events=[FleetEvent(3, "resize", "a", 12)])
    sim = FleetSim(cluster, seed=0)
    opt = make_fleet_optimizer("fleet_even", cluster)
    assert isinstance(opt, FleetStaticOptimizer)
    first = opt.propose(cluster, sim.machine)
    sim.apply(first)
    assert opt.propose(cluster, sim.machine) is first      # cached
    sim.apply(first)
    sim.apply(first)                                       # tick 3: resize
    third = opt.propose(cluster, sim.machine)
    assert third is not first                              # churn re-propose


# ----------------------------------------------------------- coordinator ---
def test_clamp_to_memory_fits_headroom():
    spec = multisource_dlrm_pipeline()
    machine = MachineSpec(n_cpus=64, mem_mb=6144.0)
    sim = PipelineSim(spec, machine)
    fat = Allocation(np.full(spec.n_stages, 12, dtype=int),
                     prefetch_mb=4096.0)
    assert sim.memory_used(fat) > machine.mem_mb
    safe = clamp_to_memory(spec, fat, machine.mem_mb, headroom=0.9)
    assert sim.memory_used(safe) <= 0.9 * machine.mem_mb
    assert np.all(safe.workers >= 1)
    ok = Allocation(np.ones(spec.n_stages, dtype=int), 256.0)
    assert clamp_to_memory(spec, ok, machine.mem_mb) is ok  # untouched
    # a proposal already below the one-batch floor is never raised by it
    tight = Allocation(np.full(spec.n_stages, 12, dtype=int), 64.0)
    clamped = clamp_to_memory(spec, tight, 4096.0, headroom=0.9)
    assert clamped.prefetch_mb <= 64.0
    assert sim.memory_used(clamped) <= 0.9 * 4096.0


def test_coordinator_protocol_no_oom_on_tight_memory():
    # fresh (unpretrained) agents: the protocol/guard mechanics under test
    # must hold regardless of policy quality
    cluster = tiny_cluster(pool=8)
    coord = FleetCoordinator(cluster, seed=0, finetune_ticks=40)
    sim = FleetSim(cluster, seed=0)
    for _ in range(60):
        falloc = coord.propose(cluster, sim.machine)
        assert sum(falloc.grants.values()) <= sim.pool
        coord.observe(sim.apply(falloc))
    assert sim.oom_count == 0
    assert len(coord.tuners) == 2
    # grants favor the unsaturated machine: "b" saturates its 2 b/s model
    # with a handful of CPUs, so the arbitration parks the pool on "a"
    assert coord.grants["a"] > coord.grants.get("b", 0)


def test_coordinator_quarantines_after_oom():
    cluster = tiny_cluster(pool=0)
    coord = FleetCoordinator(cluster, seed=0,
                             mem_guard=False, quarantine_ticks=5,
                             finetune_ticks=40)
    sim = FleetSim(cluster, seed=0)
    falloc = coord.propose(cluster, sim.machine)
    metrics = sim.apply(falloc)
    # report a synthetic OOM on "a" — the mechanism under test
    metrics["per_trainer"]["a"]["oom"] = True
    coord.observe(metrics)
    assert coord.quarantine["a"] == 5
    trainer = cluster.trainer("a")
    psim = PipelineSim(trainer.pipeline, trainer.machine)
    for _ in range(5):
        falloc = coord.propose(cluster, sim.machine)
        # quarantined machine serves the safe clamped-oracle allocation
        assert psim.memory_used(falloc.allocs["a"]) \
            <= 0.95 * trainer.machine.mem_mb
        coord.observe(sim.apply(falloc))
    assert coord.quarantine["a"] == 0


def test_coordinator_readmits_and_rearbitrates_after_quarantine():
    """The quarantine EXIT path: once the clamp window expires the
    trainer is re-admitted — its agent proposes again (exploration
    unfrozen) — and the pool grants are re-arbitrated for its return
    (the entry path alone was covered before)."""
    cluster = tiny_cluster(pool=8)
    coord = FleetCoordinator(cluster, seed=0, mem_guard=False,
                             quarantine_ticks=3, finetune_ticks=40)
    sim = FleetSim(cluster, seed=0)
    falloc = coord.propose(cluster, sim.machine)
    metrics = sim.apply(falloc)
    metrics["per_trainer"]["a"]["oom"] = True      # synthetic OOM on "a"
    coord.observe(metrics)
    assert coord.quarantine["a"] == 3
    plans = []
    orig = coord._plan_grants
    coord._plan_grants = lambda state: (plans.append(coord._tick)
                                        or orig(state))
    for _ in range(3):
        falloc = coord.propose(cluster, sim.machine)
        # frozen while quarantined: no pending transition to learn from
        assert coord.tuners["a"]._pending is None
        coord.observe(sim.apply(falloc))
    assert coord.quarantine["a"] == 0
    n_plans = len(plans)
    falloc = coord.propose(cluster, sim.machine)   # re-admission tick
    assert len(plans) == n_plans + 1, \
        "re-admission must re-arbitrate the pool grants"
    assert coord.tuners["a"]._pending is not None, \
        "re-admitted trainer must be exploring again"
    coord.observe(sim.apply(falloc))
    assert sum(falloc.grants.values()) <= sim.pool


def test_fleet_env_wrapper():
    cluster = tiny_cluster(pool=8)
    env = FleetEnv(cluster, seed=0)
    obs = env.observe()
    assert set(obs) == {"a", "b"}
    for n, o in obs.items():
        spec = cluster.trainer(n).pipeline
        assert o.shape == (2 * spec.n_stages + 6,)
    obs, reward, metrics = env.step(env.falloc)
    assert np.isfinite(reward) and reward > 0
    assert metrics["throughput"] > 0


# ------------------------------------------------- even_allocation fix -----
def test_even_allocation_distributes_remainder():
    spec = criteo_pipeline()
    alloc = even_allocation(spec, 128)
    assert alloc.workers.tolist() == [26, 26, 26, 25, 25]
    assert alloc.workers.sum() == 128


def test_even_allocation_caps_at_n_cpus():
    spec = criteo_pipeline()
    alloc = even_allocation(spec, 3)       # fewer CPUs than stages
    assert alloc.workers.tolist() == [1, 1, 1, 0, 0]
    assert alloc.workers.sum() == 3        # no oversubscription


# ------------------------------------------------------- slow acceptance ---
@pytest.mark.slow
def test_fig7_fleet_acceptance():
    """ISSUE 2 acceptance: on the 4-machine heterogeneous fleet with
    churn, the coordinator reaches >= 90% of the fleet oracle and
    >= 1.3x the fleet-even static baseline in aggregate throughput,
    with zero steady-state OOMs."""
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    from benchmarks import fig7_fleet
    summary = fig7_fleet.run(ticks=1200, seed=0, quiet=True)
    coord = summary["fleet_intune"]
    assert coord["pct_of_oracle"] >= 90.0, summary
    assert summary["_speedups"]["intune_vs_even"] >= 1.3, summary
    assert coord["ooms_steady"] == 0
    assert coord["oom_count"] == 0         # admission control: none at all
