"""End-to-end behaviour tests for the InTune system.

The paper's headline claims, verified against the calibrated simulator:
  1. InTune reaches higher throughput than AUTOTUNE-like tooling,
  2. InTune never OOMs while AUTOTUNE-like OOMs at a nonzero rate,
  3. InTune adapts to machine resizes without relaunch,
  4. convergence happens within the tuning window (paper: ~10 minutes).
"""
import numpy as np
import pytest

from repro.core import baselines as B
from repro.core.controller import InTune
from repro.core.pretrain import pretrain
from repro.data.pipeline import criteo_pipeline
from repro.data.simulator import MachineSpec, PipelineSim


@pytest.fixture(scope="module")
def pretrained_agent():
    # offline pass (full pass lives in core/pretrain.py __main__); the
    # factored branching head converges fastest (beyond-paper variant,
    # benchmarks cover the paper-faithful joint head too)
    return pretrain(5, episodes=30, ticks=250, verbose=False,
                    head="factored")


@pytest.fixture(scope="module")
def setting():
    return criteo_pipeline(), MachineSpec(n_cpus=128, mem_mb=65536)


def steady_throughput(spec, machine, alloc) -> float:
    return PipelineSim(spec, machine).apply(alloc)["throughput"]


def test_intune_beats_autotune(pretrained_agent, setting):
    spec, machine = setting
    tuner = InTune(spec, machine, seed=1, head="factored",
                   pretrained=pretrained_agent.state_dict(),
                   finetune_ticks=300)
    hist = tuner.run(600)
    intune_tput = np.mean([h["throughput"] for h in hist[-100:]])
    # autotune mean includes its OOM-crashed runs (the paper compares
    # achieved training throughput, failures included)
    at_tputs = [steady_throughput(spec, machine,
                                  B.autotune_like(spec, machine, s))
                for s in range(30)]
    assert intune_tput > np.mean(at_tputs) * 1.05
    assert tuner.env.sim.oom_count == 0


def test_autotune_ooms_sometimes(setting):
    spec, machine = setting
    ooms = sum(PipelineSim(spec, machine).apply(
        B.autotune_like(spec, machine, s))["oom"] for s in range(100))
    assert 1 <= ooms <= 30   # paper: ~8%


def test_intune_adapts_to_resize(pretrained_agent, setting):
    spec, machine = setting
    tuner = InTune(spec, machine, seed=2, head="factored",
                   pretrained=pretrained_agent.state_dict(),
                   finetune_ticks=200)
    tuner.run(400)
    base = np.mean([h["throughput"] for h in tuner.history[-50:]])
    tuner.resize(64)
    tuner.run(400)
    small = np.mean([h["throughput"] for h in tuner.history[-50:]])
    tuner.resize(128)
    tuner.run(400)
    back = np.mean([h["throughput"] for h in tuner.history[-50:]])
    # shrinking reduces throughput; growing recovers most of it without any
    # relaunch (the paper's Fig. 5C failure mode for AUTOTUNE)
    assert small < base
    assert back > small * 1.2
    assert tuner.env.sim.oom_count == 0


def test_ordering_matches_paper(setting):
    """unoptimized < autotune-like < heuristic/plumber <= oracle."""
    spec, machine = setting
    t = {}
    t["unopt"] = steady_throughput(spec, machine,
                                   B.unoptimized(spec, machine))
    t["auto"] = np.mean([steady_throughput(
        spec, machine, B.autotune_like(spec, machine, s))
        for s in range(20)])
    t["even"] = steady_throughput(spec, machine,
                                  B.heuristic_even(spec, machine))
    t["oracle"] = steady_throughput(spec, machine,
                                    B.oracle(spec, machine))
    assert t["unopt"] < t["auto"] < t["even"] <= t["oracle"]
