"""Property-based tests (hypothesis) of the pool-market auction.

Every property is a deterministic function of one integer seed (the
market generator derives from np.random.RandomState(seed)), so
hypothesis gets perfectly reproducible examples and shrinking works on
the seed alone. The properties pin the market contract DESIGN.md §12
states:

  conservation    grants never exceed the shared pool (and only go to
                  active trainers)
  floors          every active job is owed its anti-starvation floor
                  whenever the pool covers the sum of active floors
  weight monotone scaling one job's bid weight up never shrinks its
                  total grant
  idempotence     re-running the auction on the same state reproduces
                  the same grants (churn-safe re-auction: no churn, no
                  reshuffle) — both the pure function and PoolMarket's
                  cached path
  degradation     a job-less spec prices every trainer as its own
                  weight-1 job: the market IS the per-trainer greedy
                  arbiter (fleet_oracle)
"""
import dataclasses

import numpy as np
import pytest

from repro.core import baselines as B
from repro.data.fleet import (ClusterSpec, FleetSim, JobSpec, MarketSpec,
                              TrainerSpec, big_cluster)
from repro.data.pipeline import make_pipeline
from repro.data.simulator import MachineSpec

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:            # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    SEEDS = st.integers(0, 10_000)

    def seeded(max_examples: int = 40):
        def deco(fn):
            return settings(max_examples=max_examples,
                            deadline=None)(given(seed=SEEDS)(fn))
        return deco
else:
    # no hypothesis in this environment: run the same properties over a
    # fixed deterministic seed sample instead of skipping the module
    def seeded(max_examples: int = 40):
        return pytest.mark.parametrize(
            "seed", range(0, max_examples // 2))


def random_market(seed: int, jobless: bool = False) -> MarketSpec:
    """Random small market: 2-6 trainers on heterogeneous machines,
    partitioned round-robin into 1-3 weighted jobs with floors that
    always fit the pool (MarketSpec validates the sum)."""
    rng = np.random.RandomState(seed)
    n = int(rng.randint(2, 7))
    trainers = tuple(
        TrainerSpec(f"t{i}",
                    make_pipeline(int(rng.randint(3, 6)), seed=seed * 31 + i),
                    MachineSpec(n_cpus=int(rng.randint(2, 9)),
                                mem_mb=float(rng.choice([8192.0, 16384.0]))),
                    model_latency=float(rng.choice([0.05, 0.1, 0.25])),
                    start_active=bool(i == 0 or rng.rand() > 0.25))
        for i in range(n))
    pool = int(rng.randint(0, 9))
    if jobless:
        return MarketSpec(f"rand_market_{seed}", trainers, shared_pool=pool)
    k = int(rng.randint(1, min(n, 3) + 1))
    buckets = [[] for _ in range(k)]
    for i, t in enumerate(trainers):
        buckets[i % k].append(t.name)
    jobs, left = [], pool
    for j, b in enumerate(buckets):
        floor = int(rng.randint(0, min(left, 2) + 1))
        left -= floor
        jobs.append(JobSpec(f"j{j}", tuple(b),
                            weight=float(rng.choice([0.5, 1.0, 2.0, 4.0])),
                            floor=floor))
    return MarketSpec(f"rand_market_{seed}", trainers, shared_pool=pool,
                      jobs=tuple(jobs))


def state_of(market):
    return FleetSim(market, seed=0).machine


# ----------------------------------------------------------- conservation ---
@seeded(40)
def test_grants_conserve_pool_and_target_active(seed):
    market = random_market(seed)
    state = state_of(market)
    grants = B.market_grants(market, state)
    assert set(grants) == set(state.active)
    assert all(g >= 0 for g in grants.values())
    assert sum(grants.values()) <= state.pool
    # and the full allocation passes the backend's own falloc check
    falloc = B.market_oracle(market, state)
    FleetSim(market, seed=0).apply(falloc)


# ------------------------------------------------------------------ floors ---
@seeded(40)
def test_floors_respected_for_active_jobs(seed):
    market = random_market(seed)
    state = state_of(market)
    grants = B.market_grants(market, state)
    active = set(state.active)
    active_jobs = [j for j in market.jobs if any(t in active
                                                 for t in j.trainers)]
    # MarketSpec validates sum(all floors) <= pool, so the active subset
    # always fits: every active job must receive at least its floor.
    assert sum(j.floor for j in active_jobs) <= state.pool
    for j in active_jobs:
        got = sum(grants[t] for t in j.trainers if t in active)
        assert got >= j.floor, (j.name, got, j.floor, grants)


# ------------------------------------------------------- weight monotone ----
@pytest.mark.parametrize("scale", [2.0, 4.0, 16.0])
@seeded(20)
def test_weight_monotonicity(scale, seed):
    market = random_market(seed)
    state = state_of(market)
    before = B.market_grants(market, state)
    for k, j in enumerate(market.jobs):
        jobs = list(market.jobs)
        jobs[k] = dataclasses.replace(j, weight=j.weight * scale)
        scaled = dataclasses.replace(market, jobs=tuple(jobs))
        after = B.market_grants(scaled, state)
        tot = lambda g: sum(g[t] for t in j.trainers if t in g)
        assert tot(after) >= tot(before), (j.name, before, after)


# ---------------------------------------------------------- idempotence -----
@seeded(40)
def test_reauction_idempotent_under_no_churn(seed):
    market = random_market(seed)
    state = state_of(market)
    assert B.market_grants(market, state) == B.market_grants(market, state)
    a, b = B.market_oracle(market, state), B.market_oracle(market, state)
    assert a.grants == b.grants
    for n in a.allocs:
        assert np.array_equal(a.allocs[n].workers, b.allocs[n].workers)


@seeded(20)
def test_pool_market_cached_auction_matches_fresh(seed):
    """PoolMarket's budget cache (keyed on state.key()) must reproduce
    the pure auction: two proposals at the same state are identical,
    and per-job budgets match market_grants aggregated by job."""
    from repro.core.fleet_coordinator import PoolMarket
    market = random_market(seed)
    state = state_of(market)
    pm = PoolMarket(market, inner="job_oracle", seed=0)
    a = pm.propose(None, state, None)
    b = pm.propose(None, state, None)
    assert a.grants == b.grants
    for n in a.allocs:
        assert np.array_equal(a.allocs[n].workers, b.allocs[n].workers)
    grants = B.market_grants(market, state)
    active = set(state.active)
    for j in market.jobs:
        want = sum(grants[t] for t in j.trainers if t in active)
        assert pm.budgets.get(j.name, 0) == want


# ----------------------------------------------------------- degradation ----
@seeded(24)
def test_jobless_market_is_fleet_oracle(seed):
    """With jobs=() every trainer is its own weight-1 floor-0 job and
    the auction IS the per-trainer greedy arbiter."""
    market = random_market(seed, jobless=True)
    state = state_of(market)
    want = B.fleet_oracle(market, state)
    got = B.market_oracle(market, state)
    assert got.grants == want.grants
    for n in want.allocs:
        assert np.array_equal(got.allocs[n].workers, want.allocs[n].workers)


# ----------------------------------------------------------- spec checks ----
def test_market_spec_validation():
    t = [TrainerSpec(f"t{i}", make_pipeline(3, seed=i), MachineSpec())
         for i in range(2)]
    with pytest.raises(ValueError, match="no job"):
        MarketSpec("m", tuple(t), shared_pool=4,
                   jobs=(JobSpec("j0", ("t0",)),))
    with pytest.raises(ValueError, match="unknown trainer"):
        MarketSpec("m", tuple(t), shared_pool=4,
                   jobs=(JobSpec("j0", ("t0", "nope")),))
    with pytest.raises(ValueError, match="floors exceed"):
        MarketSpec("m", tuple(t), shared_pool=2,
                   jobs=(JobSpec("j0", ("t0",), floor=2),
                         JobSpec("j1", ("t1",), floor=1)))
    with pytest.raises(ValueError, match="weight"):
        MarketSpec("m", tuple(t), shared_pool=4,
                   jobs=(JobSpec("j0", ("t0", "t1"), weight=0.0),))
    ok = MarketSpec("m", tuple(t), shared_pool=4,
                    jobs=(JobSpec("j0", ("t0",), weight=2.0, floor=1),
                          JobSpec("j1", ("t1",))))
    assert ok.job("j0").floor == 1
    assert ok.job_of("t1").name == "j1"
    assert ok.job_of("t0").weight == 2.0


def test_big_cluster_shape_and_determinism():
    m1, m2 = big_cluster(32, seed=0), big_cluster(32, seed=0)
    assert len(m1.trainers) == 32 and len(m1.jobs) == 3
    assert {t for j in m1.jobs for t in j.trainers} \
        == {t.name for t in m1.trainers}
    assert m1 == m2                      # frozen dataclass deep equality
    assert big_cluster(32, seed=1) != m1


# ------------------------------------------------------- slow acceptance ---
@pytest.mark.slow
def test_fig_market_acceptance():
    """ISSUE 8 acceptance: on the 32-machine multi-job cluster with
    churn, the coordinator + market ("market": PoolMarket over per-job
    FleetCoordinators) holds >= 90% of the fleet oracle, beats the
    job-blind fleet-even split, and the weighted auction's static
    reference tracks the oracle to within 2%."""
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    from benchmarks import fig_market
    summary = fig_market.run(ticks=1200, seed=0, quiet=True)
    assert summary["market"]["pct_of_oracle"] >= 90.0, summary
    assert summary["market_oracle"]["pct_of_oracle"] >= 98.0, summary
    assert summary["_speedups"]["market_vs_even"] >= 1.3, summary
