"""StageGraph DAG semantics: validation, topological bottleneck
throughput, linear-chain equivalence with the pre-DAG pipeline, the
executor round-trip on a join graph, the prefetch-knob fix, and the RL
agent tuning a non-linear pipeline through the Optimizer protocol."""
import queue
import time

import numpy as np
import pytest

from repro.core.controller import InTune
from repro.core.env import PipelineEnv, even_allocation
from repro.core.optimizer import Optimizer, StaticOptimizer, make_optimizer
from repro.core import baselines as B
from repro.core.pretrain import pretrain
from repro.data.executor import ThreadedPipeline
from repro.data.pipeline import (StageGraph, StageSpec, criteo_pipeline,
                                 make_pipeline, multisource_dlrm_pipeline,
                                 stage_throughput)
from repro.data.simulator import Allocation, MachineSpec, PipelineSim


# ------------------------------------------------------------ validation ---
def _stage(name, cost=0.1, inputs=(), **kw):
    return StageSpec(name, "udf", cost=cost, inputs=inputs, **kw)


def test_rejects_cycle():
    # a <-> b cycle feeding a valid sink: passes the sink check, must
    # still die in the topological sort
    with pytest.raises(ValueError, match="cycle"):
        StageGraph("bad", (_stage("a", inputs=("b",)),
                           _stage("b", inputs=("a",)),
                           _stage("c", inputs=("b",))))


def test_rejects_unknown_input_and_self_loop():
    with pytest.raises(ValueError, match="unknown stage"):
        StageGraph("bad", (_stage("a"), _stage("b", inputs=("nope",))))
    with pytest.raises(ValueError, match="consumes itself"):
        StageGraph("bad", (_stage("a", inputs=("a",)),))


def test_rejects_multiple_sinks_and_duplicate_names():
    with pytest.raises(ValueError, match="exactly one sink"):
        StageGraph("bad", (_stage("a"), _stage("b", inputs=("a",)),
                           _stage("c", inputs=("a",))))
    with pytest.raises(ValueError, match="duplicate"):
        StageGraph("bad", (_stage("a"), _stage("a", inputs=("a",))))


def test_topology_accessors():
    spec = multisource_dlrm_pipeline()
    assert not spec.is_linear
    assert len(spec.sources) == 3
    assert spec.stages[spec.sink].name == "prefetch"
    assert len(spec.edges) == 6
    order = {i: k for k, i in enumerate(spec.topo_order)}
    for src, dst in spec.edges:
        assert order[src] < order[dst]


# ------------------------------------------------- throughput semantics ----
def test_join_bottleneck_matches_hand_computation():
    # serial_frac=0 -> rate = workers / cost, so everything is exact
    g = StageGraph("join", (
        _stage("a", cost=0.5, serial_frac=0.0),
        _stage("b", cost=0.25, serial_frac=0.0),
        _stage("j", cost=0.125, serial_frac=0.0, inputs=("a", "b")),
        _stage("s", cost=0.1, serial_frac=0.0, inputs=("j",)),
    ), edge_buffer_mb=10.0)
    sim = PipelineSim(g, MachineSpec())
    alloc = Allocation(np.array([1, 2, 2, 1]))
    # service rates: a=2, b=8, j=16, s=10. The join can only run at the
    # min of its parents (2), and the sink inherits that bottleneck.
    assert sim.stage_rates(alloc).tolist() == [2.0, 8.0, 16.0, 10.0]
    assert sim.sustained_rates(alloc).tolist() == [2.0, 8.0, 2.0, 2.0]
    assert sim.throughput(alloc) == 2.0
    # per-edge buffers: 3 edges * 10 MB on top of the linear-era formula
    base = 2048.0 + sum(s.mem_per_worker_mb * w
                        for s, w in zip(g.stages, alloc.workers))
    assert sim.memory_used(alloc) == base + 30.0 + alloc.prefetch_mb


def test_linear_chain_equivalence():
    """The pre-DAG bottleneck formula survives exactly: auto-wired chains
    report min-over-stages throughput and the linear-era memory model."""
    spec = criteo_pipeline()
    assert spec.is_linear
    assert [s.inputs for s in spec.stages] == [
        (), ("disk_load",), ("shuffle",), ("feature_udf",), ("batch",)]
    sim = PipelineSim(spec, MachineSpec())
    rng = np.random.RandomState(0)
    for _ in range(50):
        alloc = Allocation(rng.randint(1, 40, size=spec.n_stages))
        rates = [stage_throughput(s, w)
                 for s, w in zip(spec.stages, alloc.workers)]
        assert sim.throughput(alloc) == float(min(rates))
        assert sim.memory_used(alloc) == 2048.0 + alloc.prefetch_mb + sum(
            s.mem_per_worker_mb * w
            for s, w in zip(spec.stages, alloc.workers))
    for n in (3, 4, 5, 6):
        assert make_pipeline(n, seed=n).is_linear


# ------------------------------------------------------------- executor ----
def test_executor_roundtrip_three_source_join():
    spec = multisource_dlrm_pipeline()
    n = 15
    counts = {"d": 0, "s": 0, "l": 0}

    def src(key):
        def fn():
            if counts[key] >= n:
                return None
            counts[key] += 1
            return (key, counts[key])
        return fn

    fns = {
        "dense_source": src("d"), "sparse_source": src("s"),
        "label_source": src("l"),
        "join": lambda d, s, l: {"d": d, "s": s, "l": l},
        "feature_udf": lambda b: b,
        "batch": lambda b: b,
        "prefetch": lambda b: b,
    }
    pipe = ThreadedPipeline(spec, fns=fns, queue_depth=4, item_mb=1.0)
    got = []
    try:
        while True:
            got.append(pipe.get_batch(timeout=20))
    except StopIteration:
        pass
    finally:
        pipe.stop()
    assert len(got) == n
    # the join pairs item i of every stream with item i of the others
    for i, b in enumerate(got):
        assert b["d"][1] == b["s"][1] == b["l"][1] == i + 1
    assert len(pipe.stats()["workers"]) == spec.n_stages


def test_prefetch_budget_bounds_output_queue():
    """The agent's prefetch knob must act on the real executor: the
    output queue is re-bounded live and back-pressures the producer."""
    spec = criteo_pipeline()
    pipe = ThreadedPipeline(spec, lambda: {"x": 1}, [lambda b: b] * 4,
                            queue_depth=2, item_mb=1.0)
    try:
        # grow the budget: the output queue fills to the new depth
        pipe.set_allocation([1, 1, 1, 1, 1], prefetch_mb=6.0)
        assert pipe.prefetch_depth == 6
        deadline = time.monotonic() + 5.0
        while pipe.out_q.qsize() < 6 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert pipe.out_q.qsize() == 6
        # shrink it: buffered items drain, the producer refills only to 3
        pipe.set_allocation([1, 1, 1, 1, 1], prefetch_mb=3.0)
        assert pipe.prefetch_depth == 3
        for _ in range(5):
            pipe.get_batch(timeout=5)
        time.sleep(0.3)
        assert pipe.out_q.qsize() == 3
    finally:
        pipe.stop()


def test_stats_respects_machine_spec():
    machine = MachineSpec(n_cpus=12, mem_mb=4096.0)
    spec = criteo_pipeline()
    pipe = ThreadedPipeline(spec, lambda: None, [lambda b: b] * 4,
                            item_mb=2.0, machine=machine)
    try:
        pipe.set_allocation([2, 1, 3, 1, 1], prefetch_mb=64.0)
        time.sleep(0.05)
        st = pipe.stats()
        assert st["free_cpus"] == 12 - 8
        # edge-queue items at item_mb + the prefetch budget once (items in
        # the output queue live inside that budget, like the simulator)
        expected_mem = sum(st["queue_sizes"][:-1]) * 2.0 + 64.0
        assert st["mem_frac"] == pytest.approx(expected_mem / 4096.0)
    finally:
        pipe.stop()


# ------------------------------------------- optimizer protocol + RL -------
def test_static_optimizers_satisfy_protocol():
    spec = multisource_dlrm_pipeline()
    machine = MachineSpec(n_cpus=64)
    for name in B.BASELINES:
        opt = make_optimizer(name, spec, machine, seed=3)
        assert isinstance(opt, Optimizer)
        alloc = opt.propose(spec, machine)
        assert alloc.workers.shape == (spec.n_stages,)
        # cached until the machine changes
        assert opt.propose(spec, machine) is alloc
        opt.observe({"throughput": 0.0, "mem_mb": 0.0})
    # seeded baselines reproduce the bare-function call exactly
    ref = B.plumber_like(spec, machine, 3)
    got = StaticOptimizer("plumber", B.plumber_like,
                          seeded=True, seed=3).propose(spec, machine)
    assert np.array_equal(ref.workers, got.workers)


def test_env_and_even_allocation_on_dag():
    spec = multisource_dlrm_pipeline()
    env = PipelineEnv(spec, MachineSpec(n_cpus=128), seed=0)
    assert env.obs_dim == 2 * spec.n_stages + 6
    assert env.observe().shape == (env.obs_dim,)
    obs, reward, metrics = env.step(np.zeros(spec.n_stages, dtype=int))
    assert np.isfinite(reward) and metrics["throughput"] > 0
    assert even_allocation(spec, 128).workers.sum() <= 128


def test_env_initializes_from_paper_heuristic_not_fixed_even():
    """Frozen-protocol regression: PipelineEnv must start from the paper's
    even-division heuristic baseline (floor split, remainder dropped) —
    the state every published fig5/fig7 number started from — NOT from
    the fixed even_allocation (which distributes the remainder and would
    silently shift the whole InTune trajectory)."""
    spec = criteo_pipeline()
    env = PipelineEnv(spec, MachineSpec(n_cpus=128), seed=0)
    assert env.alloc.workers.tolist() == [25] * 5          # floor(128/5)
    assert env.alloc.workers.tolist() \
        == B.heuristic_even(spec, MachineSpec(n_cpus=128)).workers.tolist()
    # ... while the fixed even_allocation places all 128
    assert even_allocation(spec, 128).workers.tolist() \
        == [26, 26, 26, 25, 25]


@pytest.fixture(scope="module")
def pretrained_r7():
    # short offline pass over random 7-stage specs; the simulator's
    # dynamics depend only on the per-stage rate vector, so a
    # linear-chain curriculum transfers to 7-stage DAGs (DESIGN.md §4)
    return pretrain(7, episodes=30, ticks=250, verbose=False,
                    head="factored")


def test_intune_reaches_oracle_on_multisource_dag(pretrained_r7):
    """Acceptance: >= 90% of oracle throughput within 300 simulator ticks
    on the multi-source join DAG, via the Optimizer-protocol loop."""
    spec = multisource_dlrm_pipeline()
    machine = MachineSpec(n_cpus=128, mem_mb=65536)
    oracle_tput = PipelineSim(spec, machine).best_allocation()[1]

    tuner = InTune(spec, machine, seed=4, head="factored",
                   pretrained=pretrained_r7.state_dict(),
                   finetune_ticks=250)
    sim = PipelineSim(spec, machine, seed=4)
    tputs = []
    for _ in range(300):
        alloc = tuner.propose(spec, sim.machine)
        metrics = sim.apply(alloc)
        tuner.observe(metrics)
        tputs.append(metrics["throughput"])
    steady = np.mean(tputs[-40:])   # serving the incumbent best
    assert sim.oom_count == 0
    assert steady >= 0.9 * oracle_tput, \
        f"InTune reached {steady / oracle_tput:.1%} of oracle"
