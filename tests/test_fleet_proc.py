"""ProcFleet: the fleet plane on REAL OS processes.

The proc-fleet extension of tests/test_live_fleet.py — the same three
layers of evidence, now with physical CPU contention and a measured-RSS
OOM judge:

  - stream-epoch regression (tier-1): a stream trainer's arrival curve
    SURVIVES the OOM kill + relaunch — RigSlot carries the epoch
    (emitted tokens + the monotonic t0 anchoring the curve) across the
    dead window, so the relaunched source RESUMES, it does not restart.
    Pins the PR 8 bugfix: before it, every relaunch reset the curve and
    the backlog that should have accrued while dead vanished.
  - the proc-fleet differential (slow): on a 3-trainer fleet, measured
    per-trainer rates rank candidate FleetAllocations the way FleetSim
    predicts. Candidates hold the TOTAL worker count fixed and rotate
    which trainer is fed, so the ranking transfers on any host — on an
    oversubscribed box the kernel gives each runnable worker an equal
    share, making per-trainer rate proportional to its worker count.
    (Within-pipeline placement does NOT transfer on a starved host —
    see the cpu-count guard on the single-machine differential.)
  - OOM-quarantine lifecycle parity (slow): the measured-RSS kill pays
    exactly the simulator's OOM_RESTART_TICKS dead window before the
    relaunch — same lifecycle shape, sim and proc.
  - churn soak (slow): joins/leaves/resizes over a ProcessPipeline
    fleet with zero leaked OS processes (active_children accounting)
    and clean teardown books.
"""
import multiprocessing as mp
import time

import numpy as np
import pytest

from repro.core import baselines as B
from repro.data.fleet import (ClusterSpec, FleetAllocation, FleetSim,
                              TrainerSpec, churn_schedule)
from repro.data.live_fleet import ProcFleet
from repro.data.pipeline import StageGraph, StageSpec, stream_dlrm_pipeline
from repro.data.simulator import (Allocation, MachineSpec, OOM_RESTART_TICKS)
from repro.data.stream import ArrivalProcess


def spin_pipe(name: str, work_cost: float = 0.02,
              mem_per_worker_mb: float = 16.0) -> StageGraph:
    """2-stage src -> work chain with ms-scale spin costs: a short
    window catches tens of batches and the work stage is the bottleneck
    by 10x, so per-trainer rate tracks its work-worker count."""
    return StageGraph(name, (
        StageSpec("src", "source", cost=0.002, serial_frac=0.0,
                  mem_per_worker_mb=mem_per_worker_mb),
        StageSpec("work", "udf", cost=work_cost, serial_frac=0.0,
                  mem_per_worker_mb=mem_per_worker_mb, inputs=("src",)),
    ), batch_mb=1.0)


def _wait_children_settle(baseline, timeout=8.0):
    """Poll until the process's child set shrinks back to `baseline`
    (reaping is asynchronous; bounded wait)."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        extra = [p for p in mp.active_children() if p not in baseline]
        if not extra:
            return []
        time.sleep(0.05)
    return [p for p in mp.active_children() if p not in baseline]


# ------------------------------------------------- stream epoch carry -------
def test_stream_epoch_survives_oom_relaunch():
    """PR 8 regression: kill a stream trainer, pay a dead window, and
    the relaunched ProcessPipeline RESUMES the arrival curve — same t0,
    emitted tokens preserved — so backlog accrued while dead is real."""
    arr = ArrivalProcess(users=1_000_000, events_per_user_s=1e-3,
                         events_per_batch=20)          # 50 batches/s
    spec = stream_dlrm_pipeline(arr, cost_scale=0.05, batch_mb=1.0)
    cluster = ClusterSpec("stream_proc1", (
        TrainerSpec("s", spec, MachineSpec(n_cpus=2, mem_mb=4096.0)),
    ), shared_pool=0)
    fa = FleetAllocation(
        {"s": Allocation(np.ones(spec.n_stages, dtype=int), 4.0)})
    baseline = list(mp.active_children())
    fleet = ProcFleet(cluster, window_s=0.05, ballast=False)
    try:
        slot = fleet.slots["s"]
        deadline = time.monotonic() + 10.0
        pre = None
        while time.monotonic() < deadline:      # workers spawn async
            fleet.apply(fa)
            pre = slot.rig.pipe.stream_epoch()
            if pre["emitted"] > 0:
                break
        assert pre is not None and pre["emitted"] > 0
        slot.kill()
        assert slot.restart_left == OOM_RESTART_TICKS
        assert slot.carry_epoch is not None
        assert slot.carry_epoch["emitted"] >= pre["emitted"]
        carried = dict(slot.carry_epoch)
        slot.restart_left = 1           # collapse the dead window
        time.sleep(0.3)                 # ... but let stream time run on
        m = fleet.apply(fa)             # relaunch + adopt happens here
        assert m["per_trainer"]["s"]["restarting"]
        post = slot.rig.pipe.stream_epoch()
        # the bug made t0 fresh (curve restarted at zero); the fix
        # resumes the predecessor's anchor and emitted count exactly
        assert post["t0"] == carried["t0"]
        assert post["emitted"] >= carried["emitted"]
        assert slot.carry_epoch is None
        st = slot.rig.pipe.stream_state()
        assert st["t"] >= 0.3           # stream time spans the dead gap
        assert st["arrivals"] >= 50 * 0.3 * 0.9
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline:  # relaunched workers spawn async
            fleet.apply(fa)
            if slot.rig.pipe.counters()["delivered"] > 0:
                break
        assert slot.rig.pipe.counters()["delivered"] > 0
    finally:
        acct = fleet.close()
    assert acct["all_joined"], acct
    assert _wait_children_settle(baseline) == []


def test_adopt_stream_epoch_round_trips_across_pipelines():
    """API-level half of the regression: a successor pipeline that
    adopts an epoch reports it verbatim; non-stream graphs no-op."""
    from repro.data.proc_executor import ProcessPipeline, stage_fns_for
    arr = ArrivalProcess(users=1_000_000, events_per_user_s=1e-3,
                         events_per_batch=20)
    spec = stream_dlrm_pipeline(arr, cost_scale=0.05, batch_mb=1.0)
    epoch = {"emitted": 37, "t0": 123.456}
    p = ProcessPipeline(spec, fns=stage_fns_for(spec, ballast=False),
                        machine=MachineSpec(n_cpus=1, mem_mb=4096.0))
    try:
        p.adopt_stream_epoch(epoch)
        assert p.stream_epoch() == epoch
        p.adopt_stream_epoch(None)      # None epoch: no-op
        assert p.stream_epoch() == epoch
    finally:
        p.shutdown(drain=False)
    plain = spin_pipe("noepoch")
    q = ProcessPipeline(plain, fns=stage_fns_for(plain, ballast=False),
                        machine=MachineSpec(n_cpus=1, mem_mb=4096.0))
    try:
        assert q.stream_epoch() is None
        q.adopt_stream_epoch(epoch)     # non-stream graph: no-op
        assert q.stream_epoch() is None
    finally:
        q.shutdown(drain=False)


# ------------------------------------------------ proc-fleet differential ---
@pytest.mark.slow
def test_proc_fleet_differential_ranks_match_sim():
    """THE proc-fleet differential: measured per-trainer rates on a
    3-trainer ProcessPipeline fleet rank candidate FleetAllocations as
    FleetSim predicts. Every candidate uses the same TOTAL worker count
    and rotates the per-trainer work-worker levels (4, 2, 1) through a
    latin square, so per-trainer rate ~ its share of the host's
    runnable workers and the >= 1.8x designed separation survives any
    degree of host oversubscription."""
    cluster = ClusterSpec("proc_diff3", (
        TrainerSpec("a", spin_pipe("pa"), MachineSpec(10, 4096.0)),
        TrainerSpec("b", spin_pipe("pb"), MachineSpec(10, 4096.0)),
        TrainerSpec("c", spin_pipe("pc"), MachineSpec(10, 4096.0)),
    ), shared_pool=0)
    names = ("a", "b", "c")
    levels = [(9, 3, 1), (1, 9, 3), (3, 1, 9)]     # latin square, total 13

    def falloc(row):
        return FleetAllocation({
            n: Allocation(np.asarray([1, w], dtype=int), 4.0)
            for n, w in zip(names, row)})

    predicted = {n: [] for n in names}
    for row in levels:
        per = FleetSim(cluster, seed=0).apply(falloc(row))["per_trainer"]
        for n in names:
            predicted[n].append(per[n]["throughput"])
    for n, preds in predicted.items():
        ordered = sorted(preds)
        for lo, hi in zip(ordered, ordered[1:]):
            assert hi / lo >= 1.8, f"test design: {n} separation too small"

    baseline = list(mp.active_children())
    measured = {n: [0.0] * len(levels) for n in names}
    with ProcFleet(cluster, window_s=0.3, ballast=False) as pf:
        for _ in range(3):                          # interleaved rounds
            for i, row in enumerate(levels):
                pf.apply(falloc(row))               # settle the resize:
                pf.apply(falloc(row))               # reaping is async
                per = pf.apply(falloc(row))["per_trainer"]
                for n in names:
                    measured[n][i] += per[n]["throughput"]
        for n in names:
            assert np.argsort(predicted[n]).tolist() \
                == np.argsort(measured[n]).tolist(), \
                (f"{n}: sim ranks {predicted[n]} but proc measures "
                 f"{measured[n]}")
        acct = pf.close()
    assert acct["all_joined"], acct
    assert acct["oom_count"] == 0, acct
    leaked = _wait_children_settle(baseline)
    assert leaked == [], f"leaked processes: {leaked}"


# ------------------------------------------- OOM quarantine lifecycle -------
@pytest.mark.slow
def test_proc_oom_quarantine_lifecycle_matches_sim():
    """The measured-RSS judge drives the same kill -> OOM_RESTART_TICKS
    dead window -> relaunch lifecycle the simulator's budget judge
    does. The proc trainer carries real per-worker ballast and a
    mem_mb sized under it, so its resident growth must breach."""
    def lifecycle(per_ticks):
        """(first oom tick, dead-window ticks after it, relaunched).
        The dead window ends at the first tick that is either healthy
        (not restarting) or a fresh kill (oom) — a same-verdict crash
        loop re-kills the moment the relaunch is judged, so a re-kill
        proves the relaunch exactly as a healthy tick does."""
        ooms = [i for i, p in enumerate(per_ticks) if p["oom"]]
        assert ooms, "no OOM observed"
        k = ooms[0]
        down = 0
        for p in per_ticks[k + 1:]:
            if p["oom"] or not p["restarting"]:
                break
            down += 1
        after = per_ticks[k + 1 + down:]
        relaunched = bool(after) and (after[0]["oom"]
                                      or not after[0]["restarting"])
        return k, down, relaunched

    # --- sim side: budget judge (graph memory model over mem_mb) ---
    tight_sim = ClusterSpec("oom_sim", (
        TrainerSpec("t", spin_pipe("ps", mem_per_worker_mb=512.0),
                    MachineSpec(4, 700.0)),
    ), shared_pool=0)
    fa = FleetAllocation({"t": Allocation(np.asarray([1, 1], int), 4.0)})
    sim = FleetSim(tight_sim, seed=0)
    sim_per = [sim.apply(fa)["per_trainer"]["t"]
               for _ in range(OOM_RESTART_TICKS + 4)]
    k, down, relaunched = lifecycle(sim_per)
    assert (k, down, relaunched) == (0, OOM_RESTART_TICKS, True)
    # the sim's crash loop re-kills at relaunch: same budget, same verdict
    assert sim_per[OOM_RESTART_TICKS + 1]["oom"]

    # --- proc side: measured-RSS judge over real ballast ---
    tight = ClusterSpec("oom_proc", (
        TrainerSpec("t", spin_pipe("pp", mem_per_worker_mb=96.0),
                    MachineSpec(4, 120.0)),      # 2 workers' ballast >> cap
    ), shared_pool=0)
    baseline = list(mp.active_children())
    with ProcFleet(tight, window_s=0.1, ballast=True,
                   rss_interval=0.05) as pf:
        per = []
        for _ in range(OOM_RESTART_TICKS + 25):
            per.append(pf.apply(fa)["per_trainer"]["t"])
            if any(p["oom"] for p in per):
                k = next(i for i, p in enumerate(per) if p["oom"])
                if len(per) >= k + OOM_RESTART_TICKS + 3:
                    break
        k, down, relaunched = lifecycle(per)
        assert down == OOM_RESTART_TICKS, (k, down)
        assert relaunched, (k, down)
        assert per[k]["mem_mb"] > 120.0         # the verdict was measured
        assert pf.slots["t"].oom_count >= 1
        acct = pf.close()
    assert acct["oom_count"] >= 1, acct
    leaked = _wait_children_settle(baseline)
    assert leaked == [], f"leaked processes: {leaked}"


# --------------------------------------------------------- churn soak -------
@pytest.mark.slow
def test_proc_churn_soak_no_leaked_processes():
    """Slow churn over a 3-trainer process fleet: every join/leave/
    resize spawns or reaps real OS processes; after close() the child
    set settles back to the pre-test baseline (zero leaks) and the
    teardown books are clean."""
    ticks = 60
    cluster = ClusterSpec("proc_soak3", (
        TrainerSpec("a", spin_pipe("sa", 0.02), MachineSpec(3, 4096.0)),
        TrainerSpec("b", spin_pipe("sb", 0.03), MachineSpec(3, 4096.0)),
        TrainerSpec("c", spin_pipe("sc", 0.02), MachineSpec(3, 4096.0)),
    ), shared_pool=2, events=churn_schedule(ticks, [
        (0.15, "leave", "b", 0),
        (0.35, "join", "b", 0),
        (0.50, "resize", "a", 2),
        (0.65, "leave", "c", 0),
        (0.80, "join", "c", 0),
        (0.90, "pool", "", 1),
    ]))
    baseline = list(mp.active_children())
    pf = ProcFleet(cluster, window_s=0.05, ballast=False)
    try:
        for _ in range(ticks):
            st = pf.machine
            pf.apply(B.fleet_even(cluster, st))
    finally:
        acct = pf.close()
    assert acct["oom_count"] == 0, acct
    assert acct["crash_lost"] == 0, acct
    assert acct["all_joined"], acct
    leaked = _wait_children_settle(baseline)
    assert leaked == [], f"leaked processes: {leaked}"
