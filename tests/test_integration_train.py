"""Integration: real training loops decrease loss (DLRM on the synthetic
Criteo stream through the actual pipeline UDFs; tiny LM on a token stream;
grad-compression allreduce equivalence)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import DLRMConfig, TransformerConfig
from repro.data.synthetic import CriteoStream, TokenStream
from repro.models import dlrm as dlrm_lib
from repro.models import transformer as tfm
from repro.train.optim import make_optimizer
from repro.train.train_step import make_train_step


def test_dlrm_loss_decreases():
    cfg = DLRMConfig(name="dlrm-int", n_sparse=8, n_dense=6, embed_dim=16,
                     vocab_sizes=(4096,) * 8, bottom_mlp=(32, 16),
                     top_mlp=(64, 32, 1))
    stream = CriteoStream(n_sparse=8, n_dense=6, vocab=4096, seed=0)
    params, _ = dlrm_lib.init_params(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer("adagrad", lr=0.05)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(
        lambda p, b: dlrm_lib.loss_fn(p, cfg, b), opt))
    losses = []
    for i in range(60):
        # run the REAL online UDF path: raw block -> feature_udf -> batch
        batch = stream.feature_udf(stream.raw_block(256))
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = step(params, opt_state, i, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) * 0.98
    assert np.isfinite(losses).all()


def test_lm_loss_decreases_with_microbatching():
    cfg = TransformerConfig(
        name="lm-int", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
        head_dim=16, d_ff=64, vocab_size=256, param_dtype="float32",
        attn_chunk=16, remat="full")
    stream = TokenStream(256, 32, seed=0)
    params, _ = tfm.init_params(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer("adam", lr=3e-3)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(
        lambda p, b: tfm.loss_fn(p, cfg, b), opt, microbatches=2))
    losses = []
    for i in range(40):
        batch = {k: jnp.asarray(v) for k, v in stream.batch(16).items()}
        params, opt_state, metrics = step(params, opt_state, i, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]


def test_microbatched_grads_match_full_batch():
    cfg = TransformerConfig(
        name="lm-mb", n_layers=1, d_model=16, n_heads=2, n_kv_heads=2,
        head_dim=8, d_ff=32, vocab_size=64, param_dtype="float32",
        attn_chunk=8, remat="none")
    params, _ = tfm.init_params(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer("sgd", lr=0.1, momentum=0.0)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16),
                                          0, 64)}
    batch["labels"] = batch["tokens"]
    loss_fn = lambda p, b: tfm.loss_fn(p, cfg, b)
    s1 = make_train_step(loss_fn, opt, microbatches=1)
    s2 = make_train_step(loss_fn, opt, microbatches=4)
    p1, _, _ = jax.jit(s1)(params, opt.init(params), 0, batch)
    p2, _, _ = jax.jit(s2)(params, opt.init(params), 0, batch)
    for a, b_ in zip(jax.tree_util.tree_leaves(p1),
                     jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=2e-3, atol=2e-4)


def test_grad_compression_psum():
    """bf16/int8 compressed allreduce ~= exact mean (shard_map, 1 device)."""
    from repro.train.collectives import psum_tree
    from repro.common.shardlib import compat_shard_map
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    g = {"w": jnp.asarray(np.random.RandomState(0).randn(64), jnp.float32)}

    for mode, tol in [("none", 1e-7), ("bf16", 1e-2), ("int8", 2e-2)]:
        out = jax.jit(compat_shard_map(
            lambda t: psum_tree(t, ("data",), compress=mode),
            mesh=mesh, in_specs=({"w": P()},), out_specs={"w": P()}))(g)
        np.testing.assert_allclose(np.asarray(out["w"]),
                                   np.asarray(g["w"]), rtol=tol, atol=tol)
