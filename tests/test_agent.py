"""DQN agent unit + property tests: action codec roundtrip, Q-net shapes,
learning on a trivial contextual task, state_dict roundtrip."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import actions as act_lib
from repro.core.agent import DQNAgent, DQNConfig, init_qnet, qnet_apply
import jax
import jax.numpy as jnp


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 4), min_size=2, max_size=6))
def test_action_codec_roundtrip(choices):
    choices = np.array(choices)
    a = act_lib.encode_joint(choices)
    deltas = act_lib.decode_joint(a, len(choices))
    np.testing.assert_array_equal(deltas, act_lib.DELTAS[choices])


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000))
def test_apply_deltas_respects_caps(seed):
    rng = np.random.RandomState(seed)
    r = 5
    workers = rng.randint(1, 40, size=r)
    deltas = act_lib.DELTAS[rng.randint(0, 5, size=r)]
    new, pf = act_lib.apply_deltas(workers, deltas, prefetch_idx=r - 1,
                                   prefetch_mb=256.0, max_workers=64)
    assert (new >= 1).all()
    assert new.sum() <= max(64, workers.sum())
    assert pf >= act_lib.PREFETCH_MB_UNIT


@pytest.mark.parametrize("head", ["joint", "factored"])
def test_qnet_shapes(head):
    cfg = DQNConfig(obs_dim=8, n_stages=3, head=head)
    params = init_qnet(jax.random.PRNGKey(0), cfg)
    q = qnet_apply(params, jnp.zeros((4, 8)), cfg)
    if head == "joint":
        assert q.shape == (4, 125)
    else:
        assert q.shape == (4, 3, 5)


@pytest.mark.parametrize("head", ["joint", "factored"])
def test_agent_learns_trivial_task(head):
    """Reward = 1 when stage-0 choice is '+5' — the agent should find it."""
    cfg = DQNConfig(obs_dim=4, n_stages=2, head=head, eps_decay_steps=400,
                    buffer_size=2000, target_update=50)
    agent = DQNAgent(cfg, seed=0)
    rng = np.random.RandomState(0)
    obs = np.zeros(4, np.float32)
    for t in range(800):
        a = agent.act(obs)
        r = 1.0 if a[0] == 4 else 0.0
        agent.observe(obs, a, r, obs, done=False)
    hits = sum(agent.act(obs, explore=False)[0] == 4 for _ in range(5))
    assert hits == 5


def test_state_dict_roundtrip():
    cfg = DQNConfig(obs_dim=6, n_stages=3)
    a1 = DQNAgent(cfg, seed=1)
    a1.steps = 123
    state = a1.state_dict()
    a2 = DQNAgent(cfg, seed=2)
    a2.load_state_dict(state)
    obs = np.ones(6, np.float32)
    np.testing.assert_array_equal(a1.act(obs, explore=False),
                                  a2.act(obs, explore=False))
    assert a2.steps == 123
