"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs (assignment
requirement). The FULL configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (DLRMConfig, GNNConfig, RecsysConfig,
                                TransformerConfig)
from repro.models import dlrm as dlrm_lib
from repro.models import gnn as gnn_lib
from repro.models import recsys as recsys_lib
from repro.models import transformer as tfm
from repro.train.optim import make_optimizer
from repro.train.train_step import make_train_step

RNG = jax.random.PRNGKey(0)


def _finite(tree):
    return all(bool(jnp.all(jnp.isfinite(x)))
               for x in jax.tree_util.tree_leaves(tree)
               if jnp.issubdtype(x.dtype, jnp.floating))


# ------------------------------------------------------------ LM family ----
LM_REDUCED = {
    "qwen2-moe-a2.7b": TransformerConfig(
        name="qwen2-moe-r", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=96, vocab_size=512, n_experts=8,
        n_shared_experts=2, top_k=2, d_expert=48, qkv_bias=True,
        tie_embeddings=False, param_dtype="float32", attn_chunk=32),
    "kimi-k2-1t-a32b": TransformerConfig(
        name="kimi-r", n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
        head_dim=14, d_ff=48, vocab_size=512, n_experts=16,
        n_shared_experts=1, top_k=4, d_expert=48, tie_embeddings=False,
        param_dtype="float32", attn_chunk=32),
    "smollm-135m": TransformerConfig(
        name="smollm-r", n_layers=3, d_model=48, n_heads=3, n_kv_heads=1,
        head_dim=16, d_ff=128, vocab_size=512, tie_embeddings=True,
        param_dtype="float32", attn_chunk=32),
    "gemma2-2b": TransformerConfig(
        name="gemma2-r", n_layers=4, d_model=48, n_heads=2, n_kv_heads=1,
        head_dim=24, d_ff=96, vocab_size=512, sliding_window=16,
        local_global_alternating=True, attn_logit_softcap=50.0,
        final_logit_softcap=30.0, post_norm=True, scale_embed=True,
        act="gelu", scan_block=2, param_dtype="float32", attn_chunk=32),
    "qwen2.5-32b": TransformerConfig(
        name="qwen25-r", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=160, vocab_size=512, qkv_bias=True,
        tie_embeddings=False, param_dtype="float32", attn_chunk=32),
}


@pytest.mark.parametrize("arch_id", sorted(LM_REDUCED))
def test_lm_train_step(arch_id):
    cfg = LM_REDUCED[arch_id]
    params, _ = tfm.init_params(RNG, cfg)
    opt = make_optimizer("adam", lr=1e-3)
    opt_state = opt.init(params)
    step = make_train_step(lambda p, b: tfm.loss_fn(p, cfg, b), opt)
    toks = jax.random.randint(RNG, (4, 64), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    params, opt_state, metrics = jax.jit(step)(params, opt_state, 0, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert _finite(params)


@pytest.mark.parametrize("arch_id", ["gemma2-2b", "qwen2.5-32b"])
def test_lm_decode_step(arch_id):
    cfg = LM_REDUCED[arch_id]
    params, _ = tfm.init_params(RNG, cfg)
    cache = tfm.init_cache(cfg, 2, 32, dtype=jnp.float32)
    toks = jax.random.randint(RNG, (2,), 0, cfg.vocab_size)
    logits, cache = tfm.decode_step(params, cfg, cache, toks, 0)
    assert logits.shape == (2, cfg.vocab_size)
    assert _finite(logits)


# ----------------------------------------------------------------- GNN -----
def test_graphsage_smoke():
    cfg = GNNConfig(name="sage-r", n_layers=2, d_hidden=16, n_classes=5)
    params, _ = gnn_lib.init_params(RNG, cfg, d_feat=12)
    n, e = 50, 200
    batch = {
        "x": jax.random.normal(RNG, (n, 12)),
        "edge_src": jax.random.randint(RNG, (e,), 0, n),
        "edge_dst": jax.random.randint(RNG, (e,), 0, n),
        "labels": jax.random.randint(RNG, (n,), 0, 5),
    }
    opt = make_optimizer("adam", lr=1e-3)
    step = make_train_step(
        lambda p, b: gnn_lib.full_graph_loss(p, cfg, b), opt)
    params2, _, metrics = jax.jit(step)(params, opt.init(params), 0, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert _finite(params2)
    # minibatch + molecule regimes
    mb = {"x0": jax.random.normal(RNG, (8, 12)),
          "neigh1": jax.random.normal(RNG, (8, 5, 12)),
          "neigh2": jax.random.normal(RNG, (8, 5, 3, 12)),
          "labels": jax.random.randint(RNG, (8,), 0, 5)}
    loss, _ = gnn_lib.minibatch_loss(params, cfg, mb)
    assert np.isfinite(float(loss))
    bg = {"x": jax.random.normal(RNG, (4, 30, 12)),
          "edge_src": jax.random.randint(RNG, (4, 64), 0, 30),
          "edge_dst": jax.random.randint(RNG, (4, 64), 0, 30),
          "node_mask": jnp.ones((4, 30)),
          "labels": jax.random.randint(RNG, (4,), 0, 5)}
    loss, _ = gnn_lib.batched_graphs_loss(params, cfg, bg)
    assert np.isfinite(float(loss))


# -------------------------------------------------------------- recsys -----
RECSYS_REDUCED = {
    "wide-deep": RecsysConfig(
        name="wide-deep", interaction="concat", n_sparse=6, embed_dim=8,
        mlp_dims=(32, 16), n_dense=4, vocab_sizes=(256,) * 6, multi_hot=2),
    "xdeepfm": RecsysConfig(
        name="xdeepfm", interaction="cin", n_sparse=6, embed_dim=8,
        mlp_dims=(32, 16), n_dense=4, vocab_sizes=(256,) * 6,
        cin_dims=(12, 12, 12)),
    "dien": RecsysConfig(
        name="dien", interaction="augru", embed_dim=8, seq_len=12,
        gru_dim=16, mlp_dims=(32, 16), n_dense=4, vocab_sizes=(256,)),
    "bert4rec": RecsysConfig(
        name="bert4rec", interaction="bidir-seq", embed_dim=16, n_blocks=2,
        n_heads=2, seq_len=12, n_items=256, vocab_sizes=(256,),
        n_mask=3, n_negatives=7),
}


def _recsys_batch(cfg, b=16):
    r = np.random.RandomState(0)
    if cfg.name in ("wide-deep", "xdeepfm"):
        return {"sparse_ids": jnp.asarray(
                    r.randint(0, 256, (b, cfg.n_sparse, cfg.multi_hot)),
                    jnp.int32),
                "dense": jnp.asarray(r.randn(b, cfg.n_dense), jnp.float32),
                "label": jnp.asarray(r.rand(b) < 0.5, jnp.float32)}
    if cfg.name == "dien":
        return {"hist_ids": jnp.asarray(
                    r.randint(0, 256, (b, cfg.seq_len)), jnp.int32),
                "hist_mask": jnp.ones((b, cfg.seq_len), jnp.float32),
                "target_id": jnp.asarray(r.randint(0, 256, b), jnp.int32),
                "dense": jnp.asarray(r.randn(b, cfg.n_dense), jnp.float32),
                "label": jnp.asarray(r.rand(b) < 0.5, jnp.float32)}
    from repro.data.synthetic import bert4rec_batch
    return {k: jnp.asarray(v) for k, v in bert4rec_batch(
        r, b, cfg.seq_len, cfg.n_items, cfg.n_mask, cfg.n_negatives).items()}


@pytest.mark.parametrize("arch_id", sorted(RECSYS_REDUCED))
def test_recsys_train_step(arch_id):
    cfg = RECSYS_REDUCED[arch_id]
    params, _ = recsys_lib.INIT[cfg.name](RNG, cfg)
    if cfg.name == "bert4rec":
        loss_fn = lambda p, b: recsys_lib.bert4rec_loss(p, cfg, b)
    else:
        fwd = recsys_lib.FORWARD[cfg.name]
        loss_fn = lambda p, b: recsys_lib.ctr_loss(p, cfg, b, fwd)
    opt = make_optimizer("adagrad", lr=1e-2)
    step = make_train_step(loss_fn, opt)
    batch = _recsys_batch(cfg)
    params2, _, metrics = jax.jit(step)(params, opt.init(params), 0, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert _finite(params2)


@pytest.mark.parametrize("arch_id", sorted(RECSYS_REDUCED))
def test_recsys_retrieval(arch_id):
    cfg = RECSYS_REDUCED[arch_id]
    params, _ = recsys_lib.INIT[cfg.name](RNG, cfg)
    batch = _recsys_batch(cfg, b=1)
    user = {k: v for k, v in batch.items()
            if k not in ("label", "mask_pos", "mask_labels", "neg_ids")}
    cand = jnp.arange(50, dtype=jnp.int32)
    scores = recsys_lib.score_candidates(params, cfg, user, cand)
    assert scores.shape == (50,)
    assert _finite(scores)
    # chunked == unchunked
    chunked = recsys_lib.score_candidates(params, cfg, user, cand, chunks=5)
    np.testing.assert_allclose(np.asarray(scores), np.asarray(chunked),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- DLRM -----
def test_dlrm_smoke():
    cfg = DLRMConfig(name="dlrm-r", n_sparse=6, n_dense=4, embed_dim=8,
                     vocab_sizes=(256,) * 6, bottom_mlp=(16, 8),
                     top_mlp=(32, 16, 1))
    params, _ = dlrm_lib.init_params(RNG, cfg)
    r = np.random.RandomState(0)
    batch = {"sparse_ids": jnp.asarray(r.randint(0, 256, (16, 6, 1)),
                                       jnp.int32),
             "dense": jnp.asarray(r.randn(16, 4), jnp.float32),
             "label": jnp.asarray(r.rand(16) < 0.3, jnp.float32)}
    opt = make_optimizer("adagrad", lr=1e-2)
    step = make_train_step(lambda p, b: dlrm_lib.loss_fn(p, cfg, b), opt)
    params2, _, metrics = jax.jit(step)(params, opt.init(params), 0, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert _finite(params2)
