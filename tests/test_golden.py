"""Golden-file + determinism guards.

PR 1's DAG refactor claimed the fig5 criteo/custom JSON stayed
bit-identical but verified it only by hand; these tests make the claim
enforceable. The committed snapshots under tests/golden/ are the exact
bytes fig5_static wrote before the fleet plane landed — any change to
the simulator, the baselines, the agent, or the benchmark protocol that
moves a single float fails here.

Byte-identity holds because the whole stack is seeded (numpy RandomState
+ jax PRNGKey everywhere) and agent pretraining is reproducible: a fresh
`pretrain(5, ...)` regenerates the cached npz weights exactly, so the
check is stable even on a machine with a cold agent cache (CI).
"""
import os
import sys
from pathlib import Path

import numpy as np
import pytest

REPO = Path(__file__).resolve().parents[1]
GOLDEN = Path(__file__).resolve().parent / "golden"
sys.path.insert(0, str(REPO))

from repro.data.fleet import FleetSim, demo_cluster          # noqa: E402
from repro.data.pipeline import criteo_pipeline              # noqa: E402
from repro.data.simulator import (Allocation, MachineSpec,   # noqa: E402
                                  PipelineSim)


# ------------------------------------------------------------- golden ------
@pytest.mark.parametrize("pipeline", ["criteo", "custom"])
def test_fig5_matches_golden_snapshot(pipeline):
    from benchmarks import common, fig5_static
    fig5_static.run(pipeline, quiet=True)
    out = Path(common.OUT_DIR) / f"fig5_{pipeline}.json"
    golden = GOLDEN / f"fig5_{pipeline}.json"
    assert out.read_bytes() == golden.read_bytes(), \
        f"fig5_{pipeline}.json drifted from the committed golden snapshot"


# -------------------------------------------------------- determinism ------
def _pipeline_trace(seed: int):
    spec = criteo_pipeline()
    sim = PipelineSim(spec, MachineSpec(n_cpus=64, mem_mb=16384), seed=seed)
    rng = np.random.RandomState(seed)
    trace = []
    for t in range(50):
        alloc = Allocation(rng.randint(1, 16, size=spec.n_stages),
                           prefetch_mb=float(rng.randint(1, 40) * 64))
        m = sim.apply(alloc)
        lat = sim.measured_latencies(alloc)
        trace.append((m["throughput"], m["mem_mb"], m["oom"], tuple(lat)))
    return trace, sim.oom_count


def test_pipeline_sim_same_seed_is_exactly_reproducible():
    a, ooms_a = _pipeline_trace(7)
    b, ooms_b = _pipeline_trace(7)
    assert a == b and ooms_a == ooms_b
    c, _ = _pipeline_trace(8)
    assert a != c          # the seed actually feeds the noise stream


def _fleet_trace(seed: int):
    from repro.core import baselines as B
    cluster = demo_cluster(120)
    sim = FleetSim(cluster, seed=seed)
    opt_alloc = None
    trace = []
    for t in range(120):
        state = sim.machine
        # static policy, re-proposed on churn: deterministic driver
        if opt_alloc is None or state.key() != opt_alloc[0]:
            opt_alloc = (state.key(), B.fleet_even(cluster, state, seed))
        m = sim.apply(opt_alloc[1])
        trace.append((m["throughput"], m["mem_mb"], m["n_active"],
                      m["oom"]))
    return trace, sim.oom_count


def test_fleet_sim_same_seed_is_exactly_reproducible():
    a, ooms_a = _fleet_trace(3)
    b, ooms_b = _fleet_trace(3)
    assert a == b and ooms_a == ooms_b


# ----------------------------------------------- market determinism --------
_MARKET_TRACE_SRC = """\
import sys
sys.path.insert(0, "src")
from repro.core.fleet_coordinator import PoolMarket
from repro.data.fleet import FleetSim, big_cluster

market = big_cluster(32, ticks=60, seed=0)
sim = FleetSim(market, seed=0)
pm = PoolMarket(market, inner="job_oracle", seed=0)
for _ in range(40):
    state = sim.machine
    fa = pm.propose(None, state, None)
    tel = sim.apply(fa)
    pm.observe(tel)
    print(repr((tel["throughput"], tel["mem_mb"], tel["n_active"],
                tel["oom"], sorted(fa.grants.items()))))
"""


def _market_trace(seed: int):
    from repro.core.fleet_coordinator import PoolMarket
    from repro.data.fleet import big_cluster
    market = big_cluster(32, ticks=60, seed=seed)
    sim = FleetSim(market, seed=0)
    pm = PoolMarket(market, inner="job_oracle", seed=0)
    trace = []
    for _ in range(40):
        state = sim.machine
        fa = pm.propose(None, state, None)
        tel = sim.apply(fa)
        pm.observe(tel)
        trace.append((tel["throughput"], tel["mem_mb"], tel["n_active"],
                      tel["oom"], tuple(sorted(fa.grants.items()))))
    return trace


def test_market_sim_same_seed_is_exactly_reproducible():
    """The 32-machine seeded market run (big_cluster + PoolMarket over
    FleetSim, churn and all) is deterministic: the auction's tie-breaks
    are fixed iteration order, not hash/set order."""
    a = _market_trace(0)
    b = _market_trace(0)
    assert a == b
    assert _market_trace(2) != a   # the seed feeds the spec generator


@pytest.mark.slow
def test_market_trace_byte_identical_across_processes():
    """Cross-process byte-identity: two fresh interpreters produce the
    exact same market trace bytes — no PYTHONHASHSEED leakage through
    dict/set iteration anywhere in spec, auction, sim, or churn."""
    import subprocess
    outs = []
    for run in range(2):
        env = dict(os.environ, PYTHONHASHSEED=str(run))  # must not matter
        proc = subprocess.run(
            [sys.executable, "-c", _MARKET_TRACE_SRC], cwd=str(REPO),
            env=env, capture_output=True, timeout=300)
        assert proc.returncode == 0, proc.stderr.decode()
        outs.append(proc.stdout)
    assert outs[0] == outs[1]
    assert len(outs[0].splitlines()) == 40
