"""Optimizer tests: convergence on a quadratic, chunked == unchunked,
adafactor factored-state shapes, logical-axes trees align with state."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import optim
from repro.train.optim import make_optimizer, opt_logical_axes


@pytest.mark.parametrize("name", ["sgd", "adam", "adagrad", "adafactor"])
def test_optimizer_converges_quadratic(name):
    opt = make_optimizer(name, lr=0.1 if name != "adafactor" else 0.3,
                         warmup=1, total_steps=200)
    target = jnp.asarray(np.random.RandomState(0).randn(4, 8), jnp.float32)
    params = {"w": jnp.zeros((4, 8))}
    state = opt.init(params)
    loss = lambda p: jnp.mean((p["w"] - target) ** 2)
    l0 = float(loss(params))
    for step in range(150):
        grads = jax.grad(loss)(params)
        params, state, _ = opt.update(grads, state, params, step)
    assert float(loss(params)) < l0 * 0.1


def test_adam_chunked_matches_unchunked(monkeypatch):
    """Chunked (scan over axis 0) update must equal the direct update."""
    rng = np.random.RandomState(1)
    p = {"w": jnp.asarray(rng.randn(8, 16, 16), jnp.float32)}
    g = {"w": jnp.asarray(rng.randn(8, 16, 16), jnp.float32)}
    opt = make_optimizer("adam", lr=1e-2)
    s = opt.init(p)
    p_direct, s_direct, _ = opt.update(g, s, p, 3)
    monkeypatch.setattr(optim, "_CHUNK_ELEMS", 16)  # force chunking
    p_chunk, s_chunk, _ = opt.update(g, s, p, 3)
    np.testing.assert_allclose(np.asarray(p_direct["w"]),
                               np.asarray(p_chunk["w"]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(s_direct["m"]["w"]),
                               np.asarray(s_chunk["m"]["w"]), rtol=1e-6)


def test_adafactor_factored_state_shapes():
    opt = make_optimizer("adafactor", lr=1e-2)
    params = {"big": jnp.zeros((4, 256, 512)), "small": jnp.zeros((32,))}
    state = opt.init(params)
    assert state["f"]["big"]["vr"].shape == (4, 256)
    assert state["f"]["big"]["vc"].shape == (4, 512)
    assert state["f"]["small"]["v"].shape == (32,)


def test_opt_logical_axes_align():
    params = {"big": jnp.zeros((4, 256, 512)), "small": jnp.zeros((32,))}
    logical = {"big": ("layers", "fsdp", "mlp"), "small": ("mlp",)}
    ax = opt_logical_axes("adafactor", logical, params=params)
    assert ax["f"]["big"]["vr"] == ("layers", "fsdp")
    assert ax["f"]["big"]["vc"] == ("layers", "mlp")
    ax2 = opt_logical_axes("adam", logical)
    assert ax2["m"]["big"] == ("layers", "fsdp", "mlp")


def test_grad_clip():
    g = {"w": jnp.full((10,), 100.0)}
    clipped, gn = optim.clip_by_global_norm(g, 1.0)
    assert float(gn) > 100
    norm = float(jnp.linalg.norm(clipped["w"]))
    assert norm == pytest.approx(1.0, rel=1e-5)
