"""Real featurization work (data/featurize.py): golden hash stability,
pool/pad shape contracts, the FeaturizeWork/SpinWork contract, a live
real-work pipeline delivering model-ready batches, and (slow) the
calibration round-trip on real-featurization stages."""
import multiprocessing as mp
import pickle
from types import SimpleNamespace

import numpy as np
import pytest

from repro.data.calibrate import calibrate_stagegraph
from repro.data.featurize import (FeaturizeWork, RecordSpec, collate,
                                  dense_transform, featurize_block,
                                  featurize_stage_fns, featurize_work_for,
                                  hash_ids, pool_pad, raw_block,
                                  shuffle_block)
from repro.data.pipeline import StageGraph, StageSpec
from repro.data.proc_executor import ProcessPipeline
from repro.data.simulator import MachineSpec

# pinned output of hash_ids(arange(10), 1000) — the hash IS the feature
# space; silently changing it invalidates every trained checkpoint
_GOLDEN_HASH = [0, 472, 576, 60, 105, 529, 58, 417, 211, 609]


# ------------------------------------------------------------- hash_ids --

def test_hash_ids_golden_values():
    got = hash_ids(np.arange(10, dtype=np.int64), 1000)
    assert got.tolist() == _GOLDEN_HASH
    assert got.dtype == np.int32


def test_hash_ids_range_and_determinism():
    raw = np.random.RandomState(3).randint(0, 1 << 31, size=(64, 12, 8),
                                           dtype=np.int64)
    h1, h2 = hash_ids(raw, 1 << 16), hash_ids(raw, 1 << 16)
    np.testing.assert_array_equal(h1, h2)
    assert h1.shape == raw.shape
    assert h1.min() >= 0 and h1.max() < (1 << 16)
    # avalanche: adjacent raw ids should not map to adjacent rows
    seq = hash_ids(np.arange(1000, dtype=np.int64), 1 << 16)
    assert np.abs(np.diff(seq.astype(np.int64))).mean() > 1000


def _child_hash(q):
    from repro.data.featurize import hash_ids as h
    q.put(h(np.arange(10, dtype=np.int64), 1000).tolist())


def test_hash_ids_stable_across_processes():
    """The hash reads no interpreter/RNG state: a spawned child (fresh
    interpreter, fresh seeds) must produce the same golden rows."""
    ctx = mp.get_context("spawn")
    # lint: allow[mp-queue-protocol] -- one-shot child, q.get(timeout=30) then join below is the whole lifecycle
    q = ctx.Queue()
    p = ctx.Process(target=_child_hash, args=(q,))
    p.start()
    try:
        got = q.get(timeout=30)
    finally:
        p.join(10)
    assert got == _GOLDEN_HASH


# ------------------------------------------------------------- pool_pad --

def test_pool_pad_truncates_long_lists():
    ids = np.arange(8, dtype=np.int32)[None, :]          # k=8 > hot=4
    out = pool_pad(ids, np.array([8]), hot=4)
    np.testing.assert_array_equal(out, [[0, 1, 2, 3]])


def test_pool_pad_pads_short_lists_with_head():
    ids = np.array([[5, 9]], dtype=np.int32)             # k=2 < hot=4
    out = pool_pad(ids, np.array([2]), hot=4)
    np.testing.assert_array_equal(out, [[5, 9, 5, 5]])


def test_pool_pad_masks_beyond_valid_length():
    """lengths < k: slots past the valid prefix are replaced by the head
    id even though raw values are present there."""
    ids = np.array([[5, 9, 7, 3]], dtype=np.int32)
    out = pool_pad(ids, np.array([2]), hot=4)
    np.testing.assert_array_equal(out, [[5, 9, 5, 5]])


def test_pool_pad_batched_shape_contract():
    rng = np.random.RandomState(0)
    ids = rng.randint(0, 100, size=(16, 12, 8)).astype(np.int32)
    lengths = rng.randint(1, 9, size=(16, 12))
    out = pool_pad(ids, lengths, hot=4)
    assert out.shape == (16, 12, 4) and out.dtype == np.int32
    # every emitted id was present in the source list (no invented rows)
    assert np.isin(out, ids).all()


# ---------------------------------------------- block transforms / shapes --

def test_featurize_block_shape_contract():
    rs = RecordSpec(batch=32)
    blk = featurize_block(raw_block(np.random.RandomState(0), rs), rs)
    assert blk["sparse_ids"].shape == (32, rs.n_sparse, rs.hot)
    assert blk["sparse_ids"].dtype == np.int32
    assert blk["dense"].shape == (32, rs.n_dense)
    assert blk["dense"].dtype == np.float32
    assert blk["label"].shape == (32,)
    assert set(np.unique(blk["label"])) <= {0.0, 1.0}
    assert blk["sparse_ids"].min() >= 0
    assert blk["sparse_ids"].max() < rs.vocab


def test_dense_transform_standardizes():
    d = dense_transform(np.random.RandomState(0).lognormal(size=(512, 13)))
    np.testing.assert_allclose(d.mean(0), 0.0, atol=1e-4)
    np.testing.assert_allclose(d.std(0), 1.0, atol=1e-2)


def test_shuffle_rows_move_together():
    rs = RecordSpec(batch=64)
    blk = raw_block(np.random.RandomState(1), rs)
    tagged = dict(blk, tag=np.arange(64))
    shuf = shuffle_block(tagged, np.random.RandomState(2))
    perm = shuf["tag"]
    assert not np.array_equal(perm, np.arange(64))
    np.testing.assert_array_equal(shuf["label"], blk["label"][perm])
    np.testing.assert_array_equal(shuf["raw_ids"], blk["raw_ids"][perm])


def test_collate_contiguous_same_values():
    rs = RecordSpec(batch=16)
    blk = featurize_block(raw_block(np.random.RandomState(0), rs), rs)
    strided = {k: v[::1] if v.ndim == 1 else np.asarray(v, order="F")
               for k, v in blk.items()}
    out = collate(strided)
    for k in blk:
        assert out[k].flags["C_CONTIGUOUS"]
        np.testing.assert_array_equal(out[k], blk[k])


# ------------------------------------------------ FeaturizeWork contract --

def test_featurize_work_picklable_and_produces():
    w = featurize_work_for(
        StageSpec("udf", "udf", cost=0.0, serial_frac=0.0,
                  mem_per_worker_mb=0.0, inputs=("src",)),
        ballast=False, record=RecordSpec(batch=8))
    w2 = pickle.loads(pickle.dumps(w))
    assert (w2.role, w2.kind, w2.cost) == ("featurize", "map", 0.0)
    w2.bind(serial_lock=None, nworkers=SimpleNamespace(value=1))
    rs = RecordSpec(batch=8)
    out = w2(raw_block(np.random.RandomState(0), rs))
    assert out["sparse_ids"].shape == (8, rs.n_sparse, rs.hot)


def test_featurize_work_roles_follow_stage_kind():
    stages = [StageSpec("a", "source", cost=0.0, serial_frac=0.0,
                        mem_per_worker_mb=0.0),
              StageSpec("b", "shuffle", cost=0.0, serial_frac=0.0,
                        mem_per_worker_mb=0.0, inputs=("a",)),
              StageSpec("c", "batch", cost=0.0, serial_frac=0.0,
                        mem_per_worker_mb=0.0, inputs=("b",))]
    fns = featurize_stage_fns(StageGraph("g", tuple(stages), batch_mb=1.0),
                              ballast=False)
    assert fns["a"].role == "load" and fns["a"].kind == "source"
    assert fns["b"].role == "shuffle" and fns["b"].kind == "map"
    assert fns["c"].role == "collate"


def test_featurize_work_standalone_input_cached():
    """Calibration isolates each stage as a source: the synthesized
    upstream block must be generated once (upstream cost must not leak
    into the stage's measured curve)."""
    w = FeaturizeWork("featurize", cost=0.0, kind="source",
                      record=RecordSpec(batch=8))
    w.bind(serial_lock=None, nworkers=SimpleNamespace(value=1))
    assert w._standalone_input() is w._standalone_input()
    out = w()
    assert out["sparse_ids"].shape == (8, 12, 4)


# ------------------------------------------------- live real-work pipeline --

def test_real_pipeline_delivers_model_ready_batches():
    """ProcessPipeline over featurize fns: get_batch() hands back the
    exact batch shapes the DLRM train step consumes."""
    rs = RecordSpec(batch=32)
    spec = StageGraph("feed3", (
        StageSpec("src", "source", cost=0.001, serial_frac=0.0,
                  mem_per_worker_mb=2.0),
        StageSpec("udf", "udf", cost=0.001, serial_frac=0.0,
                  mem_per_worker_mb=2.0, inputs=("src",)),
        StageSpec("bat", "batch", cost=0.001, serial_frac=0.0,
                  mem_per_worker_mb=2.0, inputs=("udf",)),
    ), batch_mb=1.0)
    pipe = ProcessPipeline(
        spec, fns=featurize_stage_fns(spec, ballast=False, record=rs),
        machine=MachineSpec(n_cpus=2, mem_mb=2048.0), queue_depth=4)
    try:
        batches = [pipe.get_batch(timeout=30.0) for _ in range(3)]
    finally:
        summary = pipe.shutdown(drain=False, timeout=15.0)
    for b in batches:
        assert b["sparse_ids"].shape == (32, rs.n_sparse, rs.hot)
        assert b["dense"].shape == (32, rs.n_dense)
        assert b["label"].shape == (32,)
        assert b["sparse_ids"].flags["C_CONTIGUOUS"]
    # sibling workers draw distinct records: consecutive batches differ
    assert not np.array_equal(batches[0]["label"], batches[1]["label"])
    assert summary["joined"], summary


# -------------------------------------------- calibration on real work --

@pytest.mark.slow
def test_calibration_recovers_serial_frac_on_real_work():
    """ISSUE 6 acceptance: the Amdahl fit holds when the burned cycles
    are real featurization (quantum-based burns), not spin — designed
    serial_frac recovered within the existing 20% bar."""
    spec = StageGraph("calreal", (
        StageSpec("src", "source", cost=0.05, serial_frac=0.0,
                  mem_per_worker_mb=4.0),
        StageSpec("udf", "udf", cost=0.10, serial_frac=0.4,
                  mem_per_worker_mb=4.0, inputs=("src",)),
    ), batch_mb=1.0, work="real")
    cal, report = calibrate_stagegraph(spec, workers=(1, 2, 3),
                                       window_s=2.0)
    udf = report["udf"]
    assert abs(udf["serial_frac"] - 0.4) <= 0.2 * 0.4 + 0.08, report
    assert abs(udf["cost"] - 0.10) <= 0.03, report
    assert report["src"]["serial_frac"] <= 0.15, report
    assert getattr(cal, "work", None) == "real"
