"""Property-based tests (hypothesis) of StageGraph/simulator invariants.

Every property is a deterministic function of one integer seed (the graph
generator and allocations derive from np.random.RandomState(seed)), so
hypothesis gets perfectly reproducible examples and shrinking works on
the seed alone."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.data.pipeline import (StageGraph, StageSpec, make_pipeline,
                                 stage_throughput)
from repro.data.simulator import Allocation, MachineSpec, PipelineSim

SEEDS = st.integers(0, 10_000)


def random_stage_graph(seed: int) -> StageGraph:
    """Random valid DAG: edges only run forward (acyclic by construction),
    middle stages consume a random predecessor subset (an empty subset
    makes an extra source), and the last stage consumes every dangling
    output so there is exactly one sink."""
    rng = np.random.RandomState(seed)
    n = int(rng.randint(3, 9))
    names = [f"s{i}" for i in range(n)]
    stages = []
    for i in range(n):
        if i == 0:
            inputs = ()
        elif i < n - 1:
            k = int(rng.randint(0, min(i, 3) + 1))
            picks = rng.choice(i, size=k, replace=False)
            inputs = tuple(names[j] for j in sorted(picks))
        else:
            consumed = {p for s in stages for p in s.inputs}
            dangling = [names[j] for j in range(n - 1)
                        if names[j] not in consumed]
            inputs = tuple(dangling) if dangling else (names[n - 2],)
        kind = "source" if not inputs else (
            "prefetch" if i == n - 1 else "udf")
        stages.append(StageSpec(
            names[i], kind, cost=float(rng.uniform(0.05, 0.5)),
            serial_frac=float(rng.uniform(0.0, 0.3)),
            mem_per_worker_mb=float(rng.uniform(16, 128)),
            inputs=inputs))
    return StageGraph(f"rand_dag_{seed}", tuple(stages),
                      batch_mb=float(rng.choice([128.0, 256.0])),
                      edge_buffer_mb=float(rng.choice([0.0, 16.0, 32.0])))


@settings(max_examples=60, deadline=None)
@given(seed=SEEDS)
def test_topo_order_is_valid_linearization(seed):
    g = random_stage_graph(seed)
    assert sorted(g.topo_order) == list(range(g.n_stages))
    pos = {i: k for k, i in enumerate(g.topo_order)}
    for producer, consumer in g.edges:
        assert pos[producer] < pos[consumer]
    # and the declared sink really is the unique stage nothing consumes
    consumed = {p for p, _ in g.edges}
    assert [i for i in range(g.n_stages) if i not in consumed] == [g.sink]


@settings(max_examples=60, deadline=None)
@given(seed=SEEDS)
def test_sustained_rates_bounded_by_service_rates(seed):
    g = random_stage_graph(seed)
    rng = np.random.RandomState(seed + 1)
    sim = PipelineSim(g, MachineSpec())
    alloc = Allocation(rng.randint(1, 24, size=g.n_stages))
    assert np.all(sim.sustained_rates(alloc)
                  <= sim.stage_rates(alloc) + 1e-9)
    # the sink's sustained rate is the graph throughput (no model demand)
    assert sim.throughput(alloc) == pytest.approx(
        sim.sustained_rates(alloc)[g.sink])


@settings(max_examples=60, deadline=None)
@given(seed=SEEDS)
def test_linear_chain_reduces_to_min_bottleneck(seed):
    rng = np.random.RandomState(seed)
    spec = make_pipeline(int(rng.randint(3, 8)), seed=seed)
    assert spec.is_linear
    sim = PipelineSim(spec, MachineSpec())
    alloc = Allocation(rng.randint(1, 40, size=spec.n_stages))
    rates = [stage_throughput(s, int(w))
             for s, w in zip(spec.stages, alloc.workers)]
    assert sim.throughput(alloc) == pytest.approx(min(rates))


@settings(max_examples=40, deadline=None)
@given(seed=SEEDS)
def test_best_allocation_respects_memory_cap(seed):
    g = random_stage_graph(seed)
    rng = np.random.RandomState(seed + 2)
    machine = MachineSpec(n_cpus=int(rng.choice([16, 32, 64, 128])),
                          mem_mb=float(rng.choice([16384, 32768, 65536])))
    sim = PipelineSim(g, machine)
    alloc, tput = sim.best_allocation()
    assert sim.memory_used(alloc) <= machine.mem_mb
    assert alloc.workers.sum() >= g.n_stages       # one worker everywhere
    assert tput == pytest.approx(sim.throughput(alloc))


@settings(max_examples=40, deadline=None)
@given(seed=SEEDS)
def test_oracle_monotone_in_cpus(seed):
    """Adding a CPU to the oracle never lowers throughput (water-filling
    on concave per-stage rates is monotone in the budget)."""
    g = random_stage_graph(seed)
    rng = np.random.RandomState(seed + 3)
    machine = MachineSpec(n_cpus=128,
                          mem_mb=float(rng.choice([16384, 65536])))
    model_lat = float(rng.choice([0.0, 0.0, 0.05]))
    sim = PipelineSim(g, machine, model_lat)
    n = int(rng.randint(g.n_stages, 96))
    _, t_n = sim.best_allocation(n_cpus=n)
    _, t_n1 = sim.best_allocation(n_cpus=n + 1)
    assert t_n1 >= t_n - 1e-9
