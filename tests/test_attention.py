"""Flash attention (custom_vjp) vs a naive materialized-softmax oracle —
property-based over shapes, GQA groups, windows, softcaps, chunk sizes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.layers import chunked_attention, decode_attention, softcap


def naive_attention(q, k, v, window, cap, causal=True):
    b, s, h, hd = q.shape
    n_kv = k.shape[2]
    g = h // n_kv
    qg = q.reshape(b, s, n_kv, g, hd)
    sc = jnp.einsum("bskgh,btkh->bkgst", qg, k,
                    preferred_element_type=jnp.float32) * hd ** -0.5
    sc = softcap(sc, cap)
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    valid = jnp.ones((s, s), bool)
    if causal:
        valid = valid & (j <= i)
    if window:
        valid = valid & (i - j < window)
    sc = jnp.where(valid[None, None, None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgst,btkh->bskgh", p, v.astype(p.dtype))
    return o.reshape(b, s, h, hd).astype(q.dtype)


@settings(max_examples=25, deadline=None)
@given(
    s=st.sampled_from([8, 17, 32, 48]),
    n_kv=st.sampled_from([1, 2, 3]),
    g=st.sampled_from([1, 2, 4]),
    hd=st.sampled_from([8, 16]),
    window=st.sampled_from([0, 4, 9]),
    cap=st.sampled_from([0.0, 30.0]),
    chunk=st.sampled_from([4, 7, 16, 64]),
)
def test_flash_matches_naive(s, n_kv, g, hd, window, cap, chunk):
    rng = np.random.RandomState(abs(hash((s, n_kv, g, hd))) % (1 << 31))
    b, h = 2, n_kv * g
    q = jnp.asarray(rng.randn(b, s, h, hd), jnp.float32)
    k = jnp.asarray(rng.randn(b, s, n_kv, hd), jnp.float32)
    v = jnp.asarray(rng.randn(b, s, n_kv, hd), jnp.float32)
    out = chunked_attention(q, k, v, window=window, logit_cap=cap,
                            chunk=chunk)
    exp = naive_attention(q, k, v, window, cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(
    s=st.sampled_from([16, 33]),
    window=st.sampled_from([0, 5]),
    cap=st.sampled_from([0.0, 50.0]),
    chunk=st.sampled_from([8, 16]),
)
def test_flash_gradients_match_naive(s, window, cap, chunk):
    rng = np.random.RandomState(s * 7 + chunk)
    b, n_kv, g, hd = 2, 2, 2, 8
    h = n_kv * g
    q = jnp.asarray(rng.randn(b, s, h, hd), jnp.float32)
    k = jnp.asarray(rng.randn(b, s, n_kv, hd), jnp.float32)
    v = jnp.asarray(rng.randn(b, s, n_kv, hd), jnp.float32)

    def f(fn):
        return lambda q, k, v: jnp.sum(
            jnp.sin(fn(q, k, v)) * jnp.cos(jnp.arange(hd)))
    g1 = jax.grad(f(lambda q, k, v: chunked_attention(
        q, k, v, window=window, logit_cap=cap, chunk=chunk)),
        argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f(lambda q, k, v: naive_attention(
        q, k, v, window, cap)), argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=3e-4, atol=3e-4)


def test_decode_matches_naive_row():
    rng = np.random.RandomState(3)
    b, s, n_kv, g, hd = 2, 24, 2, 3, 8
    h = n_kv * g
    q = jnp.asarray(rng.randn(b, 1, h, hd), jnp.float32)
    k = jnp.asarray(rng.randn(b, s, n_kv, hd), jnp.float32)
    v = jnp.asarray(rng.randn(b, s, n_kv, hd), jnp.float32)
    for pos, window in [(5, 0), (20, 7), (23, 0)]:
        out = decode_attention(q, k, v, pos=pos, window=window)
        # build the equivalent full-seq naive row
        qf = jnp.zeros((b, s, h, hd)).at[:, pos].set(q[:, 0])
        exp = naive_attention(qf, k, v, window, 0.0)[:, pos:pos + 1]
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=2e-4, atol=2e-4)
