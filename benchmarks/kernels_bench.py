"""Kernel micro-benchmarks: wall-clock of the Pallas path (interpret on
CPU — correctness-representative, not TPU-speed) vs the jnp reference,
plus the analytic VMEM working-set per tile (the number that matters for
the TPU target)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.kernels import ops, ref


def _time(fn, *args, iters=3):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.tree_util.tree_map(lambda x: x.block_until_ready(), out)
    return (time.perf_counter() - t0) / iters * 1e6   # us


def run(quiet: bool = False) -> list:
    rng = np.random.RandomState(0)
    rows = []

    table = jnp.asarray(rng.randn(1 << 14, 128), jnp.float32)
    ids = jnp.asarray(rng.randint(0, 1 << 14, (256, 4)), jnp.int32)
    base_us = _time(
        lambda t, i: ops.embedding_bag(t, i, interpret=True), table, ids)
    fused_us = _time(
        lambda t, i: ops.embedding_bag_fused(t, i, interpret=True),
        table, ids)
    rows.append({
        "kernel": "embedding_bag", "shape": "16k x 128, B=256 bag=4",
        "ref_us": _time(lambda t, i: ref.embedding_bag_ref(t, i), table, ids),
        "pallas_interpret_us": base_us,
        # the landed perf variant (embedding_bag_fused): grid (B,) with a
        # resident table + in-kernel bag gather vs the baseline's
        # (B, bag) row-DMA grid — bag x fewer grid steps, bit-identical
        "pallas_fused_interpret_us": fused_us,
        "fused_speedup_x": base_us / max(fused_us, 1e-9),
        "fused_grid_steps": 256,
        "base_grid_steps": 256 * 4,
        "vmem_tile_kib": (1 * 128 * 4 + 1 * 128 * 4) / 1024,
        # the fused variant's VMEM design point is the whole table
        "vmem_fused_table_kib": (1 << 14) * 128 * 4 / 1024,
    })

    feats = jnp.asarray(rng.randn(512, 27, 128), jnp.float32)
    rows.append({
        "kernel": "dot_interact", "shape": "B=512 F=27 D=128",
        "ref_us": _time(ref.dot_interact_ref, feats),
        "pallas_interpret_us": _time(
            lambda f: ops.dot_interact(f, tile_b=128, interpret=True), feats),
        "vmem_tile_kib": (128 * 27 * 128 * 4 + 729 * 351 * 4) / 1024,
    })

    neigh = jnp.asarray(rng.randn(1024, 15, 602), jnp.float32)
    w = jnp.asarray(rng.randn(602, 128) * 0.04, jnp.float32)
    rows.append({
        "kernel": "sage_aggregate", "shape": "B=1024 F=15 D=602 H=128",
        "ref_us": _time(ref.sage_aggregate_ref, neigh, w),
        "pallas_interpret_us": _time(
            lambda n, w: ops.sage_aggregate(n, w, tile_b=128,
                                            interpret=True), neigh, w),
        "vmem_tile_kib": (128 * 15 * 602 * 4 + 602 * 128 * 4) / 1024,
    })

    if not quiet:
        print("\n== Pallas kernels (interpret-mode timing is NOT TPU "
              "speed; VMEM tile col is the TPU design point) ==")
        for r in rows:
            fused = (f"  fused {r['pallas_fused_interpret_us']:7.0f}us "
                     f"({r['fused_speedup_x']:.0f}x)"
                     if "pallas_fused_interpret_us" in r else "")
            print(f"  {r['kernel']:16s} {r['shape']:28s} "
                  f"ref {r['ref_us']:9.0f}us  "
                  f"interp {r['pallas_interpret_us']:9.0f}us  "
                  f"tile {r['vmem_tile_kib']:7.0f} KiB{fused}")
    common.save_json("kernels.json", rows)
    return rows


if __name__ == "__main__":
    run()
