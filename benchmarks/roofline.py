"""§Roofline: per (arch x shape) three-term roofline from the dry-run.

Reads experiments/dryrun/pod16x16/*.json (single-pod, per assignment),
combines:
  compute term    = loop-aware HLO dot-FLOPs / (chips x 197 TFLOP/s)
                    (cost_analysis counts while bodies once — documented;
                    both numbers are reported)
  memory term     = analytic per-device HBM traffic / 819 GB/s
  collective term = loop-aware per-device collective bytes / 50 GB/s ICI
plus MODEL_FLOPS (6·N_active·D convention) and the useful-compute ratio.

Output: printed table + experiments/bench/roofline.json. Also nominates
the three §Perf hillclimb cells (worst roofline fraction, most
collective-bound, most paper-representative).
"""
from __future__ import annotations

import glob
import json
import os

import numpy as np

from benchmarks import analytic, common
from repro.configs import get_arch

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun", "pod16x16")


def load_cells(mesh_dir: str = DRYRUN_DIR) -> list:
    cells = []
    for path in sorted(glob.glob(os.path.join(mesh_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def build_table(mesh_dir: str = DRYRUN_DIR) -> list:
    rows = []
    for rec in load_cells(mesh_dir):
        if rec.get("status") != "ok":
            if rec.get("status") == "skipped":
                rows.append({"arch": rec["arch"], "shape": rec["shape"],
                             "status": "skipped",
                             "reason": rec.get("reason", "")})
            continue
        arch = get_arch(rec["arch"])
        chips = rec["n_devices"]
        cell = analytic.model_cell(arch, rec["shape"], chips)
        hlo_flops = rec["loop_aware"]["dot_flops"]
        coll = rec["loop_aware"]["collective_bytes"]
        terms = analytic.roofline_terms(
            cell["model_flops"], hlo_flops, cell["mem_bytes_per_dev"],
            coll, chips)
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "status": "ok",
            "kind": rec["kind"], "chips": chips,
            "hlo_dot_flops_per_dev": hlo_flops,
            "cost_analysis_flops": rec["cost_analysis"].get("flops", 0.0),
            "mem_bytes_per_dev": cell["mem_bytes_per_dev"],
            "coll_bytes_per_dev": coll,
            "args_gib_per_dev":
                rec["memory_analysis"]["argument_size_in_bytes"] / 2 ** 30,
            "temp_gib_per_dev":
                rec["memory_analysis"]["temp_size_in_bytes"] / 2 ** 30,
            **terms,
        })
    return rows


def pick_hillclimb_cells(rows: list) -> dict:
    ok = [r for r in rows if r["status"] == "ok"
          and r["kind"] == "train"]     # training cells drive the fleet
    # most representative of the paper: its own Criteo DLRM training cell
    rep = next(r for r in ok if r["arch"] == "dlrm-criteo"
               and r["shape"] == "train_batch")
    rest = [r for r in ok if r is not rep]
    worst = min(rest, key=lambda r: r["roofline_fraction"])
    rest2 = [r for r in rest if r is not worst]
    coll_bound = max(rest2, key=lambda r: r["collective_s"]
                     / max(r["compute_s"], 1e-12))
    return {"worst_fraction": f"{worst['arch']}/{worst['shape']}",
            "most_collective_bound":
                f"{coll_bound['arch']}/{coll_bound['shape']}",
            "paper_representative": f"{rep['arch']}/{rep['shape']}"}


def run(quiet: bool = False) -> list:
    rows = build_table()
    if not rows:
        print("no dry-run artifacts found — run "
              "`python -m repro.launch.dryrun --mesh single` first")
        return rows
    if not quiet:
        hdr = (f"{'arch/shape':38s} {'dom':10s} {'compute_s':>10s} "
               f"{'memory_s':>10s} {'coll_s':>10s} {'useful':>7s} "
               f"{'roofline':>8s}")
        print("\n== §Roofline (single pod, 256 chips) ==")
        print(hdr)
        for r in rows:
            if r["status"] != "ok":
                print(f"{r['arch'] + '/' + r['shape']:38s} SKIPPED "
                      f"({r['reason'][:60]})")
                continue
            print(f"{r['arch'] + '/' + r['shape']:38s} "
                  f"{r['dominant']:10s} {r['compute_s']:10.2e} "
                  f"{r['memory_s']:10.2e} {r['collective_s']:10.2e} "
                  f"{r['useful_ratio']:7.2f} "
                  f"{r['roofline_fraction']:8.3f}")
        picks = pick_hillclimb_cells(rows)
        print("\n§Perf hillclimb cells:", picks)
    common.save_json("roofline.json", rows)
    return rows


if __name__ == "__main__":
    run()
