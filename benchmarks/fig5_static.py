"""Fig. 5(A)/(B): percent-of-target throughput per approach + OOM rates.

Paper values for the case-study pipeline: unoptimized 11%, AUTOTUNE 31%
(2.81x over unoptimized), human-set 41%; AUTOTUNE OOM rate ~8% (Fig 5B).
We report our simulator's numbers for the same protocol (static full
machine, 128 CPUs) and the InTune steady state. Beyond the paper's two
linear chains, the same protocol runs on the multi-source DLRM join DAG
(Zhao et al.'s DSI shape) — every policy goes through the unified
Optimizer interface, so nothing here knows linear from DAG.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.api import ControllerBackend, Session, SimBackend
from repro.core import baselines as B
from repro.core.optimizer import make_optimizer
from repro.data.pipeline import (criteo_pipeline, custom_pipeline,
                                 multisource_dlrm_pipeline)
from repro.data.simulator import MachineSpec, PipelineSim

SPECS = {
    "criteo": criteo_pipeline,
    "custom": custom_pipeline,
    "multisource": multisource_dlrm_pipeline,
}


def run(pipeline: str = "criteo", ticks: int = 600, seeds: int = 50,
        quiet: bool = False) -> dict:
    spec = SPECS[pipeline]()
    machine = MachineSpec(n_cpus=128, mem_mb=65536)
    rows = {}
    for name in B.BASELINES:    # registry order: unopt .. oracle
        tputs, ooms = [], 0
        for s in range(seeds if name in B.SEEDED else 1):
            opt = make_optimizer(name, spec, machine, seed=s)
            sim = PipelineSim(spec, machine)
            m = sim.apply(opt.propose(spec, machine))
            ooms += int(m["oom"])
            tputs.append(m["throughput"])
        rows[name] = {"pct_of_target": float(
            np.mean(tputs) / spec.target_rate * 100),
            "oom_rate_pct": 100.0 * ooms / len(tputs)}
    # both planes drive through repro.api.Session now. Linear chains keep
    # the self-driving paper protocol (ControllerBackend clocks
    # tuner.tick(); the tuner's env sim is authoritative) so the published
    # numbers stay byte-identical; DAGs run the unified propose -> apply ->
    # observe path (SimBackend authoritative + serve-best restarts).
    tuner = common.make_tuner(spec, machine, seed=0)
    if spec.is_linear:
        res = Session(ControllerBackend(tuner)).run(ticks)
    else:
        res = Session(SimBackend(spec, machine, seed=0), tuner).run(ticks)
    steady = np.mean(res["throughput"][-150:])
    rows["intune"] = {"pct_of_target": float(
        steady / spec.target_rate * 100),
        "oom_rate_pct": 100.0 * (res["oom_count"] > 0)}
    if not quiet:
        print(f"\n== Fig5 static throughput ({pipeline}) "
              f"[paper: unopt 11%, autotune 31%, human 41%] ==")
        for k, v in rows.items():
            print(f"  {k:12s} {v['pct_of_target']:6.1f}% of target   "
                  f"OOM {v['oom_rate_pct']:4.0f}%")
        speedup = rows["intune"]["pct_of_target"] / \
            max(rows["autotune"]["pct_of_target"], 1e-9)
        print(f"  InTune vs AUTOTUNE-like (static): {speedup:.2f}x "
              f"[paper static margin ~1.3x]")
        frac = rows["intune"]["pct_of_target"] / \
            max(rows["oracle"]["pct_of_target"], 1e-9)
        print(f"  InTune reaches {100 * frac:.0f}% of oracle")
    common.save_json(f"fig5_{pipeline}.json", rows)
    return rows


if __name__ == "__main__":
    run("criteo")
    run("custom")
    run("multisource")
