"""Pool market at cluster scale: multi-job arbitration of shared CPU.

Zhao et al.'s DSI setting — many concurrent training jobs drawing on one
ingestion substrate — meets the InTune fleet plane here. The 32-machine
heterogeneous cluster (repro.data.fleet.big_cluster: core-count and
socket-speed skew per Kalamkar et al., three pipeline shapes, varied
model demand, memory-tight stragglers, churn on every axis) is
partitioned into weighted jobs bidding for the shared elastic pool, and
every policy runs through the same Session propose -> apply -> observe
loop:

  fleet_even           every machine gets the same pool share; memory-
                       blind even placement (no job awareness at all)
  market_local_oracle  per-JOB local oracle: even pool split across
                       jobs, perfect water-filling within each — what
                       perfect per-job tuning buys with nobody pricing
                       the pool across jobs
  fleet_oracle         per-trainer greedy marginal-throughput water-
                       filling, ignoring job weights — the throughput
                       reference every policy is scored against
  market_oracle        the weighted cross-job auction + per-machine
                       oracle placement (the market's static reference)
  market               PoolMarket over per-job FleetCoordinators: the
                       auction prices the pool across jobs, one
                       pretrained InTune DQN per trainer tunes each
                       machine, OOM quarantine forces re-auction

Acceptance (ISSUE 8): the coordinator + market ("market") holds >= 90%
of the fleet oracle on the 32-machine multi-job cluster with churn.

The proc arm (`--proc`, included in `--quick`) scores the market against
fleet_even on a small REAL process fleet (ProcFleet: one ProcessPipeline
per trainer, physical CPU contention) — measured batch-counter rates,
zero leaked processes, clean teardown accounting.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.api import FleetSimBackend, Session, tune
from repro.core.optimizer import make_fleet_optimizer
from repro.data.fleet import (ClusterSpec, JobSpec, MarketSpec, TrainerSpec,
                              big_cluster)

STEADY_FRAC = 0.3     # last 30% of the run counts as steady state


def run(ticks: int = 600, n_machines: int = 32, seed: int = 0,
        quiet: bool = False) -> dict:
    market = big_cluster(n_machines, ticks=ticks, seed=seed)
    policies = ["fleet_even", "market_local_oracle", "fleet_oracle",
                "market_oracle", "market"]
    runs, job_tput = {}, {}
    member_job = {t: j.name for j in market.jobs for t in j.trainers}
    for name in policies:
        if name == "market":
            # short per-machine windows: the warm-start anchor is
            # measured (PoolMarket inners), so serve-best never sits
            # below the planner's point and long eps-walks only wander
            opt = common.make_pool_market(market, seed=seed,
                                          finetune_ticks=20)
            dead = 0            # re-tunes live, like the coordinator
        else:
            opt = make_fleet_optimizer(name, cluster=market, seed=seed)
            # ideal references pay nothing; deployable static splits
            # adapt to churn by checkpoint + relaunch
            dead = 0 if name in ("fleet_oracle", "market_oracle") \
                else common.RELAUNCH_TICKS
        per_job: dict = {j.name: 0.0 for j in market.jobs}

        def collect(t, m, per_job=per_job):
            per = m.get("per_trainer")
            if per is None:
                return
            for n, pm in per.items():
                per_job[member_job[n]] += pm["throughput"]

        runs[name] = Session(FleetSimBackend(market, seed=seed), opt).run(
            ticks, relaunch_dead=dead, collect=collect)
        job_tput[name] = {j: v / ticks for j, v in per_job.items()}

    steady_from = int((1 - STEADY_FRAC) * ticks)
    summary = {}
    for name, r in runs.items():
        tp = np.asarray(r["throughput"])
        summary[name] = {
            "mean_tput": float(tp.mean()),
            "steady_tput": float(tp[steady_from:].mean()),
            "oom_count": int(r["oom_count"]),
            "job_tput": job_tput[name],
        }
    oracle = summary["fleet_oracle"]["mean_tput"]
    for name in summary:
        summary[name]["pct_of_oracle"] = float(
            summary[name]["mean_tput"] / oracle * 100)
    summary["_speedups"] = {
        "market_vs_even": float(
            summary["market"]["mean_tput"]
            / max(summary["fleet_even"]["mean_tput"], 1e-9)),
        "market_vs_job_local": float(
            summary["market"]["mean_tput"]
            / max(summary["market_local_oracle"]["mean_tput"], 1e-9)),
    }
    if not quiet:
        print(f"\n== Pool market ({market.name}, {ticks} ticks, "
              f"pool {market.shared_pool}, "
              f"{len(market.jobs)} jobs) ==")
        for name in policies:
            s = summary[name]
            jt = " ".join(f"{j}:{v:6.1f}" for j, v in s["job_tput"].items())
            print(f"  {name:20s} mean {s['mean_tput']:7.2f} b/s "
                  f"({s['pct_of_oracle']:5.1f}% of oracle) | "
                  f"OOMs {s['oom_count']:3d} | per-job {jt}")
        sp = summary["_speedups"]
        print(f"  market vs fleet-even: {sp['market_vs_even']:.2f}x; "
              f"vs per-job local oracle: {sp['market_vs_job_local']:.2f}x")
    return summary


# ---------------------------------------------------------------------------
# Proc arm: the market on a REAL process fleet (measured, not modeled).
# ---------------------------------------------------------------------------

def proc_market(pool: int = 4) -> MarketSpec:
    """Small 3-trainer, 2-job market for the proc arm: spin-work stage
    costs sized so a measurement window catches tens of batches on a
    couple of cores."""
    from repro.data.pipeline import StageGraph, StageSpec

    def pipe(name, work_cost):
        return StageGraph(name, (
            StageSpec("src", "source", cost=0.002, serial_frac=0.0,
                      mem_per_worker_mb=16),
            StageSpec("work", "udf", cost=work_cost, serial_frac=0.0,
                      mem_per_worker_mb=16, inputs=("src",)),
        ), batch_mb=1.0)

    from repro.data.simulator import MachineSpec
    trainers = (
        TrainerSpec("a0", pipe("pa0", 0.02), MachineSpec(2, 4096.0)),
        TrainerSpec("a1", pipe("pa1", 0.03), MachineSpec(2, 4096.0)),
        TrainerSpec("b0", pipe("pb0", 0.02), MachineSpec(2, 4096.0)),
    )
    jobs = (JobSpec("jobA", ("a0", "a1"), weight=2.0, floor=1),
            JobSpec("jobB", ("b0",), weight=1.0))
    return MarketSpec("proc_market3", trainers, shared_pool=pool, jobs=jobs)


def run_proc(ticks: int = 40, window_s: float = 0.2, seed: int = 0,
             quiet: bool = False) -> dict:
    market = proc_market()
    runs = {}
    for name in ("fleet_even", "market"):
        opt = make_fleet_optimizer(name, cluster=market, seed=seed)
        runs[name] = tune(market, optimizer=opt, backend="proc",
                          ticks=ticks, seed=seed,
                          backend_kw={"window_s": window_s,
                                      "ballast": False})
    summary = {}
    for name, r in runs.items():
        tp = np.asarray(r["throughput"])
        summary[name] = {
            "mean_tput": float(tp.mean()),
            "oom_count": int(r["oom_count"]),
            "dropped_batches": int(r["live"]["dropped_batches"]),
            "all_joined": bool(r["live"]["all_joined"]),
        }
    summary["_speedups"] = {
        "market_vs_even": float(
            summary["market"]["mean_tput"]
            / max(summary["fleet_even"]["mean_tput"], 1e-9))}
    if not quiet:
        print(f"\n== Pool market PROC ({market.name}, {ticks} ticks x "
              f"{window_s}s windows, pool {market.shared_pool}) ==")
        for name in ("fleet_even", "market"):
            s = summary[name]
            print(f"  {name:12s} measured {s['mean_tput']:7.1f} b/s | "
                  f"OOMs {s['oom_count']:2d} | dropped "
                  f"{s['dropped_batches']} | joined {s['all_joined']}")
        print(f"  market vs fleet-even (measured): "
              f"{summary['_speedups']['market_vs_even']:.2f}x")
    return summary


def main(quick: bool = False, ticks: int = None, proc: bool = None,
         seed: int = 0) -> dict:
    sim_ticks = ticks or (240 if quick else 600)
    out = {"sim": run(ticks=sim_ticks, seed=seed)}
    if proc or proc is None:
        out["proc"] = run_proc(ticks=20 if quick else 40, seed=seed)
    common.save_json("BENCH_market.json", out)
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="short sim run + short proc arm (CI smoke)")
    ap.add_argument("--ticks", type=int, default=None)
    ap.add_argument("--no-proc", action="store_true",
                    help="skip the measured ProcFleet arm")
    args = ap.parse_args()
    main(quick=args.quick, ticks=args.ticks,
         proc=False if args.no_proc else None)
