"""Fig. 7 at fleet scale: co-tuning a multi-trainer cluster under churn.

The paper's headline (2.29x ingestion throughput, better CPU & GPU
utilization) is a cluster-level outcome: many trainer machines, tuned
per-machine, sharing elastically provisioned CPU. This driver runs the
canonical 4-machine heterogeneous fleet (repro.data.fleet.demo_cluster —
two linear DLRM chains + the multi-source join DAG, 6-64 GB hosts, a
shared elastic pool, and join/shrink/leave churn) under every fleet
policy, all through the same `repro.api.Session` propose -> apply ->
observe loop used for single machines:

  fleet_even / fleet_proportional    static pool splits + memory-blind
                                     per-machine placement; adapt to churn
                                     only by relaunch (dead window)
  fleet_local_oracle                 perfect per-machine tuning, nobody
                                     arbitrates the pool (no coordination)
  fleet_oracle                       true-cost global water-filling — the
                                     reference every policy is scored on
  fleet_intune                       the FleetCoordinator: one pretrained
                                     InTune DQN per trainer + marginal-
                                     throughput pool arbitration, OOM
                                     admission control and quarantine

Acceptance targets (ISSUE 2): coordinator >= 90% of the fleet oracle,
>= 1.3x fleet-even, zero steady-state OOMs.

`--live` (ISSUE 3) swaps the authoritative backend for LiveFleet: the
3-trainer live cluster (repro.data.live_fleet.live_demo_cluster) runs
one REAL ThreadedPipeline per trainer through the same driver loop, and
the coordinator is scored against fleet_even on MEASURED aggregate
throughput under churn — zero coordinator OOMs, zero dropped batches,
every thread joined.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.api import FleetSimBackend, Session, tune
from repro.core.optimizer import make_fleet_optimizer
from repro.data.fleet import demo_cluster

STEADY_FRAC = 0.3     # last 30% of the run counts as steady state


def _collector(store: dict):
    last_active: list = []

    def collect(t, m):
        per = m.get("per_trainer")
        if per is None:
            # fleet-wide relaunch dead window: every machine that was up
            # last tick is down now — charge 0 used CPUs, not "no data",
            # so static policies' utilization pays for their relaunches
            for name in last_active:
                s = store[name]
                s["used"].append(0)
                s["eff"].append(s["eff"][-1])
                s["tput"].append(0.0)
            return
        last_active[:] = list(per)
        for name, pm in per.items():
            s = store.setdefault(name, {"used": [], "eff": [], "tput": [],
                                        "oom_ticks": []})
            # a machine mid OOM-restart is down, not busy
            s["used"].append(0 if pm["restarting"]
                             else min(pm["used_cpus"], pm["eff_cpus"]))
            s["eff"].append(pm["eff_cpus"])
            s["tput"].append(pm["throughput"])
            if pm["oom"]:
                s["oom_ticks"].append(t)
    return collect


def run(ticks: int = 1200, seed: int = 0, quiet: bool = False) -> dict:
    cluster = demo_cluster(ticks)
    runs, per_machine = {}, {}
    policies = ["fleet_even", "fleet_proportional", "fleet_local_oracle",
                "fleet_oracle", "fleet_intune"]
    for name in policies:
        if name == "fleet_intune":
            opt = common.make_fleet_coordinator(cluster, seed=seed)
            dead = 0            # re-tunes live, like single-machine InTune
        else:
            opt = make_fleet_optimizer(name, cluster, seed=seed)
            # the ideal reference pays nothing; real static deployments
            # adapt to churn by checkpoint + relaunch
            dead = 0 if name == "fleet_oracle" else common.RELAUNCH_TICKS
        store: dict = {}
        r = Session(FleetSimBackend(cluster, seed=seed), opt).run(
            ticks, relaunch_dead=dead, collect=_collector(store))
        runs[name] = r
        per_machine[name] = store

    steady_from = int((1 - STEADY_FRAC) * ticks)
    summary = {}
    for name, r in runs.items():
        tp = np.asarray(r["throughput"])
        store = per_machine[name]
        util = {
            m: float(np.sum(s["used"]) / max(np.sum(s["eff"]), 1) * 100)
            for m, s in store.items()}
        ooms_steady = sum(
            1 for s in store.values()
            for t in s["oom_ticks"] if t >= steady_from)
        summary[name] = {
            "mean_tput": float(tp.mean()),
            "steady_tput": float(tp[steady_from:].mean()),
            "cpu_util_pct": util,
            "oom_count": int(r["oom_count"]),
            "ooms_steady": int(ooms_steady),
        }
    oracle = summary["fleet_oracle"]["mean_tput"]
    for name in summary:
        summary[name]["pct_of_oracle"] = float(
            summary[name]["mean_tput"] / oracle * 100)
    summary["_speedups"] = {
        "intune_vs_even": float(summary["fleet_intune"]["mean_tput"]
                                / max(summary["fleet_even"]["mean_tput"],
                                      1e-9)),
        "intune_vs_local_oracle": float(
            summary["fleet_intune"]["mean_tput"]
            / max(summary["fleet_local_oracle"]["mean_tput"], 1e-9)),
    }
    if not quiet:
        print(f"\n== Fig7 fleet ({cluster.name}, {ticks} ticks, "
              f"pool {cluster.shared_pool}) ==")
        for name in policies:
            s = summary[name]
            util = " ".join(f"{m}:{u:3.0f}%"
                            for m, u in s["cpu_util_pct"].items())
            print(f"  {name:20s} mean {s['mean_tput']:6.2f} b/s "
                  f"({s['pct_of_oracle']:5.1f}% of oracle) | "
                  f"OOMs {s['oom_count']:3d} (steady {s['ooms_steady']}) | "
                  f"util {util}")
        sp = summary["_speedups"]
        print(f"  coordinator vs fleet-even: {sp['intune_vs_even']:.2f}x; "
              f"vs uncoordinated per-machine oracle: "
              f"{sp['intune_vs_local_oracle']:.2f}x")
    common.save_json("fig7_fleet.json", {
        "summary": summary,
        "timelines": {k: r["throughput"] for k, r in runs.items()}})
    return summary


def run_live(ticks: int = 160, window_s: float = 0.12, seed: int = 0,
             quiet: bool = False) -> dict:
    """Coordinator vs fleet_even on real executors (LiveFleet backend).

    Scores are MEASURED batch-counter rates, not analytic predictions.
    The relaunch dead window for the static policy is scaled to the
    (shorter) live run so churn adaptation costs stay proportional to
    the sim benchmark's 20/1200.
    """
    from repro.data.live_fleet import live_demo_cluster
    cluster = live_demo_cluster(ticks)
    # same share of the run as the sim benchmark's 20/1200 per relaunch,
    # so the static baseline's churn-adaptation cost is comparable
    dead_ticks = max(2, round(ticks * common.RELAUNCH_TICKS / 1200))
    runs = {}
    for name in ("fleet_even", "fleet_intune"):
        if name == "fleet_intune":
            opt = common.make_fleet_coordinator(cluster, seed=seed,
                                                finetune_ticks=40)
            dead = 0            # re-tunes live, like single-machine InTune
        else:
            opt = make_fleet_optimizer(name, cluster, seed=seed)
            dead = dead_ticks
        runs[name] = tune(cluster, optimizer=opt, backend="live",
                          ticks=ticks, seed=seed, relaunch_dead=dead,
                          backend_kw={"window_s": window_s})

    summary = {}
    for name, r in runs.items():
        tp = np.asarray(r["throughput"])
        summary[name] = {
            "mean_tput": float(tp.mean()),
            "oom_count": int(r["oom_count"]),
            "dropped_batches": int(r["live"]["dropped_batches"]),
            "crash_lost": int(r["live"]["crash_lost"]),
            "all_joined": bool(r["live"]["all_joined"]),
        }
    summary["_speedups"] = {
        "intune_vs_even": float(
            summary["fleet_intune"]["mean_tput"]
            / max(summary["fleet_even"]["mean_tput"], 1e-9))}
    if not quiet:
        print(f"\n== Fig7 fleet LIVE ({cluster.name}, {ticks} ticks x "
              f"{window_s}s windows, pool {cluster.shared_pool}) ==")
        for name in ("fleet_even", "fleet_intune"):
            s = summary[name]
            print(f"  {name:14s} measured {s['mean_tput']:7.1f} b/s | "
                  f"OOMs {s['oom_count']:2d} | dropped "
                  f"{s['dropped_batches']} | joined {s['all_joined']}")
        print(f"  coordinator vs fleet-even (measured): "
              f"{summary['_speedups']['intune_vs_even']:.2f}x")
    common.save_json("fig7_fleet_live.json", {
        "summary": summary,
        "timelines": {k: r["throughput"] for k, r in runs.items()}})
    return summary


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--live", action="store_true",
                    help="score policies on real ThreadedPipeline "
                         "executors (LiveFleet) instead of FleetSim")
    ap.add_argument("--ticks", type=int, default=None)
    args = ap.parse_args()
    if args.live:
        run_live(**({"ticks": args.ticks} if args.ticks else {}))
    else:
        run(**({"ticks": args.ticks} if args.ticks else {}))
