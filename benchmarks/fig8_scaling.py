"""Fig. 8 drilldowns: performance scaling vs (A) pipeline complexity,
(B) CPU count, (C) batch size. All normalized to the AUTOTUNE-like
baseline on the same pipeline, constant model latency 0 (paper §5.2)."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.api import ControllerBackend, Session
from repro.core import baselines as B
from repro.data.pipeline import (PipelineSpec, StageSpec, criteo_pipeline)
from repro.data.simulator import MachineSpec, PipelineSim


def _pipeline_of_complexity(n: int, with_udf: bool,
                            batch_mb: float = 256.0) -> PipelineSpec:
    """3..5-stage pipelines; the UDF appears at n>=5 (paper: complexity is
    adjusted by adding stages, with a spike when UDFs are introduced)."""
    stages = [StageSpec("disk_load", "source", cost=0.30, serial_frac=0.12,
                        est_bias=0.7, mem_per_worker_mb=96)]
    if n >= 4:
        stages.append(StageSpec("shuffle", "shuffle", cost=0.08,
                                serial_frac=0.30, mem_per_worker_mb=48))
    if with_udf and n >= 5:
        stages.append(StageSpec("feature_udf", "udf", cost=0.42,
                                serial_frac=0.15, est_bias=0.15,
                                mem_per_worker_mb=64))
    stages.append(StageSpec("batch", "batch", cost=0.12, serial_frac=0.25,
                            mem_per_worker_mb=32))
    stages.append(StageSpec("prefetch", "prefetch", cost=0.08,
                            serial_frac=0.05, mem_per_worker_mb=16,
                            mem_per_item_mb=batch_mb))
    stages = stages[:n] if len(stages) > n else stages
    return PipelineSpec(f"cx{n}", tuple(stages), batch_mb=batch_mb,
                        target_rate=31.0)


def _autotune_mean(spec, machine, seeds=15):
    t = []
    for s in range(seeds):
        sim = PipelineSim(spec, machine)
        t.append(sim.apply(B.autotune_like(spec, machine, s))["throughput"])
    return float(np.mean(t))


def _intune_steady(spec, machine, ticks=500):
    tuner = common.make_tuner(spec, machine, seed=0)
    r = Session(ControllerBackend(tuner)).run(ticks)
    return float(np.mean(r.throughput[-100:]))


def run(quiet: bool = False) -> dict:
    machine = MachineSpec(n_cpus=128, mem_mb=65536)
    out = {"complexity": [], "cpus": [], "batch": []}

    # (A) pipeline complexity: 3, 4 stages (no UDF) then 5 (UDF appears)
    for n, udf in [(3, False), (4, False), (5, True)]:
        spec = _pipeline_of_complexity(n, with_udf=udf)
        ratio = _intune_steady(spec, machine) / max(
            _autotune_mean(spec, machine), 1e-9)
        out["complexity"].append(
            {"stages": n, "udf": udf, "intune_vs_autotune": ratio})

    # (B) machine size: 8 -> 128 CPUs
    spec = criteo_pipeline()
    for n in (8, 16, 32, 64, 96, 128):
        m = MachineSpec(n_cpus=n, mem_mb=65536)
        ratio = _intune_steady(spec, m) / max(_autotune_mean(spec, m), 1e-9)
        out["cpus"].append({"n_cpus": n, "intune_vs_autotune": ratio})

    # (C) batch size: per-batch cost and memory scale with batch size;
    # report per-SAMPLE throughput (paper: sample throughput maintained)
    base_bs = 24096
    for mult in (0.25, 0.5, 1.0, 2.0, 4.0):
        bs = int(base_bs * mult)
        spec = criteo_pipeline(batch_mb=256.0 * mult)
        spec = spec.replace(stages=tuple(
            s.__class__(**{**s.__dict__, "cost": s.cost * mult})
            for s in spec.stages), target_rate=31.0 / mult)
        intune_sps = _intune_steady(spec, machine) * bs
        auto_sps = _autotune_mean(spec, machine) * bs
        out["batch"].append({"batch_size": bs,
                             "intune_samples_per_s": intune_sps,
                             "autotune_samples_per_s": auto_sps,
                             "ratio": intune_sps / max(auto_sps, 1e-9)})

    if not quiet:
        print("\n== Fig8(A) pipeline complexity (InTune/AUTOTUNE) "
              "[paper: grows with stages, spike at UDF] ==")
        for r in out["complexity"]:
            print(f"  {r['stages']} stages (udf={r['udf']}): "
                  f"{r['intune_vs_autotune']:.2f}x")
        print("== Fig8(B) CPU count [paper: grows then flattens ~1.2x] ==")
        for r in out["cpus"]:
            print(f"  {r['n_cpus']:4d} CPUs: {r['intune_vs_autotune']:.2f}x")
        print("== Fig8(C) batch size [paper: sample tput maintained] ==")
        for r in out["batch"]:
            print(f"  batch {r['batch_size']:6d}: InTune "
                  f"{r['intune_samples_per_s']:9.0f} samp/s "
                  f"({r['ratio']:.2f}x autotune)")
    common.save_json("fig8_scaling.json", out)
    return out


if __name__ == "__main__":
    run()
