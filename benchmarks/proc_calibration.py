"""Sim-predicted vs proc-measured: the measured closure of the loop.

Fig8-style drilldown for the process plane (ISSUE 5), two phases:

  (A) CALIBRATION (repro.data.calibrate): sweep each stage of a
      designed pipeline on real OS processes, fit the Amdahl curve,
      and report designed vs fitted cost/serial_frac per stage — the
      serial-fraction recovery the sleep-based plane cannot do at all.
  (B) RANKING TRANSFER: rank candidate allocations three ways —
      PipelineSim on the DESIGNED spec, PipelineSim on the CALIBRATED
      spec, and measured on the real ProcessPipeline (interleaved
      windows, true CPU contention) — and report whether the analytic
      rankings transfer to measured physics (the paper's sim-to-real
      claim, scored on processes instead of sleep threads).

The two phases use different specs on purpose: calibration wants every
burn portion above the CPU-clock tick guard (slow, heavy stages), while
rank transfer on a small host needs candidates whose total CPU demand
stays near the machine's real capacity — a bottleneck-dominant chain
where the contrast is "waste a worker on the cheap stage" vs "fix the
bottleneck" (see DESIGN.md §9, "measurement design on small hosts").

    PYTHONPATH=src python benchmarks/proc_calibration.py
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.api import make_backend
from repro.data.calibrate import calibrate_stagegraph
from repro.data.pipeline import StageGraph, StageSpec
from repro.data.simulator import Allocation, MachineSpec, PipelineSim


def proc_demo_pipeline() -> StageGraph:
    """Calibration subject: every burn portion >= the CPU-clock tick
    guard, one stage with a real serial fraction (the fit's target),
    UDF-dominant per Fig. 3."""
    stages = (
        StageSpec("src", "source", cost=0.05, serial_frac=0.0,
                  mem_per_worker_mb=16),
        StageSpec("feature_udf", "udf", cost=0.12, serial_frac=0.4,
                  mem_per_worker_mb=24, inputs=("src",)),
        StageSpec("batch", "batch", cost=0.04, serial_frac=0.0,
                  mem_per_worker_mb=16, inputs=("feature_udf",)),
    )
    return StageGraph("proc_demo", stages, batch_mb=1.0)


def ranking_pipeline() -> StageGraph:
    """Rank-transfer subject: bottleneck-dominant, serial-free, cheap
    enough that the winning candidate's CPU demand stays realizable."""
    stages = (
        StageSpec("src", "source", cost=0.005, serial_frac=0.0,
                  mem_per_worker_mb=8),
        StageSpec("feature_udf", "udf", cost=0.06, serial_frac=0.0,
                  mem_per_worker_mb=16, inputs=("src",)),
    )
    return StageGraph("proc_rank", stages, batch_mb=1.0)


CANDIDATES = (
    (1, 1),          # floor
    (2, 1),          # waste on the cheap source
    (1, 2),          # fix the UDF bottleneck
)


def measure_rankings(spec: StageGraph, reps: int = 3,
                     window_s: float = 0.4) -> list:
    """Measured throughput per candidate on a real ProcessPipeline,
    interleaved across repetitions so host-speed drift hits every
    candidate symmetrically."""
    be = make_backend("proc", spec, MachineSpec(n_cpus=8, mem_mb=8192.0),
                      window_s=window_s, ballast=False)
    sums = [0.0] * len(CANDIDATES)
    try:
        time.sleep(1.0)                       # worker spin calibration
        for _ in range(reps):
            for i, w in enumerate(CANDIDATES):
                alloc = Allocation(np.asarray(w, dtype=int),
                                   prefetch_mb=16.0)
                be.apply(alloc)               # settle: resize + warm
                time.sleep(0.5)
                sums[i] += float(np.mean(
                    [be.apply(alloc).throughput for _ in range(2)]))
    finally:
        be.shutdown()
    return [s / reps for s in sums]


def run(quiet: bool = False) -> dict:
    # ---- (A) live calibration: designed vs fitted per stage ----------
    cal_subject = proc_demo_pipeline()
    _, report = calibrate_stagegraph(cal_subject, workers=(1, 2, 3),
                                     window_s=1.5)
    calibration = {
        name: {"designed_cost": r["spec_cost"],
               "fitted_cost": r["cost"],
               "designed_serial_frac": r["spec_serial_frac"],
               "fitted_serial_frac": r["serial_frac"],
               "rates": r["rate"], "percpu": r["percpu"]}
        for name, r in report.items()}

    # ---- (B) rankings: designed sim, calibrated sim, measured proc ---
    rank_spec = ranking_pipeline()
    cal_rank_spec, _ = calibrate_stagegraph(rank_spec, workers=(1, 2),
                                            window_s=1.0)
    big = MachineSpec(n_cpus=64, mem_mb=65536.0)
    predicted = [PipelineSim(rank_spec, big).throughput(
        Allocation(np.asarray(w))) for w in CANDIDATES]
    predicted_cal = [PipelineSim(cal_rank_spec, big).throughput(
        Allocation(np.asarray(w))) for w in CANDIDATES]
    measured = measure_rankings(rank_spec)

    def transfers(pred, meas, tol=1.05):
        """Tie-aware rank transfer: every pair the sim predicts as
        STRICTLY separated (beyond `tol`) must measure in that order;
        predicted ties constrain nothing."""
        return all(meas[i] < meas[j]
                   for i in range(len(pred)) for j in range(len(pred))
                   if pred[i] * tol < pred[j])

    out = {
        "candidates": [list(w) for w in CANDIDATES],
        "predicted_designed": predicted,
        "predicted_calibrated": predicted_cal,
        "measured_proc": measured,
        "rank_match_designed": transfers(predicted, measured),
        "rank_match_calibrated": transfers(predicted_cal, measured),
        "calibration": calibration,
    }
    if not quiet:
        print("== proc calibration: designed vs fitted ==")
        for name, c in calibration.items():
            print(f"  {name:12s} cost {c['designed_cost']:.3f} -> "
                  f"{c['fitted_cost']:.3f}   serial_frac "
                  f"{c['designed_serial_frac']:.2f} -> "
                  f"{c['fitted_serial_frac']:.2f}")
        print("== allocation rankings (sim-predicted vs proc-measured) ==")
        for w, p, pc, m in zip(CANDIDATES, predicted, predicted_cal,
                               measured):
            print(f"  {str(list(w)):8s} sim {p:7.1f}  cal-sim {pc:7.1f}  "
                  f"proc {m:7.1f} b/s")
        print(f"  rankings transfer: designed={out['rank_match_designed']} "
              f"calibrated={out['rank_match_calibrated']}")
    common.save_json("proc_calibration.json", out)
    return out


if __name__ == "__main__":
    run()
