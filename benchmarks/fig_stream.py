"""Fig Stream: flash-crowd re-adaptation on a streaming source (ISSUE 7).

The headline scenario for the streaming plane: a recommendation logging
stream with a diurnal cycle takes a 10x flash crowd mid-run. Three
policies face it, identical except for who places the workers:

  even         `heuristic_even` frozen — provisioned for the mean. When
               the spike lands its capacity sits below the arrival rate
               and it STARVES THE TRAINER for the entire spike window.
  static_best  the sim oracle's placement, frozen. On the sim arm it
               is the plan for the BASE rate: cheapest in the troughs —
               and when the spike lands, un-ingested arrivals accumulate
               as backlog whose buffer memory grows without bound: it
               OOMs, pays the restart dead window, relaunches into the
               same traffic, and crash-loops. On the proc arm it is the
               water-filled plan for the DECLARED machine: the 1-core
               host turns that overplacement into physical contention
               (per-worker cycle cost*(a*s+1-s)) and its measured
               capacity lands BELOW the spike demand.
  intune       `common.make_tuner` + streaming telemetry. It launches
               from the sim plan for the observed base rate (the
               conservative feed-boundary placement) and re-tunes live:
               the staleness trigger (level + progress guard — reopen
               only when stale AND not improving since serving began)
               catches an incumbent that cannot keep up, the
               downward-drift trigger catches the trough and sheds
               workers, and the freshness-aware reward (which charges
               staleness GROWTH, stationary across the spike) crowns an
               allocation that keeps up. On the proc arm proposals are
               held for 2 windows (`HeldTuner`) so a live resize's own
               disruption never pollutes the window that scores it.

Scored on TIME-TO-READAPT: the offset into the spike of the first run
of consecutive caught-up ticks after the arm first fell behind (sim: 5
ticks with throughput >= 95% of the arrival rate; proc: 3 windows where
the exact backlog counter did not grow — window throughput is whole-
batch quantized, backlog deltas are not). 0 if the arm never fell
behind; None if it never recovers. Acceptance, on BOTH planes: intune
re-adapts within HALF of the best frozen arm's sustained-starvation
window, with zero OOMs, while both frozen arms starve (DESIGN.md §11
records the sim-vs-proc gaps and the scoring rationale).

    PYTHONPATH=src:. python benchmarks/fig_stream.py [--quick]
                                                     [--backend sim|proc|both]
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks import common
from repro.api import FrozenPolicy, Session, make_backend
from repro.core.baselines import heuristic_even
from repro.data.pipeline import StageGraph, StageSpec, stream_dlrm_pipeline
from repro.data.simulator import MachineSpec, PipelineSim
from repro.data.stream import flash_crowd_arrivals

# ----------------------------------------------------------- sim scenario --
# Tick = 1 simulated second. The spike lands inside the diurnal dip, so
# demand peaks near 19 batches/s — reachable from heuristic_even by one
# clamped +5 move on feature_udf within the 64-CPU machine, which is
# exactly the re-adaptation the frozen arms cannot make.
SIM_BASE = 2.0           # batches/s at the diurnal mean
SIM_SPIKE_AT = 300.0
SIM_SPIKE_LEN = 150.0
SIM_SPIKE_GAIN = 10.0
SIM_TICKS = 600


def sim_scenario():
    arr = flash_crowd_arrivals(
        SIM_BASE, spike_at_s=SIM_SPIKE_AT, spike_len_s=SIM_SPIKE_LEN,
        spike_gain=SIM_SPIKE_GAIN, diurnal_amp=0.1, diurnal_period_s=600.0,
        buffer_mb_per_batch=6.0, seed=0)
    spec = stream_dlrm_pipeline(arr, cost_scale=1.25)
    machine = MachineSpec(n_cpus=64, mem_mb=16384.0)
    return spec, machine


# ---------------------------------------------------------- proc scenario --
# Wall-clock arrivals on real OS processes. Elevated serial fractions
# make overplacement melt on the 1-core host: the sim oracle, told to
# water-fill the DECLARED 25-CPU machine, piles 21 workers on
# feature_udf and its measured capacity drops BELOW the spike demand —
# the sim-to-proc gap the differential arm exists to show.
PROC_BASE = 1.5          # batches/s at the diurnal mean
PROC_SPIKE_AT = 15.0     # wall seconds after pipeline launch
PROC_SPIKE_LEN = 60.0
PROC_SPIKE_GAIN = 4.0
PROC_WINDOW_S = 1.0      # whole-batch quantization: +-1 b/s at 1 s windows
PROC_TICKS = 95
PROC_GROW_BATCHES = 1.0  # "behind" = backlog grew > this over one window


def proc_stream_pipeline(arrival) -> StageGraph:
    stages = (
        StageSpec("ingest", "stream", cost=0.008, serial_frac=0.70,
                  mem_per_worker_mb=8, arrival=arrival),
        StageSpec("decode", "udf", cost=0.010, serial_frac=0.70,
                  mem_per_worker_mb=8, inputs=("ingest",)),
        StageSpec("feature_udf", "udf", cost=0.040, serial_frac=0.70,
                  mem_per_worker_mb=8, inputs=("decode",)),
        StageSpec("batch", "batch", cost=0.010, serial_frac=0.70,
                  mem_per_worker_mb=8, inputs=("feature_udf",)),
        StageSpec("prefetch", "prefetch", cost=0.006, serial_frac=0.70,
                  mem_per_worker_mb=8, inputs=("batch",)),
    )
    return StageGraph("proc_stream", stages, batch_mb=1.0,
                      target_rate=arrival.batches_per_sec(0.0))


def proc_scenario():
    arr = flash_crowd_arrivals(
        PROC_BASE, spike_at_s=PROC_SPIKE_AT, spike_len_s=PROC_SPIKE_LEN,
        spike_gain=PROC_SPIKE_GAIN, diurnal_amp=0.0, seed=0)
    spec = proc_stream_pipeline(arr)
    machine = MachineSpec(n_cpus=25, mem_mb=16384.0)
    return spec, machine


# ---------------------------------------------------------------- scoring --
def score_spike(rows, *, behind, consecutive: int):
    """rows: per-tick dicts (tput / arr / stale / in_spike); `behind(r)`
    decides whether one tick is starving/lagging. Returns (starve_ticks,
    spike_ticks, time_to_readapt): tta is the offset (in ticks) into the
    spike of the first run of `consecutive` caught-up ticks; None if the
    arm never re-adapts, 0 if it was never behind."""
    spike = [r for r in rows if r["in_spike"]]
    flags = [behind(r) for r in spike]
    starve = sum(flags)
    if starve == 0:
        return 0, len(spike), 0        # never behind: nothing to re-adapt
    tta = None
    ok_run = 0
    fell_behind = False
    for i, lag in enumerate(flags):
        if lag:
            fell_behind = True
            ok_run = 0
            continue
        # only a recovery counts: caught-up ticks BEFORE the arm first
        # fell behind are the backlog ramp, not a re-adaptation
        ok_run = ok_run + 1 if fell_behind else 0
        if ok_run == consecutive:
            tta = i - (consecutive - 1)
            break
    return starve, len(spike), tta


def sim_behind(r) -> bool:
    """Analytic plane: the tick's throughput is exact, so compare it to
    the arrival rate directly."""
    return r["tput"] < 0.95 * r["arr"]


def proc_behind(r) -> bool:
    """Process plane: window throughput is whole-batch quantized and a
    resize disturbs the very window that measures it, so per-window
    tput-vs-arrival is noise. Backlog is EXACT (arrival integral minus
    the source's token counter): the arm is behind when backlog GREW
    over the window — service rate below the arrival rate — and caught
    up the moment it re-matches, without charging the drain tail the
    way a staleness threshold would."""
    return (r["bl_delta"] or 0.0) > PROC_GROW_BATCHES


class HeldTuner:
    """Tune every `hold` windows. A live resize disturbs the very window
    that measures it (fresh workers fork + self-calibrate on an already
    saturated core), so each proposal is held for `hold` windows and only
    the LAST — settled — window of the hold is shown to the learner: the
    Session.run analog of fig_train_feed's tune-every-k-steps protocol.
    Frozen arms never resize, so they need no hold."""

    name = "intune"

    def __init__(self, inner, hold: int = 2):
        self.inner = inner
        self.hold = max(1, int(hold))
        self._alloc = None
        self._i = 0

    def propose(self, spec, machine, stats=None):
        if self._i % self.hold == 0:
            self._alloc = self.inner.propose(spec, machine, stats)
        return self._alloc

    def observe(self, tel) -> None:
        if self._i % self.hold == self.hold - 1:
            self.inner.observe(tel)
        self._i += 1


def run_arm(backend, opt, ticks: int, *, spike_rate: float):
    """Drive one policy through the scenario; a tick is in the spike
    when the measured arrival rate sits above twice the base (the proc
    plane's windows don't align with the wall-clock spike edges, so the
    tick's own arrival_rate is the only honest marker on both planes)."""
    rows = []
    prev_bl = [0.0]

    def collect(t, tel):
        ex = tel.extras or {}
        bl = tel.backlog_items
        delta = None if bl is None else bl - prev_bl[0]
        if bl is not None:
            prev_bl[0] = bl
        rows.append({
            "t": t,
            "tput": float(tel.throughput),
            "arr": float(ex.get("arrival_rate", 0.0)),
            "in_spike": float(ex.get("arrival_rate", 0.0)) > spike_rate,
            "stale": tel.batch_staleness_s,
            "backlog": bl,
            "bl_delta": delta,
            "workers": int(tel.used_cpus),
            "shed": float(ex.get("shed_batches", 0.0) or 0.0),
        })

    with Session(backend, opt) as session:
        res = session.run(ticks, collect=collect)
    return rows, res


def summarize(label, rows, res, *, behind, consecutive):
    starve, spike_ticks, tta = score_spike(rows, behind=behind,
                                           consecutive=consecutive)
    tail = [r["workers"] for r in rows[-ticks_tail(rows):]]
    spike = [r for r in rows if r["in_spike"]]
    out = {
        "spike_mean_tput": float(np.mean([r["tput"] for r in spike]))
        if spike else 0.0,
        "spike_max_stale_s": float(max((r["stale"] or 0.0)
                                       for r in spike)) if spike else 0.0,
        "policy": label,
        "oom_count": int(res.oom_count),
        "starve_ticks": int(starve),
        "spike_ticks": int(spike_ticks),
        "time_to_readapt": tta,
        "shed_total": float(rows[-1].get("shed", 0.0) or 0.0),
        "end_backlog": float(rows[-1]["backlog"] or 0.0),
        "end_staleness_s": float(rows[-1]["stale"] or 0.0),
        "trough_mean_workers": float(np.mean(tail)) if tail else 0.0,
    }
    print(f"  {label:12s} ooms={out['oom_count']:2d} "
          f"starve={out['starve_ticks']:3d}/{out['spike_ticks']} "
          f"tta={tta} end_backlog={out['end_backlog']:.0f} "
          f"trough_workers={out['trough_mean_workers']:.0f}")
    return out


def ticks_tail(rows, frac: float = 0.15):
    return max(1, int(len(rows) * frac))


# ------------------------------------------------------------------- arms --
def run_sim(seed: int = 0) -> dict:
    spec, machine = sim_scenario()
    even = heuristic_even(spec, machine)
    oracle = PipelineSim(spec, machine,
                         model_latency=1.0 / (1.2 * SIM_BASE)) \
        .best_allocation()[0]
    spike_rate = 2.0 * SIM_BASE
    print(f"[sim] even={even.workers.tolist()} "
          f"static_best={oracle.workers.tolist()}")

    arms = {}
    for label, opt_fn in (
            ("even", lambda s, m: FrozenPolicy(even)),
            ("static_best", lambda s, m: FrozenPolicy(oracle)),
            ("intune", lambda s, m: common.make_tuner(
                s, m, seed=seed, finetune_ticks=60,
                explore_restart_every=12))):
        spec, machine = sim_scenario()     # fresh arrival state per arm
        backend = make_backend("sim", spec, machine, seed=seed)
        rows, res = run_arm(backend, opt_fn(spec, machine), SIM_TICKS,
                            spike_rate=spike_rate)
        arms[label] = summarize(label, rows, res, behind=sim_behind,
                                consecutive=5)

    frozen_starve = min(arms["even"]["starve_ticks"],
                        arms["static_best"]["starve_ticks"])
    bar = frozen_starve / 2.0
    tta = arms["intune"]["time_to_readapt"]
    return {
        "scenario": {"base": SIM_BASE, "spike_at": SIM_SPIKE_AT,
                     "spike_len": SIM_SPIKE_LEN, "gain": SIM_SPIKE_GAIN,
                     "ticks": SIM_TICKS, "seed": seed},
        "arms": arms,
        "readapt_bar_ticks": bar,
        "pass": {
            "intune_readapts": tta is not None and tta <= bar,
            "intune_no_oom": arms["intune"]["oom_count"] == 0,
            "frozen_fails": (arms["even"]["starve_ticks"] >= bar * 2
                             or arms["even"]["oom_count"] > 0)
            and (arms["static_best"]["starve_ticks"] >= bar * 2
                 or arms["static_best"]["oom_count"] > 0),
        },
    }


def run_proc(seed: int = 0) -> dict:
    import time as _time

    spec, machine = proc_scenario()
    even = heuristic_even(spec, machine)
    # "provision for peak": the sim's water-filled best placement for
    # the DECLARED 25-CPU machine. In the simulator extra workers look
    # free; on the 1-core host every one of them multiplies the Amdahl
    # cycle and the measured capacity lands BELOW the spike demand —
    # the sim-to-proc gap this arm exists to exhibit.
    oracle = PipelineSim(spec, machine).best_allocation()[0]
    # intune's launch placement: the sim plan for the observed BASE
    # rate — the controller's conservative-launch convention for a
    # feed boundary on a shared host (minimal workers, scaled only as
    # live measurements justify). The contrast with static_best is the
    # tentpole claim: plan for base + adapt live vs freeze the peak
    # plan and melt the core.
    base_plan = PipelineSim(
        spec, machine,
        model_latency=1.0 / (1.2 * PROC_BASE)).best_allocation()[0]
    spike_rate = 2.0 * PROC_BASE
    print(f"[proc] even={even.workers.tolist()} "
          f"static_best={oracle.workers.tolist()} "
          f"intune_init={base_plan.workers.tolist()}")

    arms = {}
    for label, opt_fn in (
            ("even", lambda s, m: FrozenPolicy(even)),
            ("static_best", lambda s, m: FrozenPolicy(oracle)),
            ("intune", lambda s, m: HeldTuner(common.make_tuner(
                s, m, seed=seed, finetune_ticks=4,
                explore_restart_every=3, finetune_eps=0.7,
                lcb_coef=0.3, switch_margin=0.2, init_alloc=base_plan,
                readapt_stale_s=2.0, stale_scale=2.0), hold=2))):
        spec, machine = proc_scenario()    # fresh stream epoch per arm
        backend = make_backend("proc", spec, machine, seed=seed,
                               window_s=PROC_WINDOW_S, ballast=False)
        _time.sleep(1.0)                   # worker spin calibration
        rows, res = run_arm(backend, opt_fn(spec, machine), PROC_TICKS,
                            spike_rate=spike_rate)
        arms[label] = summarize(label, rows, res, behind=proc_behind,
                                consecutive=3)

    # differential claim, with margins sized for a noisy shared host:
    # both frozen arms spend most of the spike with a GROWING backlog
    # (service rate below arrivals); intune re-adapts — a run of
    # windows where the exact backlog counter stops growing — within
    # half of their sustained-starvation window and never OOMs.
    frac = lambda a: a["starve_ticks"] / max(1, a["spike_ticks"])
    frozen_starve = min(arms["even"]["starve_ticks"],
                        arms["static_best"]["starve_ticks"])
    bar = frozen_starve / 2.0
    tta = arms["intune"]["time_to_readapt"]
    return {
        "scenario": {"base": PROC_BASE, "spike_at": PROC_SPIKE_AT,
                     "spike_len": PROC_SPIKE_LEN,
                     "gain": PROC_SPIKE_GAIN, "window_s": PROC_WINDOW_S,
                     "ticks": PROC_TICKS,
                     "grow_batches": PROC_GROW_BATCHES,
                     "seed": seed},
        "arms": arms,
        "readapt_bar_ticks": bar,
        "pass": {
            "frozen_arms_starve": frac(arms["even"]) >= 0.5
            and frac(arms["static_best"]) >= 0.5,
            "intune_readapts": tta is not None and tta <= bar,
            "intune_no_oom": arms["intune"]["oom_count"] == 0,
        },
    }


# ------------------------------------------------------------------- main --
def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="sim arm only (CI): skip the wall-clock proc arm")
    ap.add_argument("--backend", choices=("sim", "proc", "both"),
                    default="both")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-assert", action="store_true",
                    help="report only, never fail")
    args = ap.parse_args()

    payload = {}
    if args.backend in ("sim", "both"):
        payload["sim"] = run_sim(seed=args.seed)
    if args.backend in ("proc", "both") and not args.quick:
        payload["proc"] = run_proc(seed=args.seed)

    common.save_json("BENCH_stream.json", payload)
    failures = [f"{plane}.{name}"
                for plane, rep in payload.items()
                for name, ok in rep["pass"].items() if not ok]
    if failures:
        print("FAIL:", ", ".join(failures))
        return 0 if args.no_assert else 1
    print("PASS")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
