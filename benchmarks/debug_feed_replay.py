"""Instrumented intune-arm replay for fig_train_feed (diagnostic, not CI).

Prints per-tick: measured idle, worker placement, retired-but-alive
worker counts per pool, and 1-min loadavg — to watch whether a
resize-down returns silicon promptly (fast-retire) or ghost processes
linger and degrade serving windows.

    PYTHONPATH=src:. python benchmarks/debug_feed_replay.py
"""
import os
import time

import numpy as np

from benchmarks import common
from benchmarks.fig_train_feed import build_model, measure_step_time
from repro.api import FeedBackend, Session
from repro.data.device_feed import make_train_feed
from repro.data.featurize import RecordSpec, featurize_stage_fns
from repro.data.pipeline import train_feed_pipeline
from repro.data.proc_executor import ProcessPipeline
from repro.data.simulator import Allocation, MachineSpec


def main():
    import jax
    steps, tune_every, warm_steps = 80, 2, 16
    post_warm = max(1, (steps - warm_steps) // tune_every)
    finetune = max(10, post_warm * 2 // 5)
    cfg, params, opt_state, step_fn = build_model(512)
    rec = RecordSpec(batch=512, n_sparse=cfg.n_sparse, n_dense=cfg.n_dense,
                     vocab=cfg.vocab_sizes[0])
    step_time = measure_step_time(step_fn, params, opt_state, rec)
    print(f"step_time {step_time*1e3:.1f}ms finetune={finetune}")
    spec = train_feed_pipeline(step_time_s=step_time, work="real")
    machine = MachineSpec(n_cpus=30, mem_mb=4096)
    pipe = ProcessPipeline(spec, fns=featurize_stage_fns(spec, record=rec),
                           machine=machine, pin_cpus=1)
    optimizer = common.make_tuner(
        spec, machine, seed=0, finetune_ticks=finetune,
        init_alloc=Allocation(np.ones(spec.n_stages, dtype=int),
                              2.0 * spec.batch_mb),
        explore_restart_every=12)
    init = optimizer.propose(spec, machine, None)
    pipe.set_allocation(list(init.workers), init.prefetch_mb)
    feed = make_train_feed(pipe, depth=2, timeout=max(120.0, 200.0 * step_time))
    backend = FeedBackend(pipe, feed, device_step_s=step_time)
    session = Session(backend, optimizer)
    try:
        for i in range(steps):
            batch = next(feed)
            params, opt_state, _ = step_fn(params, opt_state, i, batch)
            if (i + 1) % tune_every == 0:
                jax.block_until_ready(params)
                retired = [sum(1 for p in pool._retired if p.is_alive())
                           for pool in pipe.pools]
                load = os.getloadavg()[0]
                rss = pipe.rss_mb()
                avail = 0
                with open("/proc/meminfo") as f:
                    for line in f:
                        if line.startswith("MemAvailable:"):
                            avail = int(line.split()[1]) // 1024
                            break
                if i < warm_steps:
                    m = backend.measure()
                    print(f"t{i:3d} WARM idle={m.get('device_idle_frac'):.3f}"
                          f" w={pipe.worker_counts()} ret={retired}"
                          f" load={load:.1f} rss={rss:.0f} avail={avail}")
                    continue
                m = backend.measure()
                if m.extras.get("settling"):
                    # centralized post-resize settle flag (FeedBackend)
                    print(f"t{i:3d} SETT idle={m.device_idle_frac:.3f}"
                          f" prod={m.extras.get('produced')}"
                          f" w={pipe.worker_counts()} ret={retired}"
                          f" load={load:.1f} rss={rss:.0f} avail={avail}")
                    continue
                before = (list(pipe.worker_counts()), pipe.prefetch_mb)
                tel = session.step(m)
                after = (list(pipe.worker_counts()), pipe.prefetch_mb)
                tag = "MOVE" if after != before else "tick"
                idle = tel.device_idle_frac
                print(f"t{i:3d} {tag} idle={idle if idle is None else round(idle,3)}"
                      f" w={before[0]}->{after[0]} ret={retired}"
                      f" load={load:.1f} rss={rss:.0f} avail={avail}")
    finally:
        acct = session.close()
    hist = optimizer.history if hasattr(optimizer, "history") else []
    print("teardown:", acct)
    best = getattr(optimizer, "best", None)
    print("best:", best)
    stats = getattr(optimizer, "_alloc_stats", {})
    for k, (n, mu) in sorted(stats.items(), key=lambda kv: -kv[1][1]):
        print(f"  alloc {list(k[0])} pf={k[1]:.0f}: n={n} mean={mu:.3f}")


if __name__ == "__main__":
    main()
