"""§Perf hillclimbs: lower baseline and optimized variants of the three
selected cells, extract loop-aware roofline terms for each iteration, and
save the hypothesis -> change -> before -> after log.

    PYTHONPATH=src python -m benchmarks.perf_hillclimb [--cell dlrm|bert4rec|gnn]
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import dataclasses
import json

import jax

from benchmarks import analytic
from repro.configs import get_arch
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_production_mesh
from repro.launch.programs import build_program

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments", "bench",
                   "perf_iterations.json")


def measure(arch, shape_name: str, mesh) -> dict:
    prog = build_program(arch, arch.shape(shape_name), mesh)
    jitted = jax.jit(prog.fn, in_shardings=prog.in_shardings,
                     out_shardings=prog.out_shardings,
                     donate_argnums=prog.donate_argnums)
    with mesh:
        compiled = jitted.lower(*prog.abstract_args).compile()
    res = H.analyze(compiled.as_text())
    mem = compiled.memory_analysis()
    chips = mesh.devices.size
    cell = analytic.model_cell(arch, shape_name, chips)
    terms = analytic.roofline_terms(
        cell["model_flops"], res["dot_flops"], cell["mem_bytes_per_dev"],
        res["collective_bytes"], chips)
    return {
        "dot_flops_per_dev": res["dot_flops"],
        "coll_gib_per_dev": res["collective_bytes"] / 2 ** 30,
        "temp_gib_per_dev": mem.temp_size_in_bytes / 2 ** 30,
        "args_gib_per_dev": mem.argument_size_in_bytes / 2 ** 30,
        **terms,
    }


def _fmt(tag, m):
    print(f"  {tag:34s} compute {m['compute_s']:.3e}s  "
          f"mem {m['memory_s']:.3e}s  coll {m['collective_s']:.3e}s  "
          f"dom={m['dominant']}  roofline {m['roofline_fraction']:.4f}  "
          f"temp {m['temp_gib_per_dev']:.1f}GiB args "
          f"{m['args_gib_per_dev']:.1f}GiB")


def dlrm_variants():
    opt = get_arch("dlrm-criteo")        # registry default = optimized
    base = dataclasses.replace(
        opt, model=opt.model.replace(tp_lookup=False, param_dtype="float32"),
        optimizer="adagrad")
    # iter1 (REFUTED): rows over `model` only -> tables replicate over data
    # -> 6.5 GiB/dev data-axis table-grad all-reduce. Kept for the record.
    v1 = dataclasses.replace(
        base,
        model=base.model.replace(
            tp_lookup=True, param_dtype="bfloat16",
            sharding_overrides=(("table_rows", "model"),)),
        optimizer="rowwise_adagrad")
    return [("baseline (fp32, GSPMD take, adagrad)", base),
            ("iter1 REFUTED: rows over model only", v1),
            ("iter2: all-axis rows + ag-ids/psum-scatter", opt)]


def bert4rec_variants():
    base = get_arch("bert4rec")
    # iter0: replicated item table (what non-divisible vocab silently gave
    # us) — cheap gathers but a full-table f32 grad all-reduce, and the
    # table can't grow past one device's HBM.
    v0 = dataclasses.replace(
        base, model=base.model.replace(
            sharding_overrides=(("table_rows", None),)))
    v1 = dataclasses.replace(
        base, model=base.model.replace(tp_lookup=True))
    return [("iter0: replicated items (unscalable)", v0),
            ("baseline: row-sharded + GSPMD take", base),
            ("iter1: shard_map lookup + sampled-logit psum", v1)]


def gnn_variants():
    base = get_arch("graphsage-reddit")
    v1 = dataclasses.replace(
        base, model=base.model.replace(partitioned=True))
    return [("baseline (edge-sharded, replicated nodes)", base),
            ("opt1: dst-partitioned edges, node-sharded outputs", v1)]


def wide_deep_variants():
    opt = get_arch("wide-deep")          # registry default = optimized
    base = dataclasses.replace(
        opt, model=opt.model.replace(tp_lookup=False),
        optimizer="adagrad")
    return [("baseline (GSPMD take, adagrad)", base),
            ("iter1: all-axis rows + ag-ids/psum-scatter", opt)]


CELLS = {
    "dlrm": ("train_batch", dlrm_variants),
    "bert4rec": ("train_batch", bert4rec_variants),
    "gnn": ("ogb_products", gnn_variants),
    "wide-deep": ("train_batch", wide_deep_variants),
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None, choices=list(CELLS) + [None])
    args = ap.parse_args(argv)
    mesh = make_production_mesh()
    results = {}
    if os.path.exists(OUT):
        with open(OUT) as f:
            results = json.load(f)
    for cell, (shape_name, variants_fn) in CELLS.items():
        if args.cell and cell != args.cell:
            continue
        print(f"\n== §Perf {cell} ({shape_name}) ==")
        rows = []
        for tag, arch in variants_fn():
            m = measure(arch, shape_name, mesh)
            _fmt(tag, m)
            rows.append({"variant": tag, **m})
        results[cell] = rows
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(results, f, indent=1, default=float)


if __name__ == "__main__":
    main()
