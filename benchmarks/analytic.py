"""Analytic per-cell FLOPs / bytes model for the §Roofline report.

MODEL_FLOPS = the *useful* work of the cell (6·N_active·D convention for LM
training, matmul+interaction flops for recsys/GNN, fwd-only for serving).
MEM_BYTES = napkin per-device HBM traffic per step (weights/optimizer
passes + residual-stream activations + caches). Both are deliberately
simple closed forms from the configs — the loop-aware HLO dot-FLOPs
(launch/hlo_analysis.py) provide the compiled-side number, and the ratio
MODEL_FLOPS / HLO_FLOPs is the §Roofline "useful fraction" (catches remat
recompute, capacity-factor waste, non-causal flash, padding).

Hardware constants (TPU v5e, per assignment):
  197 TFLOP/s bf16 per chip; 819 GB/s HBM; ~50 GB/s/link ICI.
"""
from __future__ import annotations

from dataclasses import dataclass

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


# ------------------------------------------------------------ LM family ----
def lm_matmul_params(cfg, active: bool):
    """Matmul params per token-pass. active=True: MoE experts at top_k/E."""
    L, D = cfg.n_layers, cfg.d_model
    attn = D * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.head_dim \
        + cfg.n_heads * cfg.head_dim * D
    if cfg.is_moe:
        routed = 3 * D * cfg.d_expert * cfg.n_experts
        if active:
            routed = 3 * D * cfg.d_expert * cfg.top_k
        shared = 3 * D * (cfg.n_shared_experts * cfg.d_expert)
        router = D * cfg.n_experts
        ffn = routed + shared + router
    else:
        ffn = 3 * D * cfg.d_ff
    head = D * cfg.vocab_size
    return L * (attn + ffn) + head


def lm_param_bytes(cfg) -> int:
    """Total stored param bytes (bf16) incl. embeddings."""
    n = lm_matmul_params(cfg, active=False) + cfg.vocab_size * cfg.d_model
    return n * 2


def lm_cell(cfg, shape, n_chips: int) -> dict:
    B, S = shape.batch, shape.seq_len
    n_active = lm_matmul_params(cfg, active=True)
    if shape.kind == "train":
        tokens = B * S
        attn_fl = 3 * 2 * B * cfg.n_heads * S * S * cfg.head_dim  # causal x2
        model_fl = 6 * n_active * tokens + attn_fl
        toks_loc = tokens / n_chips
        # weights: fwd read + bwd read (bf16) + optimizer read/write (f32-ish)
        w_traffic = 6 * lm_param_bytes(cfg) / n_chips
        act = 24 * cfg.n_layers * toks_loc * cfg.d_model * 2
        mem = w_traffic + act
    elif shape.kind == "prefill":
        tokens = B * S
        attn_fl = 2 * B * cfg.n_heads * S * S * cfg.head_dim
        model_fl = 2 * n_active * tokens + attn_fl
        mem = lm_param_bytes(cfg) / n_chips \
            + 12 * cfg.n_layers * tokens / n_chips * cfg.d_model * 2 \
            + 2 * cfg.n_layers * tokens * cfg.n_kv_heads * cfg.head_dim * 2 \
            / n_chips
    else:  # decode: one token for the whole batch over an S-entry cache
        model_fl = 2 * n_active * B \
            + 2 * 2 * B * cfg.n_heads * S * cfg.head_dim
        cache = 2 * cfg.n_layers * B * S * cfg.n_kv_heads * cfg.head_dim * 2
        mem = lm_param_bytes(cfg) / n_chips + cache / n_chips
    return {"model_flops": model_fl, "mem_bytes_per_dev": mem}


# ----------------------------------------------------------------- GNN -----
def gnn_cell(cfg, shape, n_chips: int, d_feat: int) -> dict:
    h, c = cfg.d_hidden, cfg.n_classes
    if shape.kind == "full_graph":
        n, e = shape.n_nodes, shape.n_edges
        mm = 2 * n * (2 * d_feat * h + 2 * h * c)       # w_self + w_neigh
        agg = e * (d_feat + h)                           # segment sums
        model_fl = 3 * (mm + agg)                        # train
        mem = (e * 8 + n * d_feat * 4) / n_chips * 3 \
            + (e / n_chips) * (d_feat + h) * 4 * 2
    elif shape.kind == "minibatch":
        b, (f1, f2) = shape.batch_nodes, shape.fanout
        mm = 2 * b * (1 + f1) * 2 * d_feat * h + 2 * b * 2 * h * c
        agg = b * f1 * f2 * d_feat + b * f1 * d_feat + b * h * f1
        model_fl = 3 * (mm + agg)
        mem = (b * f1 * f2 * d_feat * 4) / n_chips * 3
    else:  # batched small graphs
        g, n, e = shape.n_graphs, shape.n_nodes, shape.n_edges
        mm = 2 * g * n * (2 * d_feat * h + 2 * h * c)
        model_fl = 3 * (mm + g * e * (d_feat + h))
        mem = g * n * d_feat * 4 / n_chips * 3
    return {"model_flops": model_fl, "mem_bytes_per_dev": mem}


# -------------------------------------------------------------- recsys -----
def _mlp_flops(dims) -> int:
    return sum(2 * a * b for a, b in zip(dims[:-1], dims[1:]))


def recsys_cell(cfg, shape, n_chips: int) -> dict:
    name = cfg.name
    b = shape.batch if shape.kind != "retrieval" else shape.n_candidates
    if name == "wide-deep":
        per = _mlp_flops((cfg.n_sparse * cfg.embed_dim + cfg.n_dense,)
                         + cfg.mlp_dims + (1,))
        lookup_bytes = cfg.n_sparse * cfg.multi_hot * cfg.embed_dim * 4
    elif name == "xdeepfm":
        per = _mlp_flops((cfg.n_sparse * cfg.embed_dim + cfg.n_dense,)
                         + cfg.mlp_dims + (1,))
        hk = cfg.n_sparse
        for hnext in cfg.cin_dims:
            per += 2 * hnext * hk * cfg.n_sparse * cfg.embed_dim
            hk = hnext
        lookup_bytes = cfg.n_sparse * cfg.embed_dim * 4
    elif name == "dien":
        gru = 2 * 3 * cfg.gru_dim * (cfg.embed_dim + cfg.gru_dim)
        per = 2 * cfg.seq_len * gru \
            + _mlp_flops((cfg.gru_dim + cfg.embed_dim + cfg.n_dense,)
                         + cfg.mlp_dims + (1,))
        lookup_bytes = cfg.seq_len * cfg.embed_dim * 4
    else:  # bert4rec
        d, s = cfg.embed_dim, cfg.seq_len
        blk = 2 * s * (4 * d * d + 8 * d * d) + 2 * 2 * s * s * d
        per = cfg.n_blocks * blk
        if shape.kind == "train":
            per += 2 * cfg.n_mask * (1 + cfg.n_negatives) * d
        lookup_bytes = s * d * 4
    mult = 3 if shape.kind == "train" else 1
    model_fl = mult * per * b
    mem = b / n_chips * lookup_bytes * mult \
        + min(1.0, b / n_chips) * 2 * sum(
            v * cfg.embed_dim for v in cfg.vocab_sizes) * 4 / n_chips
    return {"model_flops": model_fl, "mem_bytes_per_dev": mem}


def dlrm_cell(cfg, shape, n_chips: int) -> dict:
    b = shape.batch if shape.kind != "retrieval" else shape.n_candidates
    f = cfg.n_sparse + 1
    per = _mlp_flops((cfg.n_dense,) + cfg.bottom_mlp) \
        + 2 * f * f * cfg.embed_dim \
        + _mlp_flops((f * (f - 1) // 2 + cfg.bottom_mlp[-1],) + cfg.top_mlp)
    mult = 3 if shape.kind == "train" else 1
    lookup_bytes = cfg.n_sparse * cfg.multi_hot * cfg.embed_dim * 4
    model_fl = mult * per * b
    mem = b / n_chips * lookup_bytes * mult
    if shape.kind == "train":   # adagrad touches gathered rows r/w
        mem += 2 * b / n_chips * lookup_bytes
    return {"model_flops": model_fl, "mem_bytes_per_dev": mem}


# -------------------------------------------------------------- roofline ---
def model_cell(arch, shape_name: str, n_chips: int) -> dict:
    shape = arch.shape(shape_name)
    if arch.family == "lm":
        return lm_cell(arch.model, shape, n_chips)
    if arch.family == "gnn":
        return gnn_cell(arch.model, shape, n_chips, d_feat=shape.d_feat)
    if arch.family == "recsys":
        return recsys_cell(arch.model, shape, n_chips)
    return dlrm_cell(arch.model, shape, n_chips)


def roofline_terms(model_flops: float, hlo_flops_per_dev: float,
                   mem_bytes_per_dev: float, coll_bytes_per_dev: float,
                   n_chips: int) -> dict:
    compute_s = hlo_flops_per_dev / PEAK_FLOPS
    memory_s = mem_bytes_per_dev / HBM_BW
    collective_s = coll_bytes_per_dev / ICI_BW
    bound = max(compute_s, memory_s, collective_s, 1e-12)
    dominant = ("compute" if bound == compute_s else
                "memory" if bound == memory_s else "collective")
    useful_s = model_flops / n_chips / PEAK_FLOPS
    return {
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant,
        "model_flops": model_flops,
        "useful_ratio": (model_flops / n_chips) / max(hlo_flops_per_dev, 1.0),
        "roofline_fraction": useful_s / bound,
    }
