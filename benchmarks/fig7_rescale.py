"""Fig. 7: throughput + utilization over time under machine rescaling.

Protocol (paper §5 Hardware Setup): CPU cap follows 32 -> 64 -> 128 -> 64
-> 32 at regular intervals. Baselines other than InTune adapt only by
manual checkpoint+relaunch (*-Adaptive, paying a relaunch window);
plain AUTOTUNE keeps its initial 32-CPU configuration throughout.
Headline paper numbers: 2.05x (custom) / 2.29x (criteo) mean throughput
vs plain AUTOTUNE; 10-20% over the human-intervention alternatives.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.api import (ControllerBackend, DeadWindow, RELAUNCH_TICKS,
                       Session, SimBackend, resize_events)
from repro.core import baselines as B
from repro.data.pipeline import criteo_pipeline, custom_pipeline
from repro.data.simulator import MachineSpec, PipelineSim, resize_schedule


def run(pipeline: str = "criteo", ticks: int = 1500,
        quiet: bool = False) -> dict:
    spec = criteo_pipeline() if pipeline == "criteo" else custom_pipeline()
    machine = MachineSpec(n_cpus=32, mem_mb=65536)
    resizes = resize_schedule(ticks)               # [(tick, cap), ...]
    out = {}

    def static(name, fn, readapt):
        alloc = fn(spec, MachineSpec(n_cpus=32, mem_mb=65536), 0) \
            if fn in (B.autotune_like, B.plumber_like) \
            else fn(spec, MachineSpec(n_cpus=32, mem_mb=65536))
        # *-Adaptive policies re-profile at every scheduled resize and pay
        # the checkpoint+relaunch window for it (explicit DeadWindows);
        # frozen policies just ride the ResizeEvents
        events = resize_events(resizes)
        if readapt is not None:
            events += [DeadWindow(t, RELAUNCH_TICKS) for t, _ in resizes]
        opt = common.ReadaptPolicy(alloc, readapt, seed=0,
                                   resize_ticks=[t for t, _ in resizes])
        out[name] = Session(SimBackend(spec, machine, seed=0), opt).run(
            ticks, events=events)

    static("unoptimized", B.unoptimized, None)
    static("autotune", B.autotune_like, None)          # never adapts
    static("autotune_adaptive", B.autotune_like,
           lambda s, m, seed: B.autotune_like(s, m, seed))
    static("plumber_adaptive", B.plumber_like,
           lambda s, m, seed: B.plumber_like(s, m, seed))
    static("heuristic_adaptive", B.heuristic_even,
           lambda s, m, seed: B.heuristic_even(s, m))
    tuner = common.make_tuner(spec, machine, seed=0, finetune_ticks=150)
    res = Session(ControllerBackend(tuner)).run(
        ticks, events=resize_events(resizes))
    out["intune"] = {k: res[k] for k in
                     ("throughput", "used_cpus", "oom_count")}

    summary = {}
    for name, r in out.items():
        tp = np.asarray(r["throughput"])
        # utilization: active-CPU fraction (paper Fig 7B) and model-fed
        # fraction (GPU util proxy, Fig 7C)
        caps = []
        cap = 32
        rmap = dict(resizes)
        for t in range(ticks):
            cap = rmap.get(t, cap)
            caps.append(cap)
        used = np.minimum(np.asarray(r["used_cpus"]), caps)
        summary[name] = {
            "mean_tput": float(tp.mean()),
            "mean_tput_pct_target": float(tp.mean() / spec.target_rate
                                          * 100),
            "cpu_util_pct": float((used / np.asarray(caps)).mean() * 100),
            "gpu_util_pct": float(np.minimum(
                tp / spec.target_rate, 1.0).mean() * 100),
            "oom_count": int(r["oom_count"]),
        }
    vs_auto = summary["intune"]["mean_tput"] / \
        max(summary["autotune"]["mean_tput"], 1e-9)
    vs_human = summary["intune"]["mean_tput"] / max(
        summary["heuristic_adaptive"]["mean_tput"],
        summary["plumber_adaptive"]["mean_tput"], 1e-9)
    summary["_speedups"] = {"vs_autotune": float(vs_auto),
                            "vs_best_human": float(vs_human)}
    if not quiet:
        print(f"\n== Fig7 rescale timeline ({pipeline}) "
              f"[paper: 2.05-2.29x vs AUTOTUNE, 1.1-1.2x vs human] ==")
        for name, s in summary.items():
            if name.startswith("_"):
                continue
            print(f"  {name:20s} mean {s['mean_tput_pct_target']:5.1f}% "
                  f"of target | cpu-util {s['cpu_util_pct']:5.1f}% | "
                  f"gpu-util {s['gpu_util_pct']:5.1f}% | "
                  f"OOMs {s['oom_count']}")
        print(f"  InTune vs AUTOTUNE: {vs_auto:.2f}x; "
              f"vs best human baseline: {vs_human:.2f}x")
    common.save_json(f"fig7_{pipeline}.json",
                     {"summary": summary,
                      "timelines": {k: v["throughput"]
                                    for k, v in out.items()
                                    if "throughput" in v}})
    return summary


if __name__ == "__main__":
    run("criteo")
    run("custom")
