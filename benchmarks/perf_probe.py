"""§Perf probe: per-collective breakdown for one (arch, shape) cell.

The hypothesis->change->measure loop's measurement tool: lowers the cell,
runs the loop-aware analysis, and prints the top collective op shapes with
their loop-scaled byte totals (so you can see WHICH tensor's movement
dominates the collective roofline term).

    PYTHONPATH=src python -m benchmarks.perf_probe --arch dlrm-criteo \
        --shape train_batch
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import re

import jax

from repro.configs import get_arch
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_production_mesh
from repro.launch.programs import build_program


def probe(arch_id: str, shape_name: str, multi_pod: bool = False,
          top: int = 18) -> dict:
    arch = get_arch(arch_id)
    mesh = make_production_mesh(multi_pod=multi_pod)
    prog = build_program(arch, arch.shape(shape_name), mesh)
    jitted = jax.jit(prog.fn, in_shardings=prog.in_shardings,
                     out_shardings=prog.out_shardings,
                     donate_argnums=prog.donate_argnums)
    with mesh:
        compiled = jitted.lower(*prog.abstract_args).compile()
    txt = compiled.as_text()
    comps, factors = H.computation_factors(txt)
    rows = []
    for name, lines in comps.items():
        if name == "ENTRY":
            continue
        f = factors.get(name, 1.0)
        for line in lines:
            for op in H.COLLECTIVE_OPS:
                if f" {op}(" in line or f" {op}-start(" in line:
                    lhs = line.split(" = ", 1)
                    if len(lhs) != 2:
                        break
                    part = lhs[1].split(op)[0].strip()
                    if part.startswith("("):
                        shapes = re.findall(r"[a-z0-9]+\[[\d,]*\]", part)
                    else:
                        shapes = re.findall(r"^[a-z0-9]+\[[\d,]*\]", part)
                    b = sum(H.shape_bytes(s) for s in shapes)
                    rows.append((f * b, op, f, shapes, name[:34],
                                 line.split("metadata")[0][-90:]))
                    break
    rows.sort(reverse=True, key=lambda r: r[0])
    total = sum(r[0] for r in rows)
    mem = compiled.memory_analysis()
    res = H.analyze(txt)
    print(f"\n== {arch_id}/{shape_name} "
          f"({'2x16x16' if multi_pod else '16x16'}) ==")
    print(f"dot flops/dev {res['dot_flops']:.3e} | "
          f"collective {total/2**30:.2f} GiB/dev | "
          f"temp {mem.temp_size_in_bytes/2**30:.2f} GiB/dev")
    for b, op, f, shapes, comp, ctx in rows[:top]:
        print(f"  {b/2**30:8.3f} GiB x{f:6.0f} {op:18s} {shapes} "
              f"[{comp}]")
    return {"total": total, "rows": rows, "dot_flops": res["dot_flops"],
            "temp": mem.temp_size_in_bytes}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi", action="store_true")
    args = ap.parse_args()
    probe(args.arch, args.shape, args.multi)
