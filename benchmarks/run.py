"""Benchmark entrypoint: one module per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--only fig5|fig7|fig7_fleet|fig8|roofline|kernels]

  fig5   static throughput + OOM rates   (paper Fig. 5A/5B)
  fig7   rescale timelines + utilization (paper Fig. 7A-C, 2.05-2.29x)
  fig7_fleet  multi-trainer cluster co-tuning under churn (fleet plane)
  fig8   scaling drilldowns              (paper Fig. 8A-C)
  roofline  §Roofline table from the dry-run artifacts
  kernels   Pallas kernel micro-bench
"""
from __future__ import annotations

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="fig5|fig7|fig7_fleet|fig8|roofline|kernels")
    args = ap.parse_args(argv)
    t0 = time.time()

    from benchmarks import (fig5_static, fig7_fleet, fig7_rescale,
                            fig8_scaling, kernels_bench, roofline)
    ran = []
    if args.only in (None, "fig5"):
        fig5_static.run("criteo")
        fig5_static.run("custom")
        ran.append("fig5")
    if args.only in (None, "fig7"):
        fig7_rescale.run("criteo")
        fig7_rescale.run("custom")
        ran.append("fig7")
    if args.only in (None, "fig7_fleet"):
        fig7_fleet.run()
        ran.append("fig7_fleet")
    if args.only in (None, "fig8"):
        fig8_scaling.run()
        ran.append("fig8")
    if args.only in (None, "roofline"):
        roofline.run()
        ran.append("roofline")
    if args.only in (None, "kernels"):
        kernels_bench.run()
        ran.append("kernels")
    print(f"\nbenchmarks done ({', '.join(ran)}) in {time.time()-t0:.0f}s; "
          f"artifacts in experiments/bench/")


if __name__ == "__main__":
    main()
