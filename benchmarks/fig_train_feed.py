"""The closed-loop headline: tuned proc feed vs baselines on DEVICE IDLE.

The paper's north-star metric is accelerator idle time, not pipeline
batches/sec (InTune §1; BagPipe). This benchmark runs the full bridge —
real featurization stages (data/featurize.py) in a ProcessPipeline,
batches crossing into jax through `device_feed.make_train_feed`, a real
(small) DLRM train step consuming them — three times, identical except
for who places the workers:

  intune      `common.make_tuner` (pretrained DQN, live fine-tune) driven
              by `Session.step` between train steps, observing measured
              `device_idle_frac` telemetry from `FeedBackend`
  even        `heuristic_even` frozen: n_cpus/n_stages workers per stage.
              On a host smaller than the declared machine this OVERPLACES
              — every extra worker multiplies the Amdahl coordination
              penalty (cost * (a*s + 1-s)) and steals real silicon from
              the trainer, so the feed falls behind and the device starves
  static_best 1 worker/stage frozen — the small-host oracle placement,
              the floor the tuner should approach

Scored on the measured tail-window device-idle fraction and step time;
emits machine-readable BENCH_train_feed.json with
`idle_reduction_vs_even` (acceptance bar: >= 0.20).

    PYTHONPATH=src python benchmarks/fig_train_feed.py [--smoke]
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from benchmarks import common
from repro.api import FeedBackend, FrozenPolicy, Session
from repro.configs.base import DLRMConfig
from repro.core.baselines import heuristic_even
from repro.data.device_feed import make_train_feed
from repro.data.featurize import (RecordSpec, featurize_block,
                                  featurize_stage_fns, raw_block)
from repro.data.pipeline import train_feed_pipeline
from repro.data.proc_executor import ProcessPipeline
from repro.data.simulator import Allocation, MachineSpec


def build_model(batch: int):
    """Small DLRM (fast step => many tuning ticks per wall minute); the
    100M-param version of the same loop is examples/train_dlrm_criteo."""
    import jax
    from repro.models import dlrm as dlrm_lib
    from repro.train.optim import make_optimizer
    from repro.train.train_step import make_train_step

    # ~10M params: big enough that the device step takes O(100ms) on a
    # small host, so the feed pipeline's designed stage costs (0.8x the
    # step, split across stages) sit ABOVE the CPU-clock tick guard and
    # worker contention is physical, not IPC noise
    n_sparse, rows = 8, 1 << 14
    cfg = DLRMConfig(name="dlrm-feed-demo", n_sparse=n_sparse, n_dense=13,
                     embed_dim=64, vocab_sizes=(rows,) * n_sparse,
                     bottom_mlp=(128, 64), top_mlp=(256, 128, 1))
    params, _ = dlrm_lib.init_params(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer("adagrad", lr=0.02)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(
        lambda p, b: dlrm_lib.loss_fn(p, cfg, b), opt))
    return cfg, params, opt_state, step_fn


def measure_step_time(step_fn, params, opt_state, rec, iters: int = 10):
    import jax
    import jax.numpy as jnp
    warm = {k: jnp.asarray(v) for k, v in featurize_block(
        raw_block(np.random.RandomState(0), rec), rec).items()}
    params, opt_state, _ = step_fn(params, opt_state, 0, warm)  # compile
    jax.block_until_ready(params)
    t0 = time.monotonic()
    for k in range(iters):
        params, opt_state, _ = step_fn(params, opt_state, k, warm)
    jax.block_until_ready(params)
    return (time.monotonic() - t0) / iters


def run_arm(name, make_opt, *, step_fn, params, opt_state, rec, spec,
            machine, steps: int, tune_every: int, step_time: float,
            warm_steps: int = 16):
    """One closed-loop run: fresh pipeline + feed + backend + session;
    the optimizer is the only difference between arms."""
    import jax

    pipe = ProcessPipeline(spec, fns=featurize_stage_fns(spec, record=rec),
                           machine=machine, pin_cpus=1)
    optimizer = make_opt(spec, machine)
    init = optimizer.propose(spec, machine, None)
    pipe.set_allocation(list(init.workers), init.prefetch_mb)
    feed = make_train_feed(pipe, depth=2,
                           timeout=max(120.0, 200.0 * step_time))
    # device_step_s: on a shared-core host the feed steals silicon from
    # the trainer instead of letting it block, so idle is scored as
    # 1 - device_busy/wall against the uncontended step time
    backend = FeedBackend(pipe, feed, device_step_s=step_time)
    session = Session(backend, optimizer)
    idles, stimes, workers = [], [], []
    try:
        for i in range(steps):
            batch = next(feed)
            params, opt_state, _ = step_fn(params, opt_state, i, batch)
            if (i + 1) % tune_every == 0:
                jax.block_until_ready(params)   # close the step window
                if i < warm_steps:
                    # cold pipeline: queues are filling and workers are
                    # self-calibrating, so the first windows read idle
                    # ~0.9 at ANY allocation. Feeding them to the tuner
                    # would poison best-tracking (the launch allocation
                    # is only ever visited cold, so a warm-but-bad
                    # allocation outscores it). Discard the measurement
                    # without observing or moving.
                    backend.measure()
                    continue
                tel = backend.measure()
                if tel.extras.get("settling"):
                    # the window that just closed measured the
                    # TRANSITION into the last-applied allocation —
                    # tearing down / spawning worker processes can
                    # starve the feed for a full window at ANY target
                    # allocation, and charging it to the new allocation
                    # career-kills good placements. FeedBackend flags
                    # such windows (first window after a resize, held
                    # while production stays zero, capped so a dead
                    # allocation is still charged — see
                    # backends.FeedBackend.measure): discard them
                    # without observing or moving.
                    continue
                tel = session.step(tel)
                if tel.step_time_s is not None:
                    idles.append(float(tel.device_idle_frac))
                    stimes.append(float(tel.step_time_s))
                    workers.append(list(pipe.worker_counts()))
    finally:
        acct = session.close()
    # tail window: the tuner's serving phase (post fine-tune), and for
    # the frozen arms just their (stationary) tail
    tail = max(1, len(idles) // 3)
    row = {
        "arm": name,
        "idle_frac": float(np.mean(idles[-tail:])),
        "step_time_s": float(np.mean(stimes[-tail:])),
        "idle_series": [round(x, 4) for x in idles],
        "workers_final": workers[-1] if workers else None,
        "ticks": len(idles),
        "teardown": acct,
    }
    print(f"  {name:12s} idle={row['idle_frac']:.3f} "
          f"step={row['step_time_s']*1e3:.0f}ms "
          f"workers={row['workers_final']}")
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short run for CI: fewer steps, same plumbing")
    # long enough that the serving tail outlives the exploration
    # phase's retired-worker decay (a retiring worker whose exit flush
    # is parked behind a full steady-state queue drains off at roughly
    # one per consumed item — see proc_executor._worker_main)
    ap.add_argument("--steps", type=int, default=320)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--tune-every", type=int, default=2)
    args = ap.parse_args(argv)
    steps = 80 if args.smoke else args.steps
    # the tuner only observes post-warmup ticks (run_arm discards the
    # first warm_steps train steps' measurements), and every exploration
    # MOVE costs two windows (one discarded settle window + one
    # observed), so the fine-tune / serve split is budgeted from the
    # post-warmup WINDOW count: 2*finetune exploration windows, the
    # rest steady serving — which keeps the scored tail inside the
    # serving phase
    warm_steps = 16
    post_warm = max(1, (steps - warm_steps) // args.tune_every)
    # cap: ~20 moves cover the 5-stage walk several times over, and
    # every extra move is process churn — long runs spend the surplus
    # in the serving phase instead, where the scored tail lives
    finetune = max(10, min(post_warm * 2 // 5, 20))

    cfg, params, opt_state, step_fn = build_model(args.batch)
    rec = RecordSpec(batch=args.batch, n_sparse=cfg.n_sparse,
                     n_dense=cfg.n_dense, vocab=cfg.vocab_sizes[0])
    step_time = measure_step_time(step_fn, params, opt_state, rec)
    print(f"device step time: {step_time*1e3:.1f} ms "
          f"({os.cpu_count()} host cores)")

    spec = train_feed_pipeline(step_time_s=step_time, work="real")
    machine = MachineSpec(n_cpus=30, mem_mb=4096)
    kw = dict(step_fn=step_fn, params=params, opt_state=opt_state, rec=rec,
              spec=spec, machine=machine, steps=steps,
              tune_every=args.tune_every, step_time=step_time,
              warm_steps=warm_steps)

    arms = {}
    print(f"running 3 arms x {steps} train steps:")
    arms["even"] = run_arm(
        "even", lambda s, m: FrozenPolicy(heuristic_even(s, m)), **kw)
    arms["static_best"] = run_arm(
        "static_best",
        lambda s, m: FrozenPolicy(
            Allocation(np.ones(s.n_stages, dtype=int), 2.0 * s.batch_mb)),
        **kw)
    arms["intune"] = run_arm(
        "intune",
        # cold-start at the conservative launch placement (1 worker per
        # stage, what a real pipeline boots with) and scale up only
        # where the measured feed reward justifies it. Starting the
        # exploration walk at heuristic_even would have the tuner spend
        # the whole window walking DOWN out of the even arm's basin
        lambda s, m: common.make_tuner(
            s, m, seed=0, finetune_ticks=finetune,
            init_alloc=Allocation(np.ones(s.n_stages, dtype=int),
                                  2.0 * s.batch_mb),
            # the pretrained Q-net learned "grow workers" on a dedicated
            # sim machine; at the feed boundary that bias points the
            # wrong way, so restart the walk from the incumbent best
            # often enough that greedy drift cannot carry it far
            explore_restart_every=12,
            # live windows are a couple of train steps of noisy wall
            # clock: penalize one-off lucky readings and demand a clear
            # margin before the serving choice flips
            lcb_coef=0.15, switch_margin=0.05), **kw)

    even, tuned = arms["even"], arms["intune"]
    idle_red = (even["idle_frac"] - tuned["idle_frac"]) \
        / max(even["idle_frac"], 1e-9)
    step_red = (even["step_time_s"] - tuned["step_time_s"]) \
        / max(even["step_time_s"], 1e-9)
    payload = {
        "host_cpus": os.cpu_count(),
        "batch": args.batch,
        "steps": steps,
        "tune_every": args.tune_every,
        "smoke": bool(args.smoke),
        "device_step_time_s": step_time,
        "arms": arms,
        "idle_reduction_vs_even": idle_red,
        "step_time_reduction_vs_even": step_red,
        # the >=20% bar is scored on the full run; --smoke runs too few
        # ticks for the tuner to finish fine-tuning and only reports
        "pass_20pct_bar": bool(idle_red >= 0.20),
    }
    common.save_json("BENCH_train_feed.json", payload)
    bar = "report-only (smoke)" if args.smoke else \
        ("PASS" if idle_red >= 0.20 else "FAIL")
    print(f"idle reduction vs even: {idle_red:+.1%} "
          f"(bar >= +20.0%: {bar}); "
          f"step-time reduction: {step_red:+.1%}")
    print(f"wrote {os.path.join(common.OUT_DIR, 'BENCH_train_feed.json')}")
    return payload


if __name__ == "__main__":
    main()
