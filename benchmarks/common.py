"""Shared benchmark machinery: cached pretrained agents, timeline runner."""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core import baselines as B
from repro.core.controller import InTune
from repro.core.pretrain import load_agent_state, pretrain, save_agent
from repro.data.simulator import Allocation, MachineSpec, PipelineSim

AGENT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                         "agents")
OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "bench")
RELAUNCH_TICKS = 20   # checkpoint + relaunch dead time for *-Adaptive


def get_agent_state(n_stages: int, head: str = "factored",
                    episodes: int = 60, ticks: int = 300) -> dict:
    os.makedirs(AGENT_DIR, exist_ok=True)
    path = os.path.join(AGENT_DIR, f"dqn_{head}_r{n_stages}.npz")
    if os.path.exists(path):
        return load_agent_state(path)
    agent = pretrain(n_stages, episodes=episodes, ticks=ticks,
                     verbose=False, head=head)
    save_agent(agent, path)
    return agent.state_dict()


def save_json(name: str, payload):
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, name), "w") as f:
        json.dump(payload, f, indent=1, default=float)


def run_static(spec, machine, alloc, ticks: int, *, resizes=None,
               readapt=None, seed: int = 0):
    """Timeline for a fixed (or relaunch-adapted) allocation.

    resizes: [(tick, n_cpus)]; readapt: fn(spec, machine, seed)->Allocation
    applied after each resize with a RELAUNCH_TICKS dead window (the
    paper's manual-intervention baseline behavior).
    """
    sim = PipelineSim(spec, machine, seed=seed)
    tput, mem, used = [], [], []
    dead = 0
    cur = alloc
    resizes = dict(resizes or [])
    for t in range(ticks):
        if t in resizes:
            sim.resize(resizes[t])
            if readapt is not None:
                cur = readapt(spec, sim.machine, seed + t)
                dead = RELAUNCH_TICKS
        if dead > 0:
            dead -= 1
            m = {"throughput": 0.0, "mem_mb": 0.0,
                 "used_cpus": 0, "oom": False}
            sim.time += 1
        else:
            m = sim.apply(cur)
        tput.append(m["throughput"])
        used.append(min(m["used_cpus"], sim.machine.n_cpus))
        mem.append(m["mem_mb"])
    return {"throughput": tput, "used_cpus": used, "mem_mb": mem,
            "oom_count": sim.oom_count,
            "caps": [resizes.get(t, None) for t in range(ticks)]}


def run_optimizer(opt, spec, machine, ticks: int, *, resizes=None,
                  seed: int = 0, relaunch_dead: int = 0,
                  sim_factory=PipelineSim, collect=None):
    """Drive any Optimizer-protocol policy against one authoritative sim.

    The generic loop the protocol exists for: propose -> apply -> observe.
    `relaunch_dead` > 0 charges the *-Adaptive relaunch window whenever a
    static policy changes its proposal after a resize (learning policies
    re-allocate live and should pass 0).

    The same loop drives BOTH planes: `sim_factory(spec, machine, seed=s)`
    defaults to the single-machine PipelineSim; pass
    `lambda c, _, seed: FleetSim(c, seed=seed)` with a ClusterSpec to
    drive a fleet policy (FleetSim speaks the same machine/apply/resize
    dialect, and FleetAllocation flattens to the same workers/prefetch_mb
    views the changed-proposal check compares). `collect(t, metrics)`,
    when given, sees every tick's full metrics dict (per-trainer
    breakdowns, which the aggregate return drops).
    """
    sim = sim_factory(spec, machine, seed=seed)
    resizes = dict(resizes or [])
    tput, used, mem = [], [], []
    dead = 0
    prev = None
    for t in range(ticks):
        if t in resizes:
            sim.resize(resizes[t])
        alloc = opt.propose(spec, sim.machine)
        # capacity the proposal was made against: reading sim.machine
        # AFTER apply would let a fleet's next-tick churn events fire
        # early and clamp this tick's used_cpus with t+1 capacity
        cap = sim.machine.n_cpus
        changed = prev is not None and (
            not np.array_equal(alloc.workers, prev.workers)
            or alloc.prefetch_mb != prev.prefetch_mb)
        if relaunch_dead and changed:
            dead = relaunch_dead
        prev = alloc
        if dead > 0:
            dead -= 1
            sim.time += 1
            # relaunch window: the pipeline process is down, matching
            # run_static's dead-window accounting
            m = {"throughput": 0.0, "mem_mb": 0.0, "oom": False,
                 "restarting": True, "used_cpus": 0}
        else:
            m = sim.apply(alloc)
        opt.observe(m)
        if collect is not None:
            collect(t, m)
        tput.append(m["throughput"])
        used.append(min(m["used_cpus"], cap))
        mem.append(m["mem_mb"])
    return {"throughput": tput, "used_cpus": used, "mem_mb": mem,
            "oom_count": sim.oom_count}


def run_fleet_optimizer(opt, cluster, ticks: int, *, seed: int = 0,
                        relaunch_dead: int = 0, collect=None,
                        backend: str = "sim", backend_kw=None):
    """run_optimizer over a fleet: same loop, the chosen backend
    authoritative.

    backend="sim" drives the analytic FleetSim; backend="live" drives
    real ThreadedPipeline executors (repro.data.live_fleet.LiveFleet —
    same dialect, measured throughput), closed after the run with its
    drop/leak accounting returned under the "live" result key.
    `backend_kw` passes backend-specific knobs (e.g. window_s,
    obs_noise).
    """
    kw = dict(backend_kw or {})
    if backend == "sim":
        from repro.data.fleet import FleetSim
        factory = lambda c, _m, seed=0: FleetSim(c, seed=seed, **kw)
        return run_optimizer(opt, cluster, None, ticks, seed=seed,
                             relaunch_dead=relaunch_dead,
                             sim_factory=factory, collect=collect)
    if backend != "live":
        raise KeyError(f"unknown fleet backend {backend!r}; "
                       f"known: ['sim', 'live']")
    from repro.data.live_fleet import LiveFleet
    created = []

    def factory(c, _m, seed=0):
        lf = LiveFleet(c, seed=seed, **kw)
        created.append(lf)
        return lf

    try:
        res = run_optimizer(opt, cluster, None, ticks, seed=seed,
                            relaunch_dead=relaunch_dead,
                            sim_factory=factory, collect=collect)
    finally:
        accts = [lf.close() for lf in created]
    res["live"] = accts[0] if accts else {}
    return res


def make_fleet_coordinator(cluster, *, seed: int = 0, head: str = "factored",
                           finetune_ticks: int = 150, **kw):
    """Benchmark-grade FleetCoordinator: one cached pretrained agent per
    distinct pipeline length in the cluster."""
    from repro.core.fleet_coordinator import FleetCoordinator
    lengths = sorted({t.pipeline.n_stages for t in cluster.trainers})
    pretrained = {n: get_agent_state(n, head=head) for n in lengths}
    return FleetCoordinator(cluster, pretrained=pretrained, seed=seed,
                            head=head, finetune_ticks=finetune_ticks, **kw)


def make_tuner(spec, machine, *, seed: int = 0, head: str = "factored",
               finetune_ticks: int = 250) -> InTune:
    """Benchmark-grade InTune: pretrained (cached) agent for this length."""
    state = get_agent_state(spec.n_stages, head=head)
    return InTune(spec, machine, seed=seed, head=head, pretrained=state,
                  finetune_ticks=finetune_ticks)


def run_intune_protocol(spec, machine, ticks: int, *, resizes=None,
                        seed: int = 0, head: str = "factored",
                        finetune_ticks: int = 250):
    """InTune behind the unified Optimizer protocol: the benchmark's own
    simulator is authoritative and the tuner only proposes/observes. The
    protocol path also restarts exploration from the incumbent best
    (controller.explore_restart_every), which the legacy run_intune path
    deliberately does not, to keep pre-DAG benchmark numbers unchanged."""
    tuner = make_tuner(spec, machine, seed=seed, head=head,
                       finetune_ticks=finetune_ticks)
    res = run_optimizer(tuner, spec, machine, ticks, resizes=resizes,
                        seed=seed)
    res["tuner"] = tuner
    return res


def run_intune(spec, machine, ticks: int, *, resizes=None, seed: int = 0,
               head: str = "factored", finetune_ticks: int = 250):
    tuner = make_tuner(spec, machine, seed=seed, head=head,
                       finetune_ticks=finetune_ticks)
    resizes = dict(resizes or [])
    tput, used = [], []
    for t in range(ticks):
        if t in resizes:
            tuner.resize(resizes[t])
        rec = tuner.tick()
        tput.append(rec["throughput"])
        used.append(min(rec["used_cpus"], tuner.env.sim.machine.n_cpus))
    return {"throughput": tput, "used_cpus": used,
            "oom_count": tuner.env.sim.oom_count, "tuner": tuner}
