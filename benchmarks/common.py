"""Shared benchmark machinery: cached pretrained agents + legacy shims.

The timeline runners that used to live here (`run_static` /
`run_optimizer` / `run_fleet_optimizer` / `run_intune*`) are now
one-PR deprecation shims over `repro.api.Session` — the single driver
loop every benchmark and example delegates to. New code should use
`repro.api` directly; the shims exist so external callers of the old
dialect get one release of warning instead of a break, and they
reproduce the legacy loops' outputs exactly (the fig5 golden suite
enforces this byte-for-byte on the linear chains).
"""
from __future__ import annotations

import json
import os
import warnings

from repro.api import (ControllerBackend, DeadWindow, FrozenPolicy,
                       RELAUNCH_TICKS, ResizeEvent, Session, SimBackend,
                       as_backend, resize_events)
from repro.core.controller import InTune
from repro.core.pretrain import load_agent_state, pretrain, save_agent
from repro.data.simulator import Allocation, MachineSpec, PipelineSim

AGENT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                         "agents")
OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "bench")

__all__ = ["AGENT_DIR", "OUT_DIR", "RELAUNCH_TICKS", "ReadaptPolicy",
           "get_agent_state", "save_json", "make_tuner",
           "make_fleet_coordinator", "run_static", "run_optimizer",
           "run_fleet_optimizer", "run_intune", "run_intune_protocol"]


def get_agent_state(n_stages: int, head: str = "factored",
                    episodes: int = 60, ticks: int = 300) -> dict:
    os.makedirs(AGENT_DIR, exist_ok=True)
    path = os.path.join(AGENT_DIR, f"dqn_{head}_r{n_stages}.npz")
    if os.path.exists(path):
        return load_agent_state(path)
    agent = pretrain(n_stages, episodes=episodes, ticks=ticks,
                     verbose=False, head=head)
    save_agent(agent, path)
    return agent.state_dict()


def save_json(name: str, payload):
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, name), "w") as f:
        json.dump(payload, f, indent=1, default=float)


class ReadaptPolicy(FrozenPolicy):
    """The *-Adaptive benchmark protocol: hold `alloc` (FrozenPolicy);
    on every scheduled resize tick, re-profile via `readapt(spec,
    machine, seed + tick)` — the manual checkpoint+relaunch behavior,
    whose dead window the caller schedules as DeadWindow events. With
    `readapt=None` this IS FrozenPolicy."""

    name = "static"

    def __init__(self, alloc: Allocation, readapt=None, *, seed: int = 0,
                 resize_ticks=()):
        super().__init__(alloc)
        self._readapt = readapt
        self._seed = seed
        self._resize_ticks = frozenset(resize_ticks)
        self._t = 0

    def propose(self, spec, machine, stats=None) -> Allocation:
        t = self._t
        self._t += 1
        if self._readapt is not None and t in self._resize_ticks:
            self.alloc = self._readapt(spec, machine, self._seed + t)
        return self.alloc


def _deprecated(old: str, new: str):
    warnings.warn(
        f"benchmarks.common.{old} is deprecated; use {new} "
        f"(repro.api) instead", DeprecationWarning, stacklevel=3)


def _as_schedule(resizes) -> list:
    """The legacy loops accepted [(tick, n_cpus), ...] or {tick: n_cpus};
    normalize to the pair list resize_events lifts."""
    return list(dict(resizes or []).items())


def make_fleet_coordinator(cluster, *, seed: int = 0, head: str = "factored",
                           finetune_ticks: int = 150, **kw):
    """Benchmark-grade FleetCoordinator: one cached pretrained agent per
    distinct pipeline length in the cluster."""
    from repro.core.fleet_coordinator import FleetCoordinator
    lengths = sorted({t.pipeline.n_stages for t in cluster.trainers})
    pretrained = {n: get_agent_state(n, head=head) for n in lengths}
    return FleetCoordinator(cluster, pretrained=pretrained, seed=seed,
                            head=head, finetune_ticks=finetune_ticks, **kw)


def make_tuner(spec, machine, *, seed: int = 0, head: str = "factored",
               finetune_ticks: int = 250) -> InTune:
    """Benchmark-grade InTune: pretrained (cached) agent for this length."""
    state = get_agent_state(spec.n_stages, head=head)
    return InTune(spec, machine, seed=seed, head=head, pretrained=state,
                  finetune_ticks=finetune_ticks)


# ---------------------------------------------------------------------------
# Deprecation shims: the legacy driver dialects, delegating to Session.
# ---------------------------------------------------------------------------

def run_static(spec, machine, alloc, ticks: int, *, resizes=None,
               readapt=None, seed: int = 0):
    """DEPRECATED: use repro.api.Session with a frozen/ReadaptPolicy
    optimizer and ResizeEvent/DeadWindow events."""
    _deprecated("run_static", "Session(SimBackend(...), ReadaptPolicy(...))")
    resizes = _as_schedule(resizes)
    events = resize_events(resizes)
    if readapt is not None:
        # the legacy protocol charges the relaunch window at EVERY
        # scheduled resize tick (even a no-op re-cap re-profiles)
        events += [DeadWindow(t, RELAUNCH_TICKS) for t, _ in resizes]
    opt = ReadaptPolicy(alloc, readapt, seed=seed,
                        resize_ticks=[t for t, _ in resizes])
    res = Session(SimBackend(spec, machine, seed=seed), opt).run(
        ticks, events=events)
    rmap = dict(resizes)
    res.extras["caps"] = [rmap.get(t, None) for t in range(ticks)]
    return res


def run_optimizer(opt, spec, machine, ticks: int, *, resizes=None,
                  seed: int = 0, relaunch_dead: int = 0,
                  sim_factory=PipelineSim, collect=None):
    """DEPRECATED: use repro.api.Session over an explicit backend."""
    _deprecated("run_optimizer", "Session(backend, opt).run(...)")
    backend = as_backend(sim_factory(spec, machine, seed=seed))
    return Session(backend, opt, spec=spec).run(
        ticks, events=resize_events(_as_schedule(resizes)),
        relaunch_dead=relaunch_dead, collect=collect)


def run_fleet_optimizer(opt, cluster, ticks: int, *, seed: int = 0,
                        relaunch_dead: int = 0, collect=None,
                        backend: str = "sim", backend_kw=None):
    """DEPRECATED: use repro.api.Session over a fleet backend (or
    repro.api.tune(cluster, ...))."""
    _deprecated("run_fleet_optimizer",
                "Session(make_backend(..., cluster), opt)")
    from repro.api import make_backend
    if backend not in ("sim", "live"):
        raise KeyError(f"unknown fleet backend {backend!r}; "
                       f"known: ['sim', 'live']")
    be = make_backend(backend, cluster, seed=seed, **(backend_kw or {}))
    try:
        res = Session(be, opt, spec=cluster).run(
            ticks, relaunch_dead=relaunch_dead, collect=collect)
    finally:
        acct = be.shutdown()
    if backend == "live":
        res.extras["live"] = acct
    return res


def run_intune_protocol(spec, machine, ticks: int, *, resizes=None,
                        seed: int = 0, head: str = "factored",
                        finetune_ticks: int = 250):
    """DEPRECATED: build a tuner (make_tuner) and drive it with
    repro.api.Session over a SimBackend."""
    _deprecated("run_intune_protocol",
                "Session(SimBackend(...), make_tuner(...))")
    tuner = make_tuner(spec, machine, seed=seed, head=head,
                       finetune_ticks=finetune_ticks)
    res = Session(SimBackend(spec, machine, seed=seed), tuner).run(
        ticks, events=resize_events(_as_schedule(resizes)))
    res.extras["tuner"] = tuner
    return res


def run_intune(spec, machine, ticks: int, *, resizes=None, seed: int = 0,
               head: str = "factored", finetune_ticks: int = 250):
    """DEPRECATED: use repro.api.Session over a ControllerBackend (the
    self-driving paper-protocol path)."""
    _deprecated("run_intune", "Session(ControllerBackend(make_tuner(...)))")
    tuner = make_tuner(spec, machine, seed=seed, head=head,
                       finetune_ticks=finetune_ticks)
    res = Session(ControllerBackend(tuner)).run(
        ticks, events=resize_events(_as_schedule(resizes)))
    res.extras["tuner"] = tuner
    return res
