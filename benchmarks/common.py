"""Shared benchmark machinery: cached pretrained agents + output helpers.

The legacy timeline runners that once lived here (`run_static` /
`run_optimizer` / `run_fleet_optimizer` / `run_intune*`) went through
their one-PR deprecation-shim stage and are now REMOVED: every
benchmark, example, and test drives `repro.api.Session` (or the
`repro.api.tune` one-liner) directly — see the migration table in
DESIGN.md §8. The fig5 golden suite pins that the direct Session path
still reproduces the published numbers byte-for-byte.
"""
from __future__ import annotations

import json
import os

from repro.api import FrozenPolicy, RELAUNCH_TICKS
from repro.core.controller import InTune
from repro.core.pretrain import load_agent_state, pretrain, save_agent
from repro.data.simulator import Allocation

AGENT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                         "agents")
OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "bench")

__all__ = ["AGENT_DIR", "OUT_DIR", "RELAUNCH_TICKS", "ReadaptPolicy",
           "get_agent_state", "save_json", "make_tuner",
           "make_fleet_coordinator"]


def get_agent_state(n_stages: int, head: str = "factored",
                    episodes: int = 60, ticks: int = 300) -> dict:
    os.makedirs(AGENT_DIR, exist_ok=True)
    path = os.path.join(AGENT_DIR, f"dqn_{head}_r{n_stages}.npz")
    if os.path.exists(path):
        return load_agent_state(path)
    agent = pretrain(n_stages, episodes=episodes, ticks=ticks,
                     verbose=False, head=head)
    save_agent(agent, path)
    return agent.state_dict()


def save_json(name: str, payload):
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, name), "w") as f:
        json.dump(payload, f, indent=1, default=float)


class ReadaptPolicy(FrozenPolicy):
    """The *-Adaptive benchmark protocol: hold `alloc` (FrozenPolicy);
    on every scheduled resize tick, re-profile via `readapt(spec,
    machine, seed + tick)` — the manual checkpoint+relaunch behavior,
    whose dead window the caller schedules as DeadWindow events. With
    `readapt=None` this IS FrozenPolicy."""

    name = "static"

    def __init__(self, alloc: Allocation, readapt=None, *, seed: int = 0,
                 resize_ticks=()):
        super().__init__(alloc)
        self._readapt = readapt
        self._seed = seed
        self._resize_ticks = frozenset(resize_ticks)
        self._t = 0

    def propose(self, spec, machine, stats=None) -> Allocation:
        t = self._t
        self._t += 1
        if self._readapt is not None and t in self._resize_ticks:
            self.alloc = self._readapt(spec, machine, self._seed + t)
        return self.alloc


def make_fleet_coordinator(cluster, *, seed: int = 0, head: str = "factored",
                           finetune_ticks: int = 150, **kw):
    """Benchmark-grade FleetCoordinator: one cached pretrained agent per
    distinct pipeline length in the cluster."""
    from repro.core.fleet_coordinator import FleetCoordinator
    lengths = sorted({t.pipeline.n_stages for t in cluster.trainers})
    pretrained = {n: get_agent_state(n, head=head) for n in lengths}
    return FleetCoordinator(cluster, pretrained=pretrained, seed=seed,
                            head=head, finetune_ticks=finetune_ticks, **kw)


def make_pool_market(market, *, seed: int = 0, head: str = "factored",
                     finetune_ticks: int = 150, **kw):
    """Benchmark-grade PoolMarket ("coordinator + market"): per-job
    FleetCoordinators, one cached pretrained agent per distinct pipeline
    length across the whole market."""
    from repro.core.fleet_coordinator import PoolMarket
    lengths = sorted({t.pipeline.n_stages for t in market.trainers})
    pretrained = {n: get_agent_state(n, head=head) for n in lengths}
    return PoolMarket(market, inner="fleet_intune", pretrained=pretrained,
                      seed=seed, head=head, finetune_ticks=finetune_ticks,
                      **kw)


def make_tuner(spec, machine, *, seed: int = 0, head: str = "factored",
               finetune_ticks: int = 250, **kw) -> InTune:
    """Benchmark-grade InTune: pretrained (cached) agent for this length."""
    state = get_agent_state(spec.n_stages, head=head)
    return InTune(spec, machine, seed=seed, head=head, pretrained=state,
                  finetune_ticks=finetune_ticks, **kw)
