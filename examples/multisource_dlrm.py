"""Multi-source DLRM ingestion: InTune tunes a non-linear StageGraph.

Production DLRM pipelines are DAGs, not chains (Zhao et al.): dense,
sparse, and label streams come from separate storage and are joined
before the feature transforms. This example runs that shape end to end,
twice:

  1. REAL execution — a ThreadedPipeline over the 3-source join graph
     (one bounded queue per edge, an aligned gather at the join),
     feeding actual numpy batches out of the sink.
  2. RL tuning at scale — the analytic simulator models the 128-CPU
     machine and the InTune DQN agent drives allocations through the
     unified Optimizer protocol (propose -> apply -> observe), landing
     within a few percent of the true-cost oracle.

    PYTHONPATH=src python examples/multisource_dlrm.py
"""
import numpy as np

from repro.api import Session, SimBackend
from repro.core.controller import InTune
from repro.core.optimizer import make_optimizer
from repro.core.pretrain import pretrain
from repro.data.executor import ThreadedPipeline
from repro.data.pipeline import multisource_dlrm_pipeline
from repro.data.simulator import MachineSpec, PipelineSim


def run_real_executor(spec, n_items: int = 24):
    """Drive the join DAG with real threads and real (tiny) arrays."""
    counts = {"dense": 0, "sparse": 0, "label": 0}
    # each source runs in its own worker thread: one RandomState apiece
    rngs = {k: np.random.RandomState(i)
            for i, k in enumerate(("dense", "sparse", "label"))}

    def source(key, make):
        def fn():
            if counts[key] >= n_items:
                return None
            i = counts[key]
            counts[key] += 1
            return {"row": i, key: make(i)}
        return fn

    fns = {
        "dense_source": source(
            "dense", lambda i: rngs["dense"].randn(32, 4).astype("f4")),
        "sparse_source": source(
            "sparse", lambda i: rngs["sparse"].randint(0, 1024, (32, 8))),
        "label_source": source(
            "label", lambda i: rngs["label"].randint(0, 2, (32,))),
        # the join pairs one item from each parent stream, in spec order
        "join": lambda d, s, l: {**d, **s, **l},
        "feature_udf": lambda b: {**b, "dense": np.log1p(np.abs(b["dense"]))},
        "batch": lambda b: b,
        "prefetch": lambda b: b,
    }
    pipe = ThreadedPipeline(spec, fns=fns, queue_depth=4, item_mb=1.0,
                            machine=MachineSpec(n_cpus=8, mem_mb=8192))
    got = 0
    try:
        while True:
            b = pipe.get_batch(timeout=10)
            assert b["dense"].shape == (32, 4) and b["label"].shape == (32,)
            got += 1
    except StopIteration:
        pass
    finally:
        pipe.stop()
    stats = pipe.stats()
    print(f"executor: {got} joined batches through "
          f"{len(spec.edges)} edges; workers {stats['workers']}, "
          f"free_cpus {stats['free_cpus']}")


def run_rl_tuning(spec, ticks: int = 300):
    machine = MachineSpec(n_cpus=128, mem_mb=65536)
    sim = PipelineSim(spec, machine)
    oracle = make_optimizer("oracle", spec, machine)
    oracle_tput = sim.throughput(oracle.propose(spec, machine))
    print(f"oracle: {oracle_tput:.2f} batches/s "
          f"({100 * oracle_tput / spec.target_rate:.0f}% of target)")

    print("pretraining a 7-stage agent offline (short pass)...")
    agent = pretrain(spec.n_stages, episodes=30, ticks=250, verbose=False,
                     head="factored")
    tuner = InTune(spec, machine, seed=0, head="factored",
                   pretrained=agent.state_dict(), finetune_ticks=250)

    # the unified Session loop every driver uses (repro.api)
    backend = SimBackend(spec, machine, seed=0)

    def report(t, tel):
        if (t + 1) % 75 == 0:
            print(f"  tick {t + 1:3d}: {tel.throughput:.2f} b/s "
                  f"workers {tuner.allocation.workers}")

    Session(backend, tuner).run(ticks, collect=report)
    final = backend.sim.apply(tuner.allocation)["throughput"]
    print(f"InTune after {ticks} ticks: {final:.2f} batches/s = "
          f"{100 * final / oracle_tput:.0f}% of oracle "
          f"(OOMs: {backend.oom_count})")


if __name__ == "__main__":
    spec = multisource_dlrm_pipeline()
    names = " -> ".join(spec.stages[i].name for i in spec.topo_order)
    print(f"StageGraph {spec.name!r}: {spec.n_stages} stages, "
          f"{len(spec.edges)} edges, topo {names}")
    run_real_executor(spec)
    run_rl_tuning(spec)
