"""Drive the FleetCoordinator on REAL executors: a 60-tick live fleet.

The same `repro.api.Session` loop as examples/fleet_tuning.py, but the
authoritative backend is LiveFleet — one ThreadedPipeline per trainer,
measured (not simulated) throughput. Runs in ~10s.

    PYTHONPATH=src python examples/live_fleet.py
"""
from repro.api import LiveFleetBackend, Session
from repro.core.fleet_coordinator import FleetCoordinator
from repro.data.live_fleet import live_demo_cluster


def main(ticks: int = 60, window_s: float = 0.1):
    cluster = live_demo_cluster(ticks)
    coord = FleetCoordinator(cluster, seed=0, finetune_ticks=20)

    def report(t, tel):
        if t % 10 == 0:
            per = " ".join(
                f"{n}:{m['throughput']:6.1f}"
                for n, m in tel["per_trainer"].items())
            print(f"tick {t:3d} | active {tel['n_active']} | "
                  f"measured {tel.throughput:7.1f} b/s | {per}")

    with Session(LiveFleetBackend(cluster, window_s=window_s),
                 coord) as session:
        session.run(ticks, collect=report)
        acct = session.close()
    print(f"\nmeasured fleet run done: OOMs {acct['oom_count']}, "
          f"dropped batches {acct['dropped_batches']}, "
          f"all threads joined: {acct['all_joined']}")


if __name__ == "__main__":
    main()
