"""ProcessPipeline: tune a REAL process-based pipeline, then calibrate it.

Three escalating views of the process plane (DESIGN.md §9):

  1. `tune(..., backend="proc")` — the same one-liner as every other
     substrate, now driving one OS-process pool per stage: worker
     processes burn real CPU (true contention), memory is measured
     resident bytes from /proc, and the OOM judge fires on what the
     kernel reports, not on an accounting model.
  2. The measured-RSS OOM judge: shrink the machine's memory below the
     pipeline's real footprint and watch the kill + dead window +
     relaunch lifecycle fire on measured bytes.
  3. Live serial_frac calibration (`repro.data.calibrate`): sweep a
     stage's worker pool, fit Amdahl's law to the measured service
     curve, and hand the calibrated StageGraph straight back to the
     analytic simulator — the measured closure of the sim <-> live loop.

    PYTHONPATH=src python examples/process_pipeline.py
"""
import time

import numpy as np

from repro.api import make_backend, tune
from repro.data.calibrate import calibrate_stagegraph
from repro.data.pipeline import StageGraph, StageSpec
from repro.data.simulator import Allocation, MachineSpec, PipelineSim


def demo_pipeline() -> StageGraph:
    stages = (
        StageSpec("src", "source", cost=0.005, serial_frac=0.0,
                  mem_per_worker_mb=24),
        StageSpec("feature_udf", "udf", cost=0.06, serial_frac=0.0,
                  mem_per_worker_mb=48, inputs=("src",)),
    )
    return StageGraph("proc_example", stages, batch_mb=1.0)


def part1_tune_on_processes():
    print("== 1. tune() on a real process-based pipeline ==")
    spec = demo_pipeline()
    machine = MachineSpec(n_cpus=4, mem_mb=4096.0)
    res = tune(spec, machine, optimizer="oracle", backend="proc",
               ticks=8, seed=0, backend_kw={"window_s": 0.3})
    live = res.extras["live"]
    print(f"  measured {float(np.mean(res.throughput[2:])):.1f} b/s "
          f"over {res.ticks} windows | OOMs {res.oom_count} | "
          f"processes joined: {live['all_joined']}")


def part2_measured_oom():
    print("== 2. the OOM judge fires on MEASURED resident bytes ==")
    spec = demo_pipeline()
    # the two workers' touched ballast (24 + 48 MB of private pages)
    # exceeds a 48 MB machine: the verdict comes from the kernel
    be = make_backend("proc", spec, MachineSpec(n_cpus=4, mem_mb=48.0),
                      window_s=0.05, ballast=True)
    alloc = Allocation(np.ones(2, dtype=int), prefetch_mb=8.0)
    try:
        time.sleep(1.2)                       # calibrate + touch ballast
        tel = be.apply(alloc)
        print(f"  measured RSS {tel.mem_mb:.0f} MB > 48 MB cap -> "
              f"oom={tel.oom} (kill + dead window + relaunch)")
        tel = be.apply(alloc)
        print(f"  next tick: restarting={tel.restarting} "
              f"(dead window, {be.restart_left} ticks left)")
    finally:
        be.shutdown()


def part3_calibration():
    print("== 3. live serial_frac calibration (sim <-> live closure) ==")
    spec = StageGraph("cal_example", (
        StageSpec("udf", "udf", cost=0.1, serial_frac=0.35,
                  mem_per_worker_mb=16),), batch_mb=1.0)
    t0 = time.monotonic()
    cal, report = calibrate_stagegraph(spec, workers=(1, 2, 3),
                                       window_s=1.2)
    r = report["udf"]
    print(f"  designed cost {r['spec_cost']:.3f} / serial_frac "
          f"{r['spec_serial_frac']:.2f}")
    print(f"  fitted   cost {r['cost']:.3f} / serial_frac "
          f"{r['serial_frac']:.2f}  "
          f"({time.monotonic() - t0:.1f}s sweep)")
    sim = PipelineSim(cal, MachineSpec(n_cpus=8, mem_mb=4096.0))
    best, tput = sim.best_allocation(8)
    print(f"  oracle on the CALIBRATED spec: workers "
          f"{best.workers.tolist()} -> {tput:.1f} b/s predicted")


if __name__ == "__main__":
    part1_tune_on_processes()
    part2_measured_oom()
    part3_calibration()
