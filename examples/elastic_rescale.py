"""Elastic rescale demo: the paper's Fig. 5C scenario end to end.

A job starts on a 32-CPU machine; the cluster scheduler grows it
32 -> 64 -> 128, then shrinks back. InTune adapts with zero relaunches;
the AUTOTUNE-like baseline is shown both frozen (never adapts) and
-Adaptive (manual checkpoint+relaunch with dead time). Also demonstrates
the compute-side elastic path: mesh re-planning + checkpoint resharding.

    PYTHONPATH=src python examples/elastic_rescale.py
"""
import numpy as np

from repro.api import (ControllerBackend, FrozenPolicy, Session, SimBackend,
                       resize_events)
from repro.core import baselines as B
from repro.core.controller import InTune
from repro.core.pretrain import pretrain
from repro.data.pipeline import criteo_pipeline
from repro.data.simulator import MachineSpec, resize_schedule
from repro.train.elastic import ElasticCoordinator


def main():
    spec = criteo_pipeline()
    ticks = 1000
    resizes = resize_schedule(ticks)
    events = resize_events(resizes)
    print("resize schedule:", resizes)

    print("\npretraining agent (offline simulator pass)...")
    agent = pretrain(5, episodes=30, ticks=250, verbose=False,
                     head="factored")

    # InTune rides the ResizeEvents live (zero relaunches): the
    # self-driving paper protocol behind the unified Session driver
    tuner = InTune(spec, MachineSpec(n_cpus=32), seed=0, head="factored",
                   pretrained=agent.state_dict(), finetune_ticks=100)
    intune_t = Session(ControllerBackend(tuner)).run(
        ticks, events=events).throughput

    # frozen AUTOTUNE (configured once for 32 CPUs), same event stream
    alloc = B.autotune_like(spec, MachineSpec(n_cpus=32), 0)
    auto_t = Session(SimBackend(spec, MachineSpec(n_cpus=32)),
                     FrozenPolicy(alloc)).run(ticks,
                                              events=events).throughput

    seg = ticks // len(resizes)
    print(f"\n{'window':>10s} {'cap':>5s} {'InTune':>8s} {'AUTOTUNE':>9s} "
          f"{'ratio':>6s}")
    for i, (t0, cap) in enumerate(resizes):
        t1 = t0 + seg
        a = np.mean(intune_t[t0:t1])
        b = np.mean(auto_t[t0:t1])
        print(f"{t0:5d}-{t1:4d} {cap:5d} {a:8.2f} {b:9.2f} "
              f"{a / max(b, 1e-9):5.2f}x")
    print(f"\noverall: InTune {np.mean(intune_t):.2f} vs frozen AUTOTUNE "
          f"{np.mean(auto_t):.2f} "
          f"({np.mean(intune_t)/max(np.mean(auto_t),1e-9):.2f}x) — "
          f"the paper's 2x-class gain comes from exactly these windows")

    # ---- compute-side elasticity: mesh re-planning ---------------------
    print("\ncompute-side recovery plans (ElasticCoordinator):")
    coord = ElasticCoordinator(n_devices=256, model_parallel=16)
    for survivors in (256, 192, 128, 60, 16):
        plan = coord.recovery_plan(survivors)
        print(f"  {survivors:4d} survivors -> mesh {plan['mesh_shape']}, "
              f"{plan['devices_idle']} idle")


if __name__ == "__main__":
    main()
