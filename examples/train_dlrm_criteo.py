"""End-to-end driver: train a ~100M-parameter DLRM for a few hundred steps
on the synthetic Criteo stream, with checkpoint/restart and the InTune
controller tuning the (simulated-machine) ingestion pipeline alongside.

    PYTHONPATH=src python examples/train_dlrm_criteo.py [--steps 300]

~100M params: 8 tables x 2^16 rows x 64-dim = 33.5M embedding + MLPs, plus
bottom/top MLPs (kept modest so the CPU run finishes in minutes). The
production-size config is `--arch dlrm-criteo` in the dry-run.
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DLRMConfig
from repro.core.controller import InTune
from repro.data.pipeline import criteo_pipeline
from repro.data.simulator import MachineSpec
from repro.data.synthetic import CriteoStream
from repro.models import dlrm as dlrm_lib
from repro.train import checkpoint as ckpt
from repro.train.optim import make_optimizer
from repro.train.train_step import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=2048)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--ckpt-dir", default="experiments/ckpt_dlrm")
    args = ap.parse_args(argv)

    n_sparse, n_dense, rows, dim = 12, 13, 1 << 16, 96
    cfg = DLRMConfig(
        name="dlrm-100m", n_sparse=n_sparse, n_dense=n_dense,
        embed_dim=dim, vocab_sizes=(rows,) * n_sparse,
        bottom_mlp=(512, 256, 96), top_mlp=(1024, 512, 256, 1))
    stream = CriteoStream(n_sparse=n_sparse, n_dense=n_dense, vocab=rows)

    params, _ = dlrm_lib.init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree_util.tree_leaves(params))
    print(f"model: {n_params/1e6:.1f}M params")
    opt = make_optimizer("adagrad", lr=0.02)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(
        lambda p, b: dlrm_lib.loss_fn(p, cfg, b), opt))

    # resume if a checkpoint exists
    start = 0
    tuner = InTune(criteo_pipeline(), MachineSpec(n_cpus=128), seed=0,
                   head="factored", finetune_ticks=150)
    last = ckpt.latest_step(args.ckpt_dir)
    if last is not None:
        tree, manifest = ckpt.restore(args.ckpt_dir)
        params, opt_state = tree["params"], tree["opt_state"]
        start = manifest["step"] + 1
        if "intune" in manifest["extras"]:
            ex = manifest["extras"]["intune"]
            tuner.load_state_dict({
                "agent": {"qnet": tree["intune_qnet"],
                          "steps": ex["agent_steps"]},
                "workers": ex["workers"],
                "prefetch_mb": ex["prefetch_mb"]})
        print(f"resumed from step {start - 1}")

    t0 = time.time()
    losses = []
    for i in range(start, args.steps):
        batch = stream.feature_udf(stream.raw_block(args.batch))
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, i, batch)
        # pipeline tuning advances in lockstep with training steps (the
        # decoupled form is Session(ControllerBackend(tuner)).run(...)
        # in a background thread — see examples/quickstart.py part 3)
        tuner.tick()
        losses.append(float(metrics["loss"]))
        if i % 25 == 0:
            rate = (i - start + 1) * args.batch / (time.time() - t0)
            print(f"step {i:4d} loss {losses[-1]:.4f} "
                  f"({rate:,.0f} samples/s) pipeline "
                  f"{tuner.history[-1]['throughput']:.1f} b/s")
        if (i + 1) % args.ckpt_every == 0 or i == args.steps - 1:
            st = tuner.state_dict()
            ckpt.save(args.ckpt_dir, i,
                      {"params": params, "opt_state": opt_state,
                       "intune_qnet": st["agent"]["qnet"]},
                      extras={"intune": {
                          "workers": st["workers"],
                          "prefetch_mb": st["prefetch_mb"],
                          "agent_steps": st["agent"]["steps"]}})
    print(f"final loss {np.mean(losses[-20:]):.4f} "
          f"(first-20 {np.mean(losses[:20]):.4f}); "
          f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
