"""End-to-end driver: train a ~100M-parameter DLRM for a few hundred steps
on synthetic Criteo data, with checkpoint/restart and the InTune
controller tuning ingestion alongside.

    PYTHONPATH=src python examples/train_dlrm_criteo.py [--steps 300]

Two backends:

  --backend proc (default)  THE CLOSED LOOP. A real ProcessPipeline runs
      the featurization stages (hashing / pooling / padding / collation,
      data/featurize.py) in worker processes; batches cross into jax
      through `device_feed.make_train_feed` (device_prefetch + stall
      metering); the InTune controller tunes THIS pipeline — the one the
      train step actually eats from — via `FeedBackend` + `Session.step`,
      observing measured `device_idle_frac` at the feed boundary.

  --backend sim  the legacy mode, kept for hosts where forking worker
      processes is unwanted. NOTE: in this mode the controller tunes a
      SIMULATED MachineSpec(n_cpus=128) pipeline that is completely
      DETACHED from the data actually fed to the model (batches are
      synthesized inline by CriteoStream); tuner output never changes
      what the train loop sees. It demonstrates the controller loop, not
      a closed tuning loop — use the default proc backend for that.

~100M params: 12 tables x 2^16 rows x 96-dim = 75.5M embedding, plus
bottom/top MLPs (kept modest so the CPU run finishes in minutes). The
production-size config is `--arch dlrm-criteo` in the dry-run.
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DLRMConfig
from repro.core.controller import InTune
from repro.data.pipeline import criteo_pipeline, train_feed_pipeline
from repro.data.simulator import Allocation, MachineSpec
from repro.data.synthetic import CriteoStream
from repro.models import dlrm as dlrm_lib
from repro.train import checkpoint as ckpt
from repro.train.optim import make_optimizer
from repro.train.train_step import make_train_step


def build_model(batch: int):
    n_sparse, n_dense, rows, dim = 12, 13, 1 << 16, 96
    cfg = DLRMConfig(
        name="dlrm-100m", n_sparse=n_sparse, n_dense=n_dense,
        embed_dim=dim, vocab_sizes=(rows,) * n_sparse,
        bottom_mlp=(512, 256, 96), top_mlp=(1024, 512, 256, 1))
    params, _ = dlrm_lib.init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree_util.tree_leaves(params))
    print(f"model: {n_params/1e6:.1f}M params")
    opt = make_optimizer("adagrad", lr=0.02)
    step_fn = jax.jit(make_train_step(
        lambda p, b: dlrm_lib.loss_fn(p, cfg, b), opt))
    return cfg, params, opt, step_fn


def restore_or_init(ckpt_dir, params, opt_state, tuner):
    start = 0
    last = ckpt.latest_step(ckpt_dir)
    if last is not None:
        tree, manifest = ckpt.restore(ckpt_dir)
        params, opt_state = tree["params"], tree["opt_state"]
        start = manifest["step"] + 1
        if "intune" in manifest["extras"]:
            ex = manifest["extras"]["intune"]
            tuner.load_state_dict({
                "agent": {"qnet": tree["intune_qnet"],
                          "steps": ex["agent_steps"]},
                "workers": ex["workers"],
                "prefetch_mb": ex["prefetch_mb"]})
        print(f"resumed from step {start - 1}")
    return start, params, opt_state


def save_step(ckpt_dir, i, params, opt_state, tuner):
    st = tuner.state_dict()
    ckpt.save(ckpt_dir, i,
              {"params": params, "opt_state": opt_state,
               "intune_qnet": st["agent"]["qnet"]},
              extras={"intune": {
                  "workers": st["workers"],
                  "prefetch_mb": st["prefetch_mb"],
                  "agent_steps": st["agent"]["steps"]}})


def run_proc(args):
    """The closed loop: tuned ProcessPipeline feeds the real train step."""
    from repro.api import FeedBackend, Session
    from repro.data.device_feed import make_train_feed
    from repro.data.featurize import (RecordSpec, featurize_block,
                                      featurize_stage_fns, raw_block)

    cfg, params, opt, step_fn = build_model(args.batch)
    opt_state = opt.init(params)
    rec = RecordSpec(batch=args.batch, n_sparse=cfg.n_sparse,
                     n_dense=cfg.n_dense, vocab=cfg.vocab_sizes[0])

    # warm up the jit + measure the raw device step time: the pipeline's
    # CPU budget (train_feed_pipeline cpu_share) is set relative to THIS,
    # so ingestion can keep up at a sane allocation but not at a bad one
    warm = {k: jnp.asarray(v) for k, v in featurize_block(
        raw_block(np.random.RandomState(0), rec), rec).items()}
    params, opt_state, _ = step_fn(params, opt_state, 0, warm)
    t0 = time.monotonic()
    for k in range(3):
        params, opt_state, _ = step_fn(params, opt_state, k, warm)
    jax.block_until_ready(params)
    step_time = (time.monotonic() - t0) / 3
    print(f"measured device step time: {step_time*1e3:.0f} ms")

    from repro.data.proc_executor import ProcessPipeline
    spec = train_feed_pipeline(step_time_s=step_time, work="real")
    # n_cpus=12 bounds how far the tuner's exploration can over-place
    # workers: on a small host, every extra worker steals silicon from
    # the trainer itself, so a huge fake machine makes the warmup phase
    # painfully slow before the agent learns to back off
    machine = MachineSpec(n_cpus=12, mem_mb=4096)
    # pin_cpus=1 leaves the host's remaining cores (if any) to the
    # trainer process; the tuner's CPU headroom is contention-real
    pipe = ProcessPipeline(spec, fns=featurize_stage_fns(spec, record=rec),
                           machine=machine, pin_cpus=1)
    pipe.set_allocation([1] * len(spec.stages), prefetch_mb=32.0)
    # timeout: a cold pipeline must push one batch through every stage
    # serially before anything reaches the sink
    feed = make_train_feed(pipe, depth=2,
                           timeout=max(120.0, 60.0 * step_time))
    # device_step_s: on a shared-core host ingestion steals silicon from
    # the trainer instead of letting it block, so device_idle_frac is
    # scored as 1 - device_busy/wall against the uncontended step time
    backend = FeedBackend(pipe, feed, device_step_s=step_time)
    # init_alloc: start the exploration walk where the pipe actually
    # launched (minimal workers), not at heuristic_even — at a feed
    # boundary the reward is device business, and over-placed workers
    # steal the trainer's own cores
    tuner = InTune(spec, machine, seed=0, head="factored",
                   finetune_ticks=args.finetune_ticks,
                   init_alloc=Allocation(
                       np.ones(len(spec.stages), dtype=int),
                       prefetch_mb=32.0),
                   # live windows are noisy: visit-penalized incumbent
                   # tracking + switch hysteresis (see fig_train_feed)
                   lcb_coef=0.15, switch_margin=0.05)
    session = Session(backend, tuner)

    start, params, opt_state = restore_or_init(
        args.ckpt_dir, params, opt_state, tuner)
    t0 = time.time()
    losses, idle = [], None
    try:
        for i in range(start, args.steps):
            batch = next(feed)
            params, opt_state, metrics = step_fn(params, opt_state, i, batch)
            losses.append(float(metrics["loss"]))
            if i % args.tune_every == 0:
                jax.block_until_ready(params)  # close the step window
                tel = session.step()
                idle = tel.device_idle_frac
            if i % 25 == 0:
                rate = (i - start + 1) * args.batch / (time.time() - t0)
                print(f"step {i:4d} loss {losses[-1]:.4f} "
                      f"({rate:,.0f} samples/s) device_idle "
                      f"{idle if idle is None else round(idle, 3)} "
                      f"workers {pipe.worker_counts()}")
            if (args.ckpt_every and (i + 1) % args.ckpt_every == 0) \
                or i == args.steps - 1:
                save_step(args.ckpt_dir, i, params, opt_state, tuner)
    finally:
        acct = session.close()
        print(f"feed teardown: {acct}")
    print(f"final loss {np.mean(losses[-20:]):.4f} "
          f"(first-20 {np.mean(losses[:20]):.4f}); "
          f"checkpoints in {args.ckpt_dir}")


def run_sim(args):
    """Legacy mode: the tuner tunes a SIMULATED 128-CPU machine; the
    batches fed to the model come from an inline CriteoStream and are
    unaffected by anything the tuner decides."""
    cfg, params, opt, step_fn = build_model(args.batch)
    opt_state = opt.init(params)
    stream = CriteoStream(n_sparse=cfg.n_sparse, n_dense=cfg.n_dense,
                          vocab=cfg.vocab_sizes[0])
    tuner = InTune(criteo_pipeline(), MachineSpec(n_cpus=128), seed=0,
                   head="factored", finetune_ticks=150)
    start, params, opt_state = restore_or_init(
        args.ckpt_dir, params, opt_state, tuner)
    t0 = time.time()
    losses = []
    for i in range(start, args.steps):
        batch = stream.feature_udf(stream.raw_block(args.batch))
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, i, batch)
        # simulated-pipeline tuning in lockstep with training steps; the
        # closed-loop form is `--backend proc` (FeedBackend + Session.step)
        tuner.tick()
        losses.append(float(metrics["loss"]))
        if i % 25 == 0:
            rate = (i - start + 1) * args.batch / (time.time() - t0)
            print(f"step {i:4d} loss {losses[-1]:.4f} "
                  f"({rate:,.0f} samples/s) sim pipeline "
                  f"{tuner.history[-1]['throughput']:.1f} b/s")
        if (args.ckpt_every and (i + 1) % args.ckpt_every == 0) \
            or i == args.steps - 1:
            save_step(args.ckpt_dir, i, params, opt_state, tuner)
    print(f"final loss {np.mean(losses[-20:]):.4f} "
          f"(first-20 {np.mean(losses[:20]):.4f}); "
          f"checkpoints in {args.ckpt_dir}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=2048)
    ap.add_argument("--backend", choices=("proc", "sim"), default="proc",
                    help="proc = tuned ProcessPipeline actually feeds the "
                         "train step (closed loop); sim = tuner runs "
                         "against a simulated machine DETACHED from the "
                         "inline data the model trains on")
    ap.add_argument("--tune-every", type=int, default=2,
                    help="proc backend: train steps per tuning tick")
    ap.add_argument("--finetune-ticks", type=int, default=90,
                    help="proc backend: InTune exploration budget before "
                         "it serves its incumbent best allocation")
    ap.add_argument("--ckpt-every", type=int, default=100,
                    help="checkpoint cadence in steps; 0 = final step only")
    ap.add_argument("--ckpt-dir", default="experiments/ckpt_dlrm")
    args = ap.parse_args(argv)
    if args.backend == "proc":
        run_proc(args)
    else:
        run_sim(args)


if __name__ == "__main__":
    main()
