"""Quickstart: wrap a real threaded data pipeline with InTune (Listing 1).

Builds the paper's 5-stage DLRM ingestion pipeline with REAL thread pools
over the synthetic Criteo stream, attaches the InTune controller, and lets
it re-allocate workers live while a tiny DLRM consumes batches.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DLRMConfig
from repro.core.controller import InTune
from repro.data.executor import ThreadedPipeline
from repro.data.pipeline import criteo_pipeline
from repro.data.simulator import MachineSpec
from repro.data.synthetic import CriteoStream
from repro.models import dlrm as dlrm_lib
from repro.train.optim import make_optimizer
from repro.train.train_step import make_train_step


def main():
    spec = criteo_pipeline(batch_mb=1.0)
    machine = MachineSpec(n_cpus=8, mem_mb=8192)
    stream = CriteoStream(n_sparse=8, n_dense=6, vocab=4096)
    rng = np.random.RandomState(0)

    # ---- the user's pipeline, standard framework code (paper §4.4) ----
    pipe = ThreadedPipeline(
        spec,
        source_fn=lambda: stream.raw_block(256),
        stage_fns=[
            lambda b: CriteoStream.shuffle_udf(b, rng),   # shuffle
            stream.feature_udf,                           # UDF (hot spot)
            CriteoStream.batch_udf,                       # batch
            lambda b: b,                                  # prefetch
        ],
        queue_depth=8, item_mb=1.0, machine=machine)

    # ---- wrap it with InTune: one line + a tuning thread --------------
    tuner = InTune(spec, machine, seed=0,
                   head="factored", finetune_ticks=50)
    tuner.attach(pipe)

    # ---- train a tiny DLRM off the pipeline ---------------------------
    cfg = DLRMConfig(name="dlrm-qs", n_sparse=8, n_dense=6, embed_dim=16,
                     vocab_sizes=(4096,) * 8, bottom_mlp=(32, 16),
                     top_mlp=(64, 32, 1))
    params, _ = dlrm_lib.init_params(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer("adagrad", lr=0.05)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(
        lambda p, b: dlrm_lib.loss_fn(p, cfg, b), opt))

    print("training 30 steps off the live pipeline...")
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in pipe.get_batch().items()}
        params, opt_state, metrics = step(params, opt_state, i, batch)
        if i % 5 == 0:
            stats = tuner.live_tick()   # InTune observes + re-allocates
            print(f"step {i:3d} loss {float(metrics['loss']):.4f} "
                  f"pipeline tput {stats['throughput']:.1f} b/s "
                  f"workers {stats['workers']}")
    pipe.stop()
    print("done — the controller re-allocated the worker pools live.")


if __name__ == "__main__":
    main()
