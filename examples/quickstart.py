"""Quickstart: the one-call API, then drop-in tuning of a real pipeline.

Three escalating integrations of the paper's controller (§4.4, Listing 1),
all through `repro.api` — the single runtime API over every substrate:

  1. `tune(...)` — one line from a pipeline spec to a tuned run on the
     analytic simulator (offline capacity planning / benchmarks).
  2. `tune(..., backend="live")` — the SAME line, now driving a real
     ThreadedPipeline: worker threads realize each stage's true cost and
     throughput is measured, not modeled.
  3. Drop-in: wrap YOUR pipeline (real stage fns over the synthetic
     Criteo stream) with `ExecutorBackend.wrap` and run the tuning
     Session in a background thread while the training loop consumes
     batches — InTune re-allocates the worker pools live under a real
     DLRM training job.

    PYTHONPATH=src python examples/quickstart.py
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ExecutorBackend, Session, tune
from repro.configs.base import DLRMConfig
from repro.core.controller import InTune
from repro.data.executor import ThreadedPipeline
from repro.data.live_fleet import live_linear_pipeline
from repro.data.pipeline import criteo_pipeline
from repro.data.simulator import MachineSpec, PipelineSim
from repro.data.synthetic import CriteoStream
from repro.models import dlrm as dlrm_lib
from repro.train.optim import make_optimizer
from repro.train.train_step import make_train_step


def part1_one_liner():
    print("== 1. tune() on the analytic simulator ==")
    spec = criteo_pipeline()
    machine = MachineSpec(n_cpus=64, mem_mb=65536)
    res = tune(spec, machine, optimizer="intune", backend="sim",
               ticks=250, seed=0)
    steady = float(np.mean(res.throughput[-50:]))
    base = tune(spec, machine, optimizer="autotune", backend="sim",
                ticks=1, seed=0)
    print(f"  InTune steady state {steady:.2f} b/s vs AUTOTUNE-like "
          f"{base.throughput[0]:.2f} b/s (OOMs: {res.oom_count})")


def part2_live_backend():
    print("== 2. the same call on a REAL threaded pipeline ==")
    spec = live_linear_pipeline()          # ms-scale costs: measurable
    machine = MachineSpec(n_cpus=8, mem_mb=4096)
    res = tune(spec, machine, optimizer="oracle", backend="live",
               ticks=12, seed=0, backend_kw={"window_s": 0.1})
    print(f"  measured {float(np.mean(res.throughput[2:])):.1f} b/s "
          f"over {res.ticks} windows | OOMs {res.oom_count} | "
          f"threads joined: {res.extras['live']['all_joined']}")


def part3_drop_in():
    print("== 3. drop-in: tune YOUR pipeline under a live training job ==")
    spec = criteo_pipeline(batch_mb=1.0)
    machine = MachineSpec(n_cpus=8, mem_mb=8192)
    stream = CriteoStream(n_sparse=8, n_dense=6, vocab=4096)
    rng = np.random.RandomState(0)

    # ---- the user's pipeline, standard framework code (paper §4.4) ----
    pipe = ThreadedPipeline(
        spec,
        source_fn=lambda: stream.raw_block(256),
        stage_fns=[
            lambda b: CriteoStream.shuffle_udf(b, rng),   # shuffle
            stream.feature_udf,                           # UDF (hot spot)
            CriteoStream.batch_udf,                       # batch
            lambda b: b,                                  # prefetch
        ],
        queue_depth=8, item_mb=1.0, machine=machine)

    # ---- wrap it: backend + controller + a background Session ---------
    backend = ExecutorBackend.wrap(pipe, window_s=0.2)
    tuner = InTune(spec, machine, seed=0, head="factored",
                   finetune_ticks=50)
    session = Session(backend, tuner)
    driver = threading.Thread(target=lambda: session.run(20), daemon=True)
    driver.start()

    # ---- train a tiny DLRM off the pipeline (the consumer) ------------
    cfg = DLRMConfig(name="dlrm-qs", n_sparse=8, n_dense=6, embed_dim=16,
                     vocab_sizes=(4096,) * 8, bottom_mlp=(32, 16),
                     top_mlp=(64, 32, 1))
    params, _ = dlrm_lib.init_params(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer("adagrad", lr=0.05)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(
        lambda p, b: dlrm_lib.loss_fn(p, cfg, b), opt))

    print("  training 30 steps off the live pipeline...")
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in pipe.get_batch().items()}
        params, opt_state, metrics = step(params, opt_state, i, batch)
        if i % 10 == 0:
            print(f"  step {i:3d} loss {float(metrics['loss']):.4f} "
                  f"workers {pipe.worker_counts()}")
    driver.join(timeout=30)
    acct = session.close()
    print(f"  done — InTune re-allocated the pools live "
          f"(final workers {pipe.worker_counts()}, "
          f"threads joined: {acct['all_joined']})")


if __name__ == "__main__":
    part1_one_liner()
    part2_live_backend()
    part3_drop_in()
