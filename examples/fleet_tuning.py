"""Fleet co-tuning: InTune agents per trainer + a cluster coordinator.

Walks the fleet plane end to end on the canonical 4-machine heterogeneous
cluster (repro.data.fleet.demo_cluster):

  1. the ClusterSpec — machines, shared elastic pool, churn schedule,
  2. what the static fleet policies propose (pool grants per machine),
  3. the FleetCoordinator driving one pretrained InTune DQN per trainer
     through the unified Optimizer protocol against a FleetSim, riding
     out a machine join, a mid-run shrink, and a machine leave — while
     its admission control keeps the memory-tight hosts from OOMing.

    PYTHONPATH=src python examples/fleet_tuning.py
"""
import numpy as np

from repro.api import FleetSimBackend, Session
from repro.core import baselines as B
from repro.core.fleet_coordinator import FleetCoordinator
from repro.core.pretrain import pretrain
from repro.data.fleet import FleetSim, demo_cluster


def show_cluster(cluster):
    print(f"ClusterSpec {cluster.name!r}: shared pool "
          f"{cluster.shared_pool} CPUs")
    for t in cluster.trainers:
        cap = "unbounded" if t.model_latency == 0 \
            else f"{1 / t.model_latency:.1f} b/s"
        print(f"  {t.name:6s} {t.pipeline.name:16s} "
              f"{t.machine.n_cpus:3d} CPUs {t.machine.mem_mb / 1024:4.0f} GB"
              f"  model demand {cap}"
              f"{'' if t.start_active else '  (joins mid-run)'}")
    for ev in cluster.events:
        print(f"  churn @{ev.tick:4d}: {ev.kind} {ev.trainer} "
              f"{ev.n_cpus if ev.kind in ('resize', 'pool') else ''}")


def show_static_policies(cluster):
    state = FleetSim(cluster, seed=0).machine
    print("\nstatic fleet policies (pool grants per machine):")
    for name, fn in B.FLEET_BASELINES.items():
        fa = fn(cluster, state, 0)
        sim = FleetSim(cluster, seed=0)
        m = sim.apply(fa)
        grants = " ".join(f"{k}:+{v}" for k, v in fa.grants.items()) or "-"
        print(f"  {name:20s} {m['throughput']:6.2f} b/s   grants {grants}")


def run_coordinator(cluster, ticks):
    print("\npretraining agents offline (short pass; benchmarks use the "
          "cached full pass)...")
    lengths = sorted({t.pipeline.n_stages for t in cluster.trainers})
    pretrained = {n: pretrain(n, episodes=30, ticks=250, verbose=False,
                              head="factored").state_dict()
                  for n in lengths}
    coord = FleetCoordinator(cluster, pretrained=pretrained, seed=0)
    backend = FleetSimBackend(cluster, seed=0)
    win = ticks // 6
    tputs = []

    def report(t, tel):
        tputs.append(tel.throughput)
        if (t + 1) % win == 0:
            grants = " ".join(f"{k}:+{v}" for k, v in coord.grants.items())
            print(f"  ticks {t + 1 - win:4d}-{t + 1:4d}: "
                  f"mean {np.mean(tputs[-win:]):6.2f} b/s "
                  f"over {tel['n_active']} machines | grants {grants}")

    res = Session(backend, coord).run(ticks, collect=report)
    # score against the ideal fleet (per-tick oracle, no churn cost)
    ref = FleetSim(cluster, seed=0)
    oracle = np.mean([
        ref.apply(B.fleet_oracle(cluster, ref.machine))["throughput"]
        for _ in range(ticks)])
    mean = float(np.mean(res.throughput))
    print(f"\ncoordinator mean {mean:.2f} b/s = "
          f"{100 * mean / oracle:.0f}% of fleet oracle "
          f"(OOMs: {res.oom_count})")


if __name__ == "__main__":
    ticks = 600
    cluster = demo_cluster(ticks)
    show_cluster(cluster)
    show_static_policies(cluster)
    run_coordinator(cluster, ticks)
