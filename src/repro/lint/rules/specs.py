"""Spec hygiene: specs are frozen values, defaults are immutable.

Specs (PipelineSpec, StageSpec, MachineSpec, ...) are the keys of every
golden file and every cache in the repo: two runs agree iff their specs
compare equal. A mutable spec invites in-place edits that alias across
a sweep grid; a mutable default (the classic `def f(x, xs=[])`) shares
one object across every call. Both families are enforced here rather
than by convention.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional, Union

from repro.lint.findings import Finding
from repro.lint.rules import ModuleInfo, Rule

_AnyFunc = Union[ast.FunctionDef, ast.AsyncFunctionDef,
                 ast.Lambda]

_SPEC_SUFFIXES = ("Spec", "Event")
_MUTABLE_CTORS = frozenset({"list", "dict", "set", "bytearray"})


def _dataclass_decorator(node: ast.ClassDef) -> Optional[ast.AST]:
    """The @dataclass / @dataclass(...) decorator node, if present."""
    for dec in node.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = target.attr if isinstance(target, ast.Attribute) else \
            target.id if isinstance(target, ast.Name) else ""
        if name == "dataclass":
            return dec
    return None


def _is_frozen(dec: ast.AST) -> bool:
    if not isinstance(dec, ast.Call):
        return False
    for kw in dec.keywords:
        if kw.arg == "frozen" and isinstance(kw.value, ast.Constant):
            return bool(kw.value.value)
    return False


def _is_mutable_literal(node: ast.AST) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set)):
        return True
    return (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id in _MUTABLE_CTORS and not node.args
            and not node.keywords)


class SpecFrozen(Rule):
    id = "spec-frozen"
    doc = ("dataclasses named *Spec / *Event must be frozen=True: specs "
           "are golden-file keys and must never alias-mutate")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            if not node.name.endswith(_SPEC_SUFFIXES):
                continue
            dec = _dataclass_decorator(node)
            if dec is not None and not _is_frozen(dec):
                yield self.finding(
                    mod, node,
                    f"dataclass {node.name!r} is spec-named but not "
                    f"frozen=True; specs key goldens and caches, so "
                    f"in-place mutation silently invalidates both")


class MutableDefault(Rule):
    id = "mutable-default"
    doc = ("no mutable default values: [] / {} / set() in function params "
           "or dataclass fields share one object across all calls")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from self._check_func(mod, node)
            elif isinstance(node, ast.ClassDef) and \
                    _dataclass_decorator(node) is not None:
                yield from self._check_dataclass(mod, node)

    def _check_func(self, mod: ModuleInfo, node: _AnyFunc
                ) -> Iterator[Finding]:
        args = node.args
        defaults = list(args.defaults) + \
            [d for d in args.kw_defaults if d is not None]
        for default in defaults:
            if _is_mutable_literal(default):
                yield self.finding(
                    mod, default,
                    f"mutable default in {node.name}(); one object is "
                    f"shared across every call — default to None or use "
                    f"a factory")

    def _check_dataclass(self, mod: ModuleInfo, node: ast.ClassDef
                         ) -> Iterator[Finding]:
        for item in node.body:
            if not isinstance(item, ast.AnnAssign) or item.value is None:
                continue
            value = item.value
            # field(default_factory=list) is the sanctioned spelling;
            # field(default=[]) is not.
            if isinstance(value, ast.Call) and \
                    isinstance(value.func, ast.Name) and \
                    value.func.id == "field":
                for kw in value.keywords:
                    if kw.arg == "default" and _is_mutable_literal(kw.value):
                        yield self.finding(
                            mod, kw.value,
                            f"field(default=<mutable>) on "
                            f"{node.name}.{_target_name(item)}; use "
                            f"default_factory")
            elif _is_mutable_literal(value):
                yield self.finding(
                    mod, value,
                    f"mutable class-level default on "
                    f"{node.name}.{_target_name(item)}; all instances "
                    f"share it — use field(default_factory=...)")


def _target_name(item: ast.AnnAssign) -> str:
    return item.target.id if isinstance(item.target, ast.Name) else "<field>"
