"""Sim-plane purity: the golden-pinned modules stay deterministic.

The analytic plane (PipelineSim / FleetSim / ArrivalProcess and the
whole control plane under core/) is scored by same-seed golden files
that CI asserts byte-identical. That only holds while every number those
modules produce is a pure function of (spec, seed, tick): one
`time.time()` in a scoring path, one module-level `np.random.rand()`,
one thread whose scheduling order leaks into accumulation order, and
the goldens become host-dependent — exactly the drift Zhao et al. warn
voids an offline model's authority over online decisions.

Wall-clock reads that feed a *log line* and nothing else are the
sanctioned exception; they carry a pragma whose reason says so.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.lint.findings import Finding
from repro.lint.rules import ModuleInfo, Rule, in_sim_plane

# time-module attributes that read a host clock
_WALL_ATTRS = frozenset({
    "time", "time_ns", "monotonic", "monotonic_ns", "perf_counter",
    "perf_counter_ns", "process_time", "process_time_ns", "clock",
    "clock_gettime", "clock_gettime_ns",
})
_THREAD_MODULES = frozenset({
    "threading", "_thread", "multiprocessing", "concurrent", "asyncio",
})
# seeded-RNG constructors: allowed iff called with an explicit seed arg
_SEEDED_CTORS = frozenset({
    "RandomState", "default_rng", "SeedSequence", "Generator",
})
_NUMPY_NAMES = frozenset({"np", "numpy"})


def _attr_on(node: ast.AST, base: str) -> str:
    """'attr' when node is `<base>.attr`, else ''."""
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == base:
        return node.attr
    return ""


class _SimScoped(Rule):
    def applies(self, path: str) -> bool:
        return in_sim_plane(path)


class SimWallClock(_SimScoped):
    id = "sim-wall-clock"
    doc = ("sim-plane modules must not read a host clock (time.time / "
           "monotonic / perf_counter / ...): goldens must be a pure "
           "function of (spec, seed, tick)")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) and \
                    _attr_on(node.func, "time") in _WALL_ATTRS:
                yield self.finding(
                    mod, node,
                    f"wall-clock read time.{node.func.attr}() in a "
                    f"sim-plane module; derive time from the tick "
                    f"counter (or pragma a log-only read)")
            elif isinstance(node, ast.ImportFrom) and node.module == "time":
                for alias in node.names:
                    if alias.name in _WALL_ATTRS:
                        yield self.finding(
                            mod, node,
                            f"imports wall clock time.{alias.name} into a "
                            f"sim-plane module")


class SimSleep(_SimScoped):
    id = "sim-sleep"
    doc = ("sim-plane modules must not sleep: simulated time advances by "
           "tick, never by the host scheduler")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) and \
                    _attr_on(node.func, "time") == "sleep":
                yield self.finding(
                    mod, node, "time.sleep() in a sim-plane module; the "
                    "sim's clock is the tick counter")
            elif isinstance(node, ast.ImportFrom) and node.module == "time":
                for alias in node.names:
                    if alias.name == "sleep":
                        yield self.finding(
                            mod, node,
                            "imports time.sleep into a sim-plane module")


class SimThreadImport(_SimScoped):
    id = "sim-thread-import"
    doc = ("sim-plane modules must not import threading/multiprocessing: "
           "scheduling order must never reach golden-pinned accumulation "
           "order")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    root = alias.name.split(".")[0]
                    if root in _THREAD_MODULES:
                        yield self.finding(
                            mod, node,
                            f"imports {alias.name} in a sim-plane module; "
                            f"concurrency belongs to the executor planes")
            elif isinstance(node, ast.ImportFrom) and node.module:
                root = node.module.split(".")[0]
                if root in _THREAD_MODULES:
                    yield self.finding(
                        mod, node,
                        f"imports from {node.module} in a sim-plane module; "
                        f"concurrency belongs to the executor planes")


class SimUnseededRng(_SimScoped):
    id = "sim-unseeded-rng"
    doc = ("sim-plane randomness must flow from an explicit seed: no "
           "module-level random.*/np.random.* draws, no seedless "
           "RandomState()/default_rng()")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            # random.<fn>(...) — the stdlib module's hidden global state
            attr = _attr_on(func, "random")
            if attr:
                if attr in ("Random", "SystemRandom") and node.args:
                    continue          # random.Random(seed) is seeded
                yield self.finding(
                    mod, node,
                    f"random.{attr}() draws from the stdlib's global RNG; "
                    f"thread an explicit seeded generator through instead")
                continue
            # np.random.<fn>(...) — numpy's hidden global state
            if isinstance(func, ast.Attribute) and \
                    isinstance(func.value, ast.Attribute) and \
                    isinstance(func.value.value, ast.Name) and \
                    func.value.value.id in _NUMPY_NAMES and \
                    func.value.attr == "random":
                if func.attr in _SEEDED_CTORS and node.args:
                    continue          # np.random.RandomState(seed) et al.
                if func.attr in _SEEDED_CTORS:
                    yield self.finding(
                        mod, node,
                        f"np.random.{func.attr}() without an explicit "
                        f"seed; pass the spec/ctor seed through")
                else:
                    yield self.finding(
                        mod, node,
                        f"np.random.{func.attr}() draws from numpy's "
                        f"global RNG; use a seeded RandomState/Generator")
