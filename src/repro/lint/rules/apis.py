"""Forbidden APIs: teardown calls the shutdown protocol outlaws.

The soft/hard `shutdown(drain=)` protocol (DESIGN.md §7/§9) is the only
sanctioned way out of a pipeline: soft-stop so in-flight items commit,
drain, hard-stop, join every process ever started. Two API families
routinely tempt code out of that protocol:

  - `Queue.cancel_join_thread()`: documented-forbidden since PR 6 — a
    queue feeder killed mid-write holds the queue's cross-process write
    lock, and cancelling the join orphans that lock, wedging every other
    writer on the queue permanently. The one sanctioned parent-side
    teardown site carries a pragma explaining why it cannot wedge.
  - bare `mp.Queue()` construction outside a class implementing
    `shutdown(drain=...)`: a queue nobody is contracted to drain is a
    queue whose writers block forever at teardown.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional

from repro.lint.findings import Finding
from repro.lint.rules import ModuleInfo, Rule

_MP_NAMES = frozenset({"mp", "multiprocessing", "ctx"})
_MP_QUEUE_CTORS = frozenset({"Queue", "SimpleQueue", "JoinableQueue"})


class NoCancelJoinThread(Rule):
    id = "no-cancel-join-thread"
    doc = ("Queue.cancel_join_thread() is banned (PR 6): cancelling a "
           "feeder that holds the queue write lock orphans the lock and "
           "wedges every writer")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "cancel_join_thread":
                yield self.finding(
                    mod, node,
                    "cancel_join_thread() can orphan the queue's "
                    "cross-process write lock; drain + join via the "
                    "shutdown(drain=) protocol instead")


class MpQueueProtocol(Rule):
    id = "mp-queue-protocol"
    doc = ("multiprocessing queues may only be constructed inside a class "
           "implementing the soft/hard shutdown(drain=) protocol")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        yield from self._scan(mod, mod.tree.body, owner=None)

    # ------------------------------------------------------------------
    def _scan(self, mod: ModuleInfo, body: List[ast.stmt],
              owner: Optional[ast.ClassDef]
              ) -> Iterator[Finding]:
        for node in body:
            if isinstance(node, ast.ClassDef):
                yield from self._scan(mod, node.body, owner=node)
            else:
                for sub in ast.walk(node):
                    if self._is_mp_queue_ctor(sub) and \
                            not self._has_shutdown_protocol(owner):
                        where = f"class {owner.name!r}" if owner else \
                            "module scope"
                        yield self.finding(
                            mod, sub,
                            f"mp queue constructed in {where}, which does "
                            f"not implement shutdown(drain=...); queues "
                            f"need a contracted drain-and-join owner")

    @staticmethod
    def _is_mp_queue_ctor(node: ast.AST) -> bool:
        return (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in _MP_QUEUE_CTORS
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id in _MP_NAMES)

    @staticmethod
    def _has_shutdown_protocol(owner: Optional[ast.ClassDef]) -> bool:
        if owner is None:
            return False
        for item in owner.body:
            if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and item.name == "shutdown":
                args = item.args
                names = [a.arg for a in args.args + args.kwonlyargs]
                return "drain" in names
        return False
