"""Concurrency analysis: lock-order cycles and blocking-while-locked.

Scope: the four modules that hold real locks across real threads and
processes (executor, proc_executor, live_fleet, device_feed). Two
invariants:

  - `lock-order-cycle`: the module's lock-acquisition graph (an edge
    A -> B whenever B is acquired while A is held, collected from
    `with`-statements and `.acquire()` calls) must be acyclic. A cycle
    is a deadlock waiting for the right interleaving.
  - `blocking-while-locked`: no unbounded blocking call (Queue.get/put
    with no timeout, join()/wait()/acquire() with no timeout) while any
    lock is held. A blocked holder stalls every other thread at the
    lock, turning one slow queue into a pipeline-wide freeze — and if
    the awaited party needs that same lock, a deadlock.

Lock identity is textual (`ast.unparse` of the receiver), which is the
right granularity here: the executor modules name their locks
(`self._lock`, `self.gather_lock`, `counter.get_lock()`) and never
alias them through locals.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.lint.findings import Finding
from repro.lint.rules import ModuleInfo, Rule, in_concurrency_scope

_LOCK_WORDS = ("lock", "mutex")
_BLOCKING_ATTRS = frozenset({"get", "put", "join", "wait", "acquire"})


def _last_segment(node: ast.AST) -> str:
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return ""


def _lock_expr(node: ast.AST) -> Optional[str]:
    """The textual identity of `node` when it looks like a lock."""
    # counter.get_lock() — multiprocessing.Value's guard
    if isinstance(node, ast.Call) and \
            isinstance(node.func, ast.Attribute) and \
            node.func.attr == "get_lock":
        return ast.unparse(node)
    seg = _last_segment(node).lower()
    if any(w in seg for w in _LOCK_WORDS):
        return ast.unparse(node)
    return None


def _kw(call: ast.Call, name: str) -> Optional[ast.expr]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _is_blocking(call: ast.Call) -> Tuple[bool, str]:
    """(blocks unboundedly, receiver description) for a method call."""
    if not isinstance(call.func, ast.Attribute):
        return False, ""
    attr = call.func.attr
    if attr not in _BLOCKING_ATTRS:
        return False, ""
    recv = ast.unparse(call.func.value)
    if attr == "get":
        # dict.get(key, default) carries positional args; Queue.get()
        # with a timeout kwarg is bounded.
        blocks = not call.args and _kw(call, "timeout") is None \
            and _kw(call, "block") is None
    elif attr == "put":
        blocks = _kw(call, "timeout") is None and _kw(call, "block") is None
    elif attr == "join":
        # str.join(parts) carries an arg; Thread/Process.join() does not.
        blocks = not call.args and _kw(call, "timeout") is None
    elif attr == "acquire":
        blocks = not call.args and _kw(call, "timeout") is None \
            and _kw(call, "blocking") is None
    else:  # wait
        blocks = not call.args and _kw(call, "timeout") is None
    return blocks, f"{recv}.{attr}"


@dataclass
class _LockGraph:
    """A -> B edges meaning B was acquired while A was held."""
    edges: Dict[str, Set[str]] = field(default_factory=dict)
    sites: Dict[Tuple[str, str], ast.AST] = field(default_factory=dict)

    def add(self, held: str, acquired: str, node: ast.AST) -> None:
        self.edges.setdefault(held, set()).add(acquired)
        self.sites.setdefault((held, acquired), node)

    def cycles(self) -> List[List[str]]:
        """Each cycle as the node path [a, b, ..., a], deterministically."""
        out: List[List[str]] = []
        seen_cycles: Set[Tuple[str, ...]] = set()

        def dfs(node: str, path: List[str], on_path: Set[str]) -> None:
            for nxt in sorted(self.edges.get(node, ())):
                if nxt in on_path:
                    cyc = path[path.index(nxt):] + [nxt]
                    key = tuple(sorted(cyc[:-1]))
                    if key not in seen_cycles:
                        seen_cycles.add(key)
                        out.append(cyc)
                    continue
                dfs(nxt, path + [nxt], on_path | {nxt})

        for start in sorted(self.edges):
            dfs(start, [start], {start})
        return out


class _HeldWalker:
    """Walks one function's statements tracking which locks are held.

    `with lock:` holds for the body; `lock.acquire()` holds until a
    matching `lock.release()` in the same suite (or the suite ends).
    """

    def __init__(self, graph: _LockGraph):
        self.graph = graph
        self.held: List[str] = []                 # acquisition order
        self.blocking: List[Tuple[ast.Call, str, str]] = []  # node, what, lock

    # -- acquisition bookkeeping ---------------------------------------
    def _acquire(self, lock: str, node: ast.AST) -> None:
        for h in self.held:
            if h != lock:
                self.graph.add(h, lock, node)
        self.held.append(lock)

    def _release(self, lock: str) -> None:
        if lock in self.held:
            self.held.remove(lock)

    # -- statement traversal -------------------------------------------
    def walk_suite(self, body: List[ast.stmt]) -> None:
        entered = len(self.held)
        for stmt in body:
            self._walk_stmt(stmt)
        # acquire() without release() does not leak past its suite
        del self.held[entered:]

    def _walk_stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            locks = []
            for item in stmt.items:
                lock = _lock_expr(item.context_expr)
                if lock is not None:
                    self._acquire(lock, item.context_expr)
                    locks.append(lock)
                else:
                    self._scan_expr(item.context_expr)
            self.walk_suite(stmt.body)
            for lock in reversed(locks):
                self._release(lock)
            return
        if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call):
            call = stmt.value
            if isinstance(call.func, ast.Attribute):
                recv_lock = _lock_expr(call.func.value)
                if recv_lock is not None and call.func.attr == "acquire":
                    self._scan_expr(call)       # may itself block
                    self._acquire(recv_lock, call)
                    return
                if recv_lock is not None and call.func.attr == "release":
                    self._release(recv_lock)
                    return
        # nested suites: functions defined inline run later, on their
        # own stack — analyze them with a fresh held-set.
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            inner = _HeldWalker(self.graph)
            inner.walk_suite(stmt.body)
            self.blocking.extend(inner.blocking)
            return
        for expr in ast.iter_child_nodes(stmt):
            if isinstance(expr, ast.expr):
                self._scan_expr(expr)
        for attr in ("body", "orelse", "finalbody"):
            sub = getattr(stmt, attr, None)
            if sub:
                self.walk_suite(sub)
        for handler in getattr(stmt, "handlers", []) or []:
            self.walk_suite(handler.body)

    def _scan_expr(self, expr: ast.expr) -> None:
        if not self.held:
            return
        for node in ast.walk(expr):
            if isinstance(node, ast.Call):
                blocks, what = _is_blocking(node)
                if blocks and what:
                    # re-acquiring a held lock is a deadlock too, but the
                    # interesting report is the blocking call itself
                    self.blocking.append((node, what, self.held[-1]))


def _analyze(mod: ModuleInfo) -> Tuple[_LockGraph, List[Tuple[ast.Call, str, str]]]:
    graph = _LockGraph()
    blocking: List[Tuple[ast.Call, str, str]] = []
    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            walker = _HeldWalker(graph)
            walker.walk_suite(node.body)
            blocking.extend(walker.blocking)
    return graph, blocking


class _ConcScoped(Rule):
    def applies(self, path: str) -> bool:
        return in_concurrency_scope(path)


class LockOrderCycle(_ConcScoped):
    id = "lock-order-cycle"
    doc = ("the per-module lock-acquisition graph must be acyclic: a "
           "cycle A->B->A deadlocks under the right interleaving")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        graph, _ = _analyze(mod)
        for cycle in graph.cycles():
            site = graph.sites.get((cycle[0], cycle[1]), mod.tree)
            yield self.finding(
                mod, site,
                f"lock-order cycle {' -> '.join(cycle)}; impose one "
                f"global acquisition order")


class BlockingWhileLocked(_ConcScoped):
    id = "blocking-while-locked"
    doc = ("no unbounded blocking call (get/put/join/wait/acquire without "
           "timeout) while holding a lock")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        _, blocking = _analyze(mod)
        for node, what, lock in blocking:
            yield self.finding(
                mod, node,
                f"unbounded {what}() while holding {lock}; a stalled "
                f"counterpart freezes every thread waiting on the lock — "
                f"use a timeout and re-check, or move the call outside "
                f"the critical section")
