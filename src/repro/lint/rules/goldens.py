"""Golden stability: telemetry records only grow with None defaults.

Telemetry and RunResult are serialized into golden JSON files that CI
compares byte-for-byte. A new field with a live default (0.0, "", [])
changes every serialized record and invalidates every golden at once;
a new field defaulting to None keeps old records parseable and old
goldens byte-identical (the serializer drops Nones). The baseline
field sets below are the PR-2 shapes the first goldens were pinned
against — fields in the baseline keep their original defaults, fields
added since must default None.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.lint.findings import Finding
from repro.lint.rules import ModuleInfo, Rule

# The field sets the first goldens were pinned against (PR 2). Do not
# grow these sets: that is the point of the rule.
_BASELINES = {
    "Telemetry": frozenset({
        "throughput", "mem_mb", "used_cpus", "oom", "restarting", "extras",
    }),
    "RunResult": frozenset({
        "throughput", "used_cpus", "mem_mb", "oom_count", "extras",
    }),
}


class GoldenFieldDefault(Rule):
    id = "golden-field-default"
    doc = ("fields added to Telemetry/RunResult after the golden baseline "
           "must default to None so pinned goldens stay byte-identical")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            baseline = _BASELINES.get(node.name)
            if baseline is None:
                continue
            for item in node.body:
                if not isinstance(item, ast.AnnAssign):
                    continue
                name = item.target.id if isinstance(item.target, ast.Name) \
                    else None
                if name is None or name.startswith("_") or name in baseline:
                    continue
                if not _defaults_to_none(item.value):
                    yield self.finding(
                        mod, item,
                        f"{node.name}.{name} is post-baseline but does not "
                        f"default to None; a live default rewrites every "
                        f"pinned golden record")


def _defaults_to_none(value: Optional[ast.expr]) -> bool:
    if value is None:
        return False                  # no default at all: also breaks goldens
    if isinstance(value, ast.Constant) and value.value is None:
        return True
    # field(default=None, ...) spelling
    if isinstance(value, ast.Call) and isinstance(value.func, ast.Name) \
            and value.func.id == "field":
        for kw in value.keywords:
            if kw.arg == "default" and isinstance(kw.value, ast.Constant) \
                    and kw.value.value is None:
                return True
    return False
