"""Interprocedural concurrency analysis: held-lock sets across calls.

PR 9's lock-graph pass (rules/concurrency.py) is intra-function and
per-module, so a lock inversion split across a call boundary — the
shape of every real deadlock this repo has audited — is invisible to
it. This pass propagates held-lock sets across calls the project call
graph (lint/callgraph.py) can resolve, bounded-depth and cycle-safe:

  - `xfn-lock-order-cycle`: the WHOLE-PROGRAM lock graph (lock ids
    qualified by owning class, so `self._lock` of two classes never
    alias) must be acyclic. Fires only on cycles the per-module intra
    rule cannot see: at least one edge acquired in a different frame
    than its held lock, or edges spanning modules.
  - `xfn-blocking-while-locked`: an unbounded blocking call made while
    holding a lock acquired by a CALLER frame. The callee looks clean
    in isolation; the deadlock only exists on the combined stack.
  - `resource-lifecycle`: every Thread/Process/pool spawn site must
    have a join()/shutdown()/terminate() reachable from its owning
    class (or owning function), over resolved calls — a spawn nobody
    is contracted to reap is a leak the churn soak can only catch
    probabilistically.

Lock identity: `self.attr` qualifies to `<module-stem>.<Class>.<attr>`
(one node per class attribute — the standard may-alias
over-approximation across instances); a lock-typed argument to a
resolved call renames the callee's parameter onto the caller's lock id;
anything else qualifies to `<module-stem>.<text>`. Unresolved calls are
recorded, never guessed — the runtime sanitizer (lint/runtime.py) is
the cross-check for what this pass cannot see.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

from repro.lint.callgraph import CallGraph, ClassKey, FuncKey, _stem
from repro.lint.findings import Finding
from repro.lint.rules import (ModuleInfo, ProjectRule, in_xfn_scope)
from repro.lint.rules.concurrency import _is_blocking, _lock_expr

_MAX_DEPTH = 8

_SPAWN_CTORS = frozenset({"Thread", "Process"})
_POOL_CTORS = frozenset({"ThreadPoolExecutor", "ProcessPoolExecutor"})
_REAP_ATTRS = frozenset({"join", "terminate", "shutdown", "kill"})
_THREAD_BASES = frozenset({"Thread", "threading.Thread", "Process",
                           "multiprocessing.Process", "mp.Process"})


@dataclass(frozen=True)
class _Edge:
    """held -> acquired, with the evidence needed for a finding."""
    held: str
    acquired: str


@dataclass
class _EdgeInfo:
    mod: ModuleInfo
    node: ast.AST
    cross: bool                       # held lock came from another frame
    chain: Tuple[str, ...]            # call chain to the acquire site


@dataclass
class _Block:
    """One blocking-call-under-caller-lock event."""
    mod: ModuleInfo
    node: ast.Call
    what: str
    lock: str
    chain: Tuple[str, ...]


@dataclass
class XfnAnalysis:
    """The shared result both xfn rules (and --runtime-report) consume."""
    edges: Dict[_Edge, _EdgeInfo] = field(default_factory=dict)
    blocking: List[_Block] = field(default_factory=list)
    graph: Dict[str, Set[str]] = field(default_factory=dict)

    def add_edge(self, held: str, acquired: str, info: _EdgeInfo) -> None:
        self.graph.setdefault(held, set()).add(acquired)
        key = _Edge(held, acquired)
        prev = self.edges.get(key)
        # keep the strongest evidence: a cross-frame sighting wins
        if prev is None or (info.cross and not prev.cross):
            self.edges[key] = info

    def cycles(self) -> List[List[str]]:
        out: List[List[str]] = []
        seen: Set[Tuple[str, ...]] = set()

        def dfs(node: str, path: List[str], on_path: Set[str]) -> None:
            for nxt in sorted(self.graph.get(node, ())):
                if nxt in on_path:
                    cyc = path[path.index(nxt):] + [nxt]
                    key = tuple(sorted(cyc[:-1]))
                    if key not in seen:
                        seen.add(key)
                        out.append(cyc)
                    continue
                dfs(nxt, path + [nxt], on_path | {nxt})

        for start in sorted(self.graph):
            dfs(start, [start], {start})
        return out


@dataclass
class _Held:
    lock: str
    frame: int                        # chain depth at acquisition


class _XWalker:
    """One frame of the interprocedural walk. Mirrors concurrency.py's
    `_HeldWalker` statement discipline (with/acquire/release, suite-
    scoped acquire, fresh stack for nested defs) but with qualified
    lock ids, caller-held propagation, and call recursion."""

    def __init__(self, analysis: XfnAnalysis, cg: CallGraph, fk: FuncKey,
                 held: List[_Held], chain: Tuple[FuncKey, ...],
                 renames: Dict[str, str]):
        self.analysis = analysis
        self.cg = cg
        self.fk = fk
        self.mod = cg.funcs[fk].mod
        self.cls = cg.funcs[fk].cls
        self.held = held
        self.chain = chain
        self.depth = len(chain) - 1
        self.renames = renames
        self.locals = cg.local_types(fk)

    # -------------------------------------------------------- identities --
    def qualify(self, node: ast.AST, text: str) -> str:
        """Map a lock expression to its whole-program node id."""
        stem = _stem(self.fk.module)
        parts = text.split(".")
        if parts[0] == "self" and self.cls is not None:
            if len(parts) >= 3:
                own = self.cg.classes.get(ClassKey(self.fk.module, self.cls))
                tck = own.attr_types.get(parts[1]) if own else None
                if tck is not None:
                    return f"{_stem(tck.module)}.{tck.name}." \
                           f"{'.'.join(parts[2:])}"
            return f"{stem}.{self.cls}.{'.'.join(parts[1:])}"
        if text in self.renames:
            return self.renames[text]
        return f"{stem}.{text}"

    # ------------------------------------------------------- acquisition --
    def _acquire(self, lock: str, node: ast.AST) -> None:
        for h in self.held:
            if h.lock != lock:
                self.analysis.add_edge(h.lock, lock, _EdgeInfo(
                    mod=self.mod, node=node,
                    cross=h.frame != self.depth,
                    chain=tuple(str(f) for f in self.chain)))
        self.held.append(_Held(lock, self.depth))

    def _release(self, lock: str) -> None:
        for i in range(len(self.held) - 1, -1, -1):
            if self.held[i].lock == lock:
                del self.held[i]
                return

    # ---------------------------------------------------------- walking --
    def walk_suite(self, body: List[ast.stmt]) -> None:
        entered = len(self.held)
        for stmt in body:
            self._walk_stmt(stmt)
        del self.held[entered:]

    def _walk_stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            locks = []
            for item in stmt.items:
                lock = _lock_expr(item.context_expr)
                if lock is not None:
                    qid = self.qualify(item.context_expr, lock)
                    self._acquire(qid, item.context_expr)
                    locks.append(qid)
                else:
                    self._scan_expr(item.context_expr)
            self.walk_suite(stmt.body)
            for qid in reversed(locks):
                self._release(qid)
            return
        if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call):
            call = stmt.value
            if isinstance(call.func, ast.Attribute):
                recv_lock = _lock_expr(call.func.value)
                if recv_lock is not None and call.func.attr == "acquire":
                    self._scan_expr(call)
                    self._acquire(self.qualify(call.func.value, recv_lock),
                                  call)
                    return
                if recv_lock is not None and call.func.attr == "release":
                    self._release(self.qualify(call.func.value, recv_lock))
                    return
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # a nested def runs later, on its own stack: fresh held set
            # (its body is covered when IT is analyzed as a root — the
            # closure's lock names are out of this frame's rename scope)
            return
        for expr in ast.iter_child_nodes(stmt):
            if isinstance(expr, ast.expr):
                self._scan_expr(expr)
        for attr in ("body", "orelse", "finalbody"):
            sub = getattr(stmt, attr, None)
            if sub:
                self.walk_suite(sub)
        for handler in getattr(stmt, "handlers", []) or []:
            self.walk_suite(handler.body)

    def _scan_expr(self, expr: ast.expr) -> None:
        for node in ast.walk(expr):
            if not isinstance(node, ast.Call):
                continue
            if self.held:
                blocks, what = _is_blocking(node)
                if blocks and what:
                    # only the CROSS-frame holds are this rule's: a
                    # same-frame hold is blocking-while-locked territory
                    for h in reversed(self.held):
                        if h.frame != self.depth:
                            self.analysis.blocking.append(_Block(
                                mod=self.mod, node=node, what=what,
                                lock=h.lock,
                                chain=tuple(str(f) for f in self.chain)))
                            break
            self._maybe_recurse(node)

    # --------------------------------------------------------- recursion --
    def _maybe_recurse(self, call: ast.Call) -> None:
        if not self.held or self.depth + 1 >= _MAX_DEPTH:
            return
        callee = self.cg.resolve_call(self.fk, call, self.locals)
        if callee is None or callee in self.chain:
            return
        fn = self.cg.funcs.get(callee)
        if fn is None:
            return
        renames = self._param_renames(call, callee)
        inner = _XWalker(self.analysis, self.cg, callee, self.held,
                         self.chain + (callee,), renames)
        inner.walk_suite(fn.node.body)

    def _param_renames(self, call: ast.Call, callee: FuncKey
                       ) -> Dict[str, str]:
        """Map the callee's parameters onto the caller's lock ids for
        lock-looking arguments, so a lock passed by argument keeps one
        whole-program identity across the call."""
        fn = self.cg.funcs[callee]
        params = [a.arg for a in fn.node.args.args]
        if fn.cls is not None and params and params[0] in ("self", "cls"):
            params = params[1:]
        out: Dict[str, str] = {}
        for i, arg in enumerate(call.args):
            lock = _lock_expr(arg)
            if lock is not None and i < len(params):
                out[params[i]] = self.qualify(arg, lock)
        for kw in call.keywords:
            if kw.arg is None:
                continue
            lock = _lock_expr(kw.value)
            if lock is not None and kw.arg in [a.arg
                                               for a in fn.node.args.args]:
                out[kw.arg] = self.qualify(kw.value, lock)
        return out


def analyze_project(mods: Sequence[ModuleInfo],
                    cg: Optional[CallGraph] = None) -> XfnAnalysis:
    """Run the interprocedural pass: every function of every in-scope
    module is a root; calls recurse only while a lock is held (a
    lock-free call chain is fully covered by the callee's own root
    walk), bounded at depth 8 and cycle-safe on the call chain."""
    if cg is None:
        cg = CallGraph(mods)
    analysis = XfnAnalysis()
    for fk in sorted(cg.funcs):
        if not in_xfn_scope(fk.module):
            continue
        walker = _XWalker(analysis, cg, fk, held=[], chain=(fk,),
                          renames={})
        walker.walk_suite(cg.funcs[fk].node.body)
    return analysis


def static_edge_set(mods: Sequence[ModuleInfo]) -> Set[Tuple[str, str]]:
    """The whole-program lock-order edges as (held, acquired) id pairs —
    what `--runtime-report` diffs the observed graph against."""
    analysis = analyze_project(mods)
    return {(e.held, e.acquired) for e in analysis.edges}


class _XfnScoped(ProjectRule):
    def applies(self, path: str) -> bool:
        return in_xfn_scope(path)


def _run_once(mods: Sequence[ModuleInfo]) -> XfnAnalysis:
    # one analysis per module set per engine run: both rules read it
    key = tuple(id(m) for m in mods)
    cached = _ANALYSIS_CACHE.get(key)
    if cached is None:
        cached = analyze_project(mods)
        _ANALYSIS_CACHE.clear()           # one entry: runs don't overlap
        _ANALYSIS_CACHE[key] = cached
    return cached


_ANALYSIS_CACHE: Dict[Tuple[int, ...], XfnAnalysis] = {}


class XfnLockOrderCycle(_XfnScoped):
    id = "xfn-lock-order-cycle"
    doc = ("the WHOLE-PROGRAM lock graph (held sets propagated across "
           "resolved calls) must be acyclic; fires only on cycles the "
           "per-module rule cannot see")

    def check_project(self, mods: Sequence[ModuleInfo]
                      ) -> Iterator[Finding]:
        analysis = _run_once(mods)
        for cycle in analysis.cycles():
            infos = [analysis.edges[_Edge(a, b)]
                     for a, b in zip(cycle, cycle[1:])]
            cross = [i for i in infos if i.cross]
            modules = {i.mod.path for i in infos}
            if not cross and len(modules) <= 1:
                continue                  # the intra rule's finding
            info = cross[0] if cross else infos[0]
            if not self.applies(info.mod.path):
                continue
            via = " via " + " -> ".join(info.chain) if len(info.chain) > 1 \
                else ""
            yield self.finding(
                info.mod, info.node,
                f"cross-function lock-order cycle "
                f"{' -> '.join(cycle)}{via}; impose one global "
                f"acquisition order across the call boundary")


class XfnBlockingWhileLocked(_XfnScoped):
    id = "xfn-blocking-while-locked"
    doc = ("no unbounded blocking call while holding a lock acquired by "
           "a CALLER frame (the callee looks clean in isolation; the "
           "freeze only exists on the combined stack)")

    def check_project(self, mods: Sequence[ModuleInfo]
                      ) -> Iterator[Finding]:
        analysis = _run_once(mods)
        seen: Set[Tuple[str, int, str]] = set()
        for b in analysis.blocking:
            if not self.applies(b.mod.path):
                continue
            key = (b.mod.path, b.node.lineno, b.lock)
            if key in seen:
                continue
            seen.add(key)
            yield self.finding(
                b.mod, b.node,
                f"unbounded {b.what}() while {b.lock} is held by a "
                f"caller ({' -> '.join(b.chain)}); use a timeout and "
                f"re-check, or move the call out of the critical "
                f"section")


class ResourceLifecycle(_XfnScoped):
    id = "resource-lifecycle"
    doc = ("every Thread/Process/pool spawn site must have a reachable "
           "join()/shutdown()/terminate() in its owning class")

    def check_project(self, mods: Sequence[ModuleInfo]
                      ) -> Iterator[Finding]:
        cg = CallGraph(mods)
        reaps = {fk: self._has_reap(cg.funcs[fk].node) for fk in cg.funcs}
        for mod in mods:
            if not self.applies(mod.path):
                continue
            yield from self._check_module(mod, cg, reaps)

    # ------------------------------------------------------------ spawns --
    def _check_module(self, mod: ModuleInfo, cg: CallGraph,
                      reaps: Dict[FuncKey, bool]) -> Iterator[Finding]:
        for fk in sorted(cg.funcs):
            if fk.module != mod.path:
                continue
            fn = cg.funcs[fk]
            # nested defs are walked as part of their enclosing function
            # (the call graph does not index closures), so their spawns
            # are charged to the enclosing owner — the right contract:
            # whoever's code spawned it must be able to reap it
            for node in ast.walk(fn.node):
                if isinstance(node, ast.Call) and \
                        self._is_spawn(node, cg, mod.path):
                    if not self._owner_reaps(fk, cg, reaps):
                        owner = fn.cls if fn.cls is not None else \
                            f"{fk.qual}()"
                        yield self.finding(
                            mod, node,
                            f"thread/process spawned here has no "
                            f"reachable join()/shutdown()/terminate() "
                            f"in its owner {owner!r}; an unreaped "
                            f"spawn is a leak the churn soak can only "
                            f"catch probabilistically")

    def _is_spawn(self, call: ast.Call, cg: CallGraph, module: str) -> bool:
        name = ""
        if isinstance(call.func, ast.Attribute):
            name = call.func.attr
        elif isinstance(call.func, ast.Name):
            name = call.func.id
        if name in _POOL_CTORS:
            return True
        if name in _SPAWN_CTORS:
            # require target= so `psutil.Process(pid)` (an info query)
            # and `str`-ish constructors never register as spawns
            return any(kw.arg == "target" for kw in call.keywords)
        # instantiation of a project class that IS a Thread/Process
        ck = cg.lookup_class(name, module)
        while ck is not None:
            cn = cg.classes.get(ck)
            if cn is None:
                return False
            if any(b in _THREAD_BASES or b.split(".")[-1] in _SPAWN_CTORS
                   for b in cn.bases):
                return True
            nxt = None
            for b in cn.bases:
                nxt = cg.lookup_class(b, ck.module)
                if nxt is not None:
                    break
            ck = nxt
        return False

    # ------------------------------------------------------------- reaps --
    @staticmethod
    def _has_reap(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> bool:
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    node.func.attr in _REAP_ATTRS and \
                    not isinstance(node.func.value, ast.Constant):
                return True               # ','.join(...) excluded above
        return False

    def _owner_reaps(self, spawn_fk: FuncKey, cg: CallGraph,
                     reaps: Dict[FuncKey, bool]) -> bool:
        """A reap call reachable (resolved calls, bounded) from any
        method of the spawning class — or from the spawning function
        itself when the spawn is not method-owned."""
        fn = cg.funcs[spawn_fk]
        if fn.cls is not None:
            ck = ClassKey(spawn_fk.module, fn.cls)
            cn = cg.classes.get(ck)
            roots = sorted(cn.methods.values()) if cn else [spawn_fk]
        else:
            roots = [spawn_fk]
        seen: Set[FuncKey] = set()
        stack: List[Tuple[FuncKey, int]] = [(r, 0) for r in roots]
        while stack:
            fk, depth = stack.pop()
            if fk in seen or depth >= _MAX_DEPTH:
                continue
            seen.add(fk)
            if reaps.get(fk, False):
                return True
            node = cg.funcs.get(fk)
            if node is None:
                continue
            locals_ = cg.local_types(fk)
            for sub in ast.walk(node.node):
                if isinstance(sub, ast.Call):
                    callee = cg.resolve_call(fk, sub, locals_)
                    if callee is not None and callee not in seen:
                        stack.append((callee, depth + 1))
        return False
