"""Rule registry + the shared Rule / ModuleInfo machinery.

A rule is one invariant with one stable kebab-case id. Rules are scoped
by path — the sim-plane purity family only runs over the modules whose
same-seed goldens CI pins, the concurrency family only over the
executor modules that hold real locks — so adding a rule never taxes
unrelated code. The registry below is THE list; the CLI's --list-rules,
the pragma validator's known-rule check, and DESIGN.md §13's table all
read from it.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import Iterator, List, Sequence, Tuple

from repro.lint.findings import Finding


@dataclass(frozen=True)
class ModuleInfo:
    """One parsed source file handed to every applicable rule."""
    path: str                    # posix-style path as reported in findings
    tree: ast.Module
    text: str

    def parts(self) -> Tuple[str, ...]:
        return tuple(self.path.replace("\\", "/").split("/"))

    def line(self, lineno: int) -> str:
        lines = self.text.splitlines()
        if 1 <= lineno <= len(lines):
            return lines[lineno - 1].strip()
        return ""


class Rule:
    """One invariant: a stable id, a one-line contract, a path scope,
    and a `check` that yields findings. Subclasses override `check`."""

    id: str = ""
    doc: str = ""                # one line: the contract being enforced

    def applies(self, path: str) -> bool:
        return True

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        raise NotImplementedError

    def finding(self, mod: ModuleInfo, node: ast.AST, message: str
                ) -> Finding:
        lineno = getattr(node, "lineno", 1)
        return Finding(mod.path, lineno,
                       getattr(node, "col_offset", 0), self.id, message,
                       snippet=mod.line(lineno))


class ProjectRule(Rule):
    """A whole-program invariant: `check_project` sees EVERY parsed
    module of the run at once (the interprocedural passes need the full
    call graph even when only a few modules are in their finding scope).
    `applies` still gates which paths may *carry findings*; the per-
    module `check` is a no-op so the engine can treat both kinds
    uniformly."""

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        return iter(())

    def check_project(self, mods: Sequence[ModuleInfo]
                      ) -> Iterator[Finding]:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# path scopes
# ---------------------------------------------------------------------------

# The modules whose same-seed goldens CI pins (fig5 byte-identical JSON,
# market determinism traces, fleet oracle scores): any wall-clock read,
# sleep, thread, or unseeded RNG here makes a "deterministic" score a
# function of the host, silently voiding every golden.
SIM_PLANE_FILES = (
    ("data", "simulator.py"),
    ("data", "fleet.py"),
    ("data", "stream.py"),
    ("data", "pipeline.py"),
)
SIM_PLANE_DIRS = ("core",)

# The modules that hold real locks across real threads/processes — the
# concurrency family's lock-graph analysis runs here.
CONCURRENCY_FILES = (
    ("data", "executor.py"),
    ("data", "proc_executor.py"),
    ("data", "live_fleet.py"),
    ("data", "device_feed.py"),
)

# The interprocedural passes' finding scope: the four executor modules
# plus fleet.py (FleetBackend's churn hooks are the cross-module call
# surface LiveFleet/ProcFleet lock traffic flows through). The call
# graph itself spans every module handed to the run — only findings are
# scoped.
XFN_FILES = CONCURRENCY_FILES + (("data", "fleet.py"),)


def in_sim_plane(path: str) -> bool:
    parts = tuple(path.replace("\\", "/").split("/"))
    if parts[-2:] in [tuple(f) for f in SIM_PLANE_FILES]:
        return True
    return len(parts) >= 2 and parts[-2] in SIM_PLANE_DIRS


def in_concurrency_scope(path: str) -> bool:
    parts = tuple(path.replace("\\", "/").split("/"))
    return parts[-2:] in [tuple(f) for f in CONCURRENCY_FILES]


def in_xfn_scope(path: str) -> bool:
    parts = tuple(path.replace("\\", "/").split("/"))
    return parts[-2:] in [tuple(f) for f in XFN_FILES]


def _registry() -> List[Rule]:
    from repro.lint.rules import (apis, concurrency, goldens, purity, specs,
                                  xfn)
    return [
        purity.SimWallClock(),
        purity.SimSleep(),
        purity.SimThreadImport(),
        purity.SimUnseededRng(),
        apis.NoCancelJoinThread(),
        apis.MpQueueProtocol(),
        specs.SpecFrozen(),
        specs.MutableDefault(),
        goldens.GoldenFieldDefault(),
        concurrency.LockOrderCycle(),
        concurrency.BlockingWhileLocked(),
        xfn.XfnLockOrderCycle(),
        xfn.XfnBlockingWhileLocked(),
        xfn.ResourceLifecycle(),
    ]


ALL_RULES: List[Rule] = _registry()
RULE_IDS = {r.id for r in ALL_RULES}
