"""CLI: `python -m repro.lint [paths...]`.

Exit 0 when every finding is pragma-suppressed (with a written reason),
exit 1 otherwise. `--json` emits the machine-readable report the CI
lint job archives; `--no-pragmas` ignores the allowlist entirely — the
acceptance tests use it to prove each pragma is load-bearing.
`--runtime-report <json>` switches to reconciliation mode: diff a
sanitizer report (repro.lint.runtime, written by the REPRO_SANITIZE=1
pytest run) against the static whole-program lock graph and fail on any
observed edge the static pass cannot account for.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.lint.engine import _iter_py_files, _parse, lint_paths
from repro.lint.findings import Finding
from repro.lint.rules import ALL_RULES, ModuleInfo


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="Contract linter: sim-plane purity, shutdown-protocol "
                    "and golden-stability invariants, lock-graph analysis.")
    parser.add_argument("paths", nargs="*", default=["src"],
                        help="files or directories to lint (default: src)")
    parser.add_argument("--json", action="store_true",
                        help="emit the machine-readable JSON report")
    parser.add_argument("--no-pragmas", action="store_true",
                        help="ignore '# lint: allow[...]' pragmas (reports "
                             "every finding as unsuppressed)")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule registry and exit")
    parser.add_argument("--runtime-report", metavar="JSON",
                        help="reconcile a REPRO_SANITIZE runtime report "
                             "against the static lock graph of `paths` "
                             "(exit 1 on any dynamic-only edge)")
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule in ALL_RULES:
            print(f"{rule.id:24s} {rule.doc}")
        return 0

    if args.runtime_report:
        return _reconcile_main(args.runtime_report, args.paths)

    report = lint_paths(args.paths, respect_pragmas=not args.no_pragmas)

    if args.json:
        print(json.dumps(report.to_dict(), indent=2, sort_keys=True))
    else:
        for f in report.findings:
            print(f.format())
        n = len(report.unsuppressed)
        sup = len(report.findings) - n
        print(f"{report.files_checked} files checked: "
              f"{n} finding(s), {sup} suppressed")
    return 0 if report.ok else 1


def _reconcile_main(report_path: str, paths: List[str]) -> int:
    """Static-vs-runtime reconciliation: every observed lock-order edge
    must be explained by the static graph. A dynamic-only edge means
    the walker has a blind spot a test just exercised — it is reported
    as a finding with the ACQUIRING creation site, and fails the run."""
    from repro.lint.runtime import reconcile
    try:
        with open(report_path, encoding="utf-8") as fh:
            runtime_report = json.load(fh)
    except (OSError, json.JSONDecodeError) as exc:
        print(f"error: cannot read runtime report {report_path}: {exc}",
              file=sys.stderr)
        return 2
    mods: List[ModuleInfo] = []
    for fpath in _iter_py_files(paths):
        try:
            with open(fpath, encoding="utf-8") as fh:
                text = fh.read()
        except OSError:
            continue
        mod = _parse(fpath, text)
        if isinstance(mod, ModuleInfo):
            mods.append(mod)
    result = reconcile(runtime_report, mods)
    for e in result["dynamic_only"]:
        path, _, line = e["acquired_site"].rpartition(":")
        f = Finding(path, int(line or 1), 0, "runtime-edge-unmodeled",
                    f"observed lock-order edge {e['held']} -> "
                    f"{e['acquired']} (seen {e['count']}x at runtime) is "
                    f"absent from the static graph; the interprocedural "
                    f"walker has a blind spot here — make the acquisition "
                    f"visible to it or extend the call-graph resolver")
        print(f.format())
    print(f"runtime reconciliation: {result['matched']} edge(s) matched, "
          f"{len(result['dynamic_only'])} dynamic-only, "
          f"{result['unattributed']} unattributed "
          f"({result['static_edges']} static edge(s))")
    return 1 if result["dynamic_only"] else 0


if __name__ == "__main__":
    sys.exit(main())
