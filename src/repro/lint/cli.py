"""CLI: `python -m repro.lint [paths...]`.

Exit 0 when every finding is pragma-suppressed (with a written reason),
exit 1 otherwise. `--json` emits the machine-readable report the CI
lint job archives; `--no-pragmas` ignores the allowlist entirely — the
acceptance tests use it to prove each pragma is load-bearing.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.lint.engine import lint_paths
from repro.lint.rules import ALL_RULES


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="Contract linter: sim-plane purity, shutdown-protocol "
                    "and golden-stability invariants, lock-graph analysis.")
    parser.add_argument("paths", nargs="*", default=["src"],
                        help="files or directories to lint (default: src)")
    parser.add_argument("--json", action="store_true",
                        help="emit the machine-readable JSON report")
    parser.add_argument("--no-pragmas", action="store_true",
                        help="ignore '# lint: allow[...]' pragmas (reports "
                             "every finding as unsuppressed)")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule registry and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule in ALL_RULES:
            print(f"{rule.id:24s} {rule.doc}")
        return 0

    report = lint_paths(args.paths, respect_pragmas=not args.no_pragmas)

    if args.json:
        print(json.dumps(report.to_dict(), indent=2, sort_keys=True))
    else:
        for f in report.findings:
            print(f.format())
        n = len(report.unsuppressed)
        sup = len(report.findings) - n
        print(f"{report.files_checked} files checked: "
              f"{n} finding(s), {sup} suppressed")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
