"""Best-effort project call graph over a set of parsed modules.

The interprocedural concurrency passes (rules/xfn.py) need to follow a
call from "function holding lock A" into "function acquiring lock B"
even when the two live in different modules. This module builds that
graph with a hard honesty rule: **a call is either resolved by one of
the mechanical rules below, or it is recorded as unresolved — never
guessed.** Unresolved calls are first-class output, because they are
exactly the soundness holes the runtime sanitizer (lint/runtime.py)
exists to cross-check.

Resolution rules, in order:

  1. `self.m(...)` / `cls.m(...)` inside class C  -> method `m` of C or
     the nearest project base class that defines it.
  2. `super().m(...)` inside class C              -> `m` on C's project
     bases, in declaration order.
  3. `self.attr.m(...)` where some method of C assigns
     `self.attr = Klass(...)` (or annotates `self.attr: Klass`) with a
     single consistent project class -> method `m` of Klass. Conflicting
     assignments drop the attribute to unresolved.
  4. `v.m(...)` where `v` is a local single-assigned from `Klass(...)`,
     or the loop variable of `for v in self.attr:` whose element type is
     known (from `self.attr: List[Klass]` annotations or
     `self.attr.append(Klass(...))` sites) -> method `m` of Klass.
  5. `f(...)` where `f` is a module-level function of the same module,
     or imported via `from mod import f` from a project module.
  6. `alias.f(...)` where `import mod as alias` names a project module
     defining function `f`.
  7. `Klass(...)` (directly, via import, or as `alias.Klass(...)`)
     -> `Klass.__init__` when the project defines it.

Module matching for imports is by dotted-suffix: `repro.data.executor`
matches any loaded file whose path ends `.../repro/data/executor.py`
(and fixture trees like `pkg/data/executor.py` match `data.executor`).

Decorators, callbacks, `getattr`, thread targets, and values that cross
a queue are all *not* resolved — see DESIGN.md §13 for the caveat list.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.lint.rules import ModuleInfo


@dataclass(frozen=True, order=True)
class FuncKey:
    """Stable identity of one project function: module path + qualname
    (`func` for module functions, `Class.method` for methods)."""
    module: str
    qual: str

    def __str__(self) -> str:
        return f"{_stem(self.module)}.{self.qual}"


@dataclass
class FuncNode:
    """One defined function: its AST, owning class (if any), module."""
    key: FuncKey
    node: ast.FunctionDef | ast.AsyncFunctionDef
    cls: Optional[str]
    mod: ModuleInfo


@dataclass
class ClassNode:
    """One defined class: methods by name, textual base names, and the
    inferred types of its `self.*` attributes."""
    name: str
    module: str
    node: ast.ClassDef
    methods: Dict[str, FuncKey] = field(default_factory=dict)
    bases: Tuple[str, ...] = ()
    # self.attr -> ClassKey of the single consistent assigned type
    attr_types: Dict[str, "ClassKey"] = field(default_factory=dict)
    # self.attr -> element ClassKey (List[Klass] annotation / .append site)
    attr_elem_types: Dict[str, "ClassKey"] = field(default_factory=dict)


@dataclass(frozen=True, order=True)
class ClassKey:
    module: str
    name: str


_AMBIGUOUS = ClassKey("", "<ambiguous>")


def _stem(path: str) -> str:
    name = path.replace("\\", "/").rsplit("/", 1)[-1]
    return name[:-3] if name.endswith(".py") else name


def _dotted(path: str) -> str:
    """`src/repro/data/executor.py` -> `src.repro.data.executor`."""
    p = path.replace("\\", "/")
    if p.endswith(".py"):
        p = p[:-3]
    return p.strip("/").replace("/", ".")


def _name_of(node: ast.expr) -> str:
    """Dotted text of a Name/Attribute chain, '' for anything else."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _name_of(node.value)
        return f"{base}.{node.attr}" if base else ""
    return ""


def _annotation_class_name(ann: ast.expr) -> str:
    """The element class named by `Klass`, `List[Klass]`,
    `Optional[Klass]`, `"Klass"` — one level deep, '' otherwise."""
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        return ann.value.strip()
    if isinstance(ann, ast.Subscript):
        return _annotation_class_name(ann.slice)
    return _name_of(ann)


class CallGraph:
    """The project-wide function/class index plus call resolution."""

    def __init__(self, mods: Sequence[ModuleInfo]):
        self.mods = list(mods)
        self.funcs: Dict[FuncKey, FuncNode] = {}
        self.classes: Dict[ClassKey, ClassNode] = {}
        # per-module: imported name -> project module path ('' = external)
        self._imports: Dict[str, Dict[str, str]] = {}
        # per-module: imported name -> ClassKey / FuncKey in that module
        self._imported_syms: Dict[str, Dict[str, str]] = {}
        # dotted-suffix index of loaded modules
        self._by_dotted: Dict[str, str] = {}
        self.unresolved: List[Tuple[FuncKey, str, int]] = []
        for m in mods:
            self._by_dotted[_dotted(m.path)] = m.path
        for m in mods:
            self._index_module(m)
        for m in mods:
            self._index_imports(m)
        for ck, cn in self.classes.items():
            self._infer_attr_types(cn)

    # ------------------------------------------------------------ indexing --
    def _index_module(self, mod: ModuleInfo) -> None:
        for node in mod.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                key = FuncKey(mod.path, node.name)
                self.funcs[key] = FuncNode(key, node, None, mod)
            elif isinstance(node, ast.ClassDef):
                ck = ClassKey(mod.path, node.name)
                cn = ClassNode(node.name, mod.path, node,
                               bases=tuple(_name_of(b) for b in node.bases
                                           if _name_of(b)))
                for item in node.body:
                    if isinstance(item, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                        fk = FuncKey(mod.path, f"{node.name}.{item.name}")
                        self.funcs[fk] = FuncNode(fk, item, node.name, mod)
                        cn.methods[item.name] = fk
                self.classes[ck] = cn

    def _index_imports(self, mod: ModuleInfo) -> None:
        imps: Dict[str, str] = {}
        syms: Dict[str, str] = {}
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    target = self._match_module(alias.name)
                    if target:
                        imps[alias.asname or alias.name.split(".")[0]] = \
                            target
            elif isinstance(node, ast.ImportFrom) and node.module:
                target = self._match_module(node.module)
                if not target:
                    continue
                for alias in node.names:
                    syms[alias.asname or alias.name] = \
                        f"{target}:{alias.name}"
        self._imports[mod.path] = imps
        self._imported_syms[mod.path] = syms

    def _match_module(self, dotted: str) -> str:
        """Project file whose dotted path ends with `dotted`, '' if none
        (or ambiguous — never guess)."""
        hits = [p for d, p in self._by_dotted.items()
                if d == dotted or d.endswith("." + dotted)]
        return hits[0] if len(hits) == 1 else ""

    # ------------------------------------------------- attribute inference --
    def _infer_attr_types(self, cn: ClassNode) -> None:
        types: Dict[str, ClassKey] = {}
        elems: Dict[str, ClassKey] = {}

        def note(table: Dict[str, ClassKey], attr: str,
                 ck: Optional[ClassKey]) -> None:
            if ck is None:
                table[attr] = _AMBIGUOUS
            elif table.get(attr, ck) != ck:
                table[attr] = _AMBIGUOUS       # conflicting assignments
            else:
                table[attr] = ck

        for node in ast.walk(cn.node):
            tgt: Optional[ast.expr] = None
            val: Optional[ast.expr] = None
            ann: Optional[ast.expr] = None
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                tgt, val = node.targets[0], node.value
            elif isinstance(node, ast.AnnAssign):
                tgt, val, ann = node.target, node.value, node.annotation
            elif isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "append":
                recv = node.func.value
                if isinstance(recv, ast.Attribute) and \
                        isinstance(recv.value, ast.Name) and \
                        recv.value.id == "self" and node.args:
                    ck = self._class_of_call(node.args[0], cn.module)
                    if ck is not None:
                        note(elems, recv.attr, ck)
                continue
            else:
                continue
            if not (isinstance(tgt, ast.Attribute)
                    and isinstance(tgt.value, ast.Name)
                    and tgt.value.id == "self"):
                continue
            if ann is not None:
                name = _annotation_class_name(ann)
                ck = self.lookup_class(name, cn.module)
                if ck is not None:
                    # List[Klass] annotation types the ELEMENTS when the
                    # value is a container literal, the attr otherwise
                    if isinstance(ann, ast.Subscript) and \
                            _name_of(ann.value).split(".")[-1] in (
                                "List", "list", "Sequence", "Tuple",
                                "tuple", "Dict", "dict"):
                        note(elems, tgt.attr, ck)
                    else:
                        note(types, tgt.attr, ck)
                    continue
            if val is not None:
                ck = self._class_of_call(val, cn.module)
                if ck is not None:
                    note(types, tgt.attr, ck)
        cn.attr_types = {a: c for a, c in types.items()
                         if c is not _AMBIGUOUS}
        cn.attr_elem_types = {a: c for a, c in elems.items()
                              if c is not _AMBIGUOUS}

    def _class_of_call(self, val: ast.expr,
                       module: str) -> Optional[ClassKey]:
        """ClassKey when `val` is `Klass(...)` for a project class."""
        if not isinstance(val, ast.Call):
            return None
        return self.lookup_class(_name_of(val.func), module)

    # ------------------------------------------------------------- lookups --
    def lookup_class(self, name: str, module: str) -> Optional[ClassKey]:
        """Resolve a (possibly dotted) class name seen in `module`."""
        if not name:
            return None
        last = name.split(".")[-1]
        direct = ClassKey(module, name)
        if direct in self.classes:
            return direct
        sym = self._imported_syms.get(module, {}).get(name)
        if sym:
            target, _, symname = sym.partition(":")
            ck = ClassKey(target, symname)
            if ck in self.classes:
                return ck
        if "." in name:
            head, _, tail = name.partition(".")
            target = self._imports.get(module, {}).get(head)
            if target and "." not in tail:
                ck = ClassKey(target, tail)
                if ck in self.classes:
                    return ck
        # bare name that IS a project class of this module
        ck = ClassKey(module, last)
        if name == last and ck in self.classes:
            return ck
        return None

    def lookup_method(self, ck: ClassKey, name: str) -> Optional[FuncKey]:
        """Method `name` on `ck`, walking project base classes."""
        seen = set()
        stack = [ck]
        while stack:
            cur = stack.pop(0)
            if cur in seen:
                continue
            seen.add(cur)
            cn = self.classes.get(cur)
            if cn is None:
                continue
            if name in cn.methods:
                return cn.methods[name]
            for base in cn.bases:
                bck = self.lookup_class(base, cur.module)
                if bck is not None:
                    stack.append(bck)
        return None

    def class_of_func(self, fk: FuncKey) -> Optional[ClassKey]:
        fn = self.funcs.get(fk)
        if fn is None or fn.cls is None:
            return None
        return ClassKey(fk.module, fn.cls)

    # ---------------------------------------------------------- resolution --
    def local_types(self, fk: FuncKey) -> Dict[str, ClassKey]:
        """Single-assignment local variable types inside `fk`: direct
        `v = Klass(...)` construction and `for v in self.attr` loop
        variables with known element type."""
        fn = self.funcs.get(fk)
        if fn is None:
            return {}
        module = fk.module
        own = self.class_of_func(fk)
        cn = self.classes.get(own) if own is not None else None
        types: Dict[str, ClassKey] = {}

        def note(name: str, ck: Optional[ClassKey]) -> None:
            if ck is None:
                types[name] = _AMBIGUOUS
            elif types.get(name, ck) != ck:
                types[name] = _AMBIGUOUS
            else:
                types[name] = ck

        for node in ast.walk(fn.node):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                name = node.targets[0].id
                ck = self._class_of_call(node.value, module)
                # any other re-assignment poisons the variable
                note(name, ck)
            elif isinstance(node, (ast.For, ast.AsyncFor)) and \
                    isinstance(node.target, ast.Name):
                it = node.iter
                # for v in self.attr / for v in self.attr + other: only
                # the plain form is typed
                if cn is not None and isinstance(it, ast.Attribute) and \
                        isinstance(it.value, ast.Name) and \
                        it.value.id == "self":
                    elem = cn.attr_elem_types.get(it.attr)
                    note(node.target.id, elem)
                else:
                    note(node.target.id, None)
        return {n: c for n, c in types.items() if c is not _AMBIGUOUS}

    def resolve_call(self, caller: FuncKey, call: ast.Call,
                     local_types: Optional[Dict[str, ClassKey]] = None,
                     ) -> Optional[FuncKey]:
        """The callee FuncKey, or None (recorded in `self.unresolved`)."""
        out = self._resolve(caller, call,
                            local_types if local_types is not None
                            else self.local_types(caller))
        if out is None:
            text = _name_of(call.func) or ast.unparse(call.func)
            self.unresolved.append(
                (caller, text, getattr(call, "lineno", 0)))
        return out

    def _resolve(self, caller: FuncKey, call: ast.Call,
                 local_types: Dict[str, ClassKey]) -> Optional[FuncKey]:
        module = caller.module
        own_class = self.class_of_func(caller)
        func = call.func
        # super().m(...)
        if isinstance(func, ast.Attribute) and \
                isinstance(func.value, ast.Call) and \
                _name_of(func.value.func) == "super" and \
                own_class is not None:
            cn = self.classes.get(own_class)
            for base in (cn.bases if cn else ()):
                bck = self.lookup_class(base, module)
                if bck is not None:
                    mk = self.lookup_method(bck, func.attr)
                    if mk is not None:
                        return mk
            return None
        name = _name_of(func)
        if not name:
            return None
        parts = name.split(".")
        # self.m() / cls.m() / self.attr.m() / self.attr chains
        if parts[0] in ("self", "cls") and own_class is not None:
            if len(parts) == 2:
                return self.lookup_method(own_class, parts[1])
            if len(parts) == 3:
                cn = self.classes.get(own_class)
                tck = cn.attr_types.get(parts[1]) if cn else None
                if tck is not None:
                    return self.lookup_method(tck, parts[2])
            return None
        # v.m() for a typed local
        if len(parts) == 2 and parts[0] in local_types:
            return self.lookup_method(local_types[parts[0]], parts[1])
        # bare f() / Klass()
        if len(parts) == 1:
            fk = FuncKey(module, name)
            if fk in self.funcs:
                return fk
            ck = self.lookup_class(name, module)
            if ck is not None:
                return self.lookup_method(ck, "__init__")
            sym = self._imported_syms.get(module, {}).get(name)
            if sym:
                target, _, symname = sym.partition(":")
                ffk = FuncKey(target, symname)
                if ffk in self.funcs:
                    return ffk
                cck = ClassKey(target, symname)
                if cck in self.classes:
                    return self.lookup_method(cck, "__init__")
            return None
        # alias.f() / alias.Klass() for an imported project module
        if len(parts) == 2:
            target = self._imports.get(module, {}).get(parts[0])
            if target:
                fk = FuncKey(target, parts[1])
                if fk in self.funcs:
                    return fk
                ck = ClassKey(target, parts[1])
                if ck in self.classes:
                    return self.lookup_method(ck, "__init__")
            # ClassName.m() for a project class in scope
            ck2 = self.lookup_class(parts[0], module)
            if ck2 is not None:
                return self.lookup_method(ck2, parts[1])
        return None
