"""repro.lint — the repo's contracts, machine-enforced (DESIGN.md §13).

Every acceptance bar in this repo rests on invariants that used to be
enforced only by prose: the sim plane must be wall-clock-free and
seed-deterministic (same-seed goldens are byte-identical), specs must
stay frozen/picklable across process boundaries, every Telemetry field
added after the pinned baseline must default to None (golden stability),
and the shutdown protocol bans the teardown calls that orphan
cross-process queue locks. This package is the compiler for those
contracts: an AST rule engine with two analysis families —

  contract rules   sim-plane purity (no wall clock / sleep / threading /
                   unseeded RNG in the golden-pinned modules), forbidden
                   APIs (`cancel_join_thread`, bare mp queues outside the
                   soft/hard `shutdown(drain=)` protocol), spec hygiene
                   (frozen dataclasses, no mutable defaults), and golden
                   stability (post-baseline Telemetry/RunResult fields
                   default to None);
  concurrency      the lock-acquisition graph extracted from `with`
                   statements and `acquire()` calls over the executor
                   modules: lock-order cycles, and unbounded blocking
                   calls (`get`/`put`/`join`/`wait`/`acquire` with no
                   timeout) made while holding a lock — the deadlock
                   class the PR-6 postmortem describes. Since PR 10 the
                   pass is WHOLE-PROGRAM: `lint/callgraph.py` resolves
                   project calls (never guessing), `rules/xfn.py`
                   propagates held-lock sets across them
                   (`xfn-lock-order-cycle`, `xfn-blocking-while-locked`,
                   `resource-lifecycle`), and `lint/runtime.py` is the
                   dynamic cross-check: REPRO_SANITIZE=1 records the
                   observed lock graph live, and
                   `python -m repro.lint --runtime-report <json>` fails
                   on any observed edge the static pass cannot explain.

A violation the repo has *decided* to keep is allowlisted in place:

    q.cancel_join_thread()  # lint: allow[no-cancel-join-thread] -- why

The written reason is mandatory; a pragma with no reason, and a pragma
that suppresses nothing, are themselves findings. CLI:

    python -m repro.lint src/            # human output, exit 1 on findings
    python -m repro.lint --json src/     # machine-readable report
"""
from repro.lint.engine import LintReport, lint_paths, lint_source
from repro.lint.findings import Finding
from repro.lint.rules import ALL_RULES, Rule

__all__ = ["ALL_RULES", "Finding", "LintReport", "Rule", "lint_paths",
           "lint_source"]
