"""The allowlist: `# lint: allow[rule-id] -- reason` pragmas.

A pragma suppresses findings of the named rule(s) on the line it sits on
— or, when it occupies a line of its own, on the next line that holds
code. The reason after `--` is mandatory: an allowlisted violation with
no written justification defeats the point of the allowlist (DESIGN.md
§13 pragma etiquette), so a reason-less pragma is itself a finding, as
is a pragma that suppresses nothing (stale allowlists rot into blanket
permission).

Comments are found with `tokenize`, not a regex over raw lines, so
pragma-shaped text inside string literals never registers.
"""
from __future__ import annotations

import io
import re
import tokenize
from dataclasses import dataclass, field
from typing import Dict, List, Set, Tuple

from repro.lint.findings import BAD_PRAGMA, Finding

# shape: "lint: allow[rule-a, rule-b] -- justification"
_PRAGMA_RE = re.compile(
    r"#\s*lint:\s*allow\[(?P<rules>[^\]]*)\]\s*(?:--\s*(?P<reason>.*))?$")
_MARKER_RE = re.compile(r"#\s*lint:")


@dataclass
class Pragma:
    """One parsed allow-pragma and the source line(s) it covers."""
    line: int                      # where the pragma comment sits
    target: int                    # the code line it suppresses
    rules: Tuple[str, ...]
    reason: str
    used: bool = False


@dataclass
class PragmaTable:
    """All pragmas of one file, indexed by the code line they cover."""
    path: str = ""
    pragmas: List[Pragma] = field(default_factory=list)
    problems: List[Finding] = field(default_factory=list)
    _by_line: Dict[int, List[Pragma]] = field(default_factory=dict)

    def covers(self, line: int, rule: str) -> bool:
        """True (and marks the pragma used) when `rule` is allowlisted
        at `line`."""
        hit = False
        for p in self._by_line.get(line, []):
            if rule in p.rules:
                p.used = True
                hit = True
        return hit

    def unused(self) -> List[Pragma]:
        return [p for p in self.pragmas if not p.used]


def collect_pragmas(path: str, text: str, known_rules: Set[str]
                    ) -> PragmaTable:
    """Parse every pragma comment in `text`.

    Malformed pragmas (unparseable allow[...], unknown rule id, missing
    `-- reason`) land in `problems` as BAD_PRAGMA findings instead of
    silently suppressing nothing.
    """
    table = PragmaTable(path=path)
    comments: List[Tuple[int, int, str, bool]] = []  # line, col, text, own_line
    try:
        for tok in tokenize.generate_tokens(io.StringIO(text).readline):
            if tok.type == tokenize.COMMENT:
                own_line = tok.line[:tok.start[1]].strip() == ""
                comments.append((tok.start[0], tok.start[1], tok.string,
                                 own_line))
    except tokenize.TokenError:
        return table          # the engine reports the parse error itself
    # map comment-only lines to the next line holding code
    code_lines = _code_lines(text)
    for line, col, comment, own_line in comments:
        if not _MARKER_RE.search(comment):
            continue
        m = _PRAGMA_RE.search(comment)
        if m is None:
            table.problems.append(Finding(
                path, line, col, BAD_PRAGMA,
                "unparseable lint pragma; expected "
                "'# lint: allow[rule-id] -- reason'"))
            continue
        rules = tuple(r.strip() for r in m.group("rules").split(",")
                      if r.strip())
        reason = (m.group("reason") or "").strip()
        if not rules:
            table.problems.append(Finding(
                path, line, col, BAD_PRAGMA,
                "pragma allowlists no rules; name the rule id being "
                "suppressed"))
            continue
        unknown = [r for r in rules if r not in known_rules]
        if unknown:
            table.problems.append(Finding(
                path, line, col, BAD_PRAGMA,
                f"pragma names unknown rule(s) {unknown}; known rules: "
                f"{sorted(known_rules)}"))
            continue
        if not reason:
            table.problems.append(Finding(
                path, line, col, BAD_PRAGMA,
                f"pragma for {list(rules)} carries no justification; "
                "append '-- <why this site is the sanctioned exception>'"))
            continue
        target = line if not own_line else _next_code_line(code_lines, line)
        pragma = Pragma(line=line, target=target, rules=rules, reason=reason)
        table.pragmas.append(pragma)
        table._by_line.setdefault(target, []).append(pragma)
    return table


def _code_lines(text: str) -> List[int]:
    """1-based line numbers that hold code (non-blank, non-comment)."""
    out = []
    for i, raw in enumerate(text.splitlines(), start=1):
        s = raw.strip()
        if s and not s.startswith("#"):
            out.append(i)
    return out


def _next_code_line(code_lines: List[int], after: int) -> int:
    for ln in code_lines:
        if ln > after:
            return ln
    return after
