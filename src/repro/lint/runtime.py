"""tsan-lite: runtime lock-order and blocking-under-lock sanitizer.

`install()` swaps `threading.Lock` / `threading.RLock` for tracked
factories and wraps `queue.Queue.get` / `queue.Queue.put`. While
installed, every lock CREATED from project code (stdlib- and
site-packages-created locks pass through untouched, so `queue.Queue`'s
internal mutex never pollutes the graph) records:

  * the observed lock-acquisition-order graph — an edge A -> B for
    every acquisition of B while A is held on the same thread, matching
    the static pass's all-held -> acquired edge semantics
    (`repro.lint.rules.xfn`);
  * held-duration histograms per lock (log-spaced ms buckets);
  * blocking-under-lock events — an UNBOUNDED `Queue.get()`/`put()`
    issued while the calling thread holds at least one tracked lock.

`snapshot()` serializes all of it to a JSON-able dict; the pytest
plugin in tests/conftest.py dumps it and fails the session on any
observed cycle or over-threshold blocking event.  `reconcile()` then
diffs the observed edges against the static whole-program graph —
`python -m repro.lint --runtime-report <json>` — so an edge the walker
cannot see (locks smuggled through callbacks, getattr indirection)
still fails CI the first time a test actually exercises it.

Soundness caveats (DESIGN.md §13): locks are attributed by CREATION
site, so a lock bound to a bare local at creation (e.g. the per-stage
closure lock in live_fleet.synthetic_stage_fns) cannot be mapped back
to a static identity — its edges are counted as `unattributed`, never
diffed. `multiprocessing` locks are process-shared and are NOT tracked.
`threading.Condition` built on a tracked RLock would bypass the
tracker's bookkeeping inside `wait()`; the repo has no such use and the
linter's scope keeps it that way.
"""
from __future__ import annotations

import ast
import os
import queue
import sys
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

# Real factories, captured before any install() can rebind them.
_REAL_LOCK = threading.Lock
_REAL_RLOCK = threading.RLock
_REAL_GET = queue.Queue.get
_REAL_PUT = queue.Queue.put

_STDLIB_DIR = os.path.dirname(os.path.abspath(threading.__file__))
_SELF = os.path.abspath(__file__)

_BUCKETS = ((1.0, "<1ms"), (10.0, "<10ms"), (100.0, "<100ms"),
            (1000.0, "<1s"), (float("inf"), ">=1s"))
_MAX_BLOCK_EVENTS = 1000


def _bucket(ms: float) -> str:
    for ceil, name in _BUCKETS:
        if ms < ceil:
            return name
    return _BUCKETS[-1][1]


@dataclass
class _LockStats:
    acquisitions: int = 0
    held_ms_max: float = 0.0
    held_ms_buckets: Dict[str, int] = field(default_factory=dict)

    def record_hold(self, ms: float) -> None:
        self.held_ms_max = max(self.held_ms_max, ms)
        b = _bucket(ms)
        self.held_ms_buckets[b] = self.held_ms_buckets.get(b, 0) + 1


@dataclass
class _HeldEntry:
    lock: "TrackedLock"
    t0: float
    depth: int = 1               # RLock re-entry count


class _State:
    """All observations of one install() window. Guarded by a REAL lock
    so the tracker can never deadlock through its own machinery."""

    def __init__(self) -> None:
        self.mu = _REAL_LOCK()
        self.locks: Dict[str, _LockStats] = {}
        self.edges: Dict[Tuple[str, str], int] = {}
        self.blocking: List[Dict[str, Any]] = []
        self.blocking_dropped = 0
        self.tls = threading.local()

    def held(self) -> List[_HeldEntry]:
        stack = getattr(self.tls, "stack", None)
        if stack is None:
            stack = []
            self.tls.stack = stack
        return stack


_STATE: Optional[_State] = None


def _creation_site() -> Optional[str]:
    """file:line of the first non-stdlib caller frame, or None when the
    lock is created by stdlib / site-packages code (untracked)."""
    frame = sys._getframe(2)
    while frame is not None:
        fn = frame.f_code.co_filename
        if os.path.abspath(fn) != _SELF:
            if (fn.startswith("<") or fn.startswith(_STDLIB_DIR)
                    or "site-packages" in fn or "dist-packages" in fn):
                return None
            return f"{os.path.abspath(fn)}:{frame.f_lineno}"
        frame = frame.f_back
    return None


class TrackedLock:
    """A Lock/RLock proxy that records acquisition order and held time.

    Unknown attributes delegate to the inner lock so duck-typed callers
    (e.g. `locked()`) keep working."""

    def __init__(self, inner: Any, site: str, reentrant: bool) -> None:
        self._inner = inner
        self.site = site
        self._reentrant = reentrant

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        ok = self._inner.acquire(blocking, timeout)
        if ok:
            self._on_acquired()
        return ok

    def release(self) -> None:
        self._on_released()
        self._inner.release()

    def __enter__(self) -> bool:
        return self.acquire()

    def __exit__(self, *exc: Any) -> None:
        self.release()

    def __getattr__(self, name: str) -> Any:
        return getattr(self._inner, name)

    # ------------------------------------------------------- bookkeeping --
    def _on_acquired(self) -> None:
        state = _STATE
        if state is None:
            return
        stack = state.held()
        if self._reentrant:
            for entry in stack:
                if entry.lock is self:
                    entry.depth += 1          # re-entry: no edge, no new hold
                    return
        with state.mu:
            stats = state.locks.setdefault(self.site, _LockStats())
            stats.acquisitions += 1
            for entry in stack:
                if entry.lock.site != self.site:
                    key = (entry.lock.site, self.site)
                    state.edges[key] = state.edges.get(key, 0) + 1
        stack.append(_HeldEntry(self, time.perf_counter()))

    def _on_released(self) -> None:
        state = _STATE
        if state is None:
            return
        stack = state.held()
        for i in range(len(stack) - 1, -1, -1):
            if stack[i].lock is self:
                stack[i].depth -= 1
                if stack[i].depth == 0:
                    ms = (time.perf_counter() - stack[i].t0) * 1000.0
                    del stack[i]
                    with state.mu:
                        state.locks.setdefault(
                            self.site, _LockStats()).record_hold(ms)
                return


def _lock_factory() -> Any:
    site = _creation_site()
    if site is None or _STATE is None:
        return _REAL_LOCK()
    return TrackedLock(_REAL_LOCK(), site, reentrant=False)


def _rlock_factory() -> Any:
    site = _creation_site()
    if site is None or _STATE is None:
        return _REAL_RLOCK()
    return TrackedLock(_REAL_RLOCK(), site, reentrant=True)


def _record_blocking(op: str, ms: float) -> None:
    state = _STATE
    if state is None:
        return
    stack = state.held()
    if not stack:
        return
    frame = sys._getframe(2)
    site = f"{os.path.abspath(frame.f_code.co_filename)}:{frame.f_lineno}"
    with state.mu:
        if len(state.blocking) >= _MAX_BLOCK_EVENTS:
            state.blocking_dropped += 1
            return
        state.blocking.append({
            "op": op, "site": site,
            "lock": stack[-1].lock.site, "ms": round(ms, 3),
        })


def _tracked_get(self: Any, block: bool = True,
                 timeout: Optional[float] = None) -> Any:
    state = _STATE
    if state is not None and block and timeout is None and state.held():
        t0 = time.perf_counter()
        try:
            return _REAL_GET(self, block, timeout)
        finally:
            _record_blocking("queue.get",
                             (time.perf_counter() - t0) * 1000.0)
    return _REAL_GET(self, block, timeout)


def _tracked_put(self: Any, item: Any, block: bool = True,
                 timeout: Optional[float] = None) -> Any:
    state = _STATE
    if state is not None and block and timeout is None and state.held():
        t0 = time.perf_counter()
        try:
            return _REAL_PUT(self, item, block, timeout)
        finally:
            _record_blocking("queue.put",
                             (time.perf_counter() - t0) * 1000.0)
    return _REAL_PUT(self, item, block, timeout)


# ---------------------------------------------------------------------------
# install / snapshot
# ---------------------------------------------------------------------------

def install() -> None:
    """Idempotently swap in the tracked factories and queue wrappers."""
    global _STATE
    if _STATE is not None:
        return
    _STATE = _State()
    threading.Lock = _lock_factory            # type: ignore[misc, assignment]
    threading.RLock = _rlock_factory          # type: ignore[misc, assignment]
    queue.Queue.get = _tracked_get            # type: ignore[method-assign]
    queue.Queue.put = _tracked_put            # type: ignore[method-assign]


def uninstall() -> None:
    global _STATE
    if _STATE is None:
        return
    threading.Lock = _REAL_LOCK               # type: ignore[misc]
    threading.RLock = _REAL_RLOCK             # type: ignore[misc]
    queue.Queue.get = _REAL_GET               # type: ignore[method-assign]
    queue.Queue.put = _REAL_PUT               # type: ignore[method-assign]
    _STATE = None


def installed() -> bool:
    return _STATE is not None


def _find_cycles(graph: Dict[str, Set[str]]) -> List[List[str]]:
    out: List[List[str]] = []
    seen: Set[Tuple[str, ...]] = set()

    def dfs(node: str, path: List[str], on_path: Set[str]) -> None:
        for nxt in sorted(graph.get(node, ())):
            if nxt in on_path:
                cyc = path[path.index(nxt):] + [nxt]
                key = tuple(sorted(cyc[:-1]))
                if key not in seen:
                    seen.add(key)
                    out.append(cyc)
            elif nxt not in path:
                dfs(nxt, path + [nxt], on_path | {nxt})

    for root in sorted(graph):
        dfs(root, [root], {root})
    return out


def snapshot() -> Dict[str, Any]:
    """The observations so far as a JSON-able report (schema 1)."""
    state = _STATE
    if state is None:
        return {"schema": 1, "locks": {}, "edges": [], "blocking": [],
                "blocking_dropped": 0, "cycles": []}
    with state.mu:
        locks = {site: {"acquisitions": s.acquisitions,
                        "held_ms_max": round(s.held_ms_max, 3),
                        "held_ms_buckets": dict(s.held_ms_buckets)}
                 for site, s in sorted(state.locks.items())}
        edges = [{"held": a, "acquired": b, "count": n}
                 for (a, b), n in sorted(state.edges.items())]
        blocking = list(state.blocking)
        dropped = state.blocking_dropped
    graph: Dict[str, Set[str]] = {}
    for e in edges:
        graph.setdefault(e["held"], set()).add(e["acquired"])
    return {"schema": 1, "locks": locks, "edges": edges,
            "blocking": blocking, "blocking_dropped": dropped,
            "cycles": _find_cycles(graph)}


# ---------------------------------------------------------------------------
# reconciliation: observed creation sites -> static qualified ids
# ---------------------------------------------------------------------------

def site_to_static_id(site: str, by_abspath: Dict[str, Any]
                      ) -> Optional[str]:
    """Map a runtime creation site `abs/path.py:line` to the qualified
    lock id the static pass uses (`{stem}.{Class}.{attr}` for
    `self.attr = threading.Lock()`). Returns None when the site falls
    outside the analyzed module set or binds a bare local (ambiguous)."""
    path, _, lineno_s = site.rpartition(":")
    try:
        lineno = int(lineno_s)
    except ValueError:
        return None
    mod = by_abspath.get(os.path.abspath(path))
    if mod is None:
        return None
    stem = os.path.splitext(os.path.basename(mod.path))[0]
    # innermost class whose span covers the creation line
    cls: Optional[str] = None
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.ClassDef):
            end = getattr(node, "end_lineno", node.lineno) or node.lineno
            if node.lineno <= lineno <= end:
                cls = node.name
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Assign) or not node.targets:
            continue
        end = getattr(node, "end_lineno", node.lineno) or node.lineno
        if not node.lineno <= lineno <= end:
            continue
        tgt = node.targets[0]
        if (isinstance(tgt, ast.Attribute)
                and isinstance(tgt.value, ast.Name)
                and tgt.value.id == "self" and cls is not None):
            return f"{stem}.{cls}.{tgt.attr}"
        return None                  # bare local / tuple target: ambiguous
    return None


def reconcile(report: Dict[str, Any], mods: Sequence[Any]
              ) -> Dict[str, Any]:
    """Diff an observed runtime report against the static edge set.

    Returns {"dynamic_only": [...], "matched": n, "unattributed": n,
    "static_edges": n}. A dynamic-only edge — both endpoints map to
    analyzed locks, yet the static pass never saw that ordering — is a
    finding: the walker has a blind spot the tests just exercised."""
    from repro.lint.rules.xfn import static_edge_set
    static = static_edge_set(mods)
    by_abspath = {os.path.abspath(m.path): m for m in mods}
    cache: Dict[str, Optional[str]] = {}

    def mapped(site: str) -> Optional[str]:
        if site not in cache:
            cache[site] = site_to_static_id(site, by_abspath)
        return cache[site]

    dynamic_only: List[Dict[str, Any]] = []
    matched = 0
    unattributed = 0
    for e in report.get("edges", []):
        a, b = mapped(e["held"]), mapped(e["acquired"])
        if a is None or b is None:
            unattributed += 1
            continue
        if (a, b) in static:
            matched += 1
        else:
            dynamic_only.append({
                "held": a, "acquired": b, "count": e.get("count", 1),
                "held_site": e["held"], "acquired_site": e["acquired"]})
    return {"dynamic_only": dynamic_only, "matched": matched,
            "unattributed": unattributed, "static_edges": len(static)}
