"""The engine: parse files, run applicable rules, apply the allowlist.

`lint_paths` walks files/directories, `lint_source` lints one in-memory
module (the test fixtures' entry point). Both return a `LintReport`:
every finding — suppressed ones included, flagged as such — plus the
pragma problems (`bad-pragma`, `unused-pragma`) and `parse-error`
findings, which can never be suppressed. The exit-code contract lives
in `LintReport.ok`: clean means zero unsuppressed findings.
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field, replace
from typing import Any, Dict, Iterable, List, Optional, Sequence

from repro.lint.findings import PARSE_ERROR, UNUSED_PRAGMA, Finding
from repro.lint.pragmas import collect_pragmas
from repro.lint.rules import ALL_RULES, RULE_IDS, ModuleInfo, Rule


@dataclass
class LintReport:
    """All findings of one lint run, sorted deterministically."""
    findings: List[Finding] = field(default_factory=list)
    files_checked: int = 0

    @property
    def unsuppressed(self) -> List[Finding]:
        return [f for f in self.findings if not f.suppressed]

    @property
    def ok(self) -> bool:
        return not self.unsuppressed

    def to_dict(self) -> Dict[str, Any]:
        return {
            "files_checked": self.files_checked,
            "ok": self.ok,
            "counts": {
                "total": len(self.findings),
                "suppressed": len(self.findings) - len(self.unsuppressed),
                "unsuppressed": len(self.unsuppressed),
            },
            "findings": [f.to_dict() for f in self.findings],
        }


def lint_source(path: str, text: str,
                rules: Optional[Sequence[Rule]] = None,
                respect_pragmas: bool = True) -> List[Finding]:
    """Lint one module given as source text. `path` scopes the rules."""
    rules = ALL_RULES if rules is None else rules
    norm = path.replace("\\", "/")
    try:
        tree = ast.parse(text, filename=path)
    except SyntaxError as exc:
        return [Finding(norm, exc.lineno or 1, (exc.offset or 1) - 1,
                        PARSE_ERROR, f"syntax error: {exc.msg}")]
    mod = ModuleInfo(path=norm, tree=tree, text=text)
    raw: List[Finding] = []
    for rule in rules:
        if rule.applies(norm):
            raw.extend(rule.check(mod))
    if not respect_pragmas:
        return sorted(raw)
    table = collect_pragmas(norm, text, known_rules=set(RULE_IDS))
    out: List[Finding] = list(table.problems)
    for f in raw:
        if table.covers(f.line, f.rule):
            f = replace(f, suppressed=True)
        out.append(f)
    for pragma in table.unused():
        out.append(Finding(
            norm, pragma.line, 0, UNUSED_PRAGMA,
            f"pragma allow{list(pragma.rules)} suppresses nothing; "
            f"delete it (stale allowlists rot into blanket permission)"))
    return sorted(out)


def lint_paths(paths: Iterable[str],
               rules: Optional[Sequence[Rule]] = None,
               respect_pragmas: bool = True) -> LintReport:
    """Lint every .py file under `paths` (files or directories)."""
    report = LintReport()
    for fpath in _iter_py_files(paths):
        try:
            with open(fpath, encoding="utf-8") as fh:
                text = fh.read()
        except OSError as exc:
            report.findings.append(Finding(
                fpath.replace("\\", "/"), 1, 0, PARSE_ERROR,
                f"unreadable: {exc}"))
            continue
        report.files_checked += 1
        report.findings.extend(
            lint_source(fpath, text, rules=rules,
                        respect_pragmas=respect_pragmas))
    report.findings.sort()
    return report


def _iter_py_files(paths: Iterable[str]) -> List[str]:
    out: List[str] = []
    for path in paths:
        if os.path.isdir(path):
            for root, dirs, files in os.walk(path):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                for name in sorted(files):
                    if name.endswith(".py"):
                        out.append(os.path.join(root, name))
        elif path.endswith(".py"):
            out.append(path)
    return sorted(out)
