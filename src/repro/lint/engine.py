"""The engine: parse files, run applicable rules, apply the allowlist.

`lint_paths` walks files/directories, `lint_source` lints one in-memory
module (the test fixtures' entry point). Both return findings with the
pragma allowlist applied: every finding — suppressed ones included,
flagged as such — plus the pragma problems (`bad-pragma`,
`unused-pragma`) and `parse-error` findings, which can never be
suppressed. The exit-code contract lives in `LintReport.ok`: clean
means zero unsuppressed findings.

Two rule kinds dispatch differently: per-module rules run inside
`lint_source` file by file; `ProjectRule`s (the interprocedural
concurrency passes) run once per `lint_paths` call over EVERY parsed
module, because their call graph must span the whole set. Pragma
application is therefore centralized here — a pragma in executor.py can
suppress a finding produced by a whole-program pass just as it does a
per-module one.
"""
from __future__ import annotations

import hashlib
import os
from dataclasses import dataclass, field, replace
from typing import Any, Dict, Iterable, List, Optional, Sequence

import ast

from repro.lint.findings import PARSE_ERROR, UNUSED_PRAGMA, Finding
from repro.lint.pragmas import PragmaTable, collect_pragmas
from repro.lint.rules import (ALL_RULES, RULE_IDS, ModuleInfo, ProjectRule,
                              Rule)


@dataclass
class LintReport:
    """All findings of one lint run, sorted deterministically."""
    findings: List[Finding] = field(default_factory=list)
    files_checked: int = 0

    @property
    def unsuppressed(self) -> List[Finding]:
        return [f for f in self.findings if not f.suppressed]

    @property
    def ok(self) -> bool:
        return not self.unsuppressed

    def to_dict(self) -> Dict[str, Any]:
        return {
            "files_checked": self.files_checked,
            "ok": self.ok,
            "counts": {
                "total": len(self.findings),
                "suppressed": len(self.findings) - len(self.unsuppressed),
                "unsuppressed": len(self.unsuppressed),
            },
            "findings": [dict(f.to_dict(), finding_id=fid)
                         for f, fid in zip(self.findings,
                                           finding_ids(self.findings))],
        }


def finding_ids(findings: Sequence[Finding]) -> List[str]:
    """Stable per-finding ids: hash of rule + path + source snippet —
    deliberately LINE-INSENSITIVE, so CI lint artifacts diff cleanly
    across runs that only shift line numbers. Repeats of the same
    (rule, path, snippet) get a deterministic `-N` occurrence suffix
    (findings arrive sorted)."""
    seen: Dict[str, int] = {}
    out: List[str] = []
    for f in findings:
        base = hashlib.sha1(
            f"{f.rule}|{f.path}|{f.snippet}".encode()).hexdigest()[:12]
        n = seen.get(base, 0)
        seen[base] = n + 1
        out.append(base if n == 0 else f"{base}-{n + 1}")
    return out


def _parse(path: str, text: str) -> ModuleInfo | Finding:
    norm = path.replace("\\", "/")
    try:
        tree = ast.parse(text, filename=path)
    except SyntaxError as exc:
        return Finding(norm, exc.lineno or 1, (exc.offset or 1) - 1,
                       PARSE_ERROR, f"syntax error: {exc.msg}")
    return ModuleInfo(path=norm, tree=tree, text=text)


def _module_findings(mod: ModuleInfo,
                     rules: Sequence[Rule]) -> List[Finding]:
    raw: List[Finding] = []
    for rule in rules:
        if not isinstance(rule, ProjectRule) and rule.applies(mod.path):
            raw.extend(rule.check(mod))
    return raw


def _apply_pragmas(table: PragmaTable, raw: List[Finding]) -> List[Finding]:
    out: List[Finding] = list(table.problems)
    for f in raw:
        if table.covers(f.line, f.rule):
            f = replace(f, suppressed=True)
        out.append(f)
    for pragma in table.unused():
        out.append(Finding(
            table.path, pragma.line, 0, UNUSED_PRAGMA,
            f"pragma allow{list(pragma.rules)} suppresses nothing; "
            f"delete it (stale allowlists rot into blanket permission)"))
    return sorted(out)


def lint_source(path: str, text: str,
                rules: Optional[Sequence[Rule]] = None,
                respect_pragmas: bool = True) -> List[Finding]:
    """Lint one module given as source text with the per-module rules.
    `path` scopes the rules. (Whole-program `ProjectRule`s need the full
    module set and only run under `lint_paths`.)"""
    rules = ALL_RULES if rules is None else rules
    mod = _parse(path, text)
    if isinstance(mod, Finding):
        return [mod]
    raw = _module_findings(mod, rules)
    if not respect_pragmas:
        return sorted(raw)
    table = collect_pragmas(mod.path, text, known_rules=set(RULE_IDS))
    return _apply_pragmas(table, raw)


def lint_paths(paths: Iterable[str],
               rules: Optional[Sequence[Rule]] = None,
               respect_pragmas: bool = True) -> LintReport:
    """Lint every .py file under `paths` (files or directories): the
    per-module rules file by file, then every ProjectRule once over the
    full parsed set, then one pragma pass over the combined findings."""
    rules = ALL_RULES if rules is None else rules
    report = LintReport()
    mods: List[ModuleInfo] = []
    raw_by_path: Dict[str, List[Finding]] = {}
    for fpath in _iter_py_files(paths):
        try:
            with open(fpath, encoding="utf-8") as fh:
                text = fh.read()
        except OSError as exc:
            report.findings.append(Finding(
                fpath.replace("\\", "/"), 1, 0, PARSE_ERROR,
                f"unreadable: {exc}"))
            continue
        report.files_checked += 1
        mod = _parse(fpath, text)
        if isinstance(mod, Finding):
            report.findings.append(mod)
            continue
        mods.append(mod)
        raw_by_path[mod.path] = _module_findings(mod, rules)
    for rule in rules:
        if isinstance(rule, ProjectRule):
            for f in rule.check_project(mods):
                raw_by_path.setdefault(f.path, []).append(f)
    for mod in mods:
        raw = raw_by_path.get(mod.path, [])
        if respect_pragmas:
            table = collect_pragmas(mod.path, mod.text,
                                    known_rules=set(RULE_IDS))
            report.findings.extend(_apply_pragmas(table, raw))
        else:
            report.findings.extend(sorted(raw))
    report.findings.sort()
    return report


def _iter_py_files(paths: Iterable[str]) -> List[str]:
    out: List[str] = []
    for path in paths:
        if os.path.isdir(path):
            for root, dirs, files in os.walk(path):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                for name in sorted(files):
                    if name.endswith(".py"):
                        out.append(os.path.join(root, name))
        elif path.endswith(".py"):
            out.append(path)
    return sorted(out)
