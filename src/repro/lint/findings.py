"""Finding: one rule violation at one source location.

Findings are plain frozen dataclasses so reports sort, dedupe, and
serialize deterministically — the JSON output is part of the CLI's
contract (tests/test_lint.py pins the schema).
"""
from __future__ import annotations

from dataclasses import asdict, dataclass
from typing import Any, Dict

# Engine-level rule ids (not in the rule registry: they report on the
# allowlist mechanism itself and can never be pragma-suppressed).
BAD_PRAGMA = "bad-pragma"
UNUSED_PRAGMA = "unused-pragma"
PARSE_ERROR = "parse-error"
META_RULES = (BAD_PRAGMA, UNUSED_PRAGMA, PARSE_ERROR)


@dataclass(frozen=True, order=True)
class Finding:
    """One violation: where, which rule, and what the reader should do.

    `suppressed` marks a finding matched by a `# lint: allow[...]`
    pragma — reported for transparency (and for the delete-any-pragma
    acceptance test) but not counted toward the exit code.

    `snippet` is the stripped source line the finding sits on: the
    line-insensitive ingredient of the report's stable `finding_id`
    (engine.finding_ids), so CI lint artifacts diff cleanly across runs
    that only shift line numbers.
    """
    path: str
    line: int
    col: int
    rule: str
    message: str
    suppressed: bool = False
    snippet: str = ""

    def to_dict(self) -> Dict[str, Any]:
        return asdict(self)

    def format(self) -> str:
        tag = " (suppressed)" if self.suppressed else ""
        return (f"{self.path}:{self.line}:{self.col}: "
                f"[{self.rule}] {self.message}{tag}")
