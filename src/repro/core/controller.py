"""InTune controller: the drop-in wrapper (paper §4.4, Listing 1).

    # the unified driver (repro.api): any backend, one loop
    backend = ExecutorBackend.wrap(pipe)      # or SimBackend(spec, machine)
    Session(backend, InTune(spec, machine)).run(ticks)
    # legacy live mode (tunes a real executor in-process):
    tuner.attach(pipe); tuner.live_tick()
    # legacy self-driving paper protocol (ControllerBackend wraps this):
    for _ in range(ticks):
        tuner.tick()

One controller instance runs per trainer host; its state (agent weights,
replay, current allocation) serializes into train/checkpoint.py extras so
a restarted job resumes pipeline tuning where it left off.
"""
from __future__ import annotations

import os
from typing import TYPE_CHECKING, Optional

import numpy as np

from repro.core import actions as act_lib
from repro.core.agent import DQNAgent, DQNConfig
from repro.core.env import PipelineEnv
from repro.data.pipeline import PipelineSpec
from repro.data.simulator import Allocation, MachineSpec

if TYPE_CHECKING:   # annotation-only: keep the core plane below repro.api
    from repro.api.telemetry import Telemetry


class InTune:
    """RL data-pipeline optimizer with online fine-tuning.

    Speaks the Optimizer protocol (repro.core.optimizer): drivers loop
    propose -> apply -> observe, with the driver owning the authoritative
    simulator or executor. The internal PipelineEnv then serves purely as
    the observation/reward builder. The legacy self-driving tick() loop
    (this env's own simulator is authoritative) remains for pretraining
    and the paper-protocol benchmarks.
    """

    name = "intune"

    def __init__(self, spec: PipelineSpec, machine: MachineSpec,
                 model_latency: float = 0.0, seed: int = 0,
                 head: str = "joint",
                 pretrained: Optional[dict] = None,
                 explore: bool = True,
                 finetune_ticks: int = 300,
                 track_best: bool = True,
                 explore_restart_every: int = 25,
                 finetune_eps: Optional[float] = 0.4,
                 init_alloc: Optional[Allocation] = None,
                 lcb_coef: float = 0.0,
                 switch_margin: float = 0.0,
                 stale_scale: float = 1.0,
                 readapt_stale_s: float = 10.0,
                 readapt_drift: float = 0.5):
        self.spec = spec
        self.env = PipelineEnv(spec, machine, model_latency, seed=seed)
        if init_alloc is not None:
            # where the exploration walk starts. The env defaults to
            # heuristic_even (use the whole machine) — right for a
            # dedicated preprocessing host, wrong at a feed boundary on
            # a shared host, where the conservative launch placement is
            # minimal workers scaled up only as measurements justify.
            self.env.set_allocation(init_alloc)
        # protocol path: hold the FIRST proposal at the launch
        # allocation so it gets measured before any move — the action
        # space allows multi-worker jumps, so an immediate move would
        # leave the launch placement (often the true optimum at a feed
        # boundary) permanently absent from the incumbent statistics.
        self._hold_first = init_alloc is not None
        cfg = DQNConfig(obs_dim=self.env.obs_dim, n_stages=spec.n_stages,
                        head=head)
        self.agent = DQNAgent(cfg, seed=seed)
        if pretrained is not None:
            self.agent.load_state_dict(pretrained)
            # pretrained agents fine-tune online at the floor epsilon
            self.agent.steps = max(self.agent.steps, cfg.eps_decay_steps)
        self.explore = explore
        # two-phase behavior (paper: "achieves a stable throughput rate
        # within about 10 minutes"): explore/fine-tune for finetune_ticks,
        # then serve greedily; a resize re-opens an exploration window.
        self.finetune_ticks = finetune_ticks
        self.ticks_since_reset = 0
        self.track_best = track_best
        # Optimizer-protocol path only: every N window ticks, restart the
        # epsilon-greedy walk from the incumbent best allocation, so
        # exploration concentrates around the best basin found instead of
        # drifting (matters once the action space is 5^r for larger DAGs).
        # The legacy tick() loop ignores this and keeps the paper protocol.
        self.explore_restart_every = explore_restart_every
        # protocol path only: exploration floor inside the tuning window
        # (the schedule's floor applies outside / when None)
        self.finetune_eps = finetune_eps
        self.best: tuple = (-1.0, None)  # (score, allocation)
        # protocol path only: per-allocation reward statistics. Live
        # windows are NOISY (a couple of train steps of wall clock), so
        # the incumbent is the argmax of a visit-count-penalized running
        # MEAN, not of any single window — one lucky window must not
        # lock a bad allocation in as the serving choice.
        self._alloc_stats: dict = {}   # key -> (visits, mean_reward)
        # how aggressively _track_best distrusts sparsely-visited
        # allocations (confidence penalty lcb_coef/sqrt(visits)) and how
        # much better a challenger must score to dethrone the incumbent.
        # Zero both on a low-noise backend (the simulator): there a
        # single reading IS the allocation's value and any stickiness
        # just slows convergence. Raise them on live process backends
        # where a window is a couple of train steps of noisy wall clock
        # (benchmarks/fig_train_feed.py uses 0.15 / 0.05 on rewards
        # in [0, 1]).
        self.lcb_coef = lcb_coef
        self.switch_margin = switch_margin
        # streaming (ISSUE 7): freshness folds into the reward through
        # staleness AGING — the per-window GROWTH of batch staleness —
        # as 1/(1 + aging/stale_scale). Growth, not the absolute level:
        # absolute staleness is a function of how long the overload has
        # lasted, so it would score the same allocation differently at
        # minute 1 and minute 5 of a spike and corrupt the incumbent
        # statistics. Aging is stationary: an allocation that drains
        # backlog ages 0 regardless of when it is visited, one that
        # falls behind ages at its (fixed) shortfall rate. In a trough
        # every keeping-up allocation ages 0 and the (1 - mem_frac)
        # factor makes shedding workers pay.
        # A serving-mode incumbent is DETHRONED (exploration reopens,
        # incumbent stats cleared) when absolute staleness sits above
        # readapt_stale_s without improving since serving began, or
        # measured throughput drifts DOWN by more than readapt_drift of
        # its serving-time EWMA: the traffic the incumbent was crowned
        # under no longer exists, so its statistics are stale too.
        # 0 disables either trigger (see _stream_readapt).
        self.stale_scale = stale_scale
        self.readapt_stale_s = readapt_stale_s
        self.readapt_drift = readapt_drift
        self._prev_stale = 0.0
        self._serve_stale0 = float("inf")
        self._tput_ref: Optional[float] = None
        self.obs = self.env.observe()
        self.history: list[dict] = []

    # --------------------------------------------------------- tuning -----
    def tick(self) -> dict:
        """One observe -> act -> apply -> learn cycle."""
        exploring = self.explore and \
            self.ticks_since_reset < self.finetune_ticks
        choices = self.agent.act(self.obs, explore=exploring)
        nobs, reward, metrics = self.env.step(choices)
        self.agent.observe(self.obs, choices, reward, nobs, done=False)
        self.obs = nobs
        self.ticks_since_reset += 1
        if self.track_best and reward > self.best[0]:
            self.best = (reward, self.env.alloc.copy())
        # at the end of the tuning window, snap back to the best allocation
        # seen (the tuner keeps learning greedily from there)
        if self.ticks_since_reset == self.finetune_ticks \
                and self.best[1] is not None:
            self.env.set_allocation(self.best[1])
            self.obs = self.env.observe()
        rec = dict(metrics)
        rec["reward"] = reward
        rec["workers"] = self.env.alloc.workers.copy()
        rec["prefetch_mb"] = self.env.alloc.prefetch_mb
        self.history.append(rec)
        return rec

    def run(self, ticks: int) -> list:
        return [self.tick() for _ in range(ticks)]

    def resize(self, n_cpus: int):
        """Machine resize: no relaunch needed — the free-CPU observation
        shifts and the agent re-allocates (the paper's Fig. 5C behavior).
        Re-opens the exploration window so the agent can work the new
        resource pool."""
        self.env.resize(n_cpus)
        self.ticks_since_reset = 0
        self.best = (-1.0, None)
        self._alloc_stats = {}

    @property
    def allocation(self) -> Allocation:
        return self.env.alloc

    # ------------------------------------------------- Optimizer protocol --
    def propose(self, spec: PipelineSpec = None, machine: MachineSpec = None,
                stats: dict = None) -> Allocation:
        """One incremental allocation move from the current observation.

        `stats`, when given, is a live stats() dict (executor contract) and
        replaces the simulator-built observation. A machine with a
        different CPU count re-opens the exploration window (resize).
        """
        if spec is not None and spec != self.spec:
            raise ValueError(
                f"InTune was built for spec {self.spec.name!r}; rebuild "
                f"the controller to tune {spec.name!r}")
        if machine is not None \
                and machine.n_cpus != self.env.sim.machine.n_cpus:
            self.resize(machine.n_cpus)
        if stats is not None:
            self.obs = self._live_obs(stats)
        if self._hold_first:
            # measure the launch allocation itself before moving
            self._hold_first = False
            self._pending = (self.obs, None)
            return self.env.alloc
        exploring = self.explore and \
            self.ticks_since_reset < self.finetune_ticks
        if not exploring and self.track_best and self.best[1] is not None:
            # serving mode: hold the incumbent best (stable throughput, the
            # paper's post-tuning behavior); a resize reopens exploration.
            # Still pend the observation (choices=None, no agent update):
            # serving windows keep refining the incumbent's reward mean,
            # so an incumbent crowned by a lucky window is dethroned by
            # its own serving measurements instead of held forever.
            self.env.alloc = self.best[1].copy()
            self._pending = (self.obs, None)
            return self.env.alloc
        choices = self.agent.act(self.obs, explore=exploring,
                                 eps=self.finetune_eps if exploring
                                 else None)
        workers, pf = act_lib.next_allocation(
            choices, self.env.alloc.workers, self.env.alloc.prefetch_mb,
            prefetch_idx=self.env.prefetch_idx,
            max_workers=self.env.sim.machine.n_cpus)
        self.env.alloc = Allocation(workers, pf)
        self._pending = (self.obs, choices)
        return self.env.alloc

    def observe(self, metrics: Telemetry) -> None:
        """Learn from the telemetry of the proposal the driver just
        applied.

        `metrics` is the backend's Telemetry (or, legacy, a simulator tick
        dict with mem_mb/throughput, or a live executor stats() dict with
        stage_latency/mem_frac/... — Telemetry is mapping-compatible so
        all three read identically). Live drivers pass stats to BOTH
        propose and observe, so the transition's next-state comes from the
        same measurement source as the state the agent acted on — never
        from the internal analytic env.
        """
        if getattr(self, "_pending", None) is None:
            return
        pobs, choices = self._pending
        self._pending = None
        if "stage_latency" in metrics:      # live stats() contract
            mem_frac = min(metrics["mem_frac"], 1.0)
            nobs = self._live_obs(metrics)
        else:
            mem_frac = min(
                metrics["mem_mb"] / self.env.sim.machine.mem_mb, 1.0)
            nobs = self.env.observe()
        idle = metrics.get("device_idle_frac") \
            if hasattr(metrics, "get") else None
        stale = metrics.get("batch_staleness_s") \
            if hasattr(metrics, "get") else None
        if idle is not None:
            # feed-boundary telemetry (FeedBackend): the objective IS
            # keeping the device busy. Pipe throughput would be the
            # WRONG reward here — on a shared host more pipeline
            # workers raise pipe throughput by stealing the trainer's
            # cores, which is exactly what device_idle_frac charges for.
            reward = (1.0 - idle) * (1 - mem_frac)
        elif stale is not None:
            # streaming telemetry: throughput alone can't distinguish
            # "keeping up" from "an arrival trough" — the freshness
            # factor charges for staleness GROWTH this window (see
            # __init__: growth is stationary across a spike, the
            # absolute level is not), so an allocation falling behind
            # scores low even while its throughput looks fine, and in
            # a trough the highest reward goes to the leanest
            # allocation that stays fresh (shed workers, save memory).
            aging = max(0.0, float(stale) - self._prev_stale)
            self._prev_stale = float(stale)
            fresh = 1.0 / (1.0 + aging / self.stale_scale)
            reward = (metrics["throughput"] / self.env.reward_scale) \
                * (1 - mem_frac) * fresh
        else:
            reward = (metrics["throughput"] / self.env.reward_scale) \
                * (1 - mem_frac)
        if choices is not None:
            self.agent.observe(pobs, choices, reward, nobs, done=False)
        self.obs = nobs
        self.ticks_since_reset += 1
        if self.track_best:
            self._track_best(reward)
        # record the allocation that actually produced this tick's metrics,
        # before any snap below replaces it
        rec = dict(metrics)
        rec["reward"] = reward
        rec["workers"] = self.env.alloc.workers.copy()
        rec["prefetch_mb"] = self.env.alloc.prefetch_mb
        self.history.append(rec)
        # end of the tuning window — or an exploration restart inside it —
        # snaps to the best allocation seen (no sim.apply here: the
        # driver's simulator is the authoritative one)
        at_window_end = self.ticks_since_reset == self.finetune_ticks
        at_restart = (self.explore_restart_every > 0
                      and self.ticks_since_reset < self.finetune_ticks
                      and self.ticks_since_reset
                      % self.explore_restart_every == 0)
        if (at_window_end or at_restart) and self.best[1] is not None:
            self.env.alloc = self.best[1].copy()
            if "stage_latency" not in metrics:
                # sim mode only: rebuild the observation for the snapped
                # allocation. In live mode the next propose(stats=...)
                # supplies the real observation — never fabricate one.
                self.obs = self.env.observe()
        if stale is not None:
            self._stream_readapt(float(stale), float(metrics["throughput"]))

    def _stream_readapt(self, stale: float, tput: float) -> None:
        """Serving-mode re-adaptation triggers for streaming graphs: the
        incumbent was crowned under the traffic of its tuning window, so
        when staleness crosses the scale (backlog building — a spike the
        incumbent can't drain) or throughput drifts DOWN from its
        serving-time EWMA (a trough leaving workers idle), reopen
        exploration exactly as a machine resize does. Upward drift is
        deliberately NOT a trigger: throughput rising while freshness
        holds means a demand surge is being served — reopening would
        trade a working allocation for an exploration storm on a loaded
        host, and the surge the incumbent CANNOT serve is exactly what
        the staleness trigger catches. The staleness trigger is level-based with a
        progress guard: reopen only when staleness is over the line AND
        has not improved since serving began. The guard separates the
        two ways to be stale: an incumbent draining a spike's backlog at
        full rate is making progress and must be left alone, while one
        whose capacity is below the arrival rate shows no improvement
        and gets retried after every failed serving stretch (the
        exploration window is the refractory period). An edge trigger
        here is a trap: if one reopening crowns a bad incumbent,
        staleness never re-crosses (it never fell) and the controller
        serves that bad incumbent for the rest of the overload."""
        serving = self.ticks_since_reset >= self.finetune_ticks
        if self.ticks_since_reset == self.finetune_ticks:
            self._serve_stale0 = stale
        crossed = (self.ticks_since_reset > self.finetune_ticks
                   and self.readapt_stale_s > 0
                   and stale > self.readapt_stale_s
                   and stale >= self._serve_stale0)
        drift = False
        if self._tput_ref is None:
            self._tput_ref = tput
        else:
            if serving and self.readapt_drift > 0 and self._tput_ref > 1e-9 \
                    and (self._tput_ref - tput) \
                    > self.readapt_drift * self._tput_ref:
                drift = True
            self._tput_ref += 0.2 * (tput - self._tput_ref)
        if serving and (crossed or drift):
            self.ticks_since_reset = 0
            self.best = (-1.0, None)
            self._alloc_stats = {}
            self._tput_ref = None
            self._serve_stale0 = float("inf")

    def _track_best(self, reward: float) -> None:
        """Update the incumbent from a measured window (protocol path).

        Each allocation's reward estimate is a running mean over its
        visits, scored with a 1/sqrt(visits) confidence penalty. The
        exploration walk restarts from the incumbent, so good basins
        accumulate visits and shed their penalty while a one-off lucky
        window keeps most of its discount — the single-max rule this
        replaces let such windows permanently capture the serving slot.
        (The legacy tick() path keeps single-max: its analytic simulator
        is deterministic, so windows are noise-free there.)
        """
        key = (tuple(int(w) for w in self.env.alloc.workers),
               float(self.env.alloc.prefetch_mb))
        n, mu = self._alloc_stats.get(key, (0, 0.0))
        n += 1
        mu += (reward - mu) / n
        self._alloc_stats[key] = (n, mu)

        def score(vn, vmu):
            return vmu - self.lcb_coef / np.sqrt(vn)

        if self.best[1] is not None:
            # refresh the incumbent's score from its own latest stats —
            # serving windows re-measure it, so a lucky crowning decays
            # toward the allocation's true mean
            bkey = (tuple(int(w) for w in self.best[1].workers),
                    float(self.best[1].prefetch_mb))
            if bkey in self._alloc_stats:
                bn, bmu = self._alloc_stats[bkey]
                self.best = (score(bn, bmu), self.best[1])
        ckey, (cn, cmu) = max(
            self._alloc_stats.items(),
            key=lambda kv: score(kv[1][0], kv[1][1]))
        # hysteresis: dethroning costs a live worker-pool resize whose
        # first window reads artificially bad, so near-ties must not
        # flip the serving choice back and forth — a challenger needs a
        # clear margin, not a coin-toss win
        if self.best[1] is None \
                or score(cn, cmu) > self.best[0] + self.switch_margin:
            self.best = (score(cn, cmu),
                         Allocation(np.array(ckey[0], dtype=int), ckey[1]))

    # ----------------------------------------------------- live executor --
    def attach(self, executor, interval_s: float = 1.0):
        """Tune a real ThreadedPipeline: each tick reads its rate meters,
        applies the chosen allocation to the worker pools."""
        self._executor = executor
        self._interval = interval_s

    def live_tick(self):
        ex = self._executor
        stats = ex.stats()
        choices = self.agent.act(self.obs, explore=self.explore)
        workers, pf = act_lib.next_allocation(
            choices, np.array(ex.worker_counts(), dtype=int),
            ex.prefetch_mb, prefetch_idx=self.env.prefetch_idx,
            max_workers=self.env.sim.machine.n_cpus)
        ex.set_allocation(workers, pf)
        reward = stats["throughput"] / self.env.reward_scale \
            * (1 - min(stats["mem_frac"], 1.0))
        nobs = self._live_obs(stats)
        self.agent.observe(self.obs, choices, reward, nobs, done=False)
        self.obs = nobs
        return stats

    def _live_obs(self, stats) -> np.ndarray:
        m = self.env.sim.machine
        lat = np.asarray(stats["stage_latency"], np.float32)
        lat = lat / (lat.mean() + 1e-9)
        workers = np.asarray(stats["workers"], np.float32) / 128.0
        return np.concatenate([
            lat, workers,
            [stats.get("prefetch_mb", 0.0) / m.mem_mb,
             stats["free_cpus"] / 128.0, 1.0 - stats["mem_frac"],
             self.env.sim.model_latency, m.dram_bw_gbps / 100.0,
             m.cpu_ghz / 4.0]]).astype(np.float32)

    # ------------------------------------------------------- persistence --
    def state_dict(self) -> dict:
        return {"agent": self.agent.state_dict(),
                "workers": self.env.alloc.workers.tolist(),
                "prefetch_mb": float(self.env.alloc.prefetch_mb)}

    def load_state_dict(self, state: dict):
        self.agent.load_state_dict(state["agent"])
        self.env.set_allocation(Allocation(
            np.array(state["workers"], dtype=int),
            float(state["prefetch_mb"])))
        self.obs = self.env.observe()
