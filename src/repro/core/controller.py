"""InTune controller: the drop-in wrapper (paper §4.4, Listing 1).

    # the unified driver (repro.api): any backend, one loop
    backend = ExecutorBackend.wrap(pipe)      # or SimBackend(spec, machine)
    Session(backend, InTune(spec, machine)).run(ticks)
    # legacy live mode (tunes a real executor in-process):
    tuner.attach(pipe); tuner.live_tick()
    # legacy self-driving paper protocol (ControllerBackend wraps this):
    for _ in range(ticks):
        tuner.tick()

One controller instance runs per trainer host; its state (agent weights,
replay, current allocation) serializes into train/checkpoint.py extras so
a restarted job resumes pipeline tuning where it left off.
"""
from __future__ import annotations

import os
from typing import Optional

import numpy as np

from typing import TYPE_CHECKING

from repro.core import actions as act_lib
from repro.core.agent import DQNAgent, DQNConfig
from repro.core.env import PipelineEnv, even_allocation
from repro.data.pipeline import PipelineSpec
from repro.data.simulator import Allocation, MachineSpec

if TYPE_CHECKING:   # annotation-only: keep the core plane below repro.api
    from repro.api.telemetry import Telemetry


class InTune:
    """RL data-pipeline optimizer with online fine-tuning.

    Speaks the Optimizer protocol (repro.core.optimizer): drivers loop
    propose -> apply -> observe, with the driver owning the authoritative
    simulator or executor. The internal PipelineEnv then serves purely as
    the observation/reward builder. The legacy self-driving tick() loop
    (this env's own simulator is authoritative) remains for pretraining
    and the paper-protocol benchmarks.
    """

    name = "intune"

    def __init__(self, spec: PipelineSpec, machine: MachineSpec,
                 model_latency: float = 0.0, seed: int = 0,
                 head: str = "joint",
                 pretrained: Optional[dict] = None,
                 explore: bool = True,
                 finetune_ticks: int = 300,
                 track_best: bool = True,
                 explore_restart_every: int = 25,
                 finetune_eps: Optional[float] = 0.4):
        self.spec = spec
        self.env = PipelineEnv(spec, machine, model_latency, seed=seed)
        cfg = DQNConfig(obs_dim=self.env.obs_dim, n_stages=spec.n_stages,
                        head=head)
        self.agent = DQNAgent(cfg, seed=seed)
        if pretrained is not None:
            self.agent.load_state_dict(pretrained)
            # pretrained agents fine-tune online at the floor epsilon
            self.agent.steps = max(self.agent.steps, cfg.eps_decay_steps)
        self.explore = explore
        # two-phase behavior (paper: "achieves a stable throughput rate
        # within about 10 minutes"): explore/fine-tune for finetune_ticks,
        # then serve greedily; a resize re-opens an exploration window.
        self.finetune_ticks = finetune_ticks
        self.ticks_since_reset = 0
        self.track_best = track_best
        # Optimizer-protocol path only: every N window ticks, restart the
        # epsilon-greedy walk from the incumbent best allocation, so
        # exploration concentrates around the best basin found instead of
        # drifting (matters once the action space is 5^r for larger DAGs).
        # The legacy tick() loop ignores this and keeps the paper protocol.
        self.explore_restart_every = explore_restart_every
        # protocol path only: exploration floor inside the tuning window
        # (the schedule's floor applies outside / when None)
        self.finetune_eps = finetune_eps
        self.best: tuple = (-1.0, None)  # (reward, allocation)
        self.obs = self.env.observe()
        self.history: list[dict] = []

    # --------------------------------------------------------- tuning -----
    def tick(self) -> dict:
        """One observe -> act -> apply -> learn cycle."""
        exploring = self.explore and \
            self.ticks_since_reset < self.finetune_ticks
        choices = self.agent.act(self.obs, explore=exploring)
        nobs, reward, metrics = self.env.step(choices)
        self.agent.observe(self.obs, choices, reward, nobs, done=False)
        self.obs = nobs
        self.ticks_since_reset += 1
        if self.track_best and reward > self.best[0]:
            self.best = (reward, self.env.alloc.copy())
        # at the end of the tuning window, snap back to the best allocation
        # seen (the tuner keeps learning greedily from there)
        if self.ticks_since_reset == self.finetune_ticks \
                and self.best[1] is not None:
            self.env.set_allocation(self.best[1])
            self.obs = self.env.observe()
        rec = dict(metrics)
        rec["reward"] = reward
        rec["workers"] = self.env.alloc.workers.copy()
        rec["prefetch_mb"] = self.env.alloc.prefetch_mb
        self.history.append(rec)
        return rec

    def run(self, ticks: int) -> list:
        return [self.tick() for _ in range(ticks)]

    def resize(self, n_cpus: int):
        """Machine resize: no relaunch needed — the free-CPU observation
        shifts and the agent re-allocates (the paper's Fig. 5C behavior).
        Re-opens the exploration window so the agent can work the new
        resource pool."""
        self.env.resize(n_cpus)
        self.ticks_since_reset = 0
        self.best = (-1.0, None)

    @property
    def allocation(self) -> Allocation:
        return self.env.alloc

    # ------------------------------------------------- Optimizer protocol --
    def propose(self, spec: PipelineSpec = None, machine: MachineSpec = None,
                stats: dict = None) -> Allocation:
        """One incremental allocation move from the current observation.

        `stats`, when given, is a live stats() dict (executor contract) and
        replaces the simulator-built observation. A machine with a
        different CPU count re-opens the exploration window (resize).
        """
        if spec is not None and spec != self.spec:
            raise ValueError(
                f"InTune was built for spec {self.spec.name!r}; rebuild "
                f"the controller to tune {spec.name!r}")
        if machine is not None \
                and machine.n_cpus != self.env.sim.machine.n_cpus:
            self.resize(machine.n_cpus)
        if stats is not None:
            self.obs = self._live_obs(stats)
        exploring = self.explore and \
            self.ticks_since_reset < self.finetune_ticks
        if not exploring and self.track_best and self.best[1] is not None:
            # serving mode: hold the incumbent best (stable throughput, the
            # paper's post-tuning behavior); a resize reopens exploration
            self.env.alloc = self.best[1].copy()
            self._pending = None
            return self.env.alloc
        choices = self.agent.act(self.obs, explore=exploring,
                                 eps=self.finetune_eps if exploring
                                 else None)
        workers, pf = act_lib.next_allocation(
            choices, self.env.alloc.workers, self.env.alloc.prefetch_mb,
            prefetch_idx=self.env.prefetch_idx,
            max_workers=self.env.sim.machine.n_cpus)
        self.env.alloc = Allocation(workers, pf)
        self._pending = (self.obs, choices)
        return self.env.alloc

    def observe(self, metrics: Telemetry) -> None:
        """Learn from the telemetry of the proposal the driver just
        applied.

        `metrics` is the backend's Telemetry (or, legacy, a simulator tick
        dict with mem_mb/throughput, or a live executor stats() dict with
        stage_latency/mem_frac/... — Telemetry is mapping-compatible so
        all three read identically). Live drivers pass stats to BOTH
        propose and observe, so the transition's next-state comes from the
        same measurement source as the state the agent acted on — never
        from the internal analytic env.
        """
        if getattr(self, "_pending", None) is None:
            return
        pobs, choices = self._pending
        self._pending = None
        if "stage_latency" in metrics:      # live stats() contract
            mem_frac = min(metrics["mem_frac"], 1.0)
            nobs = self._live_obs(metrics)
        else:
            mem_frac = min(
                metrics["mem_mb"] / self.env.sim.machine.mem_mb, 1.0)
            nobs = self.env.observe()
        reward = (metrics["throughput"] / self.env.reward_scale) \
            * (1 - mem_frac)
        self.agent.observe(pobs, choices, reward, nobs, done=False)
        self.obs = nobs
        self.ticks_since_reset += 1
        if self.track_best and reward > self.best[0]:
            self.best = (reward, self.env.alloc.copy())
        # record the allocation that actually produced this tick's metrics,
        # before any snap below replaces it
        rec = dict(metrics)
        rec["reward"] = reward
        rec["workers"] = self.env.alloc.workers.copy()
        rec["prefetch_mb"] = self.env.alloc.prefetch_mb
        self.history.append(rec)
        # end of the tuning window — or an exploration restart inside it —
        # snaps to the best allocation seen (no sim.apply here: the
        # driver's simulator is the authoritative one)
        at_window_end = self.ticks_since_reset == self.finetune_ticks
        at_restart = (self.explore_restart_every > 0
                      and self.ticks_since_reset < self.finetune_ticks
                      and self.ticks_since_reset
                      % self.explore_restart_every == 0)
        if (at_window_end or at_restart) and self.best[1] is not None:
            self.env.alloc = self.best[1].copy()
            if "stage_latency" not in metrics:
                # sim mode only: rebuild the observation for the snapped
                # allocation. In live mode the next propose(stats=...)
                # supplies the real observation — never fabricate one.
                self.obs = self.env.observe()

    # ----------------------------------------------------- live executor --
    def attach(self, executor, interval_s: float = 1.0):
        """Tune a real ThreadedPipeline: each tick reads its rate meters,
        applies the chosen allocation to the worker pools."""
        self._executor = executor
        self._interval = interval_s

    def live_tick(self):
        ex = self._executor
        stats = ex.stats()
        choices = self.agent.act(self.obs, explore=self.explore)
        workers, pf = act_lib.next_allocation(
            choices, np.array(ex.worker_counts(), dtype=int),
            ex.prefetch_mb, prefetch_idx=self.env.prefetch_idx,
            max_workers=self.env.sim.machine.n_cpus)
        ex.set_allocation(workers, pf)
        reward = stats["throughput"] / self.env.reward_scale \
            * (1 - min(stats["mem_frac"], 1.0))
        nobs = self._live_obs(stats)
        self.agent.observe(self.obs, choices, reward, nobs, done=False)
        self.obs = nobs
        return stats

    def _live_obs(self, stats) -> np.ndarray:
        m = self.env.sim.machine
        lat = np.asarray(stats["stage_latency"], np.float32)
        lat = lat / (lat.mean() + 1e-9)
        workers = np.asarray(stats["workers"], np.float32) / 128.0
        return np.concatenate([
            lat, workers,
            [stats.get("prefetch_mb", 0.0) / m.mem_mb,
             stats["free_cpus"] / 128.0, 1.0 - stats["mem_frac"],
             self.env.sim.model_latency, m.dram_bw_gbps / 100.0,
             m.cpu_ghz / 4.0]]).astype(np.float32)

    # ------------------------------------------------------- persistence --
    def state_dict(self) -> dict:
        return {"agent": self.agent.state_dict(),
                "workers": self.env.alloc.workers.tolist(),
                "prefetch_mb": float(self.env.alloc.prefetch_mb)}

    def load_state_dict(self, state: dict):
        self.agent.load_state_dict(state["agent"])
        self.env.set_allocation(Allocation(
            np.array(state["workers"], dtype=int),
            float(state["prefetch_mb"])))
        self.obs = self.env.observe()
