"""DQN agent in pure JAX (paper §4.2).

Three-layer MLP Q-network ("a simple three-layer MLP architecture"),
epsilon-greedy exploration, uniform replay, target network, Huber TD loss,
optional double-DQN. Joint 5^r head (faithful) or factored branching head
(beyond-paper; Q(s, a) = mean over per-stage branch Q's).

Everything hot is jit-compiled; the replay buffer is a numpy ring so the
agent costs almost nothing next to the training job it tunes (the paper
budgets <200 FLOPs/iteration-scale inference).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.actions import N_CHOICES, n_joint_actions


@dataclass
class DQNConfig:
    obs_dim: int = 10
    n_stages: int = 5
    head: str = "joint"          # "joint" | "factored"
    hidden: int = 128
    lr: float = 1e-3
    gamma: float = 0.95
    eps_start: float = 1.0
    eps_end: float = 0.05
    eps_decay_steps: int = 2000
    buffer_size: int = 50_000
    batch_size: int = 64
    target_update: int = 200
    double_dqn: bool = True

    @property
    def n_outputs(self) -> int:
        if self.head == "joint":
            return n_joint_actions(self.n_stages)
        return self.n_stages * N_CHOICES


def init_qnet(rng, cfg: DQNConfig):
    k1, k2, k3 = jax.random.split(rng, 3)
    h = cfg.hidden
    def lin(k, i, o):
        return {"w": jax.random.normal(k, (i, o), jnp.float32) * i ** -0.5,
                "b": jnp.zeros((o,), jnp.float32)}
    return {"l1": lin(k1, cfg.obs_dim, h), "l2": lin(k2, h, h),
            "l3": lin(k3, h, cfg.n_outputs)}


def qnet_apply(params, obs, cfg: DQNConfig):
    """obs: (..., obs_dim) -> joint-action Q values (..., 5^r)."""
    x = obs
    x = jax.nn.relu(x @ params["l1"]["w"] + params["l1"]["b"])
    x = jax.nn.relu(x @ params["l2"]["w"] + params["l2"]["b"])
    out = x @ params["l3"]["w"] + params["l3"]["b"]
    if cfg.head == "joint":
        return out
    # factored: (..., r, 5) branch values -> joint Q via broadcast-sum.
    # Materializing 5^r is avoided at ACT time (argmax per branch is the
    # argmax of the sum); for TD targets we only need max Q = sum of branch
    # maxes. Return branch view here.
    return out.reshape(out.shape[:-1] + (cfg.n_stages, N_CHOICES))


def greedy_action(params, obs, cfg: DQNConfig) -> np.ndarray:
    """Returns per-stage choice indices (r,) in 0..4."""
    q = qnet_apply(params, jnp.asarray(obs), cfg)
    if cfg.head == "joint":
        a = int(jnp.argmax(q))
        out = np.zeros(cfg.n_stages, dtype=np.int64)
        for i in range(cfg.n_stages):
            out[i] = a % N_CHOICES
            a //= N_CHOICES
        return out
    return np.asarray(jnp.argmax(q, axis=-1))


class Replay:
    def __init__(self, cfg: DQNConfig):
        n = cfg.buffer_size
        self.obs = np.zeros((n, cfg.obs_dim), np.float32)
        self.act = np.zeros((n, cfg.n_stages), np.int64)   # per-stage choices
        self.rew = np.zeros((n,), np.float32)
        self.nobs = np.zeros((n, cfg.obs_dim), np.float32)
        self.done = np.zeros((n,), np.float32)
        self.idx = 0
        self.full = False
        self.cap = n

    def add(self, o, a, r, no, d):
        i = self.idx
        self.obs[i], self.act[i], self.rew[i] = o, a, r
        self.nobs[i], self.done[i] = no, d
        self.idx = (i + 1) % self.cap
        self.full = self.full or self.idx == 0

    def __len__(self):
        return self.cap if self.full else self.idx

    def sample(self, rng: np.random.RandomState, batch: int):
        n = len(self)
        ix = rng.randint(0, n, size=batch)
        return (self.obs[ix], self.act[ix], self.rew[ix], self.nobs[ix],
                self.done[ix])


def _joint_index(act_choices, n_stages):
    """(B, r) per-stage choices -> (B,) joint indices."""
    idx = jnp.zeros(act_choices.shape[0], jnp.int32)
    for i in range(n_stages - 1, -1, -1):
        idx = idx * N_CHOICES + act_choices[:, i].astype(jnp.int32)
    return idx


def make_td_update(cfg: DQNConfig):
    """jit'd TD step: (params, target, opt_m, obs, act, rew, nobs, done)."""

    def q_of(params, obs, act):
        q = qnet_apply(params, obs, cfg)
        if cfg.head == "joint":
            ji = _joint_index(act, cfg.n_stages)
            return jnp.take_along_axis(q, ji[:, None], axis=1)[:, 0]
        per = jnp.take_along_axis(q, act[..., None].astype(jnp.int32),
                                  axis=-1)[..., 0]        # (B, r)
        return jnp.mean(per, axis=-1)

    def max_q(params, sel_params, obs):
        q_sel = qnet_apply(sel_params, obs, cfg)
        q_eval = qnet_apply(params, obs, cfg)
        if cfg.head == "joint":
            a_star = jnp.argmax(q_sel, axis=-1)
            return jnp.take_along_axis(q_eval, a_star[:, None], axis=1)[:, 0]
        a_star = jnp.argmax(q_sel, axis=-1)               # (B, r)
        per = jnp.take_along_axis(q_eval, a_star[..., None], axis=-1)[..., 0]
        return jnp.mean(per, axis=-1)

    def loss_fn(params, target, obs, act, rew, nobs, done):
        q_sa = q_of(params, obs, act)
        sel = params if cfg.double_dqn else target
        q_next = max_q(target, sel, nobs)
        y = rew + cfg.gamma * (1.0 - done) * jax.lax.stop_gradient(q_next)
        err = q_sa - y
        huber = jnp.where(jnp.abs(err) < 1.0, 0.5 * err ** 2,
                          jnp.abs(err) - 0.5)
        return jnp.mean(huber)

    @jax.jit
    def update(params, target, mom, obs, act, rew, nobs, done):
        loss, grads = jax.value_and_grad(loss_fn)(
            params, target, obs, act, rew, nobs, done)
        new_mom = jax.tree_util.tree_map(
            lambda m, g: 0.9 * m + g, mom, grads)
        new_params = jax.tree_util.tree_map(
            lambda p, m: p - cfg.lr * m, params, new_mom)
        return new_params, new_mom, loss

    return update


class DQNAgent:
    """Self-contained agent: act / observe / train-tick / save / load."""

    def __init__(self, cfg: DQNConfig, seed: int = 0):
        self.cfg = cfg
        self.rng = np.random.RandomState(seed)
        self.params = init_qnet(jax.random.PRNGKey(seed), cfg)
        self.target = jax.tree_util.tree_map(lambda x: x, self.params)
        self.mom = jax.tree_util.tree_map(jnp.zeros_like, self.params)
        self.replay = Replay(cfg)
        self.update = make_td_update(cfg)
        self.steps = 0
        self.losses: list = []

    def epsilon(self) -> float:
        c = self.cfg
        frac = min(1.0, self.steps / max(c.eps_decay_steps, 1))
        return c.eps_start + (c.eps_end - c.eps_start) * frac

    def act(self, obs: np.ndarray, explore: bool = True,
            eps: Optional[float] = None) -> np.ndarray:
        """Returns per-stage choice indices (r,) in 0..4. `eps` raises the
        exploration floor above the schedule (tuning-window exploration)."""
        e = self.epsilon() if eps is None else max(eps, self.epsilon())
        if explore and self.rng.rand() < e:
            return self.rng.randint(0, N_CHOICES, size=self.cfg.n_stages)
        return greedy_action(self.params, obs.astype(np.float32), self.cfg)

    def observe(self, obs, act, rew, nobs, done):
        self.replay.add(obs, act, rew, nobs, float(done))
        self.steps += 1
        if len(self.replay) >= self.cfg.batch_size:
            batch = self.replay.sample(self.rng, self.cfg.batch_size)
            self.params, self.mom, loss = self.update(
                self.params, self.target, self.mom,
                *[jnp.asarray(b) for b in batch])
            self.losses.append(float(loss))
        if self.steps % self.cfg.target_update == 0:
            self.target = jax.tree_util.tree_map(lambda x: x, self.params)

    # ------------------------------------------------------ persistence ---
    def state_dict(self) -> dict:
        qnet = {layer: {k: np.asarray(v) for k, v in p.items()}
                for layer, p in self.params.items()}
        return {"qnet": qnet, "steps": self.steps,
                "cfg": dataclasses.asdict(self.cfg)}

    def load_state_dict(self, state: dict):
        qnet = state["qnet"]
        for layer in self.params:
            for k in self.params[layer]:
                if layer in qnet and isinstance(qnet[layer], dict):
                    v = qnet[layer][k]
                else:                       # flat "l1/w" style
                    v = qnet[f"{layer}/{k}"]
                self.params[layer][k] = jnp.asarray(v)
        self.target = jax.tree_util.tree_map(lambda x: x, self.params)
        self.steps = int(state.get("steps", 0))
