# The paper's primary contribution: the InTune RL data-pipeline optimizer.
from repro.core.controller import InTune  # noqa: F401
