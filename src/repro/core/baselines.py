"""Baseline data-pipeline optimizers (paper §5, Baselines 1-5).

  unoptimized        1 CPU per stage, no parallelism
  heuristic          even division (also InTune's initial state)
  autotune_like      greedy latency-driven hill-climber over its ESTIMATED
                     cost model. Two paper-documented flaws are modeled
                     faithfully: (a) black-box UDF/source costs are under-
                     estimated (StageSpec.est_bias), so UDF stages are
                     starved; (b) it maximizes prefetch buffering without a
                     memory-pressure signal -> OOMs (Fig. 5B).
  plumber_like       LP/water-filling on MEASURED stage rates (Plumber's
                     MILP reduces to proportional allocation for a linear-
                     scaling model); correct costs, but assumes perfect
                     linear scaling and only re-plans when relaunched.
  oracle             true-cost greedy water-filling (the "human expert").

Static optimizers return an Allocation once; `*-Adaptive` behavior is a
relaunch on resize, orchestrated by the benchmark loop. Each of these
plain functions also runs behind the unified Optimizer protocol via
`repro.core.optimizer.make_optimizer(name, ...)` (wrapped in a
StaticOptimizer), so benchmarks drive baselines and InTune identically.

All of them water-fill over the StageGraph bottleneck: with a single
sink, the DAG's sustained rate is the min over every stage's service
rate (simulator.sustained_rates), so the same per-stage greedy /
proportional placement is optimal for linear chains and join DAGs alike.
"""
from __future__ import annotations

import numpy as np

from repro.data.pipeline import StageGraph, stage_throughput
from repro.data.simulator import Allocation, MachineSpec, PipelineSim

PipelineSpec = StageGraph   # pre-DAG alias, kept for imports

# one-shot optimizers whose run-to-run profiling noise is part of the
# model (each launch re-profiles); benchmarks sweep their seed
SEEDED = frozenset({"autotune", "plumber"})


def unoptimized(spec: PipelineSpec, machine: MachineSpec) -> Allocation:
    return Allocation(np.ones(spec.n_stages, dtype=int),
                      prefetch_mb=spec.batch_mb)


def heuristic_even(spec: PipelineSpec, machine: MachineSpec) -> Allocation:
    per = max(1, machine.n_cpus // spec.n_stages)
    return Allocation(np.full(spec.n_stages, per, dtype=int),
                      prefetch_mb=2 * spec.batch_mb)


def autotune_like(spec: PipelineSpec, machine: MachineSpec,
                  seed: int = 0) -> Allocation:
    """Greedy: hand each CPU to the stage with the highest *estimated*
    latency; then maximize prefetch depth 'for performance' (no memory-
    pressure feedback — the documented OOM source: its one-shot estimate of
    the in-flight batch footprint varies run to run, and a heavy tail of
    runs lands past the physical memory line; ~8% in the paper's Fig. 5B).
    """
    rng = np.random.RandomState(seed)
    workers = np.ones(spec.n_stages, dtype=int)
    est_costs = np.array([s.est_cost() for s in spec.stages])
    for _ in range(machine.n_cpus - spec.n_stages):
        # estimated latency with current workers (its own linear model)
        est_lat = est_costs / workers
        workers[int(np.argmax(est_lat))] += 1
    # prefetch maximization: fills what it believes is available memory,
    # with a noisy one-shot estimate of the per-batch footprint.
    est_batch_mb = spec.batch_mb * float(rng.lognormal(0.0, 0.12))
    headroom = machine.mem_mb - 2048.0 \
        - sum(s.mem_per_worker_mb * w for s, w in zip(spec.stages, workers))
    depth = max(1, int(0.85 * headroom / max(est_batch_mb, 1.0)))
    return Allocation(workers, prefetch_mb=depth * spec.batch_mb)


def plumber_like(spec: PipelineSpec, machine: MachineSpec,
                 seed: int = 0) -> Allocation:
    """Proportional (LP) allocation on measured single-worker rates.

    Plumber measures per-stage rates once (a short profiling window — the
    one-shot measurement carries noise), then solves max-min throughput
    assuming rate_i(a) = a / cost_i (linear). The LP optimum is
    a_i = N * cost_i / sum(costs). Integerized by largest remainder.
    Its two gaps vs InTune: the linear-scaling assumption (no Amdahl
    saturation) and no live feedback (only relaunch adapts it)."""
    rng = np.random.RandomState(seed)
    costs = np.array([s.cost for s in spec.stages])
    costs = costs * rng.lognormal(0.0, 0.25, size=len(costs))
    n = machine.n_cpus
    frac = n * costs / costs.sum()
    workers = np.maximum(1, np.floor(frac).astype(int))
    rem = n - workers.sum()
    if rem > 0:
        order = np.argsort(-(frac - np.floor(frac)))
        for i in order[:rem]:
            workers[i] += 1
    while workers.sum() > n:
        workers[int(np.argmax(workers))] -= 1
    return Allocation(workers, prefetch_mb=2 * spec.batch_mb)


def oracle(spec: PipelineSpec, machine: MachineSpec,
           model_latency: float = 0.0) -> Allocation:
    sim = PipelineSim(spec, machine, model_latency)
    alloc, _ = sim.best_allocation()
    return alloc


BASELINES = {
    "unoptimized": unoptimized,
    "heuristic": heuristic_even,
    "autotune": autotune_like,
    "plumber": plumber_like,
    "oracle": oracle,
}
