"""Baseline data-pipeline optimizers (paper §5, Baselines 1-5).

  unoptimized        1 CPU per stage, no parallelism
  heuristic          even division (also InTune's initial state)
  autotune_like      greedy latency-driven hill-climber over its ESTIMATED
                     cost model. Two paper-documented flaws are modeled
                     faithfully: (a) black-box UDF/source costs are under-
                     estimated (StageSpec.est_bias), so UDF stages are
                     starved; (b) it maximizes prefetch buffering without a
                     memory-pressure signal -> OOMs (Fig. 5B).
  plumber_like       LP/water-filling on MEASURED stage rates (Plumber's
                     MILP reduces to proportional allocation for a linear-
                     scaling model); correct costs, but assumes perfect
                     linear scaling and only re-plans when relaunched.
  oracle             true-cost greedy water-filling (the "human expert").

Static optimizers return an Allocation once; `*-Adaptive` behavior is a
relaunch on resize, orchestrated by the benchmark loop. Each of these
plain functions also runs behind the unified Optimizer protocol via
`repro.core.optimizer.make_optimizer(name, ...)` (wrapped in a
StaticOptimizer), so benchmarks drive baselines and InTune identically.

All of them water-fill over the StageGraph bottleneck: with a single
sink, the DAG's sustained rate is the min over every stage's service
rate (simulator.sustained_rates), so the same per-stage greedy /
proportional placement is optimal for linear chains and join DAGs alike.
"""
from __future__ import annotations

from functools import lru_cache

import numpy as np

from repro.data.pipeline import StageGraph
from repro.data.simulator import Allocation, MachineSpec, PipelineSim

PipelineSpec = StageGraph   # pre-DAG alias, kept for imports

# one-shot optimizers whose run-to-run profiling noise is part of the
# model (each launch re-profiles); benchmarks sweep their seed
SEEDED = frozenset({"autotune", "plumber"})


def unoptimized(spec: PipelineSpec, machine: MachineSpec) -> Allocation:
    return Allocation(np.ones(spec.n_stages, dtype=int),
                      prefetch_mb=spec.batch_mb)


def heuristic_even(spec: PipelineSpec, machine: MachineSpec) -> Allocation:
    per = max(1, machine.n_cpus // spec.n_stages)
    return Allocation(np.full(spec.n_stages, per, dtype=int),
                      prefetch_mb=2 * spec.batch_mb)


def autotune_like(spec: PipelineSpec, machine: MachineSpec,
                  seed: int = 0) -> Allocation:
    """Greedy: hand each CPU to the stage with the highest *estimated*
    latency; then maximize prefetch depth 'for performance' (no memory-
    pressure feedback — the documented OOM source: its one-shot estimate of
    the in-flight batch footprint varies run to run, and a heavy tail of
    runs lands past the physical memory line; ~8% in the paper's Fig. 5B).
    """
    rng = np.random.RandomState(seed)
    workers = np.ones(spec.n_stages, dtype=int)
    est_costs = np.array([s.est_cost() for s in spec.stages])
    for _ in range(machine.n_cpus - spec.n_stages):
        # estimated latency with current workers (its own linear model)
        est_lat = est_costs / workers
        workers[int(np.argmax(est_lat))] += 1
    # prefetch maximization: fills what it believes is available memory,
    # with a noisy one-shot estimate of the per-batch footprint.
    est_batch_mb = spec.batch_mb * float(rng.lognormal(0.0, 0.12))
    headroom = machine.mem_mb - 2048.0 \
        - sum(s.mem_per_worker_mb * w for s, w in zip(spec.stages, workers))
    depth = max(1, int(0.85 * headroom / max(est_batch_mb, 1.0)))
    return Allocation(workers, prefetch_mb=depth * spec.batch_mb)


def plumber_like(spec: PipelineSpec, machine: MachineSpec,
                 seed: int = 0) -> Allocation:
    """Proportional (LP) allocation on measured single-worker rates.

    Plumber measures per-stage rates once (a short profiling window — the
    one-shot measurement carries noise), then solves max-min throughput
    assuming rate_i(a) = a / cost_i (linear). The LP optimum is
    a_i = N * cost_i / sum(costs). Integerized by largest remainder.
    Its two gaps vs InTune: the linear-scaling assumption (no Amdahl
    saturation) and no live feedback (only relaunch adapts it)."""
    rng = np.random.RandomState(seed)
    costs = np.array([s.cost for s in spec.stages])
    costs = costs * rng.lognormal(0.0, 0.25, size=len(costs))
    n = machine.n_cpus
    frac = n * costs / costs.sum()
    workers = np.maximum(1, np.floor(frac).astype(int))
    rem = n - workers.sum()
    if rem > 0:
        order = np.argsort(-(frac - np.floor(frac)))
        for i in order[:rem]:
            workers[i] += 1
    while workers.sum() > n:
        workers[int(np.argmax(workers))] -= 1
    return Allocation(workers, prefetch_mb=2 * spec.batch_mb)


def oracle(spec: PipelineSpec, machine: MachineSpec,
           model_latency: float = 0.0) -> Allocation:
    sim = PipelineSim(spec, machine, model_latency)
    alloc, _ = sim.best_allocation()
    return alloc


BASELINES = {
    "unoptimized": unoptimized,
    "heuristic": heuristic_even,
    "autotune": autotune_like,
    "plumber": plumber_like,
    "oracle": oracle,
}


# ---------------------------------------------------------------------------
# Fleet baselines (cluster plane). Each is fn(cluster, state, seed) ->
# FleetAllocation: a pool-grant split across the active machines plus a
# per-machine allocation at the granted cap. They run behind
# FleetStaticOptimizer (repro.core.optimizer.make_fleet_optimizer), which
# re-proposes whenever churn changes the FleetState — the fleet analog of
# the single-machine *-Adaptive relaunch behavior.
# ---------------------------------------------------------------------------

def _eff_machine(trainer, cap: int) -> MachineSpec:
    import dataclasses
    return dataclasses.replace(trainer.machine, n_cpus=int(cap))


@lru_cache(maxsize=4096)
def _oracle_cached(pipeline: StageGraph, mem_mb: float,
                   model_latency: float, cap: int):
    """Memoized per-machine oracle point: (Allocation, throughput) of
    true-cost water-filling at `cap` CPUs. Hashable StageGraph keys it."""
    machine = MachineSpec(n_cpus=int(cap), mem_mb=mem_mb)
    sim = PipelineSim(pipeline, machine, model_latency)
    return sim.best_allocation()


def _oracle_point(trainer, cap: int):
    alloc, tput = _oracle_cached(trainer.pipeline, trainer.machine.mem_mb,
                                 trainer.model_latency, int(cap))
    return alloc.copy(), tput   # never hand out the cached (mutable) alloc


def _even_grants(pool: int, names) -> dict:
    """Pool split evenly over active machines, remainder round-robin."""
    names = list(names)
    if not names:
        return {}
    base, rem = divmod(int(pool), len(names))
    return {n: base + (1 if i < rem else 0) for i, n in enumerate(names)}


def fleet_even(cluster, state, seed: int = 0):
    """Fleet-even: every active machine gets the same pool share, then the
    single-machine even heuristic places workers — blind to machine size,
    pipeline shape, and model demand."""
    from repro.data.fleet import FleetAllocation
    grants = _even_grants(state.pool, state.active)
    allocs = {n: heuristic_even(
        cluster.trainer(n).pipeline,
        _eff_machine(cluster.trainer(n), state.base(n) + grants[n]))
        for n in state.active}
    return FleetAllocation(allocs, grants)


def fleet_proportional(cluster, state, seed: int = 0):
    """Fleet-proportional: pool shares proportional to each machine's total
    true pipeline cost (a demand proxy), per-machine Plumber-style LP
    placement. Better informed than even, but no model-demand awareness:
    a machine whose model is already saturated still draws its share."""
    from repro.data.fleet import FleetAllocation
    names = list(state.active)
    grants = {n: 0 for n in names}
    if names and state.pool:
        costs = np.array([sum(s.cost for s in
                              cluster.trainer(n).pipeline.stages)
                          for n in names])
        frac = state.pool * costs / costs.sum()
        floor = np.floor(frac).astype(int)
        order = np.argsort(-(frac - floor))
        rem = int(state.pool - floor.sum())
        for k in order[:rem]:
            floor[k] += 1
        grants = {n: int(g) for n, g in zip(names, floor)}
    allocs = {n: plumber_like(
        cluster.trainer(n).pipeline,
        _eff_machine(cluster.trainer(n), state.base(n) + grants[n]), seed)
        for n in state.active}
    return FleetAllocation(allocs, grants)


def fleet_local_oracle(cluster, state, seed: int = 0):
    """Per-machine oracle, no coordination: each machine water-fills its
    OWN CPUs perfectly but nobody arbitrates the shared pool, so it sits
    idle — the upper bound on what uncoordinated per-machine tuning buys."""
    from repro.data.fleet import FleetAllocation
    allocs = {n: _oracle_point(cluster.trainer(n), state.base(n))[0]
              for n in state.active}
    return FleetAllocation(allocs, {})


def fleet_oracle(cluster, state, seed: int = 0):
    """Fleet oracle: greedy marginal-throughput water-filling of the shared
    pool (each pool CPU goes to the machine whose oracle throughput gains
    most from +1 cap; per-machine rates are concave so greedy is optimal),
    then the per-machine oracle at the granted cap. The cluster-level
    reference every fleet policy is scored against."""
    from repro.data.fleet import FleetAllocation
    grants = {n: 0 for n in state.active}
    for _ in range(int(state.pool)):
        best_gain, best_name = 1e-12, None
        for n in state.active:
            t = cluster.trainer(n)
            cap = state.base(n) + grants[n]
            gain = _oracle_point(t, cap + 1)[1] - _oracle_point(t, cap)[1]
            if gain > best_gain:
                best_gain, best_name = gain, n
        if best_name is None:
            break               # every machine saturated: leave pool idle
        grants[best_name] += 1
    allocs = {n: _oracle_point(cluster.trainer(n),
                               state.base(n) + grants[n])[0]
              for n in state.active}
    return FleetAllocation(allocs, grants)


# ---------------------------------------------------------------------------
# Market layer (multi-job pool auction). A MarketSpec partitions the
# trainers into jobs with bid weights and anti-starvation floors; the
# auction below is the pricing rule PoolMarket
# (repro.core.fleet_coordinator) and the market baselines share.
# ---------------------------------------------------------------------------

def _job_partition(cluster, state):
    """[(job name, weight, floor, [active member names])] for every job
    with at least one active member, in spec (jobs) order. A plain
    ClusterSpec — or a MarketSpec with `jobs=()` — makes every trainer
    its own weight-1, floor-0 job, under which the market degrades to
    exactly the per-trainer greedy arbiter (`fleet_oracle`)."""
    jobs = getattr(cluster, "jobs", ()) or ()
    if not jobs:
        return [(n, 1.0, 0, [n]) for n in state.active]
    out = []
    for j in jobs:
        members = [n for n in state.active if n in set(j.trainers)]
        if members:
            out.append((j.name, j.weight, j.floor, members))
    return out


def market_grants(cluster, state) -> dict:
    """Cross-job marginal-throughput pricing: per-trainer pool grants.

    Two passes. FLOORS first: every active job is owed min(floor,
    remaining pool) cores unconditionally (anti-starvation), each core
    placed at the job's own best-marginal member. Then the AUCTION: each
    remaining core goes to the job with the highest bid
    `weight * (best member's marginal oracle throughput for +1 cap)`,
    granted to that member. Per-member rates are concave, so within a
    job the greedy placement is optimal; across jobs the weights price
    priority. Deterministic: jobs bid in spec order, members in active
    order, strict > to dethrone — so equal bids resolve to the earlier
    job/member and a re-run of the same state reproduces the same grants
    (the re-auction idempotence the property suite pins)."""
    jobs = _job_partition(cluster, state)
    grants = {n: 0 for n in state.active}

    def best_marginal(members):
        best_gain, best_name = -1.0, None
        for n in members:
            t = cluster.trainer(n)
            cap = state.base(n) + grants[n]
            gain = _oracle_point(t, cap + 1)[1] - _oracle_point(t, cap)[1]
            if gain > best_gain:
                best_gain, best_name = gain, n
        return best_gain, best_name

    pool = int(state.pool)
    for _, _, floor, members in jobs:
        for _ in range(min(int(floor), pool)):
            _, name = best_marginal(members)
            grants[name] += 1
            pool -= 1
    while pool > 0:
        best_bid, winner = 1e-12, None
        for _, weight, _, members in jobs:
            gain, name = best_marginal(members)
            bid = weight * gain
            if bid > best_bid:
                best_bid, winner = bid, name
        if winner is None:
            break               # every job saturated: leave pool idle
        grants[winner] += 1
        pool -= 1
    return grants


def market_local_oracle(cluster, state, seed: int = 0):
    """Per-JOB local oracle, no cross-job arbitration: the pool is split
    evenly across active jobs (blind to weights, floors, and demand),
    then each job water-fills its share over its own members perfectly.
    The market analog of `fleet_local_oracle` — what perfect per-job
    tuning buys when nobody prices the pool across jobs."""
    from repro.data.fleet import FleetAllocation
    jobs = _job_partition(cluster, state)
    grants = {n: 0 for n in state.active}
    shares = _even_grants(state.pool, [j[0] for j in jobs])
    for jname, _, _, members in jobs:
        for _ in range(shares.get(jname, 0)):
            best_gain, best = 1e-12, None
            for n in members:
                t = cluster.trainer(n)
                cap = state.base(n) + grants[n]
                gain = _oracle_point(t, cap + 1)[1] \
                    - _oracle_point(t, cap)[1]
                if gain > best_gain:
                    best_gain, best = gain, n
            if best is None:
                break           # job saturated: its share goes unused
            grants[best] += 1
    allocs = {n: _oracle_point(cluster.trainer(n),
                               state.base(n) + grants[n])[0]
              for n in state.active}
    return FleetAllocation(allocs, grants)


def market_oracle(cluster, state, seed: int = 0):
    """The market reference: weighted cross-job auction grants + the
    per-machine oracle placement at each granted cap. On a spec without
    jobs (or uniform weights, zero floors, one job) this reproduces
    `fleet_oracle` exactly."""
    from repro.data.fleet import FleetAllocation
    grants = market_grants(cluster, state)
    allocs = {n: _oracle_point(cluster.trainer(n),
                               state.base(n) + grants[n])[0]
              for n in state.active}
    return FleetAllocation(allocs, grants)


FLEET_BASELINES = {
    "fleet_even": fleet_even,
    "fleet_proportional": fleet_proportional,
    "fleet_local_oracle": fleet_local_oracle,
    "fleet_oracle": fleet_oracle,
    "market_local_oracle": market_local_oracle,
    "market_oracle": market_oracle,
}
