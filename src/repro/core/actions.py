"""Action-space shaping (paper §4.3).

Direct allocation of n CPUs over r stages is C(n+r-1, r-1) (~1.2e7 for
128 CPUs / 5 stages) — intractable. InTune's incremental space gives each
stage one of {-5, -1, 0, +1, +5} per step -> 5^r joint actions (r <= 5 ->
<= 3125). Memory-bound knobs (prefetch buffer) move in MB units.

Two heads are provided:
  - "joint": one Q value per joint action (paper-faithful),
  - "factored": per-stage 5-way branches (branching dueling DQN, Tavakoli
    et al. 2018) — O(5r) outputs instead of O(5^r); a beyond-paper
    optimization benchmarked in §Perf.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

DELTAS = np.array([-5, -1, 0, 1, 5], dtype=np.int64)
N_CHOICES = len(DELTAS)
PREFETCH_MB_UNIT = 64.0  # memory-bound stages move in MB units


def n_joint_actions(n_stages: int) -> int:
    return N_CHOICES ** n_stages


def decode_joint(action: int, n_stages: int) -> np.ndarray:
    """Joint action index -> per-stage deltas (base-5 digits)."""
    out = np.zeros(n_stages, dtype=np.int64)
    for i in range(n_stages):
        out[i] = DELTAS[action % N_CHOICES]
        action //= N_CHOICES
    return out


def encode_joint(choices: np.ndarray) -> int:
    """Per-stage choice indices (0..4) -> joint action index."""
    a = 0
    for i in range(len(choices) - 1, -1, -1):
        a = a * N_CHOICES + int(choices[i])
    return a


def next_allocation(choices: np.ndarray, workers: np.ndarray,
                    prefetch_mb: float, *, prefetch_idx: int,
                    max_workers: int) -> Tuple[np.ndarray, float]:
    """Per-stage choice indices (0..4) -> next (workers, prefetch_mb).
    The one place action semantics are applied — env.step, the live
    executor path, and the Optimizer-protocol path all route through it."""
    deltas = DELTAS[np.asarray(choices, dtype=int)]
    return apply_deltas(workers, deltas, prefetch_idx=prefetch_idx,
                        prefetch_mb=prefetch_mb, max_workers=max_workers)


def apply_deltas(workers: np.ndarray, deltas: np.ndarray, *,
                 prefetch_idx: int, prefetch_mb: float,
                 max_workers: int) -> Tuple[np.ndarray, float]:
    """Apply per-stage deltas. The prefetch stage's delta moves its buffer
    in PREFETCH_MB_UNIT steps; others move CPU workers.

    Clamps: >= 1 worker per stage; total <= max_workers; buffer >= 1 batch.
    """
    new = workers.copy()
    new_pf = prefetch_mb
    for i, d in enumerate(deltas):
        if i == prefetch_idx:
            new_pf = max(PREFETCH_MB_UNIT, prefetch_mb + d * PREFETCH_MB_UNIT)
        else:
            new[i] = max(1, workers[i] + d)
    # respect the CPU cap: shed from the most-replicated stages first
    while new.sum() > max_workers:
        j = int(np.argmax(new))
        if new[j] <= 1:
            break
        new[j] -= 1
    return new, new_pf
