"""Offline agent pretraining (paper §4.2).

"We train different versions of the agent in offline simulations ... Each
version is built for a different common pipeline length (e.g. one agent for
4-stage pipelines, one for 5-stage, etc)." — this module is that pass:
episodes over randomized PipelineSpecs of a fixed length, machine sizes
sampled per episode, occasional mid-episode resizes so the agent sees the
rescale dynamics it must handle live.

    python -m repro.core.pretrain --stages 5 --episodes 60 --out agents/
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from repro.core.agent import DQNAgent, DQNConfig
from repro.core.env import PipelineEnv
from repro.data.pipeline import make_pipeline
from repro.data.simulator import MachineSpec


def pretrain(n_stages: int, episodes: int = 60, ticks: int = 300,
             seed: int = 0, verbose: bool = True,
             head: str = "joint") -> DQNAgent:
    rng = np.random.RandomState(seed)
    agent = None
    for ep in range(episodes):
        spec = make_pipeline(n_stages, seed=rng.randint(1 << 30))
        machine = MachineSpec(
            n_cpus=int(rng.choice([32, 64, 96, 128])),
            mem_mb=float(rng.choice([16384, 32768, 65536])))
        model_lat = float(rng.choice([0.0, 0.02, 0.05]))
        env = PipelineEnv(spec, machine, model_lat, seed=ep)
        if agent is None:
            agent = DQNAgent(DQNConfig(obs_dim=env.obs_dim,
                                       n_stages=n_stages, head=head),
                             seed=seed)
        obs = env.observe()
        resize_at = ticks // 2 if rng.rand() < 0.5 else -1
        ep_reward = 0.0
        for t in range(ticks):
            if t == resize_at:
                env.resize(int(rng.choice([32, 64, 128])))
            a = agent.act(obs)
            nobs, r, _ = env.step(a)
            agent.observe(obs, a, r, nobs, done=(t == ticks - 1))
            obs = nobs
            ep_reward += r
        if verbose and (ep + 1) % 10 == 0:
            print(f"[pretrain r={n_stages}] episode {ep + 1}/{episodes} "
                  f"mean reward {ep_reward / ticks:.3f} "
                  f"eps {agent.epsilon():.2f}")
    return agent


def save_agent(agent: DQNAgent, path: str):
    state = agent.state_dict()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = {f"qnet/{layer}/{k}": v
            for layer, p in state["qnet"].items() for k, v in p.items()}
    np.savez(path, steps=state["steps"], **flat)


def load_agent_state(path: str) -> dict:
    z = np.load(path)
    qnet: dict = {}
    for key in z.files:
        if key.startswith("qnet/"):
            _, layer, k = key.split("/")
            qnet.setdefault(layer, {})[k] = z[key]
    return {"qnet": qnet, "steps": int(z["steps"])}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--stages", type=int, default=5)
    ap.add_argument("--episodes", type=int, default=60)
    ap.add_argument("--ticks", type=int, default=300)
    ap.add_argument("--out", default="experiments/agents")
    args = ap.parse_args(argv)
    t0 = time.time()  # lint: allow[sim-wall-clock] -- log-only: feeds the elapsed-time print below, never a score
    agent = pretrain(args.stages, args.episodes, args.ticks)
    path = os.path.join(args.out, f"dqn_r{args.stages}.npz")
    save_agent(agent, path)
    # lint: allow[sim-wall-clock] -- log-only: wall time printed for the operator, not recorded anywhere
    print(f"saved {path} ({time.time() - t0:.0f}s)")


if __name__ == "__main__":
    main()
