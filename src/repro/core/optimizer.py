"""The Optimizer protocol: one interface for every allocation policy.

Before this module, the five baselines were bare functions
`fn(spec, machine[, seed]) -> Allocation` while InTune was a stateful
class with its own tick loop, and every benchmark special-cased the two.
Now everything that places CPUs over a StageGraph speaks one protocol:

    propose(spec, machine, stats=None) -> Allocation
        The allocation the policy wants next. `stats` carries live
        measurements (the executor's stats() dict or a simulator
        observation); one-shot policies ignore it.
    observe(telemetry) -> None
        Feedback for the proposal just applied: the backend's typed
        `repro.api.Telemetry` (mapping-compatible, so policies written
        against the legacy metrics-dict dialect keep working). Learning
        policies train on it; static ones no-op.

The one driver is `repro.api.Session`: it loops propose -> apply ->
observe against any Backend without knowing which policy runs. Static
baselines re-propose on a machine resize (the paper's *-Adaptive
relaunch behavior is the driver charging a dead window for that).
"""
from __future__ import annotations

from typing import (TYPE_CHECKING, Callable, Optional, Protocol, 
                    runtime_checkable)

from repro.data.pipeline import StageGraph
from repro.data.simulator import Allocation, MachineSpec

if TYPE_CHECKING:   # annotation-only: keep the core plane below repro.api
    from repro.api.telemetry import Telemetry


@runtime_checkable
class Optimizer(Protocol):
    name: str

    def propose(self, spec: StageGraph, machine: MachineSpec,
                stats: Optional[dict] = None) -> Allocation:
        ...

    def observe(self, metrics: Telemetry) -> None:
        ...


class StaticOptimizer:
    """Adapts a one-shot baseline fn to the protocol.

    Proposes once and caches the Allocation; a machine change invalidates
    the cache (the relaunch-to-adapt behavior). Seeded policies re-profile
    on each such relaunch — the seed advances so every launch carries
    fresh one-shot measurement noise, which is part of their model.
    """

    def __init__(self, name: str, fn: Callable, *, seeded: bool = False,
                 seed: int = 0):
        self.name = name
        self._fn = fn
        self._seeded = seeded
        self._seed = seed
        self._key = None
        self._alloc: Optional[Allocation] = None

    def propose(self, spec: StageGraph, machine: MachineSpec,
                stats: Optional[dict] = None) -> Allocation:
        # spec is hashable (frozen dataclass): a changed spec with the
        # same name still invalidates the cache
        key = (spec, machine.n_cpus, machine.mem_mb)
        if self._alloc is None or key != self._key:
            self._key = key
            if self._seeded:
                self._alloc = self._fn(spec, machine, self._seed)
                self._seed += 1  # each (re)launch is a fresh one-shot run
            else:
                self._alloc = self._fn(spec, machine)
        return self._alloc

    def observe(self, metrics: Telemetry) -> None:
        pass


def make_optimizer(name: str, spec: StageGraph, machine: MachineSpec,
                   seed: int = 0, **kw) -> Optimizer:
    """Build any registered optimizer by name ("intune" or a baseline)."""
    if name == "intune":
        from repro.core.controller import InTune
        return InTune(spec, machine, seed=seed, **kw)
    from repro.core import baselines as B
    if name not in B.BASELINES:
        known = ["intune"] + sorted(B.BASELINES)
        raise KeyError(f"unknown optimizer {name!r}; known: {known}")
    return StaticOptimizer(name, B.BASELINES[name],
                           seeded=name in B.SEEDED, seed=seed)


# ---------------------------------------------------------------------------
# Cluster granularity: the same protocol, one level up. A fleet policy's
# propose(cluster, fleet_state) answers with a FleetAllocation and its
# observe gets the fleet backend's aggregate Telemetry — so
# repro.api.Session drives a whole fleet with the identical
# propose -> apply -> observe loop.
# ---------------------------------------------------------------------------

class FleetStaticOptimizer:
    """Adapts a one-shot fleet baseline fn(cluster, state, seed) to the
    protocol. The cache is keyed on FleetState.key(): any churn (join /
    leave / machine resize / pool re-cap) invalidates it, so static fleet
    policies re-propose exactly when a real deployment would relaunch —
    the driver charges that relaunch window via `relaunch_dead`."""

    def __init__(self, name: str, fn: Callable, *, seed: int = 0):
        self.name = name
        self._fn = fn
        self._seed = seed
        self._key = None
        self._falloc = None

    def propose(self, cluster, state, stats: Optional[dict] = None):
        if self._falloc is None or state.key() != self._key:
            self._key = state.key()
            self._falloc = self._fn(cluster, state, self._seed)
            self._seed += 1     # each relaunch is a fresh one-shot run
        return self._falloc

    def observe(self, metrics: Telemetry) -> None:
        pass


def make_fleet_optimizer(name: str, cluster, seed: int = 0, **kw):
    """Build any registered fleet policy: "fleet_intune" (the
    FleetCoordinator), "market" (the cross-job PoolMarket; pass
    `inner="fleet_intune"` for RL-tuned jobs), or a fleet baseline from
    B.FLEET_BASELINES."""
    if name == "fleet_intune":
        from repro.core.fleet_coordinator import FleetCoordinator
        return FleetCoordinator(cluster, seed=seed, **kw)
    if name in ("market", "pool_market"):
        from repro.core.fleet_coordinator import PoolMarket
        return PoolMarket(cluster, seed=seed, **kw)
    from repro.core import baselines as B
    if name not in B.FLEET_BASELINES:
        known = ["fleet_intune", "market"] + sorted(B.FLEET_BASELINES)
        raise KeyError(f"unknown fleet optimizer {name!r}; known: {known}")
    return FleetStaticOptimizer(name, B.FLEET_BASELINES[name], seed=seed)
