"""FleetCoordinator: InTune at cluster granularity.

One InTune DQN agent per trainer (reusing the per-length pretrained
weights), with a coordinator arbitrating the shared elastic CPU pool
above them. It speaks the Optimizer protocol against any fleet backend
(`repro.api.Session` drives it over FleetSimBackend or LiveFleetBackend):

    falloc = coord.propose(cluster, fleet_state)   # FleetAllocation
    telemetry = backend.apply(falloc)
    coord.observe(telemetry)                        # routes per-trainer

Coordinator responsibilities (the cluster plane; each InTune keeps owning
its machine's per-stage placement):

  - POOL ARBITRATION: greedy marginal-throughput exchange — pool CPUs are
    water-filled to the machines whose analytic oracle curve gains most
    from +1 cap (the same model InTune's own env uses for reward
    scaling). Grants are re-fit on churn and every `rebalance_every`
    ticks, but only applied when the plan beats the current split by
    `rebalance_tol` — a granted-cap change re-opens that trainer's
    exploration window (InTune's resize behavior), so flapping is worse
    than a slightly stale split.
  - CHURN RE-TUNING: a FleetState change (join / leave / machine resize /
    pool re-cap) re-plans grants; affected trainers see a new effective
    cap and re-open their tuning windows (the controller's serve-best /
    reopen logic), while untouched trainers keep serving their best.
  - OOM PROTECTION: admission control clamps any proposal whose analytic
    memory footprint exceeds `mem_headroom` of the machine (prefetch
    shrinks first, then workers shed from the most-replicated stage), and
    an observed OOM quarantines the trainer — it serves the safe oracle
    allocation with exploration frozen for `quarantine_ticks`.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Dict, Optional

import numpy as np

from repro.core import baselines as B
from repro.core.controller import InTune
from repro.data.fleet import ClusterSpec, FleetAllocation, FleetState
from repro.data.simulator import Allocation, graph_memory_mb

if TYPE_CHECKING:   # annotation-only: keep the core plane below repro.api
    from repro.api.telemetry import Telemetry


def clamp_to_memory(pipeline, alloc: Allocation, mem_mb: float,
                    headroom: float = 0.9) -> Allocation:
    """Admission control: shrink an allocation until its analytic memory
    footprint fits within headroom * mem_mb. Prefetch gives first (down
    to one batch, or its current value if already below that), then
    workers shed from the most-replicated stage (never below 1 per
    stage). If even the minimal allocation exceeds the budget the
    machine fundamentally cannot run within headroom — the minimal
    allocation is returned; there is nothing left to shed."""
    budget = headroom * mem_mb

    def used(workers, prefetch):
        # the simulator's own memory model: the guard and the OOM judge
        # share one definition and cannot diverge
        return graph_memory_mb(pipeline, workers, prefetch)

    workers = alloc.workers.copy()
    prefetch = alloc.prefetch_mb
    if used(workers, prefetch) <= budget:
        return alloc
    need = used(workers, prefetch) - budget
    # the floor never raises prefetch above what the proposal asked for
    prefetch = max(min(prefetch, pipeline.batch_mb), prefetch - need)
    while used(workers, prefetch) > budget and workers.max() > 1:
        workers[int(np.argmax(workers))] -= 1
    return Allocation(workers, prefetch)


class FleetCoordinator:
    """Cluster-granularity Optimizer: per-trainer InTune + pool arbitration.

    `pretrained` maps pipeline length -> agent state_dict (the cached
    offline-pretrained weights; see benchmarks.common.get_agent_state).
    A length with no entry starts that trainer's agent from scratch.
    """

    name = "fleet_intune"

    def __init__(self, cluster: ClusterSpec,
                 pretrained: Optional[Dict[int, dict]] = None,
                 seed: int = 0, head: str = "factored",
                 finetune_ticks: int = 150,
                 rebalance_every: int = 100, rebalance_tol: float = 1.02,
                 mem_headroom: float = 0.95, mem_guard: bool = True,
                 quarantine_ticks: int = 40,
                 measure_anchor: bool = False):
        self.cluster = cluster
        self.pretrained = pretrained or {}
        self.seed = seed
        self.head = head
        self.finetune_ticks = finetune_ticks
        self.rebalance_every = rebalance_every
        self.rebalance_tol = rebalance_tol
        self.mem_headroom = mem_headroom
        self.mem_guard = mem_guard
        self.quarantine_ticks = quarantine_ticks
        self.measure_anchor = measure_anchor
        self.tuners: Dict[str, InTune] = {}
        self.grants: Dict[str, int] = {}
        self.quarantine: Dict[str, int] = {}
        self.history: list = []
        self._last_key = None
        self._tick = 0
        self._last_active: tuple = ()

    # ------------------------------------------------------ arbitration ---
    def _plan_grants(self, state: FleetState) -> Dict[str, int]:
        """Greedy marginal-throughput water-filling of the pool over the
        active machines' analytic oracle curves."""
        plan = B.fleet_oracle(self.cluster, state)
        return plan.grants

    def _planned_tput(self, state: FleetState, grants: Dict[str, int]) -> float:
        return sum(B._oracle_point(self.cluster.trainer(n),
                                   state.base(n) + grants.get(n, 0))[1]
                   for n in state.active)

    def _arbitrate(self, state: FleetState):
        """Re-fit pool grants. Mandatory on churn (the active set or caps
        changed — stale grants may not even fit the pool); on periodic
        checks the new split must clear `rebalance_tol` to be applied."""
        churned = state.key() != self._last_key
        periodic = (self.rebalance_every > 0
                    and self._tick % self.rebalance_every == 0)
        if not (churned or periodic or not self.grants):
            return
        plan = self._plan_grants(state)
        if not churned and self.grants:
            cur = {n: self.grants.get(n, 0) for n in state.active}
            if self._planned_tput(state, plan) \
                    < self.rebalance_tol * self._planned_tput(state, cur):
                return          # not worth re-opening tuning windows
        self.grants = plan
        self._last_key = state.key()

    def _warm_start(self, name: str, tuner: InTune, trainer, eff: int):
        """Anchor a tuner's exploration at the planner's (memory-clamped)
        oracle point for its current effective cap. The DQN still owns the
        walk from there — the warm start just means re-tuning starts from
        the arbitration model's best guess instead of an even split, the
        same way the controller's serve-best snaps exploration back to the
        incumbent best."""
        safe = clamp_to_memory(trainer.pipeline,
                               B._oracle_point(trainer, eff)[0],
                               trainer.machine.mem_mb, self.mem_headroom)
        tuner.env.set_allocation(safe)
        tuner.obs = tuner.env.observe()
        if self.measure_anchor:
            # measure the anchor ITSELF before the eps-walk moves off
            # it: serve-best picks from MEASURED allocations only, so
            # without this the planner's point can never be served even
            # when the walk finds nothing better (the controller's own
            # launch-tick hold, re-armed for every re-anchor). Off by
            # default — it shifts the exploration trajectory, and the
            # single-job coordinator's published runs (fig7) are pinned
            # on the unheld one; the market turns it on for its per-job
            # inners, where every budget move re-anchors a machine and
            # an unmeasured anchor systematically starves serve-best.
            tuner._hold_first = True

    # --------------------------------------------------------- protocol ---
    def propose(self, cluster: ClusterSpec = None,
                state: FleetState = None,
                stats: Optional[dict] = None) -> FleetAllocation:
        if cluster is not None and cluster is not self.cluster \
                and cluster != self.cluster:
            raise ValueError("FleetCoordinator was built for cluster "
                             f"{self.cluster.name!r}")
        assert state is not None, "propose needs the FleetState"
        self._arbitrate(state)
        allocs: Dict[str, Allocation] = {}
        grants = {n: int(self.grants.get(n, 0)) for n in state.active}
        for name in state.active:
            trainer = self.cluster.trainer(name)
            eff = state.base(name) + grants[name]
            machine = dataclasses.replace(trainer.machine, n_cpus=eff)
            tuner = self.tuners.get(name)
            if tuner is None:
                tuner = InTune(
                    trainer.pipeline, machine, trainer.model_latency,
                    seed=self.seed + len(self.tuners), head=self.head,
                    pretrained=self.pretrained.get(
                        trainer.pipeline.n_stages),
                    finetune_ticks=self.finetune_ticks)
                self.tuners[name] = tuner
                self._warm_start(name, tuner, trainer, eff)
            elif eff != tuner.env.sim.machine.n_cpus:
                # churn / re-arbitration changed this machine's effective
                # cap: re-open its tuning window anchored at the planner's
                # point for the new cap (serve-best/reopen, coordinated)
                tuner.resize(eff)
                self._warm_start(name, tuner, trainer, eff)
            if self.quarantine.get(name, 0) > 0:
                # quarantined: serve the safe oracle allocation, keep the
                # agent frozen (no pending transition -> observe no-ops)
                self.quarantine[name] -= 1
                if self.quarantine[name] == 0:
                    # re-admission next tick: force a pool re-arbitration
                    # so the returning trainer's grant is re-fit against
                    # the machines that absorbed the pool meanwhile
                    self._last_key = None
                safe = clamp_to_memory(
                    trainer.pipeline, B._oracle_point(trainer, eff)[0],
                    trainer.machine.mem_mb, self.mem_headroom)
                tuner.env.alloc = safe.copy()
                tuner._pending = None
                allocs[name] = safe
                continue
            alloc = tuner.propose(trainer.pipeline, machine)
            if self.mem_guard:
                clamped = clamp_to_memory(trainer.pipeline, alloc,
                                          trainer.machine.mem_mb,
                                          self.mem_headroom)
                if clamped is not alloc:
                    # keep the tuner's notion of "what ran" consistent
                    tuner.env.alloc = clamped.copy()
                    alloc = clamped
            allocs[name] = alloc
        self._tick += 1
        self._last_active = state.active
        return FleetAllocation(allocs, grants)

    def observe(self, metrics: Telemetry) -> None:
        per = metrics.get("per_trainer")
        if per is None:
            return              # fleet-wide dead window: nothing ran
        for name, m in per.items():
            tuner = self.tuners.get(name)
            if tuner is None:
                continue
            # the tuner always sees the outcome first — an OOM tick is the
            # paper's strongest learning signal (reward collapses to 0) —
            # then the coordinator quarantines the machine
            tuner.observe(m)
            if m.get("oom"):
                self.quarantine[name] = self.quarantine_ticks
        self.history.append({
            "throughput": metrics["throughput"],
            "n_active": metrics.get("n_active", len(per)),
            "oom": metrics.get("oom", False),
            "grants": dict(self.grants)})

    # ------------------------------------------------------ persistence ---
    def state_dict(self) -> dict:
        return {"grants": dict(self.grants),
                "tuners": {n: t.state_dict()
                           for n, t in self.tuners.items()}}

    def load_state_dict(self, state: dict):
        self.grants = dict(state["grants"])
        for name, s in state["tuners"].items():
            if name in self.tuners:
                self.tuners[name].load_state_dict(s)


class _JobOracle:
    """Deterministic static per-job inner optimizer: serves the fleet
    oracle for the job's sub-state, re-fit only when the sub-state
    churns. The PoolMarket default — cheap (lru-cached oracle curves),
    seedless, and byte-stable, which is what the golden-trace and
    property suites want under the market."""

    name = "job_oracle"

    def __init__(self, cluster: ClusterSpec, seed: int = 0):
        self.cluster = cluster
        self._key = None
        self._cached: Optional[FleetAllocation] = None

    def propose(self, cluster=None, state: FleetState = None,
                stats=None) -> FleetAllocation:
        if self._cached is None or state.key() != self._key:
            self._cached = B.fleet_oracle(self.cluster, state)
            self._key = state.key()
        return self._cached.copy()

    def observe(self, metrics) -> None:
        pass


class PoolMarket:
    """The market layer: multiple concurrent training jobs bidding for
    one shared elastic CPU pool.

    The per-trainer greedy arbiter (`fleet_oracle`) already computes
    marginal-throughput prices; this lifts it across jobs — each pool
    core is auctioned to the job with the highest
    `weight * best-member-marginal` bid (after anti-starvation floors
    are honored), then each job's budget is handed to that job's OWN
    inner optimizer as its sub-fleet pool. The inner water-fills (or
    RL-tunes, with `inner="fleet_intune"`) within the budget, so
    conservation holds by construction: merged grants never exceed the
    auctioned budgets, which never exceed the pool.

    Re-auction is churn-safe: budgets are cached on `state.key()` — the
    auction only re-runs when the fleet state actually changes (job
    member join/leave, machine resize, pool re-cap) or when a member
    OOMs (its quarantine reshapes the job's real demand, so the pool is
    re-priced). Under no churn the auction is idempotent — same state,
    same grants, no flapping (re-opening a tuning window costs more
    than a slightly stale split, same reasoning as the coordinator's
    `rebalance_tol`).

    Speaks the fleet Optimizer protocol, so Session + any fleet backend
    (FleetSim / LiveFleet / ProcFleet) drive it unchanged.
    """

    name = "market"

    def __init__(self, market: ClusterSpec, inner: str = "job_oracle",
                 pretrained: Optional[Dict[int, dict]] = None,
                 seed: int = 0, **inner_kw):
        from repro.data.fleet import JobSpec
        self.market = market
        jobs = tuple(getattr(market, "jobs", ()) or ())
        if not jobs:
            # a job-less spec: every trainer is its own weight-1 job,
            # and the market degrades to the per-trainer greedy arbiter
            jobs = tuple(JobSpec(t.name, (t.name,))
                         for t in market.trainers)
        self.jobs = jobs
        self.inner: Dict[str, object] = {}
        for i, j in enumerate(jobs):
            sub = ClusterSpec(
                name=f"{market.name}/{j.name}",
                trainers=tuple(market.trainer(n) for n in j.trainers),
                shared_pool=market.shared_pool)
            if inner == "fleet_intune":
                # per-job coordinators measure their warm-start anchors:
                # every auction budget move re-anchors machines, and a
                # never-measured anchor can't be served (see _warm_start)
                kw = dict(inner_kw)
                kw.setdefault("measure_anchor", True)
                self.inner[j.name] = FleetCoordinator(
                    sub, pretrained=pretrained, seed=seed + i, **kw)
            elif inner == "job_oracle":
                self.inner[j.name] = _JobOracle(sub, seed=seed + i)
            else:
                raise ValueError(f"unknown inner optimizer {inner!r}; "
                                 "known: job_oracle, fleet_intune")
        self.budgets: Dict[str, int] = {}
        self.history: list = []
        self._last_key = None
        self._force_reauction = False

    # ---------------------------------------------------------- auction ---
    def _auction(self, state: FleetState) -> Dict[str, int]:
        """Per-job pool budgets from the weighted marginal-throughput
        auction; cached on state.key() (see class docstring)."""
        if (not self.budgets or self._force_reauction
                or state.key() != self._last_key):
            grants = B.market_grants(self.market, state)
            self.budgets = {
                j.name: sum(grants.get(n, 0) for n in j.trainers)
                for j in self.jobs}
            self._last_key = state.key()
            self._force_reauction = False
        return self.budgets

    # --------------------------------------------------------- protocol ---
    def propose(self, cluster: ClusterSpec = None,
                state: FleetState = None,
                stats: Optional[dict] = None) -> FleetAllocation:
        if cluster is not None and cluster is not self.market \
                and cluster != self.market:
            raise ValueError("PoolMarket was built for cluster "
                             f"{self.market.name!r}")
        assert state is not None, "propose needs the FleetState"
        budgets = self._auction(state)
        allocs: Dict[str, Allocation] = {}
        grants: Dict[str, int] = {}
        for j in self.jobs:
            members = tuple(n for n in state.active if n in set(j.trainers))
            if not members:
                continue
            sub_state = FleetState(
                tick=state.tick, pool=int(budgets.get(j.name, 0)),
                active=members,
                base_cpus=tuple((n, state.base(n)) for n in members))
            fa = self.inner[j.name].propose(None, sub_state, stats)
            allocs.update(fa.allocs)
            for n in members:
                grants[n] = int(fa.grants.get(n, 0))
        return FleetAllocation(allocs, grants)

    def observe(self, metrics) -> None:
        per = metrics.get("per_trainer")
        if per is None:
            return              # fleet-wide dead window: nothing ran
        any_oom = False
        for j in self.jobs:
            members = set(j.trainers)
            sub = {n: m for n, m in per.items() if n in members}
            if not sub:
                continue
            oom = any(m.get("oom") for m in sub.values())
            any_oom = any_oom or oom
            self.inner[j.name].observe({
                "per_trainer": sub,
                "throughput": sum(m["throughput"] for m in sub.values()),
                "n_active": len(sub),
                "oom": oom})
        if any_oom:
            # OOM-quarantine churn: the killed member's job will serve a
            # clamped safe point for a while — re-price the pool against
            # the fleet's real demand next tick
            self._force_reauction = True
        self.history.append({
            "throughput": metrics["throughput"],
            "oom": metrics.get("oom", False),
            "budgets": dict(self.budgets)})

    # ------------------------------------------------------ persistence ---
    def state_dict(self) -> dict:
        return {"budgets": dict(self.budgets),
                "inner": {name: opt.state_dict()
                          for name, opt in self.inner.items()
                          if hasattr(opt, "state_dict")}}

    def load_state_dict(self, state: dict):
        self.budgets = dict(state["budgets"])
        for name, s in state.get("inner", {}).items():
            if name in self.inner and hasattr(self.inner[name],
                                              "load_state_dict"):
                self.inner[name].load_state_dict(s)
