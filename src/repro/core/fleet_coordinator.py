"""FleetCoordinator: InTune at cluster granularity.

One InTune DQN agent per trainer (reusing the per-length pretrained
weights), with a coordinator arbitrating the shared elastic CPU pool
above them. It speaks the Optimizer protocol against any fleet backend
(`repro.api.Session` drives it over FleetSimBackend or LiveFleetBackend):

    falloc = coord.propose(cluster, fleet_state)   # FleetAllocation
    telemetry = backend.apply(falloc)
    coord.observe(telemetry)                        # routes per-trainer

Coordinator responsibilities (the cluster plane; each InTune keeps owning
its machine's per-stage placement):

  - POOL ARBITRATION: greedy marginal-throughput exchange — pool CPUs are
    water-filled to the machines whose analytic oracle curve gains most
    from +1 cap (the same model InTune's own env uses for reward
    scaling). Grants are re-fit on churn and every `rebalance_every`
    ticks, but only applied when the plan beats the current split by
    `rebalance_tol` — a granted-cap change re-opens that trainer's
    exploration window (InTune's resize behavior), so flapping is worse
    than a slightly stale split.
  - CHURN RE-TUNING: a FleetState change (join / leave / machine resize /
    pool re-cap) re-plans grants; affected trainers see a new effective
    cap and re-open their tuning windows (the controller's serve-best /
    reopen logic), while untouched trainers keep serving their best.
  - OOM PROTECTION: admission control clamps any proposal whose analytic
    memory footprint exceeds `mem_headroom` of the machine (prefetch
    shrinks first, then workers shed from the most-replicated stage), and
    an observed OOM quarantines the trainer — it serves the safe oracle
    allocation with exploration frozen for `quarantine_ticks`.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Dict, Optional

import numpy as np

from repro.core import baselines as B
from repro.core.controller import InTune
from repro.data.fleet import ClusterSpec, FleetAllocation, FleetState
from repro.data.simulator import Allocation, graph_memory_mb

if TYPE_CHECKING:   # annotation-only: keep the core plane below repro.api
    from repro.api.telemetry import Telemetry


def clamp_to_memory(pipeline, alloc: Allocation, mem_mb: float,
                    headroom: float = 0.9) -> Allocation:
    """Admission control: shrink an allocation until its analytic memory
    footprint fits within headroom * mem_mb. Prefetch gives first (down
    to one batch, or its current value if already below that), then
    workers shed from the most-replicated stage (never below 1 per
    stage). If even the minimal allocation exceeds the budget the
    machine fundamentally cannot run within headroom — the minimal
    allocation is returned; there is nothing left to shed."""
    budget = headroom * mem_mb

    def used(workers, prefetch):
        # the simulator's own memory model: the guard and the OOM judge
        # share one definition and cannot diverge
        return graph_memory_mb(pipeline, workers, prefetch)

    workers = alloc.workers.copy()
    prefetch = alloc.prefetch_mb
    if used(workers, prefetch) <= budget:
        return alloc
    need = used(workers, prefetch) - budget
    # the floor never raises prefetch above what the proposal asked for
    prefetch = max(min(prefetch, pipeline.batch_mb), prefetch - need)
    while used(workers, prefetch) > budget and workers.max() > 1:
        workers[int(np.argmax(workers))] -= 1
    return Allocation(workers, prefetch)


class FleetCoordinator:
    """Cluster-granularity Optimizer: per-trainer InTune + pool arbitration.

    `pretrained` maps pipeline length -> agent state_dict (the cached
    offline-pretrained weights; see benchmarks.common.get_agent_state).
    A length with no entry starts that trainer's agent from scratch.
    """

    name = "fleet_intune"

    def __init__(self, cluster: ClusterSpec,
                 pretrained: Optional[Dict[int, dict]] = None,
                 seed: int = 0, head: str = "factored",
                 finetune_ticks: int = 150,
                 rebalance_every: int = 100, rebalance_tol: float = 1.02,
                 mem_headroom: float = 0.95, mem_guard: bool = True,
                 quarantine_ticks: int = 40):
        self.cluster = cluster
        self.pretrained = pretrained or {}
        self.seed = seed
        self.head = head
        self.finetune_ticks = finetune_ticks
        self.rebalance_every = rebalance_every
        self.rebalance_tol = rebalance_tol
        self.mem_headroom = mem_headroom
        self.mem_guard = mem_guard
        self.quarantine_ticks = quarantine_ticks
        self.tuners: Dict[str, InTune] = {}
        self.grants: Dict[str, int] = {}
        self.quarantine: Dict[str, int] = {}
        self.history: list = []
        self._last_key = None
        self._tick = 0
        self._last_active: tuple = ()

    # ------------------------------------------------------ arbitration ---
    def _plan_grants(self, state: FleetState) -> Dict[str, int]:
        """Greedy marginal-throughput water-filling of the pool over the
        active machines' analytic oracle curves."""
        plan = B.fleet_oracle(self.cluster, state)
        return plan.grants

    def _planned_tput(self, state: FleetState, grants: Dict[str, int]) -> float:
        return sum(B._oracle_point(self.cluster.trainer(n),
                                   state.base(n) + grants.get(n, 0))[1]
                   for n in state.active)

    def _arbitrate(self, state: FleetState):
        """Re-fit pool grants. Mandatory on churn (the active set or caps
        changed — stale grants may not even fit the pool); on periodic
        checks the new split must clear `rebalance_tol` to be applied."""
        churned = state.key() != self._last_key
        periodic = (self.rebalance_every > 0
                    and self._tick % self.rebalance_every == 0)
        if not (churned or periodic or not self.grants):
            return
        plan = self._plan_grants(state)
        if not churned and self.grants:
            cur = {n: self.grants.get(n, 0) for n in state.active}
            if self._planned_tput(state, plan) \
                    < self.rebalance_tol * self._planned_tput(state, cur):
                return          # not worth re-opening tuning windows
        self.grants = plan
        self._last_key = state.key()

    def _warm_start(self, name: str, tuner: InTune, trainer, eff: int):
        """Anchor a tuner's exploration at the planner's (memory-clamped)
        oracle point for its current effective cap. The DQN still owns the
        walk from there — the warm start just means re-tuning starts from
        the arbitration model's best guess instead of an even split, the
        same way the controller's serve-best snaps exploration back to the
        incumbent best."""
        safe = clamp_to_memory(trainer.pipeline,
                               B._oracle_point(trainer, eff)[0],
                               trainer.machine.mem_mb, self.mem_headroom)
        tuner.env.set_allocation(safe)
        tuner.obs = tuner.env.observe()

    # --------------------------------------------------------- protocol ---
    def propose(self, cluster: ClusterSpec = None,
                state: FleetState = None,
                stats: Optional[dict] = None) -> FleetAllocation:
        if cluster is not None and cluster is not self.cluster \
                and cluster != self.cluster:
            raise ValueError("FleetCoordinator was built for cluster "
                             f"{self.cluster.name!r}")
        assert state is not None, "propose needs the FleetState"
        self._arbitrate(state)
        allocs: Dict[str, Allocation] = {}
        grants = {n: int(self.grants.get(n, 0)) for n in state.active}
        for name in state.active:
            trainer = self.cluster.trainer(name)
            eff = state.base(name) + grants[name]
            machine = dataclasses.replace(trainer.machine, n_cpus=eff)
            tuner = self.tuners.get(name)
            if tuner is None:
                tuner = InTune(
                    trainer.pipeline, machine, trainer.model_latency,
                    seed=self.seed + len(self.tuners), head=self.head,
                    pretrained=self.pretrained.get(
                        trainer.pipeline.n_stages),
                    finetune_ticks=self.finetune_ticks)
                self.tuners[name] = tuner
                self._warm_start(name, tuner, trainer, eff)
            elif eff != tuner.env.sim.machine.n_cpus:
                # churn / re-arbitration changed this machine's effective
                # cap: re-open its tuning window anchored at the planner's
                # point for the new cap (serve-best/reopen, coordinated)
                tuner.resize(eff)
                self._warm_start(name, tuner, trainer, eff)
            if self.quarantine.get(name, 0) > 0:
                # quarantined: serve the safe oracle allocation, keep the
                # agent frozen (no pending transition -> observe no-ops)
                self.quarantine[name] -= 1
                if self.quarantine[name] == 0:
                    # re-admission next tick: force a pool re-arbitration
                    # so the returning trainer's grant is re-fit against
                    # the machines that absorbed the pool meanwhile
                    self._last_key = None
                safe = clamp_to_memory(
                    trainer.pipeline, B._oracle_point(trainer, eff)[0],
                    trainer.machine.mem_mb, self.mem_headroom)
                tuner.env.alloc = safe.copy()
                tuner._pending = None
                allocs[name] = safe
                continue
            alloc = tuner.propose(trainer.pipeline, machine)
            if self.mem_guard:
                clamped = clamp_to_memory(trainer.pipeline, alloc,
                                          trainer.machine.mem_mb,
                                          self.mem_headroom)
                if clamped is not alloc:
                    # keep the tuner's notion of "what ran" consistent
                    tuner.env.alloc = clamped.copy()
                    alloc = clamped
            allocs[name] = alloc
        self._tick += 1
        self._last_active = state.active
        return FleetAllocation(allocs, grants)

    def observe(self, metrics: Telemetry) -> None:
        per = metrics.get("per_trainer")
        if per is None:
            return              # fleet-wide dead window: nothing ran
        for name, m in per.items():
            tuner = self.tuners.get(name)
            if tuner is None:
                continue
            # the tuner always sees the outcome first — an OOM tick is the
            # paper's strongest learning signal (reward collapses to 0) —
            # then the coordinator quarantines the machine
            tuner.observe(m)
            if m.get("oom"):
                self.quarantine[name] = self.quarantine_ticks
        self.history.append({
            "throughput": metrics["throughput"],
            "n_active": metrics.get("n_active", len(per)),
            "oom": metrics.get("oom", False),
            "grants": dict(self.grants)})

    # ------------------------------------------------------ persistence ---
    def state_dict(self) -> dict:
        return {"grants": dict(self.grants),
                "tuners": {n: t.state_dict()
                           for n, t in self.tuners.items()}}

    def load_state_dict(self, state: dict):
        self.grants = dict(state["grants"])
        for name, s in state["tuners"].items():
            if name in self.tuners:
                self.tuners[name].load_state_dict(s)
