"""RL environment for data-pipeline allocation (paper §4.1, Table 2).

Observation (Table 2):
  agent-modified:   per-stage pipeline latency, free CPUs, free memory
  uncorrelated:     model latency
  static:           DRAM-CPU bandwidth, CPU clock
Reward (Eq. 1):     R = throughput * (1 - mem_used / mem_total)
                    -> 0 as memory nears 100%; an OOM tick scores 0
                    throughput for the whole restart window, so the agent
                    learns the paper's no-OOM behavior from the reward
                    shape alone.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core import actions as act_lib
from repro.data.pipeline import PipelineSpec
from repro.data.simulator import Allocation, MachineSpec, PipelineSim


def even_allocation(spec: PipelineSpec, n_cpus: int) -> Allocation:
    """The paper's initialization: simple even division across stages."""
    per = max(1, n_cpus // spec.n_stages)
    return Allocation(np.full(spec.n_stages, per, dtype=int),
                      prefetch_mb=2 * spec.batch_mb)


class PipelineEnv:
    """Steps the simulator under incremental allocation actions."""

    def __init__(self, spec: PipelineSpec, machine: MachineSpec,
                 model_latency: float = 0.0, seed: int = 0,
                 reward_scale: Optional[float] = None):
        self.spec = spec
        self.sim = PipelineSim(spec, machine, model_latency, seed=seed)
        self.prefetch_idx = next(
            (i for i, s in enumerate(spec.stages) if s.kind == "prefetch"),
            spec.n_stages - 1)
        # normalize rewards by the oracle throughput so the scale is
        # comparable across random pipelines during offline pretraining
        if reward_scale is None:
            _, best = self.sim.best_allocation()
            reward_scale = max(best, 1e-6)
        self.reward_scale = reward_scale
        self.alloc = even_allocation(spec, machine.n_cpus)
        self.last_metrics = self.sim.apply(self.alloc)

    @property
    def obs_dim(self) -> int:
        # per-stage: relative latency + current workers; globals: prefetch
        # buffer frac, free CPUs, free memory, model latency, DRAM bw, GHz
        return 2 * self.spec.n_stages + 6

    def observe(self) -> np.ndarray:
        m = self.sim.machine
        lat = self.sim.measured_latencies(self.alloc)
        free_cpus = m.n_cpus - int(np.sum(self.alloc.workers))
        free_mem = m.mem_mb - self.sim.memory_used(self.alloc)
        obs = np.concatenate([
            lat / (np.mean(lat) + 1e-9),              # relative latencies
            self.alloc.workers / 128.0,               # current allocation
            [self.alloc.prefetch_mb / m.mem_mb,
             free_cpus / 128.0, free_mem / m.mem_mb,
             self.sim.model_latency,
             m.dram_bw_gbps / 100.0, m.cpu_ghz / 4.0]])
        return obs.astype(np.float32)

    def step(self, choices: np.ndarray) -> Tuple[np.ndarray, float, dict]:
        """choices: per-stage indices into DELTAS. Returns (obs, r, info)."""
        workers, pf = act_lib.next_allocation(
            choices, self.alloc.workers, self.alloc.prefetch_mb,
            prefetch_idx=self.prefetch_idx,
            max_workers=self.sim.machine.n_cpus)
        self.alloc = Allocation(workers, pf)
        metrics = self.sim.apply(self.alloc)
        self.last_metrics = metrics
        mem_frac = min(metrics["mem_mb"] / self.sim.machine.mem_mb, 1.0)
        reward = (metrics["throughput"] / self.reward_scale) * (1 - mem_frac)
        return self.observe(), float(reward), metrics

    def resize(self, n_cpus: int):
        self.sim.resize(n_cpus)

    def set_allocation(self, alloc: Allocation):
        self.alloc = alloc.copy()
        self.last_metrics = self.sim.apply(self.alloc)
