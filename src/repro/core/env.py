"""RL environment for data-pipeline allocation (paper §4.1, Table 2).

Observation (Table 2):
  agent-modified:   per-stage pipeline latency, free CPUs, free memory
  uncorrelated:     model latency
  static:           DRAM-CPU bandwidth, CPU clock
Reward (Eq. 1):     R = throughput * (1 - mem_used / mem_total)
                    -> 0 as memory nears 100%; an OOM tick scores 0
                    throughput for the whole restart window, so the agent
                    learns the paper's no-OOM behavior from the reward
                    shape alone.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core import actions as act_lib
from repro.data.pipeline import PipelineSpec
from repro.data.simulator import Allocation, MachineSpec, PipelineSim


def even_allocation(spec: PipelineSpec, n_cpus: int) -> Allocation:
    """Even division across stages, done right: the `n_cpus % n_stages`
    remainder is distributed round-robin from the front instead of
    silently dropped, and when `n_stages > n_cpus` the total is capped at
    `n_cpus` (the old `max(1, ...)` floor oversubscribed the machine).
    Below one CPU per stage the trailing stages get 0 workers — a
    visibly starved (zero-throughput) pipeline instead of a silently
    oversubscribed one; such a machine cannot run the graph either way.

    Note this is NOT PipelineEnv's initial state: the paper initializes
    InTune from the even-division *heuristic baseline* (floor split,
    baselines.heuristic_even), and every published fig5/fig7 number
    started from that state, so the env keeps it bit-for-bit. New code
    (the fleet plane, pool splitting) uses this fixed version.
    """
    n = spec.n_stages
    base, rem = divmod(int(n_cpus), n)
    workers = np.full(n, base, dtype=int)
    workers[:rem] += 1
    return Allocation(workers, prefetch_mb=2 * spec.batch_mb)


def build_obs(sim: PipelineSim, alloc: Allocation) -> np.ndarray:
    """The Table-2 observation for one machine. This layout is a hard
    contract with the pretrained DQN weights (obs_dim = 2*n_stages + 6):
    PipelineEnv and FleetEnv both build observations HERE so the shared
    agents can never see diverging layouts."""
    m = sim.machine
    lat = sim.measured_latencies(alloc)
    free_cpus = m.n_cpus - int(np.sum(alloc.workers))
    free_mem = m.mem_mb - sim.memory_used(alloc)
    obs = np.concatenate([
        lat / (np.mean(lat) + 1e-9),              # relative latencies
        alloc.workers / 128.0,                    # current allocation
        [alloc.prefetch_mb / m.mem_mb,
         free_cpus / 128.0, free_mem / m.mem_mb,
         sim.model_latency,
         m.dram_bw_gbps / 100.0, m.cpu_ghz / 4.0]])
    return obs.astype(np.float32)


class PipelineEnv:
    """Steps the simulator under incremental allocation actions."""

    def __init__(self, spec: PipelineSpec, machine: MachineSpec,
                 model_latency: float = 0.0, seed: int = 0,
                 reward_scale: Optional[float] = None):
        self.spec = spec
        self.sim = PipelineSim(spec, machine, model_latency, seed=seed)
        self.prefetch_idx = next(
            (i for i, s in enumerate(spec.stages) if s.kind == "prefetch"),
            spec.n_stages - 1)
        # normalize rewards by the oracle throughput so the scale is
        # comparable across random pipelines during offline pretraining
        if reward_scale is None:
            _, best = self.sim.best_allocation()
            reward_scale = max(best, 1e-6)
        self.reward_scale = reward_scale
        # the paper's initialization: the even-division heuristic baseline
        # (floor split — the state every published benchmark starts from)
        from repro.core.baselines import heuristic_even
        self.alloc = heuristic_even(spec, machine)
        self.last_metrics = self.sim.apply(self.alloc)

    @property
    def obs_dim(self) -> int:
        # per-stage: relative latency + current workers; globals: prefetch
        # buffer frac, free CPUs, free memory, model latency, DRAM bw, GHz
        return 2 * self.spec.n_stages + 6

    def observe(self) -> np.ndarray:
        return build_obs(self.sim, self.alloc)

    def step(self, choices: np.ndarray) -> Tuple[np.ndarray, float, dict]:
        """choices: per-stage indices into DELTAS. Returns (obs, r, info)."""
        workers, pf = act_lib.next_allocation(
            choices, self.alloc.workers, self.alloc.prefetch_mb,
            prefetch_idx=self.prefetch_idx,
            max_workers=self.sim.machine.n_cpus)
        self.alloc = Allocation(workers, pf)
        metrics = self.sim.apply(self.alloc)
        self.last_metrics = metrics
        mem_frac = min(metrics["mem_mb"] / self.sim.machine.mem_mb, 1.0)
        reward = (metrics["throughput"] / self.reward_scale) * (1 - mem_frac)
        return self.observe(), float(reward), metrics

    def resize(self, n_cpus: int):
        self.sim.resize(n_cpus)

    def set_allocation(self, alloc: Allocation):
        self.alloc = alloc.copy()
        self.last_metrics = self.sim.apply(self.alloc)


class FleetEnv:
    """Cluster-granularity environment: steps a FleetSim under
    FleetAllocations and reports per-trainer observations in the same
    Table-2 layout PipelineEnv builds for one machine.

    Reward is the fleet analog of Eq. 1, summed over active trainers and
    normalized by the analytic fleet-oracle throughput of the initial
    state, so the scale is comparable across cluster specs:

        R = sum_i tput_i * (1 - mem_used_i / mem_total_i) / oracle_fleet
    """

    def __init__(self, cluster, seed: int = 0):
        from repro.core import baselines as B
        from repro.data.fleet import FleetAllocation, FleetSim
        self.cluster = cluster
        self.sim = FleetSim(cluster, seed=seed)
        state = self.sim.machine
        ideal = B.fleet_oracle(cluster, state)
        self.reward_scale = max(sum(
            B._oracle_point(cluster.trainer(n),
                            state.base(n) + ideal.grants.get(n, 0))[1]
            for n in state.active), 1e-6)
        # neutral start: even pool split, fixed even division per machine
        grants = B._even_grants(state.pool, state.active)
        self.falloc = FleetAllocation(
            {n: even_allocation(cluster.trainer(n).pipeline,
                                state.base(n) + grants[n])
             for n in state.active}, grants)
        self.last_metrics = None

    @property
    def state(self):
        return self.sim.machine

    def observe(self) -> dict:
        """{trainer: obs} for every active trainer, PipelineEnv layout
        (built by the same build_obs the single-machine env uses)."""
        out = {}
        state = self.sim.machine
        for name in state.active:
            alloc = self.falloc.allocs.get(name) or even_allocation(
                self.cluster.trainer(name).pipeline, state.base(name))
            out[name] = build_obs(self.sim.sims[name], alloc)
        return out

    def step(self, falloc) -> Tuple[dict, float, dict]:
        self.falloc = falloc
        metrics = self.sim.apply(falloc)
        self.last_metrics = metrics
        reward = 0.0
        for name, m in metrics["per_trainer"].items():
            mem_total = self.cluster.trainer(name).machine.mem_mb
            mem_frac = min(m["mem_mb"] / mem_total, 1.0)
            reward += m["throughput"] * (1.0 - mem_frac)
        reward /= self.reward_scale
        return self.observe(), float(reward), metrics
