"""Process-based StageGraph executor: real CPU contention, measured RSS.

`ThreadedPipeline` (data/executor.py) proves the control plumbing but
emulates stage cost with `time.sleep` under the GIL — sleeps don't
contend for cores, memory is budget accounting, and a serialized
section can't realize Amdahl scaling. `ProcessPipeline` speaks the
exact same contract (`set_allocation`, `stats()`, `counters()` /
`window_rate`, soft/hard `shutdown(drain=)` with dropped-batch
accounting, `get_batch`) but runs one OS-process pool per stage over
`multiprocessing` queues:

  - WORK IS REAL: `SpinWork` burns actual CPU seconds (measured with
    `time.process_time`, so the burn is contention-invariant CPU work,
    not wall time). Over-subscribing the host's cores physically slows
    every worker down — the simulator's proportional-slowdown model is
    now an emergent measurement, not an accounting charge.
  - SERIAL SECTIONS ARE REAL: `serial_frac * cost` of every item burns
    under a per-stage cross-process lock, and the parallel remainder
    carries the Amdahl coordination penalty (`SpinWork` docstring), so
    a stage's measured service rate follows the analytic curve
    `stage_throughput` predicts while the lock serializes for real —
    it saturates the stage at `1 / (serial_frac * cost)`, exactly the
    model's asymptote.
  - MEMORY IS MEASURED: a sampler thread reads each worker process's
    private resident memory from `/proc` (psutil fallback) and charges
    its GROWTH since spawn — kernels disagree on how a forked child's
    inherited copy-on-write image shows up in per-process accounting,
    but growth over the spawn baseline is the pipeline's own footprint
    on all of them. `SpinWork` allocates `mem_per_worker_mb` of touched
    ballast pages per worker, so the spec's memory knob is physically
    resident and the OOM judge (`repro.api.ProcessBackend`) fires on
    *measured* bytes against `MachineSpec.mem_mb`, not on the
    `graph_memory_mb` declaration.
  - THE CPU CAP IS PHYSICAL where the host allows: worker processes are
    pinned (`os.sched_setaffinity`, best-effort) to the first
    `min(machine.n_cpus, host cores)` cores, so a resize event shrinks
    the silicon the pipeline may touch.

Known gap vs the model (DESIGN.md §9): on a host with fewer cores than
`machine.n_cpus` the physical cores bind first, so absolute rates read
low; rankings transfer (tests/test_proc_executor.py) because candidates
share the same per-item CPU totals. `repro.data.calibrate` closes the
loop the other way: it fits the Amdahl curve to *measured* window rates
and emits a calibrated StageGraph the simulator consumes.
"""
from __future__ import annotations

import multiprocessing as mp
import os
import queue
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence

from repro.data.executor import _RateMeter, ThreadedPipeline
from repro.data.pipeline import StageGraph
from repro.data.simulator import MachineSpec

_MB = 1024 * 1024
_OUT_QUEUE_CAP = 32768     # hard bound; the live prefetch gate is _out_depth
try:
    _PAGE = os.sysconf("SC_PAGE_SIZE")
except (AttributeError, ValueError, OSError):
    _PAGE = 4096


class _Stop:
    """End-of-stream sentinel. Crosses process boundaries by pickle, so
    identity checks don't survive — compare with isinstance."""


class _Skip:
    """Worker-local "nothing to emit this cycle" sentinel (a rate-limited
    stream source polling ahead of the arrival curve). Never crosses a
    process boundary: the worker loop consumes it in place."""


_SKIP = _Skip()


def read_rss_mb(pid: int) -> Optional[float]:
    """Measured private resident memory of one process in MB (USS:
    private clean + private dirty), best effort.

    Preference order: smaps_rollup Private_* -> smaps Private_*
    (pre-4.14 kernels) -> psutil USS/RSS -> statm (resident minus
    file-backed shared); None when the process is gone. NOTE: kernels
    disagree on whether a forked child's inherited copy-on-write anon
    pages count as private (a 4.4 kernel reports the whole parent heap
    as the child's private pages), so absolute readings are
    host-dependent — `_RssSampler` charges each worker's GROWTH over
    its spawn-time baseline, which is the pipeline's own footprint
    everywhere.
    """
    # smaps_rollup (kernel >= 4.14) is one read; plain smaps (any
    # kernel) is the same Private_* accounting summed over VMAs
    for name in ("smaps_rollup", "smaps"):
        try:
            private = 0
            seen = False
            with open(f"/proc/{pid}/{name}", "rb") as f:
                for line in f:
                    if line.startswith((b"Private_Clean:",
                                        b"Private_Dirty:")):
                        private += int(line.split()[1])
                        seen = True
            if seen:
                return private / 1024.0
        except (OSError, ValueError, IndexError):
            continue
    try:
        import psutil
        proc = psutil.Process(pid)
        try:
            return proc.memory_full_info().uss / _MB
        except Exception:
            return proc.memory_info().rss / _MB
    except Exception:
        pass
    # last resort: resident minus file-backed shared (over-counts a
    # forked worker's inherited anonymous pages — better than nothing)
    try:
        with open(f"/proc/{pid}/statm", "rb") as f:
            fields = f.read().split()
        return max(0, int(fields[1]) - int(fields[2])) * _PAGE / _MB
    except (OSError, ValueError, IndexError):
        return None


try:
    _CLK_TCK = os.sysconf("SC_CLK_TCK")
except (AttributeError, ValueError, OSError):
    _CLK_TCK = 100


def read_cpu_s(pid: int) -> Optional[float]:
    """Cumulative CPU seconds (utime + stime) one process has consumed,
    from `/proc/<pid>/stat` (psutil fallback). Contention-invariant —
    the calibrator uses deltas of this to normalize measured window
    rates by worker occupancy, so the Amdahl fit survives a host with
    fewer cores than the sweep demands."""
    try:
        with open(f"/proc/{pid}/stat", "rb") as f:
            data = f.read()
        # comm may contain spaces: fields start after the last ')'
        fields = data[data.rindex(b")") + 2:].split()
        return (int(fields[11]) + int(fields[12])) / _CLK_TCK
    except (OSError, ValueError, IndexError):
        pass
    try:
        import psutil
        t = psutil.Process(pid).cpu_times()
        return float(t.user + t.system)
    except Exception:
        return None


def _spin_iters(n: int) -> float:
    """The unit of CPU work: a pure-python arithmetic loop. Iterations
    advance only while the process is scheduled, so a fixed iteration
    count is contention-invariant CPU work."""
    x = 1.0
    for _ in range(n):
        x = x * 1.0000001 + 1e-9
    return x


_iters_per_sec: Optional[float] = None


def spin_rate(min_cpu_s: float = 0.12) -> float:
    """Iterations of `_spin_iters` this process executes per CPU-second,
    calibrated against `time.process_time` over a window long enough to
    swamp its tick granularity (~10ms on older kernels — which is also
    why the burn itself can't just poll process_time: ms-scale burns
    would quantize to whole ticks). Two passes: a short probe sizes one
    measured run of >= `min_cpu_s` CPU. Cached per process; workers
    whose stages have sub-tick burns recalibrate once at
    `SpinWork.bind` (their CPU can run a different effective speed than
    the parent's)."""
    global _iters_per_sec
    if _iters_per_sec is None:
        probe = 500_000
        t0 = time.process_time()
        _spin_iters(probe)
        dt = max(time.process_time() - t0, 0.01)
        n = max(probe, int(probe * min_cpu_s / dt))
        t0 = time.process_time()
        _spin_iters(n)
        dt = max(time.process_time() - t0, 1e-3)
        _iters_per_sec = n / dt
    return _iters_per_sec


# burns at least this long poll the kernel CPU clock directly (2 ticks
# of the ~10ms cputime granularity found on older kernels/VMs)
_TICK_GUARD = 0.02
# cumulative overshoot of clock-polled burns (per process; see _burn)
_burn_debt = 0.0


def _burn(cpu_s: float, quantum: Optional[Callable] = None,
          qrate: Optional[float] = None):
    """Burn `cpu_s` seconds of CPU *work*, not wall time: under core
    contention the wall duration stretches, which is exactly the physics
    the sleep-based executor cannot realize.

    Burns >= _TICK_GUARD poll `time.process_time` — the SAME kernel
    cputime accounting `/proc/<pid>/stat` reports — so a measured
    per-item CPU equals the designed cycle by construction, immune to
    host-speed drift and hypervisor steal (this is what makes
    calibration's Amdahl fit stable on virtualized runners). Shorter
    burns would quantize to whole cputime ticks, so they spin a
    calibrated iteration count instead: still real contention-visible
    work, but their effective cost rides the per-worker calibration and
    can drift a few percent with host speed — fine for the rank-based
    differential suites, which never assert absolute rates.

    `quantum` swaps the unit of work: instead of `_spin_iters`, the
    clock-polled loop repeats the given zero-arg callable (real
    featurization ops — see data/featurize.py), with `qrate` (quanta
    per CPU-second, measured at worker bind) sizing the sub-tick path
    the way `spin_rate` sizes the spin path. The clock discipline — and
    therefore the designed-cost == measured-CPU identity calibration
    relies on — is identical for both units."""
    global _burn_debt
    if cpu_s <= 0:
        return
    if cpu_s >= _TICK_GUARD:
        # error feedback: each burn overshoots by up to one cputime tick
        # (the clock only moves in ticks) plus up to one quantum, which
        # would bias every measured per-item CPU high by a constant —
        # carry the overshoot as debt and shave it off subsequent burns,
        # so the long-run average burn equals the requested cost exactly
        target = cpu_s - _burn_debt
        if target <= 0:
            _burn_debt -= cpu_s
            return
        t0 = time.process_time()
        while True:
            elapsed = time.process_time() - t0
            if elapsed >= target:
                break
            if quantum is not None:
                quantum()
            else:
                _spin_iters(2000)
        _burn_debt += elapsed - cpu_s
        return
    if quantum is not None and qrate:
        for _ in range(max(1, int(cpu_s * qrate))):
            quantum()
        return
    _spin_iters(max(1, int(cpu_s * spin_rate())))


class SpinWork:
    """Picklable per-stage work function burning real CPU.

    Per item at pool size `a`: `serial_frac * cost` CPU-seconds under
    the stage's cross-process lock (a REAL serialized section, constant
    per item) plus `(1 - serial_frac) * cost + (a-1) * serial_frac *
    cost` outside it — the coordination penalty the Amdahl curve
    attributes to the serial fraction, growing with the pool. The
    per-worker cycle is then `cost * (a * s + 1 - s)`, so the stage's
    measured service rate is `a / cycle = 1 / (cost * (s + (1-s)/a))` —
    exactly the analytic `stage_throughput` curve — while the lock's
    utilization `a*s / (a*s + 1 - s)` approaches 1 from below: the
    serialized section really saturates the stage at
    `1 / (serial_frac * cost)`, Amdahl's asymptote. Physical core
    contention stacks on top when the host runs out of CPUs.

    `ballast_mb` of touched pages is allocated once per worker process
    (`bind`), making the spec's per-worker memory footprint resident so
    the RSS sampler measures it.

    kind: "source" emits an infinite stream (training never hits EOS);
    "join" pairs one item per input; "map" forwards its input.
    """

    def __init__(self, cost: float, serial_frac: float = 0.0,
                 ballast_mb: float = 0.0, kind: str = "map"):
        self.cost = float(cost)
        self.serial_frac = float(serial_frac)
        self.ballast_mb = float(ballast_mb)
        self.kind = kind
        self._lock = None
        self._workers = None
        self._ballast = None

    def bind(self, serial_lock, nworkers):
        """Called once inside each worker process before the first item:
        attach the stage's shared lock + live pool size, recalibrate the
        spin clock if this stage has sub-tick burns (a worker's CPU can
        run a different effective speed than the parent's), and make the
        ballast resident (every page touched).

        Stages whose burn portions all take the CPU-clock path skip the
        recalibration entirely — it costs ~0.1s of CPU at spawn, which
        would pollute a measurement window that opens right after a
        resize-up (calibration sweeps hit exactly that)."""
        global _iters_per_sec
        serial = self.serial_frac * self.cost
        par = self.cost - serial
        if 0 < serial < _TICK_GUARD or 0 < par < _TICK_GUARD:
            _iters_per_sec = None      # drop the inherited calibration
            spin_rate()
        self._lock = serial_lock
        self._workers = nworkers
        self._touch_ballast()

    def _touch_ballast(self):
        if self.ballast_mb > 0 and self._ballast is None:
            buf = bytearray(int(self.ballast_mb * _MB))
            step = _PAGE
            buf[::step] = b"\x01" * len(buf[::step])
            self._ballast = buf

    def release(self):
        """Drop worker-side memory before exit. A retiring worker whose
        exit flush is stuck behind a full downstream queue can linger for
        the rest of the run (the queue stays full at steady state); with
        the ballast freed it lingers as a bare interpreter instead of
        pinning tens of MB per ghost on an already-small host."""
        self._ballast = None

    def _do_burn(self, cpu_s: float):
        """The burn unit — subclasses swap in a real-work quantum
        (data/featurize.py) without touching the contract math."""
        _burn(cpu_s)

    def _produce(self, items):
        """The item flowing downstream; real-work subclasses return
        actual record blocks and their CPU is charged to the parallel
        portion by __call__."""
        if self.kind == "source":
            return 1
        if self.kind == "join":
            return items
        return items[0] if items else 1

    def __call__(self, *items):
        a = max(1, self._workers.value) if self._workers is not None else 1
        serial = self.serial_frac * self.cost
        par = (self.cost - serial) + (a - 1) * serial
        t0 = time.process_time()
        out = self._produce(items)
        spent = max(0.0, time.process_time() - t0)   # real transform CPU
        if serial > 0:
            if self._lock is not None:
                with self._lock:
                    self._do_burn(serial)
            else:
                self._do_burn(serial)
        self._do_burn(max(0.0, par - spent))
        return out


class StreamSourceWork(SpinWork):
    """A rate-limited source: emits batch k only once the shared arrival
    curve says k batches have arrived — the process-plane realization of
    the sim's `min(arrival_rate(t), amdahl_rate)` service cap.

    The token bucket is a shared counter (`emitted`) claimed under its
    lock against `arrival.batches_before(now)`, where `now` is stream
    time measured from the pipeline's shared start stamp (`t0`,
    CLOCK_MONOTONIC is system-wide, so every worker reads the same
    clock). A worker that finds no token sleeps briefly and returns
    `_SKIP`; one that claims a token pays the stage's full SpinWork cost
    (serialized section included), so capacity still follows the Amdahl
    curve when arrivals outpace it.

    Until `attach_stream` is called the work degrades to a plain
    unthrottled source (so the fns dict stays usable outside
    ProcessPipeline)."""

    def __init__(self, cost: float, serial_frac: float = 0.0,
                 ballast_mb: float = 0.0, arrival=None):
        super().__init__(cost, serial_frac, ballast_mb, kind="source")
        self.arrival = arrival
        self._emitted = None
        self._t0 = None

    def attach_stream(self, emitted, t0):
        """Parent-side wiring before fork/spawn: the shared token counter
        and the pipeline's stream-epoch stamp."""
        self._emitted = emitted
        self._t0 = t0

    def __call__(self, *items):
        if self.arrival is None or self._emitted is None:
            return super().__call__(*items)
        now = time.monotonic() - self._t0.value
        with self._emitted.get_lock():
            if self._emitted.value < self.arrival.batches_before(now):
                self._emitted.value += 1
                claimed = True
            else:
                claimed = False
        if not claimed:
            time.sleep(0.005)     # ahead of the world: wait for arrivals
            return _SKIP
        return super().__call__(*items)


def spin_stage_fns(spec: StageGraph, *, ballast: bool = True
                   ) -> Dict[str, SpinWork]:
    """SpinWork per stage realizing the spec's true cost, serial_frac,
    and (with `ballast`) per-worker memory footprint — the process-plane
    analog of `live_fleet.synthetic_stage_fns`, with physics instead of
    sleeps. A stage carrying an `arrival` model becomes a rate-limited
    StreamSourceWork."""
    fns: Dict[str, SpinWork] = {}
    for st in spec.stages:
        mem = st.mem_per_worker_mb if ballast else 0.0
        if getattr(st, "arrival", None) is not None:
            fns[st.name] = StreamSourceWork(
                st.cost, st.serial_frac, ballast_mb=mem, arrival=st.arrival)
            continue
        kind = "source" if not st.inputs \
            else ("join" if len(st.inputs) > 1 else "map")
        fns[st.name] = SpinWork(
            st.cost, st.serial_frac, ballast_mb=mem, kind=kind)
    return fns


def stage_fns_for(spec: StageGraph, *, ballast: bool = True
                  ) -> Dict[str, Callable]:
    """Work fns matching the spec's `work` mode: `"spin"` (default) gets
    `spin_stage_fns`; `"real"` gets `featurize_stage_fns` — actual
    hashing/pooling/padding/collation over synthetic Criteo records
    (data/featurize.py), same Amdahl contract. Lazy import keeps the
    spin path free of the featurize module."""
    if getattr(spec, "work", "spin") == "real":
        from repro.data.featurize import featurize_stage_fns
        return featurize_stage_fns(spec, ballast=ballast)
    return spin_stage_fns(spec, ballast=ballast)


# ---------------------------------------------------------------------------
# worker process plumbing
# ---------------------------------------------------------------------------

def _q_put(q, item, hard, gate=None, deadline=None) -> bool:
    while not hard.is_set():
        if deadline is not None and time.monotonic() >= deadline:
            return False
        if gate is not None:
            try:
                if q.qsize() >= max(1, gate.value):
                    time.sleep(0.002)    # live prefetch bound (re-boundable)
                    continue
            except NotImplementedError:  # platforms without qsize: ungated
                gate = None
        try:
            q.put(item, timeout=0.05)
            return True
        except queue.Full:
            continue
    return False


def _q_get(q, soft, hard, stop_sent, committed: bool = False):
    """One item or None. A soft-stopped worker exits *between* items,
    but a gather that already holds items (`committed`) keeps waiting so
    the aligned join streams lose nothing on resize-down."""
    while not hard.is_set() and not stop_sent.is_set():
        if not committed and soft.is_set():
            return None
        try:
            return q.get(timeout=0.05)
        except queue.Empty:
            continue
    return None


def _gather(in_qs, soft, hard, stop_sent, gather_lock):
    """One item from each input queue (aligned for joins): the arg list,
    a _Stop at end of stream, or None if told to stop."""
    if gather_lock is None:
        item = _q_get(in_qs[0], soft, hard, stop_sent)
        if item is None:
            return None
        if isinstance(item, _Stop):
            return item
        return [item]
    # the lock is acquired with a timeout so siblings parked on it can
    # still honor a stop instead of blocking in acquire forever
    while not gather_lock.acquire(timeout=0.05):
        if hard.is_set() or stop_sent.is_set() or soft.is_set():
            return None
    try:
        items: List = []
        for q in in_qs:
            item = _q_get(q, soft, hard, stop_sent,
                          committed=bool(items))
            if item is None:
                return None
            if isinstance(item, _Stop):
                return item
            items.append(item)
        return items
    finally:
        gather_lock.release()


def _send_stop(stop_sent, out_qs, hard, gate):
    if not stop_sent.is_set():
        stop_sent.set()
        for q in out_qs:
            _q_put(q, _Stop(), hard, gate)


def _worker_main(fn, in_qs, out_qs, soft, hard, stop_sent, gather_lock,
                 serial_lock, nworkers, counter, gate, dropped=None):
    """One stage worker process. Soft stop (resize-down / teardown)
    delivers the in-flight item if it can COMMIT it within a short
    grace; an uncommitted item is dropped (counted in `dropped`) so the
    worker exits promptly. Without the grace bound, a resize-down to a
    lean allocation would leave every retired worker alive and blocked
    on a full downstream queue that drains at consumer speed — tens of
    seconds of ghost processes stealing the very CPU the resize-down
    was meant to return. Only the hard stop aborts a committed
    delivery (an item already placed on one fan-out edge is pushed to
    the remaining edges unconditionally, keeping join streams
    aligned)."""
    # a forked worker shares the parent's heap copy-on-write; a gen-2 gc
    # pass would traverse (and dirty) every inherited object page,
    # turning shared memory private and blowing up the measured USS the
    # OOM judge scores. Workers allocate no reference cycles, so plain
    # refcounting is enough.
    import gc
    gc.disable()
    if hasattr(fn, "bind"):
        fn.bind(serial_lock, nworkers)
    try:
        _worker_loop(fn, in_qs, out_qs, soft, hard, stop_sent, gather_lock,
                     counter, gate, dropped)
    finally:
        if hasattr(fn, "release"):
            fn.release()
    # NOTE: a retiring worker may still linger in its interpreter-exit
    # queue-feeder flush (items it already committed must cross the OS
    # pipe, which can take as long as the downstream backlog takes to
    # drain). That wait is blocked-in-write — no CPU — and must NOT be
    # short-circuited with cancel_join_thread(): killing a feeder that
    # holds the queue write lock mid-write orphans the lock and wedges
    # every other writer on that queue permanently. `fn.release()` above
    # frees the ballast first so the ghost holds no pipeline memory.


def _worker_loop(fn, in_qs, out_qs, soft, hard, stop_sent, gather_lock,
                 counter, gate, dropped):
    while not soft.is_set() and not hard.is_set():
        if not in_qs:                       # source stage
            if stop_sent.is_set():          # a sibling hit EOS
                return
            out = fn()
            if isinstance(out, _Skip):      # rate-limited: no arrival yet
                continue
            if out is None:
                _send_stop(stop_sent, out_qs, hard, gate)
                return
        else:
            got = _gather(in_qs, soft, hard, stop_sent, gather_lock)
            if got is None:
                if stop_sent.is_set():
                    return
                continue
            if isinstance(got, _Stop):
                _send_stop(stop_sent, out_qs, hard, gate)
                return
            out = fn(*got)
            if out is None:                 # filtered item
                continue
        delivered = True
        committed = False
        for q in out_qs:
            grace = time.monotonic() + 0.25 \
                if soft.is_set() and not committed else None
            ok = _q_put(q, out, hard, gate, deadline=grace)
            if not ok and grace is not None and not hard.is_set():
                # retiring, and the item landed nowhere: drop it and go
                if dropped is not None:
                    with dropped.get_lock():
                        dropped.value += 1
                break
            committed = committed or ok
            delivered = ok and delivered
        else:
            if delivered:
                with counter.get_lock():
                    counter.value += 1
            continue
        return


class _ProcStagePool:
    """Resizable worker-process pool for one graph stage (the process
    analog of executor._StagePool: same soft/hard stop split, same
    retired-handle accounting for the teardown leak check)."""

    def __init__(self, name: str, fn: Callable, in_qs: Sequence,
                 out_qs: Sequence, ctx, hard_stop, workers: int = 1,
                 out_gate=None, on_spawn: Optional[Callable] = None):
        self.name = name
        self.fn = fn
        self.in_qs = list(in_qs)
        self.out_qs = list(out_qs)
        self._ctx = ctx
        self._hard = hard_stop
        self.stop_sent = ctx.Event()
        self.counter = ctx.Value("L", 0)            # delivered items
        self.dropped_ct = ctx.Value("L", 0)         # fast-retire drops
        self.nworkers_val = ctx.Value("i", 1, lock=False)
        self.serial_lock = ctx.Lock()
        self.gather_lock = ctx.Lock() if len(self.in_qs) > 1 else None
        self.out_gate = out_gate
        self._on_spawn = on_spawn
        self.meter = _RateMeter()                   # parent-side, counter-fed
        self.procs: List = []
        self._soft_flags: List = []
        self._retired: List = []
        self.resize(workers)

    # ---------------------------------------------------------- control ---
    def resize(self, n: int):
        n = max(1, int(n))
        while len(self.procs) < n:
            soft = self._ctx.Event()
            p = self._ctx.Process(
                target=_worker_main,
                args=(self.fn, self.in_qs, self.out_qs, soft, self._hard,
                      self.stop_sent, self.gather_lock, self.serial_lock,
                      self.nworkers_val, self.counter, self.out_gate,
                      self.dropped_ct),
                daemon=True)
            p.start()
            if self._on_spawn is not None:
                self._on_spawn(p.pid)
            self.procs.append(p)
            self._soft_flags.append(soft)
        while len(self.procs) > n:
            self._retired = [p for p in self._retired if p.is_alive()]
            self._soft_flags.pop().set()            # soft stop: delivers
            self._retired.append(self.procs.pop())
        # SpinWork reads this to size the Amdahl coordination penalty:
        # the service curve tracks the live pool size
        self.nworkers_val.value = n

    @property
    def n_workers(self) -> int:
        return len(self.procs)

    def delivered(self) -> int:
        return int(self.counter.value)

    def dropped(self) -> int:
        """Items dropped by retiring workers that could not commit
        their in-flight delivery within the fast-retire grace."""
        return int(self.dropped_ct.value)

    def sync_meter(self):
        """Feed the shared-counter delta into the EWMA meter (decays on
        read like the thread meters — satellite of the stale-rate fix)."""
        self.meter.mark_many(self.delivered() - self.meter.count)

    def pids(self) -> List[int]:
        return [p.pid for p in self.procs + self._retired if p.is_alive()]

    def cpu_s(self) -> float:
        """Cumulative CPU seconds consumed by the pool's live workers
        (calibration reads deltas of this across a measurement window)."""
        return sum(filter(None, (read_cpu_s(pid) for pid in self.pids())))

    def stop(self):
        for f in self._soft_flags:
            f.set()

    def join(self, timeout: float = 2.0) -> bool:
        """Join every process this pool ever started. Returns True when
        all exited within the deadline; stragglers are then terminated
        (and as a last resort killed) so OS processes can never leak."""
        deadline = time.monotonic() + timeout
        ok = True
        for p in self.procs + self._retired:
            p.join(timeout=max(0.0, deadline - time.monotonic()))
            ok = ok and not p.is_alive()
        for p in self.procs + self._retired:
            if p.is_alive():
                p.terminate()
                p.join(0.5)
            if p.is_alive():
                p.kill()
                p.join(0.5)
        return ok


class _RssSampler(threading.Thread):
    """Parent-side thread summing measured resident MB over the worker
    processes every `interval` seconds (`sample()` also runs one
    synchronous pass, so stats() reads are never stale).

    Each worker is charged its GROWTH since spawn (`baselines`: pid ->
    reading taken right after fork): kernels differ on how much of a
    forked child's inherited copy-on-write image leaks into per-process
    private/Pss accounting (this repo has seen a 4.4 kernel report the
    whole parent heap as the child's private pages), and none of that
    memory is the pipeline's. What the pipeline ALLOCATES — ballast,
    queue buffers, interpreter arenas — is growth over the baseline on
    every kernel.
    """

    def __init__(self, pids_fn: Callable[[], List[int]],
                 baselines: Dict[int, float], interval: float = 0.05):
        super().__init__(daemon=True)
        self._pids_fn = pids_fn
        self._baselines = baselines
        self.interval = interval
        self.rss_mb = 0.0
        self.peak_mb = 0.0
        self._halt = threading.Event()

    def sample(self) -> float:
        total, got = 0.0, False
        for pid in self._pids_fn():
            mb = read_rss_mb(pid)
            if mb is not None:
                total += max(0.0, mb - self._baselines.get(pid, 0.0))
                got = True
        if got:
            self.rss_mb = total
            self.peak_mb = max(self.peak_mb, total)
        return self.rss_mb

    def run(self):
        while not self._halt.is_set():
            t0 = time.monotonic()
            self.sample()
            cost = time.monotonic() - t0
            # bound the sampler's duty cycle at ~10% of one core: a pass
            # walks /proc smaps for every live worker pid IN THE PARENT
            # (trainer) process, and during a resize-down the pid set
            # transiently includes every retiring worker — at a fixed
            # interval that scan competes with the very device step the
            # resize was meant to unblock
            self._halt.wait(max(self.interval, 9.0 * cost))

    def stop(self):
        self._halt.set()


# ---------------------------------------------------------------------------
# the pipeline
# ---------------------------------------------------------------------------

class ProcessPipeline:
    """Runs a StageGraph with one OS-process pool per stage;
    `get_batch()` feeds the trainer. ThreadedPipeline's exact contract
    (DESIGN.md §9 has the side-by-side table); differences are physics:
    measured RSS instead of budget accounting, real core contention,
    real serialized sections.

    `fns` default to `spin_stage_fns(spec)`. Custom fns must be
    picklable under the chosen start method ("fork" where available, so
    closures work on Linux; pass `ctx=multiprocessing.get_context(...)`
    to override).
    """

    def __init__(self, spec: StageGraph, *,
                 fns: Optional[Dict[str, Callable]] = None,
                 queue_depth: int = 16, item_mb: Optional[float] = None,
                 machine: Optional[MachineSpec] = None, ctx=None,
                 rss_interval: float = 0.2,
                 pin_cpus: Optional[int] = None):
        if fns is None:
            fns = stage_fns_for(spec)
        missing = [s.name for s in spec.stages if s.name not in fns]
        assert not missing, f"missing stage fns: {missing}"
        self.spec = spec
        self.item_mb = item_mb if item_mb is not None else spec.batch_mb
        self.machine = machine if machine is not None else MachineSpec()
        # feed-bridge knob: cap worker affinity to this many host cores
        # regardless of machine.n_cpus, reserving the rest for a trainer
        # process sharing the host (examples/train_dlrm_criteo.py pins
        # the feed pipeline to 1 core so JAX keeps the others)
        self.pin_cpus = pin_cpus
        self.prefetch_mb = 2 * self.item_mb
        if ctx is None:
            method = "fork" if "fork" in mp.get_all_start_methods() \
                else "spawn"
            ctx = mp.get_context(method)
        self._ctx = ctx
        # calibrate the spin-work clock BEFORE forking, so every worker
        # inherits one shared iterations/CPU-second figure (once per
        # interpreter; spawned workers recalibrate on bind)
        spin_rate()
        self.edge_queues: Dict[tuple, object] = {
            e: ctx.Queue(maxsize=queue_depth) for e in spec.edges}
        self.out_q = ctx.Queue(maxsize=_OUT_QUEUE_CAP)
        # the agent's prefetch knob: sink workers gate their puts on this
        # shared depth, so set_allocation re-bounds the output live
        self._out_depth = ctx.Value("i", self._prefetch_depth(), lock=False)
        self._eos = False
        self._hard_stop = ctx.Event()
        self._rss_baseline: Dict[int, float] = {}
        self._last_resize_at = 0.0
        # streaming source wiring: shared token counter + stream epoch,
        # attached parent-side so every forked/spawned worker claims
        # against the same arrival curve
        self._stream_arrival = None
        self._stream_emitted = None
        self._stream_t0 = None
        for st in spec.stages:
            fn = fns[st.name]
            if getattr(st, "arrival", None) is not None \
                    and hasattr(fn, "attach_stream"):
                self._stream_arrival = st.arrival
                self._stream_emitted = ctx.Value("L", 0)
                self._stream_t0 = ctx.Value("d", time.monotonic())
                fn.attach_stream(self._stream_emitted, self._stream_t0)
                break                       # StageGraph enforces <= 1
        self.pools: List[_ProcStagePool] = []
        for i, st in enumerate(spec.stages):
            in_qs = [self.edge_queues[(p, i)] for p in spec.parents(i)]
            out_qs = [self.edge_queues[(i, c)] for c in spec.children(i)]
            gate = None
            if i == spec.sink:
                out_qs = [self.out_q]
                gate = self._out_depth
            self.pools.append(_ProcStagePool(
                st.name, fns[st.name], in_qs, out_qs, ctx, self._hard_stop,
                workers=1, out_gate=gate, on_spawn=self._on_spawn))
        self.out_meter = _RateMeter()
        self._sampler = _RssSampler(self._worker_pids, self._rss_baseline,
                                    interval=rss_interval)
        self._sampler.sample()
        self._sampler.start()

    def _prefetch_depth(self) -> int:
        return max(1, int(self.prefetch_mb / max(self.item_mb, 1e-6)))

    def _worker_pids(self) -> List[int]:
        return [pid for p in self.pools for pid in p.pids()]

    # ----------------------------------------------------- physical caps --
    def _on_spawn(self, pid: int):
        """Per-worker spawn hook: record the memory baseline (the
        sampler charges growth since spawn, not the inherited image —
        see _RssSampler) and pin the worker to the capped core set."""
        self._rss_baseline[pid] = read_rss_mb(pid) or 0.0
        self._pin_worker(pid)

    def _pin_worker(self, pid: int):
        """Best-effort: pin the worker to the first min(machine cap, host
        cores) cores, so a resize event shrinks the silicon the pipeline
        may touch (the physical realization of the sim's CPU cap)."""
        if not hasattr(os, "sched_setaffinity"):
            return
        host = os.cpu_count() or 1
        cap = int(self.pin_cpus) if self.pin_cpus is not None \
            else int(self.machine.n_cpus)
        try:
            os.sched_setaffinity(pid, range(max(1, min(cap, host))))
        except OSError:
            pass

    def apply_cpu_cap(self):
        """Re-pin every live worker after a machine resize."""
        for pid in self._worker_pids():
            self._pin_worker(pid)

    # ----------------------------------------------------------- control --
    def worker_counts(self) -> List[int]:
        return [p.n_workers for p in self.pools]

    def set_allocation(self, workers, prefetch_mb: float):
        before = self.worker_counts()
        for pool, w in zip(self.pools, workers):
            pool.resize(int(w))
        self.prefetch_mb = float(prefetch_mb)
        self._out_depth.value = self._prefetch_depth()
        if self.worker_counts() != before:
            # fresh workers self-calibrate for ~0.2s before producing;
            # measure() uses this stamp to flag the settling window
            self._last_resize_at = time.monotonic()

    @property
    def prefetch_depth(self) -> int:
        return self._out_depth.value

    def rss_mb(self) -> float:
        """Measured resident MB summed over the worker processes, now."""
        return self._sampler.sample()

    def stream_state(self) -> Optional[dict]:
        """Exact stream accounting, or None for non-stream graphs:
        arrivals is the arrival curve's integral at stream time `t`,
        emitted the tokens claimed by source workers, backlog their gap
        (batches that have arrived but not yet entered the pipeline)."""
        if self._stream_arrival is None:
            return None
        t = time.monotonic() - self._stream_t0.value
        arrivals = self._stream_arrival.batches_before(t)
        emitted = float(self._stream_emitted.value)
        return {"t": t, "arrivals": arrivals, "emitted": emitted,
                "backlog": max(0.0, arrivals - emitted),
                "arrival_rate": self._stream_arrival.batches_per_sec(t)}

    def stream_epoch(self) -> Optional[dict]:
        """The stream's persistent identity: the monotonic t0 anchoring
        its arrival curve plus the tokens already emitted against it.
        None for non-stream graphs. A relaunch that adopts this epoch
        RESUMES the curve — stream time keeps running through the dead
        window, so backlog accrues while the process is down (the
        simulator's "the world does not pause for an OOM" contract)."""
        if self._stream_arrival is None:
            return None
        return {"emitted": int(self._stream_emitted.value),
                "t0": float(self._stream_t0.value)}

    def adopt_stream_epoch(self, epoch: Optional[dict]):
        """Resume a predecessor's arrival curve instead of starting a
        fresh one. Must be called before the first tokens are claimed
        (RigSlot adopts immediately after relaunch). No-op for
        non-stream graphs or a None epoch."""
        if self._stream_arrival is None or not epoch:
            return
        with self._stream_emitted.get_lock():
            self._stream_emitted.value = int(epoch["emitted"])
        # ctx.Value mutations are visible to already-forked workers:
        # both fields live in shared memory
        self._stream_t0.value = float(epoch["t0"])

    def stats(self) -> dict:
        for p in self.pools:
            p.sync_meter()
        rates = [p.meter.rate for p in self.pools]
        lat = [1.0 / r if r > 0 else 10.0 for r in rates]

        def _qs(q):
            try:
                return q.qsize()
            except NotImplementedError:
                return 0

        edge_sizes = [_qs(q) for q in self.edge_queues.values()]
        # the sampler's cached reading (at most rss_interval stale): a
        # synchronous re-scan here would walk /proc smaps a second time
        # per tick on the driver's hot path — the OOM judge calls
        # rss_mb() when it needs a fresh verdict
        rss = self._sampler.rss_mb
        stream = self.stream_state()
        extra = {} if stream is None else {
            "backlog_items": stream["backlog"],
            "arrival_rate": stream["arrival_rate"]}
        return {
            **extra,
            "throughput": self.out_meter.rate,
            "stage_rate": rates,
            "stage_latency": lat,
            "queue_sizes": edge_sizes + [_qs(self.out_q)],
            "workers": self.worker_counts(),
            "prefetch_mb": self.prefetch_mb,
            # MEASURED, not declared: the sampler's resident bytes
            "mem_frac": rss / self.machine.mem_mb,
            "free_cpus": max(0, self.machine.n_cpus
                             - sum(self.worker_counts())),
            "counts": [p.meter.count for p in self.pools],
            "rss_mb": rss,
        }

    # ------------------------------------------------------ measurement --
    def counters(self) -> dict:
        """Monotonic batch counters + timestamp (ThreadedPipeline's
        measured-window contract; `delivered` reads the sink pool's
        shared cross-process counter)."""
        return {"delivered": self.pools[self.spec.sink].delivered(),
                "consumed": self.out_meter.count,
                "time": time.monotonic(),
                "last_resize_at": self._last_resize_at}

    window_rate = staticmethod(ThreadedPipeline.window_rate)

    # ----------------------------------------------------------- teardown --
    def shutdown(self, drain: bool = True, timeout: float = 10.0) -> dict:
        """Graceful teardown honoring the soft/hard stop split (the
        ThreadedPipeline contract: soft-stop, drain, hard-stop, join —
        with `dropped` accounting; drain=False models an OOM kill)."""
        deadline = time.monotonic() + timeout
        for p in self.pools:
            p.stop()
        drained = 0
        sink_pool = self.pools[self.spec.sink]
        if drain:
            while time.monotonic() < deadline:
                try:
                    if not isinstance(self.out_q.get_nowait(), _Stop):
                        drained += 1
                except queue.Empty:
                    if not any(pr.is_alive() for pr in sink_pool.procs):
                        break
                    time.sleep(0.005)
        self._hard_stop.set()
        # pump every queue while workers exit: a worker whose interpreter
        # is flushing buffered queue items at exit blocks on a full pipe
        # until a reader empties it. The spin plane's int-sized items
        # never fill the 64KB pipe buffer; real-work record blocks
        # (data/featurize.py) overflow it at depth 1, so without this
        # pump every mid-chain worker would hang in its exit flush and
        # eat the whole join deadline before being terminated.
        def _alive():
            return any(pr.is_alive() for pool in self.pools
                       for pr in pool.procs + pool._retired)

        pump_end = max(deadline - 0.5, time.monotonic() + 0.05)
        while _alive() and time.monotonic() < pump_end:
            for q in self.edge_queues.values():
                try:
                    while True:
                        q.get_nowait()
                except queue.Empty:
                    pass
            try:
                while True:
                    if not isinstance(self.out_q.get_nowait(), _Stop) \
                            and drain:
                        drained += 1
            except queue.Empty:
                pass
            time.sleep(0.01)
        joined = True
        for p in self.pools:
            joined = p.join(max(0.1, deadline - time.monotonic())) and joined
        if drain:
            # final sweep with a short grace: a queue item written just
            # before the writer exited can land a moment after the join
            grace = time.monotonic() + 0.25
            while True:
                try:
                    if not isinstance(self.out_q.get(timeout=0.05), _Stop):
                        drained += 1
                except queue.Empty:
                    if time.monotonic() > grace:
                        break
        self._sampler.stop()
        delivered = sink_pool.delivered()
        consumed = self.out_meter.count
        for q in list(self.edge_queues.values()) + [self.out_q]:
            # lint: allow[no-cancel-join-thread] -- parent-side only, after every worker was joined/terminated/killed above; a straggler terminated mid-write leaves the queue's write lock orphaned, and without this the PARENT's feeder thread blocks forever on it at close(). The only parent data at risk here is the re-put _Stop sentinel.
            q.cancel_join_thread()
            q.close()
        return {"delivered": delivered, "consumed": consumed,
                "drained": drained, "joined": joined,
                "dropped": (max(0, delivered - consumed - drained)
                            if drain else 0),
                "dropped_inflight": sum(p.dropped() for p in self.pools)}

    # ------------------------------------------------------------ output --
    def get_batch(self, timeout: float = 10.0):
        if self._eos and self.out_q.empty():
            raise StopIteration
        item = self.out_q.get(timeout=timeout)
        if isinstance(item, _Stop):
            self._eos = True
            try:
                self.out_q.put_nowait(item)     # for sibling consumers
            except queue.Full:
                pass
            raise StopIteration
        self.out_meter.mark()
        return item

    def stop(self):
        self._hard_stop.set()
        for p in self.pools:
            p.stop()
        self._sampler.stop()
