"""Synthetic datasets + the online transformation fns the pipeline runs.

Criteo-like CTR records (26 categorical + 13 continuous), LM token
streams, and the per-family batch builders used by examples/ and tests.
The UDF here is the real feature-extraction path: hashing raw ids into
table rows, log-transforming dense features, building multi-hot bags —
exactly the per-model online work the paper argues cannot be pushed
offline (scale / reusability / volatility, §1).
"""
from __future__ import annotations

from typing import Optional

import numpy as np


class CriteoStream:
    """Infinite synthetic click-log stream with a planted CTR signal."""

    def __init__(self, n_sparse: int = 26, n_dense: int = 13,
                 vocab: int = 1 << 20, multi_hot: int = 1, seed: int = 0):
        self.n_sparse, self.n_dense = n_sparse, n_dense
        self.vocab, self.multi_hot = vocab, multi_hot
        self.rng = np.random.RandomState(seed)
        # planted weights so training actually reduces loss
        self.w_dense = self.rng.randn(n_dense) * 0.5
        self.w_sparse = self.rng.randn(n_sparse) * 0.3

    def raw_block(self, n: int) -> dict:
        """Raw (pre-UDF) records: un-hashed ids + raw dense values."""
        raw_ids = self.rng.randint(0, 1 << 31,
                                   size=(n, self.n_sparse, self.multi_hot))
        dense_raw = self.rng.lognormal(0.0, 1.0, size=(n, self.n_dense))
        # CTR signal from a few planted features
        logit = dense_raw @ self.w_dense * 0.1 + \
            ((raw_ids[:, :, 0] % 97) / 97.0 - 0.5) @ self.w_sparse
        label = (self.rng.rand(n) < 1 / (1 + np.exp(-logit))).astype(
            np.float32)
        return {"raw_ids": raw_ids.astype(np.int64),
                "dense_raw": dense_raw.astype(np.float32), "label": label}

    # --------------------------- pipeline stage UDFs (the online work) ----
    @staticmethod
    def shuffle_udf(block: dict, rng: Optional[np.random.RandomState] = None
                    ) -> dict:
        rng = rng or np.random
        n = block["label"].shape[0]
        perm = rng.permutation(n)
        return {k: v[perm] for k, v in block.items()}

    def feature_udf(self, block: dict) -> dict:
        """Hash ids into table rows; log1p + normalize dense features."""
        h = block["raw_ids"].astype(np.uint32) * np.uint32(2654435761)
        sparse_ids = (h % np.uint32(self.vocab)).astype(np.int32)
        dense = np.log1p(block["dense_raw"]).astype(np.float32)
        dense = (dense - dense.mean(0)) / (dense.std(0) + 1e-6)
        return {"sparse_ids": sparse_ids, "dense": dense,
                "label": block["label"]}

    @staticmethod
    def batch_udf(block: dict) -> dict:
        return {k: np.ascontiguousarray(v) for k, v in block.items()}


class TokenStream:
    """Synthetic LM token stream (zipf-ish unigram with local structure)."""

    def __init__(self, vocab: int, seq_len: int, seed: int = 0):
        self.vocab, self.seq_len = vocab, seq_len
        self.rng = np.random.RandomState(seed)

    def batch(self, n: int) -> dict:
        z = self.rng.zipf(1.3, size=(n, self.seq_len + 1))
        toks = (z % self.vocab).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def bert4rec_batch(rng, batch: int, seq_len: int, n_items: int,
                   n_mask: int, n_neg: int) -> dict:
    """Cloze-masked item sequences with uniform sampled-softmax negatives."""
    seq = rng.randint(0, n_items, size=(batch, seq_len)).astype(np.int32)
    pos = np.stack([rng.choice(seq_len, size=n_mask, replace=False)
                    for _ in range(batch)]).astype(np.int32)
    labels = np.take_along_axis(seq, pos, axis=1)
    masked = seq.copy()
    np.put_along_axis(masked, pos, n_items, axis=1)   # MASK token id
    negs = rng.randint(0, n_items,
                       size=(batch, n_mask, n_neg)).astype(np.int32)
    return {"item_seq": masked, "mask_pos": pos, "mask_labels": labels,
            "neg_ids": negs}


def dien_batch(rng, batch: int, seq_len: int, n_items: int,
               n_dense: int) -> dict:
    hist = rng.randint(0, n_items, size=(batch, seq_len)).astype(np.int32)
    lens = rng.randint(seq_len // 4, seq_len + 1, size=batch)
    mask = (np.arange(seq_len)[None, :] < lens[:, None]).astype(np.float32)
    target = rng.randint(0, n_items, size=batch).astype(np.int32)
    dense = rng.randn(batch, n_dense).astype(np.float32)
    # label correlates with target appearing in history (planted signal)
    appears = (hist == target[:, None]).any(1)
    label = ((appears | (rng.rand(batch) < 0.2))).astype(np.float32)
    return {"hist_ids": hist, "hist_mask": mask, "target_id": target,
            "dense": dense, "label": label}
