"""LiveFleet: the fleet plane on real ThreadedPipeline executors.

FleetSim (data/fleet.py) validates fleet policies against N analytic
per-trainer models; this module is the same plane made executable — one
real `ThreadedPipeline` per active trainer, with worker threads whose
per-item work realizes each StageSpec's true cost, and a consumer thread
per trainer modeling the training loop (pulls batches, sleeps
`model_latency` per batch, so model demand back-pressures the pipeline
exactly where the simulator caps throughput).

LiveFleet speaks the exact FleetSim driver dialect (`machine` / `apply`
/ `resize` / `oom_count`), so `repro.api.Session` and the
`FleetCoordinator` drive it unchanged. Contract alignment with the sim:

  - THROUGHPUT is measured, not modeled: `apply` sets every active
    trainer's allocation first (atomically, before any measurement),
    then sleeps one shared `window_s` window and reads each pipeline's
    batch-counter delta over the measured elapsed
    (`ThreadedPipeline.counters`, wall-clock-free of the EWMA meters).
  - MEMORY is budget-enforced accounting: the same `graph_memory_mb`
    model the simulator scores OOMs with. An over-budget allocation is
    an OOM — the pipeline process is killed (hard stop, no drain) and
    pays the simulator's `OOM_RESTART_TICKS` dead window before a fresh
    relaunch — so the coordinator's admission control and quarantine
    semantics transfer verbatim.
  - CHURN honors the soft/hard stop split: a `leave` (and `close`) tears
    a pipeline down gracefully — soft-stop, drain the output queue, then
    hard-stop and join every thread — and accounts any sink-delivered
    batch that was lost in `dropped_batches` (0 on clean teardown). A
    `join` spins up a fresh pipeline; `resize`/`pool` re-caps apply
    before the next measurement window.

Known sim-vs-live gaps (DESIGN.md §7): stage work is `time.sleep`, so a
serial fraction is emulated by a per-stage lock (exact only for
`serial_frac == 0`, which the live clusters below use), and CPU
over-subscription does not physically contend — the simulator's
proportional slowdown is charged in accounting instead. The process
plane (repro.data.proc_executor, DESIGN.md §9) closes both gaps with
real CPU burns; this module also hosts the `RigSlot` lifecycle and
`_TrainerRig` consumer rig that plane reuses.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Dict, List, Tuple

import numpy as np

from repro.data.executor import ThreadedPipeline
from repro.data.fleet import (ClusterSpec, FleetAllocation, FleetBackend,
                              TrainerSpec, churn_schedule)
from repro.data.pipeline import StageGraph, StageSpec
from repro.data.simulator import (Allocation, MachineSpec, OOM_RESTART_TICKS,
                                  graph_memory_mb)


def synthetic_stage_fns(spec: StageGraph) -> Dict[str, Callable]:
    """Work functions realizing each StageSpec's true cost with sleeps.

    A stage's fn takes `cost` seconds per item, so with `w` workers it
    sustains `w / cost` batches/s — exactly the simulator's service rate
    when `serial_frac == 0`. A non-zero serial fraction is emulated by
    taking `serial_frac * cost` under a per-stage lock (an approximation
    of Amdahl scaling: both saturate at `1 / (serial_frac * cost)`, but
    the knee differs — live differential clusters use 0).

    Sources emit an infinite stream (training never hits EOS); joins
    pair one item per input; everything else forwards its input.
    """
    fns: Dict[str, Callable] = {}
    for st in spec.stages:
        serial = st.serial_frac * st.cost
        par = st.cost - serial
        lock = threading.Lock() if serial > 1e-9 else None

        def work(lock=lock, serial=serial, par=par):
            if lock is not None:
                with lock:
                    time.sleep(serial)
            if par > 0:
                time.sleep(par)

        if not st.inputs:
            def fn(work=work):
                work()
                return 1                       # infinite stream, never EOS
        elif len(st.inputs) > 1:
            def fn(*items, work=work):
                work()
                return items
        else:
            def fn(item, work=work):
                work()
                return item
        fns[st.name] = fn
    return fns


class _TrainerRig:
    """One live trainer: a pipeline plus a consumer thread that models
    the training loop — it pulls batches and sleeps `model_latency` per
    batch, so a saturated model back-pressures the pipeline through the
    (prefetch-bounded) output queue, the live realization of the
    simulator's `1 / model_latency` throughput cap.

    `make_pipe(trainer, eff_cpus, queue_depth)` picks the execution
    substrate; the default builds a sleep-based ThreadedPipeline (the
    process plane passes a ProcessPipeline factory instead)."""

    def __init__(self, trainer: TrainerSpec, eff_cpus: int,
                 queue_depth: int = 8, make_pipe=None):
        self.trainer = trainer
        if make_pipe is None:
            self.pipe = ThreadedPipeline(
                trainer.pipeline, fns=synthetic_stage_fns(trainer.pipeline),
                queue_depth=queue_depth,
                machine=dataclasses.replace(trainer.machine,
                                            n_cpus=int(eff_cpus)))
        else:
            self.pipe = make_pipe(trainer, int(eff_cpus), queue_depth)
        self._stop = threading.Event()
        self._consumer = threading.Thread(target=self._model_loop,
                                          daemon=True)
        self._consumer.start()

    def _model_loop(self):
        while not self._stop.is_set():
            try:
                self.pipe.get_batch(timeout=0.05)
            except (queue.Empty, StopIteration):
                continue
            if self.trainer.model_latency > 0:
                time.sleep(self.trainer.model_latency)

    # ---------------------------------------------------------- control ---
    def set_allocation(self, alloc: Allocation):
        self.pipe.set_allocation(alloc.workers, alloc.prefetch_mb)

    def set_eff_cpus(self, n: int):
        self.pipe.machine = dataclasses.replace(self.pipe.machine,
                                                n_cpus=int(n))
        if hasattr(self.pipe, "apply_cpu_cap"):
            self.pipe.apply_cpu_cap()      # process plane: re-pin workers

    def counters(self) -> dict:
        return self.pipe.counters()

    def teardown(self, drain: bool = True, timeout: float = 5.0) -> dict:
        """Stop the consumer first (so the drain accounting is stable),
        then shut the pipeline down. drain=True is the clean leave path;
        drain=False models an OOM kill."""
        self._stop.set()
        self._consumer.join(timeout=timeout)
        acct = self.pipe.shutdown(drain=drain, timeout=timeout)
        acct["joined"] = acct["joined"] and not self._consumer.is_alive()
        return acct


class RigSlot:
    """One live trainer's kill / dead-window / relaunch lifecycle.

    ExecutorBackend (single machine), LiveFleet (one slot per trainer),
    and ProcessBackend all used to hand-roll the same tick: count a
    restart window down and relaunch when it expires, hard-kill on the
    OOM judge's verdict (no drain — an OOM is a crash), accumulate
    crash-lost batches and the thread-leak flag. This class is that
    tick, extracted so the paths cannot drift (the PR 4 deferred dedup;
    both sites stay pinned by the executor-parity and live-fleet tests).

    `launch(eff_cpus) -> rig` builds a fresh rig; `rig` is anything with
    the _TrainerRig surface (pipe / set_allocation / set_eff_cpus /
    counters / teardown).
    """

    def __init__(self, launch, rig=None):
        self.launch = launch
        self.rig = rig
        self.restart_left = 0
        self.oom_count = 0
        self.crash_lost = 0
        self.all_joined = True
        # stream epoch carried across an OOM relaunch (proc plane): the
        # arrival curve survives the crash — see kill()/tick_dead_window
        self.carry_epoch = None

    @property
    def live(self) -> bool:
        return self.rig is not None

    def tick_dead_window(self, eff_cpus: int) -> bool:
        """True while this tick falls inside the dead window: counts it
        down and relaunches a fresh rig the moment it expires (the
        simulator's OOM_RESTART_TICKS protocol, verbatim)."""
        if self.restart_left <= 0:
            return False
        self.restart_left -= 1
        if self.restart_left == 0 and self.rig is None:
            self.rig = self.launch(eff_cpus)
            if self.carry_epoch is not None:
                # resume the predecessor's arrival curve: stream time ran
                # on through the dead window, so the relaunched source
                # faces the backlog that accrued while it was down —
                # exactly the simulator's backlog-OOM crash-loop dynamics
                adopt = getattr(self.rig.pipe, "adopt_stream_epoch", None)
                if callable(adopt):
                    adopt(self.carry_epoch)
                self.carry_epoch = None
        return True

    def kill(self):
        """The OOM judge's verdict: the process is killed — hard stop,
        no drain — and pays the restart window before relaunch."""
        self.oom_count += 1
        self.restart_left = OOM_RESTART_TICKS
        if self.rig is not None:
            epoch = getattr(self.rig.pipe, "stream_epoch", None)
            if callable(epoch):
                self.carry_epoch = epoch()
            acct = self.rig.teardown(drain=False)
            self.crash_lost += max(0, acct["delivered"] - acct["consumed"])
            self.all_joined = self.all_joined and acct["joined"]
            self.rig = None

    def prepare(self, eff_cpus: int, alloc: Allocation):
        """Sync the rig's CPU cap and apply the allocation — called for
        every measuring trainer BEFORE any measurement window opens."""
        if self.rig.pipe.machine.n_cpus != eff_cpus:
            self.rig.set_eff_cpus(eff_cpus)
        self.rig.set_allocation(alloc)

    @staticmethod
    def discount(tput: float, used: int, eff: int) -> float:
        """Sleep-based rigs can't physically contend, so the simulator's
        proportional over-subscription slowdown is charged in
        accounting. The process plane must NOT call this — its
        contention is real and already in the measured rate."""
        return tput * (eff / used) if used > eff else tput

    def close(self, drain: bool = True) -> int:
        """Clean teardown (leave / shutdown); returns dropped batches.
        A clean leave forfeits any carried stream epoch — only the OOM
        kill/relaunch path resumes the curve; an explicit rejoin is a
        fresh stream."""
        self.carry_epoch = None
        dropped = 0
        if self.rig is not None:
            acct = self.rig.teardown(drain=drain)
            dropped = acct["dropped"]
            self.all_joined = self.all_joined and acct["joined"]
            self.rig = None
        return dropped


class LiveFleet(FleetBackend):
    """Drop-in fleet backend: one real ThreadedPipeline per active
    trainer, FleetSim's exact driver dialect.

    `seed` is accepted for factory-signature compatibility with FleetSim
    (thread scheduling is the noise source here, not an RNG).
    `window_s` is the per-tick measurement window; throughput is the
    consumed-batch counter delta over the measured elapsed. Call
    `close()` (or use as a context manager) to tear every rig down and
    collect the final drop/leak accounting.
    """

    def __init__(self, cluster: ClusterSpec, seed: int = 0,
                 window_s: float = 0.1, queue_depth: int = 8):
        super().__init__(cluster)
        self.window_s = float(window_s)
        self.queue_depth = queue_depth
        self.dropped_batches = 0
        self._closed = False
        self.slots: Dict[str, RigSlot] = {
            t.name: RigSlot(self._make_launch(t)) for t in cluster.trainers}
        for t in cluster.trainers:
            if t.start_active:
                self.slots[t.name].rig = self.slots[t.name].launch(
                    t.machine.n_cpus)

    def _make_launch(self, trainer: TrainerSpec):
        return lambda eff: _TrainerRig(trainer, eff, self.queue_depth)

    # ------------------------------------------------- legacy dict views --
    @property
    def rigs(self) -> Dict[str, _TrainerRig]:
        """Live rigs by trainer name (membership = the process is up)."""
        return {n: s.rig for n, s in self.slots.items() if s.rig is not None}

    @property
    def oom_counts(self) -> Dict[str, int]:
        return {n: s.oom_count for n, s in self.slots.items()}

    @property
    def restart_left(self) -> Dict[str, int]:
        return {n: s.restart_left for n, s in self.slots.items()}

    @property
    def crash_lost(self) -> int:
        return sum(s.crash_lost for s in self.slots.values())

    @property
    def all_joined(self) -> bool:
        return all(s.all_joined for s in self.slots.values())

    # ----------------------------------------------------------- churn ----
    def _on_join(self, name: str):
        slot = self.slots[name]
        # a (re)joining machine is a fresh process: no restart debt, and
        # no stream epoch carried over (an explicit rejoin starts fresh)
        slot.restart_left = 0
        slot.carry_epoch = None
        if slot.rig is None:
            slot.rig = slot.launch(self._base[name])

    def _on_leave(self, name: str):
        self.dropped_batches += self.slots[name].close(drain=True)

    @property
    def oom_count(self) -> int:
        return sum(s.oom_count for s in self.slots.values())

    # -------------------------------------------- substrate judge hooks ----
    # The threaded plane enforces the simulator's BUDGET memory model
    # before the window opens and charges the accounting discount for
    # over-subscription (sleeps don't contend); the process plane
    # (ProcFleet) swaps all three hooks for physics — measured RSS after
    # the window, no discount.
    def _pre_window_oom(self, trainer: TrainerSpec, slot: RigSlot,
                        mem: float) -> bool:
        """Budget-enforced OOM (the simulator's judge, verbatim): kill +
        OOM_RESTART_TICKS dead window, via the shared RigSlot
        lifecycle."""
        return mem > trainer.machine.mem_mb

    def _post_window_judge(self, trainer: TrainerSpec, slot: RigSlot,
                           mem: float) -> Tuple[float, bool]:
        """Post-measurement memory verdict: (reported mem_mb, killed).
        Budget accounting already judged pre-window, so this is a
        no-op here; ProcFleet samples measured RSS instead."""
        return mem, False

    def _discount(self, tput: float, used: int, eff: int) -> float:
        """Sleeps don't contend like real CPUs: charge the sim's
        proportional over-subscription slowdown in accounting."""
        return RigSlot.discount(tput, used, eff)

    # ------------------------------------------------------------ tick ----
    def apply(self, falloc: FleetAllocation) -> dict:
        self._advance_events()
        state = self.machine
        self._check_falloc(falloc, state)
        per: Dict[str, dict] = {}
        measuring: List[tuple] = []
        for name in state.active:
            trainer = self.cluster.trainer(name)
            eff = self._base[name] + int(falloc.grants.get(name, 0))
            if name not in falloc.allocs:
                raise KeyError(
                    f"no allocation proposed for active trainer {name!r}")
            alloc = falloc.allocs[name]
            mem = graph_memory_mb(trainer.pipeline, alloc.workers,
                                  alloc.prefetch_mb)
            used = int(np.sum(alloc.workers))
            slot = self.slots[name]
            if slot.tick_dead_window(eff):
                per[name] = {"throughput": 0.0, "mem_mb": mem, "oom": False,
                             "restarting": True, "used_cpus": used,
                             "eff_cpus": eff}
                continue
            if self._pre_window_oom(trainer, slot, mem):
                slot.kill()
                per[name] = {"throughput": 0.0, "mem_mb": mem, "oom": True,
                             "restarting": True, "used_cpus": used,
                             "eff_cpus": eff}
                continue
            slot.prepare(eff, alloc)
            measuring.append((name, trainer, slot, mem, used, eff))
        # one shared measurement window: every allocation above is applied
        # BEFORE any trainer is measured, so pool re-caps and grant moves
        # land atomically across the fleet
        before = {name: slot.rig.counters()
                  for name, _, slot, *_ in measuring}
        if measuring:
            time.sleep(self.window_s)
        for name, trainer, slot, mem, used, eff in measuring:
            after = slot.rig.counters()
            tput = ThreadedPipeline.window_rate(before[name], after)
            mem, killed = self._post_window_judge(trainer, slot, mem)
            if killed:
                per[name] = {"throughput": 0.0, "mem_mb": mem, "oom": True,
                             "restarting": True, "used_cpus": used,
                             "eff_cpus": eff}
                continue
            tput = self._discount(tput, used, eff)
            per[name] = {"throughput": tput, "mem_mb": mem, "oom": False,
                         "restarting": False, "used_cpus": used,
                         "eff_cpus": eff}
        self.time += 1
        tput = sum(m["throughput"] for m in per.values())
        mem = sum(m["mem_mb"] for m in per.values())
        used = sum(min(m["used_cpus"], m["eff_cpus"]) for m in per.values())
        return {"throughput": tput, "mem_mb": mem, "used_cpus": int(used),
                "oom": any(m["oom"] for m in per.values()),
                "restarting": any(m["restarting"] for m in per.values()),
                "n_active": len(state.active), "pool": self.pool,
                "per_trainer": per}

    # -------------------------------------------------------- teardown ----
    def close(self) -> dict:
        """Tear down every live rig; returns the final accounting the
        churn soak test asserts on: clean-teardown batch drops, OOM-crash
        losses, and whether every thread ever started was joined."""
        if not self._closed:
            self._closed = True
            for slot in self.slots.values():
                self.dropped_batches += slot.close(drain=True)
            self._acct = {"dropped_batches": self.dropped_batches,
                          "crash_lost": self.crash_lost,
                          "all_joined": self.all_joined,
                          "oom_count": self.oom_count}
        return self._acct

    def __enter__(self) -> "LiveFleet":
        return self

    def __exit__(self, *exc):
        self.close()


class ProcFleet(LiveFleet):
    """The fleet plane on REAL OS processes: one ProcessPipeline per
    active trainer via the `_TrainerRig(make_pipe=...)` hook, so every
    trainer in the fleet runs real CPU-contended burns.

    Everything LiveFleet charges in accounting is physics here, exactly
    as ProcessBackend vs ExecutorBackend on the single-machine plane:

      - NO over-subscription discount: workers across ALL trainers
        contend for the same host cores, so over-placing slows the
        measured rate because silicon actually runs out;
      - MEMORY is measured, not budgeted: after the shared window each
        trainer's OOM verdict comes from its pipeline's sampled resident
        bytes (`rss_mb()`, growth since spawn) against its machine's
        `mem_mb` — then the same kill + OOM_RESTART_TICKS + relaunch
        lifecycle as every other plane (the shared RigSlot);
      - a stream trainer's arrival curve SURVIVES the OOM: RigSlot
        carries `stream_epoch()` across the kill, so the relaunch
        resumes (backlog accrued while dead), matching the sim.

    `ballast=False` skips the per-worker memory ballast (cheap CI rigs);
    leave it True when the RSS OOM judge is under test.
    """

    def __init__(self, cluster: ClusterSpec, seed: int = 0,
                 window_s: float = 0.1, queue_depth: int = 8,
                 ballast: bool = True, rss_interval: float = 0.2):
        # set before super().__init__ — it launches the start_active rigs
        self.ballast = ballast
        self.rss_interval = rss_interval
        super().__init__(cluster, seed=seed, window_s=window_s,
                         queue_depth=queue_depth)

    def _make_launch(self, trainer: TrainerSpec):
        from repro.data.proc_executor import ProcessPipeline, stage_fns_for

        def make_pipe(tr, eff, queue_depth):
            return ProcessPipeline(
                tr.pipeline,
                fns=stage_fns_for(tr.pipeline, ballast=self.ballast),
                queue_depth=queue_depth,
                machine=dataclasses.replace(tr.machine, n_cpus=int(eff)),
                rss_interval=self.rss_interval)

        return lambda eff: _TrainerRig(trainer, eff, self.queue_depth,
                                       make_pipe=make_pipe)

    # ------------------------------------------- physics over accounting --
    def _pre_window_oom(self, trainer: TrainerSpec, slot: RigSlot,
                        mem: float) -> bool:
        return False               # the RSS judge rules after the window

    def _post_window_judge(self, trainer: TrainerSpec, slot: RigSlot,
                           mem: float) -> Tuple[float, bool]:
        rss = slot.rig.pipe.rss_mb()
        if rss > trainer.machine.mem_mb:
            slot.kill()
            return rss, True
        return rss, False

    def _discount(self, tput: float, used: int, eff: int) -> float:
        return tput                # contention is physical, already in tput


# ---------------------------------------------------------------------------
# Live clusters: ms-scale stage costs (a window catches tens of batches),
# serial_frac=0 everywhere (sleep workers realize the analytic service
# rate exactly), and 5-stage pipelines throughout so the cached r5
# pretrained agent drives every trainer.
# ---------------------------------------------------------------------------

def live_linear_pipeline(udf_cost: float = 0.03, base_cost: float = 0.004,
                         batch_mb: float = 8.0) -> StageGraph:
    """Skewed 5-stage chain: the UDF dominates, so even placement starves
    it — the live analog of the paper's Fig. 3 cost shares."""
    stages = (
        StageSpec("src", "source", cost=base_cost, serial_frac=0.0,
                  mem_per_worker_mb=64),
        StageSpec("shuffle", "shuffle", cost=base_cost, serial_frac=0.0,
                  mem_per_worker_mb=64),
        StageSpec("feature_udf", "udf", cost=udf_cost, serial_frac=0.0,
                  mem_per_worker_mb=64),
        StageSpec("batch", "batch", cost=base_cost, serial_frac=0.0,
                  mem_per_worker_mb=64),
        StageSpec("prefetch", "prefetch", cost=base_cost, serial_frac=0.0,
                  mem_per_worker_mb=64, mem_per_item_mb=batch_mb),
    )
    return StageGraph("live_lin5", stages, batch_mb=batch_mb)


def live_join_pipeline(batch_mb: float = 8.0) -> StageGraph:
    """5-stage multi-source join DAG at live (ms) scale: sparse reads and
    the feature UDF carry the weight, per Zhao et al."""
    stages = (
        StageSpec("dense_src", "source", cost=0.004, serial_frac=0.0,
                  mem_per_worker_mb=64),
        StageSpec("sparse_src", "source", cost=0.012, serial_frac=0.0,
                  mem_per_worker_mb=64),
        StageSpec("join", "join", cost=0.002, serial_frac=0.0,
                  mem_per_worker_mb=48,
                  inputs=("dense_src", "sparse_src")),
        StageSpec("feature_udf", "udf", cost=0.012, serial_frac=0.0,
                  mem_per_worker_mb=64, inputs=("join",)),
        StageSpec("prefetch", "prefetch", cost=0.004, serial_frac=0.0,
                  mem_per_worker_mb=64, mem_per_item_mb=batch_mb,
                  inputs=("feature_udf",)),
    )
    return StageGraph("live_join5", stages, batch_mb=batch_mb,
                      edge_buffer_mb=8.0)


def live_demo_cluster(ticks: int = 160, pool: int = 10) -> ClusterSpec:
    """The canonical 3-trainer live fleet with churn (fig7_fleet --live).

    Heterogeneity mirrors demo_cluster at live scale: "alpha" is the
    UDF-skewed chain AND memory-tight — an even pool grant pushes its
    even worker split past the 3 GB line (the Fig. 5B crash-loop,
    measured on real executors), while the coordinator's admission
    control clamps under it (and its pool grants carry real marginal
    throughput, +2 CPUs on the UDF lifting 200 -> 250 b/s); "beta" is
    the join DAG, joining a quarter
    of the way in; "gamma" saturates its model at 50 b/s with a handful
    of CPUs, so pool granted there is pure waste. Churn covers all four
    event kinds: join, machine resize, pool re-cap, leave.
    """
    trainers = (
        TrainerSpec("alpha", live_linear_pipeline(),
                    MachineSpec(n_cpus=10, mem_mb=3000.0),
                    model_latency=0.002),
        TrainerSpec("beta", live_join_pipeline(),
                    MachineSpec(n_cpus=8, mem_mb=8192.0),
                    model_latency=0.004, start_active=False),
        TrainerSpec("gamma", live_linear_pipeline(udf_cost=0.004),
                    MachineSpec(n_cpus=6, mem_mb=8192.0),
                    model_latency=0.02),
    )
    events = churn_schedule(ticks, [
        (0.25, "join", "beta", 0),
        (0.55, "resize", "alpha", 6),
        (0.65, "pool", "", 6),
        (0.80, "leave", "gamma", 0),
    ])
    return ClusterSpec("live_fleet3", trainers, shared_pool=pool,
                       events=events)
