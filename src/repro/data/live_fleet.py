"""LiveFleet: the fleet plane on real ThreadedPipeline executors.

FleetSim (data/fleet.py) validates fleet policies against N analytic
per-trainer models; this module is the same plane made executable — one
real `ThreadedPipeline` per active trainer, with worker threads whose
per-item work realizes each StageSpec's true cost, and a consumer thread
per trainer modeling the training loop (pulls batches, sleeps
`model_latency` per batch, so model demand back-pressures the pipeline
exactly where the simulator caps throughput).

LiveFleet speaks the exact FleetSim driver dialect (`machine` / `apply`
/ `resize` / `oom_count`), so `benchmarks.common.run_optimizer` and the
`FleetCoordinator` drive it unchanged. Contract alignment with the sim:

  - THROUGHPUT is measured, not modeled: `apply` sets every active
    trainer's allocation first (atomically, before any measurement),
    then sleeps one shared `window_s` window and reads each pipeline's
    batch-counter delta over the measured elapsed
    (`ThreadedPipeline.counters`, wall-clock-free of the EWMA meters).
  - MEMORY is budget-enforced accounting: the same `graph_memory_mb`
    model the simulator scores OOMs with. An over-budget allocation is
    an OOM — the pipeline process is killed (hard stop, no drain) and
    pays the simulator's `OOM_RESTART_TICKS` dead window before a fresh
    relaunch — so the coordinator's admission control and quarantine
    semantics transfer verbatim.
  - CHURN honors the soft/hard stop split: a `leave` (and `close`) tears
    a pipeline down gracefully — soft-stop, drain the output queue, then
    hard-stop and join every thread — and accounts any sink-delivered
    batch that was lost in `dropped_batches` (0 on clean teardown). A
    `join` spins up a fresh pipeline; `resize`/`pool` re-caps apply
    before the next measurement window.

Known sim-vs-live gaps (DESIGN.md §7): stage work is `time.sleep`, so a
serial fraction is emulated by a per-stage lock (exact only for
`serial_frac == 0`, which the live clusters below use), and CPU
over-subscription does not physically contend — the simulator's
proportional slowdown is charged in accounting instead.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.data.executor import ThreadedPipeline
from repro.data.fleet import (ClusterSpec, FleetAllocation, FleetBackend,
                              TrainerSpec, churn_schedule)
from repro.data.pipeline import StageGraph, StageSpec
from repro.data.simulator import (Allocation, MachineSpec, OOM_RESTART_TICKS,
                                  graph_memory_mb)


def synthetic_stage_fns(spec: StageGraph) -> Dict[str, Callable]:
    """Work functions realizing each StageSpec's true cost with sleeps.

    A stage's fn takes `cost` seconds per item, so with `w` workers it
    sustains `w / cost` batches/s — exactly the simulator's service rate
    when `serial_frac == 0`. A non-zero serial fraction is emulated by
    taking `serial_frac * cost` under a per-stage lock (an approximation
    of Amdahl scaling: both saturate at `1 / (serial_frac * cost)`, but
    the knee differs — live differential clusters use 0).

    Sources emit an infinite stream (training never hits EOS); joins
    pair one item per input; everything else forwards its input.
    """
    fns: Dict[str, Callable] = {}
    for st in spec.stages:
        serial = st.serial_frac * st.cost
        par = st.cost - serial
        lock = threading.Lock() if serial > 1e-9 else None

        def work(lock=lock, serial=serial, par=par):
            if lock is not None:
                with lock:
                    time.sleep(serial)
            if par > 0:
                time.sleep(par)

        if not st.inputs:
            def fn(work=work):
                work()
                return 1                       # infinite stream, never EOS
        elif len(st.inputs) > 1:
            def fn(*items, work=work):
                work()
                return items
        else:
            def fn(item, work=work):
                work()
                return item
        fns[st.name] = fn
    return fns


class _TrainerRig:
    """One live trainer: a ThreadedPipeline plus a consumer thread that
    models the training loop — it pulls batches and sleeps
    `model_latency` per batch, so a saturated model back-pressures the
    pipeline through the (prefetch-bounded) output queue, the live
    realization of the simulator's `1 / model_latency` throughput cap."""

    def __init__(self, trainer: TrainerSpec, eff_cpus: int,
                 queue_depth: int = 8):
        self.trainer = trainer
        self.pipe = ThreadedPipeline(
            trainer.pipeline, fns=synthetic_stage_fns(trainer.pipeline),
            queue_depth=queue_depth,
            machine=dataclasses.replace(trainer.machine,
                                        n_cpus=int(eff_cpus)))
        self._stop = threading.Event()
        self._consumer = threading.Thread(target=self._model_loop,
                                          daemon=True)
        self._consumer.start()

    def _model_loop(self):
        while not self._stop.is_set():
            try:
                self.pipe.get_batch(timeout=0.05)
            except (queue.Empty, StopIteration):
                continue
            if self.trainer.model_latency > 0:
                time.sleep(self.trainer.model_latency)

    # ---------------------------------------------------------- control ---
    def set_allocation(self, alloc: Allocation):
        self.pipe.set_allocation(alloc.workers, alloc.prefetch_mb)

    def set_eff_cpus(self, n: int):
        self.pipe.machine = dataclasses.replace(self.pipe.machine,
                                                n_cpus=int(n))

    def counters(self) -> dict:
        return self.pipe.counters()

    def teardown(self, drain: bool = True, timeout: float = 5.0) -> dict:
        """Stop the consumer first (so the drain accounting is stable),
        then shut the pipeline down. drain=True is the clean leave path;
        drain=False models an OOM kill."""
        self._stop.set()
        self._consumer.join(timeout=timeout)
        acct = self.pipe.shutdown(drain=drain, timeout=timeout)
        acct["joined"] = acct["joined"] and not self._consumer.is_alive()
        return acct


class LiveFleet(FleetBackend):
    """Drop-in fleet backend: one real ThreadedPipeline per active
    trainer, FleetSim's exact driver dialect.

    `seed` is accepted for factory-signature compatibility with FleetSim
    (thread scheduling is the noise source here, not an RNG).
    `window_s` is the per-tick measurement window; throughput is the
    consumed-batch counter delta over the measured elapsed. Call
    `close()` (or use as a context manager) to tear every rig down and
    collect the final drop/leak accounting.
    """

    def __init__(self, cluster: ClusterSpec, seed: int = 0,
                 window_s: float = 0.1, queue_depth: int = 8):
        super().__init__(cluster)
        self.window_s = float(window_s)
        self.queue_depth = queue_depth
        self.oom_counts = {t.name: 0 for t in cluster.trainers}
        self.restart_left = {t.name: 0 for t in cluster.trainers}
        self.dropped_batches = 0
        self.crash_lost = 0
        self.all_joined = True
        self.rigs: Dict[str, _TrainerRig] = {}
        self._closed = False
        for t in cluster.trainers:
            if t.start_active:
                self.rigs[t.name] = _TrainerRig(t, t.machine.n_cpus,
                                                queue_depth)

    # ----------------------------------------------------------- churn ----
    def _on_join(self, name: str):
        # a (re)joining machine is a fresh process: no restart debt
        self.restart_left[name] = 0
        if name not in self.rigs:
            self.rigs[name] = _TrainerRig(self.cluster.trainer(name),
                                          self._base[name], self.queue_depth)

    def _on_leave(self, name: str):
        rig = self.rigs.pop(name, None)
        if rig is not None:
            acct = rig.teardown(drain=True)
            self.dropped_batches += acct["dropped"]
            self.all_joined = self.all_joined and acct["joined"]

    @property
    def oom_count(self) -> int:
        return sum(self.oom_counts.values())

    # ------------------------------------------------------------ tick ----
    def apply(self, falloc: FleetAllocation) -> dict:
        self._advance_events()
        state = self.machine
        self._check_falloc(falloc, state)
        per: Dict[str, dict] = {}
        measuring: List[tuple] = []
        for name in state.active:
            trainer = self.cluster.trainer(name)
            eff = self._base[name] + int(falloc.grants.get(name, 0))
            if name not in falloc.allocs:
                raise KeyError(
                    f"no allocation proposed for active trainer {name!r}")
            alloc = falloc.allocs[name]
            mem = graph_memory_mb(trainer.pipeline, alloc.workers,
                                  alloc.prefetch_mb)
            used = int(np.sum(alloc.workers))
            if self.restart_left[name] > 0:
                self.restart_left[name] -= 1
                if self.restart_left[name] == 0 and name not in self.rigs:
                    # dead window over: relaunch a fresh pipeline process
                    self.rigs[name] = _TrainerRig(trainer, eff,
                                                  self.queue_depth)
                per[name] = {"throughput": 0.0, "mem_mb": mem, "oom": False,
                             "restarting": True, "used_cpus": used,
                             "eff_cpus": eff}
                continue
            if mem > trainer.machine.mem_mb:
                # budget-enforced OOM (the simulator's judge, verbatim):
                # the process is killed — hard stop, no drain — and pays
                # the same restart window before relaunch
                self.oom_counts[name] += 1
                self.restart_left[name] = OOM_RESTART_TICKS
                rig = self.rigs.pop(name, None)
                if rig is not None:
                    acct = rig.teardown(drain=False)
                    self.crash_lost += max(
                        0, acct["delivered"] - acct["consumed"])
                    self.all_joined = self.all_joined and acct["joined"]
                per[name] = {"throughput": 0.0, "mem_mb": mem, "oom": True,
                             "restarting": True, "used_cpus": used,
                             "eff_cpus": eff}
                continue
            rig = self.rigs[name]
            if rig.pipe.machine.n_cpus != eff:
                rig.set_eff_cpus(eff)
            rig.set_allocation(alloc)
            measuring.append((name, rig, mem, used, eff))
        # one shared measurement window: every allocation above is applied
        # BEFORE any trainer is measured, so pool re-caps and grant moves
        # land atomically across the fleet
        before = {name: rig.counters() for name, rig, *_ in measuring}
        if measuring:
            time.sleep(self.window_s)
        for name, rig, mem, used, eff in measuring:
            tput = ThreadedPipeline.window_rate(before[name], rig.counters())
            if used > eff:
                # sleeps don't contend like real CPUs: charge the sim's
                # proportional over-subscription slowdown in accounting
                tput *= eff / used
            per[name] = {"throughput": tput, "mem_mb": mem, "oom": False,
                         "restarting": False, "used_cpus": used,
                         "eff_cpus": eff}
        self.time += 1
        tput = sum(m["throughput"] for m in per.values())
        mem = sum(m["mem_mb"] for m in per.values())
        used = sum(min(m["used_cpus"], m["eff_cpus"]) for m in per.values())
        return {"throughput": tput, "mem_mb": mem, "used_cpus": int(used),
                "oom": any(m["oom"] for m in per.values()),
                "restarting": any(m["restarting"] for m in per.values()),
                "n_active": len(state.active), "pool": self.pool,
                "per_trainer": per}

    # -------------------------------------------------------- teardown ----
    def close(self) -> dict:
        """Tear down every live rig; returns the final accounting the
        churn soak test asserts on: clean-teardown batch drops, OOM-crash
        losses, and whether every thread ever started was joined."""
        if not self._closed:
            self._closed = True
            for name in list(self.rigs):
                acct = self.rigs.pop(name).teardown(drain=True)
                self.dropped_batches += acct["dropped"]
                self.all_joined = self.all_joined and acct["joined"]
            self._acct = {"dropped_batches": self.dropped_batches,
                          "crash_lost": self.crash_lost,
                          "all_joined": self.all_joined,
                          "oom_count": self.oom_count}
        return self._acct

    def __enter__(self) -> "LiveFleet":
        return self

    def __exit__(self, *exc):
        self.close()


# ---------------------------------------------------------------------------
# Live clusters: ms-scale stage costs (a window catches tens of batches),
# serial_frac=0 everywhere (sleep workers realize the analytic service
# rate exactly), and 5-stage pipelines throughout so the cached r5
# pretrained agent drives every trainer.
# ---------------------------------------------------------------------------

def live_linear_pipeline(udf_cost: float = 0.03, base_cost: float = 0.004,
                         batch_mb: float = 8.0) -> StageGraph:
    """Skewed 5-stage chain: the UDF dominates, so even placement starves
    it — the live analog of the paper's Fig. 3 cost shares."""
    stages = (
        StageSpec("src", "source", cost=base_cost, serial_frac=0.0,
                  mem_per_worker_mb=64),
        StageSpec("shuffle", "shuffle", cost=base_cost, serial_frac=0.0,
                  mem_per_worker_mb=64),
        StageSpec("feature_udf", "udf", cost=udf_cost, serial_frac=0.0,
                  mem_per_worker_mb=64),
        StageSpec("batch", "batch", cost=base_cost, serial_frac=0.0,
                  mem_per_worker_mb=64),
        StageSpec("prefetch", "prefetch", cost=base_cost, serial_frac=0.0,
                  mem_per_worker_mb=64, mem_per_item_mb=batch_mb),
    )
    return StageGraph("live_lin5", stages, batch_mb=batch_mb)


def live_join_pipeline(batch_mb: float = 8.0) -> StageGraph:
    """5-stage multi-source join DAG at live (ms) scale: sparse reads and
    the feature UDF carry the weight, per Zhao et al."""
    stages = (
        StageSpec("dense_src", "source", cost=0.004, serial_frac=0.0,
                  mem_per_worker_mb=64),
        StageSpec("sparse_src", "source", cost=0.012, serial_frac=0.0,
                  mem_per_worker_mb=64),
        StageSpec("join", "join", cost=0.002, serial_frac=0.0,
                  mem_per_worker_mb=48,
                  inputs=("dense_src", "sparse_src")),
        StageSpec("feature_udf", "udf", cost=0.012, serial_frac=0.0,
                  mem_per_worker_mb=64, inputs=("join",)),
        StageSpec("prefetch", "prefetch", cost=0.004, serial_frac=0.0,
                  mem_per_worker_mb=64, mem_per_item_mb=batch_mb,
                  inputs=("feature_udf",)),
    )
    return StageGraph("live_join5", stages, batch_mb=batch_mb,
                      edge_buffer_mb=8.0)


def live_demo_cluster(ticks: int = 160, pool: int = 10) -> ClusterSpec:
    """The canonical 3-trainer live fleet with churn (fig7_fleet --live).

    Heterogeneity mirrors demo_cluster at live scale: "alpha" is the
    UDF-skewed chain AND memory-tight — an even pool grant pushes its
    even worker split past the 3 GB line (the Fig. 5B crash-loop,
    measured on real executors), while the coordinator's admission
    control clamps under it (and its pool grants carry real marginal
    throughput, +2 CPUs on the UDF lifting 200 -> 250 b/s); "beta" is
    the join DAG, joining a quarter
    of the way in; "gamma" saturates its model at 50 b/s with a handful
    of CPUs, so pool granted there is pure waste. Churn covers all four
    event kinds: join, machine resize, pool re-cap, leave.
    """
    trainers = (
        TrainerSpec("alpha", live_linear_pipeline(),
                    MachineSpec(n_cpus=10, mem_mb=3000.0),
                    model_latency=0.002),
        TrainerSpec("beta", live_join_pipeline(),
                    MachineSpec(n_cpus=8, mem_mb=8192.0),
                    model_latency=0.004, start_active=False),
        TrainerSpec("gamma", live_linear_pipeline(udf_cost=0.004),
                    MachineSpec(n_cpus=6, mem_mb=8192.0),
                    model_latency=0.02),
    )
    events = churn_schedule(ticks, [
        (0.25, "join", "beta", 0),
        (0.55, "resize", "alpha", 6),
        (0.65, "pool", "", 6),
        (0.80, "leave", "gamma", 0),
    ])
    return ClusterSpec("live_fleet3", trainers, shared_pool=pool,
                       events=events)
