"""GNN neighbor sampler (minibatch_lg needs a REAL sampler).

CSR-backed uniform fanout sampling producing the dense-block format
models/gnn.py consumes: x0 (B, d), neigh1 (B, F1, d), neigh2 (B, F1, F2, d).
Sampling-with-replacement per GraphSAGE; isolated nodes self-loop.
This runs as the pipeline's "UDF" stage for the GNN family — the most
irregular, adaptive-allocation-friendly stage in the assignment
(DESIGN.md §4).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


class CSRGraph:
    def __init__(self, n_nodes: int, edge_src: np.ndarray,
                 edge_dst: np.ndarray):
        self.n_nodes = n_nodes
        order = np.argsort(edge_dst, kind="stable")
        self.nbr = edge_src[order].astype(np.int64)
        counts = np.bincount(edge_dst, minlength=n_nodes)
        self.offsets = np.zeros(n_nodes + 1, dtype=np.int64)
        np.cumsum(counts, out=self.offsets[1:])

    @classmethod
    def random(cls, n_nodes: int, n_edges: int, seed: int = 0) -> "CSRGraph":
        rng = np.random.RandomState(seed)
        src = rng.randint(0, n_nodes, size=n_edges)
        dst = rng.randint(0, n_nodes, size=n_edges)
        return cls(n_nodes, src, dst)

    def sample_neighbors(self, nodes: np.ndarray, fanout: int,
                         rng: np.random.RandomState) -> np.ndarray:
        """(N,) -> (N, fanout) uniform with replacement; self-loop if
        isolated."""
        start = self.offsets[nodes]
        deg = self.offsets[nodes + 1] - start
        pick = rng.randint(0, np.maximum(deg, 1)[:, None],
                           size=(len(nodes), fanout))
        idx = start[:, None] + pick
        out = self.nbr[np.minimum(idx, len(self.nbr) - 1)]
        return np.where(deg[:, None] > 0, out, nodes[:, None])


class NeighborSampler:
    """Two-hop dense-fanout sampler -> model-ready blocks."""

    def __init__(self, graph: CSRGraph, features: np.ndarray,
                 labels: np.ndarray, fanout: Tuple[int, int] = (15, 10),
                 seed: int = 0):
        self.g = graph
        self.x = features
        self.y = labels
        self.fanout = fanout
        self.rng = np.random.RandomState(seed)

    def sample(self, batch_nodes: int) -> dict:
        f1, f2 = self.fanout
        seeds = self.rng.randint(0, self.g.n_nodes, size=batch_nodes)
        n1 = self.g.sample_neighbors(seeds, f1, self.rng)       # (B, F1)
        n2 = self.g.sample_neighbors(n1.reshape(-1), f2, self.rng)
        n2 = n2.reshape(batch_nodes, f1, f2)                    # (B, F1, F2)
        return {
            "x0": self.x[seeds].astype(np.float32),
            "neigh1": self.x[n1].astype(np.float32),
            "neigh2": self.x[n2].astype(np.float32),
            "labels": self.y[seeds].astype(np.int32),
        }
