"""Data-pipeline stage graph: the thing InTune allocates CPUs across.

A StageGraph is a DAG of stages. The paper's pipelines are linear chains
(disk load -> shuffle -> UDF -> batch -> prefetch), but production DLRM
ingestion is multi-source: dense, sparse, and label streams read from
separate storage, joined, transformed, batched (Zhao et al.'s DSI
breakdown; BagPipe's split embedding/dense fetch). Each StageSpec names
its `inputs` (parent stages); a stage with no inputs is a source, a stage
with several is a join. A tuple of input-less stages is auto-wired into
the classic linear chain, so every pre-DAG construction site keeps
working unchanged (`PipelineSpec` remains as an alias).

Each stage carries a *true* per-batch CPU cost, a parallel-efficiency
profile (Amdahl serial fraction), and a memory footprint model. The
executor (data/executor.py) runs the graph with real threads and one
bounded queue per edge; the simulator (data/simulator.py) runs the same
spec analytically for RL training and benchmarks (DESIGN.md §3).

Stage costs default to the latency shares of the paper's Figure 3
(UDFs and disk loads dominate; shuffle/batch stay modest).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.data.stream import ArrivalProcess


@dataclass(frozen=True)
class StageSpec:
    name: str
    kind: str                  # "source" | "stream" | "shuffle" | "udf" |
                               # "join" | "batch" | "prefetch"
    cost: float                # true CPU-seconds per batch at 1 worker
    serial_frac: float = 0.05  # Amdahl: speedup(a) = 1 / (s + (1-s)/a)
    # what a one-shot profiler *thinks* the cost is (AUTOTUNE's model).
    # UDFs are black boxes: static profilers systematically underestimate
    # them (Plumber paper / InTune §3.2). est_cost = cost * est_bias, so
    # bias < 1 starves the stage; 1.0 = perfectly estimated.
    est_bias: float = 1.0
    mem_per_worker_mb: float = 64.0
    # prefetch: memory per buffered batch; tuned in MB by the agent
    mem_per_item_mb: float = 0.0
    # DAG edges: names of the stages this one consumes. () = source stage.
    inputs: Tuple[str, ...] = ()
    # "stream" sources only: the time-varying arrival model backing the
    # stage. Its service rate becomes min(arrival_rate(t), amdahl_rate) —
    # the stage cannot process events that have not happened yet — and
    # un-ingested arrivals accumulate as backlog (data/stream.py).
    arrival: Optional[ArrivalProcess] = None

    def est_cost(self) -> float:
        return self.cost * self.est_bias


@dataclass(frozen=True)
class StageGraph:
    """DAG of StageSpecs with validated topology.

    Invariants (checked at construction):
      - stage names are unique and every `inputs` entry names a stage,
      - the graph is acyclic,
      - exactly one stage has no consumers (the sink feeding the trainer),
        which with acyclicity means every stage's output reaches the sink.
    """
    name: str
    stages: Tuple[StageSpec, ...]
    batch_mb: float = 256.0          # bytes of one training batch
    target_rate: float = 10.0        # batches/s the model consumes at 0 idle
    # inter-stage buffer accounting: MB charged per graph edge by the
    # simulator's memory model. 0 keeps pre-DAG (linear) numbers identical.
    edge_buffer_mb: float = 0.0
    # what the process plane runs per item: "spin" = calibrated CPU burns
    # (proc_executor.SpinWork), "real" = actual featurization work over
    # synthetic Criteo records (data/featurize.py) realizing the same
    # cost/serial_frac contract. The analytic planes ignore this — both
    # modes follow the identical Amdahl service curve by construction.
    work: str = "spin"

    def __post_init__(self):
        if self.work not in ("spin", "real"):
            raise ValueError(f"work must be 'spin' or 'real', "
                             f"got {self.work!r}")
        stages = tuple(self.stages)
        if not stages:
            raise ValueError("StageGraph needs at least one stage")
        # Back-compat: a tuple of input-less stages is the classic linear
        # chain; wire stage i to consume stage i-1.
        if len(stages) > 1 and all(not s.inputs for s in stages):
            stages = (stages[0],) + tuple(
                dataclasses.replace(s, inputs=(stages[i].name,))
                for i, s in enumerate(stages[1:]))
            object.__setattr__(self, "stages", stages)
        index: Dict[str, int] = {}
        for i, s in enumerate(stages):
            if s.name in index:
                raise ValueError(f"duplicate stage name {s.name!r}")
            index[s.name] = i
        parents: List[Tuple[int, ...]] = []
        for s in stages:
            for p in s.inputs:
                if p not in index:
                    raise ValueError(
                        f"stage {s.name!r} consumes unknown stage {p!r}")
                if p == s.name:
                    raise ValueError(f"stage {s.name!r} consumes itself")
            parents.append(tuple(index[p] for p in s.inputs))
        children: List[List[int]] = [[] for _ in stages]
        for i, ps in enumerate(parents):
            for p in ps:
                children[p].append(i)
        sinks = [i for i, cs in enumerate(children) if not cs]
        if len(sinks) != 1:
            names = [stages[i].name for i in sinks]
            raise ValueError(
                f"StageGraph {self.name!r} must have exactly one sink "
                f"(stage nothing consumes); got {names}")
        # Kahn's algorithm; leftover nodes = a cycle.
        indeg = [len(ps) for ps in parents]
        ready = [i for i, d in enumerate(indeg) if d == 0]
        topo: List[int] = []
        while ready:
            i = ready.pop(0)
            topo.append(i)
            for c in children[i]:
                indeg[c] -= 1
                if indeg[c] == 0:
                    ready.append(c)
        if len(topo) != len(stages):
            cyc = [stages[i].name for i in range(len(stages))
                   if i not in topo]
            raise ValueError(f"StageGraph {self.name!r} has a cycle "
                             f"through {cyc}")
        # streaming-source invariants: a "stream" stage is a source with
        # an attached ArrivalProcess; at most one per graph (backlog /
        # staleness accounting is per-graph state in the simulator)
        streams = []
        for i, s in enumerate(stages):
            if s.kind == "stream":
                if s.arrival is None:
                    raise ValueError(
                        f"stream stage {s.name!r} needs an ArrivalProcess "
                        f"(StageSpec.arrival)")
                if s.inputs:
                    raise ValueError(
                        f"stream stage {s.name!r} must be a source "
                        f"(inputs=()), got inputs={s.inputs}")
                streams.append(i)
            elif s.arrival is not None:
                raise ValueError(
                    f"stage {s.name!r} carries an ArrivalProcess but its "
                    f"kind is {s.kind!r}, not 'stream'")
        if len(streams) > 1:
            names = [stages[i].name for i in streams]
            raise ValueError(f"StageGraph {self.name!r} has multiple "
                             f"stream sources {names}; at most one is "
                             f"supported")
        object.__setattr__(self, "_stream_idx",
                           streams[0] if streams else None)
        object.__setattr__(self, "_index", index)
        object.__setattr__(self, "_parents", tuple(parents))
        object.__setattr__(self, "_children",
                           tuple(tuple(cs) for cs in children))
        object.__setattr__(self, "_topo", tuple(topo))
        object.__setattr__(self, "_sink", sinks[0])

    # ---------------------------------------------------------- topology --
    @property
    def n_stages(self) -> int:
        return len(self.stages)

    @property
    def topo_order(self) -> Tuple[int, ...]:
        """Stage indices in dependency order (parents before children)."""
        return self._topo

    @property
    def sink(self) -> int:
        """Index of the unique output stage (feeds the training loop)."""
        return self._sink

    @property
    def sources(self) -> Tuple[int, ...]:
        return tuple(i for i, s in enumerate(self.stages) if not s.inputs)

    @property
    def stream_idx(self) -> Optional[int]:
        """Index of the streaming source stage, or None for the classic
        infinite-backlog graphs."""
        return self._stream_idx

    @property
    def edges(self) -> Tuple[Tuple[int, int], ...]:
        """(producer_idx, consumer_idx) for every graph edge."""
        return tuple((p, i) for i, ps in enumerate(self._parents)
                     for p in ps)

    @property
    def is_linear(self) -> bool:
        return all(ps == ((i - 1,) if i else ())
                   for i, ps in enumerate(self._parents))

    def index(self, name: str) -> int:
        return self._index[name]

    def parents(self, i: int) -> Tuple[int, ...]:
        return self._parents[i]

    def children(self, i: int) -> Tuple[int, ...]:
        return self._children[i]

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


# The pre-DAG name; a linear PipelineSpec is just a StageGraph whose
# auto-wired chain topology is the identity permutation.
PipelineSpec = StageGraph


def stage_throughput(stage: StageSpec, workers: int) -> float:
    """Batches/s this stage sustains with `workers` CPUs (Amdahl scaling)."""
    if workers <= 0:
        return 0.0
    speedup = 1.0 / (stage.serial_frac + (1.0 - stage.serial_frac) / workers)
    return speedup / stage.cost


def criteo_pipeline(batch_mb: float = 256.0,
                    target_rate: float = 31.0,
                    work: str = "spin") -> StageGraph:
    """The paper's 5-stage DLRM ingestion pipeline, cost shares per Fig. 3.

    disk load and the feature-extraction UDF dominate; the UDF is the stage
    static optimizers mis-model (est_bias < 1 = underestimated). Calibrated
    so that at 128 CPUs: 1-CPU-per-stage ~ 8% of target, oracle ~ 45%
    (the paper's Fig. 5A regime: the target rate is unreachable on one
    machine) — see benchmarks/fig5_static.py for measured values.

    `work="real"` makes the process plane run actual featurization
    (hash/pool/pad/collate over synthetic Criteo records) instead of
    calibrated spin burns; analytic planes are unaffected.
    """
    stages = (
        StageSpec("disk_load", "source", cost=0.30, serial_frac=0.12,
                  est_bias=0.7, mem_per_worker_mb=96),
        StageSpec("shuffle", "shuffle", cost=0.08, serial_frac=0.30,
                  est_bias=1.0, mem_per_worker_mb=48),
        StageSpec("feature_udf", "udf", cost=0.42, serial_frac=0.15,
                  est_bias=0.15, mem_per_worker_mb=64),
        StageSpec("batch", "batch", cost=0.12, serial_frac=0.25,
                  est_bias=1.0, mem_per_worker_mb=32),
        StageSpec("prefetch", "prefetch", cost=0.08, serial_frac=0.05,
                  est_bias=1.0, mem_per_worker_mb=16,
                  mem_per_item_mb=batch_mb),
    )
    return StageGraph("criteo_dlrm", stages, batch_mb=batch_mb,
                      target_rate=target_rate, work=work)


def train_feed_pipeline(step_time_s: float = 0.25, batch_mb: float = 8.0,
                        work: str = "real",
                        cpu_share: float = 0.8) -> StageGraph:
    """The feed-bridge demo spec (benchmarks/fig_train_feed.py and the
    proc path of examples/train_dlrm_criteo.py): the Criteo 5-stage
    chain re-costed against a MEASURED train-step time.

    Total per-batch CPU at 1 worker/stage is `cpu_share * step_time_s`,
    so a single core can keep the trainer fed under a lean allocation —
    while the ELEVATED serial fractions make over-allocation waste real
    CPU through the Amdahl coordination penalty: at heuristic_even's 6
    workers/stage (nominal 30-CPU machine) per-batch CPU inflates ~2.2x
    and the trainer starves. That contrast — measured at the feed
    boundary as `device_idle_frac` — is what the tuned arm closes.
    Ballast is kept small (the nominal machine over-places ~30 workers
    on a laptop-class host).
    """
    total = cpu_share * float(step_time_s)
    plan = (("disk_load", "source", 0.30, 0.20, 24.0),
            ("shuffle", "shuffle", 0.10, 0.40, 12.0),
            ("feature_udf", "udf", 0.35, 0.20, 16.0),
            ("batch", "batch", 0.15, 0.35, 12.0),
            ("prefetch", "prefetch", 0.10, 0.10, 8.0))
    stages = tuple(
        StageSpec(name, kind, cost=share * total, serial_frac=s,
                  mem_per_worker_mb=mb,
                  mem_per_item_mb=batch_mb if kind == "prefetch" else 0.0)
        for name, kind, share, s, mb in plan)
    return StageGraph("train_feed", stages, batch_mb=batch_mb,
                      target_rate=1.0 / max(float(step_time_s), 1e-6),
                      work=work)


def custom_pipeline(batch_mb: float = 196.0,
                    target_rate: float = 27.0) -> StageGraph:
    """The paper's second workload: the internal production recommender
    (dozens of sparse features, <5 continuous, batch in the tens of
    thousands). Heavier disk share, slightly lighter UDF than Criteo."""
    stages = (
        StageSpec("disk_load", "source", cost=0.36, serial_frac=0.10,
                  est_bias=0.7, mem_per_worker_mb=112),
        StageSpec("shuffle", "shuffle", cost=0.10, serial_frac=0.28,
                  est_bias=1.0, mem_per_worker_mb=48),
        StageSpec("feature_udf", "udf", cost=0.34, serial_frac=0.14,
                  est_bias=0.2, mem_per_worker_mb=72),
        StageSpec("batch", "batch", cost=0.14, serial_frac=0.25,
                  est_bias=1.0, mem_per_worker_mb=32),
        StageSpec("prefetch", "prefetch", cost=0.06, serial_frac=0.05,
                  est_bias=1.0, mem_per_worker_mb=16,
                  mem_per_item_mb=batch_mb),
    )
    return StageGraph("custom_prod", stages, batch_mb=batch_mb,
                      target_rate=target_rate)


def multisource_dlrm_pipeline(batch_mb: float = 256.0,
                              target_rate: float = 30.0) -> StageGraph:
    """Production-shaped multi-source DLRM ingestion DAG.

    Zhao et al.'s DSI characterization: dense, sparse, and label streams
    are read from separate storage partitions and joined before the
    feature transforms. Sparse-ID reads dominate the I/O bytes and the
    feature transforms dominate CPU time (the GPU trainer is otherwise
    starved by online preprocessing), so `sparse_source` and
    `feature_udf` carry the heavy costs here; the UDF keeps the
    black-box est_bias that misleads static profilers.

        dense_source ─┐
        sparse_source ─┼─> join ─> feature_udf ─> batch ─> prefetch
        label_source ─┘
    """
    stages = (
        StageSpec("dense_source", "source", cost=0.12, serial_frac=0.10,
                  est_bias=0.8, mem_per_worker_mb=80),
        StageSpec("sparse_source", "source", cost=0.30, serial_frac=0.12,
                  est_bias=0.7, mem_per_worker_mb=112),
        StageSpec("label_source", "source", cost=0.03, serial_frac=0.05,
                  est_bias=1.0, mem_per_worker_mb=24),
        StageSpec("join", "join", cost=0.07, serial_frac=0.30,
                  est_bias=1.0, mem_per_worker_mb=48,
                  inputs=("dense_source", "sparse_source", "label_source")),
        StageSpec("feature_udf", "udf", cost=0.40, serial_frac=0.15,
                  est_bias=0.15, mem_per_worker_mb=64,
                  inputs=("join",)),
        StageSpec("batch", "batch", cost=0.11, serial_frac=0.25,
                  est_bias=1.0, mem_per_worker_mb=32,
                  inputs=("feature_udf",)),
        StageSpec("prefetch", "prefetch", cost=0.06, serial_frac=0.05,
                  est_bias=1.0, mem_per_worker_mb=16,
                  mem_per_item_mb=batch_mb, inputs=("batch",)),
    )
    return StageGraph("multisource_dlrm", stages, batch_mb=batch_mb,
                      target_rate=target_rate, edge_buffer_mb=32.0)


def stream_dlrm_pipeline(arrival: ArrivalProcess, *,
                         batch_mb: float = 64.0,
                         cost_scale: float = 1.0,
                         work: str = "spin") -> StageGraph:
    """Streaming-ingestion chain (benchmarks/fig_stream.py): a broker
    consumer ingesting live events instead of a disk source reading an
    infinite backlog.

        ingest(stream) -> decode -> feature_udf -> batch -> prefetch

    The cost profile is deliberately SKEWED toward the UDF (low serial
    fraction, ~10x the light stages): the even-split heuristic starves
    feature_udf badly, so its sustainable rate sits far below the
    water-filled oracle's — exactly the gap a 10x flash crowd exposes.
    Five stages, so the cached r5 pretrained agent transfers.
    """
    c = float(cost_scale)
    stages = (
        StageSpec("ingest", "stream", cost=0.04 * c, serial_frac=0.05,
                  mem_per_worker_mb=48, arrival=arrival),
        StageSpec("decode", "udf", cost=0.05 * c, serial_frac=0.05,
                  mem_per_worker_mb=32),
        StageSpec("feature_udf", "udf", cost=0.50 * c, serial_frac=0.02,
                  est_bias=0.15, mem_per_worker_mb=64),
        StageSpec("batch", "batch", cost=0.05 * c, serial_frac=0.10,
                  mem_per_worker_mb=32),
        StageSpec("prefetch", "prefetch", cost=0.03 * c, serial_frac=0.05,
                  mem_per_worker_mb=16, mem_per_item_mb=batch_mb),
    )
    return StageGraph("stream_dlrm", stages, batch_mb=batch_mb,
                      target_rate=arrival.batches_per_sec(0.0), work=work)


def make_pipeline(n_stages: int, seed: int = 0, batch_mb: float = 256.0,
                  target_rate: float = 10.0) -> StageGraph:
    """Randomized linear pipeline of a given length (offline RL pretraining
    uses a distribution over these; the paper trains one agent per length).
    The simulator's dynamics depend only on the per-stage rate vector, so
    agents pretrained on these chains transfer to DAGs of equal stage
    count (DESIGN.md §4)."""
    import numpy as np
    rng = np.random.RandomState(seed)
    kinds = ["source"] + ["udf", "shuffle", "batch"][: max(n_stages - 2, 0)] \
        + ["prefetch"]
    while len(kinds) < n_stages:
        kinds.insert(1, "udf")
    kinds = kinds[:n_stages]
    stages = []
    for i, kind in enumerate(kinds):
        cost = float(rng.uniform(0.05, 0.5))
        bias = float(rng.uniform(0.3, 0.7)) if kind in ("udf", "source") \
            else 1.0
        stages.append(StageSpec(
            f"{kind}_{i}", kind, cost=cost,
            serial_frac=float(rng.uniform(0.02, 0.15)), est_bias=bias,
            mem_per_worker_mb=float(rng.uniform(16, 128)),
            mem_per_item_mb=batch_mb if kind == "prefetch" else 0.0))
    return StageGraph(f"rand{n_stages}_{seed}", tuple(stages),
                      batch_mb=batch_mb, target_rate=target_rate)
