"""Data-pipeline stage graph: the thing InTune allocates CPUs across.

A PipelineSpec is a linear chain of stages (the paper's pipelines are
linear: disk load -> shuffle -> UDF -> batch -> prefetch). Each stage
carries a *true* per-batch CPU cost, a parallel-efficiency profile
(Amdahl serial fraction), and a memory footprint model. The executor
(data/executor.py) runs it with real threads; the simulator
(data/simulator.py) runs the same spec analytically for RL training and
benchmarks.

Stage costs default to the latency shares of the paper's Figure 3
(UDFs and disk loads dominate; shuffle/batch stay modest).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Optional, Tuple


@dataclass(frozen=True)
class StageSpec:
    name: str
    kind: str                  # "source" | "shuffle" | "udf" | "batch" | "prefetch"
    cost: float                # true CPU-seconds per batch at 1 worker
    serial_frac: float = 0.05  # Amdahl: speedup(a) = 1 / (s + (1-s)/a)
    # what a one-shot profiler *thinks* the cost is (AUTOTUNE's model).
    # UDFs are black boxes: static profilers systematically underestimate
    # them (Plumber paper / InTune §3.2). est_cost = cost * est_bias, so
    # bias < 1 starves the stage; 1.0 = perfectly estimated.
    est_bias: float = 1.0
    mem_per_worker_mb: float = 64.0
    # prefetch: memory per buffered batch; tuned in MB by the agent
    mem_per_item_mb: float = 0.0

    def est_cost(self) -> float:
        return self.cost * self.est_bias


@dataclass(frozen=True)
class PipelineSpec:
    name: str
    stages: Tuple[StageSpec, ...]
    batch_mb: float = 256.0          # bytes of one training batch
    target_rate: float = 10.0        # batches/s the model consumes at 0 idle

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


def stage_throughput(stage: StageSpec, workers: int) -> float:
    """Batches/s this stage sustains with `workers` CPUs (Amdahl scaling)."""
    if workers <= 0:
        return 0.0
    speedup = 1.0 / (stage.serial_frac + (1.0 - stage.serial_frac) / workers)
    return speedup / stage.cost


def criteo_pipeline(batch_mb: float = 256.0,
                    target_rate: float = 31.0) -> PipelineSpec:
    """The paper's 5-stage DLRM ingestion pipeline, cost shares per Fig. 3.

    disk load and the feature-extraction UDF dominate; the UDF is the stage
    static optimizers mis-model (est_bias < 1 = underestimated). Calibrated
    so that at 128 CPUs: 1-CPU-per-stage ~ 8% of target, oracle ~ 45%
    (the paper's Fig. 5A regime: the target rate is unreachable on one
    machine) — see benchmarks/fig5_static.py for measured values.
    """
    stages = (
        StageSpec("disk_load", "source", cost=0.30, serial_frac=0.12,
                  est_bias=0.7, mem_per_worker_mb=96),
        StageSpec("shuffle", "shuffle", cost=0.08, serial_frac=0.30,
                  est_bias=1.0, mem_per_worker_mb=48),
        StageSpec("feature_udf", "udf", cost=0.42, serial_frac=0.15,
                  est_bias=0.15, mem_per_worker_mb=64),
        StageSpec("batch", "batch", cost=0.12, serial_frac=0.25,
                  est_bias=1.0, mem_per_worker_mb=32),
        StageSpec("prefetch", "prefetch", cost=0.08, serial_frac=0.05,
                  est_bias=1.0, mem_per_worker_mb=16,
                  mem_per_item_mb=batch_mb),
    )
    return PipelineSpec("criteo_dlrm", stages, batch_mb=batch_mb,
                        target_rate=target_rate)


def custom_pipeline(batch_mb: float = 196.0,
                    target_rate: float = 27.0) -> PipelineSpec:
    """The paper's second workload: the internal production recommender
    (dozens of sparse features, <5 continuous, batch in the tens of
    thousands). Heavier disk share, slightly lighter UDF than Criteo."""
    stages = (
        StageSpec("disk_load", "source", cost=0.36, serial_frac=0.10,
                  est_bias=0.7, mem_per_worker_mb=112),
        StageSpec("shuffle", "shuffle", cost=0.10, serial_frac=0.28,
                  est_bias=1.0, mem_per_worker_mb=48),
        StageSpec("feature_udf", "udf", cost=0.34, serial_frac=0.14,
                  est_bias=0.2, mem_per_worker_mb=72),
        StageSpec("batch", "batch", cost=0.14, serial_frac=0.25,
                  est_bias=1.0, mem_per_worker_mb=32),
        StageSpec("prefetch", "prefetch", cost=0.06, serial_frac=0.05,
                  est_bias=1.0, mem_per_worker_mb=16,
                  mem_per_item_mb=batch_mb),
    )
    return PipelineSpec("custom_prod", stages, batch_mb=batch_mb,
                        target_rate=target_rate)


def make_pipeline(n_stages: int, seed: int = 0, batch_mb: float = 256.0,
                  target_rate: float = 10.0) -> PipelineSpec:
    """Randomized pipeline of a given length (offline RL pretraining uses a
    distribution over these; the paper trains one agent per length)."""
    import numpy as np
    rng = np.random.RandomState(seed)
    kinds = ["source"] + ["udf", "shuffle", "batch"][: max(n_stages - 2, 0)] \
        + ["prefetch"]
    while len(kinds) < n_stages:
        kinds.insert(1, "udf")
    kinds = kinds[:n_stages]
    stages = []
    for i, kind in enumerate(kinds):
        cost = float(rng.uniform(0.05, 0.5))
        bias = float(rng.uniform(0.3, 0.7)) if kind in ("udf", "source") \
            else 1.0
        stages.append(StageSpec(
            f"{kind}_{i}", kind, cost=cost,
            serial_frac=float(rng.uniform(0.02, 0.15)), est_bias=bias,
            mem_per_worker_mb=float(rng.uniform(16, 128)),
            mem_per_item_mb=batch_mb if kind == "prefetch" else 0.0))
    return PipelineSpec(f"rand{n_stages}_{seed}", tuple(stages),
                        batch_mb=batch_mb, target_rate=target_rate)
