"""Time-varying arrival model for streaming ingestion (ISSUE 7).

Every benchmark before this PR tuned against an infinite backlog: the
source stage could always pull another batch, so "throughput" was purely
a capacity question. The paper's setting — and the ROADMAP's
millions-of-users north star — is a live event stream: user traffic has
a diurnal cycle, short stochastic bursts, and occasional flash crowds
(a 10x spike when something goes viral). The pipeline's job flips from
"go as fast as possible" to "keep up with the world": in a trough most
of the machine is wasted, in a spike an undersized allocation lets the
backlog (and batch staleness) grow without bound.

`ArrivalProcess` is that world model, shared verbatim by both planes:

  - the analytic plane (`PipelineSim`) integrates it per tick to get
    arrivals, and caps the stream source's service rate at
    `min(arrival_rate, amdahl_rate)` — you cannot process events that
    have not happened yet;
  - the process plane (`proc_executor.StreamSourceWork`) uses the same
    integral as a token bucket: a source worker may only emit batch k
    once `batches_before(now) > k`, so the producer is rate-limited by
    the SAME arrival curve the simulator scores.

The rate is multiplicative: `base * diurnal(t) * bursts(t) * flash(t)`,
where base comes from user-population knobs (`users x events_per_user_s`),
the diurnal term is a sinusoid, and bursts/flash crowds are piecewise-
constant multipliers. That structure keeps `events_between` EXACT (the
sinusoid integrates analytically inside each constant-gain segment) —
no numeric quadrature, so the sim's backlog accounting is reproducible
to the bit and cheap enough for a worker process to poll per item.

Determinism: the stochastic burst schedule is drawn once in
`__post_init__` from `seed` (exponential gaps over `horizon_s`) and
stored as a plain tuple, so equal-seed processes are identical, the
dataclass stays frozen/picklable across `multiprocessing` boundaries,
and tests can hand-compute every integral.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

_TWO_PI = 2.0 * math.pi


@dataclass(frozen=True)
class ArrivalProcess:
    """Deterministic-under-seed arrival-rate model, in events/second.

    Population knobs: `users * events_per_user_s` is the mean event
    rate; `events_per_batch` converts to the batch units the pipeline
    (and every backlog/staleness metric) works in.

    Shape knobs:
      diurnal_amp/period/phase   rate swings +-amp (fraction of base)
                                 sinusoidally over period_s seconds
      burst_every_s              mean gap between stochastic bursts
                                 (exponential, seeded; 0 disables);
                                 each multiplies the rate by burst_gain
                                 for burst_len_s
      flash_crowds               scheduled ((t_start, duration, gain),
                                 ...) multipliers — the benchmark's
                                 10x spike is one of these
    Buffer knobs (consumed by the sim's memory/OOM judge):
      buffer_mb_per_batch        resident MB one backlogged batch holds
                                 in the ingest buffer (0 = unaccounted)
      buffer_cap_batches         drop-oldest retention cap; beyond it
                                 arrivals are shed (counted, not stored)
    """
    users: float = 1.0e6
    events_per_user_s: float = 1.0e-3
    events_per_batch: float = 4096.0
    diurnal_amp: float = 0.0
    diurnal_period_s: float = 86400.0
    diurnal_phase_s: float = 0.0
    burst_every_s: float = 0.0
    burst_gain: float = 2.0
    burst_len_s: float = 60.0
    flash_crowds: Tuple[Tuple[float, float, float], ...] = ()
    buffer_mb_per_batch: float = 0.0
    buffer_cap_batches: Optional[float] = None
    seed: int = 0
    horizon_s: float = 7200.0

    def __post_init__(self):
        if not 0.0 <= self.diurnal_amp < 1.0:
            raise ValueError("diurnal_amp must be in [0, 1) so the rate "
                             "stays positive")
        bursts = []
        if self.burst_every_s > 0:
            rng = np.random.RandomState(self.seed)
            t = 0.0
            while True:
                t += float(rng.exponential(self.burst_every_s))
                if t >= self.horizon_s:
                    break
                bursts.append((t, t + self.burst_len_s, self.burst_gain))
        object.__setattr__(self, "_bursts", tuple(bursts))

    # ------------------------------------------------------------- rate ---
    @property
    def base_events_per_sec(self) -> float:
        return self.users * self.events_per_user_s

    def _windows(self) -> Tuple[Tuple[float, float, float], ...]:
        """All piecewise-constant gain windows: (start, end, gain)."""
        return self._bursts + tuple(
            (t0, t0 + dur, gain) for t0, dur, gain in self.flash_crowds)

    def _gain(self, t: float) -> float:
        g = 1.0
        for a, b, gain in self._windows():
            if a <= t < b:
                g *= gain
        return g

    def _diurnal(self, t: float) -> float:
        if self.diurnal_amp == 0.0:
            return 1.0
        return 1.0 + self.diurnal_amp * math.sin(
            _TWO_PI * (t - self.diurnal_phase_s) / self.diurnal_period_s)

    def events_per_sec(self, t: float) -> float:
        """Instantaneous arrival rate at stream time t (seconds)."""
        return self.base_events_per_sec * self._diurnal(t) * self._gain(t)

    def batches_per_sec(self, t: float) -> float:
        return self.events_per_sec(t) / self.events_per_batch

    # --------------------------------------------------------- integrals --
    def _diurnal_integral(self, a: float, b: float) -> float:
        """∫_a^b diurnal(t) dt, analytic."""
        if self.diurnal_amp == 0.0:
            return b - a
        w = _TWO_PI / self.diurnal_period_s
        ph = self.diurnal_phase_s
        return (b - a) - self.diurnal_amp / w * (
            math.cos(w * (b - ph)) - math.cos(w * (a - ph)))

    def events_between(self, t0: float, t1: float) -> float:
        """∫_t0^t1 events_per_sec(t) dt, exact: split at every gain-window
        boundary, integrate the sinusoid analytically per segment."""
        if t1 <= t0:
            return 0.0
        cuts = {t0, t1}
        for a, b, _ in self._windows():
            for c in (a, b):
                if t0 < c < t1:
                    cuts.add(c)
        pts = sorted(cuts)
        total = 0.0
        for a, b in zip(pts, pts[1:]):
            mid = 0.5 * (a + b)
            total += self._gain(mid) * self._diurnal_integral(a, b)
        return self.base_events_per_sec * total

    def batches_between(self, t0: float, t1: float) -> float:
        return self.events_between(t0, t1) / self.events_per_batch

    def batches_before(self, t: float) -> float:
        """Cumulative batches arrived in [0, t) — the token-bucket level
        the process plane's rate-limited producer claims against."""
        return self.batches_between(0.0, t)


def flash_crowd_arrivals(base_batches_per_sec: float, *,
                         events_per_batch: float = 4096.0,
                         spike_at_s: float, spike_len_s: float,
                         spike_gain: float = 10.0,
                         diurnal_amp: float = 0.0,
                         diurnal_period_s: float = 600.0,
                         buffer_mb_per_batch: float = 0.0,
                         seed: int = 0) -> ArrivalProcess:
    """The benchmark scenario: a steady (optionally mildly diurnal) base
    rate with one scheduled flash crowd. `base_batches_per_sec` is the
    mean rate in batch units; population knobs are derived so
    `users * events_per_user_s == base * events_per_batch`."""
    return ArrivalProcess(
        users=base_batches_per_sec * events_per_batch,
        events_per_user_s=1.0,
        events_per_batch=events_per_batch,
        diurnal_amp=diurnal_amp,
        diurnal_period_s=diurnal_period_s,
        flash_crowds=((float(spike_at_s), float(spike_len_s),
                       float(spike_gain)),),
        buffer_mb_per_batch=buffer_mb_per_batch,
        seed=seed)
