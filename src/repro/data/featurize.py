"""Real featurization work for the process plane: hash, pool, pad, collate.

`SpinWork` made the process executor's CPU demand *real* but synthetic —
a calibrated arithmetic loop. This module replaces the loop with the
actual per-batch featurization a DLRM ingestion pipeline runs (Zhao et
al.'s DSI breakdown: categorical hashing, multi-value pooling, sequence
padding, batch collation over raw click records), so `ProcessPipeline`
workers execute the same numpy code paths a production tf.data/DPP
worker would, and `repro.data.calibrate` fits curves over real code.

Two layers:

  1. PURE RECORD OPS (module functions): `hash_ids` (xxhash-style
     avalanche, deterministic across processes and interpreter seeds —
     golden-tested), `pool_pad` (multi-value pooling to a fixed hot
     size + padding short lists), `dense_transform`, `raw_block` /
     `featurize_block` / `shuffle_block` / `collate` (the per-stage
     transforms over synthetic Criteo-like records with a planted CTR
     signal, so a model trained on the pipeline's output learns).
  2. `FeaturizeWork`: the per-stage work function plugging those ops
     into `ProcessPipeline` via the exact `SpinWork` contract — same
     kind/serial-section/ballast knobs, same Amdahl coordination
     penalty, same clock discipline (`proc_executor._burn`). The stage's
     designed `cost` is realized by repeating the stage's own transform
     as the burn quantum against the kernel CPU clock, so designed cost
     == measured per-item CPU by construction AND the cycles burned are
     real featurization work, not spin. That identity is what keeps the
     calibration fit (`fit_amdahl` over CPU-normalized rates) valid on
     real-work stages: per-item CPU still varies as cost*(a*s + 1-s).

Every class and function here is picklable under both fork and spawn
(no closures, no bound locks at construction).
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from repro.data.pipeline import StageGraph, StageSpec
from repro.data.proc_executor import SpinWork, _TICK_GUARD, _burn

# xxhash32 avalanche primes (finalizer constants)
_P2 = np.uint32(2246822519)
_P3 = np.uint32(3266489917)


# ---------------------------------------------------------------------------
# pure record ops (deterministic, golden-tested)
# ---------------------------------------------------------------------------

def hash_ids(raw_ids, vocab: int) -> np.ndarray:
    """xxhash-style categorical hash: raw ids -> table rows in [0, vocab).

    Pure integer avalanche (shift-xor-multiply finalizer) over the low 32
    bits — deterministic across processes, platforms, and RNG seeds (it
    reads no random state), which is what makes hashed features stable
    between a training run and its restarted resume.
    """
    x = np.asarray(raw_ids).astype(np.uint32)
    x = x ^ (x >> np.uint32(15))
    x = x * _P2
    x = x ^ (x >> np.uint32(13))
    x = x * _P3
    x = x ^ (x >> np.uint32(16))
    return (x % np.uint32(vocab)).astype(np.int32)


def pool_pad(ids, lengths, hot: int) -> np.ndarray:
    """Multi-value pooling + padding: ragged id lists -> fixed (.., hot).

    ids: (..., K) hashed ids; lengths: (...) valid-prefix lengths in
    [1, K]. Lists longer than `hot` are truncated; shorter lists are
    padded by repeating their FIRST id (padding must be a valid table
    row, and repeating the head id keeps the bag-mean distribution
    closer to the unpadded list than a reserved zero row would).
    """
    ids = np.asarray(ids)
    k = ids.shape[-1]
    head = ids[..., :1]
    if k >= hot:
        out = ids[..., :hot]
    else:
        out = np.concatenate(
            [ids, np.broadcast_to(head, ids.shape[:-1] + (hot - k,))],
            axis=-1)
    valid = np.clip(np.asarray(lengths)[..., None], 1, hot)
    mask = np.arange(hot) < valid
    return np.where(mask, out, head).astype(np.int32)


def dense_transform(dense_raw) -> np.ndarray:
    """log1p + per-block standardization of the continuous features."""
    dense = np.log1p(np.asarray(dense_raw, np.float32))
    return ((dense - dense.mean(0)) / (dense.std(0) + 1e-6)).astype(
        np.float32)


@dataclass(frozen=True)
class RecordSpec:
    """Shape config for the synthetic click records flowing through a
    real-work pipeline (must match the consuming model's batch shape:
    `sparse_ids` (batch, n_sparse, hot) int32, `dense` (batch, n_dense)
    f32, `label` (batch,) f32)."""
    batch: int = 512
    n_sparse: int = 12
    n_dense: int = 13
    vocab: int = 1 << 16
    k_raw: int = 8          # raw multi-value list width (pre-pooling)
    hot: int = 4            # pooled bag size the model consumes
    seed: int = 0           # planted-signal weights (labels learnable)


def raw_block(rng: np.random.RandomState, rs: RecordSpec) -> dict:
    """One block of raw (pre-featurization) records with a planted CTR
    signal, so downstream training actually reduces loss."""
    w_rng = np.random.RandomState(rs.seed)
    w_dense = w_rng.randn(rs.n_dense) * 0.5
    w_sparse = w_rng.randn(rs.n_sparse) * 0.3
    n = rs.batch
    raw_ids = rng.randint(0, 1 << 31, size=(n, rs.n_sparse, rs.k_raw),
                          dtype=np.int64)
    lengths = rng.randint(1, rs.k_raw + 1,
                          size=(n, rs.n_sparse)).astype(np.int32)
    dense_raw = rng.lognormal(0.0, 1.0, size=(n, rs.n_dense))
    logit = dense_raw @ w_dense * 0.1 \
        + ((raw_ids[:, :, 0] % 97) / 97.0 - 0.5) @ w_sparse
    label = (rng.rand(n) < 1.0 / (1.0 + np.exp(-logit))).astype(np.float32)
    return {"raw_ids": raw_ids, "lengths": lengths,
            "dense_raw": dense_raw.astype(np.float32), "label": label}


def shuffle_block(block: dict, rng: np.random.RandomState) -> dict:
    n = block["label"].shape[0]
    perm = rng.permutation(n)
    return {k: v[perm] for k, v in block.items()}


def featurize_block(block: dict, rs: RecordSpec) -> dict:
    """The feature UDF: hash raw ids, pool/pad multi-value lists, and
    normalize dense features — raw block in, model-ready block out."""
    hashed = hash_ids(block["raw_ids"], rs.vocab)
    sparse = pool_pad(hashed, block["lengths"], rs.hot)
    return {"sparse_ids": sparse,
            "dense": dense_transform(block["dense_raw"]),
            "label": block["label"]}


def collate(block: dict) -> dict:
    """Batch collation: contiguous, final-dtype arrays for device_put."""
    return {k: np.ascontiguousarray(v) for k, v in block.items()}


# ---------------------------------------------------------------------------
# the per-stage work function (the SpinWork contract over real ops)
# ---------------------------------------------------------------------------

# stage kind -> which transform this stage runs
_ROLES = {"source": "load", "shuffle": "shuffle", "udf": "featurize",
          "batch": "collate", "prefetch": "pass", "join": "join"}


class FeaturizeWork(SpinWork):
    """Picklable per-stage work fn burning real featurization CPU.

    Identical contract to `SpinWork` (see its docstring for the Amdahl
    coordination-penalty math: per-item CPU = cost * (a*s + 1 - s), so
    the measured service rate follows `stage_throughput` exactly), with
    two differences:

      - the item flowing downstream is a REAL record block: sources
        synthesize raw click records, the UDF hashes/pools/pads them,
        the batch stage collates — `get_batch()` hands the trainer a
        model-ready numpy batch;
      - the burn quantum is the stage's own transform over a resident
        scratch block (hashing for the UDF, permutation for shuffle,
        contiguous copies for collate, RNG draws for the source), run
        under the same `time.process_time` clock discipline as the spin
        burns. The real transform of the actual item is charged against
        the parallel portion, and the remaining budget is filled with
        quanta — so the designed cost is realized exactly while ~all
        cycles execute featurization code.
    """

    def __init__(self, role: str, cost: float, serial_frac: float = 0.0,
                 ballast_mb: float = 0.0, kind: str = "map",
                 record: Optional[RecordSpec] = None):
        super().__init__(cost, serial_frac, ballast_mb, kind)
        assert role in ("load", "shuffle", "featurize", "collate",
                        "pass", "join"), role
        self.role = role
        self.record = record if record is not None else RecordSpec()
        self._rng = None
        self._qrate = None       # quanta per CPU-second (sub-tick burns)
        self._q = None           # role-specific scratch for the quantum
        self._self_in = None     # cached input for standalone (calibration)

    # ---------------------------------------------------------- binding ---
    def bind(self, serial_lock, nworkers):
        """Worker-side setup: lock/pool-size attach + ballast (SpinWork),
        a per-process RNG (seeded from the pid so sibling workers draw
        distinct records), the quantum scratch block, and — only when
        this stage has sub-tick burn portions — a measured quantum rate
        (the real-work analog of `spin_rate` recalibration)."""
        self._lock = serial_lock
        self._workers = nworkers
        self._rng = np.random.RandomState(
            (os.getpid() * 1000003 + self.record.seed) % (1 << 31))
        self._setup_quantum()
        serial = self.serial_frac * self.cost
        par = self.cost - serial
        if 0 < serial < _TICK_GUARD or 0 < par < _TICK_GUARD:
            self._qrate = self._measure_qrate()
        self._touch_ballast()

    def _setup_quantum(self):
        rng = self._rng
        if self.role == "shuffle":
            self._q = rng.randn(2048, 16).astype(np.float32)
        elif self.role == "collate":
            self._q = rng.randn(256, 1024).astype(np.float32)
        elif self.role == "load":
            self._q = None                      # quantum draws fresh RNG
        else:                                   # featurize / pass / join
            self._q = rng.randint(0, 1 << 31, size=16384, dtype=np.int64)

    def _quantum(self):
        """One small (~0.1-0.5ms) unit of this stage's real work — what
        the clock-polled burn loop repeats to fill the designed cost."""
        if self.role == "shuffle":
            return self._q[self._rng.permutation(self._q.shape[0])]
        if self.role == "collate":
            return np.ascontiguousarray(self._q.T)
        if self.role == "load":
            return self._rng.lognormal(0.0, 1.0, size=8192)
        return hash_ids(self._q, self.record.vocab)

    def _measure_qrate(self, min_cpu_s: float = 0.12) -> float:
        """Quanta this process executes per CPU-second (only measured
        for stages with sub-tick burns, mirroring `spin_rate`)."""
        n = 0
        t0 = time.process_time()
        while time.process_time() - t0 < min_cpu_s:
            self._quantum()
            n += 1
        return max(n, 1) / max(time.process_time() - t0, 1e-3)

    def _do_burn(self, cpu_s: float):
        _burn(cpu_s, quantum=self._quantum, qrate=self._qrate)

    def release(self):
        super().release()
        self._q = None
        self._self_in = None

    # ------------------------------------------------------- production ---
    def _standalone_input(self):
        """Input block for a stage run standalone as a source (the
        calibration harness isolates every stage that way): generated
        once and reused, so the upstream transform's cost never leaks
        into this stage's measured curve."""
        if self._self_in is None:
            rng = self._rng if self._rng is not None \
                else np.random.RandomState(self.record.seed)
            blk = raw_block(rng, self.record)
            if self.role in ("collate", "pass"):
                blk = featurize_block(blk, self.record)
            self._self_in = blk
        return self._self_in

    def _produce(self, items):
        rng = self._rng if self._rng is not None \
            else np.random.RandomState(self.record.seed)
        if self.role == "load":
            return raw_block(rng, self.record)
        if self.kind == "join":
            return items
        blk = items[0] if items else self._standalone_input()
        if self.role == "shuffle":
            return shuffle_block(blk, rng)
        if self.role == "featurize":
            return featurize_block(blk, self.record)
        if self.role == "collate":
            return collate(blk)
        return blk                              # pass-through (prefetch)


def featurize_work_for(st: StageSpec, *, ballast: bool = True,
                       kind: Optional[str] = None,
                       record: Optional[RecordSpec] = None
                       ) -> FeaturizeWork:
    """One stage's FeaturizeWork: role from the spec's stage kind, the
    SpinWork wiring (kind/serial/ballast) from its topology + knobs."""
    role = _ROLES.get(st.kind, "pass")
    if kind is None:
        kind = "source" if not st.inputs \
            else ("join" if len(st.inputs) > 1 else "map")
    return FeaturizeWork(
        role, st.cost, st.serial_frac,
        ballast_mb=st.mem_per_worker_mb if ballast else 0.0,
        kind=kind, record=record)


def featurize_stage_fns(spec: StageGraph, *, ballast: bool = True,
                        record: Optional[RecordSpec] = None
                        ) -> Dict[str, FeaturizeWork]:
    """Real-featurization work fns for every stage of `spec` — the
    `work="real"` counterpart of `proc_executor.spin_stage_fns`. The
    sink delivers model-ready batches shaped by `record`."""
    return {st.name: featurize_work_for(st, ballast=ballast, record=record)
            for st in spec.stages}
