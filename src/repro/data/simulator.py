"""Queueing simulator of the ingestion pipeline.

The paper pretrains its DQN agent "in offline simulations" (§4.2); this
module is that simulator promoted to a first-class, tested component. It is
also the benchmark engine: the container exposes one CPU, so the paper's
128-CPU Xeon scaling behavior is modeled analytically (DESIGN.md §3) —
stage throughput follows Amdahl scaling on the stage's true cost, graph
throughput propagates bottlenecks through the StageGraph in topological
order (a join runs at the min of its parents; for a linear chain this is
exactly the classic bottleneck-stage formula, pipelined execution [21]),
and memory tracks worker overheads, per-edge buffers, and the prefetch
buffer.

Semantics shared by every optimizer under test (level playing field):
  - allocations: integer workers per stage + prefetch buffer depth,
  - machine resize events change the CPU cap mid-run,
  - exceeding the memory cap is an OOM: the pipeline crashes and pays a
    teardown+restart penalty (the paper's Fig. 5B behavior),
  - observation noise on measured latencies (configurable).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.data.pipeline import PipelineSpec, StageSpec, stage_throughput


@dataclass
class MachineSpec:
    n_cpus: int = 128
    mem_mb: float = 65536.0
    dram_bw_gbps: float = 25.6
    cpu_ghz: float = 3.0


@dataclass
class Allocation:
    workers: np.ndarray              # (n_stages,) int
    prefetch_mb: float = 512.0       # buffer budget for prefetch stage

    def copy(self) -> "Allocation":
        return Allocation(self.workers.copy(), self.prefetch_mb)


OOM_RESTART_TICKS = 30  # teardown + relaunch dead time (paper: "significant")


def graph_memory_mb(spec: PipelineSpec, workers, prefetch_mb: float) -> float:
    """THE memory model: framework floor + per-worker overheads +
    per-edge buffers + the prefetch buffer. PipelineSim scores OOMs with
    it and the fleet coordinator's admission control clamps against it —
    one definition, so the guard can never diverge from the judge.
    (Accumulation order is kept stable: these floats feed byte-identical
    golden files.)"""
    mb = 2048.0  # framework + model host memory floor
    for st, w in zip(spec.stages, workers):
        mb += st.mem_per_worker_mb * int(w)
    mb += spec.edge_buffer_mb * len(spec.edges)
    mb += prefetch_mb
    return mb


class PipelineSim:
    """Analytic pipeline simulator with OOM + resize dynamics."""

    def __init__(self, spec: PipelineSpec, machine: MachineSpec,
                 model_latency: float = 0.0, seed: int = 0,
                 obs_noise: float = 0.02):
        self.spec = spec
        self.machine = machine
        self.model_latency = model_latency
        self.rng = np.random.RandomState(seed)
        self.obs_noise = obs_noise
        self.oom_count = 0
        self.restart_left = 0
        self.time = 0

    # ------------------------------------------------------------ model ---
    def stage_rates(self, alloc: Allocation) -> np.ndarray:
        """Per-stage service rate (what the stage could process given its
        workers, were its inputs never the constraint)."""
        return np.array([
            stage_throughput(st, int(w))
            for st, w in zip(self.spec.stages, alloc.workers)])

    def sustained_rates(self, alloc: Allocation) -> np.ndarray:
        """Per-stage sustained rate over the DAG in topological order: a
        stage runs at min(its own service rate, its input rate), and a
        join's input rate is the min over its parents (it pairs one item
        from each input stream per output). For a linear chain the sink's
        sustained rate is exactly min over all stages — the pre-DAG
        bottleneck formula."""
        out = self.stage_rates(alloc)
        for i in self.spec.topo_order:
            for p in self.spec.parents(i):
                if out[p] < out[i]:
                    out[i] = out[p]
        return out

    def throughput(self, alloc: Allocation) -> float:
        """Sustained batches/s at the sink, capped by model demand."""
        rates = self.stage_rates(alloc)
        if np.any(rates <= 0):
            return 0.0
        rate = float(self.sustained_rates(alloc)[self.spec.sink])
        if self.model_latency > 0:
            rate = min(rate, 1.0 / self.model_latency)
        return rate

    def memory_used(self, alloc: Allocation) -> float:
        return graph_memory_mb(self.spec, alloc.workers, alloc.prefetch_mb)

    def measured_latencies(self, alloc: Allocation) -> np.ndarray:
        """Per-stage effective latency (1/rate) with observation noise —
        what a live rate-meter reports."""
        rates = self.stage_rates(alloc)
        lat = np.where(rates > 0, 1.0 / np.maximum(rates, 1e-9), 10.0)
        noise = 1.0 + self.obs_noise * self.rng.randn(len(lat))
        return lat * np.clip(noise, 0.5, 1.5)

    # ---------------------------------------------------------- dynamics --
    def apply(self, alloc: Allocation) -> dict:
        """Advance one tick under `alloc`. Returns metrics for the tick."""
        self.time += 1
        mem = self.memory_used(alloc)
        used_cpus = int(np.sum(alloc.workers))
        if self.restart_left > 0:
            self.restart_left -= 1
            return {"throughput": 0.0, "mem_mb": mem, "oom": False,
                    "restarting": True, "used_cpus": used_cpus}
        if mem > self.machine.mem_mb:
            self.oom_count += 1
            self.restart_left = OOM_RESTART_TICKS
            return {"throughput": 0.0, "mem_mb": mem, "oom": True,
                    "restarting": True, "used_cpus": used_cpus}
        if used_cpus > self.machine.n_cpus:
            # over-subscription: everyone slows down proportionally
            scale = self.machine.n_cpus / used_cpus
            tput = self.throughput(alloc) * scale
        else:
            tput = self.throughput(alloc)
        return {"throughput": tput, "mem_mb": mem, "oom": False,
                "restarting": False, "used_cpus": used_cpus}

    def resize(self, n_cpus: int):
        self.machine = dataclasses.replace(self.machine, n_cpus=n_cpus)

    # ----------------------------------------------------------- optima ---
    def best_allocation(self, n_cpus: Optional[int] = None,
                        iters: int = 4096) -> Tuple[Allocation, float]:
        """Oracle: greedy water-filling on TRUE costs + efficiency curves
        (provably optimal for min-bottleneck with concave per-stage rates:
        each CPU goes to the current bottleneck). With a single sink every
        stage is an ancestor of it, so the DAG's sustained sink rate is the
        min over all service rates and water-filling on service rates stays
        optimal."""
        n = n_cpus or self.machine.n_cpus
        workers = np.ones(self.spec.n_stages, dtype=int)
        # leave a little memory headroom; prefetch sized to depth 2
        alloc = Allocation(workers, prefetch_mb=2 * self.spec.batch_mb)
        for _ in range(n - self.spec.n_stages):
            rates = self.stage_rates(alloc)
            i = int(np.argmin(rates))
            trial = alloc.copy()
            trial.workers[i] += 1
            if self.memory_used(trial) > self.machine.mem_mb:
                break
            alloc = trial
            if self.model_latency > 0 and \
                    np.min(self.stage_rates(alloc)) >= 1 / self.model_latency:
                break
        return alloc, self.throughput(alloc)


def resize_schedule(total_ticks: int,
                    caps: Sequence[int] = (32, 64, 128, 64, 32)
                    ) -> List[Tuple[int, int]]:
    """The paper's rescale script: [(tick, n_cpus), ...] evenly spaced."""
    seg = total_ticks // len(caps)
    return [(i * seg, c) for i, c in enumerate(caps)]
