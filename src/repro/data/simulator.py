"""Queueing simulator of the ingestion pipeline.

The paper pretrains its DQN agent "in offline simulations" (§4.2); this
module is that simulator promoted to a first-class, tested component. It is
also the benchmark engine: the container exposes one CPU, so the paper's
128-CPU Xeon scaling behavior is modeled analytically (DESIGN.md §3) —
stage throughput follows Amdahl scaling on the stage's true cost, graph
throughput propagates bottlenecks through the StageGraph in topological
order (a join runs at the min of its parents; for a linear chain this is
exactly the classic bottleneck-stage formula, pipelined execution [21]),
and memory tracks worker overheads, per-edge buffers, and the prefetch
buffer.

Semantics shared by every optimizer under test (level playing field):
  - allocations: integer workers per stage + prefetch buffer depth,
  - machine resize events change the CPU cap mid-run,
  - exceeding the memory cap is an OOM: the pipeline crashes and pays a
    teardown+restart penalty (the paper's Fig. 5B behavior),
  - observation noise on measured latencies (configurable).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.data.pipeline import PipelineSpec, stage_throughput


@dataclass(frozen=True)
class MachineSpec:
    n_cpus: int = 128
    mem_mb: float = 65536.0
    dram_bw_gbps: float = 25.6
    cpu_ghz: float = 3.0


@dataclass
class Allocation:
    workers: np.ndarray              # (n_stages,) int
    prefetch_mb: float = 512.0       # buffer budget for prefetch stage

    def copy(self) -> "Allocation":
        return Allocation(self.workers.copy(), self.prefetch_mb)


OOM_RESTART_TICKS = 30  # teardown + relaunch dead time (paper: "significant")


def graph_memory_mb(spec: PipelineSpec, workers, prefetch_mb: float) -> float:
    """THE memory model: framework floor + per-worker overheads +
    per-edge buffers + the prefetch buffer. PipelineSim scores OOMs with
    it and the fleet coordinator's admission control clamps against it —
    one definition, so the guard can never diverge from the judge.
    (Accumulation order is kept stable: these floats feed byte-identical
    golden files.)"""
    mb = 2048.0  # framework + model host memory floor
    for st, w in zip(spec.stages, workers):
        mb += st.mem_per_worker_mb * int(w)
    mb += spec.edge_buffer_mb * len(spec.edges)
    mb += prefetch_mb
    return mb


class PipelineSim:
    """Analytic pipeline simulator with OOM + resize dynamics.

    Streaming graphs (a `StageSpec` with kind="stream" carrying an
    `ArrivalProcess`) add a world clock: each `apply` tick spans `tick_s`
    stream-seconds, arrivals are the exact integral of the arrival curve
    over the tick, and the stream source's service rate is capped at
    what is actually available — `(backlog + arrivals) / tick_s`. What
    the pipeline cannot drain accumulates as backlog (batches), charged
    to memory at the arrival model's `buffer_mb_per_batch` (so an
    undersized allocation can OOM on backlog growth) and reported as the
    freshness metrics `backlog_items` / `batch_staleness_s` /
    `p99_queue_delay_s`. Non-stream graphs take none of these paths —
    their tick dicts (and golden files) are unchanged.
    """

    def __init__(self, spec: PipelineSpec, machine: MachineSpec,
                 model_latency: float = 0.0, seed: int = 0,
                 obs_noise: float = 0.02, tick_s: float = 1.0):
        self.spec = spec
        self.machine = machine
        self.model_latency = model_latency
        self.rng = np.random.RandomState(seed)
        self.obs_noise = obs_noise
        self.oom_count = 0
        self.restart_left = 0
        self.time = 0
        # streaming state (inert for non-stream specs)
        self.tick_s = float(tick_s)
        self.stream_clock = 0.0        # stream-seconds elapsed
        self.backlog = 0.0             # batches arrived but not drained
        self.shed_total = 0.0          # batches dropped at the buffer cap
        self._stale = 0.0
        self._delay_win: deque = deque(maxlen=100)

    # ----------------------------------------------------------- stream ---
    @property
    def _stream(self):
        i = getattr(self.spec, "stream_idx", None)
        return None if i is None else self.spec.stages[i].arrival

    def _arrivals_now(self) -> float:
        """Batches arriving during the CURRENT tick's stream window
        [stream_clock, stream_clock + tick_s) — exact integral."""
        arr = self._stream
        if arr is None:
            return 0.0
        return arr.batches_between(self.stream_clock,
                                   self.stream_clock + self.tick_s)

    # ------------------------------------------------------------ model ---
    def stage_rates(self, alloc: Allocation, *,
                    stream_capped: bool = True) -> np.ndarray:
        """Per-stage service rate (what the stage could process given its
        workers, were its inputs never the constraint). For a streaming
        source the rate is additionally capped by availability —
        `min(amdahl_rate, (backlog + arrivals)/tick_s)`; pass
        `stream_capped=False` for pure capacity planning (the oracle
        water-fills on capacity, not on today's traffic)."""
        rates = np.array([
            stage_throughput(st, int(w))
            for st, w in zip(self.spec.stages, alloc.workers)])
        idx = getattr(self.spec, "stream_idx", None)
        if idx is not None and stream_capped:
            avail = (self.backlog + self._arrivals_now()) / self.tick_s
            rates[idx] = min(rates[idx], avail)
        return rates

    def sustained_rates(self, alloc: Allocation) -> np.ndarray:
        """Per-stage sustained rate over the DAG in topological order: a
        stage runs at min(its own service rate, its input rate), and a
        join's input rate is the min over its parents (it pairs one item
        from each input stream per output). For a linear chain the sink's
        sustained rate is exactly min over all stages — the pre-DAG
        bottleneck formula."""
        out = self.stage_rates(alloc)
        for i in self.spec.topo_order:
            for p in self.spec.parents(i):
                if out[p] < out[i]:
                    out[i] = out[p]
        return out

    def throughput(self, alloc: Allocation) -> float:
        """Sustained batches/s at the sink, capped by model demand."""
        rates = self.stage_rates(alloc)
        if np.any(rates <= 0):
            return 0.0
        rate = float(self.sustained_rates(alloc)[self.spec.sink])
        if self.model_latency > 0:
            rate = min(rate, 1.0 / self.model_latency)
        return rate

    def memory_used(self, alloc: Allocation) -> float:
        return graph_memory_mb(self.spec, alloc.workers, alloc.prefetch_mb)

    def measured_latencies(self, alloc: Allocation) -> np.ndarray:
        """Per-stage effective latency (1/rate) with observation noise —
        what a live rate-meter reports."""
        rates = self.stage_rates(alloc)
        lat = np.where(rates > 0, 1.0 / np.maximum(rates, 1e-9), 10.0)
        noise = 1.0 + self.obs_noise * self.rng.randn(len(lat))
        return lat * np.clip(noise, 0.5, 1.5)

    # ---------------------------------------------------------- dynamics --
    def apply(self, alloc: Allocation) -> dict:
        """Advance one tick under `alloc`. Returns metrics for the tick."""
        self.time += 1
        arrivals = self._arrivals_now()
        mem = self.memory_used(alloc)
        arr = self._stream
        if arr is not None and arr.buffer_mb_per_batch > 0:
            # backlogged batches live in the ingest buffer — an undersized
            # allocation OOMs on backlog growth, not just static footprint
            mem += (self.backlog + arrivals) * arr.buffer_mb_per_batch
        used_cpus = int(np.sum(alloc.workers))
        if self.restart_left > 0:
            self.restart_left -= 1
            out = {"throughput": 0.0, "mem_mb": mem, "oom": False,
                   "restarting": True, "used_cpus": used_cpus}
            return self._finish_tick(out, arrivals, drained=0.0)
        if mem > self.machine.mem_mb:
            self.oom_count += 1
            self.restart_left = OOM_RESTART_TICKS
            out = {"throughput": 0.0, "mem_mb": mem, "oom": True,
                   "restarting": True, "used_cpus": used_cpus}
            return self._finish_tick(out, arrivals, drained=0.0)
        if used_cpus > self.machine.n_cpus:
            # over-subscription: everyone slows down proportionally
            scale = self.machine.n_cpus / used_cpus
            tput = self.throughput(alloc) * scale
        else:
            tput = self.throughput(alloc)
        out = {"throughput": tput, "mem_mb": mem, "oom": False,
               "restarting": False, "used_cpus": used_cpus}
        return self._finish_tick(out, arrivals, drained=tput * self.tick_s)

    def _finish_tick(self, out: dict, arrivals: float, drained: float) -> dict:
        """Stream bookkeeping at end of tick: backlog accrues (even while
        restarting — the world does not pause for an OOM), retention cap
        sheds, staleness = backlog drain time at the current rate, p99 over
        a sliding window. No-op (dict untouched) for non-stream specs, so
        golden files stay byte-identical."""
        arr = self._stream
        if arr is None:
            return out
        self.backlog = max(0.0, self.backlog + arrivals - drained)
        if arr.buffer_cap_batches is not None and \
                self.backlog > arr.buffer_cap_batches:
            self.shed_total += self.backlog - arr.buffer_cap_batches
            self.backlog = float(arr.buffer_cap_batches)
        rate = drained / self.tick_s
        if rate > 1e-9:
            self._stale = self.backlog / rate
        elif self.backlog > 1e-9:
            self._stale += self.tick_s   # stalled with work queued: ages
        else:
            self._stale = 0.0
        self._delay_win.append(self._stale)
        out["backlog_items"] = float(self.backlog)
        out["batch_staleness_s"] = float(self._stale)
        out["p99_queue_delay_s"] = float(np.percentile(self._delay_win, 99.0))
        out["arrival_rate"] = arrivals / self.tick_s
        out["shed_batches"] = float(self.shed_total)
        self.stream_clock += self.tick_s
        return out

    def resize(self, n_cpus: int):
        self.machine = dataclasses.replace(self.machine, n_cpus=n_cpus)

    # ----------------------------------------------------------- optima ---
    def best_allocation(self, n_cpus: Optional[int] = None,
                        iters: int = 4096) -> Tuple[Allocation, float]:
        """Oracle: greedy water-filling on TRUE costs + efficiency curves
        (provably optimal for min-bottleneck with concave per-stage rates:
        each CPU goes to the current bottleneck). With a single sink every
        stage is an ancestor of it, so the DAG's sustained sink rate is the
        min over all service rates and water-filling on service rates stays
        optimal."""
        n = n_cpus or self.machine.n_cpus
        workers = np.ones(self.spec.n_stages, dtype=int)
        # leave a little memory headroom; prefetch sized to depth 2
        alloc = Allocation(workers, prefetch_mb=2 * self.spec.batch_mb)
        for _ in range(n - self.spec.n_stages):
            # capacity planning: water-fill on UNCAPPED service rates — a
            # traffic-capped stream source would otherwise stay the argmin
            # forever and soak up every CPU
            rates = self.stage_rates(alloc, stream_capped=False)
            i = int(np.argmin(rates))
            trial = alloc.copy()
            trial.workers[i] += 1
            if self.memory_used(trial) > self.machine.mem_mb:
                break
            alloc = trial
            if self.model_latency > 0 and \
                    np.min(self.stage_rates(alloc, stream_capped=False)) \
                    >= 1 / self.model_latency:
                break
        return alloc, self.throughput(alloc)


def resize_schedule(total_ticks: int,
                    caps: Sequence[int] = (32, 64, 128, 64, 32)
                    ) -> List[Tuple[int, int]]:
    """The paper's rescale script: [(tick, n_cpus), ...] evenly spaced."""
    seg = total_ticks // len(caps)
    return [(i * seg, c) for i, c in enumerate(caps)]
