"""Fleet-scale cluster simulator: the plane above the data plane.

The paper's setting is a Netflix compute cluster of many trainer machines
whose ingestion pipelines are tuned independently; its headline numbers
(aggregate ingestion throughput, CPU & GPU utilization) are cluster-level
outcomes. Zhao et al.'s DSI characterization shows production DLRM
ingestion is provisioned fleet-wide, with machines joining and leaving
jobs. This module models that fleet:

  - a ClusterSpec of N heterogeneous TrainerSpecs (each its own
    StageGraph pipeline, MachineSpec, and model demand),
  - a shared elastic CPU pool the cluster plane can grant to machines on
    top of their owned CPUs (sum of grants <= pool),
  - a churn schedule of FleetEvents — machines join, leave, and shrink
    mid-run, and the pool itself can be re-capped — generalizing the
    single-machine `resize_schedule`.

FleetSim runs one PipelineSim per trainer and speaks the same driver
dialect as PipelineSim (`machine` / `apply` / `resize` / `oom_count`), so
`repro.api.Session` drives a fleet policy with the exact
propose -> apply -> observe loop used for single machines. Policies see
the FleetState (active set, per-machine owned CPUs, pool) and answer with
a FleetAllocation (per-trainer Allocation + pool grants).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.data.pipeline import StageGraph
from repro.data.simulator import Allocation, MachineSpec, PipelineSim

EVENT_KINDS = ("join", "leave", "resize", "pool")


@dataclass(frozen=True)
class TrainerSpec:
    """One trainer machine in the fleet: its pipeline, hardware, and the
    rate its model consumes batches (1/model_latency caps throughput)."""
    name: str
    pipeline: StageGraph
    machine: MachineSpec
    model_latency: float = 0.0
    start_active: bool = True


@dataclass(frozen=True)
class FleetEvent:
    """A churn event: at `tick`, `trainer` joins/leaves the job, its
    machine is resized to `n_cpus`, or (kind="pool") the shared pool is
    re-capped to `n_cpus`."""
    tick: int
    kind: str                    # "join" | "leave" | "resize" | "pool"
    trainer: str = ""            # unused for kind="pool"
    n_cpus: int = 0              # new cap for "resize" / "pool"


@dataclass(frozen=True)
class ClusterSpec:
    """The fleet: trainers + shared elastic CPU pool + churn schedule."""
    name: str
    trainers: Tuple[TrainerSpec, ...]
    shared_pool: int = 0
    events: Tuple[FleetEvent, ...] = ()

    def __post_init__(self):
        names = [t.name for t in self.trainers]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate trainer names in {names}")
        if self.shared_pool < 0:
            raise ValueError("shared_pool must be >= 0")
        for ev in self.events:
            if ev.kind not in EVENT_KINDS:
                raise ValueError(f"unknown event kind {ev.kind!r}; "
                                 f"known: {EVENT_KINDS}")
            if ev.kind != "pool" and ev.trainer not in names:
                raise ValueError(
                    f"event {ev.kind!r}@{ev.tick} targets unknown trainer "
                    f"{ev.trainer!r}")
            if ev.kind in ("resize", "pool") and ev.n_cpus < 0:
                raise ValueError(f"event {ev.kind!r}@{ev.tick}: n_cpus < 0")

    def trainer(self, name: str) -> TrainerSpec:
        for t in self.trainers:
            if t.name == name:
                return t
        raise KeyError(name)


@dataclass(frozen=True)
class FleetState:
    """The cluster plane's dynamic view: what a fleet policy proposes
    against. `base_cpus` is each ACTIVE machine's owned CPUs (after any
    resize churn); the pool is granted on top of those."""
    tick: int
    pool: int
    active: Tuple[str, ...]                    # spec order
    base_cpus: Tuple[Tuple[str, int], ...]     # (name, owned cpus), active

    def key(self):
        """Cache key for static policies: everything but the tick."""
        return (self.pool, self.active, self.base_cpus)

    def base(self, name: str) -> int:
        return dict(self.base_cpus)[name]

    @property
    def n_cpus(self) -> int:
        """Total CPUs the fleet can place right now (owned + pool)."""
        return sum(c for _, c in self.base_cpus) + self.pool


@dataclass
class FleetAllocation:
    """Per-trainer pipeline allocations + shared-pool grants.

    The `workers` / `prefetch_mb` views flatten the fleet into the shape
    single-machine drivers compare on (Session's changed-proposal
    check), so the same driver loop serves both planes.
    """
    allocs: Dict[str, Allocation]
    grants: Dict[str, int] = field(default_factory=dict)

    @property
    def workers(self) -> np.ndarray:
        if not self.allocs:
            return np.zeros(0, dtype=int)
        parts = [self.allocs[n].workers for n in sorted(self.allocs)]
        grants = [int(self.grants.get(n, 0)) for n in sorted(self.allocs)]
        return np.concatenate(parts + [np.asarray(grants, dtype=int)])

    @property
    def prefetch_mb(self) -> float:
        return float(sum(a.prefetch_mb for a in self.allocs.values()))

    def copy(self) -> "FleetAllocation":
        return FleetAllocation({n: a.copy() for n, a in self.allocs.items()},
                               dict(self.grants))


class FleetBackend:
    """Shared churn/state machinery for fleet backends.

    Every backend that runs a cluster — the analytic `FleetSim` below and
    the live-executor `repro.data.live_fleet.LiveFleet` — shares this
    base: the owned-CPU / active-set / pool bookkeeping, the event cursor
    (idempotent: it only moves forward), and the driver dialect surface
    (`machine` / `resize` / grant validation). Subclasses hook churn via
    `_on_join` / `_on_leave` (called AFTER the state flip, so the hook
    sees the post-event active set) and implement `apply`.
    """

    def __init__(self, cluster: ClusterSpec):
        self.cluster = cluster
        self.time = 0
        self.pool = cluster.shared_pool
        self._base = {t.name: t.machine.n_cpus for t in cluster.trainers}
        self._active = {t.name: t.start_active for t in cluster.trainers}
        self._events = sorted(cluster.events, key=lambda e: e.tick)
        self._next_event = 0

    # ----------------------------------------------------------- churn ----
    def _on_join(self, name: str):
        pass

    def _on_leave(self, name: str):
        pass

    def _advance_events(self):
        """Apply every event due at or before the current tick (idempotent:
        the cursor only moves forward)."""
        while self._next_event < len(self._events) \
                and self._events[self._next_event].tick <= self.time:
            ev = self._events[self._next_event]
            self._next_event += 1
            if ev.kind == "join":
                self._active[ev.trainer] = True
                self._on_join(ev.trainer)
            elif ev.kind == "leave":
                self._active[ev.trainer] = False
                self._on_leave(ev.trainer)
            elif ev.kind == "resize":
                self._base[ev.trainer] = int(ev.n_cpus)
            elif ev.kind == "pool":
                self.pool = int(ev.n_cpus)

    def inject_event(self, ev: FleetEvent):
        """Dynamically add a churn event to the pending schedule (the
        `repro.api` ChurnEvent injection path). Events already applied
        stay applied; the new event is merged into the not-yet-applied
        tail in tick order (stable, so same-tick events keep their
        injection order). An event whose tick is already past fires on
        the next state read."""
        if ev.kind not in EVENT_KINDS:
            raise ValueError(f"unknown event kind {ev.kind!r}; "
                             f"known: {EVENT_KINDS}")
        if ev.kind != "pool" \
                and not any(t.name == ev.trainer for t in self.cluster.trainers):
            raise ValueError(f"event {ev.kind!r}@{ev.tick} targets unknown "
                             f"trainer {ev.trainer!r}")
        pending = self._events[self._next_event:] + [ev]
        pending.sort(key=lambda e: e.tick)
        self._events = self._events[:self._next_event] + pending

    @property
    def machine(self) -> FleetState:
        self._advance_events()
        active = tuple(t.name for t in self.cluster.trainers
                       if self._active[t.name])
        return FleetState(tick=self.time, pool=self.pool, active=active,
                          base_cpus=tuple((n, self._base[n]) for n in active))

    def resize(self, pool: int):
        self.pool = int(pool)

    def _check_falloc(self, falloc: FleetAllocation, state: FleetState):
        """The grant contract every backend enforces identically."""
        unknown = [n for n in falloc.grants
                   if not any(t.name == n for t in self.cluster.trainers)]
        if unknown:
            raise ValueError(f"grants name unknown trainers {unknown}")
        # grants to inactive trainers consume nothing (stale keys after a
        # leave event are harmless); only active grants draw on the pool
        granted = sum(int(falloc.grants.get(n, 0)) for n in state.active)
        if granted > self.pool:
            raise ValueError(
                f"grants total {granted} exceed shared pool {self.pool}")


class FleetSim(FleetBackend):
    """N per-trainer PipelineSims under a shared pool and churn schedule.

    Speaks the single-machine driver dialect:
      machine   -> FleetState (events due at the current tick are applied
                   first, so policies propose against the post-churn view)
      apply     -> one tick for every active trainer; aggregate metrics
                   plus a "per_trainer" breakdown
      resize(n) -> re-caps the shared pool (the fleet-level analog of a
                   machine resize; per-machine churn goes via events)
    """

    def __init__(self, cluster: ClusterSpec, seed: int = 0,
                 obs_noise: float = 0.02):
        super().__init__(cluster)
        self.sims: Dict[str, PipelineSim] = {
            t.name: PipelineSim(t.pipeline, t.machine, t.model_latency,
                                seed=seed + i, obs_noise=obs_noise)
            for i, t in enumerate(cluster.trainers)}

    def _on_join(self, name: str):
        # a (re)joining machine is a fresh process: no restart debt
        self.sims[name].restart_left = 0

    @property
    def oom_count(self) -> int:
        return sum(s.oom_count for s in self.sims.values())

    # ------------------------------------------------------------ tick ----
    def apply(self, falloc: FleetAllocation) -> dict:
        self._advance_events()
        state = self.machine
        self._check_falloc(falloc, state)
        per: Dict[str, dict] = {}
        tput = mem = used = 0.0
        any_oom = any_restart = False
        for name in state.active:
            sim = self.sims[name]
            eff = self._base[name] + int(falloc.grants.get(name, 0))
            if sim.machine.n_cpus != eff:
                sim.resize(eff)
            if name not in falloc.allocs:
                raise KeyError(
                    f"no allocation proposed for active trainer {name!r}")
            m = sim.apply(falloc.allocs[name])
            m["eff_cpus"] = eff
            per[name] = m
            tput += m["throughput"]
            mem += m["mem_mb"]
            used += min(m["used_cpus"], eff)
            any_oom = any_oom or m["oom"]
            any_restart = any_restart or m["restarting"]
        self.time += 1
        return {"throughput": tput, "mem_mb": mem, "used_cpus": int(used),
                "oom": any_oom, "restarting": any_restart,
                "n_active": len(state.active), "pool": self.pool,
                "per_trainer": per}


def churn_schedule(total_ticks: int,
                   events: Sequence[Tuple[float, str, str, int]]
                   ) -> Tuple[FleetEvent, ...]:
    """Fleet analog of `resize_schedule`: events placed at fractions of the
    run. Each entry is (frac, kind, trainer, n_cpus); frac in [0, 1)."""
    return tuple(FleetEvent(tick=int(frac * total_ticks), kind=kind,
                            trainer=trainer, n_cpus=n_cpus)
                 for frac, kind, trainer, n_cpus in events)


def demo_cluster(ticks: int = 1200, pool: int = 80) -> ClusterSpec:
    """The canonical 4-machine heterogeneous fleet with churn used by the
    fig7_fleet benchmark, the fleet example, and the acceptance tests.

    Heterogeneity axes: machine size (24-96 owned CPUs), memory (6-64 GB),
    pipeline shape (two linear chains + the multi-source join DAG), and
    model demand (1/model_latency b/s). Two machines carry the production
    pathologies memory-blind policies die on: "small" saturates its model
    with a handful of CPUs (pool grants parked there are pure waste) and
    both "small" and "late" are memory-tight (6 GB), so an even split of
    the pool pushes their per-worker footprint past the physical memory
    line — the Fig. 5B OOM crash-loop, now at fleet scale. Churn: "late"
    joins a third of the way in, "big" shrinks mid-run, "small" leaves
    near the end.
    """
    from repro.data.pipeline import (criteo_pipeline, custom_pipeline,
                                     multisource_dlrm_pipeline)
    trainers = (
        TrainerSpec("big", criteo_pipeline(),
                    MachineSpec(n_cpus=96, mem_mb=65536.0),
                    model_latency=0.02),
        TrainerSpec("mid", custom_pipeline(),
                    MachineSpec(n_cpus=48, mem_mb=32768.0),
                    model_latency=0.04),
        TrainerSpec("small", multisource_dlrm_pipeline(),
                    MachineSpec(n_cpus=24, mem_mb=6144.0),
                    model_latency=0.2),
        TrainerSpec("late", criteo_pipeline(),
                    MachineSpec(n_cpus=64, mem_mb=6144.0),
                    model_latency=0.025, start_active=False),
    )
    events = churn_schedule(ticks, [
        (1 / 3, "join", "late", 0),
        (0.55, "resize", "big", 48),
        (0.80, "leave", "small", 0),
    ])
    return ClusterSpec("demo_fleet4", trainers, shared_pool=pool,
                       events=events)
