"""Fleet-scale cluster simulator: the plane above the data plane.

The paper's setting is a Netflix compute cluster of many trainer machines
whose ingestion pipelines are tuned independently; its headline numbers
(aggregate ingestion throughput, CPU & GPU utilization) are cluster-level
outcomes. Zhao et al.'s DSI characterization shows production DLRM
ingestion is provisioned fleet-wide, with machines joining and leaving
jobs. This module models that fleet:

  - a ClusterSpec of N heterogeneous TrainerSpecs (each its own
    StageGraph pipeline, MachineSpec, and model demand),
  - a shared elastic CPU pool the cluster plane can grant to machines on
    top of their owned CPUs (sum of grants <= pool),
  - a churn schedule of FleetEvents — machines join, leave, and shrink
    mid-run, and the pool itself can be re-capped — generalizing the
    single-machine `resize_schedule`.

FleetSim runs one PipelineSim per trainer and speaks the same driver
dialect as PipelineSim (`machine` / `apply` / `resize` / `oom_count`), so
`repro.api.Session` drives a fleet policy with the exact
propose -> apply -> observe loop used for single machines. Policies see
the FleetState (active set, per-machine owned CPUs, pool) and answer with
a FleetAllocation (per-trainer Allocation + pool grants).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.data.pipeline import StageGraph
from repro.data.simulator import Allocation, MachineSpec, PipelineSim

EVENT_KINDS = ("join", "leave", "resize", "pool")


@dataclass(frozen=True)
class TrainerSpec:
    """One trainer machine in the fleet: its pipeline, hardware, and the
    rate its model consumes batches (1/model_latency caps throughput)."""
    name: str
    pipeline: StageGraph
    machine: MachineSpec
    model_latency: float = 0.0
    start_active: bool = True


@dataclass(frozen=True)
class FleetEvent:
    """A churn event: at `tick`, `trainer` joins/leaves the job, its
    machine is resized to `n_cpus`, or (kind="pool") the shared pool is
    re-capped to `n_cpus`."""
    tick: int
    kind: str                    # "join" | "leave" | "resize" | "pool"
    trainer: str = ""            # unused for kind="pool"
    n_cpus: int = 0              # new cap for "resize" / "pool"


@dataclass(frozen=True)
class ClusterSpec:
    """The fleet: trainers + shared elastic CPU pool + churn schedule."""
    name: str
    trainers: Tuple[TrainerSpec, ...]
    shared_pool: int = 0
    events: Tuple[FleetEvent, ...] = ()

    def __post_init__(self):
        names = [t.name for t in self.trainers]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate trainer names in {names}")
        if self.shared_pool < 0:
            raise ValueError("shared_pool must be >= 0")
        for ev in self.events:
            if ev.kind not in EVENT_KINDS:
                raise ValueError(f"unknown event kind {ev.kind!r}; "
                                 f"known: {EVENT_KINDS}")
            if ev.kind != "pool" and ev.trainer not in names:
                raise ValueError(
                    f"event {ev.kind!r}@{ev.tick} targets unknown trainer "
                    f"{ev.trainer!r}")
            if ev.kind in ("resize", "pool") and ev.n_cpus < 0:
                raise ValueError(f"event {ev.kind!r}@{ev.tick}: n_cpus < 0")

    def trainer(self, name: str) -> TrainerSpec:
        for t in self.trainers:
            if t.name == name:
                return t
        raise KeyError(name)


@dataclass(frozen=True)
class FleetState:
    """The cluster plane's dynamic view: what a fleet policy proposes
    against. `base_cpus` is each ACTIVE machine's owned CPUs (after any
    resize churn); the pool is granted on top of those."""
    tick: int
    pool: int
    active: Tuple[str, ...]                    # spec order
    base_cpus: Tuple[Tuple[str, int], ...]     # (name, owned cpus), active

    def key(self):
        """Cache key for static policies: everything but the tick."""
        return (self.pool, self.active, self.base_cpus)

    def base(self, name: str) -> int:
        return dict(self.base_cpus)[name]

    @property
    def n_cpus(self) -> int:
        """Total CPUs the fleet can place right now (owned + pool)."""
        return sum(c for _, c in self.base_cpus) + self.pool


@dataclass
class FleetAllocation:
    """Per-trainer pipeline allocations + shared-pool grants.

    The `workers` / `prefetch_mb` views flatten the fleet into the shape
    single-machine drivers compare on (Session's changed-proposal
    check), so the same driver loop serves both planes.
    """
    allocs: Dict[str, Allocation]
    grants: Dict[str, int] = field(default_factory=dict)

    @property
    def workers(self) -> np.ndarray:
        if not self.allocs:
            return np.zeros(0, dtype=int)
        parts = [self.allocs[n].workers for n in sorted(self.allocs)]
        grants = [int(self.grants.get(n, 0)) for n in sorted(self.allocs)]
        return np.concatenate(parts + [np.asarray(grants, dtype=int)])

    @property
    def prefetch_mb(self) -> float:
        return float(sum(a.prefetch_mb for a in self.allocs.values()))

    def copy(self) -> "FleetAllocation":
        return FleetAllocation({n: a.copy() for n, a in self.allocs.items()},
                               dict(self.grants))


class FleetBackend:
    """Shared churn/state machinery for fleet backends.

    Every backend that runs a cluster — the analytic `FleetSim` below and
    the live-executor `repro.data.live_fleet.LiveFleet` — shares this
    base: the owned-CPU / active-set / pool bookkeeping, the event cursor
    (idempotent: it only moves forward), and the driver dialect surface
    (`machine` / `resize` / grant validation). Subclasses hook churn via
    `_on_join` / `_on_leave` (called AFTER the state flip, so the hook
    sees the post-event active set) and implement `apply`.
    """

    def __init__(self, cluster: ClusterSpec):
        self.cluster = cluster
        self.time = 0
        self.pool = cluster.shared_pool
        self._base = {t.name: t.machine.n_cpus for t in cluster.trainers}
        self._active = {t.name: t.start_active for t in cluster.trainers}
        self._events = sorted(cluster.events, key=lambda e: e.tick)
        self._next_event = 0

    # ----------------------------------------------------------- churn ----
    def _on_join(self, name: str):
        pass

    def _on_leave(self, name: str):
        pass

    def _advance_events(self):
        """Apply every event due at or before the current tick (idempotent:
        the cursor only moves forward)."""
        while self._next_event < len(self._events) \
                and self._events[self._next_event].tick <= self.time:
            ev = self._events[self._next_event]
            self._next_event += 1
            if ev.kind == "join":
                self._active[ev.trainer] = True
                self._on_join(ev.trainer)
            elif ev.kind == "leave":
                self._active[ev.trainer] = False
                self._on_leave(ev.trainer)
            elif ev.kind == "resize":
                self._base[ev.trainer] = int(ev.n_cpus)
            elif ev.kind == "pool":
                self.pool = int(ev.n_cpus)

    def inject_event(self, ev: FleetEvent):
        """Dynamically add a churn event to the pending schedule (the
        `repro.api` ChurnEvent injection path). Events already applied
        stay applied; the new event is merged into the not-yet-applied
        tail in tick order (stable, so same-tick events keep their
        injection order). An event whose tick is already past fires on
        the next state read."""
        if ev.kind not in EVENT_KINDS:
            raise ValueError(f"unknown event kind {ev.kind!r}; "
                             f"known: {EVENT_KINDS}")
        if ev.kind != "pool" \
                and not any(t.name == ev.trainer for t in self.cluster.trainers):
            raise ValueError(f"event {ev.kind!r}@{ev.tick} targets unknown "
                             f"trainer {ev.trainer!r}")
        pending = self._events[self._next_event:] + [ev]
        pending.sort(key=lambda e: e.tick)
        self._events = self._events[:self._next_event] + pending

    @property
    def machine(self) -> FleetState:
        self._advance_events()
        active = tuple(t.name for t in self.cluster.trainers
                       if self._active[t.name])
        return FleetState(tick=self.time, pool=self.pool, active=active,
                          base_cpus=tuple((n, self._base[n]) for n in active))

    def resize(self, pool: int):
        self.pool = int(pool)

    def _check_falloc(self, falloc: FleetAllocation, state: FleetState):
        """The grant contract every backend enforces identically."""
        unknown = [n for n in falloc.grants
                   if not any(t.name == n for t in self.cluster.trainers)]
        if unknown:
            raise ValueError(f"grants name unknown trainers {unknown}")
        # grants to inactive trainers consume nothing (stale keys after a
        # leave event are harmless); only active grants draw on the pool
        granted = sum(int(falloc.grants.get(n, 0)) for n in state.active)
        if granted > self.pool:
            raise ValueError(
                f"grants total {granted} exceed shared pool {self.pool}")


class FleetSim(FleetBackend):
    """N per-trainer PipelineSims under a shared pool and churn schedule.

    Speaks the single-machine driver dialect:
      machine   -> FleetState (events due at the current tick are applied
                   first, so policies propose against the post-churn view)
      apply     -> one tick for every active trainer; aggregate metrics
                   plus a "per_trainer" breakdown
      resize(n) -> re-caps the shared pool (the fleet-level analog of a
                   machine resize; per-machine churn goes via events)
    """

    def __init__(self, cluster: ClusterSpec, seed: int = 0,
                 obs_noise: float = 0.02):
        super().__init__(cluster)
        self.sims: Dict[str, PipelineSim] = {
            t.name: PipelineSim(t.pipeline, t.machine, t.model_latency,
                                seed=seed + i, obs_noise=obs_noise)
            for i, t in enumerate(cluster.trainers)}

    def _on_join(self, name: str):
        # a (re)joining machine is a fresh process: no restart debt
        self.sims[name].restart_left = 0

    @property
    def oom_count(self) -> int:
        return sum(s.oom_count for s in self.sims.values())

    # ------------------------------------------------------------ tick ----
    def apply(self, falloc: FleetAllocation) -> dict:
        self._advance_events()
        state = self.machine
        self._check_falloc(falloc, state)
        per: Dict[str, dict] = {}
        tput = mem = used = 0.0
        any_oom = any_restart = False
        for name in state.active:
            sim = self.sims[name]
            eff = self._base[name] + int(falloc.grants.get(name, 0))
            if sim.machine.n_cpus != eff:
                sim.resize(eff)
            if name not in falloc.allocs:
                raise KeyError(
                    f"no allocation proposed for active trainer {name!r}")
            m = sim.apply(falloc.allocs[name])
            m["eff_cpus"] = eff
            per[name] = m
            tput += m["throughput"]
            mem += m["mem_mb"]
            used += min(m["used_cpus"], eff)
            any_oom = any_oom or m["oom"]
            any_restart = any_restart or m["restarting"]
        self.time += 1
        return {"throughput": tput, "mem_mb": mem, "used_cpus": int(used),
                "oom": any_oom, "restarting": any_restart,
                "n_active": len(state.active), "pool": self.pool,
                "per_trainer": per}


@dataclass(frozen=True)
class JobSpec:
    """One training job bidding in the pool market: a named set of
    member trainers, a bid weight (its marginal throughput is scaled by
    `weight` at auction — priority pricing), and an anti-starvation
    `floor` of pool cores it is owed whenever it has an active member."""
    name: str
    trainers: Tuple[str, ...]
    weight: float = 1.0
    floor: int = 0


@dataclass(frozen=True)
class MarketSpec(ClusterSpec):
    """A ClusterSpec whose trainers are partitioned into concurrent
    JOBS competing for the one shared elastic pool (Zhao et al.'s DSI
    setting: many training jobs, one ingestion substrate). With
    `jobs=()` it degrades to a plain ClusterSpec; with jobs, every
    trainer must belong to exactly one job. `isinstance(spec,
    ClusterSpec)` holds, so every fleet backend runs a MarketSpec
    unchanged — jobs only matter to the optimizer layer (PoolMarket)."""
    jobs: Tuple[JobSpec, ...] = ()

    def __post_init__(self):
        super().__post_init__()
        names = {t.name for t in self.trainers}
        jnames = [j.name for j in self.jobs]
        if len(set(jnames)) != len(jnames):
            raise ValueError(f"duplicate job names in {jnames}")
        seen: Dict[str, str] = {}
        for j in self.jobs:
            if j.weight <= 0:
                raise ValueError(f"job {j.name!r}: weight must be > 0")
            if j.floor < 0:
                raise ValueError(f"job {j.name!r}: floor must be >= 0")
            for t in j.trainers:
                if t not in names:
                    raise ValueError(
                        f"job {j.name!r} names unknown trainer {t!r}")
                if t in seen:
                    raise ValueError(
                        f"trainer {t!r} in jobs {seen[t]!r} and {j.name!r}")
                seen[t] = j.name
        if self.jobs and len(seen) != len(names):
            missing = sorted(names - set(seen))
            raise ValueError(f"trainers belong to no job: {missing}")
        if sum(j.floor for j in self.jobs) > self.shared_pool:
            raise ValueError("job floors exceed the shared pool")

    def job(self, name: str) -> JobSpec:
        for j in self.jobs:
            if j.name == name:
                return j
        raise KeyError(name)

    def job_of(self, trainer: str) -> Optional[JobSpec]:
        for j in self.jobs:
            if trainer in j.trainers:
                return j
        return None


def job_events(market: MarketSpec, tick: int, kind: str,
               job: str) -> Tuple[FleetEvent, ...]:
    """Expand JOB-level churn — a whole job joining or leaving the
    cluster — into one FleetEvent per member trainer at `tick` (the
    member events fire in spec order within the tick)."""
    if kind not in ("join", "leave"):
        raise ValueError(
            f"job-level churn is join/leave only, got {kind!r}")
    return tuple(FleetEvent(tick=tick, kind=kind, trainer=t)
                 for t in market.job(job).trainers)


def churn_schedule(total_ticks: int,
                   events: Sequence[Tuple[float, str, str, int]]
                   ) -> Tuple[FleetEvent, ...]:
    """Fleet analog of `resize_schedule`: events placed at fractions of the
    run. Each entry is (frac, kind, trainer, n_cpus); frac in [0, 1)."""
    return tuple(FleetEvent(tick=int(frac * total_ticks), kind=kind,
                            trainer=trainer, n_cpus=n_cpus)
                 for frac, kind, trainer, n_cpus in events)


def demo_cluster(ticks: int = 1200, pool: int = 80) -> ClusterSpec:
    """The canonical 4-machine heterogeneous fleet with churn used by the
    fig7_fleet benchmark, the fleet example, and the acceptance tests.

    Heterogeneity axes: machine size (24-96 owned CPUs), memory (6-64 GB),
    pipeline shape (two linear chains + the multi-source join DAG), and
    model demand (1/model_latency b/s). Two machines carry the production
    pathologies memory-blind policies die on: "small" saturates its model
    with a handful of CPUs (pool grants parked there are pure waste) and
    both "small" and "late" are memory-tight (6 GB), so an even split of
    the pool pushes their per-worker footprint past the physical memory
    line — the Fig. 5B OOM crash-loop, now at fleet scale. Churn: "late"
    joins a third of the way in, "big" shrinks mid-run, "small" leaves
    near the end.
    """
    from repro.data.pipeline import (criteo_pipeline, custom_pipeline,
                                     multisource_dlrm_pipeline)
    trainers = (
        TrainerSpec("big", criteo_pipeline(),
                    MachineSpec(n_cpus=96, mem_mb=65536.0),
                    model_latency=0.02),
        TrainerSpec("mid", custom_pipeline(),
                    MachineSpec(n_cpus=48, mem_mb=32768.0),
                    model_latency=0.04),
        TrainerSpec("small", multisource_dlrm_pipeline(),
                    MachineSpec(n_cpus=24, mem_mb=6144.0),
                    model_latency=0.2),
        TrainerSpec("late", criteo_pipeline(),
                    MachineSpec(n_cpus=64, mem_mb=6144.0),
                    model_latency=0.025, start_active=False),
    )
    events = churn_schedule(ticks, [
        (1 / 3, "join", "late", 0),
        (0.55, "resize", "big", 48),
        (0.80, "leave", "small", 0),
    ])
    return ClusterSpec("demo_fleet4", trainers, shared_pool=pool,
                       events=events)


def big_cluster(n_machines: int = 32, ticks: int = 1200,
                pool: Optional[int] = None, n_jobs: int = 3,
                seed: int = 0) -> MarketSpec:
    """A 32+ machine heterogeneous multi-job cluster (the fig_market
    scale target): per-machine core-count and socket-speed skew à la
    NUMA heterogeneity (Kalamkar et al.), three pipeline shapes, varied
    model demand, memory-tight stragglers, and churn on every axis.
    Deterministic in `seed` — the spec feeds golden-trace tests.

    Speed skew is realized by scaling every stage's true cost by a
    per-machine factor in [0.6, 1.5] (a slow socket makes the SAME
    pipeline more expensive), which also gives each trainer a distinct
    StageGraph identity for the oracle's cache. Trainers are
    partitioned round-robin into `n_jobs` jobs with skewed weights
    (2.0 / 1.0 / 0.5) and small anti-starvation floors.
    """
    from repro.data.pipeline import (criteo_pipeline, custom_pipeline,
                                     multisource_dlrm_pipeline)
    if n_machines < n_jobs:
        raise ValueError("need at least one machine per job")
    rng = np.random.RandomState(seed)
    makers = (criteo_pipeline, custom_pipeline, multisource_dlrm_pipeline)
    core_classes = (16, 24, 32, 48, 64, 96)
    mem_classes = (6144.0, 16384.0, 32768.0, 65536.0)
    latencies = (0.02, 0.025, 0.04, 0.1, 0.2)
    trainers = []
    for i in range(n_machines):
        base = makers[int(rng.randint(len(makers)))]()
        speed = float(0.6 + 0.9 * rng.rand())
        stages = tuple(dataclasses.replace(s, cost=float(s.cost * speed))
                       for s in base.stages)
        pipe = base.replace(name=f"{base.name}@m{i:02d}", stages=stages)
        trainers.append(TrainerSpec(
            name=f"m{i:02d}", pipeline=pipe,
            machine=MachineSpec(
                n_cpus=int(core_classes[rng.randint(len(core_classes))]),
                mem_mb=float(mem_classes[rng.randint(len(mem_classes))])),
            model_latency=float(latencies[rng.randint(len(latencies))]),
            start_active=bool(rng.rand() > 0.15)))
    owned = sum(t.machine.n_cpus for t in trainers)
    if pool is None:
        pool = int(0.25 * owned)
    jobs = tuple(
        JobSpec(name=f"job{j}",
                trainers=tuple(t.name for k, t in enumerate(trainers)
                               if k % n_jobs == j),
                weight=float((2.0, 1.0, 0.5)[j % 3]),
                floor=int((4, 2, 0)[j % 3]))
        for j in range(n_jobs))
    sched = []
    for t in trainers:                      # late joiners arrive mid-run
        if not t.start_active:
            sched.append((float(0.15 + 0.5 * rng.rand()), "join",
                          t.name, 0))
    active = [t for t in trainers if t.start_active]
    for t in [active[int(i)] for i in
              rng.choice(len(active), size=min(3, len(active)),
                         replace=False)]:
        sched.append((float(0.30 + 0.40 * rng.rand()), "resize", t.name,
                      max(8, t.machine.n_cpus // 2)))
    for t in [active[int(i)] for i in
              rng.choice(len(active), size=min(3, len(active)),
                         replace=False)]:
        sched.append((float(0.55 + 0.35 * rng.rand()), "leave", t.name, 0))
    sched.append((0.5, "pool", "", int(pool * 0.75)))
    events = churn_schedule(ticks, sched)
    return MarketSpec(name=f"big_fleet{n_machines}",
                      trainers=tuple(trainers), shared_pool=int(pool),
                      events=events, jobs=jobs)
