"""Double-buffered host->device feed: overlap ingestion with compute.

Wraps any batch iterator; while the model runs step t, batch t+1 is
already being transferred (jax.device_put is async). On a pod, each host
feeds only its shard of the global batch (`shard_slice`). This is the
"prefetch to accelerator" stage of the paper's pipeline, realized for JAX.
"""
from __future__ import annotations

import collections
from typing import Callable, Iterator, Optional

import jax
import numpy as np


def device_prefetch(it: Iterator, depth: int = 2, sharding=None):
    """Yields device-resident batches, keeping `depth` in flight."""
    buf = collections.deque()

    def put(batch):
        if sharding is not None:
            return jax.tree_util.tree_map(
                lambda x: jax.device_put(x, sharding), batch)
        return jax.tree_util.tree_map(jax.device_put, batch)

    try:
        for _ in range(depth):
            buf.append(put(next(it)))
    except StopIteration:
        pass
    while buf:
        out = buf.popleft()
        try:
            buf.append(put(next(it)))
        except StopIteration:
            pass
        yield out


def shard_slice(batch: dict, host_id: int, n_hosts: int) -> dict:
    """Host's slice of a global batch (leading dim split)."""
    def sl(x):
        n = x.shape[0]
        per = n // n_hosts
        return x[host_id * per:(host_id + 1) * per]
    return {k: sl(v) for k, v in batch.items()}
