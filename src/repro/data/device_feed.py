"""Double-buffered host->device feed: overlap ingestion with compute.

Wraps any batch iterator; while the model runs step t, batch t+1 is
already being transferred (jax.device_put is async). On a pod, each host
feeds only its shard of the global batch (`shard_slice`). This is the
"prefetch to accelerator" stage of the paper's pipeline, realized for JAX.
"""
from __future__ import annotations

import collections
import time
from typing import Callable, Dict, Iterator, Optional

import jax
import numpy as np


def device_prefetch(it: Iterator, depth: int = 2, sharding=None):
    """Yields device-resident batches, keeping `depth` in flight."""
    buf = collections.deque()

    def put(batch):
        if sharding is not None:
            return jax.tree_util.tree_map(
                lambda x: jax.device_put(x, sharding), batch)
        return jax.tree_util.tree_map(jax.device_put, batch)

    try:
        for _ in range(depth):
            buf.append(put(next(it)))
    except StopIteration:
        pass
    while buf:
        out = buf.popleft()
        try:
            buf.append(put(next(it)))
        except StopIteration:
            pass
        yield out


class MeteredFeed:
    """Iterator wrapper that times how long the consumer blocks in
    `next()` — the host->device boundary where accelerator idle is born.

    Clock discipline: the stall clock runs ONLY inside `__next__`, on
    `time.monotonic`. Everything outside — the jitted train step, the
    optimizer tick, checkpointing — is by definition device/driver time
    and never pollutes the stall number. Because `device_prefetch` keeps
    `depth` batches in flight, a stall here means the pipeline fell
    behind by more than the prefetch buffer: exactly the starvation the
    paper's device-idle metric charges to ingestion.

    `counters()` returns monotonically increasing totals
    (`batches`, `stall_s`, plus a `time` timestamp); consumers
    (FeedBackend.measure) difference two snapshots to get a window.
    """

    def __init__(self, it: Iterator):
        self._it = it
        self.batches = 0
        self.stall_s = 0.0

    def __iter__(self):
        return self

    def __next__(self):
        t0 = time.monotonic()
        try:
            out = next(self._it)
        finally:
            self.stall_s += time.monotonic() - t0
        self.batches += 1
        return out

    def counters(self) -> Dict[str, float]:
        return {"batches": float(self.batches),
                "stall_s": float(self.stall_s),
                "time": time.monotonic()}


def make_train_feed(pipe, *, depth: int = 2, sharding=None,
                    timeout: float = 60.0) -> MeteredFeed:
    """The proc->device bridge: compose `pipe.get_batch()` (model-ready
    numpy batches out of the tuned ProcessPipeline) through
    `device_prefetch` (depth batches resident on device, transfer
    overlapped with compute) into a `MeteredFeed` (stall accounting at
    the boundary). The returned iterator is what the train loop consumes
    and what FeedBackend meters."""
    def batches():
        while True:
            yield pipe.get_batch(timeout=timeout)
    return MeteredFeed(device_prefetch(batches(), depth=depth,
                                       sharding=sharding))


def shard_slice(batch: dict, host_id: int, n_hosts: int) -> dict:
    """Host's slice of a global batch (leading dim split)."""
    def sl(x):
        n = x.shape[0]
        per = n // n_hosts
        return x[host_id * per:(host_id + 1) * per]
    return {k: sl(v) for k, v in batch.items()}
