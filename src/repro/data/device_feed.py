"""Double-buffered host->device feed: overlap ingestion with compute.

Wraps any batch iterator; while the model runs step t, batch t+1 is
already being transferred (jax.device_put is async). On a pod, each host
feeds only its shard of the global batch (`shard_slice`). This is the
"prefetch to accelerator" stage of the paper's pipeline, realized for JAX.

The prefetcher is a background producer THREAD feeding a bounded buffer
(ISSUE 7 bugfix). The original generator version refilled eagerly before
yielding — `buf.append(put(next(it)))` ran on the CONSUMER's stack, so
every `__next__` blocked on a synchronous upstream pull regardless of
`depth`, and `MeteredFeed.stall_s` charged the full producer latency to
the device boundary. With a real producer thread, `depth` batches are
genuinely in flight and a stall only accrues when the buffer is empty —
i.e. the pipeline fell behind by more than the prefetch budget.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Dict, Iterator, Optional

import jax


class ShardError(ValueError):
    """A global batch cannot be split evenly across hosts."""


_SENTINEL = object()


class DevicePrefetcher:
    """Iterator of device-resident batches with `depth` genuinely in
    flight: a daemon producer thread pulls from `it`, transfers via
    `jax.device_put` (async — the transfer overlaps compute), and parks
    results in a buffer. A counting semaphore of `depth` permits bounds
    the in-flight set: the producer acquires a permit per pull, the
    consumer releases one per yield, so at most `depth` batches sit
    between the upstream iterator and the consumer.

    Shutdown: upstream exhaustion or error lands a sentinel in the
    buffer (the error re-raises on the consumer's stack); `close()`
    stops the producer and joins it. Iterating to StopIteration also
    joins the thread, so the common full-drain path needs no explicit
    close.
    """

    def __init__(self, it: Iterator, depth: int = 2, sharding=None,
                 on_close: Optional[Callable[[], None]] = None):
        if depth < 1:
            raise ValueError("depth must be >= 1")
        self._it = it
        self._sharding = sharding
        self._on_close = on_close
        self._q: "queue.Queue" = queue.Queue()
        self._sem = threading.Semaphore(depth)
        self._stop = threading.Event()
        self._err: Optional[BaseException] = None
        self._done = False
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()

    def _put(self, batch):
        if self._sharding is not None:
            return jax.tree_util.tree_map(
                lambda x: jax.device_put(x, self._sharding), batch)
        return jax.tree_util.tree_map(jax.device_put, batch)

    def _produce(self):
        try:
            while not self._stop.is_set():
                # bounded in-flight: wait for a free permit, but keep
                # checking for close() so shutdown never deadlocks
                if not self._sem.acquire(timeout=0.1):
                    continue
                if self._stop.is_set():
                    break
                try:
                    batch = next(self._it)
                except StopIteration:
                    break
                self._q.put(self._put(batch))
        except BaseException as e:  # surface on the consumer's stack
            self._err = e
        finally:
            self._q.put(_SENTINEL)

    def __iter__(self):
        return self

    def __next__(self):
        if self._done:
            raise StopIteration
        # blocks ONLY when the buffer is genuinely empty — the stall
        # MeteredFeed should charge to ingestion
        item = self._q.get()
        if item is _SENTINEL:
            self._done = True
            self._thread.join()
            if self._err is not None:
                err, self._err = self._err, None
                raise err
            raise StopIteration
        self._sem.release()
        return item

    def close(self, timeout: float = 2.0):
        """Stop the producer and join it. Idempotent."""
        if self._on_close is not None:
            self._on_close()
        self._stop.set()
        self._sem.release()  # unblock a producer waiting on a permit
        self._thread.join(timeout)


def device_prefetch(it: Iterator, depth: int = 2, sharding=None):
    """Yields device-resident batches, keeping `depth` in flight —
    production happens on a background thread (see DevicePrefetcher)."""
    return DevicePrefetcher(it, depth=depth, sharding=sharding)


class MeteredFeed:
    """Iterator wrapper that times how long the consumer blocks in
    `next()` — the host->device boundary where accelerator idle is born.

    Clock discipline: the stall clock runs ONLY inside `__next__`, on
    `time.monotonic`. Everything outside — the jitted train step, the
    optimizer tick, checkpointing — is by definition device/driver time
    and never pollutes the stall number. Because `device_prefetch` keeps
    `depth` batches in flight, a stall here means the pipeline fell
    behind by more than the prefetch buffer: exactly the starvation the
    paper's device-idle metric charges to ingestion.

    `counters()` returns monotonically increasing totals
    (`batches`, `stall_s`, plus a `time` timestamp); consumers
    (FeedBackend.measure) difference two snapshots to get a window.
    """

    def __init__(self, it: Iterator):
        self._it = it
        self.batches = 0
        self.stall_s = 0.0

    def __iter__(self):
        return self

    def __next__(self):
        t0 = time.monotonic()
        try:
            out = next(self._it)
        finally:
            self.stall_s += time.monotonic() - t0
        self.batches += 1
        return out

    def counters(self) -> Dict[str, float]:
        return {"batches": float(self.batches),
                "stall_s": float(self.stall_s),
                "time": time.monotonic()}

    def close(self, timeout: float = 2.0):
        close = getattr(self._it, "close", None)
        if close is not None:
            close(timeout)


def make_train_feed(pipe, *, depth: int = 2, sharding=None,
                    timeout: float = 60.0) -> MeteredFeed:
    """The proc->device bridge: compose `pipe.get_batch()` (model-ready
    numpy batches out of the tuned ProcessPipeline) through
    `device_prefetch` (depth batches resident on device, transfer
    overlapped with compute) into a `MeteredFeed` (stall accounting at
    the boundary). The returned iterator is what the train loop consumes
    and what FeedBackend meters; `feed.close()` stops the producer
    thread cleanly (call it before `pipe.shutdown()`)."""
    stop = threading.Event()

    def batches():
        waited = 0.0
        while not stop.is_set():
            try:
                # short poll so close() can interrupt a blocked pull;
                # StopIteration from an EOS pipe must not leak out of a
                # generator (PEP 479) — translate it to a clean return
                yield pipe.get_batch(timeout=0.25)
                waited = 0.0
            except queue.Empty:
                waited += 0.25
                if waited >= timeout:
                    raise
            except StopIteration:
                return

    feed = MeteredFeed(DevicePrefetcher(batches(), depth=depth,
                                        sharding=sharding,
                                        on_close=stop.set))
    return feed


def shard_slice(batch: dict, host_id: int, n_hosts: int) -> dict:
    """Host's slice of a global batch (leading dim split).

    Raises ShardError when the batch cannot be split exactly — a silent
    remainder drop (or an empty slice when n < n_hosts) corrupts global
    batch size downstream where nothing is positioned to notice.
    """
    if not 0 <= host_id < n_hosts:
        raise ShardError(
            f"host_id {host_id} out of range for {n_hosts} hosts")

    def sl(k, x):
        n = x.shape[0]
        if n < n_hosts:
            raise ShardError(
                f"batch field {k!r} has {n} rows < {n_hosts} hosts: "
                "every host would receive an empty slice")
        if n % n_hosts != 0:
            raise ShardError(
                f"batch field {k!r} has {n} rows, not divisible by "
                f"{n_hosts} hosts: {n % n_hosts} rows would be dropped")
        per = n // n_hosts
        return x[host_id * per:(host_id + 1) * per]
    return {k: sl(k, v) for k, v in batch.items()}
