"""Threaded StageGraph executor: the REAL data path InTune tunes live.

Per-stage worker pools over one bounded queue per graph edge (tf.data-
isomorphic knob surface: workers per stage, prefetch buffer MB). Source
stages (no inputs) pull from their source fn; join stages (several
inputs) gather one item from EACH input edge — the gather is serialized
per stage so multi-worker joins keep the input streams aligned — and
fan-out stages broadcast their output onto every outgoing edge. The sink
stage feeds a dedicated output queue whose bound realizes the prefetch
budget (`set_allocation` re-bounds it live). Pools resize on the fly —
`set_allocation` is what the controller's live_tick drives. Rate meters
(EWMA batches/s per stage) provide the Table-2 observations.

On this 1-CPU container the executor proves correctness and the control
plumbing (quickstart example + tests); the throughput *numbers* for the
paper's figures come from the calibrated simulator (DESIGN.md §3).
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence


from repro.data.pipeline import StageGraph
from repro.data.simulator import MachineSpec

_STOP = object()


def _set_maxsize(q: "queue.Queue", n: int):
    """Re-bound a live queue: maxsize is only read under q.mutex at
    put/get time, so adjusting it there is race-free."""
    with q.mutex:
        q.maxsize = n
        q.not_full.notify_all()


class _RateMeter:
    """EWMA batches/s meter shared by the thread and process planes.

    `mark()` feeds it one event (threaded workers); `mark_many(n)` feeds
    a counter delta (the process plane syncs shared delivered-counters
    into the same meter). `rate` is decayed ON READ: a meter whose EWMA
    only updated at mark time would report its last healthy rate forever
    once the stage starved or died, going stale exactly when the
    Table-2 observation matters most — so a read caps the EWMA at
    `1 / (time since the last mark)`, which is a no-op while marks are
    on schedule and falls toward 0 for a stalled stage.
    """

    def __init__(self, alpha: float = 0.2):
        self.alpha = alpha
        self._ewma = 0.0
        self._last: Optional[float] = None
        self.count = 0
        self._lock = threading.Lock()

    def mark(self):
        self.mark_many(1)

    def mark_many(self, n: int, now: Optional[float] = None):
        """Record `n` events since the previous mark (n=1 is a plain
        mark; the process plane passes shared-counter deltas)."""
        if n <= 0:
            return
        if now is None:
            now = time.monotonic()
        with self._lock:
            self.count += n
            if self._last is not None:
                dt = max(now - self._last, 1e-6)
                inst = n / dt
                self._ewma = (1 - self.alpha) * self._ewma \
                    + self.alpha * inst
            self._last = now

    @property
    def rate(self) -> float:
        with self._lock:
            if self._last is None:
                return 0.0
            overdue = time.monotonic() - self._last
            if overdue <= 1e-9:
                return self._ewma
            # while healthy the gap since the last mark is < 1/rate, so
            # the cap is inert; a starved/dead stage decays as 1/overdue
            return min(self._ewma, 1.0 / overdue)


class _StagePool:
    """Resizable worker pool for one graph stage.

    in_qs == []   : source — fn() -> item, None = end of stream.
    len(in_qs) 1+ : fn(*items) -> item; None output = filtered (dropped).
    Output is broadcast to every out queue (fan-out edges).

    EOS caveat: with a multi-worker source over a finite stream, a sibling
    mid-produce when another worker observes EOS may enqueue its item
    after the _STOP sentinel — up to workers-1 trailing items can be
    dropped at end of stream (infinite training streams never hit this).
    """

    def __init__(self, name: str, fn: Callable, in_qs: Sequence,
                 out_qs: Sequence, workers: int = 1,
                 hard_stop: Optional[threading.Event] = None):
        self.name = name
        self.fn = fn
        self.in_qs = list(in_qs)
        self.out_qs = list(out_qs)
        self.meter = _RateMeter()
        self.threads: List[threading.Thread] = []
        self._stop_flags: List[threading.Event] = []
        # pipeline-wide teardown. A worker's own flag is a SOFT stop
        # (resize-down): it still delivers its in-flight item so nothing
        # is lost mid-stream. Only the hard stop aborts blocked puts.
        self._hard_stop = hard_stop if hard_stop is not None \
            else threading.Event()
        # joins gather one item per input under this lock so concurrent
        # workers can't interleave (item i of stream A with item j of B);
        # _partial stashes a gather interrupted by a worker stop (resize-
        # down) so the next worker resumes it instead of dropping items
        self._gather_lock = threading.Lock() if len(self.in_qs) > 1 else None
        self._partial: List = []
        self._stop_sent = threading.Event()
        self._retired: List[threading.Thread] = []
        self.resize(workers)

    # --------------------------------------------------------- plumbing ---
    def _send_stop(self):
        if not self._stop_sent.is_set():
            self._stop_sent.set()
            for q in self.out_qs:
                self._put(q, _STOP)

    def _get(self, q, stop: threading.Event):
        while not stop.is_set() and not self._stop_sent.is_set() \
                and not self._hard_stop.is_set():
            try:
                return q.get(timeout=0.1)
            except queue.Empty:
                continue
        return None

    def _put(self, q, item):
        while not self._hard_stop.is_set():
            try:
                q.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def _gather(self, stop: threading.Event):
        """One item from each input queue (aligned for joins). Returns the
        arg list, _STOP at end of stream, or None if told to stop."""
        # No _STOP re-put for siblings here: _send_stop sets _stop_sent,
        # which every sibling's _get polls, so they exit on their own — a
        # blocking re-put into a full queue would wedge the stage instead.
        if self._gather_lock is None:
            item = self._get(self.in_qs[0], stop)
            if item is None:
                return None
            if item is _STOP:
                return _STOP
            return [item]
        with self._gather_lock:
            items = self._partial
            for q in self.in_qs[len(items):]:
                item = self._get(q, stop)
                if item is None:
                    self._partial = items   # resume here next gather
                    return None
                if item is _STOP:
                    return _STOP
                items.append(item)
            self._partial = []
            return items

    def _worker(self, stop: threading.Event):
        while not stop.is_set() and not self._hard_stop.is_set():
            if not self.in_qs:                      # source stage
                if self._stop_sent.is_set():        # a sibling hit EOS
                    return
                out = self.fn()
                if out is None:
                    self._send_stop()
                    return
            else:
                got = self._gather(stop)
                if got is None:
                    if self._stop_sent.is_set():
                        return
                    continue
                if got is _STOP:
                    self._send_stop()
                    return
                out = self.fn(*got)
                if out is None:                     # filtered item
                    continue
            delivered = True
            for q in self.out_qs:
                delivered = self._put(q, out) and delivered
            if delivered:
                self.meter.mark()

    # ---------------------------------------------------------- control ---
    def resize(self, n: int):
        n = max(1, int(n))
        while len(self.threads) < n:
            stop = threading.Event()
            t = threading.Thread(target=self._worker, args=(stop,),
                                 daemon=True)
            t.start()
            self.threads.append(t)
            self._stop_flags.append(stop)
        while len(self.threads) > n:
            # SOFT stop: the worker delivers its in-flight item, then
            # exits; keep the handle so teardown can join it (leak
            # check). Handles that already exited need no join — prune
            # them so per-tick re-allocation can't grow this unboundedly.
            self._retired = [t for t in self._retired if t.is_alive()]
            self._stop_flags.pop().set()
            self._retired.append(self.threads.pop())

    @property
    def n_workers(self) -> int:
        return len(self.threads)

    def stop(self):
        for f in self._stop_flags:
            f.set()

    def join(self, timeout: float = 2.0) -> bool:
        """Join every thread this pool ever started (live + retired).
        Returns True when all of them exited within the deadline."""
        deadline = time.monotonic() + timeout
        ok = True
        for t in self.threads + self._retired:
            t.join(timeout=max(0.0, deadline - time.monotonic()))
            ok = ok and not t.is_alive()
        return ok


class ThreadedPipeline:
    """Runs a StageGraph with real threads; get_batch() feeds the trainer.

    Two construction forms:
      - DAG form: ThreadedPipeline(spec, fns={stage_name: fn}) — source
        fns take no args, a join stage's fn takes one arg per input (in
        spec order), every other fn takes one.
      - legacy linear form: ThreadedPipeline(spec, source_fn, stage_fns)
        with one fn per non-source stage of a linear chain.

    `machine` sizes the stats() observation contract (mem_frac, free_cpus)
    the same way PipelineEnv.observe sizes the simulator's.
    """

    def __init__(self, spec: StageGraph, source_fn: Optional[Callable] = None,
                 stage_fns: Optional[Sequence[Callable]] = None,
                 queue_depth: int = 16, item_mb: Optional[float] = None,
                 *, fns: Optional[Dict[str, Callable]] = None,
                 machine: Optional[MachineSpec] = None):
        if fns is None:
            assert spec.is_linear, \
                "positional (source_fn, stage_fns) form is for linear " \
                "chains; pass fns={stage_name: fn} for a DAG"
            assert source_fn is not None and stage_fns is not None
            assert len(stage_fns) == spec.n_stages - 1, \
                "one fn per non-source stage"
            fns = {spec.stages[0].name: source_fn}
            fns.update({s.name: fn
                        for s, fn in zip(spec.stages[1:], stage_fns)})
        missing = [s.name for s in spec.stages if s.name not in fns]
        assert not missing, f"missing stage fns: {missing}"
        self.spec = spec
        self.item_mb = item_mb if item_mb is not None else spec.batch_mb
        self.machine = machine if machine is not None else MachineSpec()
        self.prefetch_mb = 2 * self.item_mb
        # one bounded queue per graph edge + the sink's output queue,
        # whose bound realizes the prefetch budget
        self.edge_queues: Dict[tuple, queue.Queue] = {
            e: queue.Queue(maxsize=queue_depth) for e in spec.edges}
        # the output bound IS the prefetch budget, from construction on
        self.out_q = queue.Queue(maxsize=self._prefetch_depth())
        self._eos = False
        self._hard_stop = threading.Event()
        self.pools: List[_StagePool] = []
        for i, st in enumerate(spec.stages):
            in_qs = [self.edge_queues[(p, i)] for p in spec.parents(i)]
            out_qs = [self.edge_queues[(i, c)] for c in spec.children(i)]
            if i == spec.sink:
                out_qs = [self.out_q]
            self.pools.append(_StagePool(st.name, fns[st.name], in_qs,
                                         out_qs, workers=1,
                                         hard_stop=self._hard_stop))
        self.out_meter = _RateMeter()

    def _prefetch_depth(self) -> int:
        return max(1, int(self.prefetch_mb / max(self.item_mb, 1e-6)))

    # ----------------------------------------------------------- control --
    def worker_counts(self) -> List[int]:
        return [p.n_workers for p in self.pools]

    def set_allocation(self, workers, prefetch_mb: float):
        for pool, w in zip(self.pools, workers):
            pool.resize(int(w))
        self.prefetch_mb = float(prefetch_mb)
        # the agent's prefetch knob IS the output queue bound: re-bound it
        # live so a shrunk budget back-pressures the sink immediately
        _set_maxsize(self.out_q, self._prefetch_depth())

    @property
    def prefetch_depth(self) -> int:
        return self.out_q.maxsize

    def stats(self) -> dict:
        rates = [p.meter.rate for p in self.pools]
        lat = [1.0 / r if r > 0 else 10.0 for r in rates]
        edge_sizes = [q.qsize() for q in self.edge_queues.values()]
        qsizes = edge_sizes + [self.out_q.qsize()]
        # prefetch budget charged once (the simulator's contract); items
        # sitting in the output queue live inside that budget
        mem_mb = sum(edge_sizes) * self.item_mb + self.prefetch_mb
        return {
            "throughput": self.out_meter.rate,
            "stage_rate": rates,
            "stage_latency": lat,
            "queue_sizes": qsizes,
            "workers": self.worker_counts(),
            "prefetch_mb": self.prefetch_mb,
            "mem_frac": mem_mb / self.machine.mem_mb,
            "free_cpus": max(0, self.machine.n_cpus
                             - sum(self.worker_counts())),
            "counts": [p.meter.count for p in self.pools],
        }

    # ------------------------------------------------------ measurement --
    def counters(self) -> dict:
        """Monotonic batch counters + timestamp for measured-throughput
        windows. A window rate is a counter DELTA over the measured
        elapsed between two snapshots — free of the EWMA meters' wall-
        clock decay state, so it stays rank-stable under CI scheduler
        contention. `delivered` counts batches the sink stage put into
        the output queue; `consumed` counts batches handed to the
        trainer via get_batch()."""
        return {"delivered": self.pools[self.spec.sink].meter.count,
                "consumed": self.out_meter.count,
                "time": time.monotonic()}

    @staticmethod
    def window_rate(before: dict, after: dict, key: str = "consumed") -> float:
        """Batches/s between two counters() snapshots."""
        dt = max(after["time"] - before["time"], 1e-9)
        return (after[key] - before[key]) / dt

    # ----------------------------------------------------------- teardown --
    def shutdown(self, drain: bool = True, timeout: float = 5.0) -> dict:
        """Graceful teardown honoring the soft/hard stop split.

        Soft-stops every pool first (each worker delivers its in-flight
        item — a churn-driven leave/resize must not lose batches mid-
        stream), drains batches still parked in the output queue so the
        sink workers can flush, then hard-stops and joins every thread
        this pipeline ever started. Returns the accounting a clean leave
        is judged on: `dropped` = delivered - consumed - drained is 0
        when no sink-delivered batch was lost. `drain=False` models a
        crash (OOM kill): no drain pass, in-flight batches are lost.
        """
        deadline = time.monotonic() + timeout
        for p in self.pools:
            p.stop()
        drained = 0
        sink_pool = self.pools[self.spec.sink]
        if drain:
            # keep emptying the output queue until the sink workers have
            # flushed their in-flight items and exited — a full queue
            # would otherwise wedge their final (soft-stopped) delivery
            while time.monotonic() < deadline:
                try:
                    if self.out_q.get_nowait() is not _STOP:
                        drained += 1
                except queue.Empty:
                    if not any(t.is_alive() for t in sink_pool.threads):
                        break
                    time.sleep(0.005)
        self._hard_stop.set()
        # join BEFORE the final sweep: a worker still blocked in a put
        # could land one more item the moment the sweep makes room
        joined = True
        for p in self.pools:
            joined = p.join(max(0.1, deadline - time.monotonic())) and joined
        while True:
            try:
                if self.out_q.get_nowait() is not _STOP:
                    drained += 1
            except queue.Empty:
                break
        delivered = sink_pool.meter.count
        consumed = self.out_meter.count
        return {"delivered": delivered, "consumed": consumed,
                "drained": drained, "joined": joined,
                "dropped": (max(0, delivered - consumed - drained)
                            if drain else 0)}

    # ------------------------------------------------------------ output --
    def get_batch(self, timeout: float = 10.0):
        if self._eos and self.out_q.empty():
            raise StopIteration
        item = self.out_q.get(timeout=timeout)
        if item is _STOP:
            self._eos = True
            try:
                self.out_q.put_nowait(_STOP)   # for sibling consumers
            except queue.Full:
                pass
            raise StopIteration
        self.out_meter.mark()
        return item

    def stop(self):
        self._hard_stop.set()
        for p in self.pools:
            p.stop()
