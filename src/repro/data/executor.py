"""Threaded pipeline executor: the REAL data path InTune tunes live.

Per-stage worker pools over bounded queues (tf.data-isomorphic knob
surface: workers per stage, prefetch buffer MB). Pools resize on the fly —
`set_allocation` is what the controller's live_tick drives. Rate meters
(EWMA batches/s per stage) provide the Table-2 observations.

On this 1-CPU container the executor proves correctness and the control
plumbing (quickstart example + tests); the throughput *numbers* for the
paper's figures come from the calibrated simulator (DESIGN.md §3).
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.data.pipeline import PipelineSpec

_STOP = object()


class _RateMeter:
    def __init__(self, alpha: float = 0.2):
        self.alpha = alpha
        self.rate = 0.0
        self._last: Optional[float] = None
        self.count = 0
        self._lock = threading.Lock()

    def mark(self):
        now = time.monotonic()
        with self._lock:
            self.count += 1
            if self._last is not None:
                dt = max(now - self._last, 1e-6)
                inst = 1.0 / dt
                self.rate = (1 - self.alpha) * self.rate + self.alpha * inst
            self._last = now


class _StagePool:
    """Resizable worker pool: in_q -> fn -> out_q."""

    def __init__(self, name: str, fn: Callable, in_q, out_q,
                 workers: int = 1):
        self.name = name
        self.fn = fn
        self.in_q, self.out_q = in_q, out_q
        self.meter = _RateMeter()
        self.threads: List[threading.Thread] = []
        self._stop_flags: List[threading.Event] = []
        self.resize(workers)

    def _worker(self, stop: threading.Event):
        while not stop.is_set():
            try:
                item = self.in_q.get(timeout=0.1)
            except queue.Empty:
                continue
            if item is _STOP:
                self.in_q.put(_STOP)  # propagate to siblings
                return
            out = self.fn(item)
            if out is not None:
                self.out_q.put(out)
                self.meter.mark()

    def resize(self, n: int):
        n = max(1, int(n))
        while len(self.threads) < n:
            stop = threading.Event()
            t = threading.Thread(target=self._worker, args=(stop,),
                                 daemon=True)
            t.start()
            self.threads.append(t)
            self._stop_flags.append(stop)
        while len(self.threads) > n:
            self._stop_flags.pop().set()
            self.threads.pop()

    @property
    def n_workers(self) -> int:
        return len(self.threads)

    def stop(self):
        for f in self._stop_flags:
            f.set()


class ThreadedPipeline:
    """source_fn() -> item; stage fns: item -> item. Last queue feeds the
    training loop via get_batch()."""

    def __init__(self, spec: PipelineSpec, source_fn: Callable,
                 stage_fns: Sequence[Callable], queue_depth: int = 16,
                 item_mb: Optional[float] = None):
        assert len(stage_fns) == spec.n_stages - 1, \
            "one fn per non-source stage"
        self.spec = spec
        self.item_mb = item_mb if item_mb is not None else spec.batch_mb
        self.queues = [queue.Queue(maxsize=queue_depth)
                       for _ in range(spec.n_stages)]
        self.prefetch_mb = 2 * self.item_mb
        self._src_stop = threading.Event()
        self._src_meter = _RateMeter()
        self._src_fn = source_fn
        self._src_threads: List[threading.Thread] = []
        self._src_flags: List[threading.Event] = []
        self._resize_source(1)
        self.pools = []
        for i, fn in enumerate(stage_fns):
            self.pools.append(_StagePool(
                spec.stages[i + 1].name, fn, self.queues[i],
                self.queues[i + 1], workers=1))
        self.out_meter = _RateMeter()

    # ------------------------------------------------------------ source --
    def _src_worker(self, stop):
        while not stop.is_set() and not self._src_stop.is_set():
            item = self._src_fn()
            if item is None:
                self.queues[0].put(_STOP)
                return
            self.queues[0].put(item)
            self._src_meter.mark()

    def _resize_source(self, n: int):
        n = max(1, int(n))
        while len(self._src_threads) < n:
            stop = threading.Event()
            t = threading.Thread(target=self._src_worker, args=(stop,),
                                 daemon=True)
            t.start()
            self._src_threads.append(t)
            self._src_flags.append(stop)
        while len(self._src_threads) > n:
            self._src_flags.pop().set()
            self._src_threads.pop()

    # ----------------------------------------------------------- control --
    def worker_counts(self) -> List[int]:
        return [len(self._src_threads)] + [p.n_workers for p in self.pools]

    def set_allocation(self, workers, prefetch_mb: float):
        self._resize_source(int(workers[0]))
        for pool, w in zip(self.pools, workers[1:]):
            pool.resize(int(w))
        self.prefetch_mb = float(prefetch_mb)
        depth = max(1, int(prefetch_mb / max(self.item_mb, 1e-6)))
        # bounded final queue realizes the prefetch budget
        self._prefetch_depth = depth

    def stats(self) -> dict:
        rates = [self._src_meter.rate] + [p.meter.rate for p in self.pools]
        lat = [1.0 / r if r > 0 else 10.0 for r in rates]
        qsizes = [q.qsize() for q in self.queues]
        mem_mb = sum(qsizes) * self.item_mb + self.prefetch_mb
        return {
            "throughput": self.out_meter.rate,
            "stage_rate": rates,
            "stage_latency": lat,
            "queue_sizes": qsizes,
            "workers": self.worker_counts(),
            "prefetch_mb": self.prefetch_mb,
            "mem_frac": mem_mb / 65536.0,
            "free_cpus": 0,
            "counts": [self._src_meter.count]
            + [p.meter.count for p in self.pools],
        }

    # ------------------------------------------------------------ output --
    def get_batch(self, timeout: float = 10.0):
        item = self.queues[-1].get(timeout=timeout)
        if item is _STOP:
            raise StopIteration
        self.out_meter.mark()
        return item

    def stop(self):
        self._src_stop.set()
        for f in self._src_flags:
            f.set()
        for p in self.pools:
            p.stop()
