"""Live serial_frac calibration: fit the Amdahl curve to measured rates.

The analytic plane (`PipelineSim`, the oracle, the RL agent's pretrain
environment) models every stage with two numbers — true cost and Amdahl
serial fraction — that, until this module, were DECLARED in the spec.
This is the gap tf.data-style autotuners fall into: when the analytic
model diverges from measured behavior, the planner optimizes the wrong
pipeline (InTune §3.2; Plumber). Calibration closes the loop with
measurement:

  1. For each stage, run its work function standalone in a
     `ProcessPipeline` (one single-stage graph per stage: isolation, so
     one stage's CPU demand cannot contend with another's measurement)
     and sweep the worker pool 1..k, reading the delivered-item count
     and the pool's CPU-clock delta (`/proc/<pid>/stat`) over each
     window.
  2. Fit on the CPU-NORMALIZED service curve `rate_hat(a) =
     a / (cpu_delta / items)`, not on wall rates. Wall rates on a
     shared or virtualized host swing with hypervisor steal and
     burstable-CPU throttling (2x second-to-second swings observed);
     per-item CPU is stable because the spin work functions burn
     against the SAME kernel cputime clock the measurement reads
     (`proc_executor._burn`), so designed cycle and measured cycle
     share one unit by construction. Worker idle (lock waits, queue
     waits) is excluded automatically — CPU clocks only advance while
     a worker runs. Raw wall rates are the fallback where the host
     exposes no per-process CPU clock.
  3. Fit `rate(a) = 1 / (cost * (s + (1 - s) / a))`. The fit is a
     linear regression in disguise: `1/rate` is linear in `1/a` with
     intercept `cost * s` and slope `cost * (1 - s)`, so the estimator
     is closed-form, and per-item constant overheads (queue IPC,
     cputime tick overshoot) land in the slope — biasing `s` slightly
     low but never inventing a serial fraction.
  4. Emit a calibrated `StageGraph` (same topology, measured cost +
     serial_frac) that the simulator and oracle consume — the first
     measured sim <-> live closure (benchmarks/proc_calibration.py
     scores how well sim rankings on the calibrated spec match
     proc-measured rankings).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.data.pipeline import StageGraph, StageSpec
from repro.data.proc_executor import ProcessPipeline, SpinWork
from repro.data.simulator import MachineSpec


def fit_amdahl(workers: Sequence[int], rates: Sequence[float]
               ) -> Tuple[float, float]:
    """Least-squares fit of `rate(a) = 1/(cost * (s + (1-s)/a))` over
    (worker count, measured rate) points; returns (cost, serial_frac).

    Closed form via the linearization y = 1/rate, x = 1/a:
    y = cost*s + cost*(1-s)*x, so slope+intercept = cost and
    intercept/(slope+intercept) = s. With a single point the curve is
    underdetermined: cost = 1/rate and serial_frac = 0 are returned.
    """
    pts = [(1.0 / a, 1.0 / r) for a, r in zip(workers, rates)
           if a > 0 and r > 0]
    if not pts:
        raise ValueError("fit_amdahl needs at least one (a>0, rate>0) point")
    if len(pts) == 1 or len({x for x, _ in pts}) == 1:
        return pts[0][1], 0.0   # underdetermined: treat 1/rate as cost
    n = len(pts)
    mx = sum(x for x, _ in pts) / n
    my = sum(y for _, y in pts) / n
    sxx = sum((x - mx) ** 2 for x, _ in pts)
    sxy = sum((x - mx) * (y - my) for x, y in pts)
    slope = sxy / sxx                       # cost * (1 - s)
    intercept = my - slope * mx             # cost * s
    cost = slope + intercept
    if cost <= 0:
        return max(my, 1e-9), 0.0
    serial = min(1.0, max(0.0, intercept / cost))
    return cost, serial


def _slope(xs: Sequence[float], ys: Sequence[float]) -> Optional[float]:
    """Least-squares slope of ys over xs (None when xs has no spread)."""
    n = len(xs)
    mx = sum(xs) / n
    my = sum(ys) / n
    sxx = sum((x - mx) ** 2 for x in xs)
    if sxx <= 0:
        return None
    return sum((x - mx) * (y - my) for x, y in zip(xs, ys)) / sxx


def _drain(pipe: ProcessPipeline):
    """Empty the output queue without blocking (between sweep points)."""
    while True:
        try:
            pipe.get_batch(timeout=0.01)
        except Exception:
            return


def _standalone_graph(st: StageSpec, batch_mb: float) -> StageGraph:
    """One-stage graph isolating `st` as an infinite source (calibration
    measures the stage's own service curve, not the graph's)."""
    solo = dataclasses.replace(st, inputs=())
    return StageGraph(f"cal_{st.name}", (solo,), batch_mb=batch_mb)


def measure_stage_curve(st: StageSpec, workers: Sequence[int], *,
                        window_s: float = 1.2, warmup_s: float = 0.5,
                        ballast: bool = False, machine=None,
                        work: str = "spin") -> Dict[str, List]:
    """Measured service curve of one stage, standalone.

    Runs the stage's work fn as a single-stage ProcessPipeline and, for
    each pool size in `workers`, reads the delivered-counter delta over
    `window_s` plus the pool's CPU-clock delta. Returns
    {"workers", "rate", "occupancy", "percpu"}; `percpu` is the
    measured CPU-seconds consumed per delivered item (None when the
    host exposes no per-process CPU clock), and `rate` is the raw wall
    window rate. The fit should consume `corrected_rates(curve)`.

    `work` picks the unit under measurement: `"spin"` = SpinWork burns,
    `"real"` = the actual featurization transforms (data/featurize.py,
    run standalone with a cached self-generated input so upstream
    transform cost never leaks into this stage's curve). Both realize
    the same clock-disciplined Amdahl contract, so the CPU-normalized
    fit recovers cost/serial_frac from either.
    """
    if machine is None:
        machine = MachineSpec(n_cpus=max(workers), mem_mb=1 << 20)
    spec = _standalone_graph(st, batch_mb=1.0)
    if work == "real":
        from repro.data.featurize import featurize_work_for
        fn = featurize_work_for(st, ballast=ballast, kind="source")
    else:
        fn = SpinWork(st.cost, st.serial_frac,
                      ballast_mb=st.mem_per_worker_mb if ballast else 0.0,
                      kind="source")
    pipe = ProcessPipeline(spec, fns={spec.stages[0].name: fn},
                           queue_depth=8, item_mb=1.0, machine=machine)
    # open the prefetch gate far beyond what a window can deliver: the
    # parent then SLEEPS through the measurement instead of busy-draining
    # — on a small host a polling parent would co-spin with the workers
    # and pollute every point with its own contention
    headroom = max(64.0, 4.0 * (window_s + warmup_s) * max(workers)
                   / max(st.cost, 1e-4))
    out: Dict[str, List] = {"workers": [], "rate": [], "occupancy": [],
                            "percpu": []}
    try:
        for a in workers:
            pool = pipe.pools[0]
            pipe.set_allocation([a], prefetch_mb=headroom)
            time.sleep(warmup_s)                  # settle the new pool
            # sample (delivered, cpu) pairs through the window; the
            # regression slope is the per-item CPU with partial-item
            # boundary noise averaged out (a single end-to-end delta
            # carries up to one in-flight item's CPU per endpoint)
            items_s: List[float] = []
            cpu_s: List[float] = []
            t_s: List[float] = []
            t_end = time.monotonic() + window_s
            while True:
                c = pipe.counters()
                items_s.append(float(c["delivered"]))
                cpu_s.append(pool.cpu_s())
                t_s.append(c["time"])
                now = time.monotonic()
                if now >= t_end:
                    break
                time.sleep(max(0.0, min(window_s / 12.0, t_end - now)))
            _drain(pipe)                          # empty between points
            items = items_s[-1] - items_s[0]
            dt = max(t_s[-1] - t_s[0], 1e-9)
            dcpu = cpu_s[-1] - cpu_s[0]
            percpu = _slope(items_s, cpu_s) if items > 0 and dcpu > 0 \
                else None
            out["workers"].append(int(a))
            out["rate"].append(items / dt)
            out["occupancy"].append(
                min(1.0, dcpu / (a * dt)) if dcpu > 0 else 0.0)
            out["percpu"].append(percpu if percpu and percpu > 0
                                 else None)
    finally:
        pipe.shutdown(drain=False, timeout=5.0)
    return out


def corrected_rates(curve: Dict[str, List]) -> List[float]:
    """The host-noise-free service curve `fit_amdahl` should consume:
    `rate_hat(a) = a / percpu(a)` — per-item CPU is measured in the
    same kernel cputime unit the spin work burns against, so the curve
    is invariant to wall-speed drift, steal, and core contention. Falls
    back to the raw wall rate where no CPU clock was available."""
    return [a / p if p else r
            for a, p, r in zip(curve["workers"], curve["percpu"],
                               curve["rate"])]


def default_sweep(k: Optional[int] = None) -> Tuple[int, ...]:
    """Worker counts to sweep: 1..k (default 3, capped at 4). The
    CPU-normalized fit stays valid past the host's core count — extra
    workers contend on wall time, not on per-item CPU — so the cap is
    about sweep runtime, not about `os.cpu_count()`."""
    k = max(2, min(k if k is not None else 3, 4))
    return tuple(range(1, k + 1))


def calibrate_stagegraph(spec: StageGraph, *,
                         workers: Optional[Sequence[int]] = None,
                         window_s: float = 1.2, warmup_s: float = 0.5,
                         ) -> Tuple[StageGraph, Dict[str, dict]]:
    """Measure every stage's service curve and emit a calibrated
    StageGraph (same topology and memory model; measured cost and
    serial_frac) plus a per-stage report:

        {"workers", "rate", "occupancy", "percpu", "corrected",
         "cost", "serial_frac",               # fitted
         "spec_cost", "spec_serial_frac"}     # declared, for comparison

    For a stable serial_frac fit the stage's serial and parallel burn
    portions should each be >= proc_executor._TICK_GUARD (20ms) — below
    that the burns ride the iteration calibration instead of the CPU
    clock and the fit inherits host-speed drift.

    The calibrated graph is what the simulator/oracle should consume —
    planning then happens against measured dynamics, not declared ones.
    """
    sweep = tuple(workers) if workers is not None else default_sweep()
    report: Dict[str, dict] = {}
    stages = []
    work = getattr(spec, "work", "spin")
    for st in spec.stages:
        curve = measure_stage_curve(st, sweep, window_s=window_s,
                                    warmup_s=warmup_s, work=work)
        corrected = corrected_rates(curve)
        cost, serial = fit_amdahl(curve["workers"], corrected)
        report[st.name] = dict(curve, corrected=corrected, cost=cost,
                               serial_frac=serial, spec_cost=st.cost,
                               spec_serial_frac=st.serial_frac)
        stages.append(dataclasses.replace(st, cost=cost,
                                          serial_frac=serial))
    return spec.replace(name=f"{spec.name}_calibrated",
                        stages=tuple(stages)), report
