"""Runtime constants shared by every driver.

RELAUNCH_TICKS used to live in `benchmarks/common.py` while the fleet
benchmark and the live scaling math re-imported it from there — a
benchmark-private number that every plane actually depends on. It lives
here now; `benchmarks.common.RELAUNCH_TICKS` is a re-export.

OOM_RESTART_TICKS stays defined next to the OOM judge itself
(`repro.data.simulator`) so the data plane cannot drift from it; it is
re-exported here so API users find both windows in one place.
"""
from repro.data.simulator import OOM_RESTART_TICKS

# checkpoint + relaunch dead time a static (*-Adaptive) policy pays to
# adapt: the pipeline process is down for this many ticks
RELAUNCH_TICKS = 20

__all__ = ["RELAUNCH_TICKS", "OOM_RESTART_TICKS"]
