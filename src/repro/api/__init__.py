"""repro.api — the single public runtime API (DESIGN.md §8).

One protocol (`Backend`), one driver (`Session`), one event stream
(`ResizeEvent` / `ChurnEvent` / `DeadWindow`), typed per-tick
(`Telemetry`) and per-run (`RunResult`) results, and a string-keyed
one-liner (`tune`). Every substrate — analytic sim, threaded executor,
fleet sim, live fleet — sits behind a thin adapter; nothing above this
package speaks a substrate dialect directly.
"""
from repro.api.backend import Backend, BackendBase, UnsupportedEventError
from repro.api.backends import (ControllerBackend, DialectBackend,
                                ExecutorBackend, FeedBackend,
                                FleetSimBackend, LiveFleetBackend,
                                ProcessBackend, ProcFleetBackend,
                                SimBackend, as_backend)
from repro.api.constants import OOM_RESTART_TICKS, RELAUNCH_TICKS
from repro.api.events import (ChurnEvent, DeadWindow, Event, ResizeEvent,
                              churn_events, job_churn_events, resize_events)
from repro.api.registry import BACKENDS, make_backend, tune
from repro.api.session import FrozenPolicy, Session
from repro.api.telemetry import RunResult, Telemetry
from repro.api.validation import (AllocationError, validate_allocation,
                                  validate_fleet_allocation)

__all__ = [
    "Backend", "BackendBase", "UnsupportedEventError",
    "ControllerBackend", "DialectBackend", "ExecutorBackend",
    "FeedBackend", "FleetSimBackend", "LiveFleetBackend", "ProcessBackend",
    "ProcFleetBackend", "SimBackend", "as_backend",
    "OOM_RESTART_TICKS", "RELAUNCH_TICKS",
    "ChurnEvent", "DeadWindow", "Event", "ResizeEvent",
    "churn_events", "job_churn_events", "resize_events",
    "BACKENDS", "make_backend", "tune",
    "FrozenPolicy", "Session", "RunResult", "Telemetry",
    "AllocationError", "validate_allocation", "validate_fleet_allocation",
]
