"""Early allocation validation with errors that name the actual mistake.

A negative worker count or a workers vector of the wrong length used to
fail deep inside `PipelineSim.apply` (a nonsense service rate, a numpy
broadcast error) or `ThreadedPipeline.set_allocation` (a silent zip
truncation); a negative prefetch budget quietly produced a negative
memory footprint. Backends validate every proposal at the API boundary
instead, so a policy bug surfaces as an `AllocationError` naming the
offending field.
"""
from __future__ import annotations

from typing import Any

import numpy as np


class AllocationError(ValueError):
    """A proposed Allocation/FleetAllocation is structurally invalid."""


def validate_allocation(spec: Any, alloc: Any) -> None:
    """Reject structurally invalid single-machine Allocations.

    spec: a StageGraph (anything with n_stages); alloc: an Allocation
    (workers + prefetch_mb). Raises AllocationError; returns None on a
    valid allocation.
    """
    workers = np.asarray(alloc.workers)
    if workers.ndim != 1:
        raise AllocationError(
            f"allocation workers must be a 1-D vector, got shape "
            f"{workers.shape}")
    if len(workers) != spec.n_stages:
        raise AllocationError(
            f"allocation has {len(workers)} worker counts but "
            f"{getattr(spec, 'name', 'spec')!r} has {spec.n_stages} stages")
    if not np.issubdtype(workers.dtype, np.integer):
        raise AllocationError(
            f"worker counts must be integers, got dtype {workers.dtype}")
    if (workers < 0).any():
        bad = int(np.argmin(workers))
        raise AllocationError(
            f"negative worker count {int(workers[bad])} for stage "
            f"{spec.stages[bad].name!r}")
    if alloc.prefetch_mb < 0:
        raise AllocationError(
            f"prefetch_mb must be >= 0, got {alloc.prefetch_mb}")


def validate_fleet_allocation(cluster: Any, falloc: Any) -> None:
    """Reject structurally invalid FleetAllocations: every per-trainer
    Allocation is validated against that trainer's pipeline, and grants
    must be non-negative. (Grant totals vs the pool stay the backend's
    contract — they depend on dynamic pool state.)"""
    trainers = {t.name: t for t in cluster.trainers}
    for name, alloc in falloc.allocs.items():
        trainer = trainers.get(name)
        if trainer is None:
            raise AllocationError(
                f"allocation names unknown trainer {name!r}; known: "
                f"{sorted(trainers)}")
        try:
            validate_allocation(trainer.pipeline, alloc)
        except AllocationError as e:
            raise AllocationError(f"trainer {name!r}: {e}") from None
    for name, g in falloc.grants.items():
        if int(g) < 0:
            raise AllocationError(
                f"negative pool grant {int(g)} for trainer {name!r}")
