"""The unified runtime event stream.

Before this package, mid-run disturbances spoke two dialects: the
single-machine `resize_schedule` ([(tick, n_cpus), ...] threaded through
`run_static`/`run_optimizer`) and the fleet plane's `FleetEvent` churn
schedule baked into a ClusterSpec. `Session.run(events=...)` accepts ONE
stream for every backend:

  ResizeEvent(tick, n_cpus)   re-cap the machine (single-machine backends)
                              or the shared elastic pool (fleet backends) —
                              exactly what the dialect's `resize(n)` did.
  ChurnEvent(tick, kind, trainer, n_cpus)
                              fleet membership churn (join / leave /
                              resize / pool), injected into the backend's
                              pending event queue. Fleet backends only.
  DeadWindow(tick, ticks)     the pipeline process is down for `ticks`
                              ticks starting at `tick` (checkpoint +
                              relaunch, the paper's manual-intervention
                              cost). Handled by the Session itself: the
                              backend's clock advances but nothing runs.

Events are plain frozen dataclasses with no backend imports, so schedules
can be built (and serialized) without touching the data plane.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Iterable, List, Sequence, Tuple, Union


@dataclass(frozen=True)
class ResizeEvent:
    """At `tick`, re-cap the backend's CPU capacity to `n_cpus` (the
    machine cap for single-machine backends, the shared pool for fleet
    backends)."""
    tick: int
    n_cpus: int


@dataclass(frozen=True)
class ChurnEvent:
    """At `tick`, fleet membership churn: `trainer` joins/leaves the job,
    its machine is resized to `n_cpus`, or (kind="pool") the shared pool
    is re-capped. Mirrors `repro.data.fleet.FleetEvent` — backends convert
    via the adapter so this module stays import-free."""
    tick: int
    kind: str                  # "join" | "leave" | "resize" | "pool"
    trainer: str = ""
    n_cpus: int = 0


@dataclass(frozen=True)
class DeadWindow:
    """At `tick`, the pipeline process goes down for `ticks` ticks — the
    checkpoint + relaunch window static policies pay to adapt. The
    Session zeroes those ticks without calling the backend's apply."""
    tick: int
    ticks: int


Event = Union[ResizeEvent, ChurnEvent, DeadWindow]


def resize_events(schedule: Iterable[Tuple[int, int]]) -> List[ResizeEvent]:
    """Lift a legacy `resize_schedule` [(tick, n_cpus), ...] into the
    unified event stream."""
    return [ResizeEvent(int(t), int(n)) for t, n in schedule]


def churn_events(events: Sequence) -> List[ChurnEvent]:
    """Lift `repro.data.fleet.FleetEvent`s (e.g. a ClusterSpec's churn
    schedule) into injectable ChurnEvents."""
    return [ChurnEvent(ev.tick, ev.kind, ev.trainer, ev.n_cpus)
            for ev in events]


def job_churn_events(market: Any,
                     schedule: Iterable[Tuple[int, str, str]]
                     ) -> List[ChurnEvent]:
    """JOB-level churn for a MarketSpec: each (tick, kind, job) entry —
    a whole training job joining or leaving the cluster — expands to one
    ChurnEvent per member trainer (spec order within the tick). `market`
    is duck-typed: anything with `job(name).trainers` works, so this
    module stays free of data-plane imports."""
    out: List[ChurnEvent] = []
    for tick, kind, job in schedule:
        if kind not in ("join", "leave"):
            raise ValueError(
                f"job-level churn is join/leave only, got {kind!r}")
        for t in market.job(job).trainers:
            out.append(ChurnEvent(int(tick), kind, t))
    return out
