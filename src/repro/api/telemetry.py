"""Typed telemetry: what a backend reports per tick, and what a run returns.

Every backend used to answer `apply` with an ad-hoc metrics dict whose
keys each consumer grep'd for; `Telemetry` names the five fields every
backend must report and parks backend-specific extras (per-trainer
breakdowns, pool state, rewards) in `extras`. `RunResult` replaces the
`{"throughput": [...], ...}` dicts the benchmark loops returned.

Both are mapping-compatible (`tel["mem_mb"]`, `tel.get("per_trainer")`,
`dict(tel)`), so optimizer `observe` hooks and collectors written against
the dict dialect keep working verbatim while new code gets attributes and
types.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional, Tuple

_CORE = ("throughput", "mem_mb", "used_cpus", "oom", "restarting")

# Feed-boundary fields (ISSUE 6): reported only by backends that sit at
# a host->device feed (FeedBackend). None means "this backend has no
# feed boundary" and the field is omitted from the mapping dialect, so
# dict-shaped consumers of sim/executor telemetry see no new keys.
_FEED = ("device_idle_frac", "step_time_s", "feed_stall_s")

# Freshness fields (ISSUE 7): reported only when the graph has a
# streaming source (kind="stream"). Same None-means-absent contract as
# _FEED, so fig5/fig7 goldens stay byte-identical.
_STREAM = ("backlog_items", "batch_staleness_s", "p99_queue_delay_s")

# Every optional (hidden-when-None) field.
_OPTIONAL = _FEED + _STREAM


class _DictCompat:
    """The dict-dialect shim shared by Telemetry and RunResult: typed
    fields named in `_FIELDS` read first, everything else through
    `extras`. One implementation, so the two mapping dialects cannot
    diverge."""

    _FIELDS: tuple = ()
    extras: Dict[str, Any]

    def keys(self) -> List[str]:
        return list(self._FIELDS) + list(self.extras)

    def __getitem__(self, key: str) -> Any:
        if key in self._FIELDS:
            return getattr(self, key)
        return self.extras[key]

    def get(self, key: str, default: Any = None) -> Any:
        try:
            return self[key]
        except KeyError:
            return default

    def __contains__(self, key: str) -> bool:
        return key in self._FIELDS or key in self.extras

    def __iter__(self) -> Iterator[str]:
        return iter(self.keys())

    def items(self) -> List[Tuple[str, Any]]:
        return [(k, self[k]) for k in self.keys()]

    def values(self) -> List[Any]:
        return [self[k] for k in self.keys()]

    def to_dict(self) -> Dict[str, Any]:
        d = {k: getattr(self, k) for k in self._FIELDS}
        d.update(self.extras)
        return d


@dataclass
class Telemetry(_DictCompat):
    """One tick's outcome, the `Backend.apply` return contract.

    throughput  sustained (sim) or measured (live) batches/s this tick
    mem_mb      the allocation's memory footprint (graph_memory_mb model)
    used_cpus   workers the allocation placed (uncapped; drivers clamp)
    oom         this tick crossed the memory line (process killed)
    restarting  the pipeline is inside a dead/restart window
    extras      backend-specific breakdowns (per_trainer, pool, reward...)

    Feed-boundary fields (None unless the backend sits at a host->device
    feed — see api/backends.FeedBackend and data/device_feed.MeteredFeed):

    device_idle_frac  fraction of the window's wall time the consumer
                      spent blocked waiting on `next(feed)` — the
                      paper's headline metric (accelerator starvation)
    step_time_s       mean wall seconds per train step over the window
    feed_stall_s      total blocked-on-feed seconds over the window

    Freshness fields (None unless the graph has a streaming source —
    see data/stream.ArrivalProcess):

    backlog_items       batches arrived but not yet drained
    batch_staleness_s   age of the batch now leaving the pipeline — the
                        backlog's drain time at the current throughput
    p99_queue_delay_s   p99 of staleness over a sliding window of ticks
    """
    throughput: float = 0.0
    mem_mb: float = 0.0
    used_cpus: int = 0
    oom: bool = False
    restarting: bool = False
    extras: Dict[str, Any] = field(default_factory=dict)
    device_idle_frac: Optional[float] = None
    step_time_s: Optional[float] = None
    feed_stall_s: Optional[float] = None
    backlog_items: Optional[float] = None
    batch_staleness_s: Optional[float] = None
    p99_queue_delay_s: Optional[float] = None

    # Positional construction (`Telemetry(tput, rss, used, False, False,
    # extras)`) is load-bearing across backends and tests, so the feed +
    # stream fields live AFTER extras. The mapping dialect hides them
    # when None.
    _FIELDS = _CORE + _FEED + _STREAM

    def keys(self) -> List[str]:
        return ([k for k in self._FIELDS
                 if k not in _OPTIONAL or getattr(self, k) is not None]
                + list(self.extras))

    def to_dict(self) -> Dict[str, Any]:
        d = {k: getattr(self, k) for k in self._FIELDS
             if k not in _OPTIONAL or getattr(self, k) is not None}
        d.update(self.extras)
        return d

    @classmethod
    def from_metrics(cls, metrics: Dict[str, Any]) -> "Telemetry":
        """Lift a dialect metrics dict; unknown keys land in extras."""
        if isinstance(metrics, Telemetry):
            return metrics
        extras = {k: v for k, v in metrics.items()
                  if k not in _CORE and k not in _OPTIONAL}
        return cls(throughput=metrics.get("throughput", 0.0),
                   mem_mb=metrics.get("mem_mb", 0.0),
                   used_cpus=metrics.get("used_cpus", 0),
                   oom=bool(metrics.get("oom", False)),
                   restarting=bool(metrics.get("restarting", False)),
                   extras=extras,
                   device_idle_frac=metrics.get("device_idle_frac"),
                   step_time_s=metrics.get("step_time_s"),
                   feed_stall_s=metrics.get("feed_stall_s"),
                   backlog_items=metrics.get("backlog_items"),
                   batch_staleness_s=metrics.get("batch_staleness_s"),
                   p99_queue_delay_s=metrics.get("p99_queue_delay_s"))

    @classmethod
    def dead_tick(cls) -> "Telemetry":
        """The zero tick charged inside a dead/relaunch window."""
        return cls(restarting=True)


@dataclass
class RunResult(_DictCompat):
    """A Session run's timeline + terminal accounting.

    The per-tick series align index-for-index with the run's ticks;
    `used_cpus` is clamped to the capacity each proposal was made
    against (the legacy loops' contract). `extras` carries run-level
    artifacts: the live backend's teardown accounting under "live", the
    driving optimizer under "optimizer", legacy fields like "caps".
    """
    throughput: List[float] = field(default_factory=list)
    used_cpus: List[int] = field(default_factory=list)
    mem_mb: List[float] = field(default_factory=list)
    oom_count: int = 0
    extras: Dict[str, Any] = field(default_factory=dict)

    _FIELDS = ("throughput", "used_cpus", "mem_mb", "oom_count")

    @property
    def ticks(self) -> int:
        return len(self.throughput)
