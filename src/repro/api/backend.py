"""The Backend protocol: one runtime surface for every substrate.

PR 3 extracted a fleet-only base (`repro.data.fleet.FleetBackend`) so
FleetSim and LiveFleet could share churn machinery; this module promotes
that idea to the top of the hierarchy. EVERY substrate the repo can run —
the analytic `PipelineSim`, the threaded executor, the analytic
`FleetSim`, the live-executor `LiveFleet` — is driven through this one
protocol via a thin adapter (repro.api.backends), and `Session` is the
only driver loop:

    apply(alloc) -> Telemetry   advance one tick under the allocation
    inject(event)               ResizeEvent / ChurnEvent (fleet only)
    skip_tick() -> Telemetry    advance the clock through a dead window
                                (the process is down; nothing runs)
    snapshot() -> dict          deterministic state summary (seeded
                                backends: byte-stable across same-seed
                                replays; live backends: best-effort)
    shutdown() -> dict          idempotent teardown; live backends return
                                their drop/leak accounting
    machine                     what proposals are made against
                                (MachineSpec or FleetState)
    capacity                    total CPUs placeable right now
    oom_count                   cumulative OOM kills

`BackendBase` supplies the shared behavior: dead-tick telemetry,
shutdown idempotence (the first teardown's accounting is cached and
returned on every later call), and the default event dispatch.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Protocol, runtime_checkable

from repro.api.events import ChurnEvent, Event, ResizeEvent
from repro.api.telemetry import Telemetry


class UnsupportedEventError(TypeError):
    """The backend cannot realize this event kind (e.g. ChurnEvent on a
    single-machine backend)."""


@runtime_checkable
class Backend(Protocol):
    """What `Session` drives. See module docstring for the contract."""

    def apply(self, alloc: Any) -> Telemetry: ...

    def inject(self, event: Event) -> None: ...

    def stats(self) -> Optional[Dict[str, Any]]: ...

    def skip_tick(self) -> Telemetry: ...

    def snapshot(self) -> Dict[str, Any]: ...

    def shutdown(self) -> Dict[str, Any]: ...

    @property
    def machine(self) -> Any: ...

    @property
    def capacity(self) -> int: ...

    @property
    def oom_count(self) -> int: ...


class BackendBase:
    """Shared adapter behavior: idempotent shutdown, dead ticks, event
    dispatch. Subclasses implement `apply`, `_resize`, `_advance_clock`,
    `snapshot`, and the three properties; fleet-capable ones override
    `_churn`."""

    def __init__(self) -> None:
        self._shutdown_acct: Optional[Dict[str, Any]] = None

    # ------------------------------------------------------------ events --
    def inject(self, event: Event) -> None:
        if isinstance(event, ResizeEvent):
            self._resize(int(event.n_cpus))
        elif isinstance(event, ChurnEvent):
            self._churn(event)
        else:
            raise UnsupportedEventError(
                f"{type(self).__name__} cannot inject "
                f"{type(event).__name__} (DeadWindow events are handled "
                f"by the Session, not the backend)")

    def _resize(self, n_cpus: int) -> None:
        raise NotImplementedError

    def _churn(self, event: ChurnEvent) -> None:
        raise UnsupportedEventError(
            f"{type(self).__name__} is a single-machine backend; "
            f"ChurnEvent ({event.kind!r}) needs a fleet backend")

    # ------------------------------------------------------ observations --
    def stats(self) -> Optional[Dict[str, Any]]:
        """Live measurement stats for the optimizer's `propose(...,
        stats=...)` hook (the executor stats() contract). Analytic
        backends return None — policies then observe through their own
        model, which is the legacy sim-path behavior."""
        return None

    # ------------------------------------------------------- dead window --
    def skip_tick(self) -> Telemetry:
        """One tick with the pipeline process down: the clock advances
        (churn schedules keep firing on time) but nothing is applied."""
        self._advance_clock()
        return Telemetry.dead_tick()

    def _advance_clock(self) -> None:
        raise NotImplementedError

    # ---------------------------------------------------------- teardown --
    def shutdown(self) -> Dict[str, Any]:
        """Idempotent: the first call tears down and caches its
        accounting; every later call returns the same dict."""
        if self._shutdown_acct is None:
            self._shutdown_acct = self._do_shutdown()
        return self._shutdown_acct

    def _check_open(self) -> None:
        """Adapters call this at the top of apply(): running a torn-down
        backend is a named error on every substrate, not an
        AttributeError from whichever resource happened to be freed."""
        if self._shutdown_acct is not None:
            raise RuntimeError(
                f"{type(self).__name__} is shut down; build a fresh "
                f"backend to run again")

    def _do_shutdown(self) -> Dict[str, Any]:
        return {}

    def __enter__(self) -> "BackendBase":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.shutdown()
