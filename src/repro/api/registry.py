"""String-keyed registries + the one-liner: `repro.api.tune(...)`.

    from repro.api import tune
    from repro.data.pipeline import criteo_pipeline
    from repro.data.simulator import MachineSpec

    res = tune(criteo_pipeline(), MachineSpec(n_cpus=64),
               optimizer="intune", backend="sim", ticks=300)

The backend name picks the substrate KIND; the spec type picks the
plane: a StageGraph runs on PipelineSim ("sim") or a real
ThreadedPipeline ("live"/"executor"); a ClusterSpec runs on FleetSim
("sim") or LiveFleet ("live"). Optimizer names come from the existing
registries (`make_optimizer` / `make_fleet_optimizer`): "intune",
"oracle", "autotune", ... and "fleet_intune", "fleet_even", ... — or
pass a constructed Optimizer instance directly.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Sequence, Union

from repro.api.backend import Backend
from repro.api.backends import (ExecutorBackend, FleetSimBackend,
                                LiveFleetBackend, ProcessBackend,
                                ProcFleetBackend, SimBackend)
from repro.api.session import Session
from repro.api.telemetry import RunResult
from repro.data.fleet import ClusterSpec

# (plane, name) -> adapter class. "executor" is an alias for "live" on
# both planes; registering here is all a new substrate needs to be
# reachable from tune().
BACKENDS = {
    ("single", "sim"): SimBackend,
    ("single", "live"): ExecutorBackend,
    ("single", "proc"): ProcessBackend,
    ("fleet", "sim"): FleetSimBackend,
    ("fleet", "live"): LiveFleetBackend,
    ("fleet", "proc"): ProcFleetBackend,
}
_ALIASES = {"executor": "live", "process": "proc"}


def make_backend(name: str, spec: Any, machine: Any = None, *,
                 seed: int = 0, **kw: Any) -> Backend:
    """Build a registered backend for `spec` (StageGraph or ClusterSpec).
    Extra keyword args go to the adapter (window_s, obs_noise, ...)."""
    plane = "fleet" if isinstance(spec, ClusterSpec) else "single"
    key = (plane, _ALIASES.get(name, name))
    if key not in BACKENDS:
        known = sorted({n for p, n in BACKENDS if p == plane}
                       | {a for a, t in _ALIASES.items()
                          if (plane, t) in BACKENDS})
        raise KeyError(f"unknown {plane} backend {name!r}; known: {known}")
    cls = BACKENDS[key]
    if plane == "fleet":
        if machine is not None:
            raise TypeError(
                f"backend {name!r} over a ClusterSpec carries its own "
                f"per-trainer machines; machine={machine!r} would be "
                f"silently ignored — drop it")
        return cls(spec, seed=seed, **kw)
    if machine is None:
        raise TypeError(
            f"backend {name!r} over a StageGraph needs a MachineSpec "
            f"(got machine=None); only ClusterSpec backends carry their "
            f"own machines")
    return cls(spec, machine, seed=seed, **kw)


def tune(spec: Any, machine: Any = None, *,
         optimizer: Union[str, Any] = "intune", backend: str = "sim",
         ticks: int = 600, seed: int = 0,
         events: Optional[Sequence[Any]] = None,
         relaunch_dead: int = 0,
         collect: Optional[Callable[..., None]] = None,
         optimizer_kw: Optional[dict] = None,
         backend_kw: Optional[dict] = None) -> RunResult:
    """One line from spec to tuned run: build the backend and the
    optimizer by name, drive them through a Session, tear down, and
    return the RunResult (live accounting under `extras["live"]`, the
    optimizer instance under `extras["optimizer"]`)."""
    # resolve the optimizer FIRST: a bad name/kw must fail before a live
    # backend spawns threads it would then leak
    if isinstance(optimizer, str):
        if isinstance(spec, ClusterSpec):
            from repro.core.optimizer import make_fleet_optimizer
            opt = make_fleet_optimizer(optimizer, spec, seed=seed,
                                       **(optimizer_kw or {}))
        else:
            from repro.core.optimizer import make_optimizer
            opt = make_optimizer(optimizer, spec, machine, seed=seed,
                                 **(optimizer_kw or {}))
    else:
        opt = optimizer
    be = make_backend(backend, spec, machine, seed=seed,
                      **(backend_kw or {}))
    try:
        res = Session(be, opt, spec=spec).run(
            ticks, events=events, relaunch_dead=relaunch_dead,
            collect=collect)
    finally:
        acct = be.shutdown()
    if acct:
        res.extras["live"] = acct
    return res
