"""Thin adapters putting every substrate behind the Backend protocol.

One adapter per substrate — none of them reimplements dynamics; they
translate the protocol onto the substrate's existing surface and lift
metric dicts into `Telemetry`:

  SimBackend         PipelineSim (analytic single machine)
  ExecutorBackend    ThreadedPipeline (real threads, measured throughput,
                     budget-enforced OOM — the single-machine LiveFleet)
  ProcessBackend     ProcessPipeline (real processes: true CPU
                     contention, RSS-measured OOM, real serial sections)
  FeedBackend        a user-owned ProcessPipeline feeding a REAL train
                     loop through data/device_feed.MeteredFeed — no
                     sleep windows; the train loop owns the clock and
                     the backend reports device-idle telemetry
  FleetSimBackend    FleetSim (N analytic trainers + pool + churn)
  LiveFleetBackend   LiveFleet (N real ThreadedPipelines)
  ControllerBackend  the legacy paper-protocol path: the InTune
                     controller's own env simulator is authoritative and
                     the Session just clocks `tuner.tick()` (used with
                     optimizer=None; this is what keeps the published
                     fig5/fig7 linear-chain numbers byte-identical)

`as_backend` wraps an already-constructed substrate (or any object
speaking the legacy machine/apply/resize dialect) for the deprecation
shims in benchmarks.common.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, Optional

import numpy as np

from repro.api.backend import BackendBase
from repro.api.events import ChurnEvent
from repro.api.telemetry import Telemetry
from repro.api.validation import validate_allocation, validate_fleet_allocation
from repro.data.executor import ThreadedPipeline
from repro.data.fleet import (ClusterSpec, FleetBackend, FleetEvent,
                              FleetSim, TrainerSpec)
from repro.data.live_fleet import RigSlot, _TrainerRig
from repro.data.simulator import (MachineSpec, OOM_RESTART_TICKS,
                                  PipelineSim, graph_memory_mb)


class SimBackend(BackendBase):
    """The analytic `PipelineSim` behind the protocol."""

    def __init__(self, spec: Any = None,
                 machine: Optional[MachineSpec] = None,
                 *, model_latency: float = 0.0, seed: int = 0,
                 obs_noise: float = 0.02,
                 sim: Optional[PipelineSim] = None) -> None:
        super().__init__()
        self.sim = sim if sim is not None else PipelineSim(
            spec, machine, model_latency, seed=seed, obs_noise=obs_noise)
        self.spec = self.sim.spec

    def apply(self, alloc: Any) -> Telemetry:
        self._check_open()
        validate_allocation(self.spec, alloc)
        return Telemetry.from_metrics(self.sim.apply(alloc))

    def _resize(self, n_cpus: int) -> None:
        self.sim.resize(n_cpus)

    def _advance_clock(self) -> None:
        self.sim.time += 1

    def snapshot(self) -> Dict[str, Any]:
        return {"time": self.sim.time, "oom_count": self.sim.oom_count,
                "restart_left": self.sim.restart_left,
                "n_cpus": self.sim.machine.n_cpus}

    @property
    def machine(self) -> MachineSpec:
        return self.sim.machine

    @property
    def capacity(self) -> int:
        return self.sim.machine.n_cpus

    @property
    def oom_count(self) -> int:
        return self.sim.oom_count


class _SingleRigBackend(BackendBase):
    """Shared plumbing for the single-machine live backends (threaded
    ExecutorBackend, process-based ProcessBackend): one `RigSlot` holds
    the rig + OOM lifecycle; this base owns everything around it —
    protocol properties, snapshot, resize, the measurement window, and
    teardown accounting — so only each plane's `apply` judge differs."""

    def __init__(self, window_s: float, queue_depth: int) -> None:
        super().__init__()
        self.window_s = float(window_s)
        self.queue_depth = queue_depth
        self.time = 0

    def _launch(self, eff_cpus: Optional[int] = None) -> Any:
        raise NotImplementedError

    def _measure_window(self, cap: int, alloc: Any) -> float:
        """Apply the allocation, sleep one window, return the measured
        consumed-batch rate (the live-throughput contract)."""
        self._slot.prepare(cap, alloc)
        before = self._slot.rig.counters()
        time.sleep(self.window_s)
        return ThreadedPipeline.window_rate(before,
                                            self._slot.rig.counters())

    def _rig_extras(self) -> Dict[str, Any]:
        """The measured stats() carried in Telemetry.extras, so learning
        observers take their live branch — the next-state comes from the
        same measurement source the agent acted on."""
        return {k: v for k, v in self._slot.rig.pipe.stats().items()
                if k != "throughput"}

    def stats(self) -> Optional[Dict[str, Any]]:
        """The live stats() observation for propose(..., stats=...);
        None while the process is down (OOM restart window)."""
        return self._slot.rig.pipe.stats() if self._slot.live else None

    # ---------------------------------------------------------- protocol --
    def _resize(self, n_cpus: int) -> None:
        self._machine = dataclasses.replace(self._machine, n_cpus=n_cpus)
        if self._slot.live:
            self._slot.rig.set_eff_cpus(n_cpus)

    def _advance_clock(self) -> None:
        self.time += 1

    @property
    def restart_left(self) -> int:
        return self._slot.restart_left

    @property
    def crash_lost(self) -> int:
        return self._slot.crash_lost

    @property
    def all_joined(self) -> bool:
        return self._slot.all_joined

    def snapshot(self) -> Dict[str, Any]:
        return {"time": self.time, "oom_count": self._slot.oom_count,
                "restart_left": self._slot.restart_left,
                "n_cpus": self._machine.n_cpus}

    def _do_shutdown(self) -> Dict[str, Any]:
        dropped = self._slot.close(drain=True)
        return {"dropped_batches": dropped,
                "crash_lost": self._slot.crash_lost,
                "all_joined": self._slot.all_joined,
                "oom_count": self._slot.oom_count}

    @property
    def machine(self) -> MachineSpec:
        return self._machine

    @property
    def capacity(self) -> int:
        return self._machine.n_cpus

    @property
    def oom_count(self) -> int:
        return self._slot.oom_count


class ExecutorBackend(_SingleRigBackend):
    """A REAL ThreadedPipeline behind the protocol: the single-machine
    live backend.

    Two modes:
      - owned (default): builds a `_TrainerRig` — sleep-based stage fns
        realizing the spec's true costs plus a consumer thread modeling
        `1/model_latency` demand — and enforces the simulator's contract:
        measured window throughput, budget-based OOM (over-budget kill +
        OOM_RESTART_TICKS dead window + relaunch), over-subscription
        charged in accounting.
      - `ExecutorBackend.wrap(pipe)`: adopts a user-constructed pipeline
        (real stage fns, the training loop consuming via get_batch).
        Throughput is still the measured consumed-counter delta; OOM is
        REPORTED (the oom flag) but not enforced — the backend cannot
        relaunch user code it did not build.
    """

    def __init__(self, spec: Any = None,
                 machine: Optional[MachineSpec] = None,
                 *, model_latency: float = 0.0, window_s: float = 0.05,
                 queue_depth: int = 8, seed: int = 0,
                 pipe: Optional[ThreadedPipeline] = None) -> None:
        # seed is accepted for factory-signature parity with SimBackend
        # (thread scheduling is the noise source here, not an RNG)
        super().__init__(window_s, queue_depth)
        self._over_budget = False
        if pipe is not None:
            self.spec = pipe.spec
            self._machine = pipe.machine
            self._trainer = None
            self._slot = RigSlot(self._launch, rig=_ExternalRig(pipe))
            self._enforce_oom = False
        else:
            self.spec = spec
            self._machine = machine
            self._trainer = TrainerSpec(spec.name, spec, machine,
                                        model_latency)
            self._slot = RigSlot(self._launch)
            self._slot.rig = self._launch(machine.n_cpus)
            self._enforce_oom = True

    @classmethod
    def wrap(cls, pipe: ThreadedPipeline, *,
             window_s: float = 0.05) -> "ExecutorBackend":
        """Adopt an existing user pipeline (external consumer)."""
        return cls(pipe=pipe, window_s=window_s)

    def _launch(self, eff_cpus: Optional[int] = None) -> _TrainerRig:
        if eff_cpus is None:
            eff_cpus = self._machine.n_cpus
        return _TrainerRig(self._trainer, eff_cpus, self.queue_depth)

    # ------------------------------------------------------------- tick ---
    def apply(self, alloc: Any) -> Telemetry:
        self._check_open()
        validate_allocation(self.spec, alloc)
        mem = graph_memory_mb(self.spec, alloc.workers, alloc.prefetch_mb)
        used = int(np.sum(alloc.workers))
        cap = self._machine.n_cpus
        self.time += 1
        # the shared RigSlot lifecycle: dead-window countdown + relaunch,
        # budget-OOM kill (the simulator's judge verbatim), crash-loss
        # accounting — one implementation with LiveFleet's per-trainer tick
        if self._slot.tick_dead_window(cap):
            return Telemetry(0.0, mem, used, False, True)
        if self._enforce_oom and mem > self._machine.mem_mb:
            self._slot.kill()
            return Telemetry(0.0, mem, used, True, True)
        tput = self._measure_window(cap, alloc)
        if self._enforce_oom:
            # owned rigs only: sleeps don't contend like real CPUs, so
            # charge the simulator's proportional over-subscription
            # slowdown in accounting. A wrapped user pipeline runs real
            # stage fns whose contention the measured rate already shows.
            tput = RigSlot.discount(tput, used, cap)
        # wrap mode reports (but cannot enforce) OOM: count each ENTRY
        # into the over-budget state so oom_count stays meaningful even
        # though the user-owned process is never killed
        oom_flag = (not self._enforce_oom) and mem > self._machine.mem_mb
        if oom_flag and not self._over_budget:
            self._slot.oom_count += 1
        self._over_budget = oom_flag
        return Telemetry(tput, mem, used, oom_flag, False,
                         self._rig_extras())


class _ExternalRig:
    """Rig-shaped shim over a user-owned ThreadedPipeline (no consumer
    thread — the user's training loop is the consumer)."""

    def __init__(self, pipe: ThreadedPipeline) -> None:
        self.pipe = pipe

    def set_allocation(self, alloc: Any) -> None:
        self.pipe.set_allocation(alloc.workers, alloc.prefetch_mb)

    def set_eff_cpus(self, n: int) -> None:
        self.pipe.machine = dataclasses.replace(self.pipe.machine,
                                                n_cpus=int(n))

    def counters(self) -> dict:
        return self.pipe.counters()

    def teardown(self, drain: bool = True, timeout: float = 5.0) -> dict:
        return self.pipe.shutdown(drain=drain, timeout=timeout)


class ProcessBackend(_SingleRigBackend):
    """A REAL ProcessPipeline behind the protocol: one OS-process pool
    per stage (registered as "proc" in `repro.api.registry.BACKENDS`).

    Everything the threaded backend charges in accounting is physics
    here:

      - throughput is the measured consumed-counter delta over a
        `window_s` window, with TRUE CPU contention — there is no
        over-subscription discount; over-placing workers slows the
        measured rate because cores actually run out;
      - memory is MEASURED: the OOM judge fires on the pipeline's
        sampled resident bytes (`ProcessPipeline.rss_mb`: each worker's
        private growth since spawn, from /proc) against
        `machine.mem_mb`, then pays the same
        kill + OOM_RESTART_TICKS dead window + relaunch lifecycle as
        every other plane (the shared `RigSlot`). The spec's
        `mem_per_worker_mb` is physically allocated per worker
        (SpinWork ballast), so the memory knob moves real pages;
      - `serial_frac` is realized by a real per-stage cross-process
        serialized section (calibratable live: `repro.data.calibrate`).
    """

    def __init__(self, spec: Any = None,
                 machine: Optional[MachineSpec] = None,
                 *, model_latency: float = 0.0, window_s: float = 0.1,
                 queue_depth: int = 8, seed: int = 0, ballast: bool = True,
                 rss_interval: float = 0.2) -> None:
        # seed: factory-signature parity (OS scheduling is the noise)
        super().__init__(window_s, queue_depth)
        self.ballast = ballast
        self.rss_interval = rss_interval
        self.spec = spec
        self._machine = machine
        self._trainer = TrainerSpec(spec.name, spec, machine, model_latency)
        self._slot = RigSlot(self._launch)
        self._slot.rig = self._launch(machine.n_cpus)
        # freshness accounting for streaming graphs (inert otherwise)
        self._stale = 0.0
        self._delay_win: deque = deque(maxlen=100)

    def _launch(self, eff_cpus: Optional[int] = None) -> _TrainerRig:
        from repro.data.proc_executor import ProcessPipeline, stage_fns_for
        if eff_cpus is None:
            eff_cpus = self._machine.n_cpus

        def make_pipe(trainer: TrainerSpec, eff: int,
                      queue_depth: int) -> "ProcessPipeline":
            return ProcessPipeline(
                trainer.pipeline,
                fns=stage_fns_for(trainer.pipeline, ballast=self.ballast),
                queue_depth=queue_depth,
                machine=dataclasses.replace(trainer.machine, n_cpus=eff),
                rss_interval=self.rss_interval)

        return _TrainerRig(self._trainer, eff_cpus, self.queue_depth,
                           make_pipe=make_pipe)

    # ------------------------------------------------------------- tick ---
    def apply(self, alloc: Any) -> Telemetry:
        self._check_open()
        validate_allocation(self.spec, alloc)
        used = int(np.sum(alloc.workers))
        cap = self._machine.n_cpus
        self.time += 1
        if self._slot.tick_dead_window(cap):
            # process is down: nothing resident to measure (unlike the
            # threaded plane there is no accounting model to report)
            return Telemetry(0.0, 0.0, used, False, True)
        tput = self._measure_window(cap, alloc)
        rss = self._slot.rig.pipe.rss_mb()
        if rss > self._machine.mem_mb:
            # the measured-RSS OOM judge: same kill + dead window +
            # relaunch lifecycle, but the verdict comes from /proc, not
            # from the graph_memory_mb declaration
            self._slot.kill()
            return Telemetry(0.0, rss, used, True, True)
        # NO over-subscription discount: the contention is physical and
        # already inside the measured rate
        extras = self._rig_extras()
        stream = self._stream_fields(tput)
        if stream:
            extras.pop("backlog_items", None)   # promoted to typed field
        return Telemetry(tput, rss, used, False, False, extras, **stream)

    def _stream_fields(self, tput: float) -> Dict[str, Any]:
        """Freshness fields from the pipe's exact stream accounting
        (arrivals - emitted); {} for non-stream graphs. Staleness is the
        backlog's drain time at the measured rate; while fully stalled
        with work queued it ages by the wall window instead. A relaunch
        after an RSS OOM RESUMES the stream epoch (RigSlot carries
        `stream_epoch()` across the kill and the fresh pipe adopts it),
        so backlog keeps accruing through the dead window exactly as the
        sim retains it across its restart window."""
        state = getattr(self._slot.rig.pipe, "stream_state", lambda: None)()
        if state is None:
            return {}
        backlog = float(state["backlog"])
        if tput > 1e-9:
            self._stale = backlog / tput
        elif backlog > 1e-9:
            self._stale += self.window_s
        else:
            self._stale = 0.0
        self._delay_win.append(self._stale)
        return {"backlog_items": backlog,
                "batch_staleness_s": self._stale,
                "p99_queue_delay_s": float(np.percentile(self._delay_win,
                                                         99.0))}


class FeedBackend(BackendBase):
    """A user-owned ProcessPipeline feeding a REAL train loop, metered at
    the host->device boundary (the proc->device bridge, ISSUE 6).

    Every other live backend owns the clock: `apply` sleeps through a
    measurement window while a synthetic consumer drains the pipe. Here
    the TRAIN LOOP owns the clock — it pulls batches through a
    `MeteredFeed` (data/device_feed.make_train_feed) between ticks — so
    the backend never sleeps. `measure()` differences the pipe and feed
    counters since the previous call and charges the window that the
    training actually ran:

      throughput        consumed-batch delta / wall delta
      device_idle_frac  the paper's accelerator-starvation metric.
                        With `device_step_s` given (the uncontended
                        per-step device time, measured at warmup):
                        1 - busy*device_step_s / wall, where busy is
                        the batch delta CAPPED at the pipe's produced
                        delta — every wall second beyond pure device
                        compute is charged to ingestion, which is the
                        right accounting when trainer and pipeline
                        share host cores (the feed steals silicon
                        instead of letting the consumer block), and a
                        window that merely drains buffered inventory
                        earns no idle credit for its allocation.
                        Without it: feed stall delta / wall
                        delta (blocked-in-next time), the right metric
                        when the train step runs on a real accelerator
                        the pipeline cannot contend with.
      step_time_s       wall delta / batches stepped
      feed_stall_s      the raw blocked-in-next seconds

    `apply(alloc)` only retargets the pipeline (`set_allocation`) and
    returns the last measured window — there is nothing new to measure
    until the train loop has run more steps. `apply(None)` measures.
    OOM is REPORTED, not enforced (measured RSS over budget counts one
    oom per entry into the over-budget state): the backend cannot kill
    and relaunch a pipeline whose consumer is user code mid-step.
    `Session.step()` drives this backend one train-step window at a time.
    """

    def __init__(self, pipe: Any, feed: Any, *,
                 machine: Optional[MachineSpec] = None,
                 device_step_s: Optional[float] = None) -> None:
        super().__init__()
        self.pipe = pipe
        self.feed = feed
        self.device_step_s = device_step_s
        self.spec = pipe.spec
        self._machine = machine if machine is not None else pipe.machine
        self.time = 0
        self._oom_count = 0
        self._over_budget = False
        self._settle = 0
        self._mark_pipe = pipe.counters()
        self._mark_feed = feed.counters()
        self._last_tel = Telemetry(extras={"pending": True})

    # ------------------------------------------------------------- tick ---
    def measure(self) -> Telemetry:
        """Close the window opened by the previous measure(): difference
        the counters, judge OOM, cache + return the Telemetry."""
        self._check_open()
        self.time += 1
        now_p = self.pipe.counters()
        now_f = self.feed.counters()
        wall = max(now_f["time"] - self._mark_feed["time"], 1e-9)
        batches = now_f["batches"] - self._mark_feed["batches"]
        stall = now_f["stall_s"] - self._mark_feed["stall_s"]
        consumed = now_p["consumed"] - self._mark_pipe["consumed"]
        produced = now_p["delivered"] - self._mark_pipe["delivered"]
        prev_pipe_t = self._mark_pipe["time"]
        self._mark_pipe, self._mark_feed = now_p, now_f
        rss = self.pipe.rss_mb()
        over = rss > self._machine.mem_mb
        if over and not self._over_budget:
            self._oom_count += 1
        self._over_budget = over
        stats = self.pipe.stats()
        # stats-minus-throughput in extras: the "stage_latency" key is
        # what flips learning observers (InTune._live_obs) onto their
        # measured branch, same as the other live backends
        extras = {k: v for k, v in stats.items() if k != "throughput"}
        # raw window deltas for callers that need raw attribution data
        extras["produced"] = produced
        extras["consumed"] = consumed
        # THE settling flag (ISSUE 7: centralizes the per-driver
        # `produced == 0` heuristics): the first window after a worker
        # resize is flagged — fresh workers spend ~0.2s self-calibrating
        # before producing — and the flag holds while the pipe stays
        # unproductive, up to a bound so a genuinely starved allocation
        # is eventually charged honestly
        resize_at = now_p.get("last_resize_at", 0.0)
        if resize_at > prev_pipe_t:
            self._settle = 1
        elif self._settle and produced <= 0 and self._settle < 4:
            self._settle += 1
        else:
            self._settle = 0
        extras["settling"] = bool(self._settle)
        if self.device_step_s is not None:
            # busy credit is capped at what the pipeline PRODUCED this
            # window: a window that drains buffered inventory can step
            # the device at full speed for a moment under any
            # allocation, and crediting that would hand best-tracking
            # optimizers transient idle~0 windows unrelated to the
            # allocation under test. Long-run averages are unchanged
            # (buffers are finite); only short-window attribution is.
            sustained = min(batches, max(produced, 0.0))
            idle = 1.0 - sustained * self.device_step_s / wall
        else:
            idle = stall / wall
        self._last_tel = Telemetry(
            consumed / wall, rss,
            int(np.sum(stats.get("workers", []))), over, False, extras,
            device_idle_frac=min(1.0, max(0.0, idle)),
            step_time_s=(wall / batches) if batches > 0 else None,
            feed_stall_s=stall)
        return self._last_tel

    def apply(self, alloc: Any) -> Telemetry:
        self._check_open()
        if alloc is None:
            return self.measure()
        validate_allocation(self.spec, alloc)
        self.pipe.set_allocation(list(alloc.workers), alloc.prefetch_mb)
        return self._last_tel

    # ---------------------------------------------------------- protocol --
    def stats(self) -> Optional[Dict[str, Any]]:
        return self.pipe.stats()

    def _resize(self, n_cpus: int) -> None:
        self._machine = dataclasses.replace(self._machine, n_cpus=n_cpus)
        self.pipe.machine = dataclasses.replace(self.pipe.machine,
                                                n_cpus=n_cpus)
        self.pipe.apply_cpu_cap()

    def _advance_clock(self) -> None:
        self.time += 1

    def snapshot(self) -> Dict[str, Any]:
        return {"time": self.time, "oom_count": self._oom_count,
                "n_cpus": self._machine.n_cpus}

    def _do_shutdown(self) -> Dict[str, Any]:
        # stop the feed's producer thread FIRST: a prefetcher still
        # pulling get_batch during pipe teardown races the queue close
        close = getattr(self.feed, "close", None)
        if close is not None:
            close()
        acct = self.pipe.shutdown(drain=False, timeout=10.0)
        return {"dropped_batches": int(acct.get("dropped", 0)),
                "all_joined": bool(acct.get("joined", False)),
                "oom_count": self._oom_count}

    @property
    def machine(self) -> MachineSpec:
        return self._machine

    @property
    def capacity(self) -> int:
        return self._machine.n_cpus

    @property
    def oom_count(self) -> int:
        return self._oom_count


class _FleetAdapter(BackendBase):
    """Shared fleet adaptation: both fleet substrates subclass
    `repro.data.fleet.FleetBackend`, so the protocol mapping is
    identical — only construction and teardown differ."""

    inner: FleetBackend

    def __init__(self, inner: FleetBackend) -> None:
        super().__init__()
        self.inner = inner
        self.spec = inner.cluster

    def apply(self, falloc: Any) -> Telemetry:
        self._check_open()
        validate_fleet_allocation(self.spec, falloc)
        m = dict(self.inner.apply(falloc))
        per = m.get("per_trainer")
        if per is not None:
            m["per_trainer"] = {n: Telemetry.from_metrics(d)
                                for n, d in per.items()}
        return Telemetry.from_metrics(m)

    def _resize(self, n_cpus: int) -> None:
        self.inner.resize(n_cpus)         # fleet dialect: pool re-cap

    def _churn(self, event: ChurnEvent) -> None:
        self.inner.inject_event(FleetEvent(
            tick=event.tick, kind=event.kind, trainer=event.trainer,
            n_cpus=event.n_cpus))

    def _advance_clock(self) -> None:
        self.inner.time += 1

    def snapshot(self) -> Dict[str, Any]:
        state = self.inner.machine
        return {"time": self.inner.time, "pool": self.inner.pool,
                "active": state.active, "base_cpus": state.base_cpus,
                "oom_count": self.inner.oom_count}

    @property
    def machine(self) -> Any:
        return self.inner.machine         # FleetState

    @property
    def capacity(self) -> int:
        return self.inner.machine.n_cpus

    @property
    def oom_count(self) -> int:
        return self.inner.oom_count


class FleetSimBackend(_FleetAdapter):
    """The analytic FleetSim behind the protocol."""

    def __init__(self, cluster: Optional[ClusterSpec] = None, *,
                 seed: int = 0, obs_noise: float = 0.02,
                 sim: Optional[FleetSim] = None) -> None:
        super().__init__(sim if sim is not None
                         else FleetSim(cluster, seed=seed,
                                       obs_noise=obs_noise))

    def snapshot(self) -> Dict[str, Any]:
        snap = super().snapshot()
        snap["trainers"] = {
            n: {"time": s.time, "oom_count": s.oom_count,
                "restart_left": s.restart_left,
                "n_cpus": s.machine.n_cpus}
            for n, s in sorted(self.inner.sims.items())}
        return snap


class LiveFleetBackend(_FleetAdapter):
    """The live-executor LiveFleet behind the protocol; `shutdown()`
    returns its drop/leak accounting."""

    def __init__(self, cluster: Optional[ClusterSpec] = None, *,
                 seed: int = 0, window_s: float = 0.1,
                 queue_depth: int = 8, fleet: Any = None) -> None:
        if fleet is None:
            from repro.data.live_fleet import LiveFleet
            fleet = LiveFleet(cluster, seed=seed, window_s=window_s,
                              queue_depth=queue_depth)
        super().__init__(fleet)

    def _do_shutdown(self) -> Dict[str, Any]:
        return self.inner.close()


class ProcFleetBackend(_FleetAdapter):
    """The process-plane fleet (ProcFleet: one ProcessPipeline per
    trainer, real CPU contention, measured-RSS OOM) behind the protocol;
    `shutdown()` returns its drop/leak accounting."""

    def __init__(self, cluster: Optional[ClusterSpec] = None, *,
                 seed: int = 0, window_s: float = 0.1,
                 queue_depth: int = 8, ballast: bool = True,
                 rss_interval: float = 0.2, fleet: Any = None) -> None:
        if fleet is None:
            from repro.data.live_fleet import ProcFleet
            fleet = ProcFleet(cluster, seed=seed, window_s=window_s,
                              queue_depth=queue_depth, ballast=ballast,
                              rss_interval=rss_interval)
        super().__init__(fleet)

    def _do_shutdown(self) -> Dict[str, Any]:
        return self.inner.close()


class ControllerBackend(BackendBase):
    """The legacy paper-protocol path behind the protocol: the InTune
    controller's internal env simulator is authoritative and each apply
    is one self-driving `tuner.tick()`. Use with `Session(backend)` and
    no optimizer — the published fig5/fig7 linear-chain benchmarks run
    through exactly this, keeping their golden JSONs byte-identical."""

    def __init__(self, tuner: Any) -> None:
        super().__init__()
        self.tuner = tuner
        self.spec = tuner.spec

    def apply(self, alloc: Any) -> Telemetry:
        self._check_open()
        if alloc is not None:
            raise TypeError(
                "ControllerBackend is self-driving: run it with "
                "Session(backend) and no optimizer (the controller "
                "ignores external proposals)")
        return Telemetry.from_metrics(self.tuner.tick())

    def _resize(self, n_cpus: int) -> None:
        self.tuner.resize(n_cpus)

    def _advance_clock(self) -> None:
        self.tuner.env.sim.time += 1

    def snapshot(self) -> Dict[str, Any]:
        sim = self.tuner.env.sim
        return {"time": sim.time, "oom_count": sim.oom_count,
                "restart_left": sim.restart_left,
                "n_cpus": sim.machine.n_cpus}

    @property
    def machine(self) -> MachineSpec:
        return self.tuner.env.sim.machine

    @property
    def capacity(self) -> int:
        return self.tuner.env.sim.machine.n_cpus

    @property
    def oom_count(self) -> int:
        return self.tuner.env.sim.oom_count


def as_backend(obj: Any) -> BackendBase:
    """Wrap an already-constructed substrate. Known substrates get their
    typed adapter; anything else speaking the legacy machine/apply/resize
    dialect gets `DialectBackend` (no validation — the shim of last
    resort for custom sim_factory objects)."""
    if isinstance(obj, BackendBase):
        return obj
    if isinstance(obj, PipelineSim):
        return SimBackend(sim=obj)
    if isinstance(obj, FleetSim):
        return FleetSimBackend(sim=obj)
    from repro.data.live_fleet import LiveFleet
    if isinstance(obj, LiveFleet):
        return LiveFleetBackend(fleet=obj)
    if isinstance(obj, ThreadedPipeline):
        return ExecutorBackend.wrap(obj)
    return DialectBackend(obj)


class DialectBackend(BackendBase):
    """Adapter of last resort over the legacy driver dialect
    (`machine` / `apply(alloc) -> dict` / `resize(n)` / `time` /
    `oom_count`)."""

    def __init__(self, inner: Any) -> None:
        super().__init__()
        self.inner = inner
        self.spec = getattr(inner, "spec", getattr(inner, "cluster", None))

    def apply(self, alloc: Any) -> Telemetry:
        self._check_open()
        return Telemetry.from_metrics(self.inner.apply(alloc))

    def _resize(self, n_cpus: int) -> None:
        self.inner.resize(n_cpus)

    def _advance_clock(self) -> None:
        self.inner.time += 1

    def snapshot(self) -> Dict[str, Any]:
        return {"time": getattr(self.inner, "time", None),
                "oom_count": getattr(self.inner, "oom_count", 0)}

    @property
    def machine(self) -> Any:
        return self.inner.machine

    @property
    def capacity(self) -> int:
        return self.inner.machine.n_cpus

    @property
    def oom_count(self) -> int:
        return getattr(self.inner, "oom_count", 0)
